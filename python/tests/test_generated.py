"""L2 correctness: the DSL-compiler-generated step modules must be
numerically identical to the canonical model.py forms, and the AOT pipeline
must produce loadable HLO text for them."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref

GEN_DIR = os.path.join(os.path.dirname(__file__), "..", "compile", "generated")

gen_missing = not os.path.exists(os.path.join(GEN_DIR, "sssp_step.py"))
needs_gen = pytest.mark.skipif(
    gen_missing, reason="run `starplat compile --backend jax` first"
)


def ell_fixture(n=64, w=5, seed=0):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, n, size=(n, w), dtype=np.int32)
    mask = (rng.random((n, w)) < 0.5).astype(np.float32)
    rows = np.arange(n, dtype=np.int32)[:, None]
    idx = np.where(mask > 0, idx, rows)
    wgt = np.where(mask > 0, rng.integers(1, 100, (n, w)), 0).astype(np.int32)
    return jnp.asarray(idx), jnp.asarray(wgt), jnp.asarray(mask)


@needs_gen
def test_generated_sssp_matches_model():
    from compile.generated import sssp_step as gen

    idx, wgt, mask = ell_fixture()
    dist = jnp.asarray(np.where(np.arange(64) == 0, 0, ref.INF).astype(np.int32))
    a_new, a_fin = gen.sssp_step(dist, idx, wgt, mask)
    b_new, b_fin = model.sssp_step(dist, idx, wgt, mask)
    np.testing.assert_array_equal(np.asarray(a_new), np.asarray(b_new))
    assert int(a_fin) == int(b_fin)


@needs_gen
def test_generated_pr_matches_model():
    from compile.generated import pr_step as gen

    idx, _, mask = ell_fixture(seed=3)
    pr = jnp.full((64,), 1 / 64, jnp.float32)
    outdeg = jnp.asarray(np.random.default_rng(1).integers(1, 9, 64).astype(np.float32))
    a_val, a_diff = gen.pr_step(pr, idx, mask, outdeg, 0.85, 64.0)
    b_val, b_diff = model.pr_step(pr, idx, mask, outdeg, 0.85, 64.0)
    np.testing.assert_allclose(np.asarray(a_val), np.asarray(b_val), rtol=1e-6)
    assert float(a_diff) == pytest.approx(float(b_diff), rel=1e-6)


@needs_gen
def test_generated_bc_and_tc_match_model():
    from compile.generated import bc_step as bgen
    from compile.generated import tc_step as tgen

    idx, _, mask = ell_fixture(seed=5)
    level = jnp.asarray(np.where(np.arange(64) == 0, 0, -1).astype(np.int32))
    sigma = jnp.asarray(np.where(np.arange(64) == 0, 1.0, 0.0).astype(np.float32))
    a = bgen.bc_fwd_step(level, sigma, 0, idx, mask)
    b = model.bc_fwd_step(level, sigma, 0, idx, mask)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y))

    rng = np.random.default_rng(7)
    adj = (rng.random((64, 64)) < 0.2).astype(np.float32)
    adj = np.triu(adj, 1)
    adj = adj + adj.T
    assert float(tgen.tc_step(jnp.asarray(adj))) == pytest.approx(
        float(model.tc_step(jnp.asarray(adj)))
    )


@needs_gen
def test_generated_plans_have_host_loop_metadata():
    import json

    for algo, template in [
        ("sssp", "fixedpoint-relax"),
        ("pr", "dowhile-rank"),
        ("bc", "bfs-fwd-rev"),
        ("tc", "dense-matmul-count"),
    ]:
        path = os.path.join(GEN_DIR, f"{algo}.plan.json")
        with open(path) as f:
            plan = json.load(f)
        assert plan["template"] == template
        assert plan["outputs"], f"{algo} plan has no outputs"


def test_aot_hlo_text_is_parseable_shape():
    """Lower one step and sanity-check the HLO text envelope the rust
    runtime expects (ENTRY + tuple root)."""
    from compile.aot import specs_for, to_hlo_text

    g = {"n": 60, "n_pad": 64, "width_in": 4, "n_dense": 64}
    lowered = jax.jit(model.sssp_step).lower(*specs_for("sssp", g))
    hlo = to_hlo_text(lowered)
    assert "ENTRY" in hlo
    assert "s32[64" in hlo  # state vector shape is baked in
