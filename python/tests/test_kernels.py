"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, swept over
shapes and contents with hypothesis. This is the core kernel signal."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import kernels
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def make_ell(rng, n_pad, width, weighted=True):
    """Random valid ELL arrays: idx in range, mask 0/1, sentinel = self."""
    idx = rng.integers(0, n_pad, size=(n_pad, width), dtype=np.int32)
    mask = (rng.random((n_pad, width)) < 0.4).astype(np.float32)
    # sentinel entries point at the row itself (as the rust packer does)
    rows = np.arange(n_pad, dtype=np.int32)[:, None]
    idx = np.where(mask > 0, idx, rows)
    wgt = (
        rng.integers(1, 100, size=(n_pad, width), dtype=np.int32)
        if weighted
        else np.ones((n_pad, width), np.int32)
    )
    wgt = np.where(mask > 0, wgt, 0).astype(np.int32)
    return idx, wgt, mask


shape_strategy = st.tuples(
    st.sampled_from([4, 16, 64, 256, 512]),  # n_pad (multiples of block or smaller)
    st.integers(min_value=1, max_value=24),  # width
    st.integers(min_value=0, max_value=2**31 - 1),
)


@given(shape_strategy)
def test_ell_relax_matches_ref(params):
    n_pad, width, seed = params
    rng = np.random.default_rng(seed)
    idx, wgt, mask = make_ell(rng, n_pad, width)
    dist = rng.integers(0, 1000, size=n_pad).astype(np.int32)
    dist[rng.random(n_pad) < 0.3] = ref.INF  # unreachable mix
    got = kernels.ell_relax(jnp.asarray(dist), jnp.asarray(idx), jnp.asarray(wgt), jnp.asarray(mask))
    want = ref.ell_relax_ref(jnp.asarray(dist), jnp.asarray(idx), jnp.asarray(wgt), jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(shape_strategy)
def test_ell_spmv_matches_ref(params):
    n_pad, width, seed = params
    rng = np.random.default_rng(seed)
    idx, _, mask = make_ell(rng, n_pad, width)
    contrib = rng.random(n_pad).astype(np.float32)
    got = kernels.ell_spmv(jnp.asarray(contrib), jnp.asarray(idx), jnp.asarray(mask))
    want = ref.ell_spmv_ref(jnp.asarray(contrib), jnp.asarray(idx), jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@given(shape_strategy)
def test_ell_frontier_matches_ref(params):
    n_pad, width, seed = params
    rng = np.random.default_rng(seed)
    idx, _, mask = make_ell(rng, n_pad, width)
    level = rng.integers(-1, 4, size=n_pad).astype(np.int32)
    depth = int(rng.integers(0, 4))
    got = kernels.ell_frontier(jnp.asarray(level), depth, jnp.asarray(idx), jnp.asarray(mask))
    want = ref.ell_frontier_ref(jnp.asarray(level), depth, jnp.asarray(idx), jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    st.sampled_from([8, 32, 128, 256]),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_tc_matmul_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    a = (rng.random((n, n)) < 0.15).astype(np.float32)
    a = np.triu(a, 1)
    a = a + a.T  # symmetric, no self loops
    got = float(kernels.tc_matmul(jnp.asarray(a)))
    want = float(ref.tc_matmul_ref(jnp.asarray(a)))
    assert got == pytest.approx(want, rel=1e-5)


def test_tc_on_known_graphs():
    # K3 has one triangle, K4 has four.
    def complete(n):
        a = np.ones((n, n), np.float32) - np.eye(n, dtype=np.float32)
        return jnp.asarray(a)

    assert float(kernels.tc_matmul(complete(3))) == pytest.approx(1.0)
    assert float(kernels.tc_matmul(complete(4))) == pytest.approx(4.0)


def test_bc_steps_on_path_graph():
    """Hand-checked Brandes on the path 0-1-2 (in/out ELL views identical
    for an undirected path)."""
    n = 4  # one padding row
    width = 2
    idx = np.array([[1, 0], [0, 2], [1, 2], [3, 3]], np.int32)
    mask = np.array([[1, 0], [1, 1], [1, 0], [0, 0]], np.float32)
    level = np.full(n, -1, np.int32)
    level[0] = 0
    sigma = np.zeros(n, np.float32)
    sigma[0] = 1.0

    lvl, sig = jnp.asarray(level), jnp.asarray(sigma)
    depth = 0
    while True:
        lvl, sig, fin = kernels.bc_forward(lvl, sig, depth, jnp.asarray(idx), jnp.asarray(mask))
        if int(fin) == 1:
            break
        depth += 1
    np.testing.assert_array_equal(np.asarray(lvl)[:3], [0, 1, 2])
    np.testing.assert_allclose(np.asarray(sig)[:3], [1, 1, 1])

    delta = jnp.zeros(n, jnp.float32)
    bc = jnp.zeros(n, jnp.float32)
    for d in range(depth, -1, -1):
        delta, bc = kernels.bc_backward(
            lvl, sig, delta, bc, d, 0, jnp.asarray(idx), jnp.asarray(mask)
        )
    # from src=0 on a path, vertex 1 carries one dependent vertex
    np.testing.assert_allclose(np.asarray(bc)[:3], [0.0, 1.0, 0.0], atol=1e-6)


def test_relax_converges_to_dijkstra_on_small_graph():
    """End-to-end fixedPoint loop in python: triangle + pendant graph."""
    # edges: 0-1 (2), 1-2 (3), 0-2 (10), 2-3 (1), undirected
    n_pad, width = 4, 3
    idx = np.array([[1, 2, 0], [0, 2, 1], [0, 1, 3], [2, 3, 3]], np.int32)
    wgt = np.array([[2, 10, 0], [2, 3, 0], [10, 3, 1], [1, 0, 0]], np.int32)
    mask = np.array([[1, 1, 0], [1, 1, 0], [1, 1, 1], [1, 0, 0]], np.float32)
    dist = np.full(n_pad, ref.INF, np.int32)
    dist[0] = 0
    d = jnp.asarray(dist)
    for _ in range(n_pad + 1):
        cand = kernels.ell_relax(d, jnp.asarray(idx), jnp.asarray(wgt), jnp.asarray(mask))
        new = jnp.minimum(d, cand)
        if bool(jnp.all(new == d)):
            break
        d = new
    np.testing.assert_array_equal(np.asarray(d), [0, 2, 5, 6])
