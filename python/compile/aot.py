"""AOT lowering: step functions → HLO text artifacts + manifest.json.

Interchange is HLO *text*, not serialized protos: jax >= 0.5 emits 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser reassigns
ids (see /opt/xla-example/README.md and DESIGN.md).

Shapes come from `artifacts/graphs/shapes.json`, written by
`starplat export-graphs` (the rust side regenerates identical ELL arrays at
run time — generation is deterministic). Without shapes.json a small default
shape set is built so pytest can exercise the pipeline standalone.

Usage: cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import importlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

S = jax.ShapeDtypeStruct
I32, F32 = jnp.int32, jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def step_fn(algo_fn_name):
    """Prefer the DSL-compiler-generated module; fall back to model.py."""
    algo = algo_fn_name.split("_")[0]
    try:
        mod = importlib.import_module(f"compile.generated.{algo}_step")
        if hasattr(mod, algo_fn_name):
            return getattr(mod, algo_fn_name), f"generated.{algo}_step"
    except ImportError:
        pass
    return getattr(model, algo_fn_name), "model"


def specs_for(algo, g):
    """Input ShapeDtypeStructs per artifact, in the order the rust runtime
    feeds literals (see backends/xla)."""
    n, w = g["n_pad"], g["width_in"]
    ell = [S((n, w), I32), S((n, w), I32), S((n, w), F32)]  # idx, wgt, mask
    ell_nw = [S((n, w), I32), S((n, w), F32)]  # idx, mask
    if algo in ("sssp", "cc"):
        return [S((n,), I32)] + ell
    if algo == "bfs":
        return [S((n,), I32), S((), I32)] + ell_nw
    if algo == "pr":
        return [S((n,), F32)] + ell_nw + [S((n,), F32), S((), F32), S((), F32)]
    if algo == "bc_fwd":
        return [S((n,), I32), S((n,), F32), S((), I32)] + ell_nw
    if algo == "bc_bwd":
        return [
            S((n,), I32),
            S((n,), F32),
            S((n,), F32),
            S((n,), F32),
            S((), I32),
            S((), I32),
        ] + ell_nw
    if algo == "tc":
        nd = g["n_dense"]
        return [S((nd, nd), F32)]
    raise ValueError(algo)


ARTIFACT_FNS = {
    "sssp": "sssp_step",
    "cc": "cc_step",
    "bfs": "bfs_step",
    "pr": "pr_step",
    "bc_fwd": "bc_fwd_step",
    "bc_bwd": "bc_bwd_step",
    "tc": "tc_step",
}


def default_shapes():
    return {
        "scale": 0,
        "graphs": [
            {"short": "TEST", "n": 200, "n_pad": 256, "width_in": 16, "n_dense": 256}
        ],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--algos", default="sssp,cc,bfs,pr,bc_fwd,bc_bwd,tc")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    shapes_path = os.path.join(args.out, "graphs", "shapes.json")
    if os.path.exists(shapes_path):
        with open(shapes_path) as f:
            shapes = json.load(f)
    else:
        print(f"[aot] {shapes_path} missing — using default test shapes")
        shapes = default_shapes()

    manifest = {"scale": shapes.get("scale", 0), "artifacts": []}
    for g in shapes["graphs"]:
        for algo in args.algos.split(","):
            fn, origin = step_fn(ARTIFACT_FNS[algo])
            specs = specs_for(algo, g)
            lowered = jax.jit(fn).lower(*specs)
            hlo = to_hlo_text(lowered)
            fname = f"{algo}_{g['short']}.hlo.txt"
            with open(os.path.join(args.out, fname), "w") as f:
                f.write(hlo)
            manifest["artifacts"].append(
                {
                    "algo": algo,
                    "graph": g["short"],
                    "file": fname,
                    "origin": origin,
                    "n": g["n"],
                    "n_pad": g["n_pad"],
                    "width": g["width_in"],
                    "n_dense": g.get("n_dense", g["n_pad"]),
                }
            )
            print(f"[aot] {fname}: {len(hlo)} chars (from {origin})")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {len(manifest['artifacts'])} artifacts + manifest.json")


if __name__ == "__main__":
    main()
