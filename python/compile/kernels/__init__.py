"""Kernel library for the JAX backend (L1 of the three-layer stack).

`ell_relax`, `ell_spmv`, `ell_frontier`, `tc_matmul` are Pallas kernels
(interpret=True on CPU PJRT); `bc_forward` / `bc_backward` compose them with
jnp glue at L2. Every function has a pure-jnp oracle in `ref.py`.
"""

import jax.numpy as jnp

from .ell import ell_frontier, ell_relax, ell_spmv, tc_matmul
from .ref import INF


def bc_forward(level, sigma, depth, idx, mask):
    """Brandes forward wavefront: discover depth+1, accumulate sigma.

    Gathers share the ELL tiles with `ell_frontier`; the arithmetic after
    the gather is cheap elementwise work, kept at L2 (see DESIGN.md §2).
    """
    gathered_level = jnp.take(level, idx, axis=0)
    parents = jnp.logical_and(mask > 0, gathered_level == depth)
    has_parent = jnp.any(parents, axis=1)
    fresh = jnp.logical_and(level < 0, has_parent)
    new_level = jnp.where(fresh, depth + 1, level)
    sigma_in = jnp.take(sigma, idx, axis=0)
    sigma_add = jnp.sum(jnp.where(parents, sigma_in, 0.0), axis=1)
    new_sigma = jnp.where(fresh, sigma + sigma_add, sigma)
    finished = jnp.logical_not(jnp.any(fresh)).astype(jnp.int32)
    return new_level, new_sigma, finished


def bc_backward(level, sigma, delta, bc, depth, src, idx, mask):
    """Brandes reverse sweep for vertices at `depth` (out-edge ELL view)."""
    child_level = jnp.take(level, idx, axis=0)
    children = jnp.logical_and(mask > 0, child_level == depth + 1)
    sigma_w = jnp.take(sigma, idx, axis=0)
    delta_w = jnp.take(delta, idx, axis=0)
    safe_sigma_w = jnp.where(children, sigma_w, 1.0)
    contrib = (sigma[:, None] / safe_sigma_w) * (1.0 + delta_w)
    acc = jnp.sum(jnp.where(children, contrib, 0.0), axis=1)
    at_depth = level == depth
    new_delta = jnp.where(at_depth, acc, delta)
    n = level.shape[0]
    not_src = jnp.arange(n) != src
    new_bc = bc + jnp.where(jnp.logical_and(at_depth, not_src), new_delta, 0.0)
    return new_delta, new_bc


__all__ = [
    "INF",
    "bc_backward",
    "bc_forward",
    "ell_frontier",
    "ell_relax",
    "ell_spmv",
    "tc_matmul",
]
