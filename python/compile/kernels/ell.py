"""Pallas kernels over the padded ELL layout (DESIGN.md §2).

The paper's warp-per-vertex CSR traversal becomes row-blocked dense tiles:
each grid step owns `BLOCK_ROWS` vertices whose `[BLOCK_ROWS, width]`
index/weight/mask tiles stream HBM→VMEM via BlockSpec, while the gathered
state vector (`dist` / `contrib`) stays VMEM-resident. interpret=True is
mandatory on CPU PJRT (real-TPU lowering emits Mosaic custom-calls).

VMEM budget (estimated in DESIGN.md §7): a block holds
  BLOCK_ROWS*width*(4+4+4)B (idx/wgt/mask) + N*4B (state) + BLOCK_ROWS*4B.
With BLOCK_ROWS=256, width<=512, N<=16384: ~1.6 MiB — comfortably under
the ~16 MiB/core VMEM of a TPUv4.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import INF

BLOCK_ROWS = 256


def _block_rows(n_pad):
    return min(BLOCK_ROWS, n_pad)


def _relax_kernel(dist_ref, idx_ref, wgt_ref, mask_ref, out_ref):
    dist = dist_ref[...]          # full state vector (VMEM-resident)
    idx = idx_ref[...]            # [B, W] row tile
    wgt = wgt_ref[...]
    mask = mask_ref[...]
    gathered = jnp.take(dist, idx, axis=0)
    cand = jnp.where(mask > 0, gathered + wgt, INF)
    cand = jnp.where(gathered >= INF, INF, cand)
    out_ref[...] = jnp.min(cand, axis=1).astype(dist.dtype)


def ell_relax(dist, idx, wgt, mask):
    """Min-plus relaxation (SSSP/BFS/CC step). Matches ref.ell_relax_ref."""
    n_pad, width = idx.shape
    b = _block_rows(n_pad)
    grid = (n_pad // b,)
    return pl.pallas_call(
        _relax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(dist.shape, lambda i: (0,)),       # whole vector
            pl.BlockSpec((b, width), lambda i: (i, 0)),
            pl.BlockSpec((b, width), lambda i: (i, 0)),
            pl.BlockSpec((b, width), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), dist.dtype),
        interpret=True,
    )(dist, idx, wgt, mask)


def _spmv_kernel(contrib_ref, idx_ref, mask_ref, out_ref):
    contrib = contrib_ref[...]
    idx = idx_ref[...]
    mask = mask_ref[...]
    gathered = jnp.take(contrib, idx, axis=0)
    out_ref[...] = jnp.sum(gathered * mask, axis=1).astype(contrib.dtype)


def ell_spmv(contrib, idx, mask):
    """Masked gather-sum (PageRank pull step). Matches ref.ell_spmv_ref."""
    n_pad, width = idx.shape
    b = _block_rows(n_pad)
    grid = (n_pad // b,)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(contrib.shape, lambda i: (0,)),
            pl.BlockSpec((b, width), lambda i: (i, 0)),
            pl.BlockSpec((b, width), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), contrib.dtype),
        interpret=True,
    )(contrib, idx, mask)


def _frontier_kernel(level_ref, depth_ref, idx_ref, mask_ref, out_ref):
    level = level_ref[...]
    depth = depth_ref[0]
    idx = idx_ref[...]
    mask = mask_ref[...]
    gathered = jnp.take(level, idx, axis=0)
    out_ref[...] = jnp.any(jnp.logical_and(mask > 0, gathered == depth), axis=1)


def ell_frontier(level, depth, idx, mask):
    """has-parent-at-depth test (BFS wavefront). Matches ell_frontier_ref."""
    n_pad, width = idx.shape
    b = _block_rows(n_pad)
    grid = (n_pad // b,)
    depth_arr = jnp.asarray(depth, jnp.int32).reshape((1,))
    return pl.pallas_call(
        _frontier_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(level.shape, lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((b, width), lambda i: (i, 0)),
            pl.BlockSpec((b, width), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.bool_),
        interpret=True,
    )(level, depth_arr, idx, mask)


def _tc_kernel(a_rows_ref, a_cols_ref, a_tile_ref, out_ref):
    # MXU-friendly tile: (B, N) @ (N, B) then mask by the (B, B) tile.
    a_rows = a_rows_ref[...]
    a_cols = a_cols_ref[...]
    a_tile = a_tile_ref[...]
    paths2 = jnp.dot(a_rows, a_cols, preferred_element_type=jnp.float32)
    out_ref[0, 0] = jnp.sum(paths2 * a_tile)


@functools.partial(jax.jit, static_argnames=("block",))
def tc_matmul(adj, block=256):
    """Triangle count = sum((A@A) ⊙ A) / 6, tiled for the MXU systolic array
    (the TPU re-think of the paper's per-edge binary search — DESIGN.md §2).
    """
    n = adj.shape[0]
    b = min(block, n)
    grid = (n // b, n // b)
    partial = pl.pallas_call(
        _tc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, n), lambda i, j: (i, 0)),
            pl.BlockSpec((n, b), lambda i, j: (0, j)),
            pl.BlockSpec((b, b), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(grid, jnp.float32),
        interpret=True,
    )(adj, adj, adj)
    return jnp.sum(partial) / 6.0
