"""Pure-jnp oracles for every kernel in the library.

These are the L1 correctness ground truth: each Pallas kernel must match its
oracle to float tolerance (pytest + hypothesis sweeps in python/tests/).
"""

import jax.numpy as jnp

# Must equal rust's `reference::INF` (i32::MAX / 2) so distances round-trip.
INF = 2147483647 // 2


def ell_relax_ref(dist, idx, wgt, mask):
    """Pull min-plus relaxation over ELL in-edges.

    cand[v] = min_k mask[v,k] ? dist[idx[v,k]] + wgt[v,k] : INF
    """
    gathered = jnp.take(dist, idx, axis=0)
    cand = jnp.where(mask > 0, gathered + wgt, INF)
    # guard against overflow when dist is INF
    cand = jnp.where(gathered >= INF, INF, cand)
    return jnp.min(cand, axis=1).astype(dist.dtype)


def ell_spmv_ref(contrib, idx, mask):
    """sums[v] = sum_k mask[v,k] * contrib[idx[v,k]] (PageRank pull)."""
    gathered = jnp.take(contrib, idx, axis=0)
    return jnp.sum(gathered * mask, axis=1).astype(contrib.dtype)


def ell_frontier_ref(level, depth, idx, mask):
    """has_parent[v] = any_k mask[v,k] & level[idx[v,k]] == depth."""
    gathered = jnp.take(level, idx, axis=0)
    return jnp.any(jnp.logical_and(mask > 0, gathered == depth), axis=1)


def bc_forward_ref(level, sigma, depth, idx, mask):
    """One Brandes forward wavefront over in-edges (ELL pull).

    Returns (level', sigma', finished:int32).
    """
    gathered_level = jnp.take(level, idx, axis=0)
    parents = jnp.logical_and(mask > 0, gathered_level == depth)
    has_parent = jnp.any(parents, axis=1)
    fresh = jnp.logical_and(level < 0, has_parent)
    new_level = jnp.where(fresh, depth + 1, level)
    sigma_in = jnp.take(sigma, idx, axis=0)
    sigma_add = jnp.sum(jnp.where(parents, sigma_in, 0.0), axis=1)
    new_sigma = jnp.where(fresh, sigma + sigma_add, sigma)
    finished = jnp.logical_not(jnp.any(fresh)).astype(jnp.int32)
    return new_level, new_sigma, finished


def bc_backward_ref(level, sigma, delta, bc, depth, src, idx, mask):
    """One Brandes reverse sweep step for vertices at `depth` (ELL push view:
    idx/mask are OUT-edges). Returns (delta', bc')."""
    child_level = jnp.take(level, idx, axis=0)
    children = jnp.logical_and(mask > 0, child_level == depth + 1)
    sigma_w = jnp.take(sigma, idx, axis=0)
    delta_w = jnp.take(delta, idx, axis=0)
    safe_sigma_w = jnp.where(children, sigma_w, 1.0)
    contrib = (sigma[:, None] / safe_sigma_w) * (1.0 + delta_w)
    acc = jnp.sum(jnp.where(children, contrib, 0.0), axis=1)
    at_depth = level == depth
    new_delta = jnp.where(at_depth, acc, delta)
    n = level.shape[0]
    not_src = jnp.arange(n) != src
    new_bc = bc + jnp.where(jnp.logical_and(at_depth, not_src), new_delta, 0.0)
    return new_delta, new_bc


def tc_matmul_ref(adj):
    """T = sum((A @ A) * A) / 6 on the dense symmetric 0/1 adjacency."""
    paths2 = adj @ adj
    return (jnp.sum(paths2 * adj) / 6.0).astype(jnp.float32)
