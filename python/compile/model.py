"""L2: canonical per-algorithm step functions over padded ELL arrays.

These mirror exactly what the DSL compiler's JAX backend emits into
`compile/generated/` (the golden tests in python/tests/test_generated.py
assert the equivalence). aot.py prefers the generated modules when present
and falls back to these canonical forms, so the AOT pipeline works before
the first `starplat compile` run.

Conventions shared with the rust runtime (backends/xla):
- every convergence flag is int32 (1 = finished) — the §4.1 OR-flag word;
- state arrays come first, then loop scalars, then the ELL arrays.
"""

import jax.numpy as jnp

from compile import kernels


def sssp_step(dist, idx, wgt, mask):
    """fixedPoint body: pull min-plus relaxation (SSSP / CC / BFS family)."""
    cand = kernels.ell_relax(dist, idx, wgt, mask)
    new = jnp.minimum(dist, cand)
    changed = new < dist
    finished = jnp.logical_not(jnp.any(changed)).astype(jnp.int32)
    return new, finished


# CC is the same relaxation with weight-0 edges over component labels.
cc_step = sssp_step


def bfs_step(level, depth, idx, mask):
    """Level-synchronous BFS hop (Fig 9 kernel)."""
    has_parent = kernels.ell_frontier(level, depth, idx, mask)
    fresh = jnp.logical_and(level < 0, has_parent)
    new = jnp.where(fresh, depth + 1, level)
    finished = jnp.logical_not(jnp.any(fresh)).astype(jnp.int32)
    return new, finished


def pr_step(pageRank, idx, mask, outdeg, delta, num_nodes):
    """do-while body: double-buffered PageRank pull (Fig 7 analog)."""
    contrib = pageRank / jnp.maximum(outdeg, 1.0)
    sums = kernels.ell_spmv(contrib, idx, mask)
    val = (1.0 - delta) / num_nodes + delta * sums
    diff = jnp.sum(jnp.abs(val - pageRank))
    return val, diff


def bc_fwd_step(level, sigma, depth, idx, mask):
    """Brandes forward wavefront (iterateInBFS body)."""
    return kernels.bc_forward(level, sigma, depth, idx, mask)


def bc_bwd_step(level, sigma, delta, bc, depth, src, idx, mask):
    """Brandes reverse sweep (iterateInReverse body)."""
    return kernels.bc_backward(level, sigma, delta, bc, depth, src, idx, mask)


def tc_step(adj):
    """Triangle count on the dense adjacency (MXU formulation)."""
    return kernels.tc_matmul(adj)
