//! Quickstart: parse a StarPlat program, type-check it, generate code for
//! every accelerator backend, and execute it on a small graph with the CPU
//! interpreter — all through the public API.
//!
//! Run: cargo run --release --example quickstart

use starplat::backends::interp::{self, Args, Mode};
use starplat::codegen;
use starplat::dsl::parser::parse;
use starplat::graph::generators::rmat;
use starplat::ir::lower;
use starplat::sema::check_function;

const SSSP: &str = r#"
// Bellman-Ford SSSP, straight from the paper's §3.5 example.
function ComputeSSSP(Graph g, propNode<int> dist, propEdge<int> weight,
                     node src) {
  propNode<bool> modified;
  propNode<bool> modified_nxt;
  g.attachNodeProperty(dist = INF, modified = False, modified_nxt = False);
  src.modified = True;
  src.dist = 0;
  bool finished = False;
  fixedPoint until (finished: !modified) {
    forall (v in g.nodes().filter(modified == True)) {
      forall (nbr in g.neighbors(v)) {
        edge e = g.get_edge(v, nbr);
        <nbr.dist, nbr.modified_nxt> = <Min(nbr.dist, v.dist + e.weight), True>;
      }
    }
    modified = modified_nxt;
    g.attachNodeProperty(modified_nxt = False);
  }
}
"#;

fn main() -> anyhow::Result<()> {
    // 1. front-end: parse + type-check
    let fns = parse(SSSP).map_err(|e| anyhow::anyhow!("{e}"))?;
    let tf = check_function(&fns[0]).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("parsed `{}` with {} node properties", tf.func.name, tf.node_props.len());

    // 2. one IR, many backends (the paper's headline)
    let ir = lower(&tf);
    for backend in codegen::TEXT_BACKENDS {
        let src = codegen::generate(backend, &ir)?;
        println!("  {backend:8} -> {} lines", starplat::util::count_loc(&src));
    }

    // 3. execute the same program on a synthetic graph
    let g = rmat("demo", 500, 2500, 42);
    let out = interp::run(&tf, &g, &Args::default().node("src", 0), Mode::Par)?;
    let dist = out.prop_i64("dist");
    let reached = dist
        .iter()
        .filter(|&&d| d < starplat::algorithms::reference::INF as i64)
        .count();
    println!(
        "SSSP on {} ({} nodes, {} edges): reached {reached} vertices, dist[17] = {}",
        g.name,
        g.num_nodes(),
        g.num_edges(),
        dist[17]
    );

    // 4. cross-check against Dijkstra
    let oracle = starplat::algorithms::reference::dijkstra(&g, 0);
    assert!(dist.iter().zip(&oracle).all(|(a, b)| *a == *b as i64));
    println!("matches Dijkstra ✓");
    Ok(())
}
