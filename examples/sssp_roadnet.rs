//! Domain scenario from the paper's intro: shortest paths on a road
//! network. Generates a large road grid (the usaroad stand-in), runs the
//! DSL-compiled SSSP through the interpreter and (if built) the XLA
//! artifacts, and reports the route structure — the kind of query a
//! navigation domain-expert would issue without writing CUDA.
//!
//! Run: cargo run --release --example sssp_roadnet [-- --side 120]

use starplat::algorithms::reference;
use starplat::backends::interp::{self, Args, Mode};
use starplat::coordinator::driver::{load_program, Algo};
use starplat::graph::generators::road_grid;
use starplat::util::bench::time_once;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let side = args
        .iter()
        .position(|a| a == "--side")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(100usize);
    let g = road_grid("roadnet", side, side, 7);
    println!(
        "road network: {}x{side} grid, {} intersections, {} road segments",
        side,
        g.num_nodes(),
        g.num_edges()
    );

    let tf = load_program(Algo::Sssp)?;
    let src = 0u32;
    let (secs, out) =
        time_once(|| interp::run(&tf, &g, &Args::default().node("src", src), Mode::Par));
    let dist = out?.prop_i64("dist");

    // farthest reachable intersection = the network's weighted eccentricity
    let (far, far_d) = dist
        .iter()
        .enumerate()
        .filter(|(_, &d)| d < reference::INF as i64)
        .max_by_key(|(_, &d)| d)
        .unwrap();
    println!("DSL SSSP finished in {secs:.3}s");
    println!(
        "farthest intersection from depot 0: node {far} at weighted distance {far_d} \
         (grid corner is node {})",
        g.num_nodes() - 1
    );

    // sanity: exact agreement with Dijkstra
    let oracle = reference::dijkstra(&g, src);
    assert!(dist.iter().zip(&oracle).all(|(a, b)| *a == *b as i64));
    println!("verified against Dijkstra ✓");

    // the paper's observation: road networks have huge diameters, which is
    // what makes level-synchronous BC slow on US/GR in Tables 3-4.
    let hops = reference::bfs_levels(&g, src);
    let max_hops = hops.iter().filter(|&&h| h < reference::INF).max().unwrap();
    println!("unweighted eccentricity: {max_hops} hops (large diameter regime)");
    Ok(())
}
