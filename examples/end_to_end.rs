//! End-to-end driver (the repo's headline workload — see EXPERIMENTS.md):
//!
//! 1. builds the Table-2 graph suite at the artifact scale;
//! 2. runs all four paper algorithms through every executable path —
//!    hand-written baselines, the DSL interpreter (seq + par) and, when
//!    `make artifacts` has produced them, the AOT-compiled XLA artifacts
//!    generated from the same DSL sources;
//! 3. cross-checks every backend's checksum against the oracles;
//! 4. prints a compact Table-3/4-style report with timings.
//!
//! Run: make artifacts && cargo run --release --example end_to_end

use starplat::algorithms::reference;
use starplat::backends::xla::XlaBackend;
use starplat::coordinator::driver::{run_cell, Algo, Backend, PR_BETA, PR_DAMPING, PR_MAX_ITER};
use starplat::graph::generators::sample_sources;
use starplat::graph::suite::build_suite;
use starplat::util::table::{fmt_secs, Table};

fn main() -> anyhow::Result<()> {
    let xla = match XlaBackend::open(std::path::Path::new("artifacts")) {
        Ok(x) => Some(x),
        Err(e) => {
            eprintln!("note: XLA artifacts unavailable ({e}); running CPU paths only");
            None
        }
    };
    let scale = xla.as_ref().map(|x| x.rt.scale).unwrap_or(600);
    let suite = build_suite(scale);
    println!(
        "end-to-end: {} graphs at scale {scale}, {} backends\n",
        suite.len(),
        3 + xla.is_some() as usize
    );

    let mut failures = 0;
    for (algo, name) in
        [(Algo::Sssp, "SSSP"), (Algo::Pr, "PR"), (Algo::Bc, "BC"), (Algo::Tc, "TC")]
    {
        let mut t = Table::new(
            &format!("{name} — all executable paths (seconds; ✓ = checksum matches oracle)"),
            &["Graph", "oracle", "lonestar", "gunrock", "interp-par", "xla"],
        );
        for e in &suite {
            let sources = sample_sources(&e.graph, 5, 7);
            // oracle checksum
            let oracle = match algo {
                Algo::Sssp => reference::dijkstra(&e.graph, sources[0])
                    .iter()
                    .map(|&d| if d >= reference::INF { 0.0 } else { d as f64 })
                    .sum::<f64>(),
                Algo::Pr => {
                    reference::pagerank(&e.graph, PR_BETA, PR_DAMPING, PR_MAX_ITER).iter().sum()
                }
                Algo::Bc => reference::betweenness(&e.graph, &sources).iter().sum(),
                Algo::Tc => reference::triangle_count(&e.graph) as f64,
                _ => 0.0,
            };
            let mut row = vec![e.short.to_string(), format!("{oracle:.1}")];
            for backend in [Backend::Lonestar, Backend::Gunrock, Backend::Par, Backend::Xla] {
                if backend == Backend::Xla && xla.is_none() {
                    row.push("-".into());
                    continue;
                }
                match run_cell(algo, e.short, &e.graph, backend, &sources, xla.as_ref()) {
                    Ok(r) => {
                        let ok = (r.checksum - oracle).abs() <= 1e-3 * (1.0 + oracle.abs());
                        if !ok {
                            failures += 1;
                        }
                        row.push(format!(
                            "{}{}",
                            fmt_secs(r.secs),
                            if ok { " ✓" } else { " ✗" }
                        ));
                    }
                    Err(_) => row.push("-".into()),
                }
            }
            t.row(row);
        }
        println!("{}", t.render());
    }
    if failures == 0 {
        println!("ALL CHECKSUMS MATCH — every backend agrees with the oracles.");
        Ok(())
    } else {
        anyhow::bail!("{failures} checksum mismatches")
    }
}
