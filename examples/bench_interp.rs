//! Interpreter perf harness: runs the four Table-4 algorithms through the
//! slot-resolved interpreter in Seq and Par mode and writes a
//! machine-readable `BENCH_interp.json` (per-algorithm seconds and
//! nodes/sec) so successive PRs have a perf trajectory to compare against.
//!
//! Run: cargo run --release --example bench_interp
//! Env: STARPLAT_BENCH_N (graph size knob, default 20000),
//!      STARPLAT_THREADS (Par worker count)

use starplat::backends::interp::{self, env::Val, Args, Mode};
use starplat::coordinator::driver::{load_program, Algo};
use starplat::graph::csr::Graph;
use starplat::util::json::Json;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn bench_args(algo: Algo) -> Args {
    match algo {
        Algo::Pr => Args::default()
            .scalar("beta", Val::F(1e-7))
            .scalar("delta", Val::F(0.85))
            .scalar("maxIter", Val::I(50)),
        Algo::Bfs | Algo::Sssp => Args::default().node("src", 0),
        _ => Args::default(),
    }
}

/// Best-of-3 wall-clock seconds for one (algo, graph, mode) cell.
fn time_cell(algo: Algo, g: &Graph, mode: Mode) -> anyhow::Result<f64> {
    let tf = load_program(algo)?;
    let args = bench_args(algo);
    interp::run(&tf, g, &args, mode)?; // warmup (also surfaces errors once)
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = std::time::Instant::now();
        interp::run(&tf, g, &args, mode)?;
        best = best.min(t0.elapsed().as_secs_f64());
    }
    Ok(best)
}

fn main() -> anyhow::Result<()> {
    let n = env_usize("STARPLAT_BENCH_N", 20_000);
    let side = (n as f64).sqrt().ceil() as usize;
    let graphs = vec![
        starplat::graph::generators::road_grid("road", side, side, 0x11),
        starplat::graph::generators::rmat("rmat", n, 5 * n, 0x22),
    ];
    let algos = [Algo::Bfs, Algo::Sssp, Algo::Cc, Algo::Pr];

    let mut cells = Vec::new();
    for g in &graphs {
        for &algo in &algos {
            for (mode, label) in [(Mode::Seq, "seq"), (Mode::Par, "par")] {
                let secs = time_cell(algo, g, mode)?;
                let nps = g.num_nodes() as f64 / secs;
                println!(
                    "{:>4?} on {:<5} [{label}]  {secs:>9.4}s  {nps:>12.0} nodes/s",
                    algo, g.name
                );
                cells.push(Json::obj(vec![
                    ("algorithm", Json::Str(format!("{algo:?}").to_lowercase())),
                    ("graph", Json::Str(g.name.clone())),
                    ("mode", Json::Str(label.to_string())),
                    ("nodes", Json::Num(g.num_nodes() as f64)),
                    ("edges", Json::Num(g.num_edges() as f64)),
                    ("secs", Json::Num(secs)),
                    ("nodes_per_sec", Json::Num(nps)),
                ]));
            }
        }
    }

    let report = Json::obj(vec![
        ("engine", Json::Str("slot-resolved-v1".into())),
        ("threads_par", Json::Num(starplat::util::pool::default_threads() as f64)),
        ("bench_n", Json::Num(n as f64)),
        ("cells", Json::Arr(cells)),
    ]);
    std::fs::write("BENCH_interp.json", format!("{report}\n"))?;
    println!("\nwrote BENCH_interp.json");
    Ok(())
}
