//! Interpreter perf harness: runs the four Table-4 algorithms through the
//! slot-resolved interpreter in Seq and Par mode and writes a
//! machine-readable `BENCH_interp.json` (per-algorithm seconds and
//! nodes/sec) so successive PRs have a perf trajectory to compare against.
//!
//! Frontier-eligible algorithms (SSSP/CC — any program whose fixedPoint the
//! compiler proves sparse-safe) additionally get **frontier-vs-dense**
//! columns: the same cell timed with the sparse worklist schedule (the
//! default) and with it forced off (`ExecOpts::frontier = false`), so the
//! fast path's win is visible per cell instead of inferred across PRs.
//!
//! Every cell also carries the persistent runtime's counters
//! (frontier-engine-v3): `dispatch_ns` (average publish→first-worker-join
//! wake latency per run) and `steals` (average successful deque steals per
//! run) — the two numbers that distinguish "dispatch got cheap" from
//! "load-balancing fired" when a cell moves.
//!
//! The adaptive scheduler (frontier-engine-v4) adds the decision columns:
//! `schedule` (the direction policy the cell ran under), `direction_switches`
//! and `pull_rounds` (what the auto policy actually did), and `delta` (did
//! any fixedPoint run the bucketed delta-stepping schedule). Frontier-eligible
//! cells are additionally re-timed with the direction forced
//! (`secs_push`/`secs_pull`) so auto's overhead vs the better static choice
//! is visible per cell, and SSSP cells get a `secs_delta` column (forced
//! `STARPLAT_DELTA=auto`).
//!
//! Batched multi-source execution (frontier-engine-v5) adds a separate
//! `batch_cells` table: for k ∈ {1, 8, 32, 64} roots, one
//! `batch::run_batch_with_opts` traversal is timed against k independent
//! runs of the same roots, yielding per-root amortized seconds and the
//! batch speedup. The table is informational — it lives outside `cells` so
//! the trend gate (keyed on algorithm/graph/mode `secs`) never sees it.
//!
//! Run: cargo run --release --example bench_interp
//! Env: STARPLAT_BENCH_N (graph size knob, default 20000),
//!      STARPLAT_THREADS (Par worker count),
//!      STARPLAT_FRONTIER=0 (force the dense schedule everywhere),
//!      STARPLAT_DIRECTION / STARPLAT_DELTA (see README knob table)

use starplat::backends::interp::{
    self, batch, compile, env::Val, Args, DeltaMode, Direction, ExecOpts,
};
use starplat::coordinator::driver::{load_program, Algo};
use starplat::graph::csr::{Graph, Node};
use starplat::util::json::Json;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn bench_args(algo: Algo) -> Args {
    match algo {
        Algo::Pr => Args::default()
            .scalar("beta", Val::F(1e-7))
            .scalar("delta", Val::F(0.85))
            .scalar("maxIter", Val::I(50)),
        Algo::Bfs | Algo::Sssp => Args::default().node("src", 0),
        _ => Args::default(),
    }
}

/// Does the compiled program contain a frontier-eligible fixedPoint?
fn has_frontier_path(stmts: &[compile::HostStmt]) -> bool {
    use compile::HostStmt as H;
    stmts.iter().any(|s| match s {
        H::FixedPoint { frontier, body, .. } => frontier.is_some() || has_frontier_path(body),
        H::SeqFor { body, .. } | H::DoWhile { body, .. } | H::While { body, .. } => {
            has_frontier_path(body)
        }
        H::If { then, els, .. } => has_frontier_path(then) || has_frontier_path(els),
        _ => false,
    })
}

/// One timed cell: best-of-3 wall-clock seconds, dense-fallback count, the
/// persistent-runtime counters, and the adaptive scheduler's decision
/// counters attributed to this cell.
struct Cell {
    secs: f64,
    fallbacks: u64,
    /// average publish→first-worker-join latency per timed run (ns): the
    /// wake cost the persistent pool replaced thread spawning with
    dispatch_ns: f64,
    /// average successful deque steals per timed run
    steals: f64,
    /// push↔pull switches over the warmup run's rounds/levels
    direction_switches: u64,
    /// rounds/levels the warmup run executed in the pull direction
    pull_rounds: u64,
    /// did any fixedPoint run the delta-stepping schedule?
    delta_used: bool,
}

/// Best-of-3 wall-clock seconds (plus dense-fallback count, per-run pool
/// counter deltas, and schedule-decision counters) for one
/// (algo, graph, mode, schedule) cell. The driver is single-threaded, so the
/// pool's global counters moved only for this cell.
fn time_cell(
    algo: Algo,
    g: &Graph,
    threads: usize,
    frontier: bool,
    direction: Option<Direction>,
    delta: Option<DeltaMode>,
) -> anyhow::Result<Cell> {
    let tf = load_program(algo)?;
    let args = bench_args(algo);
    let opts = ExecOpts { threads, frontier, direction, delta, ..ExecOpts::default() };
    // warmup (also surfaces errors once and yields the decision counters)
    let stats = interp::run_with_opts(&tf, g, &args, opts.clone())?.stats;
    let mut best = f64::INFINITY;
    let before = starplat::util::pool::stats();
    for _ in 0..3 {
        let t0 = std::time::Instant::now();
        interp::run_with_opts(&tf, g, &args, opts.clone())?;
        best = best.min(t0.elapsed().as_secs_f64());
    }
    let after = starplat::util::pool::stats();
    Ok(Cell {
        secs: best,
        fallbacks: stats.fallbacks,
        dispatch_ns: (after.dispatch_ns - before.dispatch_ns) as f64 / 3.0,
        steals: (after.steals - before.steals) as f64 / 3.0,
        direction_switches: stats.direction_switches,
        pull_rounds: stats.pull_rounds,
        delta_used: stats.delta_used,
    })
}

fn main() -> anyhow::Result<()> {
    let n = env_usize("STARPLAT_BENCH_N", 20_000);
    let side = (n as f64).sqrt().ceil() as usize;
    let graphs = vec![
        starplat::graph::generators::road_grid("road", side, side, 0x11),
        starplat::graph::generators::rmat("rmat", n, 5 * n, 0x22),
    ];
    let algos = [Algo::Bfs, Algo::Sssp, Algo::Cc, Algo::Pr];
    let par_threads = starplat::util::pool::default_threads();

    let mut cells = Vec::new();
    for g in &graphs {
        for &algo in &algos {
            // the interpreter's own STARPLAT_FRONTIER gate: with the engine
            // forced off, cells report path:"dense" and skip the second run
            let eligible = interp::frontier_env_enabled()
                && has_frontier_path(&compile::compile(&load_program(algo)?)?.body);
            for (threads, label) in [(1usize, "seq"), (par_threads, "par")] {
                let cell = time_cell(algo, g, threads, true, None, None)?;
                let secs = cell.secs;
                let nps = g.num_nodes() as f64 / secs;
                let mut fields = vec![
                    ("algorithm", Json::Str(format!("{algo:?}").to_lowercase())),
                    ("graph", Json::Str(g.name.clone())),
                    ("mode", Json::Str(label.to_string())),
                    ("nodes", Json::Num(g.num_nodes() as f64)),
                    ("edges", Json::Num(g.num_edges() as f64)),
                    ("secs", Json::Num(secs)),
                    ("nodes_per_sec", Json::Num(nps)),
                    ("path", Json::Str(if eligible { "frontier" } else { "dense" }.to_string())),
                    ("fallbacks", Json::Num(cell.fallbacks as f64)),
                    // persistent-runtime columns (frontier-engine-v3): wake
                    // latency and steal traffic attributed to this cell
                    ("dispatch_ns", Json::Num(cell.dispatch_ns)),
                    ("steals", Json::Num(cell.steals)),
                    // schedule-decision columns (frontier-engine-v4): what
                    // the adaptive policy was and what it actually chose
                    ("schedule", Json::Str("auto".to_string())),
                    ("direction_switches", Json::Num(cell.direction_switches as f64)),
                    ("pull_rounds", Json::Num(cell.pull_rounds as f64)),
                    ("delta", Json::Bool(cell.delta_used)),
                ];
                if eligible {
                    // same cell with the sparse schedule forced off: the
                    // frontier-vs-dense column
                    let dense = time_cell(algo, g, threads, false, None, None)?;
                    fields.push(("secs_dense", Json::Num(dense.secs)));
                    // same cell with the direction forced each way: auto's
                    // overhead vs the better static schedule, per cell
                    let push =
                        time_cell(algo, g, threads, true, Some(Direction::Push), None)?;
                    let pull =
                        time_cell(algo, g, threads, true, Some(Direction::Pull), None)?;
                    fields.push(("secs_push", Json::Num(push.secs)));
                    fields.push(("secs_pull", Json::Num(pull.secs)));
                    if algo == Algo::Sssp {
                        // the bucketed relaxation schedule, forced on
                        let delta =
                            time_cell(algo, g, threads, true, None, Some(DeltaMode::Auto))?;
                        fields.push(("secs_delta", Json::Num(delta.secs)));
                    }
                    println!(
                        "{:>4?} on {:<5} [{label}]  frontier {secs:>9.4}s  dense {:>9.4}s  ({:.2}x)  push {:>9.4}s  pull {:>9.4}s  sw {}  {nps:>12.0} nodes/s",
                        algo,
                        g.name,
                        dense.secs,
                        dense.secs / secs,
                        push.secs,
                        pull.secs,
                        cell.direction_switches,
                    );
                } else {
                    println!(
                        "{:>4?} on {:<5} [{label}]  {secs:>9.4}s  {nps:>12.0} nodes/s  steals {:.0}",
                        algo, g.name, cell.steals
                    );
                }
                cells.push(Json::obj(fields));
            }
        }
    }

    // ---- batched multi-source cells (frontier-engine-v5) ----------------
    // One shared traversal carrying k roots vs k independent runs of the
    // same roots. Kept out of `cells` on purpose: the trend comparison keys
    // on (algorithm, graph, mode) and gates on `secs`, and these timings
    // must stay informational.
    let mut batch_cells = Vec::new();
    for g in &graphs {
        for &algo in &[Algo::Bfs, Algo::Sssp] {
            let tf = load_program(algo)?;
            let opts = ExecOpts { threads: par_threads, ..ExecOpts::default() };
            let prop = if algo == Algo::Bfs { "level" } else { "dist" };
            // warmup (also surfaces errors once)
            interp::run_with_opts(&tf, g, &bench_args(algo), opts.clone())?;
            for k in [1usize, 8, 32, 64] {
                let roots: Vec<Node> =
                    (0..k).map(|i| ((i * g.num_nodes()) / k) as Node).collect();
                // k independent single-root runs
                let t0 = std::time::Instant::now();
                for &r in &roots {
                    interp::run_with_opts(&tf, g, &Args::default().node("src", r), opts.clone())?;
                }
                let secs_indep = t0.elapsed().as_secs_f64();
                // one batched traversal carrying every root
                let t0 = std::time::Instant::now();
                let outs =
                    batch::run_batch_with_opts(&tf, g, &Args::default(), "src", &roots, &opts);
                let secs_batch = t0.elapsed().as_secs_f64();
                let mut batched = 0u64;
                for out in outs {
                    let out = out?;
                    batched += out.stats.batched_roots;
                    // keep the timing honest: the outputs must be real
                    assert_eq!(out.prop_i64(prop).len(), g.num_nodes());
                }
                let speedup = secs_indep / secs_batch;
                println!(
                    "{:>4?} on {:<5} [batch k={k:>2}]  batch {secs_batch:>9.4}s  indep {secs_indep:>9.4}s  ({speedup:.2}x)  amortized {:>9.6}s/root",
                    algo,
                    g.name,
                    secs_batch / k as f64,
                );
                batch_cells.push(Json::obj(vec![
                    ("algorithm", Json::Str(format!("{algo:?}").to_lowercase())),
                    ("graph", Json::Str(g.name.clone())),
                    ("k", Json::Num(k as f64)),
                    ("secs_batch", Json::Num(secs_batch)),
                    ("secs_indep", Json::Num(secs_indep)),
                    ("amortized_secs", Json::Num(secs_batch / k as f64)),
                    ("speedup", Json::Num(speedup)),
                    // lane engagement: 0 would mean the engine fell back and
                    // the cell timed the independent path twice
                    ("batched_roots", Json::Num(batched as f64)),
                ]));
            }
        }
    }

    let report = Json::obj(vec![
        ("engine", Json::Str("frontier-engine-v5".into())),
        ("threads_par", Json::Num(par_threads as f64)),
        ("bench_n", Json::Num(n as f64)),
        ("cells", Json::Arr(cells)),
        ("batch_cells", Json::Arr(batch_cells)),
    ]);
    std::fs::write("BENCH_interp.json", format!("{report}\n"))?;
    println!("\nwrote BENCH_interp.json");
    Ok(())
}
