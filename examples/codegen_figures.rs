//! Regenerates the paper's code-listing figures (Figures 2–12): for each
//! construct the paper illustrates, print the corresponding fragment of our
//! generated code next to the figure number.
//!
//! Run: cargo run --release --example codegen_figures [--full]

use starplat::codegen;
use starplat::dsl::parser::parse_file;
use starplat::ir::lower;
use starplat::sema::check_function;

fn gen(program: &str, backend: &str) -> anyhow::Result<String> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("dsl_programs")
        .join(program);
    let fns = parse_file(&path)?;
    let tf = check_function(&fns[0]).map_err(|e| anyhow::anyhow!("{e}"))?;
    codegen::generate(backend, &lower(&tf))
}

/// Print the lines of `src` between the first line containing `from` and the
/// following line containing `to` (inclusive), with a figure header.
fn excerpt(title: &str, src: &str, from: &str, to: &str) {
    println!("────── {title} ──────");
    let mut on = false;
    let mut shown = 0;
    for line in src.lines() {
        if !on && line.contains(from) {
            on = true;
        }
        if on {
            println!("{line}");
            shown += 1;
            if line.contains(to) && shown > 1 || shown > 40 {
                break;
            }
        }
    }
    println!();
}

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let sssp_cuda = gen("sssp.sp", "cuda")?;
    let sssp_hip = gen("sssp.sp", "hip")?;
    let sssp_acc = gen("sssp.sp", "openacc")?;
    let sssp_sycl = gen("sssp.sp", "sycl")?;
    let sssp_ocl = gen("sssp.sp", "opencl")?;
    let sssp_metal = gen("sssp.sp", "metal")?;
    let sssp_wgsl = gen("sssp.sp", "wgsl")?;
    let pr_acc = gen("pr.sp", "openacc")?;
    let tc_sycl = gen("tc.sp", "sycl")?;
    let bc_cuda = gen("bc.sp", "cuda")?;

    if full {
        for (name, src) in [
            ("sssp.cu", &sssp_cuda),
            ("sssp.hip.cpp", &sssp_hip),
            ("sssp.acc.cpp", &sssp_acc),
            ("sssp.sycl.cpp", &sssp_sycl),
            ("sssp.cl", &sssp_ocl),
            ("sssp.metal", &sssp_metal),
            ("sssp.wgsl", &sssp_wgsl),
        ] {
            println!("================ {name} ================\n{src}");
        }
        return Ok(());
    }

    excerpt(
        "Fig 2 — CUDA neighborhood iteration",
        &sssp_cuda,
        "__global__ void",
        "gpu_edgeList[edge]",
    );
    excerpt(
        "Fig 3 — OpenACC promoted data clauses",
        &sssp_acc,
        "#pragma acc data copyin(g)",
        "copy(",
    );
    excerpt("Fig 4 — SYCL parallel_for", &sssp_sycl, "Q.submit", "v += NUM_THREADS");
    excerpt("Fig 5 — OpenCL kernel", &sssp_ocl, "__kernel void", "get_global_id");
    excerpt(
        "Fig 6 — CUDA Min construct (atomicMin + flag)",
        &sssp_cuda,
        "dist_new =",
        "gpu_finished[0] = false",
    );
    excerpt(
        "Fig 7 — OpenACC reduction clause (PageRank)",
        &pr_acc,
        "reduction(+: diff)",
        "pageRank_nxt[v] = val",
    );
    excerpt("Fig 8 — SYCL atomic_ref reduction (TC)", &tc_sycl, "atomic_ref<", "atomic_data += 1");
    excerpt("Fig 9 — CUDA iterateInBFS host loop", &bc_cuda, "do {", "} while (!finished);");
    excerpt("Fig 10 — OpenACC Min construct", &sssp_acc, "dist_new =", "finished = false");
    excerpt("Fig 11 — SYCL fetch_min", &sssp_sycl, "dist_new =", "fetch_min");
    excerpt(
        "Fig 12 — fixedPoint host loop",
        &sssp_cuda,
        "while (!finished) {",
        "cudaMemcpyDeviceToHost);",
    );
    excerpt(
        "HIP — Fig 2's launch through hipLaunchKernelGGL (same plan, new spellings)",
        &sssp_hip,
        "hipLaunchKernelGGL(Compute_SSSP_kernel",
        "hipDeviceSynchronize();",
    );
    excerpt(
        "Metal — Fig 6's Min construct via atomic_fetch_min_explicit (same KernelOps)",
        &sssp_metal,
        "kernel void Compute_SSSP_kernel",
        "atomic_fetch_min_explicit",
    );
    excerpt(
        "WGSL — the same Min construct in a non-C dialect (@binding storage, atomicMin)",
        &sssp_wgsl,
        "// shader module: Compute_SSSP_kernel",
        "atomicMin(",
    );
    println!("(run with --full to dump the complete generated sources)");
    Ok(())
}
