#!/usr/bin/env python3
"""Compare two BENCH_interp.json files and emit a Markdown trend report.

Usage: bench_trend.py PREVIOUS.json CURRENT.json [--threshold 0.20]

Cells are keyed by (algorithm, graph, mode); a cell whose `secs` grew by
more than the threshold relative to the previous run is flagged. The report
is advisory — the script always exits 0 (runner timing variance is not yet
characterized well enough to gate on; see ROADMAP) — so CI pipes the output
into $GITHUB_STEP_SUMMARY instead of failing the job.
"""

import json
import sys


def cells_by_key(path):
    with open(path) as f:
        report = json.load(f)
    return {
        (c["algorithm"], c["graph"], c["mode"]): c
        for c in report.get("cells", [])
    }, report


def main(argv):
    if len(argv) < 3:
        print("usage: bench_trend.py PREVIOUS.json CURRENT.json [--threshold 0.20]")
        return 0
    threshold = 0.20
    if "--threshold" in argv:
        threshold = float(argv[argv.index("--threshold") + 1])
    try:
        prev, prev_report = cells_by_key(argv[1])
        cur, cur_report = cells_by_key(argv[2])
    except (OSError, json.JSONDecodeError, KeyError) as e:
        print(f"### Interpreter bench trend\n\n_not comparable: {e}_")
        return 0

    print("### Interpreter bench trend (advisory)")
    print()
    print(
        f"previous bench_n={prev_report.get('bench_n')} "
        f"threads={prev_report.get('threads_par')} · "
        f"current bench_n={cur_report.get('bench_n')} "
        f"threads={cur_report.get('threads_par')}"
    )
    print()
    print("| algorithm | graph | mode | prev s | cur s | Δ |")
    print("|---|---|---|---:|---:|---:|")
    regressions = []
    for key in sorted(cur):
        c = cur[key]
        p = prev.get(key)
        if p is None or not p.get("secs"):
            print(f"| {key[0]} | {key[1]} | {key[2]} | — | {c['secs']:.4f} | new |")
            continue
        delta = (c["secs"] - p["secs"]) / p["secs"]
        flag = " ⚠️" if delta > threshold else ""
        print(
            f"| {key[0]} | {key[1]} | {key[2]} | {p['secs']:.4f} "
            f"| {c['secs']:.4f} | {delta:+.1%}{flag} |"
        )
        if delta > threshold:
            regressions.append((key, delta))
    print()
    if regressions:
        worst = ", ".join(f"{a}/{g}/{m} {d:+.1%}" for (a, g, m), d in regressions)
        print(
            f"**{len(regressions)} cell(s) regressed more than "
            f"{threshold:.0%}**: {worst}. Advisory only — runner variance is "
            "not yet characterized (ROADMAP)."
        )
    else:
        print(f"No cell regressed more than {threshold:.0%}.")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
