#!/usr/bin/env python3
"""Compare BENCH_interp.json files and emit a Markdown trend report.

Usage: bench_trend.py PREV.json [PREV2.json ...] CURRENT.json [--threshold 0.20]

All files but the last are previous runs (oldest first); the last is the
current run. Cells are keyed by (algorithm, graph, mode); a cell whose `secs`
grew by more than the threshold relative to the *latest* previous run is
flagged. With more than one previous run the report also records each cell's
timing **spread** across the previous runs — (max - min) / min, excluding
the run under test so a real regression can't inflate it — the
runner-variance context for each flagged cell.

Cells may carry an optional `fallbacks` column (sparse->dense schedule
fallbacks observed during the run, PR 6). It is informational only — a
nonzero count annotates the current-seconds column as ` (fb=N)` — and
never participates in the regression decision; reports without the column
compare exactly as before.

Cells from the persistent-runtime engine (frontier-engine-v3, PR 7) carry
two more optional columns, `dispatch_ns` and `steals`. Like `fallbacks`
they never gate: unknown columns are simply ignored by the comparison,
which keys on (algorithm, graph, mode) and reads only `secs`. The report
additionally summarizes the **frontier-path speedup** vs the previous run
(geometric mean of prev/cur over cells whose `path` is "frontier") — the
headline number for the persistent pool's cheap-dispatch claim — again
informational only.

Cells from the adaptive scheduler (frontier-engine-v4, PR 8) may carry
`schedule`, `direction_switches`, `pull_rounds`, `delta`, and the
forced-direction timings `secs_push`/`secs_pull`. Cells with both forced
timings feed an informational **push-vs-pull win/loss table** — which static
direction won, and how close the auto policy came to the better one. Like
every optional column it never participates in the regression decision.

The step is **blocking**: with the spread column landed (PR 4) and worst-case
runner variance observed comfortably under the threshold, a >threshold
per-cell regression exits 1 and fails CI. Set `BENCH_TREND_ADVISORY=1` in the
environment to demote the step back to report-only (the escape hatch for a
knowingly-accepted regression or a noisy runner). Infrastructure failure
modes — missing or unparsable artifacts, and cells whose `secs` is absent or
zero (a broken or skipped measurement, rendered `n/a`) — always exit 0: only
a real, measured regression may block.

Reports from the batched multi-source engine (frontier-engine-v5, PR 9)
carry a top-level `batch_cells` array: per (algorithm, graph, k) the time of
one k-root batched traversal vs k independent runs. It feeds an
informational **per-root amortization table** and is structurally invisible
to the gate, which iterates `cells` only — a batch-column wobble can never
fail CI.

`bench_trend.py --selftest` runs a built-in fixture through the comparison
(missing-`secs` cell, zero-`secs` cell, push/pull duel, one real regression)
and exits nonzero if the guards or the gate misbehave; CI runs it before the
real comparison so a broken trend script can't silently pass.
"""

import json
import os
import sys


def cells_by_key(path):
    with open(path) as f:
        report = json.load(f)
    return {
        (c["algorithm"], c["graph"], c["mode"]): c
        for c in report.get("cells", [])
    }, report


def main(argv):
    threshold = 0.20
    if "--threshold" in argv:
        i = argv.index("--threshold")
        threshold = float(argv[i + 1])
        argv = argv[:i] + argv[i + 2:]
    paths = argv[1:]
    if len(paths) < 2:
        print("usage: bench_trend.py PREV.json [PREV2.json ...] CURRENT.json"
              " [--threshold 0.20]")
        return 0
    try:
        runs = [cells_by_key(p) for p in paths]
    except (OSError, json.JSONDecodeError, KeyError) as e:
        print(f"### Interpreter bench trend\n\n_not comparable: {e}_")
        return 0
    cur, cur_report = runs[-1]
    prev, prev_report = runs[-2]
    history = [r for r, _ in runs]  # oldest -> current

    advisory = os.environ.get("BENCH_TREND_ADVISORY") == "1"
    print("### Interpreter bench trend"
          + (" (advisory)" if advisory else " (blocking)"))
    print()
    print(
        f"{len(runs) - 1} previous run(s) · "
        f"previous bench_n={prev_report.get('bench_n')} "
        f"threads={prev_report.get('threads_par')} · "
        f"current bench_n={cur_report.get('bench_n')} "
        f"threads={cur_report.get('threads_par')}"
    )
    print()
    print("| algorithm | graph | mode | prev s | cur s | Δ | spread |")
    print("|---|---|---|---:|---:|---:|---:|")
    regressions = []
    spreads = []
    for key in sorted(cur):
        c = cur[key]
        # optional robustness column: annotate, never gate
        fb = c.get("fallbacks") or 0
        fb_s = f" (fb={int(fb)})" if fb else ""
        # spread is measured over *previous* runs only: including the run
        # under test would let a genuine regression inflate the variance
        # figure meant to contextualize it
        series = [r[key]["secs"] for r in history[:-1]
                  if key in r and r[key].get("secs")]
        if len(series) >= 2 and min(series) > 0:
            spread = (max(series) - min(series)) / min(series)
            spreads.append((key, spread))
            spread_s = f"{spread:.1%}"
        else:
            spread_s = "—"
        # a cell whose current `secs` is absent or zero is a broken or
        # skipped measurement — an infrastructure problem, not a measured
        # regression: render n/a and never let it reach the gate (or a
        # divide / format crash)
        cs = c.get("secs") or 0
        cur_s = f"{cs:.4f}{fb_s}" if cs else "n/a"
        p = prev.get(key)
        if p is None or not p.get("secs"):
            print(f"| {key[0]} | {key[1]} | {key[2]} | — "
                  f"| {cur_s} | new | {spread_s} |")
            continue
        if not cs:
            print(f"| {key[0]} | {key[1]} | {key[2]} | {p['secs']:.4f} "
                  f"| n/a | n/a | {spread_s} |")
            continue
        delta = (cs - p["secs"]) / p["secs"]
        flag = " ⚠️" if delta > threshold else ""
        print(
            f"| {key[0]} | {key[1]} | {key[2]} | {p['secs']:.4f} "
            f"| {cur_s} | {delta:+.1%}{flag} | {spread_s} |"
        )
        if delta > threshold:
            regressions.append((key, delta))
    print()
    # frontier-path speedup vs the previous run: geometric mean of
    # prev/cur over cells running the sparse worklist schedule. Purely
    # informational — never part of the regression decision.
    ratios = []
    for key in sorted(cur):
        c, p = cur[key], prev.get(key)
        if (c.get("path") == "frontier" and p and p.get("secs")
                and c.get("secs")):
            ratios.append(p["secs"] / c["secs"])
    if ratios:
        geo = 1.0
        for r in ratios:
            geo *= r
        geo **= 1.0 / len(ratios)
        print(
            f"Frontier-path cells vs previous run: {geo:.2f}x "
            f"geomean speedup over {len(ratios)} cell(s) "
            "(>1 is faster; informational)."
        )
        print()
    # push-vs-pull win/loss from the schedule columns (frontier-engine-v4):
    # which static direction won each forced-direction cell, and how close
    # the adaptive policy landed to the better one. Purely informational —
    # the regression gate reads only `secs`.
    duel = [(key, cur[key]) for key in sorted(cur)
            if cur[key].get("secs_push") and cur[key].get("secs_pull")]
    if duel:
        print("#### Push vs pull (informational)")
        print()
        print("| algorithm | graph | mode | push s | pull s | winner "
              "| auto s | auto vs best | switches |")
        print("|---|---|---|---:|---:|---|---:|---:|---:|")
        wins = {"push": 0, "pull": 0}
        for key, c in duel:
            ps, ls = c["secs_push"], c["secs_pull"]
            winner = "push" if ps <= ls else "pull"
            wins[winner] += 1
            best = min(ps, ls)
            a = c.get("secs") or 0
            auto_s = f"{a:.4f}" if a else "n/a"
            gap_s = f"{(a - best) / best:+.1%}" if a else "n/a"
            sw = c.get("direction_switches")
            sw_s = "—" if sw is None else f"{int(sw)}"
            print(f"| {key[0]} | {key[1]} | {key[2]} | {ps:.4f} | {ls:.4f} "
                  f"| {winner} | {auto_s} | {gap_s} | {sw_s} |")
        print()
        print(f"Direction wins: push {wins['push']}, pull {wins['pull']} "
              "(informational; never gates).")
        print()
    # batched multi-source amortization (frontier-engine-v5): per-root cost
    # of one k-root traversal vs k independent runs. Informational only —
    # the gate iterates `cells` and never sees `batch_cells`.
    batch = [c for c in cur_report.get("batch_cells", [])
             if c.get("secs_batch") and c.get("secs_indep") and c.get("k")]
    if batch:
        print("#### Batched multi-source amortization (informational)")
        print()
        print("| algorithm | graph | k | batch s | indep s | speedup "
              "| amortized s/root |")
        print("|---|---|---:|---:|---:|---:|---:|")
        amortized = {}
        for c in batch:
            k = int(c["k"])
            per_root = c["secs_batch"] / k
            amortized[(c.get("algorithm"), c.get("graph"), k)] = per_root
            print(f"| {c.get('algorithm')} | {c.get('graph')} | {k} "
                  f"| {c['secs_batch']:.4f} | {c['secs_indep']:.4f} "
                  f"| {c['secs_indep'] / c['secs_batch']:.2f}x "
                  f"| {per_root:.6f} |")
        print()
        gains = []
        for (algo, graph, k), per_root in sorted(amortized.items()):
            base = amortized.get((algo, graph, 1))
            if k > 1 and base and per_root > 0:
                gains.append(f"{algo}/{graph} k={k}: {base / per_root:.2f}x")
        if gains:
            print("Per-root amortized speedup vs k=1: " + ", ".join(gains)
                  + " (informational; never gates).")
            print()
    if spreads:
        worst_key, worst = max(spreads, key=lambda kv: kv[1])
        median = sorted(s for _, s in spreads)[len(spreads) // 2]
        print(
            f"Per-cell spread over {len(runs) - 1} previous run(s): "
            f"median {median:.1%}, "
            f"worst {worst:.1%} ({worst_key[0]}/{worst_key[1]}/{worst_key[2]})."
        )
        print()
    if regressions:
        worst = ", ".join(f"{a}/{g}/{m} {d:+.1%}" for (a, g, m), d in regressions)
        print(
            f"**{len(regressions)} cell(s) regressed more than "
            f"{threshold:.0%}**: {worst}. See the spread column for whether "
            "runner variance explains it."
        )
        if advisory:
            print()
            print("_BENCH_TREND_ADVISORY=1 set: reporting only, not failing "
                  "the job._")
            return 0
        return 1
    print(f"No cell regressed more than {threshold:.0%}.")
    return 0


def selftest():
    """Fixture check: broken cells must render n/a and never gate; a real
    regression must still gate; the push/pull table must not crash on a
    zero-`secs` auto cell; broken batch cells must be skipped and a batch
    slowdown must never gate. Exits 0 on success, raises on failure."""
    import tempfile

    prev = {"bench_n": 1, "threads_par": 2, "cells": [
        {"algorithm": "bfs", "graph": "road", "mode": "seq", "secs": 1.0},
        {"algorithm": "cc", "graph": "road", "mode": "seq", "secs": 2.0},
        {"algorithm": "pr", "graph": "road", "mode": "seq", "secs": 1.0},
    ]}
    broken_cur = {"bench_n": 1, "threads_par": 2, "cells": [
        # `secs` missing entirely: must render n/a, not KeyError
        {"algorithm": "bfs", "graph": "road", "mode": "seq"},
        # `secs` zero, with a push/pull duel attached: must render n/a in
        # both tables, not divide by zero, and never gate
        {"algorithm": "cc", "graph": "road", "mode": "seq", "secs": 0.0,
         "secs_push": 0.5, "secs_pull": 0.7, "schedule": "auto",
         "direction_switches": 3, "pull_rounds": 2, "delta": False},
        {"algorithm": "pr", "graph": "road", "mode": "seq", "secs": 1.0},
    ], "batch_cells": [
        # broken batch cells (missing/zero columns): skipped, never a crash
        {"algorithm": "bfs", "graph": "road", "k": 8},
        {"algorithm": "bfs", "graph": "road", "k": 0, "secs_batch": 1.0,
         "secs_indep": 1.0},
        # a batch SLOWDOWN (0.5x) in an otherwise clean report: must render
        # in the informational table without gating
        {"algorithm": "bfs", "graph": "road", "k": 1, "secs_batch": 1.0,
         "secs_indep": 1.0},
        {"algorithm": "bfs", "graph": "road", "k": 8, "secs_batch": 16.0,
         "secs_indep": 8.0},
    ]}
    regressed_cur = {"bench_n": 1, "threads_par": 2, "cells": [
        {"algorithm": "bfs", "graph": "road", "mode": "seq", "secs": 1.0},
        {"algorithm": "cc", "graph": "road", "mode": "seq", "secs": 2.0},
        # +100%: far past any sane threshold, must exit 1
        {"algorithm": "pr", "graph": "road", "mode": "seq", "secs": 2.0},
    ]}
    advisory = os.environ.pop("BENCH_TREND_ADVISORY", None)
    try:
        with tempfile.TemporaryDirectory() as d:
            paths = {}
            for name, report in [("prev", prev), ("broken", broken_cur),
                                 ("regressed", regressed_cur)]:
                paths[name] = os.path.join(d, name + ".json")
                with open(paths[name], "w") as f:
                    json.dump(report, f)
            rc = main(["bench_trend.py", paths["prev"], paths["broken"]])
            assert rc == 0, f"broken cells must not gate (exit {rc})"
            rc = main(["bench_trend.py", paths["prev"], paths["regressed"]])
            assert rc == 1, f"a real regression must gate (exit {rc})"
    finally:
        if advisory is not None:
            os.environ["BENCH_TREND_ADVISORY"] = advisory
    print()
    print("selftest ok: n/a cells never gate, real regressions still do")
    return 0


if __name__ == "__main__":
    if "--selftest" in sys.argv:
        sys.exit(selftest())
    sys.exit(main(sys.argv))
