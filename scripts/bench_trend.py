#!/usr/bin/env python3
"""Compare BENCH_interp.json files and emit a Markdown trend report.

Usage: bench_trend.py PREV.json [PREV2.json ...] CURRENT.json [--threshold 0.20]

All files but the last are previous runs (oldest first); the last is the
current run. Cells are keyed by (algorithm, graph, mode); a cell whose `secs`
grew by more than the threshold relative to the *latest* previous run is
flagged. With more than one previous run the report also records each cell's
timing **spread** across the previous runs — (max - min) / min, excluding
the run under test so a real regression can't inflate it — the
runner-variance context for each flagged cell.

Cells may carry an optional `fallbacks` column (sparse->dense schedule
fallbacks observed during the run, PR 6). It is informational only — a
nonzero count annotates the current-seconds column as ` (fb=N)` — and
never participates in the regression decision; reports without the column
compare exactly as before.

Cells from the persistent-runtime engine (frontier-engine-v3, PR 7) carry
two more optional columns, `dispatch_ns` and `steals`. Like `fallbacks`
they never gate: unknown columns are simply ignored by the comparison,
which keys on (algorithm, graph, mode) and reads only `secs`. The report
additionally summarizes the **frontier-path speedup** vs the previous run
(geometric mean of prev/cur over cells whose `path` is "frontier") — the
headline number for the persistent pool's cheap-dispatch claim — again
informational only.

The step is **blocking**: with the spread column landed (PR 4) and worst-case
runner variance observed comfortably under the threshold, a >threshold
per-cell regression exits 1 and fails CI. Set `BENCH_TREND_ADVISORY=1` in the
environment to demote the step back to report-only (the escape hatch for a
knowingly-accepted regression or a noisy runner). Infrastructure failure
modes — missing or unparsable artifacts — always exit 0: only a real,
measured regression may block.
"""

import json
import os
import sys


def cells_by_key(path):
    with open(path) as f:
        report = json.load(f)
    return {
        (c["algorithm"], c["graph"], c["mode"]): c
        for c in report.get("cells", [])
    }, report


def main(argv):
    threshold = 0.20
    if "--threshold" in argv:
        i = argv.index("--threshold")
        threshold = float(argv[i + 1])
        argv = argv[:i] + argv[i + 2:]
    paths = argv[1:]
    if len(paths) < 2:
        print("usage: bench_trend.py PREV.json [PREV2.json ...] CURRENT.json"
              " [--threshold 0.20]")
        return 0
    try:
        runs = [cells_by_key(p) for p in paths]
    except (OSError, json.JSONDecodeError, KeyError) as e:
        print(f"### Interpreter bench trend\n\n_not comparable: {e}_")
        return 0
    cur, cur_report = runs[-1]
    prev, prev_report = runs[-2]
    history = [r for r, _ in runs]  # oldest -> current

    advisory = os.environ.get("BENCH_TREND_ADVISORY") == "1"
    print("### Interpreter bench trend"
          + (" (advisory)" if advisory else " (blocking)"))
    print()
    print(
        f"{len(runs) - 1} previous run(s) · "
        f"previous bench_n={prev_report.get('bench_n')} "
        f"threads={prev_report.get('threads_par')} · "
        f"current bench_n={cur_report.get('bench_n')} "
        f"threads={cur_report.get('threads_par')}"
    )
    print()
    print("| algorithm | graph | mode | prev s | cur s | Δ | spread |")
    print("|---|---|---|---:|---:|---:|---:|")
    regressions = []
    spreads = []
    for key in sorted(cur):
        c = cur[key]
        # optional robustness column: annotate, never gate
        fb = c.get("fallbacks") or 0
        fb_s = f" (fb={int(fb)})" if fb else ""
        # spread is measured over *previous* runs only: including the run
        # under test would let a genuine regression inflate the variance
        # figure meant to contextualize it
        series = [r[key]["secs"] for r in history[:-1]
                  if key in r and r[key].get("secs")]
        if len(series) >= 2 and min(series) > 0:
            spread = (max(series) - min(series)) / min(series)
            spreads.append((key, spread))
            spread_s = f"{spread:.1%}"
        else:
            spread_s = "—"
        p = prev.get(key)
        if p is None or not p.get("secs"):
            print(f"| {key[0]} | {key[1]} | {key[2]} | — "
                  f"| {c['secs']:.4f}{fb_s} | new | {spread_s} |")
            continue
        delta = (c["secs"] - p["secs"]) / p["secs"]
        flag = " ⚠️" if delta > threshold else ""
        print(
            f"| {key[0]} | {key[1]} | {key[2]} | {p['secs']:.4f} "
            f"| {c['secs']:.4f}{fb_s} | {delta:+.1%}{flag} | {spread_s} |"
        )
        if delta > threshold:
            regressions.append((key, delta))
    print()
    # frontier-path speedup vs the previous run: geometric mean of
    # prev/cur over cells running the sparse worklist schedule. Purely
    # informational — never part of the regression decision.
    ratios = []
    for key in sorted(cur):
        c, p = cur[key], prev.get(key)
        if (c.get("path") == "frontier" and p and p.get("secs")
                and c.get("secs")):
            ratios.append(p["secs"] / c["secs"])
    if ratios:
        geo = 1.0
        for r in ratios:
            geo *= r
        geo **= 1.0 / len(ratios)
        print(
            f"Frontier-path cells vs previous run: {geo:.2f}x "
            f"geomean speedup over {len(ratios)} cell(s) "
            "(>1 is faster; informational)."
        )
        print()
    if spreads:
        worst_key, worst = max(spreads, key=lambda kv: kv[1])
        median = sorted(s for _, s in spreads)[len(spreads) // 2]
        print(
            f"Per-cell spread over {len(runs) - 1} previous run(s): "
            f"median {median:.1%}, "
            f"worst {worst:.1%} ({worst_key[0]}/{worst_key[1]}/{worst_key[2]})."
        )
        print()
    if regressions:
        worst = ", ".join(f"{a}/{g}/{m} {d:+.1%}" for (a, g, m), d in regressions)
        print(
            f"**{len(regressions)} cell(s) regressed more than "
            f"{threshold:.0%}**: {worst}. See the spread column for whether "
            "runner variance explains it."
        )
        if advisory:
            print()
            print("_BENCH_TREND_ADVISORY=1 set: reporting only, not failing "
                  "the job._")
            return 0
        return 1
    print(f"No cell regressed more than {threshold:.0%}.")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
