#!/usr/bin/env python3
"""Smoke-compile generated WGSL with naga.

A generated `.wgsl` file is a manifest header plus two sections: a
`shaders.wgsl` section holding one self-contained WGSL module per kernel
(each with its own `struct Params` and `@group(0)` bindings, delimited by
`// shader module: <name>` markers) and a C++ `host.cpp` section. The
concatenation is NOT one valid WGSL compilation unit — modules redeclare
`Params` and reuse binding indices by design — so this script performs the
same split the embedder does (see rust/include/libstarplat_webgpu.h),
writes each module to its own file, and runs `naga <module>.wgsl` on each.

Exit codes:
  0  every module of every input validated (or naga missing without
     --require-naga: extraction still ran, validation skipped)
  1  naga rejected a module, an input had no shader modules, or naga is
     missing while --require-naga is set

Usage: wgsl_smoke.py [--require-naga] [--keep DIR] FILE.wgsl...
"""

import argparse
import pathlib
import shutil
import subprocess
import sys
import tempfile

SHADERS_MARK = "// ---- shaders.wgsl"
HOST_MARK = "// ---- host.cpp"
MODULE_MARK = "// shader module: "


def split_modules(text):
    """Return [(module_name, wgsl_source)] for one generated file."""
    modules = []
    name = None
    lines = []
    in_shaders = False
    for line in text.splitlines():
        stripped = line.strip()
        if stripped.startswith(HOST_MARK):
            break
        if stripped.startswith(SHADERS_MARK):
            in_shaders = True
            continue
        if not in_shaders:
            continue
        if stripped.startswith(MODULE_MARK):
            if name is not None:
                modules.append((name, "\n".join(lines) + "\n"))
            name = stripped[len(MODULE_MARK):].strip()
            lines = []
            continue
        if name is not None:
            lines.append(line)
    if name is not None:
        modules.append((name, "\n".join(lines) + "\n"))
    return modules


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="+", help="generated .wgsl files")
    ap.add_argument(
        "--require-naga",
        action="store_true",
        help="fail (instead of skipping validation) when naga is not installed",
    )
    ap.add_argument(
        "--keep",
        metavar="DIR",
        help="write split modules here instead of a temp dir (kept afterwards)",
    )
    args = ap.parse_args()

    naga = shutil.which("naga")
    if naga is None:
        if args.require_naga:
            print("wgsl-smoke: FAIL: naga not found and --require-naga set", file=sys.stderr)
            return 1
        print("wgsl-smoke: naga not found; extracting modules without validating")

    if args.keep:
        out_dir = pathlib.Path(args.keep)
        out_dir.mkdir(parents=True, exist_ok=True)
        tmp = None
    else:
        tmp = tempfile.TemporaryDirectory(prefix="wgsl_smoke_")
        out_dir = pathlib.Path(tmp.name)

    failures = 0
    total = 0
    try:
        for f in args.files:
            path = pathlib.Path(f)
            modules = split_modules(path.read_text())
            if not modules:
                print(f"wgsl-smoke: FAIL: {f}: no `{MODULE_MARK.strip()}` sections found")
                failures += 1
                continue
            for name, source in modules:
                total += 1
                mod_path = out_dir / f"{path.stem}__{name}.wgsl"
                mod_path.write_text(source)
                if naga is None:
                    continue
                r = subprocess.run(
                    [naga, str(mod_path)], capture_output=True, text=True
                )
                if r.returncode != 0:
                    failures += 1
                    print(f"wgsl-smoke: FAIL: {f} module `{name}`:")
                    sys.stdout.write(r.stdout)
                    sys.stderr.write(r.stderr)
    finally:
        if tmp is not None:
            tmp.cleanup()

    verb = "validated" if naga else "extracted"
    print(
        f"wgsl-smoke: {verb} {total} modules from {len(args.files)} files, "
        f"{failures} failures"
    )
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
