//! Regenerates the paper's §5 lines-of-code comparison: DSL programs fit in
//! 13–30 lines while the generated per-backend implementations are several
//! times larger, with OpenCL the most verbose (paper: CUDA≈150/120/125/75,
//! OpenACC −33%, SYCL +50%, OpenCL +100%).
//!
//! Run: cargo bench --bench loc_table

fn main() {
    match starplat::coordinator::loc_table() {
        Ok(t) => {
            println!("{}", t.render());
            println!("Shape check: DSL ≤ ~30 lines; generated backends are 2–5×; SYCL/OpenCL");
            println!("are the most verbose, OpenACC the most compact (matches §5).");
        }
        Err(e) => {
            eprintln!("loc_table failed: {e:#}");
            std::process::exit(1);
        }
    }
}
