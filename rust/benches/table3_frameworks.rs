//! Regenerates the paper's Table 3: StarPlat-generated accelerator code vs
//! the hand-crafted Gunrock and LonestarGPU baselines, on all four
//! algorithms over the ten-graph suite. Absolute numbers differ (our
//! "accelerator" is XLA-CPU, the paper's is a V100), but the paper's
//! qualitative shape should hold — see EXPERIMENTS.md.
//!
//! StarPlat column = the XLA artifact path when `make artifacts` has run at
//! the current scale, otherwise the parallel interpreter (noted in output).
//!
//! Run: cargo bench --bench table3_frameworks
//! Env: STARPLAT_SCALE, STARPLAT_BENCH_TIMEOUT_S, STARPLAT_BC_SOURCES

use starplat::backends::xla::XlaBackend;
use starplat::coordinator::driver::{run_cell, Algo, Backend};
use starplat::graph::generators::sample_sources;
use starplat::graph::suite::{build_suite, default_scale};
use starplat::util::bench::{bench_cell, BenchConfig, Cell};
use starplat::util::table::Table;

fn main() {
    // Default to the artifact scale so the XLA column is live.
    let scale = std::env::var("STARPLAT_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| {
            XlaBackend::open(std::path::Path::new("artifacts"))
                .map(|x| x.rt.scale)
                .unwrap_or(default_scale())
        });
    let suite = build_suite(scale);
    let cfg = BenchConfig::default();
    let n_sources: usize = std::env::var("STARPLAT_BC_SOURCES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20);

    let xla = XlaBackend::open(std::path::Path::new("artifacts"))
        .ok()
        .filter(|x| x.rt.scale == scale);
    let starplat_label =
        if xla.is_some() { "StarPlat (XLA)" } else { "StarPlat (par)" };
    let starplat_backend = if xla.is_some() { Backend::Xla } else { Backend::Par };
    println!("Table 3 — framework comparison at scale {scale}; StarPlat = {starplat_label}");
    println!("BC uses {n_sources} source(s). '-' = unimplemented (paper's empty cells).\n");

    for (algo, name) in
        [(Algo::Bc, "BC"), (Algo::Pr, "PR"), (Algo::Sssp, "SSSP"), (Algo::Tc, "TC")]
    {
        if let Some(x) = xla.as_ref() {
            x.rt.clear_cache(); // bound peak memory across tables
        }
        let mut header = vec!["Framework"];
        let shorts: Vec<&str> = suite.iter().map(|e| e.short).collect();
        header.extend(shorts.iter().copied());
        header.push("Total");
        let mut t = Table::new(&format!("Table 3 — {name}"), &header);
        for (fw, backend) in [
            ("LonestarGPU-style", Backend::Lonestar),
            ("Gunrock-style", Backend::Gunrock),
            (starplat_label, starplat_backend),
        ] {
            let mut row = vec![fw.to_string()];
            let mut total = 0.0;
            let mut all_ok = true;
            for e in &suite {
                let sources = sample_sources(&e.graph, n_sources, 7);
                // probe support with one cheap call
                let supported =
                    run_cell(algo, e.short, &e.graph, backend, &sources, xla.as_ref()).is_ok();
                let cell = if supported {
                    bench_cell(&cfg, || {
                        let _ =
                            run_cell(algo, e.short, &e.graph, backend, &sources, xla.as_ref());
                    })
                } else {
                    Cell::Unsupported
                };
                match cell.secs() {
                    Some(s) => total += s,
                    None => all_ok = false,
                }
                row.push(cell.display());
            }
            row.push(if all_ok { format!("{total:.3}") } else { "-".to_string() });
            t.row(row);
        }
        println!("{}", t.render());
    }
    println!("Paper shape to verify: LonestarGPU has no BC row; StarPlat is competitive");
    println!("with hand-crafted codes; TC blows up on the skewed graphs (TW/RM analogs).");
}
