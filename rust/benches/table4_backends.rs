//! Regenerates the paper's Table 4: the same StarPlat programs executed by
//! every backend this testbed supports, mapped to the paper's columns:
//!
//! | paper column          | here                                          |
//! |-----------------------|-----------------------------------------------|
//! | CUDA (V100)           | XLA artifacts, device-resident buffers (§4.1) |
//! | OpenACC (NVIDIA GPU)  | XLA artifacts, literal round-trip per iter    |
//! | OpenACC (Intel CPU)   | DSL interpreter, single thread                |
//! | SYCL (Intel CPU)      | DSL interpreter, multi-thread                 |
//!
//! BC additionally sweeps the paper's multi-source sizes (1 / 20 / 80).
//!
//! Run: cargo bench --bench table4_backends

use starplat::backends::xla::{Transfer, XlaBackend};
use starplat::coordinator::driver::{run_cell, Algo, Backend};
use starplat::graph::generators::sample_sources;
use starplat::graph::suite::build_suite;
use starplat::util::bench::{bench_cell, BenchConfig, Cell};
use starplat::util::table::Table;

fn main() {
    // ONE PJRT client + executable cache shared by both accelerator rows
    // (a second client doubles memory and OOMs the 1-CPU testbed).
    let mut xla = XlaBackend::open(std::path::Path::new("artifacts")).ok();
    let scale = xla
        .as_ref()
        .map(|x| x.rt.scale)
        .unwrap_or_else(starplat::graph::suite::default_scale);
    let suite = build_suite(scale);
    let cfg = BenchConfig::default();
    println!("Table 4 — backend comparison at scale {scale}");
    println!("(see bench header comment for the paper-column mapping)\n");

    let mut algos: Vec<(Algo, String, usize)> = vec![
        (Algo::Pr, "PR".into(), 1),
        (Algo::Sssp, "SSSP".into(), 1),
        (Algo::Tc, "TC".into(), 1),
        (Algo::Bc, "BC/1".into(), 1),
    ];
    // The paper's multi-source sweeps are opt-in: the 20/80-source rows
    // multiply the execution count ~20–80× and the vendored xla crate's
    // per-execute literal handling accumulates enough to OOM small
    // testbeds over a full sweep (single cells run fine via
    // `starplat run --algo bc --sources 20 --backend xla`).
    if std::env::var("STARPLAT_BC_FULL").map(|v| v == "1").unwrap_or(false) {
        algos.push((Algo::Bc, "BC/20".into(), 20));
        algos.push((Algo::Bc, "BC/80".into(), 80));
    }
    for (algo, name, nsrc) in algos {
        // keep peak memory bounded: drop the previous table's executables
        if let Some(x) = xla.as_ref() {
            x.rt.clear_cache();
        }
        let mut header = vec!["Backend"];
        let shorts: Vec<&str> = suite.iter().map(|e| e.short).collect();
        header.extend(shorts.iter().copied());
        header.push("Total");
        let mut t = Table::new(&format!("Table 4 — {name}"), &header);
        let rows: Vec<(&str, Backend, Option<Transfer>)> = vec![
            ("XLA dev-resident (CUDA analog)", Backend::Xla, Some(Transfer::DeviceResident)),
            (
                "XLA literal-roundtrip (ACC-GPU analog)",
                Backend::Xla,
                Some(Transfer::LiteralRoundtrip),
            ),
            ("Interp 1T (ACC-CPU analog)", Backend::Seq, None),
            ("Interp MT (SYCL-CPU analog)", Backend::Par, None),
        ];
        for (label, backend, transfer) in rows {
            if let (Some(t), Some(x)) = (transfer, xla.as_mut()) {
                x.transfer = t;
            }
            let x = if backend == Backend::Xla { xla.as_ref() } else { None };
            let mut row = vec![label.to_string()];
            let mut total = 0.0;
            let mut all_ok = true;
            for e in &suite {
                let sources = sample_sources(&e.graph, nsrc, 7);
                let supported = if backend == Backend::Xla {
                    x.is_some()
                        && run_cell(algo, e.short, &e.graph, backend, &sources, x).is_ok()
                } else {
                    true
                };
                let cell = if supported {
                    bench_cell(&cfg, || {
                        let _ = run_cell(algo, e.short, &e.graph, backend, &sources, x);
                    })
                } else {
                    Cell::Unsupported
                };
                match cell.secs() {
                    Some(s) => total += s,
                    None => all_ok = false,
                }
                row.push(cell.display());
            }
            row.push(if all_ok { format!("{total:.3}") } else { "-".into() });
            t.row(row);
        }
        println!("{}", t.render());
    }
    println!("Paper shape to verify: the accelerator path beats single-thread CPU on the");
    println!("compute-bound cells; the literal-roundtrip row shows the §4 transfer cost;");
    println!("BC time scales ~linearly with #sources on short-diameter graphs (§5.2).");
}
