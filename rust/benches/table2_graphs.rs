//! Regenerates the paper's Table 2: the ten-graph input suite with
//! |V| / |E| / average and maximum degree (plus a diameter proxy and the
//! generation time — our graphs are synthesized, not downloaded).
//!
//! Run: cargo bench --bench table2_graphs

use starplat::coordinator;
use starplat::graph::suite;
use starplat::util::bench::time_once;

fn main() {
    let scale = suite::default_scale();
    let (secs, table) = time_once(|| coordinator::table2(scale));
    println!("{}", table.render());
    println!("suite generated in {:.2}s at scale {scale} (STARPLAT_SCALE to change)", secs);
    println!();
    println!("Paper check (Table 2 shape): six social graphs with hubs (max δ >> avg δ),");
    println!("two road networks with δ̄≈2–4 and tiny max degree, RMAT skewed, UR tight.");
}
