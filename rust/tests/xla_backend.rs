//! Integration: the XLA artifact path (DSL → generated JAX → AOT HLO → PJRT)
//! must agree with the oracles on the benchmark suite. Requires
//! `make artifacts`; tests become no-ops (with a notice) if artifacts are
//! missing so `cargo test` stays green pre-build.

use starplat::algorithms::reference;
use starplat::backends::xla::{Transfer, XlaBackend};
use starplat::graph::generators::sample_sources;
use starplat::graph::suite::build_suite;

fn open() -> Option<(XlaBackend, Vec<starplat::graph::suite::SuiteEntry>)> {
    let xla = match XlaBackend::open(std::path::Path::new(
        &format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")),
    )) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("skipping XLA tests (run `make artifacts`): {e:#}");
            return None;
        }
    };
    let suite = build_suite(xla.rt.scale);
    Some((xla, suite))
}

/// Small-but-varied subset: a social graph, a road graph, the RMAT.
const TEST_GRAPHS: [&str; 3] = ["OK", "GR", "RM"];

#[test]
fn xla_sssp_matches_dijkstra() {
    let Some((xla, suite)) = open() else { return };
    for short in TEST_GRAPHS {
        let e = suite.iter().find(|e| e.short == short).unwrap();
        let got = xla.run_sssp(short, &e.graph, 0).unwrap();
        let want = reference::dijkstra(&e.graph, 0);
        assert_eq!(got, want, "{short}");
    }
}

#[test]
fn xla_sssp_literal_roundtrip_agrees() {
    let Some((mut xla, suite)) = open() else { return };
    xla.transfer = Transfer::LiteralRoundtrip;
    let e = suite.iter().find(|e| e.short == "RM").unwrap();
    let got = xla.run_sssp("RM", &e.graph, 0).unwrap();
    assert_eq!(got, reference::dijkstra(&e.graph, 0));
}

#[test]
fn xla_bfs_matches_reference() {
    let Some((xla, suite)) = open() else { return };
    for short in TEST_GRAPHS {
        let e = suite.iter().find(|e| e.short == short).unwrap();
        let got = xla.run_bfs(short, &e.graph, 0).unwrap();
        let want = reference::bfs_levels(&e.graph, 0);
        assert_eq!(got, want, "{short}");
    }
}

#[test]
fn xla_pr_matches_reference() {
    let Some((xla, suite)) = open() else { return };
    for short in TEST_GRAPHS {
        let e = suite.iter().find(|e| e.short == short).unwrap();
        let got = xla.run_pr(short, &e.graph, 1e-7, 0.85, 100).unwrap();
        let want = reference::pagerank(&e.graph, 1e-7, 0.85, 100);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(
                (f64::from(*a) - b).abs() < 1e-3 * (1.0 + b.abs()),
                "{short} v{i}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn xla_bc_matches_brandes() {
    let Some((xla, suite)) = open() else { return };
    for short in ["OK", "GR"] {
        let e = suite.iter().find(|e| e.short == short).unwrap();
        let sources = sample_sources(&e.graph, 3, 7);
        let got = xla.run_bc(short, &e.graph, &sources).unwrap();
        let want = reference::betweenness(&e.graph, &sources);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(
                (f64::from(*a) - b).abs() < 1e-2 * (1.0 + b.abs()),
                "{short} v{i}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn xla_tc_matches_reference() {
    let Some((xla, suite)) = open() else { return };
    for short in TEST_GRAPHS {
        let e = suite.iter().find(|e| e.short == short).unwrap();
        let got = xla.run_tc(short, &e.graph).unwrap();
        let want = reference::triangle_count(&e.graph);
        assert_eq!(got, want, "{short}");
    }
}

#[test]
fn xla_cc_matches_reference() {
    let Some((xla, suite)) = open() else { return };
    let e = suite.iter().find(|e| e.short == "US").unwrap();
    let got = xla.run_cc("US", &e.graph).unwrap();
    let want = reference::connected_components(&e.graph);
    assert_eq!(got, want);
}
