//! Golden tests: the generated accelerator code must reproduce the idioms of
//! the paper's Figures 2–12 (one test per figure). We assert on the
//! characteristic lines rather than byte-identical files so cosmetic emitter
//! changes don't break the suite.

use starplat::codegen;
use starplat::dsl::parser::parse_file;
use starplat::ir::lower;
use starplat::sema::check_function;

fn gen(program: &str, backend: &str) -> String {
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("dsl_programs").join(program);
    let fns = parse_file(&path).unwrap();
    let tf = check_function(&fns[0]).unwrap();
    codegen::generate(backend, &lower(&tf)).unwrap()
}

/// Generate from inline DSL source (idiom pins that need a shape no shipped
/// program exercises, e.g. a `*=` product reduction).
fn gen_src(src: &str, backend: &str) -> String {
    let fns = starplat::dsl::parser::parse(src).unwrap();
    let tf = check_function(&fns[0]).unwrap();
    codegen::generate(backend, &lower(&tf)).unwrap()
}

fn assert_has(src: &str, needles: &[&str], what: &str) {
    for n in needles {
        assert!(src.contains(n), "{what}: missing `{n}` in generated code:\n{src}");
    }
}

#[test]
fn fig2_cuda_neighborhood_iteration() {
    let cuda = gen("sssp.sp", "cuda");
    assert_has(
        &cuda,
        &[
            "__global__ void",
            "blockIdx.x * blockDim.x + threadIdx.x",
            "for (int edge = gpu_OA[v]; edge < gpu_OA[v+1]; edge++) {",
            "int nbr = gpu_edgeList[edge];",
            "<<<numBlocks, threadsPerBlock>>>",
        ],
        "Fig 2 (CUDA neighbor iteration)",
    );
}

#[test]
fn fig3_openacc_data_clauses() {
    let acc = gen("sssp.sp", "openacc");
    assert_has(
        &acc,
        &[
            "#pragma acc data copyin(g)",
            "g.edgeList[0:g.num_edges()]",
            "g.indexofNodes[:g.num_nodes()+1]",
            "copy(dist[0:g.num_nodes()])",
            "#pragma acc parallel loop",
            "int nbr = g.edgeList[edge];",
        ],
        "Fig 3 (OpenACC data clauses + neighbor loop)",
    );
}

#[test]
fn fig4_sycl_parallel_for() {
    let sycl = gen("sssp.sp", "sycl");
    assert_has(
        &sycl,
        &[
            "Q.submit([&](handler& h) {",
            "h.parallel_for(NUM_THREADS, [=](id<1> v) {",
            "for (; v < V; v += NUM_THREADS) {",
            "}).wait();",
        ],
        "Fig 4 (SYCL parallel_for)",
    );
}

#[test]
fn fig5_opencl_kernel() {
    let ocl = gen("sssp.sp", "opencl");
    assert_has(
        &ocl,
        &[
            "__kernel void",
            "get_global_id(0)",
            "__global int* gpu_OA",
            "clEnqueueNDRangeKernel",
            "clSetKernelArg",
        ],
        "Fig 5 (OpenCL kernel + host)",
    );
}

#[test]
fn fig6_cuda_min_construct_atomics() {
    let cuda = gen("sssp.sp", "cuda");
    assert_has(
        &cuda,
        &[
            "int e = edge;",
            "int dist_new = gpu_dist[v] + gpu_weight[e];",
            "if (gpu_dist[nbr] > dist_new) {",
            "atomicMin(&gpu_dist[nbr], dist_new);",
            "gpu_modified_nxt[nbr] = true;",
            "gpu_finished[0] = false;",
        ],
        "Fig 6 (CUDA atomicMin + flag)",
    );
}

#[test]
fn fig7_openacc_reduction_clause() {
    let acc = gen("pr.sp", "openacc");
    assert_has(
        &acc,
        &[
            "#pragma acc parallel loop reduction(+: diff)",
            "int nbr = g.srcList[edge];",
            "pageRank_nxt[v] = val;",
        ],
        "Fig 7 (OpenACC PR reduction clause)",
    );
}

#[test]
fn fig8_sycl_atomic_ref_reduction() {
    let sycl = gen("tc.sp", "sycl");
    assert_has(
        &sycl,
        &[
            "atomic_ref<",
            "memory_order::relaxed",
            "memory_scope::device",
            "access::address_space::global_space",
            "atomic_data += 1;",
        ],
        "Fig 8 (SYCL atomic_ref reduction in TC)",
    );
}

#[test]
fn fig9_cuda_bfs_host_device_split() {
    let cuda = gen("bc.sp", "cuda");
    assert_has(
        &cuda,
        &[
            "do {",
            "} while (!finished);",
            "++hops_from_source;",
            "if (gpu_level[v] == *d_hops_from_source) {",
            "if (gpu_level[nbr] == -1) {",
            "gpu_level[nbr] = *d_hops_from_source + 1;",
            "*d_finished = false;",
        ],
        "Fig 9 (CUDA iterateInBFS)",
    );
}

#[test]
fn fig10_openacc_min_construct() {
    let acc = gen("sssp.sp", "openacc");
    assert_has(
        &acc,
        &[
            "int dist_new = dist[v] + weight[e];",
            "if (dist[nbr] > dist_new) {",
            "#pragma acc atomic write",
            "dist[nbr] = dist_new;",
            "finished = false;",
        ],
        "Fig 10 (OpenACC Min construct)",
    );
    // the old walker declared an untyped `int oldValue` it never read; the
    // KernelDialect arm types the compare temporary from the plan instead
    assert!(!acc.contains("oldValue"), "dead oldValue temporary crept back in:\n{acc}");
}

/// Satellite pin: both iterateInBFS sweeps restrict neighbor iteration with
/// the same §3.4 BFS-DAG child filter — one structured condition in the
/// KernelOp lowering, not two byte-identical per-direction match arms.
#[test]
fn bfs_dag_level_filter_identical_in_both_sweeps() {
    let cuda = gen("bc.sp", "cuda");
    let filter = "if (gpu_level[w] == gpu_level[v] + 1) {";
    let count = cuda.matches(filter).count();
    assert!(
        count >= 2,
        "expected the BFS-DAG level filter in both the forward and reverse sweep \
         (found {count} occurrence(s) of `{filter}`):\n{cuda}"
    );
}

#[test]
fn fig11_sycl_fetch_min() {
    let sycl = gen("sssp.sp", "sycl");
    assert_has(
        &sycl,
        &[
            "int dist_new = g.gpu_dist[v] + g.gpu_weight[e];",
            "atomic_data.fetch_min(dist_new);",
            "*d_finished = false;",
        ],
        "Fig 11 (SYCL Min via fetch_min)",
    );
}

#[test]
fn fig12_fixed_point_host_loop() {
    let cuda = gen("sssp.sp", "cuda");
    assert_has(
        &cuda,
        &[
            "while (!finished) {",
            "finished = true;",
            "cudaMemcpy(gpu_finished, &finished, sizeof(bool) * 1, cudaMemcpyHostToDevice);",
            "cudaMemcpy(&finished, gpu_finished, sizeof(bool) * 1, cudaMemcpyDeviceToHost);",
        ],
        "Fig 12 (fixedPoint host loop)",
    );
}

#[test]
fn transfer_optimizations_visible_in_all_backends() {
    // §4: graph copied once; outputs returned once; OR-flag is one word.
    let cuda = gen("sssp.sp", "cuda");
    assert!(cuda.contains("copied to the device once"));
    assert!(cuda.contains("cudaMemcpy(dist, gpu_dist"));
    let sycl = gen("sssp.sp", "sycl");
    assert!(sycl.contains("malloc_device"));
    assert!(sycl.contains("Q.memcpy(dist, g.gpu_dist"));
    let acc = gen("pr.sp", "openacc");
    assert!(acc.contains("copy(pageRank[0:g.num_nodes()])"));
}

#[test]
fn all_programs_generate_on_all_text_backends() {
    for p in ["bc.sp", "pr.sp", "sssp.sp", "tc.sp", "cc.sp", "bfs.sp"] {
        for b in codegen::TEXT_BACKENDS {
            let out = gen(p, b);
            assert!(out.len() > 200, "{p}/{b} suspiciously small:\n{out}");
            // no unresolved filter artifacts like `modified == True`
            assert!(!out.contains("True"), "{p}/{b} leaked DSL literal True");
        }
    }
}

// ---------------------------------------------------------------------------
// HIP variants of the Fig 2 / 9 / 12 idiom tests: the fifth backend renders
// the same plan as CUDA with HIP spellings.
// ---------------------------------------------------------------------------

#[test]
fn hip_fig2_neighborhood_iteration_and_launch() {
    let hip = gen("sssp.sp", "hip");
    assert_has(
        &hip,
        &[
            "#include <hip/hip_runtime.h>",
            "__global__ void",
            "blockIdx.x * blockDim.x + threadIdx.x",
            "for (int edge = gpu_OA[v]; edge < gpu_OA[v+1]; edge++) {",
            "int nbr = gpu_edgeList[edge];",
            "hipLaunchKernelGGL(Compute_SSSP_kernel_1, dim3(numBlocks), dim3(threadsPerBlock), 0, 0, ",
            "hipMemcpy(gpu_edgeList, g.edgeList, sizeof(int) * E, hipMemcpyHostToDevice);",
            "hipDeviceSynchronize();",
        ],
        "HIP Fig 2 (neighbor iteration + hipLaunchKernelGGL)",
    );
}

#[test]
fn hip_fig9_level_sync_bfs_do_while() {
    let hip = gen("bc.sp", "hip");
    assert_has(
        &hip,
        &[
            "do {",
            "} while (!finished);",
            "++hops_from_source;",
            "if (gpu_level[v] == *d_hops_from_source) {",
            "if (gpu_level[nbr] == -1) {",
            "gpu_level[nbr] = *d_hops_from_source + 1;",
            "*d_finished = false;",
            "hipLaunchKernelGGL(Compute_BC_bfs_kernel_",
            "hipLaunchKernelGGL(HIP_KERNEL_NAME(initKernel<int>),",
        ],
        "HIP Fig 9 (iterateInBFS do-while)",
    );
}

#[test]
fn hip_fig12_fixed_point_host_loop() {
    let hip = gen("sssp.sp", "hip");
    assert_has(
        &hip,
        &[
            "while (!finished) {",
            "finished = true;",
            "hipMemcpy(gpu_finished, &finished, sizeof(bool) * 1, hipMemcpyHostToDevice);",
            "hipMemcpy(&finished, gpu_finished, sizeof(bool) * 1, hipMemcpyDeviceToHost);",
        ],
        "HIP Fig 12 (fixedPoint host loop)",
    );
}

// ---------------------------------------------------------------------------
// Metal and WGSL: the two backends the old per-Target kernel walker could
// not express — typed atomic buffers (declaration + loads change), and a
// non-C shader dialect with @group/@binding storage bindings.
// ---------------------------------------------------------------------------

#[test]
fn metal_min_construct_and_atomic_buffer_typing() {
    let metal = gen("sssp.sp", "metal");
    assert_has(
        &metal,
        &[
            "kernel void Compute_SSSP_kernel_1(",
            "[[buffer(0)]]",
            "uint tid [[thread_position_in_grid]]",
            // dist is atomically updated, so its buffer is atomic_int and
            // its plain reads are explicit atomic loads
            "device atomic_int* gpu_dist",
            "int dist_new = atomic_load_explicit(&gpu_dist[v], memory_order_relaxed) + gpu_weight[e];",
            "atomic_fetch_min_explicit(&gpu_dist[nbr], dist_new, memory_order_relaxed);",
            "atomic_store_explicit(gpu_finished, false, memory_order_relaxed);",
            // host half: metal-cpp shared-storage buffers + dispatch
            "MTL::Buffer* gpu_dist = dev->newBuffer(sizeof(int) * V, MTL::ResourceStorageModeShared);",
            "enc->setComputePipelineState(pipelineFor(dev, \"Compute_SSSP_kernel_1\"));",
            "enc->dispatchThreads(gridSize, threadsPerGroup);",
        ],
        "Metal (MSL Min construct + metal-cpp host)",
    );
    // the §3.3 reduction cell shape on Metal: TC's count lands in an
    // atomic_int cell via fetch_add (MSL has no 64-bit fetch-ops, so the
    // long long cell demotes and the host stages it through an int word)
    let tc = gen("tc.sp", "metal");
    assert_has(
        &tc,
        &[
            "device atomic_int* d_triangle_count",
            "atomic_fetch_add_explicit(&d_triangle_count[0], 1, memory_order_relaxed);",
            "*(int*)d_triangle_count->contents() = (int)triangle_count;",
            "triangle_count = *(int*)d_triangle_count->contents();",
        ],
        "Metal (TC reduction cell)",
    );
}

#[test]
fn wgsl_min_construct_storage_bindings_and_uniform_params() {
    let wgsl = gen("sssp.sp", "wgsl");
    assert_has(
        &wgsl,
        &[
            "// shader module: Compute_SSSP_kernel_1",
            "@group(0) @binding(0) var<uniform> params : Params;",
            // atomically-updated buffer: atomic<i32> element type, loads
            // through atomicLoad, the Min itself through atomicMin
            "var<storage, read_write> gpu_dist : array<atomic<i32>>;",
            "var dist_new : i32 = atomicLoad(&gpu_dist[v]) + gpu_weight[e];",
            "if (atomicLoad(&gpu_dist[nbr]) > dist_new) {",
            "atomicMin(&gpu_dist[nbr], dist_new);",
            "atomicStore(&gpu_finished[0], 0);",
            "@compute @workgroup_size(256)",
            "fn Compute_SSSP_kernel_1(@builtin(global_invocation_id) gid : vec3<u32>) {",
            "let v = i32(gid.x);",
            // host half: Dawn/webgpu_cpp skeleton
            "wgpu::Buffer gpu_dist = makeStorageBuffer(device, sizeof(int) * V);",
            "pass.SetPipeline(pipelineFor(device, \"Compute_SSSP_kernel_1\"));",
            "pass.DispatchWorkgroups(numWorkgroups, 1, 1);",
        ],
        "WGSL (storage bindings + atomicMin + WebGPU host)",
    );
    // graph arrays stay read-only storage; neighbor loops are WGSL `for`
    assert_has(
        &wgsl,
        &[
            "var<storage, read> gpu_OA : array<i32>;",
            "for (var edge : i32 = gpu_OA[v]; edge < gpu_OA[v + 1]; edge++) {",
            "let nbr = gpu_edgeList[edge];",
        ],
        "WGSL (CSR neighbor scan)",
    );
    // TC: module-scope edge lookup helper without pointer-passing the CSR
    let tc = gen("tc.sp", "wgsl");
    assert_has(
        &tc,
        &[
            "fn findNeighborSorted(u : i32, w : i32) -> bool {",
            "if (findNeighborSorted(u, w)) {",
            "atomicAdd(&d_triangle_count[0], 1);",
        ],
        "WGSL (TC edge lookup + cell reduction)",
    );
    // PR: f32 cells are atomic<u32> bit patterns updated by the real
    // bitcast-CAS helper (§3.3's float story — WGSL has no f32 atomics)
    let pr = gen("pr.sp", "wgsl");
    assert_has(
        &pr,
        &[
            "fn atomicAddF32(cell : ptr<storage, atomic<u32>, read_write>, value : f32) {",
            "let old = atomicLoad(cell);",
            "let updated = bitcast<u32>(bitcast<f32>(old) + value);",
            "if (atomicCompareExchangeWeak(cell, old, updated).exchanged) { break; }",
            "var<storage, read_write> d_diff : array<atomic<u32>>;",
            "atomicAddF32(&d_diff[0], abs(val - gpu_pageRank[v]));",
        ],
        "WGSL (f32 reduction via atomic<u32> bitcast-CAS)",
    );
    // the old commented read-modify-write must be gone
    assert!(
        !pr.contains("*cell = *cell + value;"),
        "plain RMW body crept back into atomicAddF32:\n{pr}"
    );
}

/// Satellite pin: an atomically-updated *f32 property buffer* (BC's sigma /
/// delta accumulations) types as `array<atomic<u32>>`, its plain reads
/// bitcast the loaded word back to f32, and the add goes through the CAS
/// helper — the declaration-changes-with-usage property that forced the
/// KernelDialect design in the first place.
#[test]
fn wgsl_f32_prop_buffers_are_bit_pattern_atomics() {
    let bc = gen("bc.sp", "wgsl");
    assert_has(
        &bc,
        &[
            "var<storage, read_write> gpu_sigma : array<atomic<u32>>;",
            "atomicAddF32(&gpu_sigma[w], bitcast<f32>(atomicLoad(&gpu_sigma[v])));",
            "atomicAddF32(&gpu_delta[v], ",
        ],
        "WGSL (f32 property buffer as atomic<u32>)",
    );
}

/// Satellite pin: Metal's `atomicMulCAS` has a real definition (MSL has no
/// `atomic_fetch_mul`), emitted only when a kernel multiplies into an atomic
/// location; WGSL's integer `atomicMulCAS` helper pairs with it.
#[test]
fn mul_reduction_cas_helpers_are_defined() {
    const MUL_SRC: &str = "function Compute_Scale(Graph g, propNode<int> fact) {
        forall (v in g.nodes()) {
          forall (nbr in g.neighbors(v)) {
            nbr.fact *= 2;
          }
        }
      }";
    let metal = gen_src(MUL_SRC, "metal");
    assert_has(
        &metal,
        &[
            "static inline void atomicMulCAS(device atomic_int* cell, int value) {",
            "static inline void atomicMulCAS(device atomic_float* cell, float value) {",
            "while (!atomic_compare_exchange_weak_explicit(cell, &old, old * value, memory_order_relaxed, memory_order_relaxed)) { }",
            "atomicMulCAS(&gpu_fact[nbr], 2);",
        ],
        "Metal (atomicMulCAS definition + call site)",
    );
    let wgsl = gen_src(MUL_SRC, "wgsl");
    assert_has(
        &wgsl,
        &[
            "fn atomicMulCAS(cell : ptr<storage, atomic<i32>, read_write>, value : i32) {",
            "atomicMulCAS(&gpu_fact[nbr], 2);",
        ],
        "WGSL (integer mul CAS helper)",
    );
    // f32 products must NOT route through the i32 helper: the buffer is an
    // atomic<u32> bit pattern, so the mul gets its own bitcast-CAS helper
    const MUL_F32_SRC: &str = "function Compute_Damp(Graph g, propNode<float> w) {
        forall (v in g.nodes()) {
          forall (nbr in g.neighbors(v)) {
            nbr.w *= 0.5;
          }
        }
      }";
    let wgsl_f = gen_src(MUL_F32_SRC, "wgsl");
    assert_has(
        &wgsl_f,
        &[
            "var<storage, read_write> gpu_w : array<atomic<u32>>;",
            "fn atomicMulF32(cell : ptr<storage, atomic<u32>, read_write>, value : f32) {",
            "let updated = bitcast<u32>(bitcast<f32>(old) * value);",
            "atomicMulF32(&gpu_w[nbr], 0.5);",
        ],
        "WGSL (f32 mul bitcast-CAS helper)",
    );
    assert!(
        !wgsl_f.contains("atomicMulCAS"),
        "f32 product routed through the i32 helper:\n{wgsl_f}"
    );
    // programs without a product reduction don't pay for the helper
    let sssp = gen("sssp.sp", "metal");
    assert!(
        !sssp.contains("atomicMulCAS"),
        "mul helper emitted without a Mul reduce:\n{sssp}"
    );
}

// ---------------------------------------------------------------------------
// Negative assertions on all seven backends: no placeholder params, no
// buffer used before its alloc line, every alloc has a matching free/release.
// ---------------------------------------------------------------------------

const ALL_PROGRAMS: [&str; 6] = ["bc.sp", "pr.sp", "sssp.sp", "tc.sp", "cc.sp", "bfs.sp"];

#[test]
fn no_placeholder_params_on_any_backend() {
    for p in ALL_PROGRAMS {
        for b in codegen::TEXT_BACKENDS {
            let out = gen(p, b);
            assert!(!out.contains("..."), "{p}/{b}: `...` placeholder left in generated code");
            assert!(
                !out.contains("/* launch"),
                "{p}/{b}: placeholder launch comment left in generated code"
            );
        }
    }
}

/// The host section of a generated file (kernel text precedes it in the
/// split backends and may legally name buffers in parameter lists).
fn host_section(src: &str, backend: &str) -> String {
    let marker = match backend {
        "opencl" => "// ---- host.cpp ----",
        "metal" => "// ---- host.mm",
        "wgsl" => "// ---- host.cpp",
        _ => "\nvoid ",
    };
    match src.find(marker) {
        Some(i) => src[i..].to_string(),
        None => src.to_string(),
    }
}

/// `needle` appears in `hay` bounded by non-identifier characters.
fn mentions(hay: &str, needle: &str) -> bool {
    let mut from = 0;
    while let Some(i) = hay[from..].find(needle) {
        let start = from + i;
        let end = start + needle.len();
        let pre_ok = start == 0
            || !hay.as_bytes()[start - 1].is_ascii_alphanumeric()
                && hay.as_bytes()[start - 1] != b'_'
                && hay.as_bytes()[start - 1] != b'.';
        let post_ok = end == hay.len()
            || !hay.as_bytes()[end].is_ascii_alphanumeric() && hay.as_bytes()[end] != b'_';
        if pre_ok && post_ok {
            return true;
        }
        from = end;
    }
    false
}

/// Extract (buffer name, alloc line index) pairs and free-site names from
/// one backend's host section.
fn alloc_free_events(host: &str, backend: &str) -> (Vec<(String, usize)>, Vec<String>) {
    let mut allocs = Vec::new();
    let mut frees = Vec::new();
    for (i, l) in host.lines().enumerate() {
        let t = l.trim();
        match backend {
            "cuda" | "hip" => {
                let m = if backend == "cuda" { "cudaMalloc(&" } else { "hipMalloc(&" };
                if let Some(rest) = t.split(m).nth(1) {
                    let name = rest.split(',').next().unwrap().to_string();
                    allocs.push((name, i));
                }
                let f = if backend == "cuda" { "cudaFree(" } else { "hipFree(" };
                if let Some(rest) = t.strip_prefix(f) {
                    frees.push(rest.trim_end_matches(");").to_string());
                }
            }
            "opencl" => {
                if t.starts_with("cl_mem ") && t.contains("= clCreateBuffer") {
                    let name = t["cl_mem ".len()..].split(' ').next().unwrap().to_string();
                    allocs.push((name, i));
                }
                if let Some(rest) = t.strip_prefix("clReleaseMemObject(") {
                    frees.push(rest.trim_end_matches(");").to_string());
                }
            }
            "sycl" => {
                if t.contains("= malloc_device<") {
                    let lhs = t.split(" = malloc_device").next().unwrap();
                    let name = lhs.split(' ').next_back().unwrap().to_string();
                    allocs.push((name, i));
                }
                if let Some(rest) = t.strip_prefix("sycl::free(") {
                    frees.push(rest.split(',').next().unwrap().to_string());
                }
            }
            "openacc" => {
                if t.contains("= new ") && t.contains('[') {
                    let lhs = t.split(" = new ").next().unwrap();
                    let name = lhs.split(' ').next_back().unwrap().to_string();
                    allocs.push((name, i));
                }
                if let Some(rest) = t.strip_prefix("delete[] ") {
                    frees.push(rest.trim_end_matches(';').to_string());
                }
            }
            "metal" => {
                if let Some(rest) = t.strip_prefix("MTL::Buffer* ") {
                    if rest.contains("= dev->newBuffer(") {
                        allocs.push((rest.split(' ').next().unwrap().to_string(), i));
                    }
                }
                if t.ends_with("->release();") {
                    frees.push(t.trim_end_matches("->release();").to_string());
                }
            }
            "wgsl" => {
                if let Some(rest) = t.strip_prefix("wgpu::Buffer ") {
                    if rest.contains("= makeStorageBuffer(") || rest.contains("= makeUniformBuffer(")
                    {
                        allocs.push((rest.split(' ').next().unwrap().to_string(), i));
                    }
                }
                if t.ends_with(".Destroy();") {
                    frees.push(t.trim_end_matches(".Destroy();").to_string());
                }
            }
            other => panic!("unknown backend {other}"),
        }
    }
    (allocs, frees)
}

#[test]
fn every_alloc_is_freed_and_no_buffer_is_used_before_alloc() {
    for p in ALL_PROGRAMS {
        for b in codegen::TEXT_BACKENDS {
            let out = gen(p, b);
            let host = host_section(&out, b);
            let (allocs, frees) = alloc_free_events(&host, b);
            assert!(!allocs.is_empty() || b == "openacc", "{p}/{b}: no allocations found");
            // (1) alloc/free multisets match
            let mut a: Vec<&str> = allocs.iter().map(|(n, _)| n.as_str()).collect();
            let mut f: Vec<&str> = frees.iter().map(String::as_str).collect();
            a.sort_unstable();
            f.sort_unstable();
            assert_eq!(a, f, "{p}/{b}: allocs and frees don't pair up");
            // (2) every mention of an allocated buffer before its alloc
            // line must be a declaration — never a use
            let lines: Vec<&str> = host.lines().collect();
            for (name, alloc_line) in &allocs {
                for (i, l) in lines.iter().enumerate().take(*alloc_line) {
                    if mentions(l, name) {
                        assert!(
                            is_decl_of(l, name),
                            "{p}/{b}: `{name}` used on line {i} before its alloc on {alloc_line}:\n{l}"
                        );
                    }
                }
            }
        }
    }
}

/// Is this line a declaration of `name` (e.g. `int* gpu_OA;`,
/// `bool* d_finished;`)?
fn is_decl_of(line: &str, name: &str) -> bool {
    let t = line.trim();
    t.ends_with(&format!("* {name};")) || t.ends_with(&format!(" {name};"))
}

