//! Differential testing of the 7-backend lowering: the plan executor
//! (`backends::planexec`) runs the exact `DevicePlan` every text backend
//! renders, and must match the AST interpreter **bit for bit** — integer
//! props by value, float props by `f64::to_bits` — across all six shipped
//! programs, seeded graph families, and all three direction policies.
//!
//! The oracle is the interpreter at 1 thread with the dense schedule: the
//! executor's sequential `v = 0..V` sweeps visit vertices in the same order,
//! so even order-sensitive float accumulations (PageRank's `diff`, BC's
//! delta sums) agree exactly. Confluent integer algorithms (SSSP/BFS/CC)
//! agree under any schedule. A mismatch therefore indicts the lowering —
//! slot assignment, transfer protocol, loop skeletons, kernel-op semantics —
//! not arithmetic noise, which is the point of executing the plan at all.
//!
//! Every assertion message carries the family seed, so a failure reproduces
//! with `SEEDS = [<seed>]`.

use starplat::backends::interp::{self, DeltaMode, Direction, ExecOpts};
use starplat::backends::planexec;
use starplat::coordinator::driver::{algo_args, load_program, Algo};
use starplat::graph::csr::Graph;
use starplat::graph::generators::{
    path_graph, road_grid, sample_sources, star_graph, uniform_random,
};
use starplat::util::rng::Rng;

const SEEDS: [u64; 2] = [0xA11CE, 0x5EED2];

const ALGOS: [Algo; 6] = [Algo::Bfs, Algo::Sssp, Algo::Cc, Algo::Pr, Algo::Tc, Algo::Bc];

/// Rewrite every weight to 1: the unweighted view of a family (weights are
/// CSR-parallel, so this preserves the topology exactly).
fn unit_weighted(mut g: Graph) -> Graph {
    for w in &mut g.weights {
        *w = 1;
    }
    g
}

/// The seeded families: path (max diameter), star (max degree), grid
/// (mesh), G(n,m) (uniform random) — weighted and unweighted views.
fn families(seed: u64) -> Vec<Graph> {
    let mut rng = Rng::new(seed);
    let n_path = rng.range(12, 40);
    let n_star = rng.range(8, 30);
    let rows = rng.range(4, 8);
    let cols = rng.range(4, 8);
    let n = rng.range(40, 120);
    let m = rng.range(2 * n, 4 * n);
    vec![
        path_graph("path-w", n_path, seed, false),
        path_graph("path-u", n_path, seed, true),
        star_graph("star-w", n_star, seed, false),
        road_grid("grid", rows, cols, seed),
        uniform_random("gnm-w", n, m, seed),
        unit_weighted(uniform_random("gnm-u", n, m, seed ^ 0x9E37)),
    ]
}

fn assert_bits_eq(got: &[f64], want: &[f64], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: prop length diverged");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{ctx}: v{i} diverged bitwise: planexec {a:?} vs interp {b:?}"
        );
    }
}

/// Run one (program, graph, direction) cell through both engines and
/// compare bit-for-bit.
fn run_pair(algo: Algo, g: &Graph, seed: u64, dir: Direction) {
    let ctx = format!("{algo:?} on {} (seed {seed:#x}, dir {dir:?})", g.name);
    let tf = load_program(algo).unwrap();
    let sources = sample_sources(g, 3, seed);
    let args = algo_args(algo, &sources);
    // oracle: 1-thread dense interpreter — same vertex order as the
    // executor's sequential sweeps
    let oracle = ExecOpts {
        threads: 1,
        frontier: false,
        direction: Some(dir),
        delta: Some(DeltaMode::Off),
        ..Default::default()
    };
    let want = interp::run_with_opts(&tf, g, &args, oracle)
        .unwrap_or_else(|e| panic!("{ctx}: interpreter failed: {e:#}"));
    let got = planexec::run_with_opts(
        &tf,
        g,
        &args,
        ExecOpts { direction: Some(dir), ..Default::default() },
    )
    .unwrap_or_else(|e| panic!("{ctx}: plan executor failed: {e:#}"));
    match algo {
        Algo::Bfs => {
            let w = want.prop_i64("level");
            assert!(!w.is_empty(), "{ctx}: oracle produced no levels");
            assert_eq!(got.prop_i64("level"), w, "{ctx}: BFS levels diverged");
        }
        Algo::Sssp => {
            let w = want.prop_i64("dist");
            assert!(!w.is_empty(), "{ctx}: oracle produced no distances");
            assert_eq!(got.prop_i64("dist"), w, "{ctx}: SSSP distances diverged");
        }
        Algo::Cc => {
            let w = want.prop_i64("comp");
            assert!(!w.is_empty(), "{ctx}: oracle produced no components");
            assert_eq!(got.prop_i64("comp"), w, "{ctx}: CC labels diverged");
            // acceptance: the one program whose relaxation compiles a pull
            // twin must actually run it when the host switch selects pull
            if dir == Direction::Pull {
                assert!(
                    got.stats.pull_rounds > 0,
                    "{ctx}: pull twin compiled in but the executor never ran it"
                );
            }
        }
        Algo::Pr => {
            let w = want.prop_f64("pageRank");
            assert!(!w.is_empty(), "{ctx}: oracle produced no ranks");
            assert_bits_eq(&got.prop_f64("pageRank"), &w, &ctx);
        }
        Algo::Bc => {
            let w = want.prop_f64("BC");
            assert!(!w.is_empty(), "{ctx}: oracle produced no centrality");
            assert_bits_eq(&got.prop_f64("BC"), &w, &ctx);
        }
        Algo::Tc => {
            let w = want.ret.and_then(|v| v.as_i().ok());
            let g_ = got.ret.and_then(|v| v.as_i().ok());
            assert!(w.is_some(), "{ctx}: oracle returned no count");
            assert_eq!(g_, w, "{ctx}: triangle counts diverged");
        }
    }
    // the executor never pulls without the switch; forced push must stay push
    if dir == Direction::Push {
        assert_eq!(got.stats.pull_rounds, 0, "{ctx}: push forced but executor pulled");
    }
}

fn sweep(dir: Direction) {
    for seed in SEEDS {
        for g in families(seed) {
            for algo in ALGOS {
                run_pair(algo, &g, seed, dir);
            }
        }
    }
}

#[test]
fn planexec_matches_interpreter_push() {
    sweep(Direction::Push);
}

#[test]
fn planexec_matches_interpreter_pull() {
    sweep(Direction::Pull);
}

#[test]
fn planexec_matches_interpreter_auto() {
    sweep(Direction::Auto);
}

/// The reverse differential: planexec as the *oracle* for the interpreter's
/// parallel frontier engine. Integer algorithms are confluent (any
/// schedule reaches the same fixpoint exactly), so the work-stealing
/// frontier path at 8 threads must match the executor's sequential plan
/// semantics bit-for-bit — including under `STARPLAT_FAULT` (CI's
/// planexec-differential job exports claim_gather / pool_dispatch seeds;
/// the sparse→dense fallback is exact recovery, and planexec ignores fault
/// switches entirely, so parity must survive injected faults unchanged).
#[test]
fn parallel_frontier_interpreter_matches_planexec() {
    for seed in SEEDS {
        for g in families(seed) {
            for algo in [Algo::Bfs, Algo::Sssp, Algo::Cc, Algo::Tc] {
                let ctx = format!("{algo:?} on {} (seed {seed:#x}, 8 threads)", g.name);
                let tf = load_program(algo).unwrap();
                let sources = sample_sources(&g, 3, seed);
                let args = algo_args(algo, &sources);
                let want = planexec::run(&tf, &g, &args)
                    .unwrap_or_else(|e| panic!("{ctx}: plan executor failed: {e:#}"));
                let opts = ExecOpts { threads: 8, frontier: true, ..Default::default() };
                let got = interp::run_with_opts(&tf, &g, &args, opts)
                    .unwrap_or_else(|e| panic!("{ctx}: interpreter failed: {e:#}"));
                match algo {
                    Algo::Tc => {
                        let w = want.ret.and_then(|v| v.as_i().ok());
                        assert_eq!(got.ret.and_then(|v| v.as_i().ok()), w, "{ctx}");
                    }
                    _ => {
                        let prop = match algo {
                            Algo::Bfs => "level",
                            Algo::Sssp => "dist",
                            _ => "comp",
                        };
                        assert_eq!(got.prop_i64(prop), want.prop_i64(prop), "{ctx}");
                    }
                }
            }
        }
    }
}

/// The CLI surface: `--backend planexec` resolves through the coordinator
/// and produces the interpreter's checksum for every algorithm.
#[test]
fn planexec_backend_checksums_match_interpreter() {
    use starplat::backends::interp::Mode;
    use starplat::coordinator::driver::checksum_of;
    let g = uniform_random("cli", 80, 240, 0xD15C);
    let sources = sample_sources(&g, 3, 11);
    for algo in ALGOS {
        let tf = load_program(algo).unwrap();
        let args = algo_args(algo, &sources);
        let want = checksum_of(algo, &interp::run(&tf, &g, &args, Mode::Seq).unwrap()).unwrap();
        let got = checksum_of(algo, &planexec::run(&tf, &g, &args).unwrap()).unwrap();
        assert_eq!(got.to_bits(), want.to_bits(), "{algo:?}: checksum diverged");
    }
}
