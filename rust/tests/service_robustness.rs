//! Robustness pins for the in-process execution service
//! (`starplat::runtime::service`): validated registration, panic isolation,
//! deadlines, cancellation, admission control, result caching, and the
//! sparse→dense schedule fallback — each failure mode forced
//! deterministically and checked against a fault-free oracle.

use starplat::backends::interp::{self, Args, ExecError, ExecOpts};
use starplat::dsl::parse;
use starplat::graph::csr::Graph;
use starplat::graph::generators::rmat;
use starplat::runtime::service::{Request, Service, ServiceConfig, ServiceError};
use starplat::sema::check_function;
use starplat::util::cancel::CancelToken;
use starplat::util::fault::{FaultPlan, FaultSite};
use std::sync::Arc;
use std::time::Duration;

const SSSP: &str = include_str!("../dsl_programs/sssp.sp");
const CC: &str = include_str!("../dsl_programs/cc.sp");

fn test_graph() -> Graph {
    rmat("g", 200, 800, 7)
}

/// A service with `test_graph` under "g" and sssp/cc registered.
fn service(cfg: ServiceConfig) -> Service {
    let svc = Service::new(cfg);
    svc.register_graph("g", test_graph()).unwrap();
    svc.register_program("sssp", SSSP).unwrap();
    svc.register_program("cc", CC).unwrap();
    svc
}

/// Fault-free request: `FaultPlan::off` defeats any `STARPLAT_FAULT` in the
/// environment so only the per-test plan is ever active.
fn sssp_req() -> Request {
    Request {
        graph: "g".to_string(),
        program: "sssp".to_string(),
        args: Args::default().node("src", 1),
        fault: Some(FaultPlan::off()),
        ..Default::default()
    }
}

/// Direct interpreter run of sssp on the same graph: the oracle every
/// successful service response must match.
fn sssp_oracle() -> Vec<i64> {
    let fns = parse(SSSP).unwrap();
    let tf = check_function(&fns[0]).unwrap();
    let opts = ExecOpts { threads: 1, fault: Some(FaultPlan::off()), ..Default::default() };
    let args = Args::default().node("src", 1);
    interp::run_with_opts(&tf, &test_graph(), &args, opts).unwrap().prop_i64("dist")
}

#[test]
fn corrupt_graph_is_rejected_at_registration() {
    let svc = Service::new(ServiceConfig::default());
    let mut g = test_graph();
    g.adj[0] = 1_000_000; // dangling edge target
    let err = svc.register_graph("bad", g).expect_err("validation must gate registration");
    match err {
        ServiceError::InvalidGraph { id, reason } => {
            assert_eq!(id, "bad");
            assert!(reason.contains("1000000"), "unhelpful reason: {reason}");
        }
        other => panic!("expected InvalidGraph, got {other:?}"),
    }
}

#[test]
fn bad_program_is_rejected_at_registration() {
    let svc = Service::new(ServiceConfig::default());
    let err = svc.register_program("broken", "function f(Graph g {").unwrap_err();
    assert!(matches!(err, ServiceError::InvalidProgram { .. }), "got {err:?}");
    let err = svc.register_program("empty", "// nothing here\n").unwrap_err();
    assert!(matches!(err, ServiceError::InvalidProgram { .. }), "got {err:?}");
}

#[test]
fn unknown_ids_fail_typed() {
    let svc = service(ServiceConfig::default());
    let mut req = sssp_req();
    req.graph = "nope".to_string();
    assert!(matches!(svc.execute(&req).unwrap_err(), ServiceError::UnknownGraph(_)));
    let mut req = sssp_req();
    req.program = "nope".to_string();
    assert!(matches!(svc.execute(&req).unwrap_err(), ServiceError::UnknownProgram(_)));
}

#[test]
fn missing_argument_is_failed_not_panic() {
    let svc = service(ServiceConfig::default());
    let mut req = sssp_req();
    req.args = Args::default(); // sssp needs `src`
    match svc.execute(&req).unwrap_err() {
        ServiceError::Failed(msg) => assert!(msg.contains("src"), "unhelpful: {msg}"),
        other => panic!("expected Failed, got {other:?}"),
    }
}

#[test]
fn panic_poisons_only_its_own_request() {
    let svc = service(ServiceConfig { cache_capacity: 0, ..Default::default() });

    // request 1: every pool dispatch panics
    let mut req = sssp_req();
    req.fault = Some(FaultPlan::new(FaultSite::PoolDispatch, 7, 1.0));
    match svc.execute(&req).unwrap_err() {
        ServiceError::Exec(ExecError::WorkerPanic(msg)) => {
            assert!(msg.contains("injected fault"), "panic message lost: {msg}");
        }
        other => panic!("expected WorkerPanic, got {other:?}"),
    }
    assert_eq!(svc.stats().panics, 1);

    // request 2 on the same service: unaffected and correct
    let out = svc.execute(&sssp_req()).expect("service must survive a worker panic");
    assert_eq!(out.prop_i64("dist"), sssp_oracle());
    assert_eq!(svc.stats().completed, 1);
}

#[test]
fn expired_deadline_surfaces_typed() {
    let svc = service(ServiceConfig::default());
    let mut req = sssp_req();
    req.deadline = Some(Duration::ZERO);
    match svc.execute(&req).unwrap_err() {
        ServiceError::Exec(ExecError::DeadlineExceeded) => {}
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    assert_eq!(svc.stats().deadline_exceeded, 1);
}

#[test]
fn service_default_deadline_applies() {
    let svc = service(ServiceConfig {
        default_deadline: Some(Duration::ZERO),
        ..Default::default()
    });
    let err = svc.execute(&sssp_req()).unwrap_err();
    assert_eq!(err, ServiceError::Exec(ExecError::DeadlineExceeded));
}

#[test]
fn pre_cancelled_token_stops_the_request() {
    let svc = service(ServiceConfig::default());
    let token = CancelToken::new();
    token.cancel();
    let mut req = sssp_req();
    req.cancel = Some(token);
    let err = svc.execute(&req).unwrap_err();
    assert_eq!(err, ServiceError::Exec(ExecError::Cancelled));
    assert_eq!(svc.stats().cancelled, 1);
}

#[test]
fn admission_control_rejects_and_recovers() {
    // capacity 0: everything is load-shed, nothing executes
    let svc = service(ServiceConfig { max_in_flight: 0, ..Default::default() });
    let err = svc.execute(&sssp_req()).unwrap_err();
    assert!(matches!(err, ServiceError::Overloaded { limit: 0 }), "got {err:?}");
    assert_eq!(svc.stats().rejected, 1);

    // capacity 1: sequential requests keep succeeding, proving the
    // in-flight slot is released on completion
    let svc = service(ServiceConfig { max_in_flight: 1, ..Default::default() });
    svc.execute(&sssp_req()).expect("first request fits");
    svc.execute(&sssp_req()).expect("slot must be released after completion");
}

#[test]
fn in_flight_slot_is_released_after_failures() {
    let svc = service(ServiceConfig {
        max_in_flight: 1,
        cache_capacity: 0,
        ..Default::default()
    });
    let mut req = sssp_req();
    req.fault = Some(FaultPlan::new(FaultSite::PoolDispatch, 9, 1.0));
    assert!(svc.execute(&req).is_err());
    svc.execute(&sssp_req()).expect("slot must be released after a panic");
}

#[test]
fn identical_requests_share_a_cached_output() {
    let svc = service(ServiceConfig { cache_capacity: 8, ..Default::default() });
    let a = svc.execute(&sssp_req()).unwrap();
    let b = svc.execute(&sssp_req()).unwrap();
    assert!(Arc::ptr_eq(&a, &b), "second request must be served from cache");
    assert_eq!(svc.stats().cache_hits, 1);
    // different arguments miss
    let mut req = sssp_req();
    req.args = Args::default().node("src", 2);
    let c = svc.execute(&req).unwrap();
    assert!(!Arc::ptr_eq(&a, &c));
    assert_eq!(svc.stats().cache_hits, 1);
}

#[test]
fn re_registering_a_graph_invalidates_cached_results() {
    let svc = service(ServiceConfig { cache_capacity: 8, ..Default::default() });
    let first = svc.execute(&sssp_req()).unwrap();
    assert_eq!(svc.stats().cache_hits, 0);

    // replace "g" with a *different* graph under the same id; the oracle is
    // a direct interpreter run on an identically-generated copy
    let replacement = || rmat("g", 200, 900, 11);
    let oracle = {
        let fns = parse(SSSP).unwrap();
        let tf = check_function(&fns[0]).unwrap();
        let opts = ExecOpts { threads: 1, fault: Some(FaultPlan::off()), ..Default::default() };
        let args = Args::default().node("src", 1);
        interp::run_with_opts(&tf, &replacement(), &args, opts).unwrap().prop_i64("dist")
    };
    svc.register_graph("g", replacement()).unwrap();

    // the version bump keys this request away from the stale entry
    let second = svc.execute(&sssp_req()).unwrap();
    assert!(
        !Arc::ptr_eq(&first, &second),
        "re-registered graph must not be served the old graph's cached result"
    );
    assert_eq!(svc.stats().cache_hits, 0);
    assert_eq!(second.prop_i64("dist"), oracle, "result computed against the old CSR");

    // and the new version has its own working cache line
    let third = svc.execute(&sssp_req()).unwrap();
    assert!(Arc::ptr_eq(&second, &third), "new-version result must itself be cacheable");
    assert_eq!(svc.stats().cache_hits, 1);
}

#[test]
fn claim_gather_fault_falls_back_to_dense_and_stays_correct() {
    let svc = service(ServiceConfig { cache_capacity: 0, ..Default::default() });
    let mut req = sssp_req();
    req.fault = Some(FaultPlan::new(FaultSite::ClaimGather, 3, 1.0));
    let out = svc.execute(&req).expect("fallback must recover the run");
    assert_eq!(out.prop_i64("dist"), sssp_oracle(), "dense fallback changed the answer");
    assert!(out.stats.fallbacks >= 1, "fallback not recorded in run stats");
    assert!(svc.stats().fallbacks >= 1, "fallback not aggregated in service stats");
}

#[test]
fn atomic_reduce_fault_is_typed() {
    let svc = service(ServiceConfig { cache_capacity: 0, ..Default::default() });
    let mut req = sssp_req();
    req.fault = Some(FaultPlan::new(FaultSite::AtomicReduce, 5, 1.0));
    match svc.execute(&req).unwrap_err() {
        ServiceError::Exec(ExecError::Fault(site)) => assert_eq!(site, "atomic_reduce"),
        other => panic!("expected Fault, got {other:?}"),
    }
    assert_eq!(svc.stats().faults, 1);
}
