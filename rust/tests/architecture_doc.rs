//! docs/ARCHITECTURE.md embeds the four SSSP manifest blocks as worked
//! examples; this suite pins them to the generator's actual output so the
//! document cannot drift from the code. Each excerpt sits in a fenced code
//! block immediately after an HTML marker comment
//! (`<!-- manifest:sssp:device -->` etc.) and must equal the corresponding
//! `DevicePlan` manifest line for line.

use starplat::dsl::parser::parse_file;
use starplat::ir::lower;
use starplat::ir::plan::DevicePlan;
use starplat::sema::check_function;

fn doc() -> String {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("docs")
        .join("ARCHITECTURE.md");
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path:?}: {e}"))
}

fn sssp_plan() -> DevicePlan {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("dsl_programs")
        .join("sssp.sp");
    let fns = parse_file(&path).unwrap();
    let tf = check_function(&fns[0]).unwrap();
    DevicePlan::build(&lower(&tf)).expect("plan builds")
}

/// Lines of the fenced code block immediately following `marker`.
fn block_after(doc: &str, marker: &str) -> Vec<String> {
    let at = doc.find(marker).unwrap_or_else(|| panic!("marker `{marker}` missing from doc"));
    let rest = &doc[at..];
    let fence = rest.find("```").unwrap_or_else(|| panic!("no fence after `{marker}`"));
    let mut lines = rest[fence..].lines();
    lines.next(); // the opening ``` line
    let mut out = Vec::new();
    for l in lines {
        if l.trim_start().starts_with("```") {
            return out;
        }
        out.push(l.to_string());
    }
    panic!("unterminated fence after `{marker}`");
}

#[test]
fn device_plan_excerpt_matches_generator() {
    assert_eq!(
        block_after(&doc(), "<!-- manifest:sssp:device -->"),
        sssp_plan().manifest(),
        "docs/ARCHITECTURE.md device-plan excerpt drifted from DevicePlan::manifest()"
    );
}

#[test]
fn host_schedule_excerpt_matches_generator() {
    assert_eq!(
        block_after(&doc(), "<!-- manifest:sssp:host -->"),
        sssp_plan().host_manifest(),
        "docs/ARCHITECTURE.md host-schedule excerpt drifted from DevicePlan::host_manifest()"
    );
}

#[test]
fn kernel_ops_excerpt_matches_generator() {
    assert_eq!(
        block_after(&doc(), "<!-- manifest:sssp:kernel -->"),
        sssp_plan().kernel_manifest(),
        "docs/ARCHITECTURE.md kernel-ops excerpt drifted from DevicePlan::kernel_manifest()"
    );
}

#[test]
fn schedule_plan_excerpt_matches_generator() {
    assert_eq!(
        block_after(&doc(), "<!-- manifest:sssp:schedule -->"),
        sssp_plan().schedule_manifest(),
        "docs/ARCHITECTURE.md schedule-plan excerpt drifted from DevicePlan::schedule_manifest()"
    );
}

/// The doc is linked from the places a reader lands first.
#[test]
fn architecture_doc_is_linked() {
    let readme = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("README.md"),
    )
    .unwrap();
    assert!(
        readme.contains("docs/ARCHITECTURE.md"),
        "README must link docs/ARCHITECTURE.md"
    );
    for src in ["src/codegen/mod.rs", "src/backends/interp/mod.rs"] {
        let text = std::fs::read_to_string(
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(src),
        )
        .unwrap();
        assert!(
            text.contains("docs/ARCHITECTURE.md"),
            "{src} rustdoc must point at docs/ARCHITECTURE.md"
        );
    }
}
