//! Cross-backend numbering snapshots: the property the `ir/plan.rs` device
//! plan guarantees is that every backend sees the *same* buffer slots and
//! kernel schedule. Each text backend embeds the plan manifest as a comment
//! block; these tests assert the block is byte-identical across all text
//! backends (CUDA, OpenCL, SYCL, OpenACC, HIP, Metal, WGSL) for all six
//! shipped programs, and that the interpreter's slot assignment (which
//! consumes the same `PropTable`) matches too.

use starplat::backends::interp;
use starplat::codegen;
use starplat::dsl::parser::parse_file;
use starplat::ir::plan::DevicePlan;
use starplat::ir::{lower, IrProgram};
use starplat::sema::{check_function, TypedFunction};

const PROGRAMS: [&str; 6] = ["bc.sp", "pr.sp", "sssp.sp", "tc.sp", "cc.sp", "bfs.sp"];

fn typed(program: &str) -> TypedFunction {
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("dsl_programs").join(program);
    let fns = parse_file(&path).unwrap();
    check_function(&fns[0]).unwrap()
}

fn ir_of(program: &str) -> IrProgram {
    lower(&typed(program))
}

/// Extract the `// ==== device plan ... ====` comment block from generated
/// source (inclusive of both markers).
fn manifest_block(src: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut inside = false;
    for l in src.lines() {
        if l.starts_with("// ==== device plan:") {
            inside = true;
        }
        if inside {
            out.push(l.trim_end().to_string());
        }
        if l.starts_with("// ==== end device plan") {
            break;
        }
    }
    out
}

#[test]
fn manifest_identical_across_all_text_backends() {
    for p in PROGRAMS {
        let ir = ir_of(p);
        let expected: Vec<String> =
            DevicePlan::build(&ir)
                .expect("plan builds")
                .manifest()
                .iter()
                .map(|l| format!("// {l}"))
                .collect();
        assert!(expected.len() > 3, "{p}: manifest suspiciously small");
        for b in codegen::TEXT_BACKENDS {
            let src = codegen::generate(b, &ir).unwrap();
            let block = manifest_block(&src);
            assert_eq!(
                block, expected,
                "{p}/{b}: embedded plan manifest diverged from the device plan"
            );
        }
    }
}

#[test]
fn interpreter_and_codegen_agree_on_buffer_numbering() {
    for p in PROGRAMS {
        let tf = typed(p);
        let prog = interp::compile::compile(&tf).unwrap();
        let plan = DevicePlan::build(&lower(&tf)).expect("plan builds");
        let interp_slots: Vec<(String, bool, bool)> =
            prog.props.iter().map(|m| (m.name.clone(), m.edge, m.param)).collect();
        // the interpreter's table is exactly the declared properties; the
        // plan may append synthetic scratch buffers (BFS level save) after
        // them, so declared numbering agrees prefix-for-prefix
        let declared = plan.props.metas().iter().filter(|m| !m.synthetic).count();
        let plan_slots: Vec<(String, bool, bool)> = plan.props.metas()[..declared]
            .iter()
            .map(|m| (m.name.clone(), m.edge, m.param))
            .collect();
        assert_eq!(interp_slots, plan_slots, "{p}: slot tables diverged");
        for m in &plan.props.metas()[declared..] {
            assert!(m.synthetic && !m.param, "{p}: non-synthetic buffer after declared range");
        }
    }
}

#[test]
fn kernel_schedule_matches_ir_and_names_appear_in_named_backends() {
    for p in PROGRAMS {
        let ir = ir_of(p);
        let plan = DevicePlan::build(&ir).expect("plan builds");
        // the IR kernel schedule is a prefix of the plan's: synthetic
        // repair kernels (BFS level restore) are appended after it
        assert!(plan.kernels.len() >= ir.kernels.len(), "{p}");
        for (kp, ki) in plan.kernels.iter().zip(&ir.kernels) {
            assert_eq!(kp.id, ki.id, "{p}");
            assert_eq!(kp.kind, ki.kind, "{p}");
            assert_eq!(kp.in_host_loop, ki.in_host_loop, "{p}");
            assert!(!kp.synthetic, "{p}: IR-scheduled kernel marked synthetic");
        }
        for kp in &plan.kernels[ir.kernels.len()..] {
            assert!(kp.synthetic, "{p}: extra kernel beyond the IR schedule not synthetic");
        }
        // CUDA and OpenCL name their kernels after the plan schedule
        let cuda = codegen::generate("cuda", &ir).unwrap();
        let ocl = codegen::generate("opencl", &ir).unwrap();
        for k in &plan.kernels {
            if k.kind == starplat::ir::KernelKind::InitProps {
                continue; // rendered through the init template helpers
            }
            assert!(cuda.contains(&k.name), "{p}/cuda: kernel `{}` not emitted", k.name);
            assert!(ocl.contains(&k.name), "{p}/opencl: kernel `{}` not emitted", k.name);
        }
    }
}

#[test]
fn kernel_parameter_lists_follow_slot_order() {
    use starplat::ir::plan::KernelParam;
    for p in PROGRAMS {
        let plan = DevicePlan::build(&ir_of(p)).expect("plan builds");
        for k in &plan.kernels {
            let slots: Vec<u32> = k
                .params(false)
                .iter()
                .filter_map(|pm| match pm {
                    KernelParam::Prop(s) => Some(*s),
                    _ => None,
                })
                .collect();
            let mut sorted = slots.clone();
            sorted.sort_unstable();
            assert_eq!(slots, sorted, "{p}: kernel {} props out of slot order", k.id);
        }
    }
}
