//! Corpus-mutation fuzzing of the DSL front-end: every shipped program,
//! truncated at several points and byte-flipped at seeded positions, must
//! come back as a typed `Err(DslError)` from parse + type-check — never a
//! panic, and never silent acceptance of a damaged program. Each candidate
//! runs under `catch_unwind` so a panic anywhere in the front-end fails the
//! test with the offending mutation identified.

use starplat::dsl::diag::DslError;
use starplat::dsl::parse;
use starplat::sema::check_function;
use starplat::util::rng::Rng;
use std::panic::catch_unwind;

const CORPUS: [(&str, &str); 6] = [
    ("bc.sp", include_str!("../dsl_programs/bc.sp")),
    ("bfs.sp", include_str!("../dsl_programs/bfs.sp")),
    ("cc.sp", include_str!("../dsl_programs/cc.sp")),
    ("pr.sp", include_str!("../dsl_programs/pr.sp")),
    ("sssp.sp", include_str!("../dsl_programs/sssp.sp")),
    ("tc.sp", include_str!("../dsl_programs/tc.sp")),
];

const FLIPS_PER_PROGRAM: usize = 32;

/// Run the front-end on `src`, catching panics. The inner `Result` is the
/// front-end's own verdict; the outer one records whether it panicked.
fn front_end(src: String) -> Result<Result<usize, DslError>, String> {
    catch_unwind(move || -> Result<usize, DslError> {
        let fns = parse(&src)?;
        let mut checked = 0;
        for f in &fns {
            check_function(f)?;
            checked += 1;
        }
        Ok(checked)
    })
    .map_err(|_| "front-end panicked".to_string())
}

/// The mutated program must be *rejected with a typed error*: a panic and
/// an accepted parse are both failures.
fn assert_rejected(label: &str, src: String) {
    match front_end(src) {
        Err(msg) => panic!("{label}: {msg}"),
        Ok(Ok(n)) => panic!("{label}: damaged program accepted ({n} functions checked)"),
        Ok(Err(_)) => {} // typed rejection — the pin
    }
}

/// Byte positions eligible for flipping: printable content outside line
/// comments (mutating a comment cannot make a program invalid).
fn eligible_positions(src: &str) -> Vec<usize> {
    let mut eligible = Vec::new();
    let mut base = 0;
    for line in src.split_inclusive('\n') {
        let code_end = line.find("//").unwrap_or(line.len());
        for (i, b) in line.as_bytes()[..code_end].iter().enumerate() {
            if !b.is_ascii_whitespace() {
                eligible.push(base + i);
            }
        }
        base += line.len();
    }
    eligible
}

#[test]
fn intact_corpus_still_passes() {
    // baseline: the harness itself accepts every unmutated program
    for (name, src) in CORPUS {
        assert!(src.is_ascii(), "{name}: mutation offsets assume ASCII sources");
        match front_end(src.to_string()) {
            Ok(Ok(n)) => assert_eq!(n, 1, "{name}: expected exactly one function"),
            other => panic!("{name}: intact program rejected: {other:?}"),
        }
    }
}

#[test]
fn truncated_programs_are_rejected_not_crashed() {
    for (name, src) in CORPUS {
        let start = src.find("function").expect("corpus program has a function") + "function".len();
        let end = src.rfind('}').expect("corpus program has a closing brace");
        for k in 1..=7 {
            let cut = start + (end - start) * k / 8;
            assert_rejected(&format!("{name} truncated at byte {cut}"), src[..cut].to_string());
        }
    }
}

#[test]
fn byte_flipped_programs_are_rejected_not_crashed() {
    for (name, src) in CORPUS {
        let eligible = eligible_positions(src);
        assert!(eligible.len() > FLIPS_PER_PROGRAM, "{name}: suspiciously little code");
        let mut rng = Rng::new(0xF1A5 ^ name.len() as u64);
        for _ in 0..FLIPS_PER_PROGRAM {
            let pos = eligible[rng.below(eligible.len() as u64) as usize];
            let mut bytes = src.as_bytes().to_vec();
            // NUL is never legal DSL outside comments: the lexer either
            // rejects it or treats it as a hard stop (mid-program EOF) —
            // both must surface as a typed parse error
            bytes[pos] = b'\0';
            let mutated = String::from_utf8(bytes).expect("ASCII source stays valid UTF-8");
            assert_rejected(&format!("{name} with byte {pos} nulled"), mutated);
        }
    }
}
