//! Coverage audit for the plan executor's differential suite: the six
//! shipped DSL programs must, between them, construct every `HostOp` and
//! `KernelOp` variant the lowering can emit — otherwise "planexec matches
//! the interpreter on all six programs" silently stops covering part of the
//! surface every text backend renders.
//!
//! Three host variants are *genuinely* unconstructible from the shipped
//! programs (no DSL program has a host-level `while`, `if`, or an
//! unsupported construct), so the audit pins the inventory in
//! both directions: every variant outside the pinned uncovered set must be
//! constructed, and the uncovered set must stay exactly those four — if a
//! future program starts covering one, the pin here is updated and the
//! executor's handling of it graduates from desk-checked to
//! differential-tested. (`ReduceScalar` *is* constructed: PageRank's host
//! `iterCount++` parses as a Count reduction.)

use starplat::coordinator::driver::{load_program, Algo};
use starplat::ir::kernel::{BfsDir, KernelBody, KernelOp};
use starplat::ir::lower;
use starplat::ir::plan::{DevicePlan, HostOp};
use std::collections::BTreeSet;

const ALGOS: [Algo; 6] = [Algo::Bfs, Algo::Sssp, Algo::Cc, Algo::Pr, Algo::Tc, Algo::Bc];

fn plans() -> Vec<(Algo, DevicePlan)> {
    ALGOS
        .iter()
        .map(|&a| {
            let tf = load_program(a).unwrap();
            let plan = DevicePlan::build(&lower(&tf))
                .unwrap_or_else(|e| panic!("{a:?}: plan build failed: {e:?}"));
            (a, plan)
        })
        .collect()
}

fn host_variant(op: &HostOp) -> &'static str {
    match op {
        HostOp::DeclDims => "DeclDims",
        HostOp::GraphToDevice => "GraphToDevice",
        HostOp::AllocProp { .. } => "AllocProp",
        HostOp::AllocFlag => "AllocFlag",
        HostOp::LaunchSetup => "LaunchSetup",
        HostOp::DeclScalar { .. } => "DeclScalar",
        HostOp::AssignScalar { .. } => "AssignScalar",
        HostOp::CopyProp { .. } => "CopyProp",
        HostOp::SetElement { .. } => "SetElement",
        HostOp::ReduceScalar { .. } => "ReduceScalar",
        HostOp::InitProps { .. } => "InitProps",
        HostOp::Launch { .. } => "Launch",
        HostOp::SeqFor { .. } => "SeqFor",
        HostOp::FixedPoint { .. } => "FixedPoint",
        HostOp::Bfs { .. } => "Bfs",
        HostOp::DoWhile { .. } => "DoWhile",
        HostOp::While { .. } => "While",
        HostOp::If { .. } => "If",
        HostOp::Return { .. } => "Return",
        HostOp::Unsupported { .. } => "Unsupported",
        HostOp::EpilogueBegin => "EpilogueBegin",
        HostOp::CopyOut { .. } => "CopyOut",
        HostOp::FreeProp { .. } => "FreeProp",
        HostOp::FreeFlag => "FreeFlag",
        HostOp::FreeGraph => "FreeGraph",
    }
}

/// Every `HostOp` variant; a new variant must be added here (the exhaustive
/// match in `host_variant` forces the companion update).
const HOST_INVENTORY: [&str; 25] = [
    "DeclDims",
    "GraphToDevice",
    "AllocProp",
    "AllocFlag",
    "LaunchSetup",
    "DeclScalar",
    "AssignScalar",
    "CopyProp",
    "SetElement",
    "ReduceScalar",
    "InitProps",
    "Launch",
    "SeqFor",
    "FixedPoint",
    "Bfs",
    "DoWhile",
    "While",
    "If",
    "Return",
    "Unsupported",
    "EpilogueBegin",
    "CopyOut",
    "FreeProp",
    "FreeFlag",
    "FreeGraph",
];

/// Host variants no shipped program can construct today (see module doc).
const HOST_UNCOVERED: [&str; 3] = ["While", "If", "Unsupported"];

fn kernel_variant(op: &KernelOp) -> &'static str {
    match op {
        KernelOp::Decl { .. } => "Decl",
        KernelOp::AssignVar { .. } => "AssignVar",
        KernelOp::AssignProp { .. } => "AssignProp",
        KernelOp::Reduce { .. } => "Reduce",
        KernelOp::MinMax { .. } => "MinMax",
        KernelOp::NeighborLoop { .. } => "NeighborLoop",
        KernelOp::If { .. } => "If",
        KernelOp::Unsupported { .. } => "Unsupported",
    }
}

const KERNEL_INVENTORY: [&str; 8] =
    ["Decl", "AssignVar", "AssignProp", "Reduce", "MinMax", "NeighborLoop", "If", "Unsupported"];

/// The only kernel variant no program constructs: `Unsupported` exists for
/// diagnosing constructs the lowering rejects, and all six programs lower
/// cleanly.
const KERNEL_UNCOVERED: [&str; 1] = ["Unsupported"];

fn walk_host<'a>(ops: &'a [HostOp], seen: &mut BTreeSet<&'static str>) {
    for op in ops {
        seen.insert(host_variant(op));
        match op {
            HostOp::SeqFor { body, .. }
            | HostOp::FixedPoint { body, .. }
            | HostOp::DoWhile { body, .. }
            | HostOp::While { body, .. } => walk_host(body, seen),
            HostOp::If { then, els, .. } => {
                walk_host(then, seen);
                if let Some(e) = els {
                    walk_host(e, seen);
                }
            }
            _ => {}
        }
    }
}

fn walk_kernel(body: &KernelBody, seen: &mut BTreeSet<&'static str>) {
    for op in &body.ops {
        op.visit(&mut |o| {
            seen.insert(kernel_variant(o));
        });
    }
}

#[test]
fn six_programs_construct_the_pinned_hostop_inventory() {
    let mut seen = BTreeSet::new();
    for (_, plan) in plans() {
        walk_host(&plan.host_ops, &mut seen);
    }
    let uncovered: Vec<&str> =
        HOST_INVENTORY.iter().filter(|v| !seen.contains(**v)).copied().collect();
    assert_eq!(
        uncovered, HOST_UNCOVERED,
        "HostOp coverage drifted from the pin: uncovered={uncovered:?} \
         (covered={seen:?}); update HOST_UNCOVERED only with a reason"
    );
    // everything seen must be in the inventory (catches a variant rename
    // that left the inventory stale)
    for v in &seen {
        assert!(HOST_INVENTORY.contains(v), "variant {v} missing from HOST_INVENTORY");
    }
}

#[test]
fn six_programs_construct_the_pinned_kernelop_inventory() {
    let mut seen = BTreeSet::new();
    for (_, plan) in plans() {
        for k in &plan.kernels {
            if let Some(b) = &k.body {
                walk_kernel(b, &mut seen);
            }
            if let Some(b) = &k.pull_body {
                walk_kernel(b, &mut seen);
            }
        }
    }
    let uncovered: Vec<&str> =
        KERNEL_INVENTORY.iter().filter(|v| !seen.contains(**v)).copied().collect();
    assert_eq!(
        uncovered, KERNEL_UNCOVERED,
        "KernelOp coverage drifted from the pin: uncovered={uncovered:?} (covered={seen:?})"
    );
}

/// The structural features the parity suite's acceptance criteria lean on
/// must exist in the plans it runs: CC's pull twin, BC's reverse BFS sweep,
/// both BFS-DAG filter directions, a reverse-CSR (pull-over-in-edges)
/// neighbor loop (PR), and a guarded (filtered-forall) kernel body.
#[test]
fn plans_carry_the_structures_the_parity_suite_exercises() {
    let all = plans();
    let find = |a: Algo| &all.iter().find(|(x, _)| *x == a).unwrap().1;

    let cc = find(Algo::Cc);
    assert!(
        cc.kernels.iter().any(|k| k.pull_body.is_some()),
        "CC lost its pull twin — the forced-Pull parity leg no longer tests pull execution"
    );

    let bc = find(Algo::Bc);
    assert!(bc.bfs_loops.iter().any(|b| b.rev.is_some()), "BC lost its iterateInReverse sweep");

    let mut dirs = BTreeSet::new();
    let mut reverse_csr = false;
    let mut guarded = false;
    for (_, plan) in &all {
        for k in &plan.kernels {
            for b in k.body.iter().chain(k.pull_body.iter()) {
                guarded |= b.guard.is_some();
                for op in &b.ops {
                    op.visit(&mut |o| {
                        if let KernelOp::NeighborLoop { reverse, bfs, .. } = o {
                            reverse_csr |= *reverse;
                            if let Some(d) = bfs {
                                dirs.insert(match d {
                                    BfsDir::Forward => "fwd",
                                    BfsDir::Reverse => "rev",
                                });
                            }
                        }
                    });
                }
            }
        }
    }
    assert!(dirs.contains("fwd"), "no forward BFS-DAG filter constructed");
    assert!(dirs.contains("rev"), "no reverse BFS-DAG filter constructed");
    assert!(reverse_csr, "no reverse-CSR neighbor loop constructed (PR pull)");
    assert!(guarded, "no guarded kernel body constructed (filtered forall)");
}
