//! Cross-backend host-schedule and kernel-op conformance: the guarantee the
//! `HostOp` refactor added on top of `tests/plan_numbering.rs` — every
//! backend's *host section* is derived from the identical [`HostOp`]
//! sequence, not a per-backend AST walk — now extends to the device half:
//! every kernel *body* is the identical plan-carried `KernelOp` tree. Each
//! text backend embeds the host-schedule and kernel-op manifests as comment
//! blocks; these tests pin both blocks byte-identical across all seven
//! backends, pin HIP↔CUDA launch/parameter agreement down to the argument
//! list, and check that every atomic reduction targets a cell the kernel
//! actually receives as a parameter.

use starplat::codegen;
use starplat::dsl::parser::parse_file;
use starplat::ir::kernel::{KCell, KernelOp};
use starplat::ir::plan::{DevicePlan, HostOp, KernelParam};
use starplat::ir::{lower, IrProgram, KernelKind};
use starplat::sema::check_function;

const PROGRAMS: [&str; 6] = ["bc.sp", "pr.sp", "sssp.sp", "tc.sp", "cc.sp", "bfs.sp"];
/// The paper's four evaluated algorithms — the set the snapshot suite pins.
const PAPER_FOUR: [&str; 4] = ["bc.sp", "pr.sp", "sssp.sp", "tc.sp"];

fn ir_of(program: &str) -> IrProgram {
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("dsl_programs").join(program);
    let fns = parse_file(&path).unwrap();
    lower(&check_function(&fns[0]).unwrap())
}

/// Extract the `// ==== host schedule ... ====` comment block (inclusive).
fn host_schedule_block(src: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut inside = false;
    for l in src.lines() {
        if l.starts_with("// ==== host schedule:") {
            inside = true;
        }
        if inside {
            out.push(l.trim_end().to_string());
        }
        if l.starts_with("// ==== end host schedule") {
            break;
        }
    }
    out
}

#[test]
fn host_manifest_identical_across_all_text_backends() {
    for p in PROGRAMS {
        let ir = ir_of(p);
        let expected: Vec<String> = DevicePlan::build(&ir)
            .expect("plan builds")
            .host_manifest()
            .iter()
            .map(|l| format!("// {l}"))
            .collect();
        assert!(expected.len() > 5, "{p}: host manifest suspiciously small");
        for b in codegen::TEXT_BACKENDS {
            let src = codegen::generate(b, &ir).unwrap();
            let block = host_schedule_block(&src);
            assert_eq!(
                block, expected,
                "{p}/{b}: embedded host schedule diverged from the plan's HostOp sequence"
            );
        }
    }
}

/// The "lowered once" check the issue asks for on the paper's four
/// programs: the manifest is not merely equal backend-to-backend, it is the
/// *plan's* — i.e. the single lowering in ir/plan.rs is the source of every
/// backend's host section.
#[test]
fn paper_four_host_sections_share_one_lowering() {
    for p in PAPER_FOUR {
        let ir = ir_of(p);
        let plan = DevicePlan::build(&ir).expect("plan builds");
        let blocks: Vec<Vec<String>> = codegen::TEXT_BACKENDS
            .iter()
            .map(|b| host_schedule_block(&codegen::generate(b, &ir).unwrap()))
            .collect();
        for w in blocks.windows(2) {
            assert_eq!(w[0], w[1], "{p}: two backends embed different host schedules");
        }
        // and the block is non-trivial: it names every kernel launch
        for k in &plan.kernels {
            if k.kind == KernelKind::InitProps {
                continue;
            }
            if matches!(k.kind, KernelKind::BfsForward | KernelKind::BfsReverse) {
                continue; // named via the bfs[...] skeleton line
            }
            assert!(
                blocks[0].iter().any(|l| l.contains(&k.name)),
                "{p}: host schedule misses launch of `{}`",
                k.name
            );
        }
    }
}

/// Every kernel in the plan is referenced by the host schedule exactly once,
/// in schedule order — the invariant that lets renderers index
/// `plan.kernels` straight from the ops.
fn collect_kernel_refs(plan: &DevicePlan, ops: &[HostOp], out: &mut Vec<usize>) {
    for op in ops {
        match op {
            HostOp::InitProps { kernel, .. } | HostOp::Launch { kernel, .. } => out.push(*kernel),
            HostOp::Bfs { index, .. } => {
                let b = &plan.bfs_loops[*index];
                out.push(b.fwd);
                out.extend(b.rev);
            }
            HostOp::SeqFor { body, .. }
            | HostOp::FixedPoint { body, .. }
            | HostOp::DoWhile { body, .. }
            | HostOp::While { body, .. } => collect_kernel_refs(plan, body, out),
            HostOp::If { then, els, .. } => {
                collect_kernel_refs(plan, then, out);
                if let Some(e) = els {
                    collect_kernel_refs(plan, e, out);
                }
            }
            _ => {}
        }
    }
}

#[test]
fn host_ops_reference_every_kernel_once_in_order() {
    for p in PROGRAMS {
        let plan = DevicePlan::build(&ir_of(p)).expect("plan builds");
        let mut refs = Vec::new();
        collect_kernel_refs(&plan, &plan.host_ops, &mut refs);
        let expect: Vec<usize> = (0..plan.kernels.len()).collect();
        assert_eq!(refs, expect, "{p}");
    }
}

/// Extract the `// ==== kernel ops ... ====` comment block (inclusive).
fn kernel_ops_block(src: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut inside = false;
    for l in src.lines() {
        if l.starts_with("// ==== kernel ops:") {
            inside = true;
        }
        if inside {
            out.push(l.trim_end().to_string());
        }
        if l.starts_with("// ==== end kernel ops") {
            break;
        }
    }
    out
}

/// The device-side twin of the host-manifest check: the embedded kernel-op
/// manifest must be byte-identical across all seven text backends on all six
/// programs — proof that kernel emission is one lowering (`ir/kernel.rs`)
/// plus per-backend `KernelDialect` spellings, with no AST walk left in any
/// renderer.
#[test]
fn kernel_manifest_identical_across_all_text_backends() {
    for p in PROGRAMS {
        let ir = ir_of(p);
        let expected: Vec<String> = DevicePlan::build(&ir)
            .expect("plan builds")
            .kernel_manifest()
            .iter()
            .map(|l| format!("// {l}"))
            .collect();
        assert!(expected.len() > 2, "{p}: kernel manifest suspiciously small");
        for b in codegen::TEXT_BACKENDS {
            let src = codegen::generate(b, &ir).unwrap();
            assert_eq!(
                kernel_ops_block(&src),
                expected,
                "{p}/{b}: embedded kernel-op manifest diverged from the plan's lowering"
            );
        }
    }
}

/// Every `KernelOp::Reduce` names a cell that appears in its kernel's
/// canonical parameter list — the invariant that makes the launch sites'
/// reduction-cell allocations line up with what the kernel body touches.
#[test]
fn every_kernel_reduce_targets_a_declared_parameter() {
    for p in PROGRAMS {
        let plan = DevicePlan::build(&ir_of(p)).expect("plan builds");
        for k in &plan.kernels {
            let Some(body) = &k.body else { continue };
            let params = k.params(true);
            for op in &body.ops {
                op.visit(&mut |o| {
                    if let KernelOp::Reduce { cell, .. } = o {
                        let ok = match cell {
                            KCell::Prop { slot, .. } => params
                                .iter()
                                .any(|q| matches!(q, KernelParam::Prop(s) if s == slot)),
                            KCell::Cell { name } => params.iter().any(|q| {
                                matches!(q, KernelParam::ReductionCell { name: n, .. } if n == name)
                            }),
                        };
                        assert!(
                            ok,
                            "{p}: kernel `{}` reduces into {cell:?}, which is not in its parameter list",
                            k.name
                        );
                    }
                });
            }
        }
    }
}

/// Extract the `// ==== schedule plan ... ====` comment block (inclusive).
fn schedule_plan_block(src: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut inside = false;
    for l in src.lines() {
        if l.starts_with("// ==== schedule plan:") {
            inside = true;
        }
        if inside {
            out.push(l.trim_end().to_string());
        }
        if l.starts_with("// ==== end schedule plan") {
            break;
        }
    }
    out
}

/// The schedule-plan manifest (direction verdicts, pull bodies, delta
/// eligibility) must be byte-identical across all seven text backends on
/// all six programs — the decision is made once, in the plan, never
/// re-derived by a renderer.
#[test]
fn schedule_manifest_identical_across_all_text_backends() {
    for p in PROGRAMS {
        let ir = ir_of(p);
        let expected: Vec<String> = DevicePlan::build(&ir)
            .expect("plan builds")
            .schedule_manifest()
            .iter()
            .map(|l| format!("// {l}"))
            .collect();
        assert!(expected.len() > 2, "{p}: schedule manifest suspiciously small");
        for b in codegen::TEXT_BACKENDS {
            let src = codegen::generate(b, &ir).unwrap();
            assert_eq!(
                schedule_plan_block(&src),
                expected,
                "{p}/{b}: embedded schedule plan diverged from the plan's decisions"
            );
        }
    }
}

/// Every kernel the schedule pass marks push+pull gets its `_pull` twin and
/// a `STARPLAT_DIRECTION` runtime switch in every text backend; kernels
/// without one never do. CC (weight-free relax) is the positive case; SSSP
/// (weighted — no device `rev_edge_id`) is the negative one.
#[test]
fn pull_variants_emitted_exactly_where_the_schedule_says() {
    for p in PROGRAMS {
        let ir = ir_of(p);
        let plan = DevicePlan::build(&ir).expect("plan builds");
        for b in codegen::TEXT_BACKENDS {
            let src = codegen::generate(b, &ir).unwrap();
            for (k, c) in plan.kernels.iter().zip(&plan.schedule.choices) {
                let pull_name = format!("{}_pull", k.name);
                let has_switch = src.contains(&format!("usePull_{}", k.id));
                // SYCL and OpenACC render kernels inline (lambda / pragma
                // loop), so only the host-side switch is observable there;
                // every other backend emits a named `{name}_pull` twin
                let named_kernels = !matches!(b, "sycl" | "openacc");
                if c.push_only.is_none() {
                    if named_kernels {
                        assert!(src.contains(&pull_name), "{p}/{b}: `{pull_name}` missing");
                    }
                    assert!(has_switch, "{p}/{b}: no direction switch for `{}`", k.name);
                } else {
                    // comment blocks print kernel names too, so check for the
                    // pull symbol only outside the manifest comments
                    let emitted = src
                        .lines()
                        .filter(|l| !l.starts_with("// "))
                        .any(|l| l.contains(&pull_name));
                    assert!(!emitted, "{p}/{b}: unexpected `{pull_name}` emitted");
                    assert!(!has_switch, "{p}/{b}: stray switch for `{}`", k.name);
                }
            }
        }
    }
}

/// Pull the argument list of the CUDA launch `name<<<grid, block>>>(args);`.
fn cuda_launch_args(src: &str, kernel: &str) -> Vec<String> {
    let needle = format!("{kernel}<<<");
    src.lines()
        .filter(|l| l.contains(&needle))
        .map(|l| {
            let after = l.split(">>>(").nth(1).unwrap_or_else(|| {
                panic!("malformed CUDA launch line for `{kernel}`: {l}")
            });
            after.trim_end().trim_end_matches(");").to_string()
        })
        .collect()
}

/// Pull the argument list of `hipLaunchKernelGGL(name, dim3(..), dim3(..),
/// 0, 0, args);`.
fn hip_launch_args(src: &str, kernel: &str) -> Vec<String> {
    let needle = format!("hipLaunchKernelGGL({kernel},");
    src.lines()
        .filter(|l| l.contains(&needle))
        .map(|l| {
            let after = l.split("0, 0, ").nth(1).unwrap_or_else(|| {
                panic!("malformed HIP launch line for `{kernel}`: {l}")
            });
            after.trim_end().trim_end_matches(");").to_string()
        })
        .collect()
}

/// HIP is CUDA's plan with new spellings: same kernel names, same slot
/// numbering, and byte-identical launch argument lists at every site.
#[test]
fn hip_and_cuda_agree_on_kernels_slots_and_launch_args() {
    for p in PROGRAMS {
        let ir = ir_of(p);
        let plan = DevicePlan::build(&ir).expect("plan builds");
        let cuda = codegen::generate("cuda", &ir).unwrap();
        let hip = codegen::generate("hip", &ir).unwrap();
        for k in &plan.kernels {
            if k.kind == KernelKind::InitProps {
                continue; // rendered through the init template helpers
            }
            assert!(hip.contains(&k.name), "{p}/hip: kernel `{}` not emitted", k.name);
            let c = cuda_launch_args(&cuda, &k.name);
            let h = hip_launch_args(&hip, &k.name);
            assert!(!c.is_empty(), "{p}: no CUDA launch site for `{}`", k.name);
            assert_eq!(
                c, h,
                "{p}: HIP and CUDA disagree on launch args for `{}`",
                k.name
            );
            // param agreement at the signature level too: identical
            // `__global__ void name(...)` declarations
            let sig_of = |src: &str| {
                src.lines()
                    .find(|l| l.starts_with(&format!("__global__ void {}(", k.name)))
                    .map(str::to_string)
            };
            let (cs, hs) = (sig_of(&cuda), sig_of(&hip));
            assert!(cs.is_some(), "{p}: CUDA signature for `{}` missing", k.name);
            assert_eq!(cs, hs, "{p}: HIP and CUDA kernel signatures diverged for `{}`", k.name);
        }
    }
}
