//! Behavioral pins for the persistent work-stealing runtime
//! (`starplat::util::pool`): stealing under skewed chunk costs, idempotent
//! shutdown + lazy re-initialization, cancellation and deadlines tripping
//! mid-run, panic isolation, and the dispatch accounting the bench harness
//! consumes.
//!
//! These tests observe process-global pool state (worker counts, monotonic
//! stats counters), so they serialize on one mutex — the rest of the test
//! binary would otherwise race the counters and the shutdown/re-init cycle.

use starplat::util::cancel::CancelToken;
use starplat::util::pool::{self, PoolInterrupt};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

/// Serialize every test in this binary (pool stats and worker lifecycle are
/// process-global). Poison-tolerant: a failing test must not cascade.
fn gate() -> MutexGuard<'static, ()> {
    static GATE: OnceLock<Mutex<()>> = OnceLock::new();
    GATE.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn stealing_rebalances_skewed_chunk_costs() {
    let _g = gate();
    let before = pool::stats().steals;
    // the first partition is pathologically expensive: whoever owns it gets
    // stuck, and everyone else must finish by stealing from its range (or
    // from the ranges of participants that never woke). 2048 items over 8
    // participants in chunks of 16.
    let hits: Vec<AtomicU64> = (0..2048).map(|_| AtomicU64::new(0)).collect();
    pool::parallel_for_dynamic(2048, 8, 16, |i| {
        if i < 48 {
            // ~10ms of skew concentrated at the head of participant 0's range
            std::thread::sleep(Duration::from_micros(200));
        }
        hits[i].fetch_add(1, Ordering::Relaxed);
    });
    // exactly-once under stealing: the deque CAS transitions hand each index
    // to one participant regardless of who ends up running it
    assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    let after = pool::stats().steals;
    assert!(
        after > before,
        "skewed costs must trigger work stealing (steals {before} -> {after})"
    );
}

#[test]
fn shutdown_is_idempotent_and_pool_reinitializes() {
    let _g = gate();
    // warm the pool
    let c = AtomicU64::new(0);
    pool::parallel_for_dynamic(4096, 4, 64, |_| {
        c.fetch_add(1, Ordering::Relaxed);
    });
    assert_eq!(c.load(Ordering::Relaxed), 4096);
    assert!(pool::stats().workers >= 1, "parallel region must have spawned workers");

    pool::shutdown();
    assert_eq!(pool::stats().workers, 0, "shutdown must join every worker");
    pool::shutdown(); // second call is a no-op, not a hang or panic
    assert_eq!(pool::stats().workers, 0);

    // the pool lazily re-initializes on the next parallel region
    let c = AtomicU64::new(0);
    pool::parallel_for_dynamic(4096, 4, 64, |_| {
        c.fetch_add(1, Ordering::Relaxed);
    });
    assert_eq!(c.load(Ordering::Relaxed), 4096);
    assert!(pool::stats().workers >= 1, "pool must re-initialize after shutdown");
}

#[test]
fn cancel_mid_run_stops_stealing_participants() {
    let _g = gate();
    // enough slow work that the region is mid-flight (and mid-steal: tiny
    // chunks force constant deque traffic) when the cancel lands
    let token = CancelToken::new();
    let done = AtomicU64::new(0);
    let canceller = {
        let token = token.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            token.cancel();
        })
    };
    let r = pool::try_parallel_for_dynamic_scoped(
        100_000,
        8,
        4,
        Some(&token),
        || (),
        |_, _| {
            std::thread::sleep(Duration::from_micros(20));
            done.fetch_add(1, Ordering::Relaxed);
        },
    );
    canceller.join().unwrap();
    assert_eq!(r, Err(PoolInterrupt::Cancelled));
    let done = done.load(Ordering::Relaxed);
    assert!(done < 100_000, "cancel must interrupt the run, not drain it ({done} done)");
}

#[test]
fn deadline_mid_run_stops_stealing_participants() {
    let _g = gate();
    let token = CancelToken::with_deadline(Duration::from_millis(5));
    let done = AtomicU64::new(0);
    let r = pool::try_parallel_for_dynamic_scoped(
        100_000,
        8,
        4,
        Some(&token),
        || (),
        |_, _| {
            std::thread::sleep(Duration::from_micros(20));
            done.fetch_add(1, Ordering::Relaxed);
        },
    );
    assert_eq!(r, Err(PoolInterrupt::DeadlineExceeded));
    assert!(done.load(Ordering::Relaxed) < 100_000);
}

#[test]
fn panic_isolation_matches_the_scoped_pool_contract() {
    let _g = gate();
    let workers_before = {
        // warm the pool so the count is meaningful
        pool::parallel_for_dynamic(1024, 4, 16, |_| {});
        pool::stats().workers
    };
    // a panicking chunk surfaces as a typed interrupt with its message…
    let r = pool::try_parallel_for_dynamic_scoped(1024, 4, 16, None, || (), |_, i| {
        if i == 513 {
            panic!("skewed boom at {i}");
        }
    });
    match r {
        Err(PoolInterrupt::Panicked(msg)) => {
            assert!(msg.contains("skewed boom at 513"), "message lost: {msg}");
        }
        other => panic!("expected Panicked, got {other:?}"),
    }
    // …and the persistent workers survive it: same pool, next region is
    // exact (the old scoped pool got this for free by respawning; the
    // persistent pool must actively confine the unwind)
    assert_eq!(pool::stats().workers, workers_before, "a worker died on a caught panic");
    let hits: Vec<AtomicU64> = (0..1024).map(|_| AtomicU64::new(0)).collect();
    pool::parallel_for_dynamic(1024, 4, 16, |i| {
        hits[i].fetch_add(1, Ordering::Relaxed);
    });
    assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
}

#[test]
fn dispatch_accounting_separates_inline_from_pooled_regions() {
    let _g = gate();
    let s0 = pool::stats();
    // n <= block: runs inline on the caller, no job published
    pool::parallel_for_dynamic(32, 8, 64, |_| {});
    let s1 = pool::stats();
    assert_eq!(s1.dispatches, s0.dispatches, "tiny region must not dispatch");
    // threads == 1: sequential path, no job published
    pool::parallel_for_dynamic(4096, 1, 64, |_| {});
    let s2 = pool::stats();
    assert_eq!(s2.dispatches, s1.dispatches, "single-thread region must not dispatch");
    // a real parallel region publishes exactly one job
    pool::parallel_for_dynamic(4096, 4, 64, |_| {});
    let s3 = pool::stats();
    assert_eq!(s3.dispatches, s2.dispatches + 1, "parallel region must count one dispatch");
}
