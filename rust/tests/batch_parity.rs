//! Batched multi-source execution parity: every per-root `Output` of
//! [`batch::run_batch_with_opts`] must be bit-for-bit equal to an
//! independent single-root run — across thread counts, forced traversal
//! directions, wave tilings, duplicate roots, and mid-batch fault
//! injection. The single-root side of that equivalence is itself pinned
//! against the sequential oracle by `seq_par_parity.rs`, so one oracle per
//! (graph, root) closes the whole triangle.

use starplat::backends::interp::{self, batch, compile, Args, Direction, ExecOpts};
use starplat::coordinator::driver::{load_program, Algo};
use starplat::graph::csr::{Graph, Node};
use starplat::graph::generators::{rmat, road_grid, uniform_random};
use starplat::sema::TypedFunction;
use starplat::util::fault::{FaultPlan, FaultSite};
use starplat::util::rng::Rng;

const THREADS: [usize; 3] = [1, 2, 8];
const DIRECTIONS: [Direction; 3] = [Direction::Auto, Direction::Push, Direction::Pull];

fn test_graphs() -> Vec<Graph> {
    let mut rng = Rng::new(0xC0FFEE);
    let mut gs = Vec::new();
    for i in 0..3 {
        let n = rng.range(60, 280);
        let m = rng.range(n, 5 * n);
        gs.push(rmat(&format!("rmat{i}"), n, m, rng.next_u64()));
    }
    gs.push(uniform_random("ur", 150, 600, rng.next_u64()));
    // mesh-shaped graph: many BFS levels / frontier rounds per wave
    gs.push(road_grid("grid", 15, 14, 9));
    gs
}

/// k roots spread across the vertex range (all in range, first is 0).
fn roots_for(g: &Graph, k: usize) -> Vec<Node> {
    let n = g.num_nodes().max(1);
    (0..k).map(|i| ((i * n) / k) as Node).collect()
}

/// Batch-side options: forced direction, pool engaged even on these tiny
/// graphs, env fault injection defeated.
fn opts(threads: usize, dir: Direction) -> ExecOpts {
    ExecOpts {
        threads,
        direction: Some(dir),
        fault: Some(FaultPlan::off()),
        frontier_par_min: Some(1),
        ..ExecOpts::default()
    }
}

/// Independent single-root run at one thread with faults off.
fn oracle(tf: &TypedFunction, g: &Graph, root: Node, prop: &str) -> Vec<i64> {
    let o = ExecOpts { threads: 1, fault: Some(FaultPlan::off()), ..ExecOpts::default() };
    interp::run_with_opts(tf, g, &Args::default().node("src", root), o)
        .unwrap()
        .prop_i64(prop)
}

/// The shipped BFS/SSSP programs must actually engage the batch engines —
/// otherwise the parity sweeps below would silently test the fallback path
/// against itself.
#[test]
fn shipped_programs_are_recognized_as_batchable() {
    let bfs = compile::compile(&load_program(Algo::Bfs).unwrap()).unwrap();
    assert!(
        matches!(batch::recognize(&bfs, "src"), Some(batch::BatchPlan::BfsLevels { .. })),
        "bfs.sp must recognize as an MS-BFS shape"
    );
    let sssp = compile::compile(&load_program(Algo::Sssp).unwrap()).unwrap();
    assert!(
        matches!(batch::recognize(&sssp, "src"), Some(batch::BatchPlan::KLane { .. })),
        "sssp.sp must recognize as a k-lane relaxation shape"
    );
    // a parameter the program does not declare can never be a batch axis
    assert!(batch::recognize(&bfs, "nonexistent").is_none());
}

#[test]
fn bfs_batch_matches_independent_runs_across_schedules() {
    let tf = load_program(Algo::Bfs).unwrap();
    for g in test_graphs() {
        let roots = roots_for(&g, 8);
        let want: Vec<Vec<i64>> =
            roots.iter().map(|&r| oracle(&tf, &g, r, "level")).collect();
        for t in THREADS {
            for dir in DIRECTIONS {
                let outs =
                    batch::run_batch_with_opts(&tf, &g, &Args::default(), "src", &roots, &opts(t, dir));
                for (i, out) in outs.into_iter().enumerate() {
                    let out = out.unwrap();
                    assert_eq!(
                        out.prop_i64("level"),
                        want[i],
                        "{} root {} ({t} threads, {dir:?})",
                        g.name,
                        roots[i]
                    );
                    // all 8 roots fit one wave; anything else means the
                    // engine fell back without being asked to
                    assert_eq!(out.stats.batched_roots, roots.len() as u64, "{}", g.name);
                }
            }
        }
    }
}

#[test]
fn sssp_batch_matches_independent_runs_across_schedules() {
    let tf = load_program(Algo::Sssp).unwrap();
    for g in test_graphs() {
        let roots = roots_for(&g, 8);
        let want: Vec<Vec<i64>> = roots.iter().map(|&r| oracle(&tf, &g, r, "dist")).collect();
        for t in THREADS {
            for dir in DIRECTIONS {
                let outs =
                    batch::run_batch_with_opts(&tf, &g, &Args::default(), "src", &roots, &opts(t, dir));
                for (i, out) in outs.into_iter().enumerate() {
                    let out = out.unwrap();
                    assert_eq!(
                        out.prop_i64("dist"),
                        want[i],
                        "{} root {} ({t} threads, {dir:?})",
                        g.name,
                        roots[i]
                    );
                    assert_eq!(out.stats.batched_roots, roots.len() as u64, "{}", g.name);
                }
            }
        }
    }
}

/// A lane width below the root count tiles the batch into waves; results
/// must not change, and each output reports its own wave's width.
#[test]
fn narrow_lane_width_tiles_waves_without_changing_results() {
    let bfs = load_program(Algo::Bfs).unwrap();
    let sssp = load_program(Algo::Sssp).unwrap();
    let g = rmat("tiling", 180, 720, 0x7A11E5);
    let roots = roots_for(&g, 8);
    for (tf, prop) in [(&bfs, "level"), (&sssp, "dist")] {
        let want: Vec<Vec<i64>> = roots.iter().map(|&r| oracle(tf, &g, r, prop)).collect();
        let o = ExecOpts { batch: Some(3), ..opts(2, Direction::Auto) };
        let outs = batch::run_batch_with_opts(tf, &g, &Args::default(), "src", &roots, &o);
        for (i, out) in outs.into_iter().enumerate() {
            let out = out.unwrap();
            assert_eq!(out.prop_i64(prop), want[i], "{prop} root {}", roots[i]);
            // waves of 3, 3, 2 over 8 roots
            let expect_wave = if i < 6 { 3 } else { 2 };
            assert_eq!(out.stats.batched_roots, expect_wave, "{prop} root {}", roots[i]);
        }
    }
}

/// Duplicate roots are legal: they ride the same lane-discovery bits and
/// every copy gets a full, equal output.
#[test]
fn duplicate_roots_all_receive_faithful_outputs() {
    let tf = load_program(Algo::Bfs).unwrap();
    let g = uniform_random("dups", 120, 500, 0xD0D0);
    let roots: Vec<Node> = vec![5, 17, 5, 5, 63, 17];
    let outs =
        batch::run_batch_with_opts(&tf, &g, &Args::default(), "src", &roots, &opts(2, Direction::Auto));
    for (i, out) in outs.into_iter().enumerate() {
        let out = out.unwrap();
        assert_eq!(out.prop_i64("level"), oracle(&tf, &g, roots[i], "level"), "root {}", roots[i]);
    }
}

/// `STARPLAT_FAULT=claim_gather` mid-batch: a firing wave is abandoned and
/// every root of that wave re-runs independently (those runs honor the same
/// plan, degrading sparse→dense where it applies). Results must equal the
/// fault-free oracle and the abandonment must be visible in the stats.
#[test]
fn claim_gather_fault_degrades_to_faithful_independent_runs() {
    let plan = FaultPlan::new(FaultSite::ClaimGather, 7, 1.0);
    let bfs = load_program(Algo::Bfs).unwrap();
    let sssp = load_program(Algo::Sssp).unwrap();
    let g = rmat("faulted", 150, 600, 0xFA17);
    let roots = roots_for(&g, 8);
    for (tf, prop) in [(&bfs, "level"), (&sssp, "dist")] {
        let want: Vec<Vec<i64>> = roots.iter().map(|&r| oracle(tf, &g, r, prop)).collect();
        for t in THREADS {
            let o = ExecOpts {
                threads: t,
                fault: Some(plan),
                frontier_par_min: Some(1),
                ..ExecOpts::default()
            };
            let outs = batch::run_batch_with_opts(tf, &g, &Args::default(), "src", &roots, &o);
            for (i, out) in outs.into_iter().enumerate() {
                let out = out.unwrap();
                assert_eq!(
                    out.prop_i64(prop),
                    want[i],
                    "{prop} root {} under claim_gather ({t} threads)",
                    roots[i]
                );
                assert!(
                    out.stats.fallbacks >= 1,
                    "{prop} root {}: wave abandonment must be counted",
                    roots[i]
                );
                // the degraded path runs single-source: no batched lanes
                assert_eq!(out.stats.batched_roots, 0, "{prop} root {}", roots[i]);
            }
        }
    }
}

/// Programs without a batchable shape still work — every root just takes
/// the independent path, preserving the positional contract.
#[test]
fn unbatchable_programs_fall_back_per_root() {
    // CC declares no root parameter at all, so the recognizer declines and
    // the spurious per-root binding is ignored by the interpreter's by-name
    // parameter lookup: every "root" gets the same full CC output.
    let tf = load_program(Algo::Cc).unwrap();
    let g = road_grid("fallback", 8, 8, 3);
    let want = {
        let o = ExecOpts { threads: 1, fault: Some(FaultPlan::off()), ..ExecOpts::default() };
        interp::run_with_opts(&tf, &g, &Args::default(), o).unwrap().prop_i64("comp")
    };
    let roots: Vec<Node> = vec![0, 9, 33];
    let outs =
        batch::run_batch_with_opts(&tf, &g, &Args::default(), "src", &roots, &opts(1, Direction::Auto));
    for out in outs {
        let out = out.unwrap();
        assert_eq!(out.prop_i64("comp"), want);
        assert_eq!(out.stats.batched_roots, 0, "fallback runs carry no lanes");
    }
}
