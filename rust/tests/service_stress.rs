//! Service storm: thousands of interleaved requests across graphs and
//! programs with deterministic fault injection. Pins three properties:
//!
//! 1. every request terminates in a *typed* outcome (Ok, WorkerPanic with
//!    the injected message, typed Fault) — never an unhandled panic;
//! 2. the outcome sequence is a pure function of the fault plan: the same
//!    storm run twice produces identical per-request outcome classes;
//! 3. non-faulted results are bit-identical to a fault-free oracle, and the
//!    service still serves clean requests correctly after the storm.
//!
//! The fault plan comes from `STARPLAT_FAULT=<site>:<seed>:<rate>` when set
//! (the CI matrix drives this), with a low-rate pool_dispatch default
//! otherwise. Each request re-scopes the plan with its own index as salt,
//! so faults land on a deterministic subset of requests.

use starplat::backends::interp::{self, Args, ExecError, ExecOpts, Output};
use starplat::dsl::parse;
use starplat::graph::csr::Graph;
use starplat::graph::generators::{rmat, road_grid};
use starplat::runtime::service::{Request, Service, ServiceConfig, ServiceError};
use starplat::sema::check_function;
use starplat::util::fault::{FaultPlan, FaultSite};
use std::sync::Once;

const PROGRAMS: [(&str, &str); 4] = [
    ("bfs", include_str!("../dsl_programs/bfs.sp")),
    ("sssp", include_str!("../dsl_programs/sssp.sp")),
    ("cc", include_str!("../dsl_programs/cc.sp")),
    ("tc", include_str!("../dsl_programs/tc.sp")),
];

const CLIENTS: usize = 8;
const REQUESTS_PER_CLIENT: usize = 256;

/// Scheduling-independent summary of a run's observable outputs.
type Digest = (Vec<(String, Vec<i64>)>, String);

/// One storm cell plus its fault-free expectation.
type Cell = ((&'static str, &'static str), Digest);

fn graphs() -> Vec<(&'static str, Graph)> {
    vec![("rmat", rmat("rmat", 120, 480, 0x5EED)), ("grid", road_grid("grid", 8, 8, 0x5EED))]
}

fn args_for(program: &str) -> Args {
    match program {
        "bfs" | "sssp" => Args::default().node("src", 1),
        _ => Args::default(),
    }
}

/// Injected pool panics are expected by the thousand here; silence their
/// default-hook backtraces while letting every other panic print normally.
fn install_quiet_panic_hook() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let default = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| info.payload().downcast_ref::<&str>().copied())
                .is_some_and(|m| m.contains("injected fault"));
            if !injected {
                default(info);
            }
        }));
    });
}

fn digest(out: &Output) -> Digest {
    let mut props: Vec<(String, Vec<i64>)> =
        out.props.keys().map(|k| (k.clone(), out.prop_i64(k))).collect();
    props.sort();
    (props, format!("{:?}", out.ret))
}

/// Fault-free ground truth for one (graph, program) cell, computed on the
/// interpreter directly — no service machinery involved.
fn oracle(g: &Graph, src: &str, args: &Args) -> Digest {
    let fns = parse(src).unwrap();
    let tf = check_function(&fns[0]).unwrap();
    let opts = ExecOpts { threads: 1, fault: Some(FaultPlan::off()), ..Default::default() };
    digest(&interp::run_with_opts(&tf, g, args, opts).unwrap())
}

/// What class of typed outcome a request ended in. Admission rejections are
/// retried (they depend on thread timing, not the fault plan), so they
/// never appear here.
#[derive(Clone, Debug, PartialEq, Eq)]
enum Outcome {
    Ok,
    Panic,
    Fault,
}

fn storm(plan: FaultPlan, oracles: &[Cell]) -> Vec<Outcome> {
    let svc = Service::new(ServiceConfig {
        threads: 2,
        max_in_flight: 4,
        // cache off: every request must actually execute (and fault)
        cache_capacity: 0,
        ..Default::default()
    });
    for (id, g) in graphs() {
        svc.register_graph(id, g).unwrap();
    }
    for (name, src) in PROGRAMS {
        svc.register_program(name, src).unwrap();
    }

    let mut outcomes: Vec<Option<Outcome>> = vec![None; CLIENTS * REQUESTS_PER_CLIENT];
    let chunks: Vec<&mut [Option<Outcome>]> = outcomes.chunks_mut(REQUESTS_PER_CLIENT).collect();
    std::thread::scope(|s| {
        for (client, chunk) in chunks.into_iter().enumerate() {
            let svc = &svc;
            s.spawn(move || {
                for (i, slot) in chunk.iter_mut().enumerate() {
                    let r = client * REQUESTS_PER_CLIENT + i;
                    let ((graph, program), want) = &oracles[r % oracles.len()];
                    let req = Request {
                        graph: graph.to_string(),
                        program: program.to_string(),
                        args: args_for(program),
                        // per-request fault scope: deterministic in r alone
                        fault: Some(plan.salted(r as u64)),
                        ..Default::default()
                    };
                    let res = loop {
                        match svc.execute(&req) {
                            Err(ServiceError::Overloaded { .. }) => std::thread::yield_now(),
                            other => break other,
                        }
                    };
                    *slot = Some(match res {
                        Ok(out) => {
                            // a request whose faults never fired (or that
                            // recovered via dense fallback) must be exact
                            assert_eq!(&digest(&out), want, "request {r} diverged from oracle");
                            Outcome::Ok
                        }
                        Err(ServiceError::Exec(ExecError::WorkerPanic(msg))) => {
                            assert!(msg.contains("injected fault"), "unexpected panic: {msg}");
                            Outcome::Panic
                        }
                        Err(ServiceError::Exec(ExecError::Fault(_))) => Outcome::Fault,
                        Err(other) => panic!("request {r}: untyped outcome {other:?}"),
                    });
                }
            });
        }
    });

    // the storm must leave the service healthy: stats add up and a clean
    // request per cell still matches the oracle
    let stats = svc.stats();
    assert_eq!(
        stats.completed + stats.panics + stats.faults,
        (CLIENTS * REQUESTS_PER_CLIENT) as u64,
        "requests unaccounted for: {stats:?}"
    );
    for ((graph, program), want) in oracles {
        let out = svc
            .execute(&Request {
                graph: graph.to_string(),
                program: program.to_string(),
                args: args_for(program),
                fault: Some(FaultPlan::off()),
                ..Default::default()
            })
            .expect("clean request after the storm");
        assert_eq!(&digest(&out), want, "{graph}/{program}: wrong result after storm");
    }

    match plan.site {
        FaultSite::PoolDispatch => {
            assert!(stats.panics > 0, "pool_dispatch storm injected no panics: {stats:?}");
        }
        FaultSite::ClaimGather => {
            assert!(stats.fallbacks > 0, "claim_gather storm forced no fallbacks: {stats:?}");
        }
        // atomic-reduce faults are rarer (keyed per reduce target); the
        // type-correctness assertions above are the pin
        FaultSite::AtomicReduce => {}
    }

    outcomes.into_iter().map(|o| o.expect("every request classified")).collect()
}

#[test]
fn storm_is_typed_correct_and_deterministic() {
    install_quiet_panic_hook();
    let plan = FaultPlan::from_env()
        .unwrap_or_else(|| FaultPlan::new(FaultSite::PoolDispatch, 0xC0FFEE, 0.002));

    let mut oracles: Vec<Cell> = Vec::new();
    for (gid, g) in &graphs() {
        for (name, src) in PROGRAMS {
            oracles.push(((*gid, name), oracle(g, src, &args_for(name))));
        }
    }

    let first = storm(plan, &oracles);
    assert_eq!(first.len(), CLIENTS * REQUESTS_PER_CLIENT);
    let ok = first.iter().filter(|o| **o == Outcome::Ok).count();
    assert!(ok > 0, "storm produced no successful requests");

    // determinism: the same plan re-scoped the same way yields the same
    // outcome class for every request index
    let second = storm(plan, &oracles);
    assert_eq!(first, second, "fault outcomes changed between identical storms");
}
