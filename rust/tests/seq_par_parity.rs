//! Seq-vs-Par parity: the slot-resolved interpreter must produce identical
//! results (tolerance-equal for PageRank's floating-point reductions) across
//! execution modes and worker counts. This pins down two properties at once:
//! the atomic idioms are schedule-independent, and the fixedPoint frontier
//! fast path (SSSP/CC) computes exactly what the dense sweeps compute.

use starplat::backends::interp::{self, env::Val, Args, DeltaMode, Direction, ExecOpts};
use starplat::coordinator::driver::{load_program, Algo};
use starplat::dsl::parser::parse;
use starplat::graph::csr::Graph;
use starplat::graph::generators::{rmat, road_grid, uniform_random};
use starplat::sema::check_function;
use starplat::util::rng::Rng;

const THREADS: [usize; 3] = [1, 2, 8];

fn test_graphs() -> Vec<Graph> {
    let mut rng = Rng::new(0xC0FFEE);
    let mut gs = Vec::new();
    for i in 0..3 {
        let n = rng.range(60, 280);
        let m = rng.range(n, 5 * n);
        gs.push(rmat(&format!("rmat{i}"), n, m, rng.next_u64()));
    }
    gs.push(uniform_random("ur", 150, 600, rng.next_u64()));
    // mesh-shaped graph: exercises the sparse-frontier path for many rounds
    gs.push(road_grid("grid", 15, 14, 9));
    gs
}

/// Run one algorithm across all worker counts × both schedules (sparse
/// frontier on/off) and hand results to `check` with a context label. The
/// full grid pins that the persistent work-stealing runtime is
/// schedule-independent: claims, steals, and gather order must never show
/// up in results.
fn sweep_threads(algo: Algo, g: &Graph, args: &Args, check: impl Fn(&interp::Output, &str)) {
    let tf = load_program(algo).unwrap();
    for t in THREADS {
        for frontier in [true, false] {
            let opts = ExecOpts { threads: t, frontier, ..Default::default() };
            let out = interp::run_with_opts(&tf, g, args, opts).unwrap();
            check(&out, &format!("{t} threads (frontier={frontier})"));
        }
    }
}

#[test]
fn bfs_parity() {
    for g in test_graphs() {
        let tf = load_program(Algo::Bfs).unwrap();
        let args = Args::default().node("src", 0);
        let want = interp::run_with_threads(&tf, &g, &args, 1).unwrap().prop_i64("level");
        sweep_threads(Algo::Bfs, &g, &args, |out, ctx| {
            assert_eq!(out.prop_i64("level"), want, "{} with {ctx}", g.name);
        });
    }
}

#[test]
fn sssp_parity() {
    let mut rng = Rng::new(7);
    for g in test_graphs() {
        let src = rng.range(0, g.num_nodes()) as u32;
        let tf = load_program(Algo::Sssp).unwrap();
        let args = Args::default().node("src", src);
        let want = interp::run_with_threads(&tf, &g, &args, 1).unwrap().prop_i64("dist");
        sweep_threads(Algo::Sssp, &g, &args, |out, ctx| {
            assert_eq!(out.prop_i64("dist"), want, "{} src {src} with {ctx}", g.name);
        });
    }
}

#[test]
fn cc_parity() {
    for g in test_graphs() {
        let tf = load_program(Algo::Cc).unwrap();
        let args = Args::default();
        let want = interp::run_with_threads(&tf, &g, &args, 1).unwrap().prop_i64("comp");
        sweep_threads(Algo::Cc, &g, &args, |out, ctx| {
            assert_eq!(out.prop_i64("comp"), want, "{} with {ctx}", g.name);
        });
    }
}

#[test]
fn pr_parity_within_tolerance() {
    for g in test_graphs() {
        let args = Args::default()
            .scalar("beta", Val::F(1e-12))
            .scalar("delta", Val::F(0.85))
            .scalar("maxIter", Val::I(50));
        let tf = load_program(Algo::Pr).unwrap();
        let want = interp::run_with_threads(&tf, &g, &args, 1).unwrap().prop_f64("pageRank");
        sweep_threads(Algo::Pr, &g, &args, |out, ctx| {
            let got = out.prop_f64("pageRank");
            assert_eq!(got.len(), want.len());
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-7, "{} v{i} with {ctx}: {a} vs {b}", g.name);
            }
        });
    }
}

/// A pull-style fixedPoint: min-label propagation whose relaxation writes
/// land on *in-neighbors* (`g.nodes_to`), so the sparse gather must walk the
/// reverse CSR. The compile-layer tests pin that this shape is
/// frontier-eligible; here we pin that the sparse schedule computes exactly
/// what the dense schedule computes, across worker counts.
const PULL_CC: &str = "function Compute_CC_Pull(Graph g, propNode<int> comp) {
    propNode<bool> modified;
    propNode<bool> modified_nxt;
    bool finished = False;
    forall (v in g.nodes()) {
      v.comp = v;
    }
    g.attachNodeProperty(modified = True, modified_nxt = False);
    fixedPoint until (finished: !modified) {
      forall (v in g.nodes().filter(modified == True)) {
        for (u in g.nodes_to(v)) {
          <u.comp, u.modified_nxt> = <Min(u.comp, v.comp), True>;
        }
      }
      modified = modified_nxt;
      g.attachNodeProperty(modified_nxt = False);
    }
  }";

#[test]
fn pull_fixedpoint_parity_and_frontier_dense_agreement() {
    let fns = parse(PULL_CC).unwrap();
    let tf = check_function(&fns[0]).unwrap();
    for g in test_graphs() {
        let args = Args::default();
        // dense schedule at 1 thread is the ground truth
        let seq = ExecOpts { threads: 1, frontier: false, ..Default::default() };
        let want = interp::run_with_opts(&tf, &g, &args, seq).unwrap().prop_i64("comp");
        for t in THREADS {
            for frontier in [true, false] {
                let opts = ExecOpts { threads: t, frontier, ..Default::default() };
                let out = interp::run_with_opts(&tf, &g, &args, opts).unwrap();
                assert_eq!(
                    out.prop_i64("comp"),
                    want,
                    "{} with {t} threads (frontier={frontier})",
                    g.name
                );
            }
        }
    }
}

/// The adaptive scheduler is a pure work-order heuristic: every point in
/// {push, pull, auto} × {sweep, delta-stepping} must compute exactly what
/// the 1-thread dense oracle computes, across worker counts. SSSP exercises
/// the weighted relaxation (delta-eligible, interpreter-pullable); CC the
/// unweighted one (pull-eligible, delta silently inapplicable).
#[test]
fn schedule_cross_parity() {
    let mut rng = Rng::new(0xD1CE);
    for g in test_graphs() {
        for algo in [Algo::Sssp, Algo::Cc] {
            let tf = load_program(algo).unwrap();
            let (args, prop) = match algo {
                Algo::Sssp => {
                    (Args::default().node("src", rng.range(0, g.num_nodes()) as u32), "dist")
                }
                _ => (Args::default(), "comp"),
            };
            // dense schedule at 1 thread is the ground truth
            let seq = ExecOpts { threads: 1, frontier: false, ..Default::default() };
            let want = interp::run_with_opts(&tf, &g, &args, seq).unwrap().prop_i64(prop);
            for t in THREADS {
                for dir in [Direction::Push, Direction::Pull, Direction::Auto] {
                    for delta in [DeltaMode::Off, DeltaMode::Auto] {
                        let opts = ExecOpts {
                            threads: t,
                            direction: Some(dir),
                            delta: Some(delta),
                            ..Default::default()
                        };
                        let out = interp::run_with_opts(&tf, &g, &args, opts).unwrap();
                        let ctx = format!(
                            "{algo:?} on {} with {t} threads dir={dir:?} delta={delta:?}",
                            g.name
                        );
                        assert_eq!(out.prop_i64(prop), want, "{ctx}");
                        // a forced direction must actually be honored: pull
                        // rounds run unless the delta schedule replaced the
                        // frontier loop outright (weighted relax + delta on)
                        let delta_ran = algo == Algo::Sssp && delta == DeltaMode::Auto;
                        assert_eq!(out.stats.delta_used, delta_ran, "{ctx}");
                        if dir == Direction::Pull && !delta_ran {
                            assert!(out.stats.pull_rounds > 0, "{ctx}: pull forced but never ran");
                        }
                        if dir == Direction::Push {
                            assert_eq!(out.stats.pull_rounds, 0, "{ctx}: push forced but pulled");
                        }
                    }
                }
            }
        }
    }
}

/// Forcing pull must be a no-op when no kernel admits a reverse-CSR
/// schedule: PULL_CC's relaxation already writes *in*-neighbors (not the
/// canonical push-relax shape), so no pull twin exists and the engine must
/// stay push — pinned by the `pull_rounds` counter staying at zero while
/// results still match the dense oracle.
#[test]
fn forced_pull_is_ignored_without_an_eligible_kernel() {
    let fns = parse(PULL_CC).unwrap();
    let tf = check_function(&fns[0]).unwrap();
    for g in test_graphs() {
        let args = Args::default();
        let seq = ExecOpts { threads: 1, frontier: false, ..Default::default() };
        let want = interp::run_with_opts(&tf, &g, &args, seq).unwrap().prop_i64("comp");
        for t in THREADS {
            let opts =
                ExecOpts { threads: t, direction: Some(Direction::Pull), ..Default::default() };
            let out = interp::run_with_opts(&tf, &g, &args, opts).unwrap();
            assert_eq!(
                out.stats.pull_rounds, 0,
                "{} with {t} threads: pull forced but no reverse-CSR-eligible kernel",
                g.name
            );
            assert_eq!(out.prop_i64("comp"), want, "{} with {t} threads", g.name);
        }
    }
}

/// Nested BFS-DAG loops read levels two hops from the current frontier, so
/// the compiled level discovery must settle the whole graph before any body
/// sweep runs (a one-level-ahead scheme would silently skip every
/// grandchild). Oracle: count DAG 2-paths per endpoint from the reference
/// BFS levels.
#[test]
fn nested_bfs_dag_loops_see_settled_levels() {
    use starplat::algorithms::reference;
    const TWO_HOP: &str = "function Compute_TwoHop(Graph g, propNode<int> paths2, node src) {
        g.attachNodeProperty(paths2 = 0);
        iterateInBFS(v in g.nodes() from src) {
          forall (w in g.neighbors(v)) {
            forall (x in g.neighbors(w)) {
              x.paths2 += 1;
            }
          }
        }
      }";
    let fns = parse(TWO_HOP).unwrap();
    let tf = check_function(&fns[0]).unwrap();
    for g in test_graphs() {
        let levels = reference::bfs_levels(&g, 0);
        let mut want = vec![0i64; g.num_nodes()];
        for v in 0..g.num_nodes() as u32 {
            if levels[v as usize] == reference::INF {
                continue;
            }
            for &w in g.neighbors(v) {
                if levels[w as usize] != levels[v as usize] + 1 {
                    continue;
                }
                for &x in g.neighbors(w) {
                    if levels[x as usize] == levels[w as usize] + 1 {
                        want[x as usize] += 1;
                    }
                }
            }
        }
        let args = Args::default().node("src", 0);
        for t in THREADS {
            let out = interp::run_with_threads(&tf, &g, &args, t).unwrap();
            assert_eq!(out.prop_i64("paths2"), want, "{} with {t} threads", g.name);
        }
    }
}

/// ExecStats must be *invariants*, not best-effort telemetry: downstream
/// harnesses (the planexec differential suite, the CI fault matrix, the
/// bench tables) branch on these counters, so a drifting counter silently
/// rewires what those harnesses think they tested. Pinned here with the
/// fault machinery explicitly disabled (`FaultPlan::off()`), so the
/// assertions stay meaningful even when CI exports `STARPLAT_FAULT` seeds
/// into the whole test run: forcing push means zero pull rounds, an
/// unfaulted run means zero fallbacks, and single-source runs never batch.
#[test]
fn exec_stats_counters_are_invariants() {
    use starplat::util::fault::FaultPlan;
    let mut rng = Rng::new(0x57A7);
    for g in test_graphs() {
        for algo in [Algo::Bfs, Algo::Sssp, Algo::Cc, Algo::Pr] {
            let tf = load_program(algo).unwrap();
            let args = match algo {
                Algo::Bfs | Algo::Sssp => {
                    Args::default().node("src", rng.range(0, g.num_nodes()) as u32)
                }
                Algo::Pr => Args::default()
                    .scalar("beta", Val::F(1e-9))
                    .scalar("delta", Val::F(0.85))
                    .scalar("maxIter", Val::I(30)),
                _ => Args::default(),
            };
            for t in [1, 4] {
                let opts = ExecOpts {
                    threads: t,
                    direction: Some(Direction::Push),
                    delta: Some(DeltaMode::Off),
                    fault: Some(FaultPlan::off()),
                    ..Default::default()
                };
                let out = interp::run_with_opts(&tf, &g, &args, opts).unwrap();
                let ctx = format!("{algo:?} on {} with {t} threads", g.name);
                let s = &out.stats;
                assert_eq!(s.pull_rounds, 0, "{ctx}: push forced, yet pull rounds ran");
                assert_eq!(s.fallbacks, 0, "{ctx}: unfaulted run recorded a fault fallback");
                assert_eq!(s.batched_roots, 0, "{ctx}: single-source run claimed batching");
                assert!(!s.delta_used, "{ctx}: delta disabled, yet delta schedule used");
                assert_eq!(
                    s.direction_switches, 0,
                    "{ctx}: forced direction cannot switch mid-run"
                );
            }
        }
    }
}

/// The frontier fast path must agree with the oracles, not just with itself.
#[test]
fn frontier_path_matches_oracles() {
    use starplat::algorithms::reference;
    for g in test_graphs() {
        let tf = load_program(Algo::Sssp).unwrap();
        let out = interp::run_with_threads(&tf, &g, &Args::default().node("src", 0), 8).unwrap();
        let want: Vec<i64> = reference::dijkstra(&g, 0).into_iter().map(|d| d as i64).collect();
        assert_eq!(out.prop_i64("dist"), want, "{}", g.name);

        let tf = load_program(Algo::Cc).unwrap();
        let out = interp::run_with_threads(&tf, &g, &Args::default(), 8).unwrap();
        let want: Vec<i64> =
            reference::connected_components(&g).into_iter().map(|c| c as i64).collect();
        assert_eq!(out.prop_i64("comp"), want, "{}", g.name);
    }
}
