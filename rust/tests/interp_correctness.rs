//! Integration: DSL programs executed by the interpreter backend must match
//! the hand-written oracles on a variety of graphs, in both Seq and Par
//! modes. This is the core "generated code is correct" signal for the CPU
//! rows of the paper's Tables 3–4.

use starplat::algorithms::reference;
use starplat::backends::interp::{self, env::Val, Args, Mode};
use starplat::dsl::parser::parse_file;
use starplat::graph::csr::Graph;
use starplat::graph::generators::{
    preferential_attachment, rmat, road_grid, sample_sources, uniform_random,
};
use starplat::sema::{check_function, TypedFunction};

fn load(name: &str) -> TypedFunction {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("dsl_programs").join(name);
    let fns = parse_file(&path).unwrap();
    check_function(&fns[0]).unwrap()
}

fn graphs() -> Vec<Graph> {
    vec![
        rmat("rmat", 200, 900, 41),
        road_grid("grid", 12, 11, 42),
        preferential_attachment("pa", 180, 4, 43),
        uniform_random("ur", 150, 700, 44),
    ]
}

#[test]
fn sssp_matches_dijkstra_both_modes() {
    let tf = load("sssp.sp");
    for g in graphs() {
        let want: Vec<i64> =
            reference::dijkstra(&g, 0).into_iter().map(|d| d as i64).collect();
        for mode in [Mode::Seq, Mode::Par] {
            let out = interp::run(&tf, &g, &Args::default().node("src", 0), mode).unwrap();
            assert_eq!(out.prop_i64("dist"), want, "{} {:?}", g.name, mode);
        }
    }
}

#[test]
fn pr_matches_reference() {
    let tf = load("pr.sp");
    for g in graphs() {
        let want = reference::pagerank(&g, 1e-10, 0.85, 100);
        for mode in [Mode::Seq, Mode::Par] {
            let args = Args::default()
                .scalar("beta", Val::F(1e-10))
                .scalar("delta", Val::F(0.85))
                .scalar("maxIter", Val::I(100));
            let out = interp::run(&tf, &g, &args, mode).unwrap();
            let got = out.prop_f64("pageRank");
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-6, "{} {:?} v{}: {} vs {}", g.name, mode, i, a, b);
            }
        }
    }
}

#[test]
fn bc_matches_brandes() {
    let tf = load("bc.sp");
    for g in graphs() {
        let sources = sample_sources(&g, 5, 7);
        let want = reference::betweenness(&g, &sources);
        for mode in [Mode::Seq, Mode::Par] {
            let args = Args::default().set("sourceSet", sources.clone());
            let out = interp::run(&tf, &g, &args, mode).unwrap();
            let got = out.prop_f64("BC");
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() < 1e-5 * (1.0 + b.abs()),
                    "{} {:?} v{}: {} vs {}",
                    g.name,
                    mode,
                    i,
                    a,
                    b
                );
            }
        }
    }
}

#[test]
fn tc_matches_reference() {
    let tf = load("tc.sp");
    for g in graphs() {
        let want = reference::triangle_count(&g) as i64;
        for mode in [Mode::Seq, Mode::Par] {
            let out = interp::run(&tf, &g, &Args::default(), mode).unwrap();
            assert_eq!(out.ret, Some(Val::I(want)), "{} {:?}", g.name, mode);
        }
    }
}

#[test]
fn bfs_levels_match() {
    let tf = load("bfs.sp");
    for g in graphs() {
        let want: Vec<i64> =
            reference::bfs_levels(&g, 1).into_iter().map(|l| l as i64).collect();
        let out = interp::run(&tf, &g, &Args::default().node("src", 1), Mode::Par).unwrap();
        assert_eq!(out.prop_i64("level"), want, "{}", g.name);
    }
}

#[test]
fn cc_matches_reference() {
    let tf = load("cc.sp");
    for g in graphs() {
        let want: Vec<i64> =
            reference::connected_components(&g).into_iter().map(|c| c as i64).collect();
        let out = interp::run(&tf, &g, &Args::default(), Mode::Par).unwrap();
        assert_eq!(out.prop_i64("comp"), want, "{}", g.name);
    }
}

/// Property test: on random graphs, all executable paths agree — the DSL via
/// interpreter, the gunrock-style and lonestar-style baselines, and the
/// sequential oracle.
#[test]
fn property_all_implementations_agree() {
    use starplat::algorithms::{gunrock, lonestar};
    use starplat::util::rng::Rng;
    let mut rng = Rng::new(2024);
    let sssp_tf = load("sssp.sp");
    let tc_tf = load("tc.sp");
    for round in 0..8 {
        let n = rng.range(20, 220);
        let m = rng.range(n, 6 * n);
        let g = rmat("prop", n, m, rng.next_u64());
        let src = (rng.range(0, n)) as u32;

        let d_ref = reference::dijkstra(&g, src);
        assert_eq!(lonestar::sssp(&g, src, 3), d_ref, "round {round} lonestar");
        assert_eq!(gunrock::sssp(&g, src, 3), d_ref, "round {round} gunrock");
        let d_dsl =
            interp::run(&sssp_tf, &g, &Args::default().node("src", src), Mode::Par).unwrap();
        let want: Vec<i64> = d_ref.iter().map(|&d| d as i64).collect();
        assert_eq!(d_dsl.prop_i64("dist"), want, "round {round} dsl");

        let t_ref = reference::triangle_count(&g);
        assert_eq!(lonestar::triangle_count(&g, 3), t_ref);
        assert_eq!(gunrock::triangle_count(&g, 3), t_ref);
        let t_dsl = interp::run(&tc_tf, &g, &Args::default(), Mode::Par).unwrap();
        assert_eq!(t_dsl.ret, Some(Val::I(t_ref as i64)));
    }
}
