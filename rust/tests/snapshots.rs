//! Full-file snapshot tests: the generated output for the paper's four
//! algorithms × all seven text backends is pinned under `tests/snapshots/`,
//! so host-lowering refactors show up as reviewable snapshot diffs instead
//! of silent drift.
//!
//! Workflow:
//! - `cargo test` compares regeneration against the committed snapshots;
//! - `UPDATE_SNAPSHOTS=1 cargo test --test snapshots` rewrites them (commit
//!   the diff with the change that caused it);
//! - a missing snapshot (e.g. a freshly added backend) is bootstrapped:
//!   written on first run after a determinism self-check, compared on every
//!   run thereafter.

use starplat::codegen;
use starplat::dsl::parser::parse_file;
use starplat::ir::lower;
use starplat::sema::check_function;
use std::path::PathBuf;

/// The paper's four evaluated algorithms (Table 3).
const ALGOS: [&str; 4] = ["bc.sp", "pr.sp", "sssp.sp", "tc.sp"];

fn snapshot_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("snapshots")
}

fn gen(program: &str, backend: &str) -> String {
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("dsl_programs").join(program);
    let fns = parse_file(&path).unwrap();
    let tf = check_function(&fns[0]).unwrap();
    codegen::generate(backend, &lower(&tf)).unwrap()
}

/// First differing line, for a reviewable failure message.
fn first_diff(expected: &str, actual: &str) -> String {
    for (i, (e, a)) in expected.lines().zip(actual.lines()).enumerate() {
        if e != a {
            return format!("first diff at line {}:\n  snapshot: {e}\n  actual:   {a}", i + 1);
        }
    }
    format!(
        "line counts differ: snapshot {} vs actual {}",
        expected.lines().count(),
        actual.lines().count()
    )
}

#[test]
fn generated_output_matches_snapshots() {
    let dir = snapshot_dir();
    std::fs::create_dir_all(&dir).unwrap();
    let update = std::env::var("UPDATE_SNAPSHOTS").map(|v| v == "1").unwrap_or(false);
    let mut bootstrapped = Vec::new();
    for p in ALGOS {
        let stem = p.trim_end_matches(".sp");
        for b in codegen::TEXT_BACKENDS {
            let actual = gen(p, b);
            // determinism self-check: a snapshot is only meaningful if
            // regeneration is stable within one build
            assert_eq!(actual, gen(p, b), "{p}/{b}: generation is nondeterministic");
            let path = dir.join(format!("{stem}.{b}.snap"));
            if update || !path.exists() {
                std::fs::write(&path, &actual).unwrap();
                bootstrapped.push(format!("{stem}.{b}.snap"));
                continue;
            }
            let expected = std::fs::read_to_string(&path).unwrap();
            assert_eq!(
                expected,
                actual,
                "{p}/{b}: generated output drifted from tests/snapshots/{stem}.{b}.snap \
                 (run with UPDATE_SNAPSHOTS=1 to rewrite after reviewing the diff)\n{}",
                first_diff(&expected, &actual)
            );
        }
    }
    if !bootstrapped.is_empty() {
        eprintln!(
            "snapshots: wrote {} file(s): {} — commit them to pin generation",
            bootstrapped.len(),
            bootstrapped.join(", ")
        );
    }
    // the matrix is complete after one run: 4 algorithms × 7 backends
    for p in ALGOS {
        let stem = p.trim_end_matches(".sp");
        for b in codegen::TEXT_BACKENDS {
            let path = dir.join(format!("{stem}.{b}.snap"));
            assert!(path.exists(), "missing snapshot {}", path.display());
        }
    }
}
