//! Service-layer batched execution: `execute_batch` grouping/fan-out,
//! per-request cache interop, counter accounting, the configured lane
//! width, and the transparent coalescing window. Output fidelity against
//! independent runs is pinned here end-to-end; the engine-level parity
//! grid lives in `batch_parity.rs`.

use starplat::backends::interp::{self, Args, ExecOpts};
use starplat::dsl::parse;
use starplat::graph::csr::Graph;
use starplat::graph::generators::rmat;
use starplat::runtime::service::{Request, Service, ServiceConfig};
use starplat::sema::check_function;
use starplat::util::fault::FaultPlan;
use std::sync::Arc;
use std::time::Duration;

const BFS: &str = include_str!("../dsl_programs/bfs.sp");
const SSSP: &str = include_str!("../dsl_programs/sssp.sp");
const CC: &str = include_str!("../dsl_programs/cc.sp");

/// Deterministic generator: reconstructible for oracle runs outside the
/// service.
fn test_graph() -> Graph {
    rmat("g", 200, 800, 7)
}

fn service(cfg: ServiceConfig) -> Service {
    let svc = Service::new(cfg);
    svc.register_graph("g", test_graph()).unwrap();
    svc.register_program("bfs", BFS).unwrap();
    svc.register_program("sssp", SSSP).unwrap();
    svc.register_program("cc", CC).unwrap();
    svc
}

/// Faults forced off so `STARPLAT_FAULT` in the environment (the CI
/// fault-stress matrix) can never leak into these deterministic checks.
fn cfg() -> ServiceConfig {
    ServiceConfig { threads: 2, fault: Some(FaultPlan::off()), ..Default::default() }
}

fn req(program: &str, root: u32) -> Request {
    Request {
        graph: "g".into(),
        program: program.into(),
        args: Args::default().node("src", root),
        ..Request::default()
    }
}

/// Independent single-root oracle straight through the interpreter.
fn oracle(src: &str, args: &Args, prop: &str) -> Vec<i64> {
    let fns = parse(src).unwrap();
    let tf = check_function(&fns[0]).unwrap();
    let o = ExecOpts { threads: 1, fault: Some(FaultPlan::off()), ..ExecOpts::default() };
    interp::run_with_opts(&tf, &test_graph(), args, o).unwrap().prop_i64(prop)
}

fn bfs_oracle(root: u32) -> Vec<i64> {
    oracle(BFS, &Args::default().node("src", root), "level")
}

#[test]
fn execute_batch_matches_independent_outputs_and_counts_roots() {
    let svc = service(cfg());
    assert!(svc.execute_batch(&[]).is_empty());
    let roots = [0u32, 5, 5, 9, 13, 21];
    let reqs: Vec<Request> = roots.iter().map(|&r| req("bfs", r)).collect();
    let results = svc.execute_batch(&reqs);
    assert_eq!(results.len(), reqs.len());
    for (i, r) in results.iter().enumerate() {
        let out = r.as_ref().unwrap();
        assert_eq!(out.prop_i64("level"), bfs_oracle(roots[i]), "root {}", roots[i]);
    }
    // duplicate roots ran one lane and share one Arc
    assert!(Arc::ptr_eq(results[1].as_ref().unwrap(), results[2].as_ref().unwrap()));
    let s = svc.stats();
    assert_eq!(s.completed, 6);
    assert_eq!(s.batched_roots, 5, "five unique roots in one merged run");
    assert_eq!(s.cache_hits, 0);
    assert_eq!(s.coalesced, 0, "execute_batch merges explicitly, not via the window");

    // fan-out cached every root under its ordinary per-request key
    let again = svc.execute(&req("bfs", 13)).unwrap();
    assert_eq!(again.prop_i64("level"), bfs_oracle(13));
    assert_eq!(svc.stats().cache_hits, 1);

    // a second identical batch is served from cache end to end
    let cached = svc.execute_batch(&reqs);
    for (i, r) in cached.iter().enumerate() {
        assert_eq!(r.as_ref().unwrap().prop_i64("level"), bfs_oracle(roots[i]));
    }
    let s = svc.stats();
    assert_eq!(s.cache_hits, 7);
    assert_eq!(s.batched_roots, 5, "no new lanes dispatched for cache hits");
}

#[test]
fn mixed_batch_routes_ineligible_requests_through_the_solo_path() {
    let svc = service(cfg());
    let cc_req = Request { graph: "g".into(), program: "cc".into(), ..Request::default() };
    // a per-request knob (here: an explicit fault plan) opts out of merging
    let pinned = Request { fault: Some(FaultPlan::off()), ..req("bfs", 40) };
    let reqs = vec![req("bfs", 3), cc_req.clone(), req("sssp", 3), cc_req, pinned];
    let results = svc.execute_batch(&reqs);
    assert_eq!(results[0].as_ref().unwrap().prop_i64("level"), bfs_oracle(3));
    let cc_want = oracle(CC, &Args::default(), "comp");
    assert_eq!(results[1].as_ref().unwrap().prop_i64("comp"), cc_want);
    assert_eq!(
        results[2].as_ref().unwrap().prop_i64("dist"),
        oracle(SSSP, &Args::default().node("src", 3), "dist")
    );
    // the duplicate rootless request deduped through the result cache
    assert!(Arc::ptr_eq(results[1].as_ref().unwrap(), results[3].as_ref().unwrap()));
    assert_eq!(results[4].as_ref().unwrap().prop_i64("level"), bfs_oracle(40));
    let s = svc.stats();
    assert_eq!(s.completed, 5);
    assert_eq!(s.cache_hits, 1, "second cc request is a cache hit");
    // bfs root 3 and sssp root 3 are different groups of one root each; the
    // solo-path requests contribute no lanes
    assert_eq!(s.batched_roots, 2);
}

#[test]
fn configured_batch_width_tiles_waves_without_changing_results() {
    let svc = service(ServiceConfig { batch_width: 2, ..cfg() });
    let roots = [1u32, 3, 5, 7, 9];
    let reqs: Vec<Request> = roots.iter().map(|&r| req("sssp", r)).collect();
    let results = svc.execute_batch(&reqs);
    for (i, r) in results.iter().enumerate() {
        assert_eq!(
            r.as_ref().unwrap().prop_i64("dist"),
            oracle(SSSP, &Args::default().node("src", roots[i]), "dist"),
            "root {}",
            roots[i]
        );
    }
    assert_eq!(svc.stats().batched_roots, 5);
}

/// Concurrent same-group requests inside the coalescing window merge into
/// the leader's single batched traversal; every caller still gets its own
/// faithful per-root output.
#[test]
fn coalescing_window_merges_concurrent_requests() {
    let svc = service(ServiceConfig {
        // cache off so every request must miss and reach the window
        cache_capacity: 0,
        batch_window: Some(Duration::from_millis(400)),
        ..cfg()
    });
    let roots = [2u32, 4, 8, 16];
    std::thread::scope(|s| {
        let handles: Vec<_> = roots
            .iter()
            .map(|&r| {
                let svc = &svc;
                s.spawn(move || svc.execute(&req("bfs", r)).unwrap())
            })
            .collect();
        for (h, &r) in handles.into_iter().zip(&roots) {
            let out = h.join().unwrap();
            assert_eq!(out.prop_i64("level"), bfs_oracle(r), "root {r}");
        }
    });
    let s = svc.stats();
    assert_eq!(s.completed, 4);
    assert!(s.coalesced >= 1, "concurrent same-group requests should coalesce: {s:?}");
    // every distinct root rode exactly one merged run, whether it joined the
    // leader's window or (under pathological scheduling) led its own
    assert_eq!(s.batched_roots, 4);
}

/// With no window configured, execute() behaves exactly as before batching
/// existed — no gather detour, no counters moving.
#[test]
fn no_window_means_no_coalescing() {
    let svc = service(cfg());
    let out = svc.execute(&req("bfs", 11)).unwrap();
    assert_eq!(out.prop_i64("level"), bfs_oracle(11));
    let s = svc.stats();
    assert_eq!((s.coalesced, s.batched_roots), (0, 0));
    assert_eq!(s.completed, 1);
}
