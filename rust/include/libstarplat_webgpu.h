// libstarplat_webgpu.h — host-side helpers for the generated WGSL/Dawn
// skeletons (the `host.cpp` section of a generated program). WebGPU's
// ceremonies — async adapter/device acquisition, MapAsync readbacks,
// per-pipeline bind-group layouts — live here once instead of being
// repeated at every generated dispatch site.
//
// Build shape the helpers assume: the embedder splits the generated file's
// `shaders.wgsl` section on its `// shader module: <name>` markers (each
// module is a self-contained WGSL compilation unit with its own Params
// struct and @group(0) bindings — see scripts/wgsl_smoke.py for the same
// split) and calls `registerShaderModule(name, source)` for each before
// invoking the generated entry point.
#pragma once

#include <webgpu/webgpu_cpp.h>

#include <cstdio>
#include <cstdlib>
#include <cstring>
#include <initializer_list>
#include <map>
#include <string>
#include <vector>

// ---- shader module registry -----------------------------------------------

inline std::map<std::string, std::string>& starplatShaderSources() {
    static std::map<std::string, std::string> sources;
    return sources;
}

inline void registerShaderModule(const char* name, const char* wgsl) {
    starplatShaderSources()[name] = wgsl;
}

// ---- device acquisition ---------------------------------------------------

// Synchronous wrapper over the async adapter/device handshake; one device
// is shared by every generated function in the process.
inline wgpu::Device requestDevice() {
    static wgpu::Device device = nullptr;
    if (device) {
        return device;
    }
    static wgpu::Instance instance = wgpu::CreateInstance();
    wgpu::Adapter adapter = nullptr;
    instance.RequestAdapter(
        nullptr,
        [](WGPURequestAdapterStatus status, WGPUAdapter a, const char* msg, void* userdata) {
            if (status != WGPURequestAdapterStatus_Success) {
                std::fprintf(stderr, "libstarplat_webgpu: adapter request failed: %s\n",
                             msg != nullptr ? msg : "(no message)");
                std::abort();
            }
            *static_cast<wgpu::Adapter*>(userdata) = wgpu::Adapter::Acquire(a);
        },
        &adapter);
    while (!adapter) {
        instance.ProcessEvents();
    }
    adapter.RequestDevice(
        nullptr,
        [](WGPURequestDeviceStatus status, WGPUDevice d, const char* msg, void* userdata) {
            if (status != WGPURequestDeviceStatus_Success) {
                std::fprintf(stderr, "libstarplat_webgpu: device request failed: %s\n",
                             msg != nullptr ? msg : "(no message)");
                std::abort();
            }
            *static_cast<wgpu::Device*>(userdata) = wgpu::Device::Acquire(d);
        },
        &device);
    while (!device) {
        instance.ProcessEvents();
    }
    return device;
}

// ---- buffers --------------------------------------------------------------

inline wgpu::Buffer makeStorageBuffer(const wgpu::Device& device, size_t size) {
    wgpu::BufferDescriptor desc;
    desc.size = size;
    desc.usage = wgpu::BufferUsage::Storage | wgpu::BufferUsage::CopySrc |
                 wgpu::BufferUsage::CopyDst;
    return device.CreateBuffer(&desc);
}

// Uniform params structs are tiny and rebuilt per dispatch; the generated
// code destroys them right after submission.
inline wgpu::Buffer makeUniformBuffer(const wgpu::Device& device, const void* data,
                                      size_t size) {
    wgpu::BufferDescriptor desc;
    desc.size = (size + 3) & ~static_cast<size_t>(3);
    desc.usage = wgpu::BufferUsage::Uniform | wgpu::BufferUsage::CopyDst;
    wgpu::Buffer buf = device.CreateBuffer(&desc);
    device.GetQueue().WriteBuffer(buf, 0, data, size);
    return buf;
}

template <typename T>
inline void fillBuffer(const wgpu::Device& /*device*/, const wgpu::Queue& queue,
                       const wgpu::Buffer& buf, int count, T value) {
    std::vector<T> host(static_cast<size_t>(count), value);
    queue.WriteBuffer(buf, 0, host.data(), host.size() * sizeof(T));
}

// The MapAsync readback ceremony: copy into a MapRead staging buffer,
// submit, poll to completion, memcpy out. Every §4.1 copy-out in the
// generated host code funnels through here.
inline void readBuffer(const wgpu::Device& device, const wgpu::Queue& queue,
                       const wgpu::Buffer& src, void* dst, size_t size) {
    size_t padded = (size + 3) & ~static_cast<size_t>(3);
    wgpu::BufferDescriptor desc;
    desc.size = padded;
    desc.usage = wgpu::BufferUsage::MapRead | wgpu::BufferUsage::CopyDst;
    wgpu::Buffer staging = device.CreateBuffer(&desc);
    wgpu::CommandEncoder enc = device.CreateCommandEncoder();
    enc.CopyBufferToBuffer(src, 0, staging, 0, padded);
    wgpu::CommandBuffer cb = enc.Finish();
    queue.Submit(1, &cb);
    bool done = false;
    staging.MapAsync(
        wgpu::MapMode::Read, 0, padded,
        [](WGPUBufferMapAsyncStatus status, void* userdata) {
            if (status != WGPUBufferMapAsyncStatus_Success) {
                std::fprintf(stderr, "libstarplat_webgpu: MapAsync failed (%d)\n",
                             static_cast<int>(status));
                std::abort();
            }
            *static_cast<bool*>(userdata) = true;
        },
        &done);
    while (!done) {
        device.Tick();  // Dawn; use wgpuInstanceProcessEvents on other runtimes
    }
    std::memcpy(dst, staging.GetConstMappedRange(0, padded), size);
    staging.Unmap();
    staging.Destroy();
}

// ---- pipelines and bind groups --------------------------------------------

// One compute pipeline per kernel entry point, compiled lazily from the
// registered WGSL source and cached: generated code resolves pipelines at
// every dispatch site, including inside fixedPoint/BFS host loops.
inline wgpu::ComputePipeline pipelineFor(const wgpu::Device& device, const char* name) {
    static std::map<std::string, wgpu::ComputePipeline> cache;
    auto it = cache.find(name);
    if (it != cache.end()) {
        return it->second;
    }
    auto& sources = starplatShaderSources();
    auto src = sources.find(name);
    if (src == sources.end()) {
        std::fprintf(stderr,
                     "libstarplat_webgpu: shader module `%s` not registered — call "
                     "registerShaderModule before the generated entry point\n",
                     name);
        std::abort();
    }
    wgpu::ShaderModuleWGSLDescriptor wgsl;
    wgsl.code = src->second.c_str();
    wgpu::ShaderModuleDescriptor smDesc;
    smDesc.nextInChain = &wgsl;
    wgpu::ShaderModule module = device.CreateShaderModule(&smDesc);
    wgpu::ComputePipelineDescriptor desc;
    desc.compute.module = module;
    desc.compute.entryPoint = name;
    wgpu::ComputePipeline pipeline = device.CreateComputePipeline(&desc);
    cache[name] = pipeline;
    return pipeline;
}

// Bind group in the generated binding order: binding 0 is the uniform
// params buffer, then the module's storage buffers in canonical parameter
// order (the same order the module's @binding indices were emitted in).
inline wgpu::BindGroup bindGroupFor(const wgpu::Device& device, const char* name,
                                    std::initializer_list<wgpu::Buffer> buffers) {
    std::vector<wgpu::BindGroupEntry> entries;
    uint32_t binding = 0;
    for (const wgpu::Buffer& buf : buffers) {
        wgpu::BindGroupEntry e;
        e.binding = binding++;
        e.buffer = buf;
        e.offset = 0;
        e.size = buf.GetSize();
        entries.push_back(e);
    }
    wgpu::BindGroupDescriptor desc;
    desc.layout = pipelineFor(device, name).GetBindGroupLayout(0);
    desc.entryCount = entries.size();
    desc.entries = entries.data();
    return device.CreateBindGroup(&desc);
}
