// libstarplat_metal.h — shared helper header for the generated Metal
// skeletons. The same file is included from both halves of a generated
// program: the `kernels.metal` section (compiled by the Metal shader
// compiler, __METAL_VERSION__ defined) and the `host.mm` section (metal-cpp
// C++). Each side sees only its own half of this header.
//
// Build shape the host half assumes: `kernels.metal` is compiled into the
// app's default library (`default.metallib`), so `pipelineFor` can resolve
// every kernel by entry-point name at first use.
#pragma once

#if defined(__METAL_VERSION__)

// ---- MSL side -------------------------------------------------------------

#include <metal_stdlib>

// generated kernels spell the DSL's INF as INT_MAX; metal_stdlib's
// <metal_limits> provides it on current toolchains, older ones do not
#ifndef INT_MAX
#define INT_MAX 2147483647
#endif

// `is_an_edge` lookup: binary search of w in u's adjacency slice (the CSR
// edge list is sorted within each row). Same contract as the CUDA/OpenCL
// helper of the same name.
static inline bool findNeighborSorted(int u, int w,
                                      device const int* OA,
                                      device const int* edgeList) {
    int lo = OA[u];
    int hi = OA[u + 1] - 1;
    while (lo <= hi) {
        int mid = (lo + hi) / 2;
        if (edgeList[mid] == w) {
            return true;
        }
        if (edgeList[mid] < w) {
            lo = mid + 1;
        } else {
            hi = mid - 1;
        }
    }
    return false;
}

#else  // !__METAL_VERSION__

// ---- host side (metal-cpp) ------------------------------------------------

#include <Metal/Metal.hpp>
#include <cstdio>
#include <cstdlib>
#include <map>
#include <string>

// One compute pipeline per kernel entry point, compiled lazily from the
// default library and cached: generated code calls `pipelineFor` at every
// dispatch site, including inside fixedPoint/BFS host loops, so repeat
// lookups must be cheap.
inline MTL::ComputePipelineState* pipelineFor(MTL::Device* dev, const char* name) {
    static std::map<std::string, MTL::ComputePipelineState*> cache;
    auto it = cache.find(name);
    if (it != cache.end()) {
        return it->second;
    }
    static MTL::Library* lib = nullptr;
    if (lib == nullptr) {
        lib = dev->newDefaultLibrary();
        if (lib == nullptr) {
            std::fprintf(stderr,
                         "libstarplat_metal: no default.metallib — compile the "
                         "kernels.metal section into the app's default library\n");
            std::abort();
        }
    }
    NS::String* entry = NS::String::string(name, NS::UTF8StringEncoding);
    MTL::Function* fn = lib->newFunction(entry);
    if (fn == nullptr) {
        std::fprintf(stderr, "libstarplat_metal: kernel `%s` not in default library\n", name);
        std::abort();
    }
    NS::Error* err = nullptr;
    MTL::ComputePipelineState* pipeline = dev->newComputePipelineState(fn, &err);
    if (pipeline == nullptr) {
        std::fprintf(stderr, "libstarplat_metal: pipeline for `%s` failed: %s\n", name,
                     err != nullptr ? err->localizedDescription()->utf8String() : "unknown");
        std::abort();
    }
    fn->release();
    cache[name] = pipeline;
    return pipeline;
}

#endif  // __METAL_VERSION__
