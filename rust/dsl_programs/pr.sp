// Double-buffered PageRank (paper Fig 7): pull over in-edges, L1-delta
// convergence against beta, capped at maxIter host iterations.
function Compute_PR(Graph g, float beta, float delta, int maxIter, propNode<float> pageRank) {
  float num_nodes = g.num_nodes();
  propNode<float> pageRank_nxt;
  int iterCount = 0;
  float diff = 0.0;
  g.attachNodeProperty(pageRank = 1 / num_nodes);
  do {
    diff = 0.0;
    forall (v in g.nodes()) {
      float sum = 0.0;
      for (nbr in g.nodes_to(v)) {
        sum = sum + nbr.pageRank / nbr.outDegree();
      }
      float val = (1 - delta) / num_nodes + delta * sum;
      diff += abs(val - v.pageRank);
      v.pageRank_nxt = val;
    }
    pageRank = pageRank_nxt;
    iterCount++;
  } while ((diff > beta) && (iterCount < maxIter));
}
