// Triangle counting (paper §5.1): for each vertex v, count neighbor pairs
// (u, w) with u < v < w that close a triangle — each triangle is counted
// exactly once, at its middle vertex.
function Compute_TC(Graph g) {
  long triangle_count = 0;
  forall (v in g.nodes()) {
    forall (u in g.neighbors(v).filter(u < v)) {
      forall (w in g.neighbors(v).filter(w > v)) {
        if (g.is_an_edge(u, w)) {
          triangle_count += 1;
        }
      }
    }
  }
  return triangle_count;
}
