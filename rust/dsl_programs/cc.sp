// Connected components by min-label propagation: the SSSP relaxation shape
// without edge weights — every vertex converges to the smallest vertex id in
// its (weakly) connected component.
function Compute_CC(Graph g, propNode<int> comp) {
  propNode<bool> modified;
  propNode<bool> modified_nxt;
  bool finished = False;
  forall (v in g.nodes()) {
    v.comp = v;
  }
  g.attachNodeProperty(modified = True, modified_nxt = False);
  fixedPoint until (finished: !modified) {
    forall (v in g.nodes().filter(modified == True)) {
      forall (nbr in g.neighbors(v)) {
        <nbr.comp, nbr.modified_nxt> = <Min(nbr.comp, v.comp), True>;
      }
    }
    modified = modified_nxt;
    g.attachNodeProperty(modified_nxt = False);
  }
}
