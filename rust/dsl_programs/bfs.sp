// BFS levels via iterateInBFS (paper §3.4): inside the construct,
// g.neighbors(v) yields only the BFS-DAG children of v, so every reachable
// vertex receives level(parent) + 1; unreachable vertices keep INF.
function Compute_BFS(Graph g, propNode<int> level, node src) {
  g.attachNodeProperty(level = INF);
  src.level = 0;
  iterateInBFS(v in g.nodes() from src) {
    forall (w in g.neighbors(v)) {
      w.level = v.level + 1;
    }
  }
}
