// Single-source shortest paths (paper Fig 1 / §3.5): fixedPoint relaxation
// with the Min construct; `modified` / `modified_nxt` ping-pong drives the
// OR-flag convergence test (§4.1).
function Compute_SSSP(Graph g, propNode<int> dist, propEdge<int> weight, node src) {
  propNode<bool> modified;
  propNode<bool> modified_nxt;
  bool finished = False;
  g.attachNodeProperty(dist = INF, modified = False, modified_nxt = False);
  src.modified = True;
  src.dist = 0;
  fixedPoint until (finished: !modified) {
    forall (v in g.nodes().filter(modified == True)) {
      forall (nbr in g.neighbors(v)) {
        edge e = g.get_edge(v, nbr);
        <nbr.dist, nbr.modified_nxt> = <Min(nbr.dist, v.dist + e.weight), True>;
      }
    }
    modified = modified_nxt;
    g.attachNodeProperty(modified_nxt = False);
  }
}
