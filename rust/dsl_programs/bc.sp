// Betweenness centrality (Brandes over a source set, paper Fig 1 / §3.4):
// forward BFS accumulates sigma along BFS-DAG edges, the reverse sweep
// accumulates delta and folds it into BC.
function Compute_BC(Graph g, propNode<float> BC, SetN<g> sourceSet) {
  g.attachNodeProperty(BC = 0);
  for (src in sourceSet) {
    propNode<float> sigma;
    propNode<float> delta;
    g.attachNodeProperty(delta = 0, sigma = 0);
    src.sigma = 1;
    iterateInBFS(v in g.nodes() from src) {
      forall (w in g.neighbors(v)) {
        w.sigma += v.sigma;
      }
    }
    iterateInReverse(v != src) {
      forall (w in g.neighbors(v)) {
        v.delta += (v.sigma / w.sigma) * (1 + w.delta);
      }
      v.BC += v.delta;
    }
  }
}
