//! Sequential, obviously-correct reference implementations of the paper's
//! four algorithms. These are the correctness oracles for every backend
//! (interpreter, XLA, and the hand-written Gunrock/Lonestar baselines).

use crate::graph::csr::{Graph, Node};
use std::collections::VecDeque;

/// Large-but-safe infinity for i32 distance arithmetic (INF + weight must
/// not overflow, matching the generated `dist[v] != INT_MAX` guards).
pub const INF: i32 = i32::MAX / 2;

/// BFS levels from `src`; unreachable = INF.
pub fn bfs_levels(g: &Graph, src: Node) -> Vec<i32> {
    let mut level = vec![INF; g.num_nodes()];
    level[src as usize] = 0;
    let mut q = VecDeque::new();
    q.push_back(src);
    while let Some(u) = q.pop_front() {
        for &w in g.neighbors(u) {
            if level[w as usize] == INF {
                level[w as usize] = level[u as usize] + 1;
                q.push_back(w);
            }
        }
    }
    level
}

/// Dijkstra with a binary heap — the SSSP oracle.
pub fn dijkstra(g: &Graph, src: Node) -> Vec<i32> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut dist = vec![INF; g.num_nodes()];
    dist[src as usize] = 0;
    let mut heap = BinaryHeap::new();
    heap.push(Reverse((0i64, src)));
    while let Some(Reverse((d, u))) = heap.pop() {
        if d as i32 > dist[u as usize] {
            continue;
        }
        for e in g.edge_range(u) {
            let w = g.adj[e];
            let nd = dist[u as usize].saturating_add(g.weights[e]);
            if nd < dist[w as usize] {
                dist[w as usize] = nd;
                heap.push(Reverse((nd as i64, w)));
            }
        }
    }
    dist
}

/// Double-buffered PageRank (the paper's formulation, Fig 7): pull over
/// in-edges, `(1-d)/n + d * Σ pr[nbr]/outdeg[nbr]`, L1-convergence on beta.
pub fn pagerank(g: &Graph, beta: f64, damping: f64, max_iter: usize) -> Vec<f64> {
    let n = g.num_nodes();
    let mut pr = vec![1.0 / n as f64; n];
    let mut nxt = vec![0.0; n];
    for _ in 0..max_iter {
        let mut diff = 0.0;
        for v in 0..n {
            let mut sum = 0.0;
            for &u in g.in_neighbors(v as Node) {
                sum += pr[u as usize] / g.out_degree(u) as f64;
            }
            let val = (1.0 - damping) / n as f64 + damping * sum;
            diff += (val - pr[v]).abs();
            nxt[v] = val;
        }
        std::mem::swap(&mut pr, &mut nxt);
        if diff <= beta {
            break;
        }
    }
    pr
}

/// Brandes betweenness centrality accumulated over `sources`
/// (unweighted shortest paths, as in the paper's BC).
pub fn betweenness(g: &Graph, sources: &[Node]) -> Vec<f64> {
    let n = g.num_nodes();
    let mut bc = vec![0.0f64; n];
    for &s in sources {
        // forward phase
        let mut sigma = vec![0.0f64; n];
        let mut level = vec![-1i64; n];
        let mut order: Vec<Node> = Vec::with_capacity(n);
        sigma[s as usize] = 1.0;
        level[s as usize] = 0;
        let mut q = VecDeque::new();
        q.push_back(s);
        while let Some(u) = q.pop_front() {
            order.push(u);
            for &w in g.neighbors(u) {
                if level[w as usize] < 0 {
                    level[w as usize] = level[u as usize] + 1;
                    q.push_back(w);
                }
                if level[w as usize] == level[u as usize] + 1 {
                    sigma[w as usize] += sigma[u as usize];
                }
            }
        }
        // backward phase
        let mut delta = vec![0.0f64; n];
        for &v in order.iter().rev() {
            for &w in g.neighbors(v) {
                if level[w as usize] == level[v as usize] + 1 {
                    delta[v as usize] +=
                        (sigma[v as usize] / sigma[w as usize]) * (1.0 + delta[w as usize]);
                }
            }
            if v != s {
                bc[v as usize] += delta[v as usize];
            }
        }
    }
    bc
}

/// Triangle count: for each v, pairs (u, w) of neighbors with u < v < w and
/// edge (u, w) — each triangle counted exactly once (paper's TC shape).
pub fn triangle_count(g: &Graph) -> u64 {
    let mut count = 0u64;
    for v in 0..g.num_nodes() as Node {
        let nb = g.neighbors(v);
        for &u in nb.iter().take_while(|&&u| u < v) {
            for &w in nb.iter().rev().take_while(|&&w| w > v) {
                if g.is_an_edge(u, w) {
                    count += 1;
                }
            }
        }
    }
    count
}

/// Connected components by label propagation (oracle for cc.sp): every
/// vertex ends with the minimum vertex id of its (weakly) connected
/// component. Assumes a symmetric graph.
pub fn connected_components(g: &Graph) -> Vec<i32> {
    let n = g.num_nodes();
    let mut comp: Vec<i32> = (0..n as i32).collect();
    let mut changed = true;
    while changed {
        changed = false;
        for v in 0..n as Node {
            for &w in g.neighbors(v) {
                if comp[v as usize] < comp[w as usize] {
                    comp[w as usize] = comp[v as usize];
                    changed = true;
                } else if comp[w as usize] < comp[v as usize] {
                    comp[v as usize] = comp[w as usize];
                    changed = true;
                }
            }
        }
    }
    comp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::GraphBuilder;
    use crate::graph::generators::rmat;

    fn triangle_graph() -> Graph {
        // K3 plus a pendant
        let mut b = GraphBuilder::new(4);
        b.add_undirected(0, 1, 2);
        b.add_undirected(1, 2, 3);
        b.add_undirected(0, 2, 10);
        b.add_undirected(2, 3, 1);
        b.build()
    }

    #[test]
    fn bfs_and_dijkstra_on_triangle() {
        let g = triangle_graph();
        assert_eq!(bfs_levels(&g, 0), vec![0, 1, 1, 2]);
        // dist 0->2: direct 10 vs 0->1->2 = 5
        assert_eq!(dijkstra(&g, 0), vec![0, 2, 5, 6]);
    }

    #[test]
    fn tc_counts_one_triangle() {
        assert_eq!(triangle_count(&triangle_graph()), 1);
    }

    #[test]
    fn tc_on_k4_is_four() {
        let mut b = GraphBuilder::new(4);
        for u in 0..4u32 {
            for v in (u + 1)..4 {
                b.add_undirected(u, v, 1);
            }
        }
        assert_eq!(triangle_count(&b.build()), 4);
    }

    #[test]
    fn pagerank_sums_to_one_and_ranks_hub_highest() {
        let mut b = GraphBuilder::new(5);
        for v in 1..5u32 {
            b.add_undirected(0, v, 1);
        }
        let g = b.build();
        let pr = pagerank(&g, 1e-12, 0.85, 200);
        let sum: f64 = pr.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "sum {sum}");
        assert!(pr[0] > pr[1]);
    }

    #[test]
    fn bc_path_graph_middle_is_highest() {
        // path 0-1-2: vertex 1 lies on the 0<->2 shortest path
        let mut b = GraphBuilder::new(3);
        b.add_undirected(0, 1, 1);
        b.add_undirected(1, 2, 1);
        let g = b.build();
        let bc = betweenness(&g, &[0, 1, 2]);
        assert!(bc[1] > bc[0] && bc[1] > bc[2]);
        assert_eq!(bc[0], 0.0);
        // From src=0: delta contribution to v=1 is 1 (one dependent vertex).
        assert!((bc[1] - 2.0).abs() < 1e-12, "bc[1] = {}", bc[1]);
    }

    #[test]
    fn cc_labels_components() {
        let mut b = GraphBuilder::new(5);
        b.add_undirected(0, 1, 1);
        b.add_undirected(3, 4, 1);
        let g = b.build();
        let c = connected_components(&g);
        assert_eq!(c, vec![0, 0, 2, 3, 3]);
    }

    #[test]
    fn dijkstra_unreachable_is_inf() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1, 5);
        let g = b.build();
        let d = dijkstra(&g, 0);
        assert_eq!(d[2], INF);
    }

    #[test]
    fn oracles_deterministic_on_random_graph() {
        let g = rmat("x", 128, 512, 3);
        assert_eq!(triangle_count(&g), triangle_count(&g));
        assert_eq!(dijkstra(&g, 0), dijkstra(&g, 0));
    }
}
