//! Hand-written graph algorithms: sequential oracles plus the two
//! hand-crafted baselines the paper compares against (Table 3) —
//! topology-driven LonestarGPU style and frontier-based Gunrock style.

pub mod gunrock;
pub mod lonestar;
pub mod reference;
