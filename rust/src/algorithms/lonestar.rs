//! LonestarGPU-style baselines: **topology-driven** hand-optimized parallel
//! implementations (Burtscher, Nasre, Pingali, IISWC'12). Every kernel
//! sweeps all vertices each round (no frontier/worklist), exactly the
//! processing style the paper compares against in Table 3. LonestarGPU has
//! no BC — the paper's Table 3 marks those cells "-" and so do we.

use crate::algorithms::reference::INF;
use crate::graph::csr::{Graph, Node};
use crate::util::atomics::{atomic_add_f64, atomic_min_i32};
use crate::util::pool::{parallel_for, parallel_for_dynamic};
use std::sync::atomic::{AtomicBool, AtomicI32, AtomicU64, Ordering};

/// Topology-driven Bellman-Ford: every round relaxes the out-edges of every
/// vertex; converges when no distance changed.
pub fn sssp(g: &Graph, src: Node, threads: usize) -> Vec<i32> {
    let n = g.num_nodes();
    let dist: Vec<AtomicI32> = (0..n).map(|_| AtomicI32::new(INF)).collect();
    dist[src as usize].store(0, Ordering::Relaxed);
    loop {
        let changed = AtomicBool::new(false);
        parallel_for(n, threads, |v| {
            let dv = dist[v].load(Ordering::Relaxed);
            if dv >= INF {
                return;
            }
            for e in g.edge_range(v as Node) {
                let w = g.adj[e] as usize;
                let nd = dv + g.weights[e];
                if nd < dist[w].load(Ordering::Relaxed) {
                    let prev = atomic_min_i32(&dist[w], nd);
                    if nd < prev {
                        changed.store(true, Ordering::Relaxed);
                    }
                }
            }
        });
        if !changed.load(Ordering::Relaxed) {
            break;
        }
    }
    dist.into_iter().map(|d| d.into_inner()).collect()
}

/// Topology-driven BFS: level-synchronous sweep over all vertices
/// (LonestarGPU's `bfs` without worklists).
pub fn bfs(g: &Graph, src: Node, threads: usize) -> Vec<i32> {
    let n = g.num_nodes();
    let level: Vec<AtomicI32> = (0..n).map(|_| AtomicI32::new(INF)).collect();
    level[src as usize].store(0, Ordering::Relaxed);
    let mut depth = 0;
    loop {
        let changed = AtomicBool::new(false);
        parallel_for(n, threads, |v| {
            if level[v].load(Ordering::Relaxed) != depth {
                return;
            }
            for &w in g.neighbors(v as Node) {
                if level[w as usize].load(Ordering::Relaxed) == INF {
                    level[w as usize].store(depth + 1, Ordering::Relaxed);
                    changed.store(true, Ordering::Relaxed);
                }
            }
        });
        if !changed.load(Ordering::Relaxed) {
            break;
        }
        depth += 1;
    }
    level.into_iter().map(|l| l.into_inner()).collect()
}

/// In-place PageRank (LonestarGPU converges faster with in-place updates —
/// paper §5.1 PageRank discussion).
pub fn pagerank(g: &Graph, beta: f64, damping: f64, max_iter: usize, threads: usize) -> Vec<f64> {
    let n = g.num_nodes();
    let pr: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new((1.0 / n as f64).to_bits())).collect();
    for _ in 0..max_iter {
        let diff = AtomicU64::new(0f64.to_bits());
        parallel_for(n, threads, |v| {
            let mut sum = 0.0;
            for &u in g.in_neighbors(v as Node) {
                sum += f64::from_bits(pr[u as usize].load(Ordering::Relaxed))
                    / g.out_degree(u) as f64;
            }
            let val = (1.0 - damping) / n as f64 + damping * sum;
            let old = f64::from_bits(pr[v].swap(val.to_bits(), Ordering::Relaxed));
            atomic_add_f64(&diff, (val - old).abs());
        });
        if f64::from_bits(diff.load(Ordering::Relaxed)) <= beta {
            break;
        }
    }
    pr.into_iter().map(|b| f64::from_bits(b.into_inner())).collect()
}

/// Triangle counting with sorted-adjacency binary search, dynamically
/// scheduled (power-law degree skew makes static chunks imbalanced — the
/// paper's TC blow-up case).
pub fn triangle_count(g: &Graph, threads: usize) -> u64 {
    let n = g.num_nodes();
    let count = AtomicU64::new(0);
    parallel_for_dynamic(n, threads, 64, |v| {
        let v = v as Node;
        let nb = g.neighbors(v);
        let mut local = 0u64;
        for &u in nb.iter().take_while(|&&u| u < v) {
            for &w in nb.iter().rev().take_while(|&&w| w > v) {
                if g.is_an_edge(u, w) {
                    local += 1;
                }
            }
        }
        if local > 0 {
            count.fetch_add(local, Ordering::Relaxed);
        }
    });
    count.into_inner()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::reference;
    use crate::graph::generators::{rmat, road_grid, uniform_random};

    #[test]
    fn sssp_matches_dijkstra() {
        for (i, g) in
            [rmat("r", 200, 800, 1), road_grid("g", 12, 12, 2), uniform_random("u", 150, 600, 3)]
                .iter()
                .enumerate()
        {
            assert_eq!(sssp(g, 0, 3), reference::dijkstra(g, 0), "graph {i}");
        }
    }

    #[test]
    fn bfs_matches_reference() {
        let g = rmat("r", 300, 1200, 7);
        assert_eq!(bfs(&g, 5, 3), reference::bfs_levels(&g, 5));
    }

    #[test]
    fn pagerank_close_to_reference() {
        let g = rmat("r", 200, 800, 9);
        let a = pagerank(&g, 1e-10, 0.85, 100, 3);
        let b = reference::pagerank(&g, 1e-10, 0.85, 100);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn tc_matches_reference() {
        for g in [rmat("r", 256, 2000, 11), uniform_random("u", 200, 1500, 13)] {
            assert_eq!(triangle_count(&g, 3), reference::triangle_count(&g));
        }
    }
}
