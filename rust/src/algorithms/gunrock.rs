//! Gunrock-style baselines: **data-centric frontier** implementations
//! (Wang et al., PPoPP'16). All operations are bulk-synchronous and built
//! from the three Gunrock primitives the paper describes — `advance`
//! (expand a frontier along edges), `filter` (compact by predicate), and
//! per-element `compute` — mirroring the library the paper benchmarks
//! against in Table 3.

use crate::algorithms::reference::INF;
use crate::graph::csr::{Graph, Node};
use crate::util::atomics::{atomic_add_f64, atomic_min_i32};
use crate::util::pool::parallel_for;
use std::sync::atomic::{AtomicBool, AtomicI32, AtomicU64, Ordering};

/// Frontier advance: apply `f(u, e, w)` over all out-edges of the frontier;
/// `f` returns whether `w` enters the next frontier. Deduplication happens
/// through an atomically-claimed membership bitmap (Gunrock's idempotent
/// filter).
pub fn advance<F>(g: &Graph, frontier: &[Node], threads: usize, f: F) -> Vec<Node>
where
    F: Fn(Node, usize, Node) -> bool + Sync,
{
    let n = g.num_nodes();
    let claimed: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
    // per-thread local buffers, merged afterwards (no Mutex on the hot path)
    let nthreads = threads.max(1);
    let buckets: Vec<std::sync::Mutex<Vec<Node>>> = (0..frontier.len().min(nthreads).max(1))
        .map(|_| std::sync::Mutex::new(Vec::new()))
        .collect();
    parallel_for(frontier.len(), nthreads, |i| {
        let u = frontier[i];
        let mut local = Vec::new();
        for e in g.edge_range(u) {
            let w = g.adj[e];
            if f(u, e, w) && !claimed[w as usize].swap(true, Ordering::Relaxed) {
                local.push(w);
            }
        }
        if !local.is_empty() {
            buckets[i % buckets.len()].lock().unwrap().extend(local);
        }
    });
    let mut out = Vec::new();
    for b in buckets {
        out.extend(b.into_inner().unwrap());
    }
    out
}

/// Frontier filter: keep elements satisfying `pred`.
pub fn filter<F>(frontier: &[Node], pred: F) -> Vec<Node>
where
    F: Fn(Node) -> bool,
{
    frontier.iter().copied().filter(|&v| pred(v)).collect()
}

/// Frontier-based BFS.
pub fn bfs(g: &Graph, src: Node, threads: usize) -> Vec<i32> {
    let n = g.num_nodes();
    let level: Vec<AtomicI32> = (0..n).map(|_| AtomicI32::new(INF)).collect();
    level[src as usize].store(0, Ordering::Relaxed);
    let mut frontier = vec![src];
    let mut depth = 0;
    while !frontier.is_empty() {
        frontier = advance(g, &frontier, threads, |_, _, w| {
            level[w as usize]
                .compare_exchange(INF, depth + 1, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
        });
        depth += 1;
    }
    level.into_iter().map(|l| l.into_inner()).collect()
}

/// Frontier-based SSSP (delta-less Bellman-Ford over active vertices; the
/// paper notes Gunrock actually ships a two-level-priority Dijkstra — the
/// structural point, frontier-driven relaxation, is preserved).
pub fn sssp(g: &Graph, src: Node, threads: usize) -> Vec<i32> {
    let n = g.num_nodes();
    let dist: Vec<AtomicI32> = (0..n).map(|_| AtomicI32::new(INF)).collect();
    dist[src as usize].store(0, Ordering::Relaxed);
    let mut frontier = vec![src];
    while !frontier.is_empty() {
        frontier = advance(g, &frontier, threads, |u, e, w| {
            let nd = dist[u as usize].load(Ordering::Relaxed) + g.weights[e];
            nd < atomic_min_i32(&dist[w as usize], nd)
        });
    }
    dist.into_iter().map(|d| d.into_inner()).collect()
}

/// Gunrock-style PageRank: bulk-synchronous double-buffered compute over all
/// vertices each round (PR has a full frontier each iteration).
pub fn pagerank(g: &Graph, beta: f64, damping: f64, max_iter: usize, threads: usize) -> Vec<f64> {
    let n = g.num_nodes();
    let mut pr = vec![1.0 / n as f64; n];
    let mut nxt = vec![0.0f64; n];
    for _ in 0..max_iter {
        let diff = AtomicU64::new(0f64.to_bits());
        {
            let prr = &pr;
            let slots: Vec<std::sync::Mutex<&mut f64>> =
                nxt.iter_mut().map(std::sync::Mutex::new).collect();
            parallel_for(n, threads, |v| {
                let mut sum = 0.0;
                for &u in g.in_neighbors(v as Node) {
                    sum += prr[u as usize] / g.out_degree(u) as f64;
                }
                let val = (1.0 - damping) / n as f64 + damping * sum;
                atomic_add_f64(&diff, (val - prr[v]).abs());
                **slots[v].lock().unwrap() = val;
            });
        }
        std::mem::swap(&mut pr, &mut nxt);
        if f64::from_bits(diff.load(Ordering::Relaxed)) <= beta {
            break;
        }
    }
    pr
}

/// Betweenness centrality, frontier-based forward + dependency backward
/// (Gunrock ships BC; LonestarGPU does not — Table 3).
pub fn betweenness(g: &Graph, sources: &[Node], threads: usize) -> Vec<f64> {
    let n = g.num_nodes();
    let bc: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0f64.to_bits())).collect();
    for &s in sources {
        let level: Vec<AtomicI32> = (0..n).map(|_| AtomicI32::new(-1)).collect();
        let sigma: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0f64.to_bits())).collect();
        level[s as usize].store(0, Ordering::Relaxed);
        sigma[s as usize].store(1f64.to_bits(), Ordering::Relaxed);
        // forward: level-synchronous frontiers, accumulate sigma
        let mut frontiers: Vec<Vec<Node>> = vec![vec![s]];
        let mut depth = 0i32;
        loop {
            let cur = frontiers.last().unwrap();
            if cur.is_empty() {
                frontiers.pop();
                break;
            }
            let next = advance(g, cur, threads, |u, _, w| {
                let lw = &level[w as usize];
                let fresh = lw
                    .compare_exchange(-1, depth + 1, Ordering::Relaxed, Ordering::Relaxed)
                    .is_ok();
                if level[w as usize].load(Ordering::Relaxed) == depth + 1 {
                    atomic_add_f64(
                        &sigma[w as usize],
                        f64::from_bits(sigma[u as usize].load(Ordering::Relaxed)),
                    );
                }
                fresh
            });
            frontiers.push(next);
            depth += 1;
        }
        // backward: walk frontiers in reverse, accumulate delta
        let delta: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0f64.to_bits())).collect();
        for d in (0..frontiers.len()).rev() {
            let f = &frontiers[d];
            parallel_for(f.len(), threads, |i| {
                let v = f[i];
                let lv = level[v as usize].load(Ordering::Relaxed);
                let mut acc = 0.0;
                for &w in g.neighbors(v) {
                    if level[w as usize].load(Ordering::Relaxed) == lv + 1 {
                        let sw = f64::from_bits(sigma[w as usize].load(Ordering::Relaxed));
                        let sv = f64::from_bits(sigma[v as usize].load(Ordering::Relaxed));
                        let dw = f64::from_bits(delta[w as usize].load(Ordering::Relaxed));
                        acc += (sv / sw) * (1.0 + dw);
                    }
                }
                if acc != 0.0 {
                    atomic_add_f64(&delta[v as usize], acc);
                }
                if v != s {
                    atomic_add_f64(&bc[v as usize], acc);
                }
            });
        }
    }
    bc.into_iter().map(|b| f64::from_bits(b.into_inner())).collect()
}

/// Intersection-based TC (Gunrock's `intersection` operator): for each
/// directed edge u→w with u < w, two-pointer merge of sorted adjacency
/// lists counting common neighbors beyond w... counted per ordered triple
/// exactly once via u < w < c ordering.
pub fn triangle_count(g: &Graph, threads: usize) -> u64 {
    let n = g.num_nodes();
    let total = AtomicU64::new(0);
    parallel_for(n, threads, |u| {
        let u = u as Node;
        let nu = g.neighbors(u);
        let mut local = 0u64;
        for &w in nu.iter().rev().take_while(|&&w| w > u) {
            // count common neighbors c with c > w
            let nw = g.neighbors(w);
            let (mut i, mut j) = (0, 0);
            while i < nu.len() && j < nw.len() {
                let (a, b) = (nu[i], nw[j]);
                if a <= w {
                    i += 1;
                    continue;
                }
                if b <= w {
                    j += 1;
                    continue;
                }
                match a.cmp(&b) {
                    std::cmp::Ordering::Less => i += 1,
                    std::cmp::Ordering::Greater => j += 1,
                    std::cmp::Ordering::Equal => {
                        local += 1;
                        i += 1;
                        j += 1;
                    }
                }
            }
        }
        if local > 0 {
            total.fetch_add(local, Ordering::Relaxed);
        }
    });
    total.into_inner()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::reference;
    use crate::graph::generators::{preferential_attachment, rmat, road_grid};

    #[test]
    fn bfs_matches_reference() {
        let g = rmat("r", 300, 1200, 21);
        assert_eq!(bfs(&g, 3, 3), reference::bfs_levels(&g, 3));
    }

    #[test]
    fn sssp_matches_dijkstra() {
        for g in [rmat("r", 200, 800, 23), road_grid("g", 11, 13, 25)] {
            assert_eq!(sssp(&g, 0, 3), reference::dijkstra(&g, 0));
        }
    }

    #[test]
    fn pagerank_close_to_reference() {
        let g = preferential_attachment("p", 250, 4, 27);
        let a = pagerank(&g, 1e-10, 0.85, 100, 3);
        let b = reference::pagerank(&g, 1e-10, 0.85, 100);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn bc_matches_reference() {
        let g = preferential_attachment("p", 120, 3, 29);
        let srcs: Vec<u32> = vec![0, 5, 17];
        let a = betweenness(&g, &srcs, 3);
        let b = reference::betweenness(&g, &srcs);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!((x - y).abs() < 1e-6, "v{i}: {x} vs {y}");
        }
    }

    #[test]
    fn tc_matches_reference() {
        for g in [rmat("r", 256, 2000, 31), preferential_attachment("p", 300, 6, 33)] {
            assert_eq!(triangle_count(&g, 3), reference::triangle_count(&g));
        }
    }

    #[test]
    fn advance_dedups() {
        // diamond: two paths into node 3; frontier contains it once
        let mut b = crate::graph::csr::GraphBuilder::new(4);
        b.add_edge(0, 1, 1);
        b.add_edge(0, 2, 1);
        b.add_edge(1, 3, 1);
        b.add_edge(2, 3, 1);
        let g = b.build();
        let next = advance(&g, &[1, 2], 2, |_, _, _| true);
        assert_eq!(next, vec![3]);
    }
}
