//! Semantic analysis for the StarPlat DSL: scoped symbol table, property
//! registry, and type checking (paper §2.1's data types and constructs).

pub mod typeck;

pub use typeck::{check_function, TypedFunction};
