//! Type checker.
//!
//! Responsibilities:
//! - scoped variable typing (function params, decls, loop variables);
//! - the property registry: `propNode<T> p` (decl or param) makes `v.p`
//!   readable/writable at type T for any node-typed `v`; likewise propEdge;
//! - construct rules: filters and conditions are boolean; reduction
//!   operators (Table 1) match their operand types; `Min`/`Max` tuple
//!   assignments update properties; `fixedPoint` conditions reference a
//!   boolean node property.

use crate::dsl::ast::*;
use crate::dsl::diag::DslError;
use crate::dsl::token::Span;
use std::collections::HashMap;

#[derive(Clone, Debug, PartialEq)]
pub struct TypedFunction {
    pub func: Function,
    /// node property name -> value type
    pub node_props: HashMap<String, Type>,
    /// edge property name -> value type
    pub edge_props: HashMap<String, Type>,
    /// property names in declaration order (parameters first, then body
    /// declarations) — slot-assigning backends need a deterministic order,
    /// which the registry HashMaps cannot provide
    pub prop_order: Vec<String>,
    /// variable name -> type (flattened over all scopes; names are unique
    /// per function in well-formed StarPlat programs)
    pub vars: HashMap<String, Type>,
    /// name of the single Graph parameter
    pub graph: String,
    /// return type if the function returns a value
    pub returns: Option<Type>,
}

struct Ctx {
    scopes: Vec<HashMap<String, Type>>,
    node_props: HashMap<String, Type>,
    edge_props: HashMap<String, Type>,
    prop_order: Vec<String>,
    all_vars: HashMap<String, Type>,
    graph: Option<String>,
    returns: Option<Type>,
    /// true while inside a parallel (forall / BFS) region
    in_parallel: bool,
}

impl Ctx {
    fn lookup(&self, name: &str) -> Option<&Type> {
        self.scopes.iter().rev().find_map(|s| s.get(name))
    }
    fn declare(&mut self, name: &str, ty: Type, span: Span) -> Result<(), DslError> {
        if self.scopes.last().unwrap().contains_key(name) {
            return Err(DslError::at(span, &format!("`{name}` redeclared in the same scope")));
        }
        self.scopes.last_mut().unwrap().insert(name.to_string(), ty.clone());
        self.all_vars.insert(name.to_string(), ty);
        Ok(())
    }
    fn push(&mut self) {
        self.scopes.push(HashMap::new());
    }
    fn register_prop(&mut self, name: &str) {
        if !self.prop_order.iter().any(|p| p == name) {
            self.prop_order.push(name.to_string());
        }
    }
    fn pop(&mut self) {
        self.scopes.pop();
    }
}

/// Widening order for numeric types.
fn rank(t: &Type) -> Option<u8> {
    Some(match t {
        Type::Bool => 0,
        Type::Node => 1, // nodes coerce to integers (vertex ids)
        Type::Int => 1,
        Type::Long => 2,
        Type::Float => 3,
        Type::Double => 4,
        _ => return None,
    })
}

/// Can a value of `from` be stored into `to`? (numeric widening/narrowing is
/// allowed C-style; bools only into bools)
fn assignable(to: &Type, from: &Type) -> bool {
    if to == from {
        return true;
    }
    match (rank(to), rank(from)) {
        (Some(a), Some(b)) => {
            // bool is not implicitly numeric in the DSL
            !(a == 0) && !(b == 0) || (a == 0 && b == 0)
        }
        _ => false,
    }
}

fn unify_numeric(a: &Type, b: &Type, span: Span, what: &str) -> Result<Type, DslError> {
    match (rank(a), rank(b)) {
        (Some(ra), Some(rb)) if ra > 0 && rb > 0 => {
            Ok(if ra >= rb { a.clone() } else { b.clone() })
        }
        _ => Err(DslError::at(
            span,
            &format!("{what} requires numeric operands, got {} and {}", a.display(), b.display()),
        )),
    }
}

pub fn check_function(f: &Function) -> Result<TypedFunction, DslError> {
    let mut cx = Ctx {
        scopes: vec![HashMap::new()],
        node_props: HashMap::new(),
        edge_props: HashMap::new(),
        prop_order: Vec::new(),
        all_vars: HashMap::new(),
        graph: None,
        returns: None,
        in_parallel: false,
    };
    for p in &f.params {
        match &p.ty {
            Type::Graph => {
                if cx.graph.is_some() {
                    return Err(DslError::at(p.span, "multiple Graph parameters"));
                }
                cx.graph = Some(p.name.clone());
            }
            Type::PropNode(inner) => {
                cx.node_props.insert(p.name.clone(), (**inner).clone());
                cx.register_prop(&p.name);
            }
            Type::PropEdge(inner) => {
                cx.edge_props.insert(p.name.clone(), (**inner).clone());
                cx.register_prop(&p.name);
            }
            _ => {}
        }
        cx.declare(&p.name, p.ty.clone(), p.span)?;
    }
    let graph = cx
        .graph
        .clone()
        .ok_or_else(|| DslError::at(f.span, "function needs a Graph parameter"))?;
    check_block(&mut cx, &f.body)?;
    Ok(TypedFunction {
        func: f.clone(),
        node_props: cx.node_props,
        edge_props: cx.edge_props,
        prop_order: cx.prop_order,
        vars: cx.all_vars,
        graph,
        returns: cx.returns,
    })
}

fn check_block(cx: &mut Ctx, b: &Block) -> Result<(), DslError> {
    cx.push();
    for s in b {
        check_stmt(cx, s)?;
    }
    cx.pop();
    Ok(())
}

fn check_stmt(cx: &mut Ctx, s: &Stmt) -> Result<(), DslError> {
    match s {
        Stmt::Decl { ty, name, init, span } => {
            match ty {
                Type::PropNode(inner) => {
                    cx.node_props.insert(name.clone(), (**inner).clone());
                    cx.register_prop(name);
                }
                Type::PropEdge(inner) => {
                    cx.edge_props.insert(name.clone(), (**inner).clone());
                    cx.register_prop(name);
                }
                _ => {}
            }
            if let Some(e) = init {
                let et = type_expr(cx, e, *span)?;
                if !ty.is_prop() && !assignable(ty, &et) {
                    return Err(DslError::at(
                        *span,
                        &format!(
                            "cannot initialize {} `{}` from {}",
                            ty.display(),
                            name,
                            et.display()
                        ),
                    ));
                }
            }
            cx.declare(name, ty.clone(), *span)
        }
        Stmt::Assign { target, value, span } => {
            let tt = type_lvalue(cx, target, *span)?;
            // whole-property copy: `modified = modified_nxt` (both sides must
            // be properties of the same value type)
            if tt.is_prop() {
                if let Expr::Var(src) = value {
                    match cx.lookup(src) {
                        Some(st) if st == &tt => return Ok(()),
                        Some(st) => {
                            return Err(DslError::at(
                                *span,
                                &format!(
                                    "property copy type mismatch: {} vs {}",
                                    tt.display(),
                                    st.display()
                                ),
                            ))
                        }
                        None => {
                            return Err(DslError::at(*span, &format!("unknown variable `{src}`")))
                        }
                    }
                }
                return Err(DslError::at(
                    *span,
                    "property copy requires a property name on the right",
                ));
            }
            let vt = type_expr(cx, value, *span)?;
            if !assignable(&tt, &vt) {
                return Err(DslError::at(
                    *span,
                    &format!("cannot assign {} to {}", vt.display(), tt.display()),
                ));
            }
            Ok(())
        }
        Stmt::Reduce { target, op, value, span } => {
            let tt = type_lvalue(cx, target, *span)?;
            let vt = type_expr(cx, value, *span)?;
            match op {
                ReduceOp::Add | ReduceOp::Mul | ReduceOp::Count => {
                    unify_numeric(&tt, &vt, *span, &format!("reduction `{}`", op.symbol()))?;
                }
                ReduceOp::And | ReduceOp::Or => {
                    if tt != Type::Bool || vt != Type::Bool {
                        return Err(DslError::at(
                            *span,
                            &format!("reduction `{}` requires bool operands", op.symbol()),
                        ));
                    }
                }
            }
            Ok(())
        }
        Stmt::MinMaxAssign { target, compare, extra, span, .. } => {
            let tt = type_lvalue(cx, target, *span)?;
            let ct = type_expr(cx, compare, *span)?;
            unify_numeric(&tt, &ct, *span, "Min/Max construct")?;
            for (t, v) in extra {
                let et = type_lvalue(cx, t, *span)?;
                let evt = type_expr(cx, v, *span)?;
                if !assignable(&et, &evt) {
                    return Err(DslError::at(
                        *span,
                        &format!("cannot assign {} to {}", evt.display(), et.display()),
                    ));
                }
            }
            Ok(())
        }
        Stmt::AttachNodeProperty { graph, inits, span } => {
            if cx.lookup(graph) != Some(&Type::Graph) {
                return Err(DslError::at(*span, &format!("`{graph}` is not a Graph")));
            }
            for (prop, e) in inits {
                let pt = cx
                    .node_props
                    .get(prop)
                    .or_else(|| cx.edge_props.get(prop))
                    .cloned()
                    .ok_or_else(|| {
                        DslError::at(
                            *span,
                            &format!("unknown property `{prop}` in attachNodeProperty"),
                        )
                    })?;
                let et = type_expr(cx, e, *span)?;
                if et != Type::Bool && pt == Type::Bool {
                    return Err(DslError::at(*span, &format!("property `{prop}` is bool")));
                }
                if pt != Type::Bool && !assignable(&pt, &et) {
                    return Err(DslError::at(
                        *span,
                        &format!(
                            "cannot initialize {} property `{prop}` from {}",
                            pt.display(),
                            et.display()
                        ),
                    ));
                }
            }
            Ok(())
        }
        Stmt::For { iter, body, parallel, span } => {
            cx.push();
            check_iter(cx, iter, *span)?;
            let was = cx.in_parallel;
            cx.in_parallel |= *parallel;
            for st in body {
                check_stmt(cx, st)?;
            }
            cx.in_parallel = was;
            cx.pop();
            Ok(())
        }
        Stmt::IterateBFS { var, graph, from, body, reverse, span } => {
            if cx.lookup(graph) != Some(&Type::Graph) {
                return Err(DslError::at(*span, &format!("`{graph}` is not a Graph")));
            }
            match cx.lookup(from) {
                Some(Type::Node) => {}
                _ => {
                    return Err(DslError::at(
                        *span,
                        &format!("BFS source `{from}` must be a node"),
                    ))
                }
            }
            cx.push();
            cx.declare(var, Type::Node, *span)?;
            let was = cx.in_parallel;
            cx.in_parallel = true;
            for st in body {
                check_stmt(cx, st)?;
            }
            if let Some((cond, rbody)) = reverse {
                let ct = type_expr(cx, cond, *span)?;
                if ct != Type::Bool {
                    return Err(DslError::at(*span, "iterateInReverse filter must be boolean"));
                }
                for st in rbody {
                    check_stmt(cx, st)?;
                }
            }
            cx.in_parallel = was;
            cx.pop();
            Ok(())
        }
        Stmt::FixedPoint { var, cond, body, span } => {
            match cx.lookup(var) {
                Some(Type::Bool) => {}
                _ => {
                    return Err(DslError::at(
                        *span,
                        &format!("fixedPoint variable `{var}` must be a declared bool"),
                    ))
                }
            }
            // The convergence expression references a boolean node property
            // (paper §2.1: "a boolean expression on node-properties").
            let mut prop_ok = false;
            let mut probe = |name: &str| {
                if cx.node_props.get(name) == Some(&Type::Bool) {
                    prop_ok = true;
                }
            };
            cond.visit_vars(&mut probe);
            if !prop_ok {
                return Err(DslError::at(
                    *span,
                    "fixedPoint condition must reference a boolean node property",
                ));
            }
            check_block(cx, body)
        }
        Stmt::DoWhile { body, cond, span } | Stmt::While { cond, body, span } => {
            check_block(cx, body)?;
            let ct = type_expr(cx, cond, *span)?;
            if ct != Type::Bool {
                return Err(DslError::at(*span, "loop condition must be boolean"));
            }
            Ok(())
        }
        Stmt::If { cond, then, els, span } => {
            let ct = type_expr(cx, cond, *span)?;
            if ct != Type::Bool {
                return Err(DslError::at(*span, "if condition must be boolean"));
            }
            check_block(cx, then)?;
            if let Some(e) = els {
                check_block(cx, e)?;
            }
            Ok(())
        }
        Stmt::Return { value, span } => {
            let t = type_expr(cx, value, *span)?;
            cx.returns = Some(t);
            Ok(())
        }
    }
}

fn check_iter(cx: &mut Ctx, iter: &Iterator_, span: Span) -> Result<(), DslError> {
    match &iter.source {
        IterSource::Nodes { graph }
        | IterSource::Neighbors { graph, .. }
        | IterSource::NodesTo { graph, .. } => {
            if cx.lookup(graph) != Some(&Type::Graph) {
                return Err(DslError::at(span, &format!("`{graph}` is not a Graph")));
            }
            if let IterSource::Neighbors { of, .. } | IterSource::NodesTo { of, .. } = &iter.source
            {
                match cx.lookup(of) {
                    Some(Type::Node) => {}
                    _ => {
                        return Err(DslError::at(
                            span,
                            &format!("neighbor iteration over non-node `{of}`"),
                        ))
                    }
                }
            }
        }
        IterSource::Set { set } => match cx.lookup(set) {
            Some(Type::SetN(_)) => {}
            _ => return Err(DslError::at(span, &format!("`{set}` is not a SetN"))),
        },
    }
    cx.declare(&iter.var, Type::Node, span)?;
    if let Some(f) = &iter.filter {
        let ft = type_expr(cx, f, span)?;
        if ft != Type::Bool {
            return Err(DslError::at(span, "filter expression must be boolean"));
        }
    }
    Ok(())
}

fn type_lvalue(cx: &Ctx, lv: &LValue, span: Span) -> Result<Type, DslError> {
    match lv {
        LValue::Var(v) => {
            let t = cx
                .lookup(v)
                .ok_or_else(|| DslError::at(span, &format!("unknown variable `{v}`")))?;
            // Assigning to a propNode variable means whole-property copy.
            match t {
                Type::PropNode(_) | Type::PropEdge(_) => Ok(t.clone()),
                _ => Ok(t.clone()),
            }
        }
        LValue::Prop { obj, prop } => prop_type(cx, obj, prop, span),
    }
}

fn prop_type(cx: &Ctx, obj: &str, prop: &str, span: Span) -> Result<Type, DslError> {
    let ot = cx
        .lookup(obj)
        .ok_or_else(|| DslError::at(span, &format!("unknown variable `{obj}`")))?;
    match ot {
        Type::Node => cx.node_props.get(prop).cloned().ok_or_else(|| {
            DslError::at(span, &format!("unknown node property `{prop}` on `{obj}`"))
        }),
        Type::Edge => cx.edge_props.get(prop).cloned().ok_or_else(|| {
            DslError::at(span, &format!("unknown edge property `{prop}` on `{obj}`"))
        }),
        other => Err(DslError::at(
            span,
            &format!("`{obj}` has type {}, which has no properties", other.display()),
        )),
    }
}

fn type_expr(cx: &Ctx, e: &Expr, span: Span) -> Result<Type, DslError> {
    Ok(match e {
        Expr::IntLit(_) => Type::Int,
        Expr::FloatLit(_) => Type::Float,
        Expr::BoolLit(_) => Type::Bool,
        Expr::Inf => Type::Int, // sentinel; assignable to any numeric
        Expr::Var(v) => {
            let t = cx
                .lookup(v)
                .cloned()
                .ok_or_else(|| DslError::at(span, &format!("unknown variable `{v}`")))?;
            // A property used as a value denotes the current element's value
            // (StarPlat filter / fixedPoint idiom: `filter(modified == True)`).
            match t {
                Type::PropNode(inner) | Type::PropEdge(inner) => *inner,
                other => other,
            }
        }
        Expr::Prop { obj, prop } => prop_type(cx, obj, prop, span)?,
        Expr::Call { recv, name, args } => {
            return type_call(cx, recv.as_deref(), name, args, span)
        }
        Expr::Unary { op, expr } => {
            let t = type_expr(cx, expr, span)?;
            match op {
                UnOp::Not => {
                    // `!modified` over a bool node property is allowed in
                    // fixedPoint conditions.
                    if t == Type::Bool || t == Type::PropNode(Box::new(Type::Bool)) {
                        Type::Bool
                    } else {
                        return Err(DslError::at(span, "`!` requires a boolean"));
                    }
                }
                UnOp::Neg => unify_numeric(&t, &Type::Int, span, "negation")?,
            }
        }
        Expr::Binary { op, lhs, rhs } => {
            let lt = type_expr(cx, lhs, span)?;
            let rt = type_expr(cx, rhs, span)?;
            if op.is_logical() {
                if lt != Type::Bool || rt != Type::Bool {
                    return Err(DslError::at(
                        span,
                        &format!("`{}` requires boolean operands", op.symbol()),
                    ));
                }
                Type::Bool
            } else if op.is_comparison() {
                // == / != also compare booleans (e.g. `modified == True`)
                let bool_eq = matches!(op, BinOp::Eq | BinOp::Ne)
                    && lt == Type::Bool
                    && rt == Type::Bool;
                if !bool_eq {
                    unify_numeric(&lt, &rt, span, &format!("comparison `{}`", op.symbol()))?;
                }
                Type::Bool
            } else {
                unify_numeric(&lt, &rt, span, &format!("operator `{}`", op.symbol()))?
            }
        }
    })
}

fn type_call(
    cx: &Ctx,
    recv: Option<&str>,
    name: &str,
    args: &[Expr],
    span: Span,
) -> Result<Type, DslError> {
    let argc = args.len();
    match (recv, name, argc) {
        (None, "abs", 1) => type_expr(cx, &args[0], span),
        (Some(r), "num_nodes", 0) | (Some(r), "num_edges", 0) => {
            if cx.lookup(r) != Some(&Type::Graph) {
                return Err(DslError::at(span, &format!("`{r}` is not a Graph")));
            }
            Ok(Type::Int)
        }
        (Some(r), "minWt", 0) | (Some(r), "maxWt", 0) => {
            if cx.lookup(r) != Some(&Type::Graph) {
                return Err(DslError::at(span, &format!("`{r}` is not a Graph")));
            }
            Ok(Type::Int)
        }
        (Some(r), "is_an_edge", 2) => {
            if cx.lookup(r) != Some(&Type::Graph) {
                return Err(DslError::at(span, &format!("`{r}` is not a Graph")));
            }
            Ok(Type::Bool)
        }
        (Some(r), "get_edge", 2) => {
            if cx.lookup(r) != Some(&Type::Graph) {
                return Err(DslError::at(span, &format!("`{r}` is not a Graph")));
            }
            Ok(Type::Edge)
        }
        (Some(r), "outDegree", 0) | (Some(r), "inDegree", 0) => {
            match cx.lookup(r) {
                Some(Type::Node) => Ok(Type::Int),
                _ => Err(DslError::at(span, &format!("`{r}.{name}()` requires a node"))),
            }
        }
        _ => Err(DslError::at(
            span,
            &format!(
                "unknown builtin `{}{name}/{argc}`",
                recv.map(|r| format!("{r}.")).unwrap_or_default()
            ),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parser::parse;

    fn check(src: &str) -> Result<TypedFunction, DslError> {
        let fns = parse(src).unwrap();
        check_function(&fns[0])
    }

    #[test]
    fn shipped_programs_typecheck() {
        for p in ["bc.sp", "pr.sp", "sssp.sp", "tc.sp", "cc.sp", "bfs.sp"] {
            let path =
                std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("dsl_programs").join(p);
            let src = std::fs::read_to_string(&path).unwrap();
            let fns = parse(&src).unwrap();
            check_function(&fns[0]).unwrap_or_else(|e| panic!("{p}: {e}"));
        }
    }

    #[test]
    fn registers_props_from_params_and_decls() {
        let tf = check(
            "function f(Graph g, propNode<float> BC) {
               propNode<int> lvl;
               g.attachNodeProperty(BC = 0, lvl = 0);
             }",
        )
        .unwrap();
        assert_eq!(tf.node_props.get("BC"), Some(&Type::Float));
        assert_eq!(tf.node_props.get("lvl"), Some(&Type::Int));
        assert_eq!(tf.graph, "g");
    }

    #[test]
    fn prop_order_is_declaration_order() {
        let tf = check(
            "function f(Graph g, propNode<float> BC, propEdge<int> w) {
               propNode<int> lvl;
               propNode<bool> seen;
               g.attachNodeProperty(BC = 0, lvl = 0, seen = False);
             }",
        )
        .unwrap();
        assert_eq!(tf.prop_order, vec!["BC", "w", "lvl", "seen"]);
    }

    #[test]
    fn rejects_unknown_property() {
        let r = check("function f(Graph g) { g.attachNodeProperty(nope = 0); }");
        assert!(r.is_err());
    }

    #[test]
    fn rejects_bool_arith() {
        let r = check("function f(Graph g) { bool b = True; float x = b + 1; }");
        assert!(r.is_err());
    }

    #[test]
    fn rejects_nonbool_filter() {
        let r = check(
            "function f(Graph g) { forall (v in g.nodes().filter(v + 1)) { } }",
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_bad_fixedpoint_var() {
        let r = check(
            "function f(Graph g, propNode<bool> m) {
               fixedPoint until (nothere: !m) { }
             }",
        );
        assert!(r.is_err());
    }

    #[test]
    fn fixedpoint_needs_bool_prop() {
        let r = check(
            "function f(Graph g, propNode<int> m) {
               bool fin = False;
               fixedPoint until (fin: !m) { }
             }",
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_and_reduce_on_numeric() {
        let r = check("function f(Graph g) { int x = 1; x &&= True; }");
        assert!(r.is_err());
    }

    #[test]
    fn node_coerces_to_int() {
        let tf = check(
            "function f(Graph g, propNode<int> comp) {
               forall (v in g.nodes()) { v.comp = v; }
             }",
        );
        assert!(tf.is_ok());
    }

    #[test]
    fn redeclaration_rejected() {
        let r = check("function f(Graph g) { int x = 1; int x = 2; }");
        assert!(r.is_err());
    }

    #[test]
    fn unknown_builtin_rejected() {
        let r = check("function f(Graph g) { int x = g.frobnicate(); }");
        assert!(r.is_err());
    }

    #[test]
    fn return_type_captured() {
        let tf = check("function f(Graph g) { long c = 0; return c; }").unwrap();
        assert_eq!(tf.returns, Some(Type::Long));
    }
}
