//! Fault-tolerant in-process execution service.
//!
//! One [`Service`] owns a registry of immutable CSR graphs (shared as
//! `Arc<Graph>` across concurrent requests), a registry of parsed +
//! type-checked DSL programs, and a bounded-admission dispatch path onto the
//! CPU interpreter. Robustness properties, each pinned by
//! `tests/service_robustness.rs`:
//!
//! - **validated registration**: [`Graph::validate`] gates every graph, so a
//!   corrupt CSR is rejected at the door instead of crashing a sweep later;
//!   programs must parse and type-check before they are runnable;
//! - **admission control**: at most `max_in_flight` requests execute at
//!   once — excess load fails fast with [`ServiceError::Overloaded`] instead
//!   of queueing unboundedly;
//! - **isolation**: the interpreter runs under `catch_unwind`, so a panic
//!   (real or injected via [`crate::util::fault`]) poisons only its own
//!   request — the graphs, programs, cache, and in-flight accounting stay
//!   healthy and the next request succeeds;
//! - **deadlines / cancellation**: each request gets a [`CancelToken`]
//!   (caller-supplied or fresh) with the request or service-default deadline
//!   applied; cooperative polls inside the interpreter surface
//!   [`ExecError::Cancelled`] / [`ExecError::DeadlineExceeded`];
//! - **result cache**: completed outputs are memoised by
//!   (graph id, graph version, program hash, argument fingerprint) with
//!   FIFO eviction; the version is bumped on re-registration so a replaced
//!   CSR never serves the old graph's cached results; capacity 0 disables
//!   caching (the stress suite does this so every request actually
//!   executes).
//!
//! On top of the solo path sits **batched multi-source execution**
//! (`tests/service_batch.rs`): [`Service::execute_batch`] groups requests
//! that target the same (graph, version, program) and differ only in the
//! program's single `Node` parameter, and runs each group through
//! [`interp::batch::run_batch_with_opts`] — one shared CSR traversal
//! carrying up to 64 roots per wave. Results fan back out as ordinary
//! per-root [`Output`]s cached under the same per-request keys the solo
//! path uses, so later solo requests hit them. A configured
//! [`ServiceConfig::batch_window`] makes the merging transparent:
//! [`Service::execute`] holds an eligible cache-missing request open for
//! the window, and any same-group requests arriving meanwhile coalesce into
//! the leader's merged run instead of traversing the graph again.

use crate::backends::interp::env::Val;
use crate::backends::interp::{self, Args, ExecError, ExecOpts, Output};
use crate::dsl::ast::Type;
use crate::dsl::parse;
use crate::graph::csr::{Graph, Node};
use crate::sema::{check_function, TypedFunction};
use crate::util::cancel::CancelToken;
use crate::util::fault::FaultPlan;
use crate::util::pool::panic_message;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock};
use std::time::Duration;

// ---------------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------------

/// Typed failure classes of the service surface. Everything a request can
/// do wrong — and everything the runtime can do to a request — has a
/// variant; nothing escapes as a panic.
#[derive(Clone, Debug, PartialEq, thiserror::Error)]
pub enum ServiceError {
    /// Admission control: `max_in_flight` requests were already executing.
    #[error("service overloaded: {limit} requests already in flight")]
    Overloaded { limit: usize },
    /// No graph registered under this id.
    #[error("unknown graph `{0}`")]
    UnknownGraph(String),
    /// No program registered under this name.
    #[error("unknown program `{0}`")]
    UnknownProgram(String),
    /// The graph failed CSR integrity validation at registration.
    #[error("graph `{id}` failed validation: {reason}")]
    InvalidGraph { id: String, reason: String },
    /// The program failed to parse or type-check at registration.
    #[error("program `{name}` rejected: {reason}")]
    InvalidProgram { name: String, reason: String },
    /// The run terminated with a typed interpreter error (cancelled,
    /// deadline exceeded, worker panic, injected fault).
    #[error(transparent)]
    Exec(#[from] ExecError),
    /// Any other execution failure (e.g. a missing argument binding).
    #[error("execution failed: {0}")]
    Failed(String),
}

// ---------------------------------------------------------------------------
// Configuration and requests
// ---------------------------------------------------------------------------

/// Service-wide knobs. [`Default`] gives a permissive production shape;
/// tests shrink the limits to force each failure mode deterministically.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// concurrent-request ceiling (admission control)
    pub max_in_flight: usize,
    /// deadline applied to requests that do not carry their own
    pub default_deadline: Option<Duration>,
    /// interpreter worker threads per request (0 = pool default)
    pub threads: usize,
    /// result-cache entries (FIFO eviction); 0 disables caching
    pub cache_capacity: usize,
    /// service-wide fault plan for requests that do not carry their own
    /// (`None` leaves the `STARPLAT_FAULT` environment fallback in effect)
    pub fault: Option<FaultPlan>,
    /// lane width for merged runs (1..=64); 0 defers to the interpreter's
    /// `STARPLAT_BATCH` default
    pub batch_width: usize,
    /// transparent request coalescing: an eligible cache-missing
    /// [`Service::execute`] call waits this long for same-group requests to
    /// arrive, then runs them all as one batched traversal. `None` (the
    /// default) dispatches immediately, exactly the pre-batching behavior.
    pub batch_window: Option<Duration>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_in_flight: 64,
            default_deadline: None,
            threads: 0,
            cache_capacity: 256,
            fault: None,
            batch_width: 0,
            batch_window: None,
        }
    }
}

/// One execution request against registered state.
#[derive(Clone, Debug, Default)]
pub struct Request {
    /// id of a registered graph
    pub graph: String,
    /// name of a registered program
    pub program: String,
    /// scalar / set argument bindings
    pub args: Args,
    /// per-request deadline (overrides the service default)
    pub deadline: Option<Duration>,
    /// caller-held token for explicit cancellation
    pub cancel: Option<CancelToken>,
    /// per-request fault plan; callers running many requests under one plan
    /// should re-scope it per request with [`FaultPlan::salted`], and oracle
    /// runs should pass [`FaultPlan::off`] to defeat the env fallback
    pub fault: Option<FaultPlan>,
}

// ---------------------------------------------------------------------------
// Statistics
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
struct StatCells {
    completed: AtomicU64,
    cache_hits: AtomicU64,
    rejected: AtomicU64,
    cancelled: AtomicU64,
    deadline_exceeded: AtomicU64,
    panics: AtomicU64,
    faults: AtomicU64,
    failed: AtomicU64,
    fallbacks: AtomicU64,
    batched_roots: AtomicU64,
    coalesced: AtomicU64,
}

/// Point-in-time copy of the service counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    /// requests that returned an [`Output`]
    pub completed: u64,
    /// requests served from the result cache (subset of `completed`)
    pub cache_hits: u64,
    /// requests refused by admission control
    pub rejected: u64,
    /// requests ended by explicit cancellation
    pub cancelled: u64,
    /// requests ended by deadline expiry
    pub deadline_exceeded: u64,
    /// requests ended by a (caught) worker panic
    pub panics: u64,
    /// requests ended by a typed injected fault
    pub faults: u64,
    /// requests ended by any other execution error
    pub failed: u64,
    /// sparse→dense schedule fallbacks summed over completed runs
    pub fallbacks: u64,
    /// unique roots dispatched through merged (multi-source) runs
    pub batched_roots: u64,
    /// requests that joined another request's coalescing window instead of
    /// dispatching their own run
    pub coalesced: u64,
}

// ---------------------------------------------------------------------------
// Service
// ---------------------------------------------------------------------------

#[derive(Clone)]
struct ProgramEntry {
    tf: Arc<TypedFunction>,
    /// FNV-1a of the source text: the cache's program identity
    hash: u64,
    /// the program's unique `Node` parameter, when it has exactly one —
    /// the axis [`Service::execute_batch`] merges requests along
    root_param: Option<String>,
}

/// (graph id, graph version, program hash, argument fingerprint). The
/// version is bumped every time an id is re-registered, so entries computed
/// against a replaced CSR can never be served for the new graph (they age
/// out via FIFO eviction).
type CacheKey = (String, u64, u64, u64);

/// Same shape as [`CacheKey`], but the argument fingerprint excludes the
/// root parameter: requests sharing a group key differ only in root and may
/// merge into one batched run.
type GroupKey = (String, u64, u64, u64);

#[derive(Default)]
struct CacheInner {
    map: HashMap<CacheKey, Arc<Output>>,
    /// insertion order for FIFO eviction
    order: VecDeque<CacheKey>,
}

/// Rendezvous for one coalescing window: the leader collects members while
/// it sleeps, runs the merged batch, and publishes per-member results.
struct GatherState {
    /// (root, per-request cache key) per member; index 0 is the leader
    members: Vec<(Node, CacheKey)>,
    /// set once the leader snapshots `members` — late arrivals must open a
    /// new gather instead of joining one that stopped listening
    closed: bool,
    /// per-member results, aligned with `members`; publication wakes the
    /// condvar
    results: Option<Vec<Result<Arc<Output>, ServiceError>>>,
}

struct Gather {
    state: Mutex<GatherState>,
    cv: Condvar,
}

/// The in-process execution service. Cheap to share: every method takes
/// `&self`, so one instance serves many threads.
pub struct Service {
    cfg: ServiceConfig,
    /// graph per id plus its registration version (monotonic per id)
    graphs: RwLock<HashMap<String, (Arc<Graph>, u64)>>,
    programs: RwLock<HashMap<String, ProgramEntry>>,
    cache: Mutex<CacheInner>,
    /// open coalescing windows by group key
    windows: Mutex<HashMap<GroupKey, Arc<Gather>>>,
    in_flight: AtomicUsize,
    stats: StatCells,
}

/// RAII in-flight slot: decrements on every exit path, including panics
/// that unwind past `execute` itself.
struct InFlightSlot<'a>(&'a AtomicUsize);

impl Drop for InFlightSlot<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Poison-tolerant lock helpers: no user code ever runs under these locks
/// (panics are caught at the interpreter boundary), but a robustness layer
/// should not turn a poisoned mutex into a second panic either.
fn lock_mutex<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn read_lock<T>(l: &RwLock<T>) -> std::sync::RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

fn write_lock<T>(l: &RwLock<T>) -> std::sync::RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| e.into_inner())
}

/// The request's root vertex, when the bound value is representable as a
/// `Node`. Anything else (missing, wrong type, negative, oversized) makes
/// the request ineligible for merging — the solo path then surfaces exactly
/// the error it always did.
fn root_of(root_param: &str, args: &Args) -> Option<Node> {
    match args.scalars.get(root_param) {
        Some(Val::I(x)) if *x >= 0 && *x <= u32::MAX as i64 => Some(*x as Node),
        _ => None,
    }
}

impl Service {
    pub fn new(cfg: ServiceConfig) -> Service {
        Service {
            cfg,
            graphs: RwLock::new(HashMap::new()),
            programs: RwLock::new(HashMap::new()),
            cache: Mutex::new(CacheInner::default()),
            windows: Mutex::new(HashMap::new()),
            in_flight: AtomicUsize::new(0),
            stats: StatCells::default(),
        }
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Register a graph under `id` after CSR integrity validation.
    /// Re-registering an id replaces the graph (in-flight requests keep
    /// their `Arc` to the old one) and bumps the id's version, so cached
    /// results computed against the old CSR are never served for the new
    /// one.
    pub fn register_graph(&self, id: &str, g: Graph) -> Result<(), ServiceError> {
        g.validate().map_err(|v| ServiceError::InvalidGraph {
            id: id.to_string(),
            reason: v.to_string(),
        })?;
        let mut graphs = write_lock(&self.graphs);
        let version = graphs.get(id).map_or(0, |(_, v)| v + 1);
        graphs.insert(id.to_string(), (Arc::new(g), version));
        Ok(())
    }

    /// Parse + type-check `src` and register it under `name`.
    pub fn register_program(&self, name: &str, src: &str) -> Result<(), ServiceError> {
        let reject = |reason: String| ServiceError::InvalidProgram {
            name: name.to_string(),
            reason,
        };
        let fns = parse(src).map_err(|e| reject(e.to_string()))?;
        let f = fns.first().ok_or_else(|| reject("no function in source".to_string()))?;
        let tf = check_function(f).map_err(|e| reject(e.to_string()))?;
        let mut node_params = tf.func.params.iter().filter(|p| matches!(p.ty, Type::Node));
        let root_param = match (node_params.next(), node_params.next()) {
            (Some(p), None) => Some(p.name.clone()),
            _ => None,
        };
        let entry = ProgramEntry { tf: Arc::new(tf), hash: fnv1a(src.as_bytes()), root_param };
        write_lock(&self.programs).insert(name.to_string(), entry);
        Ok(())
    }

    pub fn stats(&self) -> StatsSnapshot {
        let s = &self.stats;
        StatsSnapshot {
            completed: s.completed.load(Ordering::Relaxed),
            cache_hits: s.cache_hits.load(Ordering::Relaxed),
            rejected: s.rejected.load(Ordering::Relaxed),
            cancelled: s.cancelled.load(Ordering::Relaxed),
            deadline_exceeded: s.deadline_exceeded.load(Ordering::Relaxed),
            panics: s.panics.load(Ordering::Relaxed),
            faults: s.faults.load(Ordering::Relaxed),
            failed: s.failed.load(Ordering::Relaxed),
            fallbacks: s.fallbacks.load(Ordering::Relaxed),
            batched_roots: s.batched_roots.load(Ordering::Relaxed),
            coalesced: s.coalesced.load(Ordering::Relaxed),
        }
    }

    /// Classify an interpreter error without touching the counters (merged
    /// runs count once per affected request, not once per unique root).
    fn classify(&self, e: &anyhow::Error) -> ServiceError {
        match e.downcast_ref::<ExecError>() {
            Some(te) => te.clone().into(),
            None => ServiceError::Failed(format!("{e:#}")),
        }
    }

    /// Bump the stats cell a terminal error belongs to. Registration and
    /// admission errors are counted at their own sites.
    fn count_error(&self, err: &ServiceError) {
        let cell = match err {
            ServiceError::Exec(ExecError::Cancelled) => &self.stats.cancelled,
            ServiceError::Exec(ExecError::DeadlineExceeded) => &self.stats.deadline_exceeded,
            ServiceError::Exec(ExecError::WorkerPanic(_)) => &self.stats.panics,
            ServiceError::Exec(ExecError::Fault(_)) => &self.stats.faults,
            _ => &self.stats.failed,
        };
        cell.fetch_add(1, Ordering::Relaxed);
    }

    /// Memoise `out` under `key` with FIFO eviction (no-op when caching is
    /// disabled).
    fn cache_insert(&self, key: &CacheKey, out: Arc<Output>) {
        if self.cfg.cache_capacity == 0 {
            return;
        }
        let mut c = lock_mutex(&self.cache);
        if !c.map.contains_key(key) {
            if c.order.len() >= self.cfg.cache_capacity {
                if let Some(evict) = c.order.pop_front() {
                    c.map.remove(&evict);
                }
            }
            c.order.push_back(key.clone());
        }
        c.map.insert(key.clone(), out);
    }

    /// Execute one request. Never panics: interpreter panics are caught at
    /// this boundary and surfaced as [`ExecError::WorkerPanic`].
    ///
    /// When [`ServiceConfig::batch_window`] is set and the request is
    /// merge-eligible (no per-request deadline/cancel/fault, program has a
    /// unique `Node` parameter bound to a valid root), a cache miss holds
    /// the request open for the window so concurrent same-group requests
    /// coalesce into one batched traversal.
    pub fn execute(&self, req: &Request) -> Result<Arc<Output>, ServiceError> {
        // ---- admission: claim a slot before doing any work ----
        let limit = self.cfg.max_in_flight;
        let prev = self.in_flight.fetch_add(1, Ordering::AcqRel);
        let _slot = InFlightSlot(&self.in_flight);
        if prev >= limit {
            self.stats.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(ServiceError::Overloaded { limit });
        }

        // ---- resolve registered state (Arc clones; no locks held later) ----
        let (graph, graph_version) = read_lock(&self.graphs)
            .get(&req.graph)
            .cloned()
            .ok_or_else(|| ServiceError::UnknownGraph(req.graph.clone()))?;
        let entry = read_lock(&self.programs)
            .get(&req.program)
            .cloned()
            .ok_or_else(|| ServiceError::UnknownProgram(req.program.clone()))?;

        // ---- result cache ----
        let key: CacheKey =
            (req.graph.clone(), graph_version, entry.hash, fingerprint(&req.args));
        if self.cfg.cache_capacity > 0 {
            if let Some(hit) = lock_mutex(&self.cache).map.get(&key).cloned() {
                self.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
                self.stats.completed.fetch_add(1, Ordering::Relaxed);
                return Ok(hit);
            }
        }

        // ---- transparent coalescing window ----
        if let Some(window) = self.cfg.batch_window {
            if req.deadline.is_none() && req.cancel.is_none() && req.fault.is_none() {
                if let Some(rp) = entry.root_param.clone() {
                    if let Some(root) = root_of(&rp, &req.args) {
                        return self.execute_coalesced(
                            req, window, &graph, graph_version, &entry, &rp, root, key,
                        );
                    }
                }
            }
        }

        // ---- cancellation / deadline ----
        let token = req.cancel.clone().unwrap_or_default();
        if let Some(d) = req.deadline.or(self.cfg.default_deadline) {
            token.set_deadline_in(d);
        }
        let opts = ExecOpts {
            threads: self.cfg.threads,
            cancel: Some(token),
            fault: req.fault.or(self.cfg.fault),
            ..ExecOpts::default()
        };

        // ---- dispatch; panics stop here ----
        let ran = catch_unwind(AssertUnwindSafe(|| {
            interp::run_with_opts(&entry.tf, &graph, &req.args, opts)
        }));
        let out = match ran {
            Err(payload) => {
                self.stats.panics.fetch_add(1, Ordering::Relaxed);
                return Err(ExecError::WorkerPanic(panic_message(payload)).into());
            }
            Ok(Err(e)) => {
                let err = self.classify(&e);
                self.count_error(&err);
                return Err(err);
            }
            Ok(Ok(out)) => out,
        };

        self.stats.completed.fetch_add(1, Ordering::Relaxed);
        self.stats.fallbacks.fetch_add(out.stats.fallbacks, Ordering::Relaxed);
        let out = Arc::new(out);
        self.cache_insert(&key, out.clone());
        Ok(out)
    }

    /// Execute many requests, merging the ones that differ only in root.
    ///
    /// Requests that cannot merge — unknown graph/program, per-request
    /// deadline/cancel/fault, no unique `Node` parameter, root not a valid
    /// `Node` — run through [`Service::execute`] individually and keep its
    /// exact semantics. Merge-eligible requests group by (graph, version,
    /// program hash, non-root arguments); each group claims **one**
    /// in-flight slot, serves members from the result cache first, runs the
    /// remaining unique roots as one batched traversal, and fans the
    /// outputs back out (cached under each member's own request key).
    /// Results align positionally with `reqs`.
    pub fn execute_batch(&self, reqs: &[Request]) -> Vec<Result<Arc<Output>, ServiceError>> {
        let mut results: Vec<Option<Result<Arc<Output>, ServiceError>>> =
            reqs.iter().map(|_| None).collect();
        struct Group {
            graph: Arc<Graph>,
            entry: ProgramEntry,
            root_param: String,
            base_args: Args,
            /// (request index, root, per-request cache key)
            members: Vec<(usize, Node, CacheKey)>,
        }
        let mut order: Vec<GroupKey> = Vec::new();
        let mut groups: HashMap<GroupKey, Group> = HashMap::new();
        for (i, req) in reqs.iter().enumerate() {
            let eligible = req.deadline.is_none() && req.cancel.is_none() && req.fault.is_none();
            let resolved = if eligible {
                let graph = read_lock(&self.graphs).get(&req.graph).cloned();
                let entry = read_lock(&self.programs).get(&req.program).cloned();
                match (graph, entry) {
                    (Some((graph, version)), Some(entry)) => match entry.root_param.clone() {
                        Some(rp) => {
                            root_of(&rp, &req.args).map(|root| (graph, version, entry, rp, root))
                        }
                        None => None,
                    },
                    _ => None,
                }
            } else {
                None
            };
            match resolved {
                None => results[i] = Some(self.execute(req)),
                Some((graph, version, entry, rp, root)) => {
                    let full_key: CacheKey =
                        (req.graph.clone(), version, entry.hash, fingerprint(&req.args));
                    let mut base_args = req.args.clone();
                    base_args.scalars.remove(&rp);
                    let gkey: GroupKey =
                        (req.graph.clone(), version, entry.hash, fingerprint(&base_args));
                    let group = groups.entry(gkey.clone()).or_insert_with(|| {
                        order.push(gkey.clone());
                        Group { graph, entry, root_param: rp, base_args, members: Vec::new() }
                    });
                    group.members.push((i, root, full_key));
                }
            }
        }
        for gkey in order {
            let group = groups.remove(&gkey).expect("group recorded in order");
            // one admission slot per merged run, not per member
            let limit = self.cfg.max_in_flight;
            let prev = self.in_flight.fetch_add(1, Ordering::AcqRel);
            let _slot = InFlightSlot(&self.in_flight);
            if prev >= limit {
                for (i, _, _) in &group.members {
                    self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                    results[*i] = Some(Err(ServiceError::Overloaded { limit }));
                }
                continue;
            }
            let members: Vec<(Node, CacheKey)> =
                group.members.iter().map(|(_, root, key)| (*root, key.clone())).collect();
            let merged = self.run_merged(
                &group.graph,
                &group.entry,
                &group.root_param,
                &group.base_args,
                &members,
            );
            for ((i, _, _), r) in group.members.iter().zip(merged) {
                results[*i] = Some(r);
            }
        }
        results.into_iter().map(|r| r.expect("every request resolved")).collect()
    }

    /// Shared core of [`execute_batch`] and the coalescing window: serve
    /// each member from the cache if possible, run the remaining unique
    /// roots as one batched traversal, and fan results back out with the
    /// same per-request stats/cache accounting the solo path performs.
    /// The caller handles admission.
    fn run_merged(
        &self,
        graph: &Arc<Graph>,
        entry: &ProgramEntry,
        root_param: &str,
        base_args: &Args,
        members: &[(Node, CacheKey)],
    ) -> Vec<Result<Arc<Output>, ServiceError>> {
        let mut results: Vec<Option<Result<Arc<Output>, ServiceError>>> =
            members.iter().map(|_| None).collect();
        if self.cfg.cache_capacity > 0 {
            let c = lock_mutex(&self.cache);
            for (i, (_, key)) in members.iter().enumerate() {
                if let Some(hit) = c.map.get(key).cloned() {
                    results[i] = Some(Ok(hit));
                }
            }
        }
        let hits = results.iter().filter(|r| r.is_some()).count() as u64;
        if hits > 0 {
            self.stats.cache_hits.fetch_add(hits, Ordering::Relaxed);
            self.stats.completed.fetch_add(hits, Ordering::Relaxed);
        }
        let misses: Vec<usize> = (0..members.len()).filter(|&i| results[i].is_none()).collect();
        if !misses.is_empty() {
            // identical roots run once and share the resulting Arc
            let mut uniq_roots: Vec<Node> = Vec::new();
            let mut root_ix: HashMap<Node, usize> = HashMap::new();
            for &i in &misses {
                let root = members[i].0;
                root_ix.entry(root).or_insert_with(|| {
                    uniq_roots.push(root);
                    uniq_roots.len() - 1
                });
            }
            let token = CancelToken::default();
            if let Some(d) = self.cfg.default_deadline {
                token.set_deadline_in(d);
            }
            let opts = ExecOpts {
                threads: self.cfg.threads,
                cancel: Some(token),
                fault: self.cfg.fault,
                batch: (self.cfg.batch_width > 0).then_some(self.cfg.batch_width),
                ..ExecOpts::default()
            };
            let ran = catch_unwind(AssertUnwindSafe(|| {
                interp::batch::run_batch_with_opts(
                    &entry.tf, graph, base_args, root_param, &uniq_roots, &opts,
                )
            }));
            let per_root: Vec<Result<Arc<Output>, ServiceError>> = match ran {
                Err(payload) => {
                    let err: ServiceError =
                        ExecError::WorkerPanic(panic_message(payload)).into();
                    uniq_roots.iter().map(|_| Err(err.clone())).collect()
                }
                Ok(v) => {
                    self.stats.batched_roots.fetch_add(uniq_roots.len() as u64, Ordering::Relaxed);
                    v.into_iter()
                        .map(|r| match r {
                            Ok(out) => {
                                // once per unique root, matching the solo
                                // run-then-cache-hit accounting
                                self.stats
                                    .fallbacks
                                    .fetch_add(out.stats.fallbacks, Ordering::Relaxed);
                                Ok(Arc::new(out))
                            }
                            Err(e) => Err(self.classify(&e)),
                        })
                        .collect()
                }
            };
            for &i in &misses {
                let (root, key) = &members[i];
                match &per_root[root_ix[root]] {
                    Ok(out) => {
                        self.stats.completed.fetch_add(1, Ordering::Relaxed);
                        self.cache_insert(key, out.clone());
                        results[i] = Some(Ok(out.clone()));
                    }
                    Err(err) => {
                        self.count_error(err);
                        results[i] = Some(Err(err.clone()));
                    }
                }
            }
        }
        results.into_iter().map(|r| r.expect("every member resolved")).collect()
    }

    /// The coalescing rendezvous behind [`Service::execute`]: the first
    /// request of a group opens a gather and sleeps the window (it already
    /// holds an admission slot); same-group requests arriving meanwhile
    /// join as members (each holding its own slot) and wait on the condvar.
    /// The leader then runs the merged batch and publishes per-member
    /// results.
    #[allow(clippy::too_many_arguments)]
    fn execute_coalesced(
        &self,
        req: &Request,
        window: Duration,
        graph: &Arc<Graph>,
        graph_version: u64,
        entry: &ProgramEntry,
        root_param: &str,
        root: Node,
        key: CacheKey,
    ) -> Result<Arc<Output>, ServiceError> {
        let mut base_args = req.args.clone();
        base_args.scalars.remove(root_param);
        let gkey: GroupKey =
            (req.graph.clone(), graph_version, entry.hash, fingerprint(&base_args));
        use std::collections::hash_map::Entry;
        loop {
            let (gather, leader) = {
                let mut w = lock_mutex(&self.windows);
                match w.entry(gkey.clone()) {
                    Entry::Occupied(e) => (e.get().clone(), false),
                    Entry::Vacant(e) => {
                        let g = Arc::new(Gather {
                            state: Mutex::new(GatherState {
                                members: vec![(root, key.clone())],
                                closed: false,
                                results: None,
                            }),
                            cv: Condvar::new(),
                        });
                        e.insert(g.clone());
                        (g, true)
                    }
                }
            };
            if leader {
                // collect members while the window is open
                std::thread::sleep(window);
                lock_mutex(&self.windows).remove(&gkey);
                let members = {
                    let mut st = lock_mutex(&gather.state);
                    st.closed = true;
                    st.members.clone()
                };
                let merged = self.run_merged(graph, entry, root_param, &base_args, &members);
                let mine = merged[0].clone();
                let mut st = lock_mutex(&gather.state);
                st.results = Some(merged);
                drop(st);
                gather.cv.notify_all();
                return mine;
            }
            let my_index = {
                let mut st = lock_mutex(&gather.state);
                if st.closed {
                    // the leader snapshotted between our map lookup and this
                    // lock: start a fresh gather
                    continue;
                }
                st.members.push((root, key.clone()));
                st.members.len() - 1
            };
            self.stats.coalesced.fetch_add(1, Ordering::Relaxed);
            let mut st = lock_mutex(&gather.state);
            while st.results.is_none() {
                st = gather.cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            return st.results.as_ref().expect("published with results")[my_index].clone();
        }
    }
}

// ---------------------------------------------------------------------------
// Hashing
// ---------------------------------------------------------------------------

/// FNV-1a: small, dependency-free, and stable across platforms — the cache
/// key only needs identity, not cryptographic strength.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Order-insensitive fingerprint of an argument set: names are sorted so
/// `Args` built in different insertion orders hash identically.
///
/// Every variable-length field (names, set payloads) is length-prefixed so
/// field boundaries are unambiguous: without the prefixes, bytes that end a
/// name and bytes that start a value can trade places across two different
/// argument sets and still serialize identically, silently sharing a cache
/// entry between distinct invocations.
fn fingerprint(args: &Args) -> u64 {
    let mut buf: Vec<u8> = Vec::new();
    let put_name = |buf: &mut Vec<u8>, name: &str| {
        buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
        buf.extend_from_slice(name.as_bytes());
    };
    let mut scalars: Vec<_> = args.scalars.iter().collect();
    scalars.sort_by(|a, b| a.0.cmp(b.0));
    for (name, v) in scalars {
        put_name(&mut buf, name);
        match v {
            Val::I(x) => {
                buf.push(b'i');
                buf.extend_from_slice(&x.to_le_bytes());
            }
            Val::F(x) => {
                buf.push(b'f');
                buf.extend_from_slice(&x.to_bits().to_le_bytes());
            }
            Val::B(x) => buf.extend_from_slice(&[b'b', *x as u8]),
        }
    }
    let mut sets: Vec<_> = args.sets.iter().collect();
    sets.sort_by(|a, b| a.0.cmp(b.0));
    for (name, vs) in sets {
        buf.push(b's');
        put_name(&mut buf, name);
        buf.extend_from_slice(&(vs.len() as u32).to_le_bytes());
        for v in vs {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    fnv1a(&buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_ignores_insertion_order() {
        let a = Args::default().scalar("x", Val::I(3)).scalar("y", Val::F(1.5));
        let b = Args::default().scalar("y", Val::F(1.5)).scalar("x", Val::I(3));
        assert_eq!(fingerprint(&a), fingerprint(&b));
    }

    #[test]
    fn fingerprint_separates_values_and_types() {
        let base = fingerprint(&Args::default().scalar("x", Val::I(3)));
        assert_ne!(base, fingerprint(&Args::default().scalar("x", Val::I(4))));
        assert_ne!(base, fingerprint(&Args::default().scalar("x", Val::F(3.0))));
        assert_ne!(base, fingerprint(&Args::default().set("x", vec![3])));
    }

    #[test]
    fn fnv1a_is_stable() {
        // pinned reference value: the cache key must not drift across builds
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
    }

    // The next three cases are crafted collisions of the unprefixed
    // serialization: each pair produced byte-identical buffers before names
    // and set payloads were length-prefixed.

    #[test]
    fn fingerprint_separates_adjacent_set_names() {
        // {"a": [], "b": []} vs {"asb": []}: without a name-length prefix the
        // second set's 's' marker and name fuse into one longer name.
        let split = Args::default().set("a", vec![]).set("b", vec![]);
        let fused = Args::default().set("asb", vec![]);
        assert_ne!(fingerprint(&split), fingerprint(&fused));
    }

    #[test]
    fn fingerprint_separates_set_values_from_set_headers() {
        // 25203 is 0x6273 — little-endian it spells "sb\0\0", i.e. exactly the
        // marker + name + two pad bytes of a following set("b\0\0", []).
        let a = Args::default().set("a", vec![5, 25203]);
        let b = Args::default().set("a", vec![5]).set("b\0\0", vec![]);
        assert_ne!(fingerprint(&a), fingerprint(&b));
    }

    #[test]
    fn fingerprint_separates_scalar_names_from_values() {
        // Bool scalars serialize as name + 'b' + byte, so {"a": true,
        // "ab": true} and {"ab\x01ab": true} were byte-identical unprefixed.
        let pair = Args::default()
            .scalar("a", Val::B(true))
            .scalar("ab", Val::B(true));
        let fused = Args::default().scalar("ab\u{1}ab", Val::B(true));
        assert_ne!(fingerprint(&pair), fingerprint(&fused));
    }

    #[test]
    fn root_of_requires_a_representable_node() {
        let args = Args::default().scalar("src", Val::I(7));
        assert_eq!(root_of("src", &args), Some(7));
        assert_eq!(root_of("src", &Args::default()), None);
        assert_eq!(root_of("src", &Args::default().scalar("src", Val::I(-1))), None);
        assert_eq!(root_of("src", &Args::default().scalar("src", Val::F(7.0))), None);
        assert_eq!(
            root_of("src", &Args::default().scalar("src", Val::I(i64::from(u32::MAX) + 1))),
            None
        );
    }
}
