//! PJRT runtime: load AOT artifacts (HLO text) and execute them on the CPU
//! client. Mirrors /opt/xla-example/load_hlo — text is the interchange
//! format because xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id protos.

pub mod service;

use crate::util::json::Json;
use crate::xla_stub as xla;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One manifest row (see python/compile/aot.py).
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub algo: String,
    pub graph: String,
    pub file: String,
    pub n: usize,
    pub n_pad: usize,
    pub width: usize,
    pub n_dense: usize,
}

/// Runtime owning the PJRT client and a compile-once executable cache.
pub struct Runtime {
    pub client: xla::PjRtClient,
    dir: PathBuf,
    pub artifacts: Vec<ArtifactInfo>,
    pub scale: usize,
    cache: std::cell::RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open an artifact directory produced by `make artifacts`.
    pub fn open(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!("read {} — run `make artifacts` first", manifest_path.display())
        })?;
        let manifest = Json::parse(&text).context("parse manifest.json")?;
        let mut artifacts = Vec::new();
        for a in manifest.get("artifacts").as_arr().unwrap_or(&[]) {
            artifacts.push(ArtifactInfo {
                algo: a.get("algo").as_str().unwrap_or_default().to_string(),
                graph: a.get("graph").as_str().unwrap_or_default().to_string(),
                file: a.get("file").as_str().unwrap_or_default().to_string(),
                n: a.get("n").as_usize().unwrap_or(0),
                n_pad: a.get("n_pad").as_usize().unwrap_or(0),
                width: a.get("width").as_usize().unwrap_or(0),
                n_dense: a.get("n_dense").as_usize().unwrap_or(0),
            });
        }
        let scale = manifest.get("scale").as_usize().unwrap_or(0);
        Ok(Runtime { client, dir: dir.to_path_buf(), artifacts, scale, cache: Default::default() })
    }

    pub fn info(&self, algo: &str, graph: &str) -> Result<ArtifactInfo> {
        self.artifacts
            .iter()
            .find(|a| a.algo == algo && a.graph == graph)
            .cloned()
            .ok_or_else(|| anyhow!("no artifact for algo={algo} graph={graph} in manifest"))
    }

    /// Load + compile (cached) an artifact.
    pub fn executable(
        &self,
        algo: &str,
        graph: &str,
    ) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        let key = format!("{algo}/{graph}");
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let info = self.info(algo, graph)?;
        let path = self.dir.join(&info.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse HLO {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {key}: {e:?}"))?;
        let rc = std::rc::Rc::new(exe);
        self.cache.borrow_mut().insert(key, rc.clone());
        Ok(rc)
    }

    /// Drop all compiled executables (bench hygiene: ~70 cached XLA CPU
    /// executables can exhaust memory on small testbeds).
    pub fn clear_cache(&self) {
        self.cache.borrow_mut().clear();
    }

    /// Execute with literal inputs; returns the decomposed output tuple.
    pub fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe.execute::<xla::Literal>(inputs).map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("to_literal: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
    }

    /// Execute with device-resident buffers (perf path — avoids the
    /// host↔device literal round-trip the paper's §4 warns about).
    pub fn execute_buffers(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let mut result =
            exe.execute_b::<&xla::PjRtBuffer>(inputs).map_err(|e| anyhow!("execute_b: {e:?}"))?;
        Ok(std::mem::take(&mut result[0]))
    }

    pub fn buffer_from_literal(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow!("buffer_from_host_literal: {e:?}"))
    }
}

// ---- literal helpers ----------------------------------------------------

pub fn lit_i32_1d(v: &[i32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

pub fn lit_f32_1d(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

pub fn lit_i32_2d(v: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(v)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

pub fn lit_f32_2d(v: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(v)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

pub fn scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn to_vec_i32(l: &xla::Literal) -> Result<Vec<i32>> {
    l.to_vec::<i32>().map_err(|e| anyhow!("to_vec<i32>: {e:?}"))
}

pub fn to_vec_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow!("to_vec<f32>: {e:?}"))
}

pub fn scalar_to_i32(l: &xla::Literal) -> Result<i32> {
    l.get_first_element::<i32>().map_err(|e| anyhow!("first element: {e:?}"))
}

pub fn scalar_to_f32(l: &xla::Literal) -> Result<f32> {
    l.get_first_element::<f32>().map_err(|e| anyhow!("first element: {e:?}"))
}

/// Check the manifest was built at a compatible suite scale.
pub fn check_scale(rt: &Runtime, expected: usize) -> Result<()> {
    if rt.scale != expected {
        bail!(
            "artifact scale {} != requested scale {expected}; re-run `make artifacts` \
             with STARPLAT_XLA_SCALE={expected}",
            rt.scale
        );
    }
    Ok(())
}
