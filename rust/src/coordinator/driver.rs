//! Unified driver: run (algorithm × graph × backend) cells.
//!
//! Backends map to the paper's columns:
//! - `gunrock` / `lonestar`     — the Table-3 hand-crafted baselines;
//! - `xla`                      — StarPlat's accelerator path (CUDA analog);
//! - `par` (interpreter, MT)    — SYCL-on-CPU analog (Table 4);
//! - `seq` (interpreter, 1T)    — OpenACC-on-CPU analog (Table 4);
//! - `planexec`                 — the device-plan reference executor: runs
//!   the exact lowering the 7 text backends render, in-process.

use crate::algorithms::{gunrock, lonestar, reference};
use crate::backends::interp::{self, env::Val, Args, Mode, Output};
use crate::backends::planexec;
use crate::backends::xla::XlaBackend;
use crate::dsl::parser::parse_file;
use crate::graph::csr::{Graph, Node};
use crate::graph::generators::sample_sources;
use crate::graph::suite::build_suite;
use crate::sema::{check_function, TypedFunction};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::OnceLock;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    Bc,
    Pr,
    Sssp,
    Tc,
    Bfs,
    Cc,
}

impl Algo {
    pub fn parse(s: &str) -> Result<Algo> {
        Ok(match s {
            "bc" => Algo::Bc,
            "pr" => Algo::Pr,
            "sssp" => Algo::Sssp,
            "tc" => Algo::Tc,
            "bfs" => Algo::Bfs,
            "cc" => Algo::Cc,
            other => bail!("unknown algorithm `{other}`"),
        })
    }
    pub fn program(&self) -> &'static str {
        match self {
            Algo::Bc => "bc.sp",
            Algo::Pr => "pr.sp",
            Algo::Sssp => "sssp.sp",
            Algo::Tc => "tc.sp",
            Algo::Bfs => "bfs.sp",
            Algo::Cc => "cc.sp",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Seq,
    Par,
    /// the plan-level reference executor (`backends::planexec`)
    Planexec,
    Xla,
    Gunrock,
    Lonestar,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend> {
        Ok(match s {
            "seq" => Backend::Seq,
            "par" => Backend::Par,
            "planexec" => Backend::Planexec,
            "xla" => Backend::Xla,
            "gunrock" => Backend::Gunrock,
            "lonestar" => Backend::Lonestar,
            other => bail!("unknown backend `{other}`"),
        })
    }
}

/// Parsed + type-checked DSL programs, loaded once.
pub fn load_program(algo: Algo) -> Result<TypedFunction> {
    static CACHE: OnceLock<std::sync::Mutex<HashMap<&'static str, TypedFunction>>> =
        OnceLock::new();
    let cache = CACHE.get_or_init(Default::default);
    let mut guard = cache.lock().unwrap();
    if let Some(tf) = guard.get(algo.program()) {
        return Ok(tf.clone());
    }
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("dsl_programs").join(algo.program());
    let fns = parse_file(&path)?;
    let tf = check_function(&fns[0]).map_err(|e| anyhow!("{e}"))?;
    guard.insert(algo.program(), tf.clone());
    Ok(tf)
}

/// The result of one cell: elapsed seconds + a checksum for verification.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub secs: f64,
    pub checksum: f64,
}

/// Standard parameters (match the interp/oracle tests).
pub const PR_BETA: f64 = 1e-7;
pub const PR_DAMPING: f64 = 0.85;
pub const PR_MAX_ITER: usize = 100;

/// Execute one (algo, graph, backend) cell; sources used by BC only.
pub fn run_cell(
    algo: Algo,
    entry_short: &str,
    g: &Graph,
    backend: Backend,
    sources: &[Node],
    xla: Option<&XlaBackend>,
) -> Result<CellResult> {
    let threads = crate::util::pool::default_threads();
    let src: Node = sources.first().copied().unwrap_or(0);
    let t0 = std::time::Instant::now();
    let checksum = match (backend, algo) {
        // ---- hand-written baselines (Table 3) ----
        (Backend::Gunrock, Algo::Sssp) => sum_i32(&gunrock::sssp(g, src, threads)),
        (Backend::Gunrock, Algo::Bfs) => sum_i32(&gunrock::bfs(g, src, threads)),
        (Backend::Gunrock, Algo::Pr) => {
            gunrock::pagerank(g, PR_BETA, PR_DAMPING, PR_MAX_ITER, threads).iter().sum()
        }
        (Backend::Gunrock, Algo::Tc) => gunrock::triangle_count(g, threads) as f64,
        (Backend::Gunrock, Algo::Bc) => gunrock::betweenness(g, sources, threads).iter().sum(),
        (Backend::Gunrock, Algo::Cc) => bail!("gunrock baseline has no CC"),
        (Backend::Lonestar, Algo::Sssp) => sum_i32(&lonestar::sssp(g, src, threads)),
        (Backend::Lonestar, Algo::Bfs) => sum_i32(&lonestar::bfs(g, src, threads)),
        (Backend::Lonestar, Algo::Pr) => {
            lonestar::pagerank(g, PR_BETA, PR_DAMPING, PR_MAX_ITER, threads).iter().sum()
        }
        (Backend::Lonestar, Algo::Tc) => lonestar::triangle_count(g, threads) as f64,
        // the paper's Table 3: LonestarGPU does not implement BC
        (Backend::Lonestar, Algo::Bc) => bail!("lonestar has no BC (paper Table 3 `-`)"),
        (Backend::Lonestar, Algo::Cc) => bail!("lonestar baseline has no CC"),
        // ---- DSL via interpreter (CPU rows of Table 4) ----
        (Backend::Seq, _) | (Backend::Par, _) => {
            let tf = load_program(algo)?;
            let mode = if backend == Backend::Seq { Mode::Seq } else { Mode::Par };
            let out = interp::run(&tf, g, &algo_args(algo, sources), mode)?;
            checksum_of(algo, &out)?
        }
        // ---- DSL via the device-plan executor (same bindings, same
        // checksum extraction — a drop-in second executing backend) ----
        (Backend::Planexec, _) => {
            let tf = load_program(algo)?;
            let out = planexec::run(&tf, g, &algo_args(algo, sources))?;
            checksum_of(algo, &out)?
        }
        // ---- DSL via XLA artifacts (accelerator rows) ----
        (Backend::Xla, a) => {
            let xla = xla.ok_or_else(|| anyhow!("XLA backend unavailable (no artifacts)"))?;
            match a {
                Algo::Sssp => sum_i32(&xla.run_sssp(entry_short, g, src)?),
                Algo::Bfs => sum_i32(&xla.run_bfs(entry_short, g, src)?),
                Algo::Cc => sum_i32(&xla.run_cc(entry_short, g)?),
                Algo::Pr => xla
                    .run_pr(entry_short, g, PR_BETA as f32, PR_DAMPING as f32, PR_MAX_ITER)?
                    .iter()
                    .map(|&x| x as f64)
                    .sum(),
                Algo::Bc => xla
                    .run_bc(entry_short, g, sources)?
                    .iter()
                    .map(|&x| x as f64)
                    .sum(),
                Algo::Tc => xla.run_tc(entry_short, g)? as f64,
            }
        }
    };
    Ok(CellResult { secs: t0.elapsed().as_secs_f64(), checksum })
}

fn sum_i32(v: &[i32]) -> f64 {
    v.iter().map(|&x| if x >= reference::INF { 0.0 } else { x as f64 }).sum()
}

/// Canonical argument bindings for one algorithm — shared by every backend
/// that runs the DSL program itself (interpreter and plan executor).
pub fn algo_args(algo: Algo, sources: &[Node]) -> Args {
    let src: Node = sources.first().copied().unwrap_or(0);
    match algo {
        Algo::Sssp | Algo::Bfs => Args::default().node("src", src),
        Algo::Cc | Algo::Tc => Args::default(),
        Algo::Pr => Args::default()
            .scalar("beta", Val::F(PR_BETA))
            .scalar("delta", Val::F(PR_DAMPING))
            .scalar("maxIter", Val::I(PR_MAX_ITER as i64)),
        Algo::Bc => Args::default().set("sourceSet", sources.to_vec()),
    }
}

/// Canonical checksum over an execution output — unreachable sentinels
/// contribute zero, matching the baselines' accounting.
pub fn checksum_of(algo: Algo, out: &Output) -> Result<f64> {
    Ok(match algo {
        Algo::Sssp => out
            .prop_i64("dist")
            .iter()
            .map(|&x| if x >= reference::INF as i64 { 0.0 } else { x as f64 })
            .sum(),
        Algo::Bfs => out
            .prop_i64("level")
            .iter()
            .map(|&x| if x >= reference::INF as i64 { 0.0 } else { x as f64 })
            .sum(),
        Algo::Cc => out.prop_i64("comp").iter().map(|&x| x as f64).sum(),
        Algo::Pr => out.prop_f64("pageRank").iter().sum(),
        Algo::Bc => out.prop_f64("BC").iter().sum(),
        Algo::Tc => match out.ret {
            Some(Val::I(n)) => n as f64,
            _ => bail!("TC returned no count"),
        },
    })
}

/// CLI entry: run one cell and render a short report.
pub fn run_one(
    algo: &str,
    graph_short: &str,
    backend: &str,
    scale: usize,
    n_sources: usize,
) -> Result<String> {
    let algo = Algo::parse(algo)?;
    let backend_e = Backend::parse(backend)?;
    let suite = build_suite(scale);
    let entry = super::find_graph(&suite, graph_short)
        .ok_or_else(|| anyhow!("unknown graph `{graph_short}` (TW SW OK WK LJ PK US GR RM UR)"))?;
    let sources = sample_sources(&entry.graph, n_sources, 7);
    let xla = if backend_e == Backend::Xla {
        Some(XlaBackend::open(std::path::Path::new("artifacts"))?)
    } else {
        None
    };
    let r = run_cell(algo, graph_short, &entry.graph, backend_e, &sources, xla.as_ref())?;
    Ok(format!(
        "{algo:?} on {graph_short} ({} nodes, {} edges) via {backend}: {:.4}s  checksum={:.4}",
        entry.graph.num_nodes(),
        entry.graph.num_edges(),
        r.secs,
        r.checksum
    ))
}
