//! Experiment coordinator: runs the paper's evaluation matrix
//! (algorithm × graph × framework/backend) and renders Tables 2–4 plus the
//! §5 lines-of-code comparison.

pub mod driver;

use crate::graph::ell::EllGraph;
use crate::graph::suite::{build_suite, SuiteEntry};
use crate::util::json::Json;
use crate::util::table::Table;
use anyhow::Result;

pub use driver::{run_one, Algo, Backend};

/// Table 2: the input graph suite.
pub fn table2(scale: usize) -> Table {
    let suite = build_suite(scale);
    let mut t = Table::new(
        &format!("Table 2 — input graphs (scale {scale}; δ = degree)"),
        &["Graph", "Short", "|V|", "|E|", "Avg. δ", "Max. δ", "ecc(0)"],
    );
    for e in &suite {
        let s = crate::graph::stats::stats(&e.graph, e.short);
        t.row(vec![
            e.paper_name.to_string(),
            e.short.to_string(),
            s.num_nodes.to_string(),
            s.num_edges.to_string(),
            format!("{:.1}", s.avg_degree),
            s.max_degree.to_string(),
            s.ecc_from_0.to_string(),
        ]);
    }
    t
}

/// shapes.json for the AOT pipeline (consumed by python/compile/aot.py).
/// Padding parameters must match backends/xla (ROW_PAD/WIDTH_PAD).
pub fn export_shapes(scale: usize) -> Json {
    let suite = build_suite(scale);
    let graphs: Vec<Json> = suite
        .iter()
        .map(|e| {
            let ell = EllGraph::from_csr_in(
                &e.graph,
                crate::backends::xla::ROW_PAD,
                crate::backends::xla::WIDTH_PAD,
            );
            let n_dense = e.graph.num_nodes().div_ceil(crate::backends::xla::ROW_PAD)
                * crate::backends::xla::ROW_PAD;
            Json::obj(vec![
                ("short", Json::Str(e.short.to_string())),
                ("paper_name", Json::Str(e.paper_name.to_string())),
                ("n", Json::Num(e.graph.num_nodes() as f64)),
                ("n_pad", Json::Num(ell.n_pad as f64)),
                ("width_in", Json::Num(ell.width as f64)),
                ("n_dense", Json::Num(n_dense as f64)),
                ("padding_overhead", Json::Num(ell.padding_overhead())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("scale", Json::Num(scale as f64)),
        ("row_pad", Json::Num(crate::backends::xla::ROW_PAD as f64)),
        ("width_pad", Json::Num(crate::backends::xla::WIDTH_PAD as f64)),
        ("graphs", Json::Arr(graphs)),
    ])
}

/// Paper §5 LoC comparison: DSL programs are ~20–30 lines; generated CUDA is
/// ~5× that; OpenACC ≈ −33%, SYCL ≈ +50%, OpenCL ≈ +100% relative to CUDA.
pub fn loc_table() -> Result<Table> {
    use crate::dsl::parser::parse;
    use crate::ir::lower;
    use crate::sema::check_function;
    let mut t = Table::new(
        "§5 — lines of code: DSL source vs generated backends",
        &["Algorithm", "DSL", "CUDA", "OpenACC", "SYCL", "OpenCL", "JAX"],
    );
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("dsl_programs");
    for (algo, file) in
        [("BC", "bc.sp"), ("PR", "pr.sp"), ("SSSP", "sssp.sp"), ("TC", "tc.sp")]
    {
        let src = std::fs::read_to_string(root.join(file))?;
        let fns = parse(&src).map_err(|e| anyhow::anyhow!("{e}"))?;
        let tf = check_function(&fns[0]).map_err(|e| anyhow::anyhow!("{e}"))?;
        let ir = lower(&tf);
        let dsl_loc = crate::util::count_loc(&src);
        let mut row = vec![algo.to_string(), dsl_loc.to_string()];
        for b in ["cuda", "openacc", "sycl", "opencl"] {
            let gen = crate::codegen::generate(b, &ir)?;
            row.push(crate::util::count_loc(&gen).to_string());
        }
        let jax = crate::codegen::jax::generate(&ir)?;
        row.push(crate::util::count_loc(&jax.python).to_string());
        t.row(row);
    }
    Ok(t)
}

/// Find a suite entry by short name.
pub fn find_graph<'a>(suite: &'a [SuiteEntry], short: &str) -> Option<&'a SuiteEntry> {
    suite.iter().find(|e| e.short == short)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_has_ten_rows() {
        let t = table2(300);
        assert_eq!(t.rows.len(), 10);
    }

    #[test]
    fn shapes_json_padding_consistent() {
        let j = export_shapes(300);
        let graphs = j.get("graphs").as_arr().unwrap();
        assert_eq!(graphs.len(), 10);
        for g in graphs {
            let n_pad = g.get("n_pad").as_usize().unwrap();
            assert_eq!(n_pad % crate::backends::xla::ROW_PAD, 0);
            let nd = g.get("n_dense").as_usize().unwrap();
            assert_eq!(nd % crate::backends::xla::ROW_PAD, 0);
        }
    }

    #[test]
    fn loc_table_matches_paper_shape() {
        let t = loc_table().unwrap();
        assert_eq!(t.rows.len(), 4);
        for row in &t.rows {
            let dsl: usize = row[1].parse().unwrap();
            let cuda: usize = row[2].parse().unwrap();
            let opencl: usize = row[5].parse().unwrap();
            // DSL is compact (paper: 20-30 lines); generated code is larger;
            // OpenCL is the most verbose backend (paper: +100% over CUDA).
            assert!(dsl <= 35, "DSL too long: {dsl}");
            assert!(cuda > dsl, "CUDA {cuda} !> DSL {dsl}");
            assert!(opencl as f64 >= cuda as f64 * 0.9, "OpenCL {opencl} vs CUDA {cuda}");
        }
    }
}
