//! In-tree stand-in for the `xla` (PJRT) bindings.
//!
//! The XLA execution path (`runtime` + `backends::xla`) was written against
//! the `xla-rs` API, which needs the native `xla_extension` C++ library —
//! not something a plain `cargo build` can fetch. This module mirrors the
//! small API surface those modules use so the crate builds everywhere:
//! [`PjRtClient::cpu`] returns an error, which surfaces through
//! `XlaBackend::open` as the "artifacts unavailable" condition every test,
//! bench, and example already handles by skipping the XLA column.
//!
//! To run the real accelerator path, replace the `use crate::xla_stub as
//! xla;` aliases in `runtime/mod.rs` and `backends/xla/mod.rs` with the real
//! `xla` crate (and install `xla_extension`); the call sites compile
//! unchanged against either.

#[derive(Debug, thiserror::Error)]
#[error("{0}")]
pub struct Error(pub String);

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "PJRT bindings unavailable: built with the in-tree stub (no xla_extension); \
         the XLA backend is disabled"
            .to_string(),
    ))
}

/// Element types the artifact pipeline produces.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    S32,
    S64,
    F32,
    F64,
    Pred,
}

/// Host-side tensor value (stub: carries no data).
#[derive(Clone, Debug, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_v: &[T]) -> Literal {
        Literal
    }
    pub fn scalar<T>(_v: T) -> Literal {
        Literal
    }
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }
    pub fn get_first_element<T>(&self) -> Result<T, Error> {
        unavailable()
    }
    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        unavailable()
    }
    pub fn ty(&self) -> Result<ElementType, Error> {
        unavailable()
    }
}

#[derive(Clone, Debug, Default)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
    pub fn execute_b<T>(&self, _inputs: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_open_reports_stub() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
    }

    #[test]
    fn literal_constructors_are_infallible() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.clone().to_vec::<i32>().is_err());
        let _s = Literal::scalar(4f32);
    }
}
