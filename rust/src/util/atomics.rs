//! Atomic helpers mirroring the GPU intrinsics the paper's generated code
//! relies on (`atomicMin`, `atomicAdd` on float), built from CAS loops —
//! exactly how OpenCL simulates float atomics via `atomic_cmpxchg` (§3.3).

use std::sync::atomic::{AtomicI32, AtomicI64, AtomicU32, AtomicU64, Ordering};

/// `atomicMin(&x, v)` for i32. Returns the previous value.
#[inline]
pub fn atomic_min_i32(cell: &AtomicI32, v: i32) -> i32 {
    let mut cur = cell.load(Ordering::Relaxed);
    while v < cur {
        match cell.compare_exchange_weak(cur, v, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(prev) => return prev,
            Err(now) => cur = now,
        }
    }
    cur
}

/// `atomicMax(&x, v)` for i32. Returns the previous value.
#[inline]
pub fn atomic_max_i32(cell: &AtomicI32, v: i32) -> i32 {
    let mut cur = cell.load(Ordering::Relaxed);
    while v > cur {
        match cell.compare_exchange_weak(cur, v, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(prev) => return prev,
            Err(now) => cur = now,
        }
    }
    cur
}

/// `atomicAdd` on f32 via CAS on the bit pattern.
#[inline]
pub fn atomic_add_f32(cell: &AtomicU32, v: f32) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let new = (f32::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(now) => cur = now,
        }
    }
}

/// `atomicAdd` on f64 via CAS on the bit pattern.
#[inline]
pub fn atomic_add_f64(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let new = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(now) => cur = now,
        }
    }
}

/// `atomicMin` on f64 (used by Min constructs on float properties).
#[inline]
pub fn atomic_min_f64(cell: &AtomicU64, v: f64) -> f64 {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let cur_f = f64::from_bits(cur);
        if !(v < cur_f) {
            return cur_f;
        }
        match cell.compare_exchange_weak(cur, v.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(prev) => return f64::from_bits(prev),
            Err(now) => cur = now,
        }
    }
}

/// `atomicMax` on f64.
#[inline]
pub fn atomic_max_f64(cell: &AtomicU64, v: f64) -> f64 {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let cur_f = f64::from_bits(cur);
        if !(v > cur_f) {
            return cur_f;
        }
        match cell.compare_exchange_weak(cur, v.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(prev) => return f64::from_bits(prev),
            Err(now) => cur = now,
        }
    }
}

/// `atomicMin` for i64 cells.
#[inline]
pub fn atomic_min_i64(cell: &AtomicI64, v: i64) -> i64 {
    let mut cur = cell.load(Ordering::Relaxed);
    while v < cur {
        match cell.compare_exchange_weak(cur, v, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(prev) => return prev,
            Err(now) => cur = now,
        }
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::parallel_for;

    #[test]
    fn min_i32_concurrent() {
        let cell = AtomicI32::new(i32::MAX);
        parallel_for(1000, 4, |i| {
            atomic_min_i32(&cell, 1000 - i as i32);
        });
        assert_eq!(cell.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn add_f64_concurrent_sums() {
        let cell = AtomicU64::new(0f64.to_bits());
        parallel_for(10_000, 4, |_| {
            atomic_add_f64(&cell, 0.5);
        });
        assert_eq!(f64::from_bits(cell.load(Ordering::Relaxed)), 5000.0);
    }

    #[test]
    fn min_max_f64() {
        let cell = AtomicU64::new(10f64.to_bits());
        atomic_min_f64(&cell, 3.5);
        assert_eq!(f64::from_bits(cell.load(Ordering::Relaxed)), 3.5);
        atomic_max_f64(&cell, 99.0);
        assert_eq!(f64::from_bits(cell.load(Ordering::Relaxed)), 99.0);
        // no-ops
        atomic_min_f64(&cell, 100.0);
        atomic_max_f64(&cell, 0.0);
        assert_eq!(f64::from_bits(cell.load(Ordering::Relaxed)), 99.0);
    }

    #[test]
    fn max_i32() {
        let cell = AtomicI32::new(0);
        parallel_for(100, 4, |i| {
            atomic_max_i32(&cell, i as i32);
        });
        assert_eq!(cell.load(Ordering::Relaxed), 99);
    }
}
