//! Cooperative cancellation: a cloneable token carrying an explicit cancel
//! flag and an optional wall-clock deadline.
//!
//! The interpreter has no preemption — a sweep over millions of vertices runs
//! to completion once launched — so bounding a request means *polling*: the
//! token is checked at host-statement boundaries, at fixed-point/BFS iteration
//! boundaries, and at pool block-claim boundaries (see
//! [`crate::util::pool::try_parallel_for_dynamic_scoped`]). That makes the
//! worst-case overrun one block of work (~64 elements), not one sweep.
//!
//! Both trip conditions surface as an [`Interrupt`], which the interpreter
//! maps onto its typed `ExecError::{Cancelled, DeadlineExceeded}` variants.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Why a cancellation point tripped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Interrupt {
    /// [`CancelToken::cancel`] was called.
    Cancelled,
    /// The token's deadline passed.
    DeadlineExceeded,
}

/// Cloneable cancellation handle; all clones share one state.
///
/// The caller keeps one clone and hands another to the run; calling
/// [`cancel`](CancelToken::cancel) (or letting the deadline pass) makes every
/// subsequent [`interrupted`](CancelToken::interrupted) poll report the trip.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    inner: Arc<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    cancelled: AtomicBool,
    // fast-path gate so deadline-free tokens never touch the mutex
    has_deadline: AtomicBool,
    deadline: Mutex<Option<Instant>>,
}

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// A token that auto-expires `after` from now.
    pub fn with_deadline(after: Duration) -> CancelToken {
        let t = CancelToken::new();
        t.set_deadline_in(after);
        t
    }

    /// Install (or replace) the deadline as now + `after`. Expiry is
    /// cooperative: it surfaces at the next cancellation point, not
    /// preemptively.
    pub fn set_deadline_in(&self, after: Duration) {
        *self.inner.deadline.lock().unwrap() = Some(Instant::now() + after);
        self.inner.has_deadline.store(true, Ordering::Release);
    }

    /// Request cancellation; idempotent.
    pub fn cancel(&self) {
        self.inner.cancelled.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.inner.cancelled.load(Ordering::Acquire)
    }

    /// The poll every cancellation point runs: `None` while the run may
    /// continue, `Some(reason)` once it must stop. Explicit cancellation
    /// wins over an expired deadline when both hold.
    #[inline]
    pub fn interrupted(&self) -> Option<Interrupt> {
        if self.inner.cancelled.load(Ordering::Acquire) {
            return Some(Interrupt::Cancelled);
        }
        if self.inner.has_deadline.load(Ordering::Acquire) {
            if let Some(d) = *self.inner.deadline.lock().unwrap() {
                if Instant::now() >= d {
                    return Some(Interrupt::DeadlineExceeded);
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_token_is_clear() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        assert_eq!(t.interrupted(), None);
    }

    #[test]
    fn cancel_is_shared_across_clones() {
        let t = CancelToken::new();
        let clone = t.clone();
        t.cancel();
        assert!(clone.is_cancelled());
        assert_eq!(clone.interrupted(), Some(Interrupt::Cancelled));
    }

    #[test]
    fn zero_deadline_expires_immediately() {
        let t = CancelToken::with_deadline(Duration::ZERO);
        assert_eq!(t.interrupted(), Some(Interrupt::DeadlineExceeded));
    }

    #[test]
    fn distant_deadline_does_not_trip() {
        let t = CancelToken::with_deadline(Duration::from_secs(3600));
        assert_eq!(t.interrupted(), None);
    }

    #[test]
    fn cancel_wins_over_expired_deadline() {
        let t = CancelToken::with_deadline(Duration::ZERO);
        t.cancel();
        assert_eq!(t.interrupted(), Some(Interrupt::Cancelled));
    }
}
