//! A small scoped data-parallel executor.
//!
//! rayon is unavailable; the interpreter backend and the handwritten
//! baselines need `parallel_for`-style vertex loops. We implement static
//! chunking over `std::thread::scope`, which is enough for the regular,
//! balanced loops generated from the DSL (the paper's backends likewise use
//! static thread/block decompositions).
//!
//! The dynamic runners are additionally the runtime's **fault boundary**: the
//! `try_` variants poll a [`CancelToken`] at every block claim and wrap each
//! block's user code in `catch_unwind`, so a deadline, an explicit cancel, or
//! a panicking kernel body surfaces as a typed [`PoolInterrupt`] from *this*
//! call only — the threads are scoped and joined, no state outlives the call,
//! and the next call starts from a healthy pool.

use crate::util::cancel::{CancelToken, Interrupt};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use: respects STARPLAT_THREADS, defaults to
/// available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("STARPLAT_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Why a `try_` runner stopped early. The first interrupt observed wins;
/// other workers wind down at their next block claim.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PoolInterrupt {
    /// The [`CancelToken`] was cancelled.
    Cancelled,
    /// The [`CancelToken`]'s deadline passed.
    DeadlineExceeded,
    /// A worker's block panicked; the payload message is preserved. The pool
    /// itself stays healthy — the panic is confined to the failing call.
    Panicked(String),
}

impl From<Interrupt> for PoolInterrupt {
    fn from(i: Interrupt) -> PoolInterrupt {
        match i {
            Interrupt::Cancelled => PoolInterrupt::Cancelled,
            Interrupt::DeadlineExceeded => PoolInterrupt::DeadlineExceeded,
        }
    }
}

/// Best-effort text of a panic payload (`&str` and `String` payloads; every
/// `panic!` with a message produces one of those).
pub fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with non-string payload".to_string()
    }
}

/// Record the first interrupt and tell every worker to wind down.
fn record(first: &Mutex<Option<PoolInterrupt>>, stop: &AtomicBool, interrupt: PoolInterrupt) {
    let mut slot = first.lock().unwrap();
    if slot.is_none() {
        *slot = Some(interrupt);
    }
    stop.store(true, Ordering::Relaxed);
}

/// Run `f(i)` for every `i in 0..n`, statically chunked over `threads`
/// workers. `f` must be `Sync` — all mutation must go through atomics or
/// interior-mutable cells, exactly like a GPU kernel body.
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || {
                for i in lo..hi {
                    f(i);
                }
            });
        }
    });
}

/// Dynamic (work-stealing-ish) variant: workers grab fixed-size blocks from a
/// shared counter. Better for skewed per-item cost (e.g. triangle counting on
/// power-law graphs, the paper's TC blow-up case).
pub fn parallel_for_dynamic<F>(n: usize, threads: usize, block: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    parallel_for_dynamic_scoped(n, threads, block, || (), |_, i| f(i));
}

/// Dynamic variant with per-worker scratch state: each worker calls `init`
/// once and reuses the resulting value across all blocks it claims. The
/// slot-resolved interpreter uses this to allocate one register frame per
/// worker instead of one per element (zero allocations on the per-vertex
/// path).
///
/// Returns the final per-worker states in worker order — pure `for` callers
/// ignore it; [`parallel_collect`] uses the states as claim buffers.
///
/// Infallible wrapper over [`try_parallel_for_dynamic_scoped`] with no cancel
/// token; a worker panic is re-raised here, preserving the old contract.
pub fn parallel_for_dynamic_scoped<T, I, F>(
    n: usize,
    threads: usize,
    block: usize,
    init: I,
    f: F,
) -> Vec<T>
where
    T: Send,
    I: Fn() -> T + Sync,
    F: Fn(&mut T, usize) + Sync,
{
    match try_parallel_for_dynamic_scoped(n, threads, block, None, init, f) {
        Ok(states) => states,
        Err(PoolInterrupt::Panicked(msg)) => panic!("{msg}"),
        Err(other) => panic!("pool interrupted without a cancel token: {other:?}"),
    }
}

/// Fallible dynamic runner: the cooperative-cancellation and panic-isolation
/// boundary of the runtime.
///
/// At every block claim each worker polls `cancel`; a trip stops all workers
/// at their next claim and returns the corresponding [`PoolInterrupt`]. Each
/// block's `f` calls run inside `catch_unwind`, so a panicking element
/// poisons only this call: the first panic's message is captured, the other
/// workers wind down, every scoped thread is joined, and the caller gets
/// `Err(PoolInterrupt::Panicked(_))` instead of a propagating unwind.
///
/// On `Ok`, every index in `0..n` was processed exactly once; on `Err`, an
/// unspecified prefix of blocks was processed (callers treat the work as
/// abandoned).
pub fn try_parallel_for_dynamic_scoped<T, I, F>(
    n: usize,
    threads: usize,
    block: usize,
    cancel: Option<&CancelToken>,
    init: I,
    f: F,
) -> Result<Vec<T>, PoolInterrupt>
where
    T: Send,
    I: Fn() -> T + Sync,
    F: Fn(&mut T, usize) + Sync,
{
    if n == 0 {
        return Ok(Vec::new());
    }
    let threads = threads.clamp(1, n);
    let block = block.max(1);
    let first = Mutex::new(None);
    let stop = AtomicBool::new(false);
    let states = if threads == 1 {
        let mut state = init();
        let mut lo = 0;
        while lo < n {
            if let Some(i) = cancel.and_then(|c| c.interrupted()) {
                record(&first, &stop, i.into());
                break;
            }
            let hi = (lo + block).min(n);
            let state = &mut state;
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| {
                for i in lo..hi {
                    f(state, i);
                }
            })) {
                record(&first, &stop, PoolInterrupt::Panicked(panic_message(p)));
                break;
            }
            lo = hi;
        }
        vec![state]
    } else {
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..threads)
                .map(|_| {
                    let f = &f;
                    let init = &init;
                    let next = &next;
                    let first = &first;
                    let stop = &stop;
                    s.spawn(move || {
                        let mut state = init();
                        loop {
                            if stop.load(Ordering::Relaxed) {
                                break;
                            }
                            if let Some(i) = cancel.and_then(|c| c.interrupted()) {
                                record(first, stop, i.into());
                                break;
                            }
                            let lo = next.fetch_add(block, Ordering::Relaxed);
                            if lo >= n {
                                break;
                            }
                            let hi = (lo + block).min(n);
                            let state = &mut state;
                            if let Err(p) = catch_unwind(AssertUnwindSafe(|| {
                                for i in lo..hi {
                                    f(state, i);
                                }
                            })) {
                                record(first, stop, PoolInterrupt::Panicked(panic_message(p)));
                                break;
                            }
                        }
                        state
                    })
                })
                .collect();
            let mut states = Vec::with_capacity(handles.len());
            for h in handles {
                match h.join() {
                    Ok(state) => states.push(state),
                    // a panic outside the per-block wall (e.g. in `init`)
                    Err(p) => record(&first, &stop, PoolInterrupt::Panicked(panic_message(p))),
                }
            }
            states
        })
    };
    match first.into_inner().unwrap() {
        Some(interrupt) => Err(interrupt),
        None => Ok(states),
    }
}

/// Parallel emit-collect: run `emit(i, &mut buf)` for every `i in 0..n`,
/// where each worker owns a private **claim buffer**; the buffers are then
/// concatenated into one `Vec` via prefix offsets (one `with_capacity`
/// allocation, one append per worker).
///
/// This is the frontier-gather primitive of the interpreter backend: after a
/// sweep, workers claim the vertices whose `nxt` bit the kernel set (an
/// atomic swap makes each claim exclusive, so no vertex is emitted twice)
/// and the next worklist is the concatenation. Element order *across*
/// workers is unspecified — callers must be order-independent, exactly like
/// a GPU frontier compaction.
pub fn parallel_collect<T, F>(n: usize, threads: usize, block: usize, emit: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, &mut Vec<T>) + Sync,
{
    match try_parallel_collect(n, threads, block, None, emit) {
        Ok(out) => out,
        Err(PoolInterrupt::Panicked(msg)) => panic!("{msg}"),
        Err(other) => panic!("pool interrupted without a cancel token: {other:?}"),
    }
}

/// Fallible [`parallel_collect`]: same claim-buffer gather, but cancellable
/// and panic-isolated like [`try_parallel_for_dynamic_scoped`]. On `Err` the
/// partial buffers are dropped — an interrupted gather yields no elements.
pub fn try_parallel_collect<T, F>(
    n: usize,
    threads: usize,
    block: usize,
    cancel: Option<&CancelToken>,
    emit: F,
) -> Result<Vec<T>, PoolInterrupt>
where
    T: Send,
    F: Fn(usize, &mut Vec<T>) + Sync,
{
    // the per-worker scratch of the dynamic-scoped runner IS the claim
    // buffer: one chunking implementation, not two
    let buffers = try_parallel_for_dynamic_scoped(n, threads, block, cancel, Vec::new, |buf, i| {
        emit(i, buf)
    })?;
    // prefix offsets: one exact allocation, each worker's buffer lands at
    // the running offset of the lengths before it
    let total = buffers.iter().map(Vec::len).sum();
    let mut out = Vec::with_capacity(total);
    for b in buffers {
        out.extend(b);
    }
    Ok(out)
}

/// Parallel map: collects `f(i)` into a Vec, preserving order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots: Vec<std::sync::Mutex<&mut T>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_for(n, threads, |i| {
            **slots[i].lock().unwrap() = f(i);
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    #[test]
    fn covers_all_indices() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for(1000, 4, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn dynamic_covers_all_indices() {
        let hits: Vec<AtomicU64> = (0..777).map(|_| AtomicU64::new(0)).collect();
        parallel_for_dynamic(777, 3, 16, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_and_single() {
        parallel_for(0, 4, |_| panic!("must not run"));
        let c = AtomicU64::new(0);
        parallel_for(1, 8, |_| {
            c.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(c.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn scoped_covers_all_indices_and_reuses_state() {
        let hits: Vec<AtomicU64> = (0..513).map(|_| AtomicU64::new(0)).collect();
        let inits = AtomicU64::new(0);
        parallel_for_dynamic_scoped(
            513,
            4,
            8,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
                vec![0u8; 4]
            },
            |scratch, i| {
                scratch[0] = scratch[0].wrapping_add(1);
                hits[i].fetch_add(1, Ordering::Relaxed);
            },
        );
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        // one frame per worker, not per element
        assert!(inits.load(Ordering::Relaxed) <= 4);
    }

    #[test]
    fn collect_emits_every_index_exactly_once() {
        for threads in [1, 3, 8] {
            let mut got = parallel_collect(997, threads, 16, |i, out| {
                if i % 3 == 0 {
                    out.push(i);
                }
            });
            got.sort_unstable();
            let want: Vec<usize> = (0..997).filter(|i| i % 3 == 0).collect();
            assert_eq!(got, want, "{threads} threads");
        }
        let empty: Vec<usize> = parallel_collect(0, 4, 8, |i, out| out.push(i));
        assert!(empty.is_empty());
    }

    #[test]
    fn collect_claim_buffers_are_exclusive_under_atomic_claims() {
        // the frontier-gather shape: many indices race to claim the same
        // cells; the swap makes each claim exclusive, so the concatenated
        // buffers contain each claimed cell exactly once
        let cells: Vec<AtomicU64> = (0..64).map(|_| AtomicU64::new(1)).collect();
        let mut got = parallel_collect(4096, 8, 32, |i, out| {
            let c = i % 64;
            if cells[c].swap(0, Ordering::Relaxed) == 1 {
                out.push(c);
            }
        });
        got.sort_unstable();
        assert_eq!(got, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(100, 4, |i| i * i);
        assert_eq!(v[7], 49);
        assert_eq!(v[99], 9801);
    }

    #[test]
    fn cancelled_token_stops_before_any_work() {
        for threads in [1, 4] {
            let token = CancelToken::new();
            token.cancel();
            let done = AtomicU64::new(0);
            let r = try_parallel_for_dynamic_scoped(
                10_000,
                threads,
                8,
                Some(&token),
                || (),
                |_, _| {
                    done.fetch_add(1, Ordering::Relaxed);
                },
            );
            assert_eq!(r, Err(PoolInterrupt::Cancelled), "{threads} threads");
            // workers poll before every claim, so a pre-cancelled token
            // admits no blocks at all
            assert_eq!(done.load(Ordering::Relaxed), 0, "{threads} threads");
        }
    }

    #[test]
    fn expired_deadline_surfaces_as_interrupt() {
        let token = CancelToken::with_deadline(Duration::ZERO);
        let r = try_parallel_for_dynamic_scoped(1000, 4, 8, Some(&token), || (), |_, _| {});
        assert_eq!(r, Err(PoolInterrupt::DeadlineExceeded));
    }

    #[test]
    fn panic_in_block_becomes_typed_interrupt() {
        for threads in [1, 4] {
            let r = try_parallel_for_dynamic_scoped(
                1000,
                threads,
                8,
                None,
                || (),
                |_, i| {
                    if i == 137 {
                        panic!("boom at {i}");
                    }
                },
            );
            match r {
                Err(PoolInterrupt::Panicked(msg)) => {
                    assert!(msg.contains("boom at 137"), "message lost: {msg}");
                }
                other => panic!("expected Panicked, got {other:?} ({threads} threads)"),
            }
        }
    }

    #[test]
    fn pool_is_healthy_after_a_panicking_call() {
        let r = try_parallel_for_dynamic_scoped(64, 4, 4, None, || (), |_, _| {
            panic!("poison attempt");
        });
        assert!(matches!(r, Err(PoolInterrupt::Panicked(_))));
        // the panic was confined to the failing call: the very next call on
        // the same primitives runs every index exactly once
        let hits: Vec<AtomicU64> = (0..500).map(|_| AtomicU64::new(0)).collect();
        parallel_for_dynamic(500, 4, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn interrupted_collect_drops_partial_buffers() {
        let token = CancelToken::new();
        token.cancel();
        let r: Result<Vec<usize>, _> =
            try_parallel_collect(1000, 4, 8, Some(&token), |i, out| out.push(i));
        assert_eq!(r, Err(PoolInterrupt::Cancelled));
    }

    #[test]
    fn try_runner_matches_infallible_on_success() {
        let states =
            try_parallel_for_dynamic_scoped(100, 3, 7, None, || 0u64, |acc, _| *acc += 1).unwrap();
        assert_eq!(states.iter().sum::<u64>(), 100);
    }
}
