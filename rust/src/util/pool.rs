//! Persistent work-stealing executor for the interpreter's data-parallel
//! loops.
//!
//! rayon is unavailable; the interpreter backend and the handwritten
//! baselines need `parallel_for`-style vertex loops. Earlier revisions
//! spawned fresh threads per parallel region over `std::thread::scope`, so
//! every sweep and every frontier gather paid full thread fan-out (~tens of
//! microseconds × workers) — which is why small-frontier levels on mesh
//! graphs had to stay sequential. The executor here keeps a process-wide
//! pool of **parked workers**:
//!
//! - **Wake protocol**: workers park on a condvar; publishing a job bumps an
//!   epoch under the pool mutex and notifies. Dispatch is a wake (~single-
//!   digit microseconds), not a spawn. The submitting thread always
//!   participates as participant 0, so a region completes even if every
//!   worker is busy with another job — there is no queueing deadlock, and
//!   concurrent submitters (the execution service) share one pool.
//! - **Chunked work-stealing deques**: each participant owns a contiguous
//!   index range packed into one atomic word. The owner pops fixed-size
//!   chunks off the *front* (the order it would process sequentially —
//!   cache-friendly), idle participants steal the *back half* of a victim's
//!   remaining range in one CAS and continue from there. Skewed per-element
//!   cost (triangle counting on power-law graphs, the paper's TC blow-up
//!   case) rebalances without a shared counter in the hot path.
//! - **Scratch reuse**: [`Arena`] recycles per-worker scratch (register
//!   frames, claim buffers) across parallel regions, so a fixedPoint running
//!   hundreds of small-frontier rounds stops allocating per level.
//!
//! The dynamic runners remain the runtime's **fault boundary**: the `try_`
//! variants poll a [`CancelToken`] at every chunk claim and wrap each chunk's
//! user code in `catch_unwind`, so a deadline, an explicit cancel, or a
//! panicking kernel body surfaces as a typed [`PoolInterrupt`] from *this*
//! call only — the job's state is confined to the call, and the pool stays
//! healthy for the next one. On `Ok`, every index was processed exactly once
//! (the deque CAS transitions transfer ownership of each subrange exactly
//! once).
//!
//! Regions whose total work is at most one chunk run inline on the caller —
//! a 3-vertex frontier sweep costs no wake at all.
//!
//! `STARPLAT_THREADS` caps the per-call worker count exactly as before (the
//! callers pass it via [`default_threads`]); `STARPLAT_POOL_MAX` bounds how
//! many persistent workers the pool will ever park (default: available
//! parallelism − 1, at least 7 so thread-sweep tests exercise real
//! concurrency on small CI machines). [`shutdown`] drains and joins the
//! workers (idempotent; the pool lazily re-initializes on next use).

use crate::util::cancel::{CancelToken, Interrupt};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

/// Number of worker threads to use: respects STARPLAT_THREADS, defaults to
/// available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("STARPLAT_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Why a `try_` runner stopped early. The first interrupt observed wins;
/// other workers wind down at their next chunk claim.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PoolInterrupt {
    /// The [`CancelToken`] was cancelled.
    Cancelled,
    /// The [`CancelToken`]'s deadline passed.
    DeadlineExceeded,
    /// A worker's chunk panicked; the payload message is preserved. The pool
    /// itself stays healthy — the panic is confined to the failing call.
    Panicked(String),
}

impl From<Interrupt> for PoolInterrupt {
    fn from(i: Interrupt) -> PoolInterrupt {
        match i {
            Interrupt::Cancelled => PoolInterrupt::Cancelled,
            Interrupt::DeadlineExceeded => PoolInterrupt::DeadlineExceeded,
        }
    }
}

/// Best-effort text of a panic payload (`&str` and `String` payloads; every
/// `panic!` with a message produces one of those).
pub fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with non-string payload".to_string()
    }
}

// ---------------------------------------------------------------------------
// Pool statistics
// ---------------------------------------------------------------------------

static DISPATCHES: AtomicU64 = AtomicU64::new(0);
static STEALS: AtomicU64 = AtomicU64::new(0);
static DISPATCH_NS: AtomicU64 = AtomicU64::new(0);

/// Point-in-time counters of the persistent runtime. All monotonic; callers
/// (the bench harness) difference two snapshots around a timed region.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// parallel regions published to the worker pool (inline and
    /// single-thread regions are not dispatches)
    pub dispatches: u64,
    /// successful deque steals (a participant ran out of its own range and
    /// took the back half of another's)
    pub steals: u64,
    /// cumulative publish→first-worker-join latency in nanoseconds — the
    /// wake cost the persistent pool replaces thread spawning with
    pub dispatch_ns: u64,
    /// persistent workers currently parked or running (0 before first use
    /// and after [`shutdown`])
    pub workers: usize,
}

/// Snapshot the pool counters.
pub fn stats() -> PoolStats {
    let workers = POOL.get().map_or(0, |p| lock(&p.state).workers);
    PoolStats {
        dispatches: DISPATCHES.load(Ordering::Relaxed),
        steals: STEALS.load(Ordering::Relaxed),
        dispatch_ns: DISPATCH_NS.load(Ordering::Relaxed),
        workers,
    }
}

// ---------------------------------------------------------------------------
// Scratch arenas
// ---------------------------------------------------------------------------

/// A recycling bin for per-worker scratch values (register frames, claim
/// buffers). Parallel regions `take` a scratch value in their worker `init`
/// and the caller `put`s the final per-worker states back, so repeated
/// sweeps reuse allocations instead of reallocating per region. Returned
/// values keep whatever state they were put back with — takers must clear.
#[derive(Debug, Default)]
pub struct Arena<T> {
    slots: Mutex<Vec<T>>,
}

impl<T> Arena<T> {
    pub fn new() -> Arena<T> {
        Arena { slots: Mutex::new(Vec::new()) }
    }

    /// Pop a recycled value, if any.
    pub fn take(&self) -> Option<T> {
        lock(&self.slots).pop()
    }

    /// Return a value for reuse by a later region.
    pub fn put(&self, value: T) {
        lock(&self.slots).push(value);
    }

    /// Recycled values currently parked (test hook).
    pub fn len(&self) -> usize {
        lock(&self.slots).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// The persistent worker pool
// ---------------------------------------------------------------------------

/// Poison-tolerant lock: user code never runs under pool locks (panics are
/// caught at chunk granularity), but the executor must not turn a poisoned
/// mutex into a second panic.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// A type-erased parallel region a worker can participate in.
trait ParallelJob: Sync {
    fn run(&self, participant: usize);
}

/// One published region. `task` borrows the submitting call's stack frame;
/// the lifetime is erased to `'static` (see the safety argument in
/// [`run_job`]) and guarded by the join/finish handshake below: a worker
/// counts itself in `joined` while the job is still in the slab (under the
/// pool mutex), the submitter removes the job from the slab and snapshots
/// `joined` under the same mutex, then blocks until `finished` catches up —
/// so no worker can touch `task` after `run_job` returns.
struct ActiveJob {
    task: &'static (dyn ParallelJob + 'static),
    /// workers that claimed a participant slot (written under the pool mutex)
    joined: AtomicUsize,
    /// workers whose participation fully completed
    finished: Mutex<usize>,
    done: Condvar,
    /// publish time, for the wake-latency metric
    published: Instant,
    first_join: AtomicBool,
}

/// Slab entry: a job that still has unclaimed participant slots.
struct JobEntry {
    job: Arc<ActiveJob>,
    /// next participant index to hand out (0 is the submitter)
    next_slot: usize,
    slots_left: usize,
}

struct PoolState {
    jobs: Vec<JobEntry>,
    workers: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    wake: Condvar,
}

static POOL: OnceLock<Arc<PoolShared>> = OnceLock::new();

fn pool() -> &'static Arc<PoolShared> {
    POOL.get_or_init(|| {
        Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                jobs: Vec::new(),
                workers: 0,
                handles: Vec::new(),
                shutdown: false,
            }),
            wake: Condvar::new(),
        })
    })
}

/// Ceiling on persistent workers (`STARPLAT_POOL_MAX`; default available
/// parallelism − 1, at least 7 so the {1,2,8}-thread test sweeps exercise
/// real concurrency even on small CI machines).
fn worker_cap() -> usize {
    static CAP: OnceLock<usize> = OnceLock::new();
    *CAP.get_or_init(|| {
        if let Ok(v) = std::env::var("STARPLAT_POOL_MAX") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        default_threads().saturating_sub(1).max(7)
    })
}

/// Spawn workers (under the pool lock) until `target` are alive or the cap
/// is hit. Workers are lazily created on demand and then parked forever.
fn ensure_workers(shared: &Arc<PoolShared>, st: &mut PoolState, target: usize) {
    let target = target.min(worker_cap());
    while st.workers < target {
        let shared = Arc::clone(shared);
        let name = format!("starplat-worker-{}", st.workers);
        match std::thread::Builder::new().name(name).spawn(move || worker_loop(shared)) {
            Ok(h) => {
                st.handles.push(h);
                st.workers += 1;
            }
            Err(_) => break, // resource exhaustion: run with what we have
        }
    }
}

/// Claim a participant slot from any published job (caller holds the lock).
fn claim_slot(st: &mut PoolState) -> Option<(Arc<ActiveJob>, usize)> {
    let entry = st.jobs.iter_mut().find(|e| e.slots_left > 0)?;
    let slot = entry.next_slot;
    entry.next_slot += 1;
    entry.slots_left -= 1;
    entry.job.joined.fetch_add(1, Ordering::Relaxed);
    let job = Arc::clone(&entry.job);
    st.jobs.retain(|e| e.slots_left > 0);
    Some((job, slot))
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let (job, slot) = {
            let mut st = lock(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(claim) = claim_slot(&mut st) {
                    break claim;
                }
                st = shared.wake.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        if !job.first_join.swap(true, Ordering::Relaxed) {
            DISPATCH_NS.fetch_add(job.published.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        // belt and braces: RangeTask::run catches panics internally; nothing
        // may unwind through the worker loop, and `finished` must advance on
        // every exit path or the submitter would wait forever
        let _ = catch_unwind(AssertUnwindSafe(|| job.task.run(slot)));
        let mut fin = lock(&job.finished);
        *fin += 1;
        job.done.notify_all();
    }
}

/// Drain and join every persistent worker. Idempotent; the pool
/// re-initializes lazily on the next parallel region. Intended for tests and
/// orderly teardown — calling it while regions are in flight is safe (the
/// submitters finish their own work), just slow.
pub fn shutdown() {
    let Some(shared) = POOL.get() else { return };
    let handles = {
        let mut st = lock(&shared.state);
        st.shutdown = true;
        shared.wake.notify_all();
        std::mem::take(&mut st.handles)
    };
    for h in handles {
        let _ = h.join();
    }
    let mut st = lock(&shared.state);
    st.workers = 0;
    st.shutdown = false;
}

// ---------------------------------------------------------------------------
// The range task: chunked deques + stealing
// ---------------------------------------------------------------------------

/// Pack a half-open index range into one atomic word (`lo` high half, `hi`
/// low half). Ranges only ever shrink in place; a steal transfers the back
/// half to the thief's own deque in a single CAS.
#[inline]
fn pack(lo: usize, hi: usize) -> u64 {
    ((lo as u64) << 32) | hi as u64
}

#[inline]
fn unpack(v: u64) -> (usize, usize) {
    ((v >> 32) as usize, (v & 0xFFFF_FFFF) as usize)
}

/// Owner path: pop one chunk of up to `chunk` items off the front.
fn pop_front(cell: &AtomicU64, chunk: usize) -> Option<(usize, usize)> {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let (lo, hi) = unpack(cur);
        if lo >= hi {
            return None;
        }
        let mid = (lo + chunk).min(hi);
        match cell.compare_exchange_weak(cur, pack(mid, hi), Ordering::Relaxed, Ordering::Relaxed)
        {
            Ok(_) => return Some((lo, mid)),
            Err(now) => cur = now,
        }
    }
}

/// Thief path: take the back half of a victim's remaining range.
fn steal_back_half(cell: &AtomicU64) -> Option<(usize, usize)> {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let (lo, hi) = unpack(cur);
        if lo >= hi {
            return None;
        }
        let mid = lo + (hi - lo) / 2;
        match cell.compare_exchange_weak(cur, pack(lo, mid), Ordering::Relaxed, Ordering::Relaxed)
        {
            Ok(_) => return Some((mid, hi)),
            Err(now) => cur = now,
        }
    }
}

/// The region body shared by submitter and workers: per-participant deques,
/// per-participant scratch state, cooperative cancellation, per-chunk panic
/// walls, and the first-interrupt-wins record.
struct RangeTask<'a, T, I, F> {
    n: usize,
    chunk: usize,
    /// per-participant packed ranges; indices of participants that never
    /// join are still drained — by whoever steals them
    deques: Vec<AtomicU64>,
    /// shared-counter fallback for ranges too large to pack (n ≥ 2³²)
    counter: Option<AtomicUsize>,
    cancel: Option<&'a CancelToken>,
    init: &'a I,
    f: &'a F,
    first: Mutex<Option<PoolInterrupt>>,
    stop: AtomicBool,
    states: Mutex<Vec<T>>,
}

impl<'a, T, I, F> RangeTask<'a, T, I, F>
where
    T: Send,
    I: Fn() -> T + Sync,
    F: Fn(&mut T, usize) + Sync,
{
    fn new(
        n: usize,
        chunk: usize,
        participants: usize,
        cancel: Option<&'a CancelToken>,
        init: &'a I,
        f: &'a F,
    ) -> Self {
        let (deques, counter) = if n < u32::MAX as usize {
            // even initial partition: participant p owns [p·n/P, (p+1)·n/P)
            let d = (0..participants)
                .map(|p| {
                    let lo = p * n / participants;
                    let hi = (p + 1) * n / participants;
                    AtomicU64::new(pack(lo, hi))
                })
                .collect();
            (d, None)
        } else {
            (Vec::new(), Some(AtomicUsize::new(0)))
        };
        RangeTask {
            n,
            chunk,
            deques,
            counter,
            cancel,
            init,
            f,
            first: Mutex::new(None),
            stop: AtomicBool::new(false),
            states: Mutex::new(Vec::with_capacity(participants)),
        }
    }

    /// Record the first interrupt and tell every participant to wind down.
    fn record(&self, interrupt: PoolInterrupt) {
        let mut slot = lock(&self.first);
        if slot.is_none() {
            *slot = Some(interrupt);
        }
        self.stop.store(true, Ordering::Relaxed);
    }

    /// Claim the next chunk for participant `p`: poll cancellation, pop the
    /// own deque, then try to steal the back half of someone else's range.
    fn claim(&self, p: usize) -> Option<(usize, usize)> {
        if self.stop.load(Ordering::Relaxed) {
            return None;
        }
        if let Some(i) = self.cancel.and_then(|c| c.interrupted()) {
            self.record(i.into());
            return None;
        }
        if let Some(next) = &self.counter {
            // huge-range fallback: plain shared chunk counter
            let lo = next.fetch_add(self.chunk, Ordering::Relaxed);
            if lo >= self.n {
                return None;
            }
            return Some((lo, (lo + self.chunk).min(self.n)));
        }
        if let Some(r) = pop_front(&self.deques[p], self.chunk) {
            return Some(r);
        }
        // own range drained: steal. Scan the other deques round-robin from
        // our right-hand neighbor; install the stolen remainder as our own
        // range (only the owner ever *stores* to its deque, so an empty
        // deque can only grow back via this store).
        let q = self.deques.len();
        for k in 1..q {
            if let Some((lo, hi)) = steal_back_half(&self.deques[(p + k) % q]) {
                STEALS.fetch_add(1, Ordering::Relaxed);
                let mid = (lo + self.chunk).min(hi);
                self.deques[p].store(pack(mid, hi), Ordering::Relaxed);
                return Some((lo, mid));
            }
        }
        // nothing anywhere: all remaining work is claimed (possibly still
        // being processed by others) — this participant is done
        None
    }
}

impl<T, I, F> ParallelJob for RangeTask<'_, T, I, F>
where
    T: Send,
    I: Fn() -> T + Sync,
    F: Fn(&mut T, usize) + Sync,
{
    fn run(&self, participant: usize) {
        let mut state = match catch_unwind(AssertUnwindSafe(self.init)) {
            Ok(s) => s,
            Err(p) => {
                // a panic outside the per-chunk wall (in `init`)
                self.record(PoolInterrupt::Panicked(panic_message(p)));
                return;
            }
        };
        while let Some((lo, hi)) = self.claim(participant) {
            let state = &mut state;
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| {
                for i in lo..hi {
                    (self.f)(state, i);
                }
            })) {
                self.record(PoolInterrupt::Panicked(panic_message(p)));
                break;
            }
        }
        lock(&self.states).push(state);
    }
}

/// Publish `task` to the pool, participate as participant 0, and wait for
/// every joined worker to finish before returning.
fn run_job<T, I, F>(task: &RangeTask<'_, T, I, F>, extra: usize)
where
    T: Send,
    I: Fn() -> T + Sync,
    F: Fn(&mut T, usize) + Sync,
{
    // SAFETY of the lifetime erasure: `task` lives on this stack frame for
    // the whole function. A worker can only obtain the pointer by claiming a
    // participant slot *while the job is in the slab*, which counts it in
    // `joined` under the pool mutex. Below, we remove the job from the slab
    // and snapshot `joined` under the same mutex — after that no new worker
    // can reach the pointer — and then block until `finished == joined`, so
    // every worker that ever dereferenced `task` has completely finished
    // doing so before this frame is torn down. Panics cannot unwind through
    // the protocol: user code runs behind per-chunk `catch_unwind` walls.
    let erased: &(dyn ParallelJob + '_) = task;
    let erased: &'static (dyn ParallelJob + 'static) = unsafe { std::mem::transmute(erased) };
    let shared = pool();
    let job = Arc::new(ActiveJob {
        task: erased,
        joined: AtomicUsize::new(0),
        finished: Mutex::new(0),
        done: Condvar::new(),
        published: Instant::now(),
        first_join: AtomicBool::new(false),
    });
    {
        let mut st = lock(&shared.state);
        if !st.shutdown {
            let outstanding: usize = st.jobs.iter().map(|e| e.slots_left).sum();
            ensure_workers(shared, &mut st, outstanding + extra);
        }
        st.jobs.push(JobEntry { job: Arc::clone(&job), next_slot: 1, slots_left: extra });
        shared.wake.notify_all();
    }
    DISPATCHES.fetch_add(1, Ordering::Relaxed);

    task.run(0);

    let snapshot = {
        let mut st = lock(&shared.state);
        st.jobs.retain(|e| !Arc::ptr_eq(&e.job, &job));
        job.joined.load(Ordering::Relaxed)
    };
    let mut fin = lock(&job.finished);
    while *fin < snapshot {
        fin = job.done.wait(fin).unwrap_or_else(|e| e.into_inner());
    }
}

// ---------------------------------------------------------------------------
// Public runners (contracts unchanged from the scoped-pool era)
// ---------------------------------------------------------------------------

/// Run `f(i)` for every `i in 0..n`, statically partitioned over `threads`
/// workers (chunk = the whole initial share; stealing still rebalances a
/// straggler's tail). `f` must be `Sync` — all mutation must go through
/// atomics or interior-mutable cells, exactly like a GPU kernel body.
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    parallel_for_dynamic(n, threads, n.div_ceil(threads), f);
}

/// Dynamic (work-stealing) variant: participants pop fixed-size chunks off
/// their own deque and steal from each other when they run dry. Better for
/// skewed per-item cost (e.g. triangle counting on power-law graphs, the
/// paper's TC blow-up case).
pub fn parallel_for_dynamic<F>(n: usize, threads: usize, block: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    parallel_for_dynamic_scoped(n, threads, block, || (), |_, i| f(i));
}

/// Dynamic variant with per-worker scratch state: each participant calls
/// `init` once and reuses the resulting value across all chunks it claims.
/// The slot-resolved interpreter uses this to allocate one register frame
/// per worker instead of one per element (zero allocations on the
/// per-vertex path).
///
/// Returns the final per-worker states (order unspecified) — pure `for`
/// callers ignore it; [`parallel_collect`] uses the states as claim buffers.
///
/// Infallible wrapper over [`try_parallel_for_dynamic_scoped`] with no cancel
/// token; a worker panic is re-raised here, preserving the old contract.
pub fn parallel_for_dynamic_scoped<T, I, F>(
    n: usize,
    threads: usize,
    block: usize,
    init: I,
    f: F,
) -> Vec<T>
where
    T: Send,
    I: Fn() -> T + Sync,
    F: Fn(&mut T, usize) + Sync,
{
    match try_parallel_for_dynamic_scoped(n, threads, block, None, init, f) {
        Ok(states) => states,
        Err(PoolInterrupt::Panicked(msg)) => panic!("{msg}"),
        Err(other) => panic!("pool interrupted without a cancel token: {other:?}"),
    }
}

/// Fallible dynamic runner: the cooperative-cancellation and panic-isolation
/// boundary of the runtime.
///
/// At every chunk claim each participant polls `cancel`; a trip stops all
/// participants at their next claim and returns the corresponding
/// [`PoolInterrupt`]. Each chunk's `f` calls run inside `catch_unwind`, so a
/// panicking element poisons only this call: the first panic's message is
/// captured, the other participants wind down, the completion handshake
/// joins everyone who touched the region, and the caller gets
/// `Err(PoolInterrupt::Panicked(_))` instead of a propagating unwind.
///
/// On `Ok`, every index in `0..n` was processed exactly once; on `Err`, an
/// unspecified subset of chunks was processed (callers treat the work as
/// abandoned).
pub fn try_parallel_for_dynamic_scoped<T, I, F>(
    n: usize,
    threads: usize,
    block: usize,
    cancel: Option<&CancelToken>,
    init: I,
    f: F,
) -> Result<Vec<T>, PoolInterrupt>
where
    T: Send,
    I: Fn() -> T + Sync,
    F: Fn(&mut T, usize) + Sync,
{
    if n == 0 {
        return Ok(Vec::new());
    }
    let threads = threads.clamp(1, n);
    let block = block.max(1);
    // a region of at most one chunk runs inline: no wake, no deques — a
    // tiny frontier sweep costs what the equivalent sequential loop costs
    if threads == 1 || n <= block {
        let first = Mutex::new(None);
        let mut state = init();
        let mut lo = 0;
        while lo < n {
            if let Some(i) = cancel.and_then(|c| c.interrupted()) {
                let mut slot = lock(&first);
                if slot.is_none() {
                    *slot = Some(PoolInterrupt::from(i));
                }
                break;
            }
            let hi = (lo + block).min(n);
            let state = &mut state;
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| {
                for i in lo..hi {
                    f(state, i);
                }
            })) {
                let mut slot = lock(&first);
                if slot.is_none() {
                    *slot = Some(PoolInterrupt::Panicked(panic_message(p)));
                }
                break;
            }
            lo = hi;
        }
        return match first.into_inner().unwrap_or_else(|e| e.into_inner()) {
            Some(interrupt) => Err(interrupt),
            None => Ok(vec![state]),
        };
    }
    // no point waking more participants than there are chunks
    let participants = threads.min(n.div_ceil(block)).max(2);
    let task = RangeTask::new(n, block, participants, cancel, &init, &f);
    run_job(&task, participants - 1);
    let states = task.states.into_inner().unwrap_or_else(|e| e.into_inner());
    match task.first.into_inner().unwrap_or_else(|e| e.into_inner()) {
        Some(interrupt) => Err(interrupt),
        None => Ok(states),
    }
}

/// Parallel emit-collect: run `emit(i, &mut buf)` for every `i in 0..n`,
/// where each worker owns a private **claim buffer**; the buffers are then
/// concatenated into one `Vec` via prefix offsets (one `with_capacity`
/// allocation, one append per worker).
///
/// This is the frontier-gather primitive of the interpreter backend: after a
/// sweep, workers claim the vertices whose `nxt` bit the kernel set (an
/// atomic swap makes each claim exclusive, so no vertex is emitted twice)
/// and the next worklist is the concatenation. Element order *across*
/// workers is unspecified — callers must be order-independent, exactly like
/// a GPU frontier compaction.
pub fn parallel_collect<T, F>(n: usize, threads: usize, block: usize, emit: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, &mut Vec<T>) + Sync,
{
    match try_parallel_collect(n, threads, block, None, emit) {
        Ok(out) => out,
        Err(PoolInterrupt::Panicked(msg)) => panic!("{msg}"),
        Err(other) => panic!("pool interrupted without a cancel token: {other:?}"),
    }
}

/// Fallible [`parallel_collect`]: same claim-buffer gather, but cancellable
/// and panic-isolated like [`try_parallel_for_dynamic_scoped`]. On `Err` the
/// partial buffers are dropped — an interrupted gather yields no elements.
pub fn try_parallel_collect<T, F>(
    n: usize,
    threads: usize,
    block: usize,
    cancel: Option<&CancelToken>,
    emit: F,
) -> Result<Vec<T>, PoolInterrupt>
where
    T: Send,
    F: Fn(usize, &mut Vec<T>) + Sync,
{
    // the per-worker scratch of the dynamic-scoped runner IS the claim
    // buffer: one chunking implementation, not two
    let buffers = try_parallel_for_dynamic_scoped(n, threads, block, cancel, Vec::new, |buf, i| {
        emit(i, buf)
    })?;
    // prefix offsets: one exact allocation, each worker's buffer lands at
    // the running offset of the lengths before it
    let total = buffers.iter().map(Vec::len).sum();
    let mut out = Vec::with_capacity(total);
    for b in buffers {
        out.extend(b);
    }
    Ok(out)
}

/// [`try_parallel_collect`] with claim buffers recycled through `arena`:
/// worker buffers are taken from the arena (cleared), drained into the
/// concatenated result, and put back with their capacity intact — a
/// fixedPoint running hundreds of gather rounds stops allocating per round.
pub fn try_parallel_collect_in<T, F>(
    n: usize,
    threads: usize,
    block: usize,
    cancel: Option<&CancelToken>,
    arena: &Arena<Vec<T>>,
    emit: F,
) -> Result<Vec<T>, PoolInterrupt>
where
    T: Send,
    F: Fn(usize, &mut Vec<T>) + Sync,
{
    let init = || {
        let mut b = arena.take().unwrap_or_default();
        b.clear();
        b
    };
    let mut buffers =
        try_parallel_for_dynamic_scoped(n, threads, block, cancel, init, |buf, i| emit(i, buf))?;
    let total = buffers.iter().map(Vec::len).sum();
    let mut out = Vec::with_capacity(total);
    for b in &mut buffers {
        out.append(b); // drains b, keeps its capacity
    }
    for b in buffers {
        arena.put(b);
    }
    Ok(out)
}

/// Parallel map: collects `f(i)` into a Vec, preserving order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots: Vec<std::sync::Mutex<&mut T>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_for(n, threads, |i| {
            **slots[i].lock().unwrap() = f(i);
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    #[test]
    fn covers_all_indices() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for(1000, 4, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn dynamic_covers_all_indices() {
        let hits: Vec<AtomicU64> = (0..777).map(|_| AtomicU64::new(0)).collect();
        parallel_for_dynamic(777, 3, 16, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_and_single() {
        parallel_for(0, 4, |_| panic!("must not run"));
        let c = AtomicU64::new(0);
        parallel_for(1, 8, |_| {
            c.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(c.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn scoped_covers_all_indices_and_reuses_state() {
        let hits: Vec<AtomicU64> = (0..513).map(|_| AtomicU64::new(0)).collect();
        let inits = AtomicU64::new(0);
        parallel_for_dynamic_scoped(
            513,
            4,
            8,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
                vec![0u8; 4]
            },
            |scratch, i| {
                scratch[0] = scratch[0].wrapping_add(1);
                hits[i].fetch_add(1, Ordering::Relaxed);
            },
        );
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        // one frame per participant, not per element
        assert!(inits.load(Ordering::Relaxed) <= 4);
    }

    #[test]
    fn collect_emits_every_index_exactly_once() {
        for threads in [1, 3, 8] {
            let mut got = parallel_collect(997, threads, 16, |i, out| {
                if i % 3 == 0 {
                    out.push(i);
                }
            });
            got.sort_unstable();
            let want: Vec<usize> = (0..997).filter(|i| i % 3 == 0).collect();
            assert_eq!(got, want, "{threads} threads");
        }
        let empty: Vec<usize> = parallel_collect(0, 4, 8, |i, out| out.push(i));
        assert!(empty.is_empty());
    }

    #[test]
    fn collect_claim_buffers_are_exclusive_under_atomic_claims() {
        // the frontier-gather shape: many indices race to claim the same
        // cells; the swap makes each claim exclusive, so the concatenated
        // buffers contain each claimed cell exactly once
        let cells: Vec<AtomicU64> = (0..64).map(|_| AtomicU64::new(1)).collect();
        let mut got = parallel_collect(4096, 8, 32, |i, out| {
            let c = i % 64;
            if cells[c].swap(0, Ordering::Relaxed) == 1 {
                out.push(c);
            }
        });
        got.sort_unstable();
        assert_eq!(got, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn arena_collect_recycles_buffers_and_matches_plain_collect() {
        let arena: Arena<Vec<usize>> = Arena::new();
        for round in 0..3 {
            let mut got = match try_parallel_collect_in(500, 4, 16, None, &arena, |i, out| {
                if i % 7 == 0 {
                    out.push(i);
                }
            }) {
                Ok(v) => v,
                Err(e) => panic!("round {round}: {e:?}"),
            };
            got.sort_unstable();
            let want: Vec<usize> = (0..500).filter(|i| i % 7 == 0).collect();
            assert_eq!(got, want, "round {round}");
            // buffers came back for reuse
            assert!(!arena.is_empty(), "round {round}: no buffer recycled");
        }
    }

    #[test]
    fn arena_take_put_roundtrip() {
        let a: Arena<Vec<u32>> = Arena::new();
        assert!(a.take().is_none());
        a.put(vec![1, 2, 3]);
        assert_eq!(a.len(), 1);
        let v = a.take().unwrap();
        assert_eq!(v, vec![1, 2, 3]);
        assert!(a.take().is_none());
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(100, 4, |i| i * i);
        assert_eq!(v[7], 49);
        assert_eq!(v[99], 9801);
    }

    #[test]
    fn cancelled_token_stops_before_any_work() {
        for threads in [1, 4] {
            let token = CancelToken::new();
            token.cancel();
            let done = AtomicU64::new(0);
            let r = try_parallel_for_dynamic_scoped(
                10_000,
                threads,
                8,
                Some(&token),
                || (),
                |_, _| {
                    done.fetch_add(1, Ordering::Relaxed);
                },
            );
            assert_eq!(r, Err(PoolInterrupt::Cancelled), "{threads} threads");
            // participants poll before every chunk claim, so a pre-cancelled
            // token admits no chunks at all
            assert_eq!(done.load(Ordering::Relaxed), 0, "{threads} threads");
        }
    }

    #[test]
    fn expired_deadline_surfaces_as_interrupt() {
        let token = CancelToken::with_deadline(Duration::ZERO);
        let r = try_parallel_for_dynamic_scoped(1000, 4, 8, Some(&token), || (), |_, _| {});
        assert_eq!(r, Err(PoolInterrupt::DeadlineExceeded));
    }

    #[test]
    fn panic_in_block_becomes_typed_interrupt() {
        for threads in [1, 4] {
            let r = try_parallel_for_dynamic_scoped(
                1000,
                threads,
                8,
                None,
                || (),
                |_, i| {
                    if i == 137 {
                        panic!("boom at {i}");
                    }
                },
            );
            match r {
                Err(PoolInterrupt::Panicked(msg)) => {
                    assert!(msg.contains("boom at 137"), "message lost: {msg}");
                }
                other => panic!("expected Panicked, got {other:?} ({threads} threads)"),
            }
        }
    }

    #[test]
    fn panic_in_init_becomes_typed_interrupt() {
        let r = try_parallel_for_dynamic_scoped(
            1000,
            4,
            8,
            None,
            || -> () { panic!("init exploded") },
            |_, _| {},
        );
        match r {
            Err(PoolInterrupt::Panicked(msg)) => {
                assert!(msg.contains("init exploded"), "message lost: {msg}");
            }
            other => panic!("expected Panicked, got {other:?}"),
        }
    }

    #[test]
    fn pool_is_healthy_after_a_panicking_call() {
        let r = try_parallel_for_dynamic_scoped(64, 4, 4, None, || (), |_, _| {
            panic!("poison attempt");
        });
        assert!(matches!(r, Err(PoolInterrupt::Panicked(_))));
        // the panic was confined to the failing call: the very next call on
        // the same primitives runs every index exactly once
        let hits: Vec<AtomicU64> = (0..500).map(|_| AtomicU64::new(0)).collect();
        parallel_for_dynamic(500, 4, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn interrupted_collect_drops_partial_buffers() {
        let token = CancelToken::new();
        token.cancel();
        let r: Result<Vec<usize>, _> =
            try_parallel_collect(1000, 4, 8, Some(&token), |i, out| out.push(i));
        assert_eq!(r, Err(PoolInterrupt::Cancelled));
    }

    #[test]
    fn try_runner_matches_infallible_on_success() {
        let states =
            try_parallel_for_dynamic_scoped(100, 3, 7, None, || 0u64, |acc, _| *acc += 1).unwrap();
        assert_eq!(states.iter().sum::<u64>(), 100);
    }

    #[test]
    fn tiny_region_runs_inline_without_dispatch() {
        let before = stats().dispatches;
        // n <= block: must not publish a job to the pool at all
        let hits = AtomicU64::new(0);
        parallel_for_dynamic(32, 8, 64, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 32);
        // other tests may dispatch concurrently, so we can only assert this
        // call's contribution is zero when the process is otherwise quiet;
        // the strong form lives in tests/pool_runtime.rs (own process)
        let _ = before;
    }

    #[test]
    fn deque_pack_roundtrip_and_split() {
        assert_eq!(unpack(pack(0, 0)), (0, 0));
        assert_eq!(unpack(pack(17, 4096)), (17, 4096));
        let cell = AtomicU64::new(pack(0, 100));
        assert_eq!(pop_front(&cell, 16), Some((0, 16)));
        assert_eq!(steal_back_half(&cell), Some((58, 100)));
        assert_eq!(unpack(cell.load(Ordering::Relaxed)), (16, 58));
        // drain
        let mut seen = Vec::new();
        while let Some((lo, hi)) = pop_front(&cell, 16) {
            seen.extend(lo..hi);
        }
        assert_eq!(seen, (16..58).collect::<Vec<_>>());
        assert_eq!(steal_back_half(&cell), None);
    }
}
