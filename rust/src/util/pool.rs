//! A small scoped data-parallel executor.
//!
//! rayon is unavailable; the interpreter backend and the handwritten
//! baselines need `parallel_for`-style vertex loops. We implement static
//! chunking over `std::thread::scope`, which is enough for the regular,
//! balanced loops generated from the DSL (the paper's backends likewise use
//! static thread/block decompositions).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use: respects STARPLAT_THREADS, defaults to
/// available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("STARPLAT_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(i)` for every `i in 0..n`, statically chunked over `threads`
/// workers. `f` must be `Sync` — all mutation must go through atomics or
/// interior-mutable cells, exactly like a GPU kernel body.
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || {
                for i in lo..hi {
                    f(i);
                }
            });
        }
    });
}

/// Dynamic (work-stealing-ish) variant: workers grab fixed-size blocks from a
/// shared counter. Better for skewed per-item cost (e.g. triangle counting on
/// power-law graphs, the paper's TC blow-up case).
pub fn parallel_for_dynamic<F>(n: usize, threads: usize, block: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    parallel_for_dynamic_scoped(n, threads, block, || (), |_, i| f(i));
}

/// Dynamic variant with per-worker scratch state: each worker calls `init`
/// once and reuses the resulting value across all blocks it claims. The
/// slot-resolved interpreter uses this to allocate one register frame per
/// worker instead of one per element (zero allocations on the per-vertex
/// path).
///
/// Returns the final per-worker states in worker order — pure `for` callers
/// ignore it; [`parallel_collect`] uses the states as claim buffers.
pub fn parallel_for_dynamic_scoped<T, I, F>(
    n: usize,
    threads: usize,
    block: usize,
    init: I,
    f: F,
) -> Vec<T>
where
    T: Send,
    I: Fn() -> T + Sync,
    F: Fn(&mut T, usize) + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        let mut state = init();
        for i in 0..n {
            f(&mut state, i);
        }
        return vec![state];
    }
    let block = block.max(1);
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let f = &f;
                let init = &init;
                let next = &next;
                s.spawn(move || {
                    let mut state = init();
                    loop {
                        let lo = next.fetch_add(block, Ordering::Relaxed);
                        if lo >= n {
                            break;
                        }
                        let hi = (lo + block).min(n);
                        for i in lo..hi {
                            f(&mut state, i);
                        }
                    }
                    state
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Parallel emit-collect: run `emit(i, &mut buf)` for every `i in 0..n`,
/// where each worker owns a private **claim buffer**; the buffers are then
/// concatenated into one `Vec` via prefix offsets (one `with_capacity`
/// allocation, one append per worker).
///
/// This is the frontier-gather primitive of the interpreter backend: after a
/// sweep, workers claim the vertices whose `nxt` bit the kernel set (an
/// atomic swap makes each claim exclusive, so no vertex is emitted twice)
/// and the next worklist is the concatenation. Element order *across*
/// workers is unspecified — callers must be order-independent, exactly like
/// a GPU frontier compaction.
pub fn parallel_collect<T, F>(n: usize, threads: usize, block: usize, emit: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, &mut Vec<T>) + Sync,
{
    // the per-worker scratch of the dynamic-scoped runner IS the claim
    // buffer: one chunking implementation, not two
    let buffers = parallel_for_dynamic_scoped(n, threads, block, Vec::new, |buf, i| emit(i, buf));
    // prefix offsets: one exact allocation, each worker's buffer lands at
    // the running offset of the lengths before it
    let total = buffers.iter().map(Vec::len).sum();
    let mut out = Vec::with_capacity(total);
    for b in buffers {
        out.extend(b);
    }
    out
}

/// Parallel map: collects `f(i)` into a Vec, preserving order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots: Vec<std::sync::Mutex<&mut T>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_for(n, threads, |i| {
            **slots[i].lock().unwrap() = f(i);
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for(1000, 4, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn dynamic_covers_all_indices() {
        let hits: Vec<AtomicU64> = (0..777).map(|_| AtomicU64::new(0)).collect();
        parallel_for_dynamic(777, 3, 16, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_and_single() {
        parallel_for(0, 4, |_| panic!("must not run"));
        let c = AtomicU64::new(0);
        parallel_for(1, 8, |_| {
            c.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(c.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn scoped_covers_all_indices_and_reuses_state() {
        let hits: Vec<AtomicU64> = (0..513).map(|_| AtomicU64::new(0)).collect();
        let inits = AtomicU64::new(0);
        parallel_for_dynamic_scoped(
            513,
            4,
            8,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
                vec![0u8; 4]
            },
            |scratch, i| {
                scratch[0] = scratch[0].wrapping_add(1);
                hits[i].fetch_add(1, Ordering::Relaxed);
            },
        );
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        // one frame per worker, not per element
        assert!(inits.load(Ordering::Relaxed) <= 4);
    }

    #[test]
    fn collect_emits_every_index_exactly_once() {
        for threads in [1, 3, 8] {
            let mut got = parallel_collect(997, threads, 16, |i, out| {
                if i % 3 == 0 {
                    out.push(i);
                }
            });
            got.sort_unstable();
            let want: Vec<usize> = (0..997).filter(|i| i % 3 == 0).collect();
            assert_eq!(got, want, "{threads} threads");
        }
        let empty: Vec<usize> = parallel_collect(0, 4, 8, |i, out| out.push(i));
        assert!(empty.is_empty());
    }

    #[test]
    fn collect_claim_buffers_are_exclusive_under_atomic_claims() {
        // the frontier-gather shape: many indices race to claim the same
        // cells; the swap makes each claim exclusive, so the concatenated
        // buffers contain each claimed cell exactly once
        let cells: Vec<AtomicU64> = (0..64).map(|_| AtomicU64::new(1)).collect();
        let mut got = parallel_collect(4096, 8, 32, |i, out| {
            let c = i % 64;
            if cells[c].swap(0, Ordering::Relaxed) == 1 {
                out.push(c);
            }
        });
        got.sort_unstable();
        assert_eq!(got, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(100, 4, |i| i * i);
        assert_eq!(v[7], 49);
        assert_eq!(v[99], 9801);
    }
}
