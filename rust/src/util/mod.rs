//! Dependency-free substrates: PRNG, JSON, tables, parallel loops, benching.

pub mod atomics;
pub mod bench;
pub mod cancel;
pub mod fault;
pub mod json;
pub mod pool;
pub mod rng;
pub mod table;

/// Count non-blank, non-comment-only lines in a source string — used for the
/// paper's §5 lines-of-code comparison across backends.
pub fn count_loc(src: &str) -> usize {
    src.lines()
        .map(str::trim)
        .filter(|l| {
            let code = !l.is_empty() && !l.starts_with("//") && !l.starts_with('#');
            // #pragma / #include are real code even though they start with '#'.
            code || l.starts_with("#pragma")
                || l.starts_with("#include")
                || l.starts_with("#define")
        })
        .count()
}

#[cfg(test)]
mod tests {
    #[test]
    fn loc_counts_pragmas_not_comments() {
        let src = "// c\n\nint x;\n#pragma acc parallel loop\n# plain comment\n";
        assert_eq!(super::count_loc(src), 2);
    }
}
