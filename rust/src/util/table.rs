//! ASCII table rendering for benchmark reports (paper-style tables).

/// A simple column-aligned table with a header row.
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .chain(std::iter::once("+\n".to_string()))
            .collect();
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                line.push_str(&format!("| {:<width$} ", cells[i], width = widths[i]));
            }
            line.push_str("|\n");
            line
        };
        out.push_str(&sep);
        out.push_str(&fmt_row(&self.header));
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&fmt_row(r));
        }
        out.push_str(&sep);
        out
    }
}

/// Format a duration in seconds the way the paper's tables do (3 decimals,
/// with OOT/OOM markers passed through).
pub fn fmt_secs(s: f64) -> String {
    if s < 0.0005 {
        format!("{:.5}", s)
    } else {
        format!("{:.3}", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["graph", "time"]);
        t.row(vec!["RM".into(), "1.234".into()]);
        t.row(vec!["longer-name".into(), "0.5".into()]);
        let s = t.render();
        assert!(s.contains("## demo"));
        assert!(s.contains("| graph       | time  |"));
        assert!(s.lines().all(|l| {
            l.is_empty() || l.starts_with('+') || l.starts_with('|') || l.starts_with('#')
        }));
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(1.2345), "1.234");
        assert_eq!(fmt_secs(0.0001), "0.00010");
    }
}
