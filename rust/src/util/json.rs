//! Minimal JSON value type, parser, and pretty-printer.
//!
//! serde is not available in this environment; the artifact manifest and the
//! DSL compiler's host plans are small JSON documents, so a compact
//! hand-rolled implementation is sufficient and dependency-free.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; returns Null for missing keys on non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    fn write(&self, f: &mut fmt::Formatter<'_>, indent: usize) -> fmt::Result {
        let pad = |f: &mut fmt::Formatter<'_>, n: usize| write!(f, "{:width$}", "", width = n * 2);
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    return write!(f, "[]");
                }
                writeln!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    pad(f, indent + 1)?;
                    v.write(f, indent + 1)?;
                    if i + 1 < a.len() {
                        write!(f, ",")?;
                    }
                    writeln!(f)?;
                }
                pad(f, indent)?;
                write!(f, "]")
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    return write!(f, "{{}}");
                }
                writeln!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    pad(f, indent + 1)?;
                    write_escaped(f, k)?;
                    write!(f, ": ")?;
                    v.write(f, indent + 1)?;
                    if i + 1 < o.len() {
                        write!(f, ",")?;
                    }
                    writeln!(f)?;
                }
                pad(f, indent)?;
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write(f, 0)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c => {
                    // Re-decode UTF-8 starting at pos-1.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = (start + len).min(self.b.len());
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": true, "d": null}, "s": "x\ny"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").get("c").as_bool(), Some(true));
        assert_eq!(v.get("s").as_str(), Some("x\ny"));
        // print → reparse is identity
        let printed = v.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-12.5e2").unwrap().as_f64(), Some(-1250.0));
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse("\"caf\u{e9} \\u00e9\"").unwrap();
        assert_eq!(v.as_str(), Some("café é"));
    }

    #[test]
    fn get_on_missing_is_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(*v.get("nope"), Json::Null);
    }
}
