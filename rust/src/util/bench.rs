//! Criterion-lite: a dependency-free measurement harness for `cargo bench`.
//!
//! The registry's criterion crate is unavailable offline, so the bench
//! binaries (declared `harness = false`) use this module: warmup, repeated
//! timed runs, robust statistics, and optional wall-clock budgets (the
//! paper's one-hour OOT cells are reproduced with a scaled timeout).

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>, // seconds per run
}

impl Measurement {
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        if n % 2 == 1 {
            s[n / 2]
        } else {
            0.5 * (s[n / 2 - 1] + s[n / 2])
        }
    }
    pub fn stddev(&self) -> f64 {
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / self.samples.len().max(1) as f64;
        var.sqrt()
    }
    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }
}

/// Outcome of a bench cell: a time, or the paper's OOT/OOM markers.
#[derive(Clone, Debug)]
pub enum Cell {
    Secs(f64),
    OutOfTime,
    OutOfMemory,
    Unsupported, // the paper's "-" cells
}

impl Cell {
    pub fn display(&self) -> String {
        match self {
            Cell::Secs(s) => crate::util::table::fmt_secs(*s),
            Cell::OutOfTime => "OOT".into(),
            Cell::OutOfMemory => "OOM".into(),
            Cell::Unsupported => "-".into(),
        }
    }
    pub fn secs(&self) -> Option<f64> {
        match self {
            Cell::Secs(s) => Some(*s),
            _ => None,
        }
    }
}

/// Bench configuration.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup: usize,
    pub runs: usize,
    /// Per-cell budget; a run exceeding it marks the cell OOT (scaled stand-in
    /// for the paper's one-hour timeout).
    pub timeout: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        // Quick mode (STARPLAT_BENCH_QUICK=1) keeps CI fast on 1 CPU.
        let quick = std::env::var("STARPLAT_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
        BenchConfig {
            warmup: if quick { 0 } else { 1 },
            runs: if quick { 1 } else { 3 },
            timeout: Duration::from_secs(
                std::env::var("STARPLAT_BENCH_TIMEOUT_S")
                    .ok()
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(30),
            ),
        }
    }
}

/// Time a closure under the config. Returns OOT if the *first* run exceeds
/// the budget (subsequent runs are then skipped).
pub fn bench_cell<F: FnMut()>(cfg: &BenchConfig, mut f: F) -> Cell {
    for _ in 0..cfg.warmup {
        let t = Instant::now();
        f();
        if t.elapsed() > cfg.timeout {
            return Cell::OutOfTime;
        }
    }
    let mut samples = Vec::with_capacity(cfg.runs);
    for i in 0..cfg.runs {
        let t = Instant::now();
        f();
        let el = t.elapsed();
        if el > cfg.timeout && i == 0 {
            return Cell::OutOfTime;
        }
        samples.push(el.as_secs_f64());
    }
    let m = Measurement { name: String::new(), samples };
    Cell::Secs(m.median())
}

/// Convenience: time one invocation.
pub fn time_once<F: FnOnce() -> T, T>(f: F) -> (f64, T) {
    let t = Instant::now();
    let out = f();
    (t.elapsed().as_secs_f64(), out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let m = Measurement { name: "x".into(), samples: vec![1.0, 2.0, 3.0, 4.0] };
        assert!((m.mean() - 2.5).abs() < 1e-12);
        assert!((m.median() - 2.5).abs() < 1e-12);
        assert!((m.min() - 1.0).abs() < 1e-12);
        assert!(m.stddev() > 1.0 && m.stddev() < 1.2);
    }

    #[test]
    fn bench_returns_secs() {
        let cfg = BenchConfig { warmup: 0, runs: 2, timeout: Duration::from_secs(5) };
        let c = bench_cell(&cfg, || {
            std::hint::black_box(1 + 1);
        });
        assert!(c.secs().unwrap() < 1.0);
    }

    #[test]
    fn bench_oot() {
        let cfg = BenchConfig { warmup: 0, runs: 1, timeout: Duration::from_millis(1) };
        let c = bench_cell(&cfg, || std::thread::sleep(Duration::from_millis(10)));
        assert!(matches!(c, Cell::OutOfTime));
    }

    #[test]
    fn cell_display() {
        assert_eq!(Cell::OutOfMemory.display(), "OOM");
        assert_eq!(Cell::Unsupported.display(), "-");
        assert_eq!(Cell::Secs(1.5).display(), "1.500");
    }
}
