//! Deterministic fault injection for the interpreter runtime.
//!
//! Robustness paths (panic isolation, sparse→dense fallback, typed reduce
//! errors) are worthless if they only run when something actually breaks, so
//! the runtime carries named **fault points** that a [`FaultPlan`] can trip on
//! purpose:
//!
//! | site            | where it fires                                   | effect            |
//! |-----------------|--------------------------------------------------|-------------------|
//! | `pool_dispatch` | per element inside a sweep worker                | injected `panic!` |
//! | `claim_gather`  | per frontier iteration, before the claim gather  | dense fallback    |
//! | `atomic_reduce` | per reduce executed by a kernel                  | typed `Err`       |
//!
//! Whether a point fires is a **pure function** of `(site, seed, salt, key)` —
//! no global RNG state, no time, no thread identity — so a fixed seed replays
//! the exact same faults no matter how requests interleave across threads.
//! The `salt` distinguishes requests (the service salts each request with a
//! caller-supplied index); the `key` distinguishes firings within a run
//! (vertex id, iteration index, reduce target).
//!
//! Enable globally with `STARPLAT_FAULT=<site>:<seed>:<rate>`, e.g.
//! `STARPLAT_FAULT=pool_dispatch:7:0.002`, or per run via
//! `ExecOpts::fault` / `Request::fault` (which override the environment).

use crate::util::rng::splitmix64;
use std::sync::OnceLock;

/// A named fault point in the runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// Inside a pool worker, per swept element: injects a panic, exercising
    /// the `catch_unwind` wall at the pool boundary.
    PoolDispatch,
    /// At a frontier iteration boundary, before the claim-buffer gather:
    /// abandons the sparse schedule for the dense one (graceful degradation).
    ClaimGather,
    /// At an atomic reduce executed by a kernel: surfaces a typed error.
    AtomicReduce,
}

impl FaultSite {
    pub const ALL: [FaultSite; 3] =
        [FaultSite::PoolDispatch, FaultSite::ClaimGather, FaultSite::AtomicReduce];

    pub fn name(self) -> &'static str {
        match self {
            FaultSite::PoolDispatch => "pool_dispatch",
            FaultSite::ClaimGather => "claim_gather",
            FaultSite::AtomicReduce => "atomic_reduce",
        }
    }

    pub fn parse(s: &str) -> Option<FaultSite> {
        FaultSite::ALL.into_iter().find(|site| site.name() == s)
    }
}

/// A seeded plan deciding which fault-point firings trip.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    pub site: FaultSite,
    pub seed: u64,
    /// Probability in [0, 1] that a given `(salt, key)` trips the site.
    pub rate: f64,
    /// Request-scoped discriminator, mixed into every decision. Must come
    /// from the caller (e.g. a request index), never from shared mutable
    /// state, or determinism under concurrency is lost.
    pub salt: u64,
}

impl FaultPlan {
    pub fn new(site: FaultSite, seed: u64, rate: f64) -> FaultPlan {
        FaultPlan { site, seed, rate, salt: 0 }
    }

    /// A plan that never fires — lets callers force faults *off* even when
    /// `STARPLAT_FAULT` is set (e.g. oracle runs in the stress test).
    pub fn off() -> FaultPlan {
        FaultPlan::new(FaultSite::PoolDispatch, 0, 0.0)
    }

    /// The same plan rescoped to one request.
    pub fn salted(self, salt: u64) -> FaultPlan {
        FaultPlan { salt, ..self }
    }

    /// Parse a `<site>:<seed>:<rate>` spec (the `STARPLAT_FAULT` format).
    pub fn parse_spec(spec: &str) -> Result<FaultPlan, String> {
        let parts: Vec<&str> = spec.split(':').collect();
        if parts.len() != 3 {
            return Err(format!("bad fault spec `{spec}`: expected <site>:<seed>:<rate>"));
        }
        let site = FaultSite::parse(parts[0]).ok_or_else(|| {
            format!("unknown fault site `{}` (pool_dispatch|claim_gather|atomic_reduce)", parts[0])
        })?;
        let seed: u64 = parts[1].parse().map_err(|_| format!("bad fault seed `{}`", parts[1]))?;
        let rate: f64 = parts[2].parse().map_err(|_| format!("bad fault rate `{}`", parts[2]))?;
        if !(0.0..=1.0).contains(&rate) {
            return Err(format!("fault rate {rate} outside [0, 1]"));
        }
        Ok(FaultPlan::new(site, seed, rate))
    }

    /// The process-wide plan from `STARPLAT_FAULT`, if any. Read once and
    /// cached; a malformed spec warns to stderr and disables injection
    /// rather than silently corrupting runs.
    pub fn from_env() -> Option<FaultPlan> {
        static PLAN: OnceLock<Option<FaultPlan>> = OnceLock::new();
        *PLAN.get_or_init(|| match std::env::var("STARPLAT_FAULT") {
            Ok(spec) if !spec.is_empty() => match FaultPlan::parse_spec(&spec) {
                Ok(p) => Some(p),
                Err(e) => {
                    eprintln!("warning: ignoring STARPLAT_FAULT: {e}");
                    None
                }
            },
            _ => None,
        })
    }

    /// Does this firing of `site` (discriminated by `key`) trip? Pure in
    /// `(self, site, key)`.
    #[inline]
    pub fn fires(&self, site: FaultSite, key: u64) -> bool {
        if site != self.site || self.rate <= 0.0 {
            return false;
        }
        if self.rate >= 1.0 {
            return true;
        }
        let mut x = self.seed.wrapping_mul(0xA24BAED4963EE407)
            ^ self.salt.wrapping_mul(0xD1B54A32D192ED03)
            ^ key.wrapping_mul(0x9E3779B97F4A7C15);
        let z = splitmix64(&mut x);
        ((z >> 11) as f64) * (1.0 / (1u64 << 53) as f64) < self.rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_deterministic() {
        let site = FaultSite::PoolDispatch;
        let a = FaultPlan::new(site, 42, 0.25).salted(7);
        let b = FaultPlan::new(site, 42, 0.25).salted(7);
        for key in 0..512 {
            assert_eq!(a.fires(site, key), b.fires(site, key));
        }
    }

    #[test]
    fn rate_zero_never_fires_and_rate_one_always_fires() {
        let off = FaultPlan::new(FaultSite::AtomicReduce, 1, 0.0);
        let on = FaultPlan::new(FaultSite::AtomicReduce, 1, 1.0);
        for key in 0..256 {
            assert!(!off.fires(FaultSite::AtomicReduce, key));
            assert!(on.fires(FaultSite::AtomicReduce, key));
        }
        assert!(!FaultPlan::off().fires(FaultSite::PoolDispatch, 3));
    }

    #[test]
    fn other_sites_never_fire() {
        let plan = FaultPlan::new(FaultSite::ClaimGather, 9, 1.0);
        for key in 0..64 {
            assert!(plan.fires(FaultSite::ClaimGather, key));
            assert!(!plan.fires(FaultSite::PoolDispatch, key));
            assert!(!plan.fires(FaultSite::AtomicReduce, key));
        }
    }

    #[test]
    fn rate_is_roughly_respected() {
        let plan = FaultPlan::new(FaultSite::PoolDispatch, 1234, 0.1);
        let hits = (0..10_000).filter(|&k| plan.fires(FaultSite::PoolDispatch, k)).count();
        assert!((700..1300).contains(&hits), "hits {hits} far from 10% of 10000");
    }

    #[test]
    fn salt_rescopes_decisions() {
        let site = FaultSite::PoolDispatch;
        let base = FaultPlan::new(site, 5, 0.5);
        let a = base.salted(1);
        let b = base.salted(2);
        let differing = (0..1000).filter(|&k| a.fires(site, k) != b.fires(site, k)).count();
        assert!(differing > 100, "salts produced near-identical decisions ({differing})");
    }

    #[test]
    fn parse_spec_round_trips() {
        let p = FaultPlan::parse_spec("claim_gather:77:0.125").unwrap();
        assert_eq!(p.site, FaultSite::ClaimGather);
        assert_eq!(p.seed, 77);
        assert_eq!(p.rate, 0.125);
        assert_eq!(p.salt, 0);
    }

    #[test]
    fn parse_spec_rejects_malformed() {
        for bad in [
            "",
            "pool_dispatch",
            "pool_dispatch:1",
            "nowhere:1:0.5",
            "pool_dispatch:x:0.5",
            "pool_dispatch:1:nan",
            "pool_dispatch:1:1.5",
            "pool_dispatch:1:-0.1",
            "pool_dispatch:1:0.5:extra",
        ] {
            assert!(FaultPlan::parse_spec(bad).is_err(), "accepted `{bad}`");
        }
    }

    #[test]
    fn site_names_round_trip() {
        for site in FaultSite::ALL {
            assert_eq!(FaultSite::parse(site.name()), Some(site));
        }
        assert_eq!(FaultSite::parse("bogus"), None);
    }
}
