//! Deterministic pseudo-random number generation (splitmix64 + xoshiro256**).
//!
//! The environment ships no `rand` crate, and the graph generators / property
//! tests need seedable, reproducible streams, so we carry our own small PRNG.
//! splitmix64 is used to expand a user seed into xoshiro256** state, which is
//! the generator actually used for sampling.

/// splitmix64 step — used for seeding and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, bound) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi) (half-open).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), order unspecified.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm: O(k) expected time, no O(n) allocation.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below((j + 1) as u64) as usize;
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} too far from 0.5");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_no_dups() {
        let mut r = Rng::new(5);
        for _ in 0..50 {
            let s = r.sample_distinct(40, 17);
            assert_eq!(s.len(), 17);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 17);
            assert!(s.iter().all(|&x| x < 40));
        }
    }
}
