//! # starplat-rs
//!
//! Reproduction of *"Code Generation for a Variety of Accelerators for a
//! Graph DSL"* (StarPlat, CS.DC 2024) as a three-layer Rust + JAX + Pallas
//! system:
//!
//! - **DSL front-end** ([`dsl`], [`sema`]) — the StarPlat language.
//! - **IR + analyses** ([`ir`]) — the paper's §4 backend optimizations.
//! - **Code generators** ([`codegen`]) — CUDA / OpenCL / SYCL / OpenACC text
//!   emitters (validated against the paper's Figures 2–12) plus the JAX
//!   backend that produces the executable accelerator path.
//! - **Execution backends** ([`backends`]) — a parallel CPU interpreter and
//!   an XLA/PJRT driver for AOT-compiled artifacts.
//! - **Substrates** — graph storage and generators ([`graph`]), handwritten
//!   Gunrock/Lonestar-style baselines ([`algorithms`]), the experiment
//!   coordinator ([`coordinator`]) and dependency-free utilities ([`util`]).
//!
//! See `DESIGN.md` for the full inventory and `EXPERIMENTS.md` for results.

pub mod algorithms;
pub mod backends;
pub mod cli;
pub mod codegen;
pub mod coordinator;
pub mod dsl;
pub mod graph;
pub mod ir;
pub mod runtime;
pub mod sema;
pub mod util;
pub mod xla_stub;
