//! Abstract syntax tree for the StarPlat DSL (paper §2.1).

use super::token::Span;

#[derive(Clone, Debug, PartialEq)]
pub enum Type {
    Int,
    Bool,
    Long,
    Float,
    Double,
    Node,
    Edge,
    Graph,
    PropNode(Box<Type>),
    PropEdge(Box<Type>),
    /// `SetN<g>` — a set of nodes of graph `g`.
    SetN(String),
}

impl Type {
    pub fn is_numeric(&self) -> bool {
        matches!(self, Type::Int | Type::Long | Type::Float | Type::Double | Type::Node)
    }
    pub fn is_prop(&self) -> bool {
        matches!(self, Type::PropNode(_) | Type::PropEdge(_))
    }
    /// C-style display, used by error messages and code generators.
    pub fn display(&self) -> String {
        match self {
            Type::Int => "int".into(),
            Type::Bool => "bool".into(),
            Type::Long => "long".into(),
            Type::Float => "float".into(),
            Type::Double => "double".into(),
            Type::Node => "node".into(),
            Type::Edge => "edge".into(),
            Type::Graph => "Graph".into(),
            Type::PropNode(t) => format!("propNode<{}>", t.display()),
            Type::PropEdge(t) => format!("propEdge<{}>", t.display()),
            Type::SetN(g) => format!("SetN<{g}>"),
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct Param {
    pub name: String,
    pub ty: Type,
    pub span: Span,
}

#[derive(Clone, Debug, PartialEq)]
pub struct Function {
    pub name: String,
    pub params: Vec<Param>,
    pub body: Block,
    pub span: Span,
}

pub type Block = Vec<Stmt>;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOp {
    /// `+=` — Sum
    Add,
    /// `*=` — Product
    Mul,
    /// `++` — Count
    Count,
    /// `&&=` — All
    And,
    /// `||=` — Any
    Or,
}

impl ReduceOp {
    pub fn symbol(&self) -> &'static str {
        match self {
            ReduceOp::Add => "+=",
            ReduceOp::Mul => "*=",
            ReduceOp::Count => "++",
            ReduceOp::And => "&&=",
            ReduceOp::Or => "||=",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MinMax {
    Min,
    Max,
}

/// Assignment targets: plain variables, property reads (`v.dist`), or whole
/// properties (`modified = modified_nxt`).
#[derive(Clone, Debug, PartialEq)]
pub enum LValue {
    Var(String),
    /// `obj.prop` where obj is a node/edge-typed variable.
    Prop { obj: String, prop: String },
}

#[derive(Clone, Debug, PartialEq)]
pub enum IterSource {
    /// `g.nodes()`
    Nodes { graph: String },
    /// `g.neighbors(v)`
    Neighbors { graph: String, of: String },
    /// `g.nodes_to(v)` — in-neighbors
    NodesTo { graph: String, of: String },
    /// items of a `SetN` parameter
    Set { set: String },
}

#[derive(Clone, Debug, PartialEq)]
pub struct Iterator_ {
    pub var: String,
    pub source: IterSource,
    /// `.filter(<expr>)` — predicate over the loop variable.
    pub filter: Option<Expr>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Stmt {
    /// `int x;` / `propNode<float> sigma;` / `edge e = g.get_edge(v, nbr);`
    Decl { ty: Type, name: String, init: Option<Expr>, span: Span },
    /// `x = e;` `v.p = e;` (plain store)
    Assign { target: LValue, value: Expr, span: Span },
    /// Reduction: `x += e;`, `cnt++;`, `flag &&= e;` (Table 1)
    Reduce { target: LValue, op: ReduceOp, value: Expr, span: Span },
    /// `<a.p, b.q> = <Min(a.p, e), v>;` — atomic multi-assign (§3.5)
    MinMaxAssign {
        kind: MinMax,
        /// first target and its proposed value (the Min/Max pair)
        target: LValue,
        compare: Expr,
        /// extra (target, value) pairs updated only if the Min/Max won
        extra: Vec<(LValue, Expr)>,
        span: Span,
    },
    /// `g.attachNodeProperty(p1 = e1, p2 = e2, ...);`
    AttachNodeProperty { graph: String, inits: Vec<(String, Expr)>, span: Span },
    /// `for (v in ...) { }` (sequential) / `forall (v in ...) { }` (parallel)
    For { iter: Iterator_, body: Block, parallel: bool, span: Span },
    /// `iterateInBFS(v in g.nodes() from src) { .. }` with optional
    /// `iterateInReverse(v != src) { .. }` tail (§3.4)
    IterateBFS {
        var: String,
        graph: String,
        from: String,
        body: Block,
        reverse: Option<(Expr, Block)>,
        span: Span,
    },
    /// `fixedPoint until (var: !prop) { .. }` (§3.6)
    FixedPoint { var: String, cond: Expr, body: Block, span: Span },
    DoWhile { body: Block, cond: Expr, span: Span },
    While { cond: Expr, body: Block, span: Span },
    If { cond: Expr, then: Block, els: Option<Block>, span: Span },
    Return { value: Expr, span: Span },
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Lt,
    Gt,
    Le,
    Ge,
    Eq,
    Ne,
    And,
    Or,
}

impl BinOp {
    pub fn symbol(&self) -> &'static str {
        match self {
            BinOp::Add => "+",
            BinOp::Sub => "-",
            BinOp::Mul => "*",
            BinOp::Div => "/",
            BinOp::Mod => "%",
            BinOp::Lt => "<",
            BinOp::Gt => ">",
            BinOp::Le => "<=",
            BinOp::Ge => ">=",
            BinOp::Eq => "==",
            BinOp::Ne => "!=",
            BinOp::And => "&&",
            BinOp::Or => "||",
        }
    }
    pub fn is_comparison(&self) -> bool {
        matches!(self, BinOp::Lt | BinOp::Gt | BinOp::Le | BinOp::Ge | BinOp::Eq | BinOp::Ne)
    }
    pub fn is_logical(&self) -> bool {
        matches!(self, BinOp::And | BinOp::Or)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnOp {
    Neg,
    Not,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    IntLit(i64),
    FloatLit(f64),
    BoolLit(bool),
    /// `INF`
    Inf,
    Var(String),
    /// `v.sigma`, `e.weight`
    Prop { obj: String, prop: String },
    /// method / builtin calls: `g.num_nodes()`, `nbr.outDegree()`,
    /// `g.is_an_edge(u, w)`, `g.get_edge(v, nbr)`, `abs(x)`, `g.minWt()`.
    Call { recv: Option<String>, name: String, args: Vec<Expr> },
    Unary { op: UnOp, expr: Box<Expr> },
    Binary { op: BinOp, lhs: Box<Expr>, rhs: Box<Expr> },
}

impl Expr {
    /// Free variables referenced (vars and property-bearing objects).
    pub fn visit_vars(&self, f: &mut impl FnMut(&str)) {
        match self {
            Expr::Var(v) => f(v),
            Expr::Prop { obj, .. } => f(obj),
            Expr::Call { recv, args, .. } => {
                if let Some(r) = recv {
                    f(r);
                }
                for a in args {
                    a.visit_vars(f);
                }
            }
            Expr::Unary { expr, .. } => expr.visit_vars(f),
            Expr::Binary { lhs, rhs, .. } => {
                lhs.visit_vars(f);
                rhs.visit_vars(f);
            }
            _ => {}
        }
    }

    /// Property names referenced anywhere in the expression.
    pub fn visit_props(&self, f: &mut impl FnMut(&str, &str)) {
        match self {
            Expr::Prop { obj, prop } => f(obj, prop),
            Expr::Call { args, .. } => {
                for a in args {
                    a.visit_props(f);
                }
            }
            Expr::Unary { expr, .. } => expr.visit_props(f),
            Expr::Binary { lhs, rhs, .. } => {
                lhs.visit_props(f);
                rhs.visit_props(f);
            }
            _ => {}
        }
    }
}
