//! Hand-written lexer for the StarPlat DSL.

use super::diag::DslError;
use super::token::{Span, Spanned, Tok};

pub struct Lexer<'a> {
    src: &'a [u8],
    pos: usize,
    line: u32,
    col: u32,
}

impl<'a> Lexer<'a> {
    pub fn new(src: &'a str) -> Self {
        Lexer { src: src.as_bytes(), pos: 0, line: 1, col: 1 }
    }

    pub fn tokenize(src: &str) -> Result<Vec<Spanned>, DslError> {
        let mut lx = Lexer::new(src);
        let mut out = Vec::new();
        loop {
            let t = lx.next_token()?;
            let eof = t.tok == Tok::Eof;
            out.push(t);
            if eof {
                return Ok(out);
            }
        }
    }

    fn peek(&self) -> u8 {
        self.src.get(self.pos).copied().unwrap_or(0)
    }
    fn peek2(&self) -> u8 {
        self.src.get(self.pos + 1).copied().unwrap_or(0)
    }

    fn bump(&mut self) -> u8 {
        let c = self.peek();
        self.pos += 1;
        if c == b'\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        c
    }

    fn skip_trivia(&mut self) -> Result<(), DslError> {
        loop {
            match self.peek() {
                b' ' | b'\t' | b'\r' | b'\n' => {
                    self.bump();
                }
                b'/' if self.peek2() == b'/' => {
                    while self.peek() != b'\n' && self.peek() != 0 {
                        self.bump();
                    }
                }
                b'/' if self.peek2() == b'*' => {
                    let start = self.span_here();
                    self.bump();
                    self.bump();
                    loop {
                        if self.peek() == 0 {
                            return Err(DslError::at(start, "unterminated block comment"));
                        }
                        if self.peek() == b'*' && self.peek2() == b'/' {
                            self.bump();
                            self.bump();
                            break;
                        }
                        self.bump();
                    }
                }
                _ => return Ok(()),
            }
        }
    }

    fn span_here(&self) -> Span {
        Span { lo: self.pos, hi: self.pos, line: self.line, col: self.col }
    }

    fn next_token(&mut self) -> Result<Spanned, DslError> {
        self.skip_trivia()?;
        let mut span = self.span_here();
        let c = self.peek();
        let tok = match c {
            0 => Tok::Eof,
            b'(' => {
                self.bump();
                Tok::LParen
            }
            b')' => {
                self.bump();
                Tok::RParen
            }
            b'{' => {
                self.bump();
                Tok::LBrace
            }
            b'}' => {
                self.bump();
                Tok::RBrace
            }
            b',' => {
                self.bump();
                Tok::Comma
            }
            b';' => {
                self.bump();
                Tok::Semi
            }
            b':' => {
                self.bump();
                Tok::Colon
            }
            b'.' => {
                self.bump();
                Tok::Dot
            }
            b'+' => {
                self.bump();
                match self.peek() {
                    b'=' => {
                        self.bump();
                        Tok::PlusEq
                    }
                    b'+' => {
                        self.bump();
                        Tok::PlusPlus
                    }
                    _ => Tok::Plus,
                }
            }
            b'-' => {
                self.bump();
                match self.peek() {
                    b'-' => {
                        self.bump();
                        Tok::MinusMinus
                    }
                    _ => Tok::Minus,
                }
            }
            b'*' => {
                self.bump();
                if self.peek() == b'=' {
                    self.bump();
                    Tok::StarEq
                } else {
                    Tok::Star
                }
            }
            b'/' => {
                self.bump();
                Tok::Slash
            }
            b'%' => {
                self.bump();
                Tok::Percent
            }
            b'<' => {
                self.bump();
                if self.peek() == b'=' {
                    self.bump();
                    Tok::Le
                } else {
                    Tok::Lt
                }
            }
            b'>' => {
                self.bump();
                if self.peek() == b'=' {
                    self.bump();
                    Tok::Ge
                } else {
                    Tok::Gt
                }
            }
            b'=' => {
                self.bump();
                if self.peek() == b'=' {
                    self.bump();
                    Tok::EqEq
                } else {
                    Tok::Assign
                }
            }
            b'!' => {
                self.bump();
                if self.peek() == b'=' {
                    self.bump();
                    Tok::NotEq
                } else {
                    Tok::Not
                }
            }
            b'&' => {
                self.bump();
                if self.peek() != b'&' {
                    return Err(DslError::at(span, "expected `&&`"));
                }
                self.bump();
                if self.peek() == b'=' {
                    self.bump();
                    Tok::AndEq
                } else {
                    Tok::AndAnd
                }
            }
            b'|' => {
                self.bump();
                if self.peek() != b'|' {
                    return Err(DslError::at(span, "expected `||`"));
                }
                self.bump();
                if self.peek() == b'=' {
                    self.bump();
                    Tok::OrEq
                } else {
                    Tok::OrOr
                }
            }
            b'0'..=b'9' => self.number(&mut span)?,
            c if c == b'_' || c.is_ascii_alphabetic() => {
                let start = self.pos;
                while self.peek() == b'_' || self.peek().is_ascii_alphanumeric() {
                    self.bump();
                }
                let word = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
                Tok::keyword(word).unwrap_or_else(|| Tok::Ident(word.to_string()))
            }
            other => {
                return Err(DslError::at(
                    span,
                    &format!("unexpected character `{}`", other as char),
                ))
            }
        };
        span.hi = self.pos;
        Ok(Spanned { tok, span })
    }

    fn number(&mut self, span: &mut Span) -> Result<Tok, DslError> {
        let start = self.pos;
        while self.peek().is_ascii_digit() {
            self.bump();
        }
        let mut is_float = false;
        if self.peek() == b'.' && self.peek2().is_ascii_digit() {
            is_float = true;
            self.bump();
            while self.peek().is_ascii_digit() {
                self.bump();
            }
        }
        if matches!(self.peek(), b'e' | b'E') {
            is_float = true;
            self.bump();
            if matches!(self.peek(), b'+' | b'-') {
                self.bump();
            }
            while self.peek().is_ascii_digit() {
                self.bump();
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        span.hi = self.pos;
        if is_float {
            text.parse::<f64>()
                .map(Tok::FloatLit)
                .map_err(|_| DslError::at(*span, "malformed float literal"))
        } else {
            text.parse::<i64>()
                .map(Tok::IntLit)
                .map_err(|_| DslError::at(*span, "malformed integer literal"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(src: &str) -> Vec<Tok> {
        Lexer::tokenize(src).unwrap().into_iter().map(|s| s.tok).collect()
    }

    #[test]
    fn lexes_keywords_and_idents() {
        let t = toks("function foo forall INF sigma");
        assert_eq!(
            t,
            vec![
                Tok::Function,
                Tok::Ident("foo".into()),
                Tok::Forall,
                Tok::Inf,
                Tok::Ident("sigma".into()),
                Tok::Eof
            ]
        );
    }

    #[test]
    fn lexes_compound_operators() {
        let t = toks("+= *= && &&= || ||= ++ == != <= >= < > ! =");
        assert_eq!(
            t,
            vec![
                Tok::PlusEq,
                Tok::StarEq,
                Tok::AndAnd,
                Tok::AndEq,
                Tok::OrOr,
                Tok::OrEq,
                Tok::PlusPlus,
                Tok::EqEq,
                Tok::NotEq,
                Tok::Le,
                Tok::Ge,
                Tok::Lt,
                Tok::Gt,
                Tok::Not,
                Tok::Assign,
                Tok::Eof
            ]
        );
    }

    #[test]
    fn lexes_numbers() {
        assert_eq!(toks("42")[0], Tok::IntLit(42));
        assert_eq!(toks("1.5")[0], Tok::FloatLit(1.5));
        assert_eq!(toks("2e3")[0], Tok::FloatLit(2000.0));
        // member access is not a float: v.sigma
        let t = toks("v.sigma");
        assert_eq!(t[1], Tok::Dot);
    }

    #[test]
    fn comments_and_spans() {
        let lexed = Lexer::tokenize("// line\nx /* block\n */ y").unwrap();
        assert_eq!(lexed[0].tok, Tok::Ident("x".into()));
        assert_eq!(lexed[0].span.line, 2);
        assert_eq!(lexed[1].tok, Tok::Ident("y".into()));
        assert_eq!(lexed[1].span.line, 3);
    }

    #[test]
    fn errors_on_garbage() {
        assert!(Lexer::tokenize("a $ b").is_err());
        assert!(Lexer::tokenize("a & b").is_err());
        assert!(Lexer::tokenize("/* unterminated").is_err());
    }

    #[test]
    fn lexes_full_bc_header() {
        let t = toks("function ComputeBC(Graph g, propNode<float> BC, SetN<g> sourceSet) {");
        assert!(t.contains(&Tok::PropNode));
        assert!(t.contains(&Tok::SetN));
        assert!(t.contains(&Tok::Lt));
    }
}
