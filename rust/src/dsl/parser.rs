//! Recursive-descent parser for the StarPlat DSL.

use super::ast::*;
use super::diag::DslError;
use super::lexer::Lexer;
use super::token::{Span, Spanned, Tok};

pub struct Parser {
    toks: Vec<Spanned>,
    pos: usize,
}

/// Parse a whole source file into its functions.
pub fn parse(src: &str) -> Result<Vec<Function>, DslError> {
    let toks = Lexer::tokenize(src)?;
    let mut p = Parser { toks, pos: 0 };
    let mut fns = Vec::new();
    while p.peek() != &Tok::Eof {
        fns.push(p.function()?);
    }
    if fns.is_empty() {
        return Err(DslError::at(Span::DUMMY, "no functions in source"));
    }
    Ok(fns)
}

/// Parse a file, attaching its path to errors.
pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Vec<Function>> {
    let src = std::fs::read_to_string(path)?;
    parse(&src)
        .map_err(|e| anyhow::anyhow!("{}", e.in_file(&path.display().to_string()).render(&src)))
}

impl Parser {
    fn peek(&self) -> &Tok {
        &self.toks[self.pos].tok
    }
    fn peek_at(&self, off: usize) -> &Tok {
        &self.toks[(self.pos + off).min(self.toks.len() - 1)].tok
    }
    fn span(&self) -> Span {
        self.toks[self.pos].span
    }
    fn bump(&mut self) -> Tok {
        let t = self.toks[self.pos].tok.clone();
        if self.pos + 1 < self.toks.len() {
            self.pos += 1;
        }
        t
    }
    fn eat(&mut self, t: Tok) -> Result<(), DslError> {
        if *self.peek() == t {
            self.bump();
            Ok(())
        } else {
            Err(DslError::at(
                self.span(),
                &format!("expected {}, found {}", t.describe(), self.peek().describe()),
            ))
        }
    }
    fn ident(&mut self) -> Result<String, DslError> {
        match self.peek().clone() {
            Tok::Ident(s) => {
                self.bump();
                Ok(s)
            }
            other => Err(DslError::at(
                self.span(),
                &format!("expected identifier, found {}", other.describe()),
            )),
        }
    }

    // ---- declarations -------------------------------------------------

    fn function(&mut self) -> Result<Function, DslError> {
        let span = self.span();
        self.eat(Tok::Function)?;
        let name = self.ident()?;
        self.eat(Tok::LParen)?;
        let mut params = Vec::new();
        if *self.peek() != Tok::RParen {
            loop {
                params.push(self.param()?);
                if *self.peek() == Tok::Comma {
                    self.bump();
                } else {
                    break;
                }
            }
        }
        self.eat(Tok::RParen)?;
        let body = self.block()?;
        Ok(Function { name, params, body, span })
    }

    fn param(&mut self) -> Result<Param, DslError> {
        let span = self.span();
        let ty = self.type_()?;
        let name = self.ident()?;
        Ok(Param { name, ty, span })
    }

    fn is_type_start(t: &Tok) -> bool {
        matches!(
            t,
            Tok::Int
                | Tok::Bool
                | Tok::Long
                | Tok::Float
                | Tok::Double
                | Tok::Node
                | Tok::Edge
                | Tok::Graph
                | Tok::PropNode
                | Tok::PropEdge
                | Tok::SetN
        )
    }

    fn type_(&mut self) -> Result<Type, DslError> {
        let t = self.bump();
        Ok(match t {
            Tok::Int => Type::Int,
            Tok::Bool => Type::Bool,
            Tok::Long => Type::Long,
            Tok::Float => Type::Float,
            Tok::Double => Type::Double,
            Tok::Node => Type::Node,
            Tok::Edge => Type::Edge,
            Tok::Graph => Type::Graph,
            Tok::PropNode | Tok::PropEdge => {
                let is_node = t == Tok::PropNode;
                self.eat(Tok::Lt)?;
                let inner = self.type_()?;
                self.eat(Tok::Gt)?;
                if is_node {
                    Type::PropNode(Box::new(inner))
                } else {
                    Type::PropEdge(Box::new(inner))
                }
            }
            Tok::SetN => {
                self.eat(Tok::Lt)?;
                let g = self.ident()?;
                self.eat(Tok::Gt)?;
                Type::SetN(g)
            }
            other => {
                return Err(DslError::at(
                    self.span(),
                    &format!("expected a type, found {}", other.describe()),
                ))
            }
        })
    }

    // ---- statements ---------------------------------------------------

    fn block(&mut self) -> Result<Block, DslError> {
        self.eat(Tok::LBrace)?;
        let mut stmts = Vec::new();
        while *self.peek() != Tok::RBrace {
            if *self.peek() == Tok::Eof {
                return Err(DslError::at(self.span(), "unexpected end of input inside block"));
            }
            stmts.push(self.stmt()?);
        }
        self.eat(Tok::RBrace)?;
        Ok(stmts)
    }

    fn stmt(&mut self) -> Result<Stmt, DslError> {
        let span = self.span();
        match self.peek().clone() {
            t if Self::is_type_start(&t) => self.decl(span),
            Tok::Lt => self.minmax_assign(span),
            Tok::Forall => {
                self.bump();
                self.for_loop(span, true)
            }
            Tok::For => {
                self.bump();
                self.for_loop(span, false)
            }
            Tok::IterateInBFS => self.iterate_bfs(span),
            Tok::IterateInReverse => Err(DslError::at(
                span,
                "iterateInReverse must directly follow an iterateInBFS block (paper §2)",
            )),
            Tok::FixedPoint => self.fixed_point(span),
            Tok::Do => self.do_while(span),
            Tok::While => {
                self.bump();
                self.eat(Tok::LParen)?;
                let cond = self.expr()?;
                self.eat(Tok::RParen)?;
                let body = self.block()?;
                Ok(Stmt::While { cond, body, span })
            }
            Tok::If => {
                self.bump();
                self.eat(Tok::LParen)?;
                let cond = self.expr()?;
                self.eat(Tok::RParen)?;
                let then = self.block()?;
                let els = if *self.peek() == Tok::Else {
                    self.bump();
                    Some(self.block()?)
                } else {
                    None
                };
                Ok(Stmt::If { cond, then, els, span })
            }
            Tok::Return => {
                self.bump();
                let value = self.expr()?;
                self.eat(Tok::Semi)?;
                Ok(Stmt::Return { value, span })
            }
            Tok::Ident(_) => self.assign_or_call(span),
            other => Err(DslError::at(span, &format!("unexpected {}", other.describe()))),
        }
    }

    fn decl(&mut self, span: Span) -> Result<Stmt, DslError> {
        let ty = self.type_()?;
        let name = self.ident()?;
        let init = if *self.peek() == Tok::Assign {
            self.bump();
            Some(self.expr()?)
        } else {
            None
        };
        self.eat(Tok::Semi)?;
        Ok(Stmt::Decl { ty, name, init, span })
    }

    /// `<lv1, lv2, ...> = <Min(a, b), v2, ...>;`
    fn minmax_assign(&mut self, span: Span) -> Result<Stmt, DslError> {
        self.eat(Tok::Lt)?;
        let mut targets = vec![self.lvalue()?];
        while *self.peek() == Tok::Comma {
            self.bump();
            targets.push(self.lvalue()?);
        }
        self.eat(Tok::Gt)?;
        self.eat(Tok::Assign)?;
        self.eat(Tok::Lt)?;
        let kind = match self.bump() {
            Tok::Min => MinMax::Min,
            Tok::Max => MinMax::Max,
            other => {
                return Err(DslError::at(
                    self.span(),
                    &format!("expected Min or Max in tuple assignment, found {}", other.describe()),
                ))
            }
        };
        self.eat(Tok::LParen)?;
        let _current = self.expr()?; // first arg: the current value (by convention, == target)
        self.eat(Tok::Comma)?;
        let compare = self.expr()?;
        self.eat(Tok::RParen)?;
        let mut extras_vals = Vec::new();
        while *self.peek() == Tok::Comma {
            self.bump();
            // Additive precedence: the tuple's closing `>` must not be
            // swallowed as a comparison. Parenthesize comparisons if needed.
            extras_vals.push(self.add_expr()?);
        }
        self.eat(Tok::Gt)?;
        self.eat(Tok::Semi)?;
        if extras_vals.len() != targets.len() - 1 {
            return Err(DslError::at(
                span,
                &format!(
                    "tuple assignment arity mismatch: {} targets but {} values",
                    targets.len(),
                    extras_vals.len() + 1
                ),
            ));
        }
        let mut it = targets.into_iter();
        let target = it.next().unwrap();
        let extra = it.zip(extras_vals).collect();
        Ok(Stmt::MinMaxAssign { kind, target, compare, extra, span })
    }

    fn lvalue(&mut self) -> Result<LValue, DslError> {
        let obj = self.ident()?;
        if *self.peek() == Tok::Dot {
            self.bump();
            let prop = self.ident()?;
            Ok(LValue::Prop { obj, prop })
        } else {
            Ok(LValue::Var(obj))
        }
    }

    fn for_loop(&mut self, span: Span, parallel: bool) -> Result<Stmt, DslError> {
        self.eat(Tok::LParen)?;
        let var = self.ident()?;
        self.eat(Tok::In)?;
        let source_obj = self.ident()?;
        let source = if *self.peek() == Tok::Dot {
            self.bump();
            let method = self.ident()?;
            self.eat(Tok::LParen)?;
            let arg = if *self.peek() != Tok::RParen { Some(self.ident()?) } else { None };
            self.eat(Tok::RParen)?;
            match (method.as_str(), arg) {
                ("nodes", None) => IterSource::Nodes { graph: source_obj },
                ("neighbors", Some(of)) => IterSource::Neighbors { graph: source_obj, of },
                ("nodes_to", Some(of)) => IterSource::NodesTo { graph: source_obj, of },
                (m, _) => {
                    return Err(DslError::at(
                        span,
                        &format!("unknown iteration source `{source_obj}.{m}(..)` (expected nodes/neighbors/nodes_to)"),
                    ))
                }
            }
        } else {
            IterSource::Set { set: source_obj }
        };
        // optional `.filter(expr)`
        let filter = if *self.peek() == Tok::Dot && *self.peek_at(1) == Tok::Filter {
            self.bump();
            self.bump();
            self.eat(Tok::LParen)?;
            let e = self.expr()?;
            self.eat(Tok::RParen)?;
            Some(e)
        } else {
            None
        };
        self.eat(Tok::RParen)?;
        let body = self.block()?;
        Ok(Stmt::For { iter: Iterator_ { var, source, filter }, body, parallel, span })
    }

    fn iterate_bfs(&mut self, span: Span) -> Result<Stmt, DslError> {
        self.eat(Tok::IterateInBFS)?;
        self.eat(Tok::LParen)?;
        let var = self.ident()?;
        self.eat(Tok::In)?;
        let graph = self.ident()?;
        self.eat(Tok::Dot)?;
        let m = self.ident()?;
        if m != "nodes" {
            return Err(DslError::at(span, "iterateInBFS expects `v in g.nodes() from src`"));
        }
        self.eat(Tok::LParen)?;
        self.eat(Tok::RParen)?;
        self.eat(Tok::From)?;
        let from = self.ident()?;
        self.eat(Tok::RParen)?;
        let body = self.block()?;
        let reverse = if *self.peek() == Tok::IterateInReverse {
            self.bump();
            self.eat(Tok::LParen)?;
            let cond = self.expr()?;
            self.eat(Tok::RParen)?;
            let rbody = self.block()?;
            Some((cond, rbody))
        } else {
            None
        };
        Ok(Stmt::IterateBFS { var, graph, from, body, reverse, span })
    }

    fn fixed_point(&mut self, span: Span) -> Result<Stmt, DslError> {
        self.eat(Tok::FixedPoint)?;
        self.eat(Tok::Until)?;
        self.eat(Tok::LParen)?;
        let var = self.ident()?;
        self.eat(Tok::Colon)?;
        let cond = self.expr()?;
        self.eat(Tok::RParen)?;
        let body = self.block()?;
        Ok(Stmt::FixedPoint { var, cond, body, span })
    }

    fn do_while(&mut self, span: Span) -> Result<Stmt, DslError> {
        self.eat(Tok::Do)?;
        let body = self.block()?;
        self.eat(Tok::While)?;
        self.eat(Tok::LParen)?;
        let cond = self.expr()?;
        self.eat(Tok::RParen)?;
        self.eat(Tok::Semi)?;
        Ok(Stmt::DoWhile { body, cond, span })
    }

    /// Statements starting with an identifier: assignment, reduction,
    /// increment, or a method-call statement like `g.attachNodeProperty(..)`.
    fn assign_or_call(&mut self, span: Span) -> Result<Stmt, DslError> {
        let obj = self.ident()?;
        // method call statement?
        if *self.peek() == Tok::Dot {
            if let Tok::Ident(m) = self.peek_at(1).clone() {
                if *self.peek_at(2) == Tok::LParen {
                    self.bump(); // .
                    self.bump(); // method
                    return self.method_stmt(span, obj, m);
                }
            }
        }
        let target = if *self.peek() == Tok::Dot {
            self.bump();
            let prop = self.ident()?;
            LValue::Prop { obj, prop }
        } else {
            LValue::Var(obj)
        };
        let t = self.bump();
        let stmt = match t {
            Tok::Assign => {
                let value = self.expr()?;
                Stmt::Assign { target, value, span }
            }
            Tok::PlusEq => {
                let value = self.expr()?;
                Stmt::Reduce { target, op: ReduceOp::Add, value, span }
            }
            Tok::StarEq => {
                let value = self.expr()?;
                Stmt::Reduce { target, op: ReduceOp::Mul, value, span }
            }
            Tok::AndEq => {
                let value = self.expr()?;
                Stmt::Reduce { target, op: ReduceOp::And, value, span }
            }
            Tok::OrEq => {
                let value = self.expr()?;
                Stmt::Reduce { target, op: ReduceOp::Or, value, span }
            }
            Tok::PlusPlus => {
                Stmt::Reduce { target, op: ReduceOp::Count, value: Expr::IntLit(1), span }
            }
            other => {
                return Err(DslError::at(
                    span,
                    &format!(
                        "expected assignment or reduction operator, found {}",
                        other.describe()
                    ),
                ))
            }
        };
        self.eat(Tok::Semi)?;
        Ok(stmt)
    }

    fn method_stmt(&mut self, span: Span, obj: String, method: String) -> Result<Stmt, DslError> {
        match method.as_str() {
            "attachNodeProperty" | "attachEdgeProperty" => {
                self.eat(Tok::LParen)?;
                let mut inits = Vec::new();
                loop {
                    let prop = self.ident()?;
                    self.eat(Tok::Assign)?;
                    let e = self.expr()?;
                    inits.push((prop, e));
                    if *self.peek() == Tok::Comma {
                        self.bump();
                    } else {
                        break;
                    }
                }
                self.eat(Tok::RParen)?;
                self.eat(Tok::Semi)?;
                Ok(Stmt::AttachNodeProperty { graph: obj, inits, span })
            }
            other => Err(DslError::at(
                span,
                &format!("unknown statement method `{obj}.{other}(..)`"),
            )),
        }
    }

    // ---- expressions (precedence climbing) -----------------------------

    pub fn expr(&mut self) -> Result<Expr, DslError> {
        self.or_expr()
    }

    fn or_expr(&mut self) -> Result<Expr, DslError> {
        let mut lhs = self.and_expr()?;
        while *self.peek() == Tok::OrOr {
            self.bump();
            let rhs = self.and_expr()?;
            lhs = Expr::Binary { op: BinOp::Or, lhs: Box::new(lhs), rhs: Box::new(rhs) };
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<Expr, DslError> {
        let mut lhs = self.cmp_expr()?;
        while *self.peek() == Tok::AndAnd {
            self.bump();
            let rhs = self.cmp_expr()?;
            lhs = Expr::Binary { op: BinOp::And, lhs: Box::new(lhs), rhs: Box::new(rhs) };
        }
        Ok(lhs)
    }

    fn cmp_expr(&mut self) -> Result<Expr, DslError> {
        let lhs = self.add_expr()?;
        let op = match self.peek() {
            Tok::Lt => BinOp::Lt,
            Tok::Gt => BinOp::Gt,
            Tok::Le => BinOp::Le,
            Tok::Ge => BinOp::Ge,
            Tok::EqEq => BinOp::Eq,
            Tok::NotEq => BinOp::Ne,
            _ => return Ok(lhs),
        };
        self.bump();
        let rhs = self.add_expr()?;
        Ok(Expr::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs) })
    }

    fn add_expr(&mut self) -> Result<Expr, DslError> {
        let mut lhs = self.mul_expr()?;
        loop {
            let op = match self.peek() {
                Tok::Plus => BinOp::Add,
                Tok::Minus => BinOp::Sub,
                _ => return Ok(lhs),
            };
            self.bump();
            let rhs = self.mul_expr()?;
            lhs = Expr::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs) };
        }
    }

    fn mul_expr(&mut self) -> Result<Expr, DslError> {
        let mut lhs = self.unary_expr()?;
        loop {
            let op = match self.peek() {
                Tok::Star => BinOp::Mul,
                Tok::Slash => BinOp::Div,
                Tok::Percent => BinOp::Mod,
                _ => return Ok(lhs),
            };
            self.bump();
            let rhs = self.unary_expr()?;
            lhs = Expr::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs) };
        }
    }

    fn unary_expr(&mut self) -> Result<Expr, DslError> {
        match self.peek() {
            Tok::Not => {
                self.bump();
                let e = self.unary_expr()?;
                Ok(Expr::Unary { op: UnOp::Not, expr: Box::new(e) })
            }
            Tok::Minus => {
                self.bump();
                let e = self.unary_expr()?;
                Ok(Expr::Unary { op: UnOp::Neg, expr: Box::new(e) })
            }
            _ => self.postfix_expr(),
        }
    }

    fn postfix_expr(&mut self) -> Result<Expr, DslError> {
        match self.peek().clone() {
            Tok::IntLit(n) => {
                self.bump();
                Ok(Expr::IntLit(n))
            }
            Tok::FloatLit(x) => {
                self.bump();
                Ok(Expr::FloatLit(x))
            }
            Tok::True => {
                self.bump();
                Ok(Expr::BoolLit(true))
            }
            Tok::False => {
                self.bump();
                Ok(Expr::BoolLit(false))
            }
            Tok::Inf => {
                self.bump();
                Ok(Expr::Inf)
            }
            Tok::LParen => {
                self.bump();
                let e = self.expr()?;
                self.eat(Tok::RParen)?;
                Ok(e)
            }
            Tok::Ident(name) => {
                self.bump();
                // free function call: abs(x)
                if *self.peek() == Tok::LParen {
                    let args = self.call_args()?;
                    return Ok(Expr::Call { recv: None, name, args });
                }
                // member: v.prop or g.method(..)
                if *self.peek() == Tok::Dot {
                    self.bump();
                    let member = self.ident()?;
                    if *self.peek() == Tok::LParen {
                        let args = self.call_args()?;
                        return Ok(Expr::Call { recv: Some(name), name: member, args });
                    }
                    return Ok(Expr::Prop { obj: name, prop: member });
                }
                Ok(Expr::Var(name))
            }
            other => Err(DslError::at(
                self.span(),
                &format!("expected an expression, found {}", other.describe()),
            )),
        }
    }

    fn call_args(&mut self) -> Result<Vec<Expr>, DslError> {
        self.eat(Tok::LParen)?;
        let mut args = Vec::new();
        if *self.peek() != Tok::RParen {
            loop {
                args.push(self.expr()?);
                if *self.peek() == Tok::Comma {
                    self.bump();
                } else {
                    break;
                }
            }
        }
        self.eat(Tok::RParen)?;
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse1(src: &str) -> Function {
        parse(src).unwrap().remove(0)
    }

    #[test]
    fn parses_minimal_function() {
        let f = parse1("function f(Graph g) { int x = 1; }");
        assert_eq!(f.name, "f");
        assert_eq!(f.params.len(), 1);
        assert!(matches!(f.body[0], Stmt::Decl { .. }));
    }

    #[test]
    fn parses_forall_with_filter() {
        let f = parse1(
            "function f(Graph g, propNode<bool> modified) {
               forall (v in g.nodes().filter(modified == True)) { v.modified = False; }
             }",
        );
        match &f.body[0] {
            Stmt::For { iter, parallel, .. } => {
                assert!(*parallel);
                assert_eq!(iter.var, "v");
                assert!(iter.filter.is_some());
                assert_eq!(iter.source, IterSource::Nodes { graph: "g".into() });
            }
            s => panic!("expected forall, got {s:?}"),
        }
    }

    #[test]
    fn parses_minmax_tuple_assign() {
        let f = parse1(
            "function f(Graph g, propNode<int> dist, propNode<bool> m) {
               forall (v in g.nodes()) { forall (nbr in g.neighbors(v)) {
                 <nbr.dist, nbr.m> = <Min(nbr.dist, v.dist + 3), True>;
               } }
             }",
        );
        let Stmt::For { body, .. } = &f.body[0] else { panic!() };
        let Stmt::For { body, .. } = &body[0] else { panic!() };
        match &body[0] {
            Stmt::MinMaxAssign { kind, target, extra, .. } => {
                assert_eq!(*kind, MinMax::Min);
                assert_eq!(*target, LValue::Prop { obj: "nbr".into(), prop: "dist".into() });
                assert_eq!(extra.len(), 1);
            }
            s => panic!("expected MinMaxAssign, got {s:?}"),
        }
    }

    #[test]
    fn parses_fixed_point_and_attach() {
        let f = parse1(
            "function f(Graph g, propNode<bool> modified) {
               bool fin = False;
               g.attachNodeProperty(modified = False);
               fixedPoint until (fin: !modified) { }
             }",
        );
        assert!(matches!(f.body[1], Stmt::AttachNodeProperty { .. }));
        match &f.body[2] {
            Stmt::FixedPoint { var, cond, .. } => {
                assert_eq!(var, "fin");
                assert!(matches!(cond, Expr::Unary { op: UnOp::Not, .. }));
            }
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn parses_bfs_with_reverse() {
        let f = parse1(
            "function f(Graph g, node src, propNode<float> sigma) {
               iterateInBFS(v in g.nodes() from src) { }
               iterateInReverse(v != src) { }
             }",
        );
        match &f.body[0] {
            Stmt::IterateBFS { var, from, reverse, .. } => {
                assert_eq!(var, "v");
                assert_eq!(from, "src");
                assert!(reverse.is_some());
            }
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn orphan_reverse_is_error() {
        assert!(parse("function f(Graph g) { iterateInReverse(v != s) { } }").is_err());
    }

    #[test]
    fn parses_reductions() {
        let f = parse1(
            "function f(Graph g) {
               long c = 0; float x = 1;
               c += 1; x *= 2; c++;
               bool a = True; bool o = False;
               a &&= False; o ||= True;
             }",
        );
        let ops: Vec<ReduceOp> = f
            .body
            .iter()
            .filter_map(|s| match s {
                Stmt::Reduce { op, .. } => Some(*op),
                _ => None,
            })
            .collect();
        assert_eq!(
            ops,
            vec![ReduceOp::Add, ReduceOp::Mul, ReduceOp::Count, ReduceOp::And, ReduceOp::Or]
        );
    }

    #[test]
    fn precedence() {
        let f = parse1("function f(Graph g) { float x = 1 + 2 * 3; }");
        let Stmt::Decl { init: Some(e), .. } = &f.body[0] else { panic!() };
        // 1 + (2*3)
        match e {
            Expr::Binary { op: BinOp::Add, rhs, .. } => {
                assert!(matches!(**rhs, Expr::Binary { op: BinOp::Mul, .. }))
            }
            _ => panic!("{e:?}"),
        }
    }

    #[test]
    fn arity_mismatch_in_tuple_assign() {
        let r = parse(
            "function f(Graph g, propNode<int> d) {
               <v.d, v.d, v.d> = <Min(v.d, 1), True>;
             }",
        );
        assert!(r.is_err());
    }

    #[test]
    fn parses_do_while_and_method_exprs() {
        let f = parse1(
            "function f(Graph g, propNode<float> pr) {
               float n = g.num_nodes();
               do {
                 forall (v in g.nodes()) {
                   float s = 0;
                   for (nbr in g.nodes_to(v)) { s = s + nbr.pr / nbr.outDegree(); }
                 }
               } while (n > 0);
             }",
        );
        assert!(matches!(f.body[1], Stmt::DoWhile { .. }));
    }

    #[test]
    fn parses_all_shipped_programs() {
        for p in ["bc.sp", "pr.sp", "sssp.sp", "tc.sp", "cc.sp", "bfs.sp"] {
            let path =
                std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("dsl_programs").join(p);
            let fns = parse_file(&path).unwrap_or_else(|e| panic!("{p}: {e}"));
            assert_eq!(fns.len(), 1, "{p}");
        }
    }
}
