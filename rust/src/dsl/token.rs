//! Token set for the StarPlat DSL (paper §2.1).

#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    // literals & identifiers
    Ident(String),
    IntLit(i64),
    FloatLit(f64),

    // keywords
    Function,
    Graph,
    Node,
    Edge,
    Int,
    Bool,
    Long,
    Float,
    Double,
    PropNode,
    PropEdge,
    SetN,
    Forall,
    For,
    In,
    If,
    Else,
    While,
    Do,
    Return,
    FixedPoint,
    Until,
    IterateInBFS,
    IterateInReverse,
    From,
    Filter,
    Min,
    Max,
    True,
    False,
    Inf,

    // punctuation / operators
    LParen,
    RParen,
    LBrace,
    RBrace,
    Comma,
    Semi,
    Colon,
    Dot,
    Assign,     // =
    PlusEq,     // +=
    StarEq,     // *=
    AndEq,      // &&=
    OrEq,       // ||=
    PlusPlus,   // ++
    MinusMinus, // --
    Plus,
    Minus,
    Star,
    Slash,
    Percent,
    Lt,
    Gt,
    Le,
    Ge,
    EqEq,
    NotEq,
    AndAnd,
    OrOr,
    Not,

    Eof,
}

impl Tok {
    /// Keyword lookup for identifiers.
    pub fn keyword(s: &str) -> Option<Tok> {
        Some(match s {
            "function" => Tok::Function,
            "Graph" => Tok::Graph,
            "node" => Tok::Node,
            "edge" => Tok::Edge,
            "int" => Tok::Int,
            "bool" => Tok::Bool,
            "long" => Tok::Long,
            "float" => Tok::Float,
            "double" => Tok::Double,
            "propNode" => Tok::PropNode,
            "propEdge" => Tok::PropEdge,
            "SetN" => Tok::SetN,
            "forall" => Tok::Forall,
            "for" => Tok::For,
            "in" => Tok::In,
            "if" => Tok::If,
            "else" => Tok::Else,
            "while" => Tok::While,
            "do" => Tok::Do,
            "return" => Tok::Return,
            "fixedPoint" => Tok::FixedPoint,
            "until" => Tok::Until,
            "iterateInBFS" => Tok::IterateInBFS,
            "iterateInReverse" => Tok::IterateInReverse,
            "from" => Tok::From,
            "filter" => Tok::Filter,
            "Min" => Tok::Min,
            "Max" => Tok::Max,
            "True" => Tok::True,
            "False" => Tok::False,
            "INF" => Tok::Inf,
            _ => return None,
        })
    }

    pub fn describe(&self) -> String {
        match self {
            Tok::Ident(s) => format!("identifier `{s}`"),
            Tok::IntLit(n) => format!("integer `{n}`"),
            Tok::FloatLit(x) => format!("float `{x}`"),
            Tok::Eof => "end of input".to_string(),
            other => format!("`{}`", other.text()),
        }
    }

    /// Literal text for fixed tokens (used by diagnostics and the pretty
    /// printer).
    pub fn text(&self) -> &'static str {
        match self {
            Tok::Function => "function",
            Tok::Graph => "Graph",
            Tok::Node => "node",
            Tok::Edge => "edge",
            Tok::Int => "int",
            Tok::Bool => "bool",
            Tok::Long => "long",
            Tok::Float => "float",
            Tok::Double => "double",
            Tok::PropNode => "propNode",
            Tok::PropEdge => "propEdge",
            Tok::SetN => "SetN",
            Tok::Forall => "forall",
            Tok::For => "for",
            Tok::In => "in",
            Tok::If => "if",
            Tok::Else => "else",
            Tok::While => "while",
            Tok::Do => "do",
            Tok::Return => "return",
            Tok::FixedPoint => "fixedPoint",
            Tok::Until => "until",
            Tok::IterateInBFS => "iterateInBFS",
            Tok::IterateInReverse => "iterateInReverse",
            Tok::From => "from",
            Tok::Filter => "filter",
            Tok::Min => "Min",
            Tok::Max => "Max",
            Tok::True => "True",
            Tok::False => "False",
            Tok::Inf => "INF",
            Tok::LParen => "(",
            Tok::RParen => ")",
            Tok::LBrace => "{",
            Tok::RBrace => "}",
            Tok::Comma => ",",
            Tok::Semi => ";",
            Tok::Colon => ":",
            Tok::Dot => ".",
            Tok::Assign => "=",
            Tok::PlusEq => "+=",
            Tok::StarEq => "*=",
            Tok::AndEq => "&&=",
            Tok::OrEq => "||=",
            Tok::PlusPlus => "++",
            Tok::MinusMinus => "--",
            Tok::Plus => "+",
            Tok::Minus => "-",
            Tok::Star => "*",
            Tok::Slash => "/",
            Tok::Percent => "%",
            Tok::Lt => "<",
            Tok::Gt => ">",
            Tok::Le => "<=",
            Tok::Ge => ">=",
            Tok::EqEq => "==",
            Tok::NotEq => "!=",
            Tok::AndAnd => "&&",
            Tok::OrOr => "||",
            Tok::Not => "!",
            _ => "?",
        }
    }
}

/// Byte-offset source span for diagnostics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    pub lo: usize,
    pub hi: usize,
    pub line: u32,
    pub col: u32,
}

impl Span {
    pub const DUMMY: Span = Span { lo: 0, hi: 0, line: 0, col: 0 };
}

#[derive(Clone, Debug)]
pub struct Spanned {
    pub tok: Tok,
    pub span: Span,
}
