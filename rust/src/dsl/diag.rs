//! Diagnostics for the DSL front-end.

use super::token::Span;

#[derive(Debug, thiserror::Error)]
#[error("{file}:{line}:{col}: {msg}")]
pub struct DslError {
    pub file: String,
    pub line: u32,
    pub col: u32,
    pub msg: String,
}

impl DslError {
    pub fn at(span: Span, msg: &str) -> DslError {
        DslError { file: "<dsl>".into(), line: span.line, col: span.col, msg: msg.to_string() }
    }

    pub fn in_file(mut self, file: &str) -> DslError {
        self.file = file.to_string();
        self
    }

    /// Render with a source snippet and caret, gcc-style.
    pub fn render(&self, src: &str) -> String {
        let mut out =
            format!("error: {}\n  --> {}:{}:{}\n", self.msg, self.file, self.line, self.col);
        if self.line >= 1 {
            if let Some(line_txt) = src.lines().nth(self.line as usize - 1) {
                out.push_str(&format!(
                    "   | {}\n   | {}^\n",
                    line_txt,
                    " ".repeat(self.col.saturating_sub(1) as usize)
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::token::Span;

    #[test]
    fn renders_caret() {
        let e = DslError::at(Span { lo: 4, hi: 5, line: 1, col: 5 }, "boom").in_file("x.sp");
        let r = e.render("abc def");
        assert!(r.contains("x.sp:1:5"));
        assert!(r.contains("    ^"));
    }
}
