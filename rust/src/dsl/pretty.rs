//! Pretty-printer for the DSL AST.
//!
//! Produces parseable StarPlat source; `parse(pretty(ast)) == ast` is a
//! property test in `rust/tests/`, and the LoC bench uses it to measure DSL
//! program sizes uniformly.

use super::ast::*;

pub fn pretty_function(f: &Function) -> String {
    let mut out = String::new();
    let params: Vec<String> =
        f.params.iter().map(|p| format!("{} {}", p.ty.display(), p.name)).collect();
    out.push_str(&format!("function {}({}) {{\n", f.name, params.join(", ")));
    for s in &f.body {
        stmt(&mut out, s, 1);
    }
    out.push_str("}\n");
    out
}

fn ind(out: &mut String, level: usize) {
    out.push_str(&"  ".repeat(level));
}

fn block(out: &mut String, b: &Block, level: usize) {
    out.push_str("{\n");
    for s in b {
        stmt(out, s, level + 1);
    }
    ind(out, level);
    out.push('}');
}

fn stmt(out: &mut String, s: &Stmt, level: usize) {
    ind(out, level);
    match s {
        Stmt::Decl { ty, name, init, .. } => {
            out.push_str(&format!("{} {}", ty.display(), name));
            if let Some(e) = init {
                out.push_str(&format!(" = {}", expr(e)));
            }
            out.push_str(";\n");
        }
        Stmt::Assign { target, value, .. } => {
            out.push_str(&format!("{} = {};\n", lvalue(target), expr(value)));
        }
        Stmt::Reduce { target, op, value, .. } => match op {
            ReduceOp::Count => out.push_str(&format!("{}++;\n", lvalue(target))),
            _ => out.push_str(&format!("{} {} {};\n", lvalue(target), op.symbol(), expr(value))),
        },
        Stmt::MinMaxAssign { kind, target, compare, extra, .. } => {
            let mut tgts = vec![lvalue(target)];
            let mut vals = vec![format!(
                "{}({}, {})",
                if *kind == MinMax::Min { "Min" } else { "Max" },
                lvalue(target),
                expr(compare)
            )];
            for (t, v) in extra {
                tgts.push(lvalue(t));
                vals.push(expr(v));
            }
            out.push_str(&format!("<{}> = <{}>;\n", tgts.join(", "), vals.join(", ")));
        }
        Stmt::AttachNodeProperty { graph, inits, .. } => {
            let args: Vec<String> =
                inits.iter().map(|(p, e)| format!("{} = {}", p, expr(e))).collect();
            out.push_str(&format!("{}.attachNodeProperty({});\n", graph, args.join(", ")));
        }
        Stmt::For { iter, body, parallel, .. } => {
            let kw = if *parallel { "forall" } else { "for" };
            let src = match &iter.source {
                IterSource::Nodes { graph } => format!("{graph}.nodes()"),
                IterSource::Neighbors { graph, of } => format!("{graph}.neighbors({of})"),
                IterSource::NodesTo { graph, of } => format!("{graph}.nodes_to({of})"),
                IterSource::Set { set } => set.clone(),
            };
            let filt =
                iter.filter.as_ref().map(|e| format!(".filter({})", expr(e))).unwrap_or_default();
            out.push_str(&format!("{kw} ({} in {src}{filt}) ", iter.var));
            block(out, body, level);
            out.push('\n');
        }
        Stmt::IterateBFS { var, graph, from, body, reverse, .. } => {
            out.push_str(&format!("iterateInBFS({var} in {graph}.nodes() from {from}) "));
            block(out, body, level);
            out.push('\n');
            if let Some((cond, rbody)) = reverse {
                ind(out, level);
                out.push_str(&format!("iterateInReverse({}) ", expr(cond)));
                block(out, rbody, level);
                out.push('\n');
            }
        }
        Stmt::FixedPoint { var, cond, body, .. } => {
            out.push_str(&format!("fixedPoint until ({var}: {}) ", expr(cond)));
            block(out, body, level);
            out.push('\n');
        }
        Stmt::DoWhile { body, cond, .. } => {
            out.push_str("do ");
            block(out, body, level);
            out.push_str(&format!(" while ({});\n", expr(cond)));
        }
        Stmt::While { cond, body, .. } => {
            out.push_str(&format!("while ({}) ", expr(cond)));
            block(out, body, level);
            out.push('\n');
        }
        Stmt::If { cond, then, els, .. } => {
            out.push_str(&format!("if ({}) ", expr(cond)));
            block(out, then, level);
            if let Some(e) = els {
                out.push_str(" else ");
                block(out, e, level);
            }
            out.push('\n');
        }
        Stmt::Return { value, .. } => {
            out.push_str(&format!("return {};\n", expr(value)));
        }
    }
}

fn lvalue(lv: &LValue) -> String {
    match lv {
        LValue::Var(v) => v.clone(),
        LValue::Prop { obj, prop } => format!("{obj}.{prop}"),
    }
}

pub fn expr(e: &Expr) -> String {
    match e {
        Expr::IntLit(n) => n.to_string(),
        Expr::FloatLit(x) => {
            if x.fract() == 0.0 {
                format!("{x:.1}")
            } else {
                x.to_string()
            }
        }
        Expr::BoolLit(true) => "True".into(),
        Expr::BoolLit(false) => "False".into(),
        Expr::Inf => "INF".into(),
        Expr::Var(v) => v.clone(),
        Expr::Prop { obj, prop } => format!("{obj}.{prop}"),
        Expr::Call { recv, name, args } => {
            let a: Vec<String> = args.iter().map(expr).collect();
            match recv {
                Some(r) => format!("{r}.{name}({})", a.join(", ")),
                None => format!("{name}({})", a.join(", ")),
            }
        }
        Expr::Unary { op, expr: e } => {
            let sym = match op {
                UnOp::Neg => "-",
                UnOp::Not => "!",
            };
            format!("{sym}{}", atom(e))
        }
        Expr::Binary { op, lhs, rhs } => {
            format!("({} {} {})", expr(lhs), op.symbol(), expr(rhs))
        }
    }
}

fn atom(e: &Expr) -> String {
    match e {
        Expr::Binary { .. } => format!("({})", expr(e)),
        _ => expr(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parser::parse;

    #[test]
    fn roundtrip_shipped_programs() {
        for p in ["bc.sp", "pr.sp", "sssp.sp", "tc.sp", "cc.sp", "bfs.sp"] {
            let path =
                std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("dsl_programs").join(p);
            let src = std::fs::read_to_string(&path).unwrap();
            let fns = parse(&src).unwrap_or_else(|e| panic!("{p}: {e}"));
            let printed = pretty_function(&fns[0]);
            let reparsed =
                parse(&printed).unwrap_or_else(|e| panic!("{p} reparse: {e}\n{printed}"));
            // Compare structurally, ignoring spans, via re-printing.
            assert_eq!(printed, pretty_function(&reparsed[0]), "{p} round-trip");
        }
    }
}
