//! StarPlat DSL front-end: lexer, AST, parser, diagnostics, pretty-printer.

pub mod ast;
pub mod diag;
pub mod lexer;
pub mod parser;
pub mod pretty;
pub mod token;

pub use ast::{Expr, Function, Stmt, Type};
pub use parser::{parse, parse_file};
