//! Code generators: the paper's four accelerator backends (CUDA, OpenCL,
//! SYCL, OpenACC — §3) plus the executable JAX backend (DESIGN.md §1).

pub mod body;
pub mod buf;
pub mod cexpr;
pub mod cuda;
pub mod jax;
pub mod openacc;
pub mod opencl;
pub mod sycl;

use crate::dsl::ast::Expr;
use crate::ir::IrProgram;
use crate::sema::TypedFunction;

/// Textual backends by name.
pub fn generate(backend: &str, ir: &IrProgram) -> anyhow::Result<String> {
    Ok(match backend {
        "cuda" => cuda::generate(ir),
        "opencl" => opencl::generate(ir),
        "sycl" => sycl::generate(ir),
        "openacc" => openacc::generate(ir),
        "jax" => jax::generate(ir)?.python,
        other => anyhow::bail!("unknown backend `{other}` (cuda|opencl|sycl|openacc|jax)"),
    })
}

pub const TEXT_BACKENDS: [&str; 4] = ["cuda", "opencl", "sycl", "openacc"];

/// Resolve bare property names in filter expressions to explicit
/// `loopVar.prop` accesses (the StarPlat `filter(modified == True)` idiom).
pub fn resolve_filter(e: &Expr, var: &str, tf: &TypedFunction) -> Expr {
    match e {
        Expr::Var(name) if tf.node_props.contains_key(name) => {
            Expr::Prop { obj: var.to_string(), prop: name.clone() }
        }
        Expr::Unary { op, expr } => {
            Expr::Unary { op: *op, expr: Box::new(resolve_filter(expr, var, tf)) }
        }
        Expr::Binary { op, lhs, rhs } => Expr::Binary {
            op: *op,
            lhs: Box::new(resolve_filter(lhs, var, tf)),
            rhs: Box::new(resolve_filter(rhs, var, tf)),
        },
        Expr::Call { recv, name, args } => Expr::Call {
            recv: recv.clone(),
            name: name.clone(),
            args: args.iter().map(|a| resolve_filter(a, var, tf)).collect(),
        },
        other => other.clone(),
    }
}

/// Normalize boolean comparisons for C output: `x == True` → `x`,
/// `x == False` → `!x` (cleaner generated code, as in the paper's figures).
pub fn simplify_bool_cmp(e: &Expr) -> Expr {
    use crate::dsl::ast::{BinOp, UnOp};
    if let Expr::Binary { op, lhs, rhs } = e {
        if let Expr::BoolLit(b) = **rhs {
            let want = match op {
                BinOp::Eq => Some(b),
                BinOp::Ne => Some(!b),
                _ => None,
            };
            if let Some(w) = want {
                return if w {
                    (**lhs).clone()
                } else {
                    Expr::Unary { op: UnOp::Not, expr: lhs.clone() }
                };
            }
        }
    }
    e.clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::ast::{BinOp, Expr};
    use crate::dsl::parser::parse;
    use crate::sema::check_function;

    #[test]
    fn filter_resolution() {
        let fns = parse(
            "function f(Graph g, propNode<bool> modified) {
               forall (v in g.nodes().filter(modified == True)) { }
             }",
        )
        .unwrap();
        let tf = check_function(&fns[0]).unwrap();
        let e = Expr::Binary {
            op: BinOp::Eq,
            lhs: Box::new(Expr::Var("modified".into())),
            rhs: Box::new(Expr::BoolLit(true)),
        };
        let r = resolve_filter(&e, "v", &tf);
        let s = simplify_bool_cmp(&r);
        assert_eq!(s, Expr::Prop { obj: "v".into(), prop: "modified".into() });
    }
}
