//! Code generators: the paper's four accelerator backends (CUDA, OpenCL,
//! SYCL, OpenACC — §3) plus the executable JAX backend (DESIGN.md §1).
//!
//! All five are renderers over the backend-neutral device plan
//! ([`crate::ir::plan::DevicePlan`]): buffers, kernel parameter lists,
//! transfer steps, and host-loop skeletons are resolved once there; these
//! modules contribute syntax only.

pub mod body;
pub mod buf;
pub mod cexpr;
pub mod cuda;
pub mod jax;
pub mod openacc;
pub mod opencl;
pub mod sycl;

use crate::dsl::ast::{Expr, ReduceOp};
use crate::ir::IrProgram;
use crate::sema::TypedFunction;

/// Textual backends by name. The device plan is lowered once and shared by
/// whichever renderer is selected.
pub fn generate(backend: &str, ir: &IrProgram) -> anyhow::Result<String> {
    let plan = crate::ir::plan::DevicePlan::build(ir);
    Ok(match backend {
        "cuda" => cuda::generate_with(ir, &plan),
        "opencl" => opencl::generate_with(ir, &plan),
        "sycl" => sycl::generate_with(ir, &plan),
        "openacc" => openacc::generate_with(ir, &plan),
        "jax" => jax::generate_with(ir, &plan)?.python,
        other => anyhow::bail!("unknown backend `{other}` (cuda|opencl|sycl|openacc|jax)"),
    })
}

pub const TEXT_BACKENDS: [&str; 4] = ["cuda", "opencl", "sycl", "openacc"];

/// C operator for a host-side scalar reduction (shared by all renderers).
pub(crate) fn red_sym(op: ReduceOp) -> &'static str {
    match op {
        ReduceOp::Add | ReduceOp::Count => "+",
        ReduceOp::Mul => "*",
        ReduceOp::And => "&&",
        ReduceOp::Or => "||",
    }
}

/// Resolve bare property names in filter expressions to explicit
/// `loopVar.prop` accesses (the StarPlat `filter(modified == True)` idiom).
pub fn resolve_filter(e: &Expr, var: &str, tf: &TypedFunction) -> Expr {
    match e {
        Expr::Var(name) if tf.node_props.contains_key(name) => {
            Expr::Prop { obj: var.to_string(), prop: name.clone() }
        }
        Expr::Unary { op, expr } => {
            Expr::Unary { op: *op, expr: Box::new(resolve_filter(expr, var, tf)) }
        }
        Expr::Binary { op, lhs, rhs } => Expr::Binary {
            op: *op,
            lhs: Box::new(resolve_filter(lhs, var, tf)),
            rhs: Box::new(resolve_filter(rhs, var, tf)),
        },
        Expr::Call { recv, name, args } => Expr::Call {
            recv: recv.clone(),
            name: name.clone(),
            args: args.iter().map(|a| resolve_filter(a, var, tf)).collect(),
        },
        other => other.clone(),
    }
}

/// Normalize boolean comparisons for C output, with the literal on either
/// side: `x == True` / `True == x` → `x`, `x == False` / `False == x` → `!x`
/// (cleaner generated code, as in the paper's figures). `!=` flips the sense.
pub fn simplify_bool_cmp(e: &Expr) -> Expr {
    use crate::dsl::ast::{BinOp, UnOp};
    if let Expr::Binary { op, lhs, rhs } = e {
        let (lit, other) = match (&**lhs, &**rhs) {
            (_, Expr::BoolLit(b)) => (Some(*b), lhs),
            (Expr::BoolLit(b), _) => (Some(*b), rhs),
            _ => (None, lhs),
        };
        let want = match (op, lit) {
            (BinOp::Eq, Some(b)) => Some(b),
            (BinOp::Ne, Some(b)) => Some(!b),
            _ => None,
        };
        if let Some(w) = want {
            return if w {
                (**other).clone()
            } else {
                Expr::Unary { op: UnOp::Not, expr: other.clone() }
            };
        }
    }
    e.clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::ast::{BinOp, Expr};
    use crate::dsl::parser::parse;
    use crate::sema::check_function;

    #[test]
    fn filter_resolution() {
        let fns = parse(
            "function f(Graph g, propNode<bool> modified) {
               forall (v in g.nodes().filter(modified == True)) { }
             }",
        )
        .unwrap();
        let tf = check_function(&fns[0]).unwrap();
        let e = Expr::Binary {
            op: BinOp::Eq,
            lhs: Box::new(Expr::Var("modified".into())),
            rhs: Box::new(Expr::BoolLit(true)),
        };
        let r = resolve_filter(&e, "v", &tf);
        let s = simplify_bool_cmp(&r);
        assert_eq!(s, Expr::Prop { obj: "v".into(), prop: "modified".into() });
    }

    fn var(name: &str) -> Box<Expr> {
        Box::new(Expr::Var(name.into()))
    }

    fn lit(b: bool) -> Box<Expr> {
        Box::new(Expr::BoolLit(b))
    }

    fn cmp(op: BinOp, lhs: Box<Expr>, rhs: Box<Expr>) -> Expr {
        Expr::Binary { op, lhs, rhs }
    }

    fn not(e: Box<Expr>) -> Expr {
        Expr::Unary { op: crate::dsl::ast::UnOp::Not, expr: e }
    }

    #[test]
    fn bool_cmp_literal_on_the_right() {
        assert_eq!(simplify_bool_cmp(&cmp(BinOp::Eq, var("x"), lit(true))), *var("x"));
        assert_eq!(simplify_bool_cmp(&cmp(BinOp::Eq, var("x"), lit(false))), not(var("x")));
        assert_eq!(simplify_bool_cmp(&cmp(BinOp::Ne, var("x"), lit(false))), *var("x"));
        assert_eq!(simplify_bool_cmp(&cmp(BinOp::Ne, var("x"), lit(true))), not(var("x")));
    }

    #[test]
    fn bool_cmp_literal_on_the_left() {
        assert_eq!(simplify_bool_cmp(&cmp(BinOp::Eq, lit(true), var("x"))), *var("x"));
        assert_eq!(simplify_bool_cmp(&cmp(BinOp::Eq, lit(false), var("x"))), not(var("x")));
        assert_eq!(simplify_bool_cmp(&cmp(BinOp::Ne, lit(false), var("x"))), *var("x"));
        assert_eq!(simplify_bool_cmp(&cmp(BinOp::Ne, lit(true), var("x"))), not(var("x")));
    }

    #[test]
    fn non_bool_comparisons_are_untouched() {
        let e = cmp(BinOp::Lt, var("x"), Box::new(Expr::IntLit(3)));
        assert_eq!(simplify_bool_cmp(&e), e);
        // Eq without a bool literal on either side stays as written
        let e = cmp(BinOp::Eq, var("x"), var("y"));
        assert_eq!(simplify_bool_cmp(&e), e);
    }
}
