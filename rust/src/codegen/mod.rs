//! Code generators: the paper's four accelerator backends (CUDA, OpenCL,
//! SYCL, OpenACC — §3), the HIP, Metal, and WebGPU/WGSL backends, and the
//! executable JAX backend (DESIGN.md §1).
//!
//! # The plan → {HostOp, KernelOp} → render pipeline
//!
//! ```text
//! AST ──sema──▶ TypedFunction ──ir::lower──▶ IrProgram
//!                                               │
//!                              DevicePlan::build (ir/plan.rs)
//!          buffers · kernel schedule · HostOp schedule · KernelOp bodies
//!                                               │
//!                      ┌────────────────────────┴─────────────────────┐
//!         render_host_schedule (host half)        render_kernel_ops (device half)
//!         one driver walks the HostOp tree,       one driver walks each kernel's
//!         calling a backend's HostDialect         KernelOp tree, calling its
//!         hooks for each op's spelling            KernelDialect spelling hooks
//!                      └────────────────────────┬─────────────────────┘
//!        ┌───────┬───────┬────────┬───────┬─────┴───┬────────┬────────┬──────┐
//!        ▼       ▼       ▼        ▼       ▼         ▼        ▼        ▼      ▼
//!      cuda     hip    opencl    sycl   openacc   metal     wgsl          jax
//! ```
//!
//! Lowering happens exactly once, in [`crate::ir::plan`]: buffer slots,
//! kernel parameter lists, §4 transfer steps, every *host statement*
//! ([`DevicePlan::host_ops`]), and — since the KernelOp refactor — every
//! *kernel body* ([`crate::ir::plan::KernelPlan::body`], a typed
//! [`crate::ir::kernel::KernelOp`] tree with slots, scalar types, structured
//! BFS/filter guards, and OR-flag context resolved). A text backend is two
//! spelling tables: a [`HostDialect`] (`cudaMemcpy` vs `clEnqueueWriteBuffer`
//! vs `Q.memcpy` vs `queue.WriteBuffer`) driven by [`render_host_schedule`],
//! and a `KernelDialect` (`atomicMin` vs `atomic_fetch_min_explicit` vs
//! WGSL's `atomicMin(&…)`; `int x = e;` vs `var x : i32 = e;`) driven by
//! `body::render_kernel_ops`. No renderer walks the AST at all — which is
//! what makes a non-C-family backend possible: `wgsl.rs` spells the same op
//! tree into `var<storage>` bindings and `@compute` entry points.
//!
//! Each generated file embeds four comment blocks — the device-plan,
//! host-schedule, kernel-op, and schedule-plan manifests — that are
//! byte-identical across all text backends (`tests/plan_numbering.rs`,
//! `tests/host_schedule_conformance.rs`).
//!
//! The end-to-end walk-through of this pipeline — with a worked SSSP
//! example showing all three manifests, pinned to generator output by
//! `tests/architecture_doc.rs` — lives in `docs/ARCHITECTURE.md`.

pub mod body;
pub mod buf;
pub mod cexpr;
pub mod cuda;
pub mod hip;
pub mod jax;
pub mod metal;
pub mod openacc;
pub mod opencl;
pub mod sycl;
pub mod wgsl;

use crate::dsl::ast::{Expr, ReduceOp};
use crate::ir::plan::{DevicePlan, HostOp, TypeMap};
use crate::ir::IrProgram;
use buf::CodeBuf;
use cexpr::{emit, Style};

pub use crate::ir::kernel::{resolve_filter, simplify_bool_cmp};

/// Textual backends by name. The device plan is lowered once and shared by
/// whichever renderer is selected.
pub fn generate(backend: &str, ir: &IrProgram) -> anyhow::Result<String> {
    let plan = DevicePlan::build(ir)?;
    Ok(match backend {
        "cuda" => cuda::generate_with(ir, &plan),
        "hip" => hip::generate_with(ir, &plan),
        "opencl" => opencl::generate_with(ir, &plan),
        "sycl" => sycl::generate_with(ir, &plan),
        "openacc" => openacc::generate_with(ir, &plan),
        "metal" => metal::generate_with(ir, &plan),
        "wgsl" => wgsl::generate_with(ir, &plan),
        "jax" => jax::generate_with(ir, &plan)?.python,
        "planexec" => planexec_listing(&plan),
        other => anyhow::bail!(
            "unknown backend `{other}` (cuda|hip|opencl|sycl|openacc|metal|wgsl|jax|planexec)"
        ),
    })
}

/// `--backend planexec` emits no device source — the plan executor
/// ([`crate::backends::planexec`]) runs the lowering in-process. Compiling
/// still produces a text artifact: the exact plan manifests the executor
/// walks (the same blocks every text backend embeds as comments), so the
/// executed schedule can be inspected and diffed like any generated file.
fn planexec_listing(plan: &DevicePlan) -> String {
    let mut buf = CodeBuf::new();
    buf.line(&format!("// {} — plan-level reference execution listing", plan.func));
    buf.line("// This backend is executable, not textual: `--backend planexec` at run");
    buf.line("// time walks the device plan below in-process (simulated slot buffers,");
    buf.line("// sequential thread sweeps), differential-tested against the AST");
    buf.line("// interpreter in tests/planexec_parity.rs.");
    buf.line("");
    for l in plan.manifest() {
        buf.line(&format!("// {l}"));
    }
    buf.line("");
    for l in plan.host_manifest() {
        buf.line(&format!("// {l}"));
    }
    buf.line("");
    for l in plan.kernel_manifest() {
        buf.line(&format!("// {l}"));
    }
    buf.line("");
    for l in plan.schedule_manifest() {
        buf.line(&format!("// {l}"));
    }
    buf.finish()
}

/// Every text backend, in the order the snapshot matrix pins them.
pub const TEXT_BACKENDS: [&str; 7] =
    ["cuda", "opencl", "sycl", "openacc", "hip", "metal", "wgsl"];

/// Per-backend spellings for the host half of a generated program. The
/// driver ([`render_host_schedule`]) owns all host *structure* — statement
/// order, loop and branch nesting, the OR-flag context — and calls these
/// hooks for each [`HostOp`]'s backend-specific text. Implementations hold
/// their own [`DevicePlan`] reference and code buffers.
pub(crate) trait HostDialect {
    /// Scalar-type spelling for host declarations (C for every backend).
    fn host_types(&self) -> &'static TypeMap {
        &TypeMap::C
    }
    /// Expression naming style (buffer prefixes, bool literals).
    fn expr_style(&self) -> Style;
    /// Buffer receiving host-side lines.
    fn buf(&mut self) -> &mut CodeBuf;

    // -- prologue --
    fn decl_dims(&mut self);
    fn graph_to_device(&mut self);
    fn alloc_prop(&mut self, slot: u32);
    fn alloc_flag(&mut self);
    fn launch_setup(&mut self);

    // -- body --
    fn copy_prop(&mut self, dst: u32, src: u32);
    fn set_element(&mut self, slot: u32, index: &str, value: &Expr);
    fn init_props(&mut self, kernel: usize, inits: &[(u32, Expr)]);
    /// Emit kernel + launch site for one `forall`. The device body is
    /// plan-carried (`plan.kernels[kernel].body`); `or_flag` is the
    /// enclosing fixedPoint's flag property, when any (§4.1).
    fn launch(&mut self, kernel: usize, or_flag: Option<&str>);
    /// Emit the Fig 9 BFS skeleton; sweep bodies come from the plan's
    /// forward / reverse kernels.
    fn bfs(&mut self, index: usize, var: &str, from: &str);
    /// Open the fixedPoint host loop; returns the OR-flag property name the
    /// enclosed launches bind (§4.1).
    fn fixed_point_enter(&mut self, index: usize, var: &str) -> String;
    fn fixed_point_exit(&mut self, var: &str);

    // -- epilogue --
    fn epilogue_begin(&mut self);
    fn copy_out(&mut self, slot: u32);
    fn free_prop(&mut self, slot: u32);
    fn free_flag(&mut self);
    fn free_graph(&mut self);
}

/// The one host-statement driver shared by every text backend: walks a
/// [`HostOp`] schedule, rendering structure (declarations, assignments,
/// loops, branches) directly and delegating backend-specific operations to
/// the [`HostDialect`]. `or_flag` is the enclosing fixedPoint's OR-flag
/// property, threaded to kernel launches.
pub(crate) fn render_host_schedule<D: HostDialect + ?Sized>(
    d: &mut D,
    ops: &[HostOp],
    or_flag: Option<&str>,
) {
    for op in ops {
        match op {
            HostOp::DeclDims => d.decl_dims(),
            HostOp::GraphToDevice => d.graph_to_device(),
            HostOp::AllocProp { slot } => d.alloc_prop(*slot),
            HostOp::AllocFlag => d.alloc_flag(),
            HostOp::LaunchSetup => d.launch_setup(),
            HostOp::DeclScalar { name, ty, init } => {
                let t = d.host_types().name(*ty);
                let line = match init {
                    Some(e) => format!("{t} {name} = {};", emit(e, &d.expr_style())),
                    None => format!("{t} {name};"),
                };
                d.buf().line(&line);
            }
            HostOp::AssignScalar { name, value } => {
                let line = format!("{name} = {};", emit(value, &d.expr_style()));
                d.buf().line(&line);
            }
            HostOp::CopyProp { dst, src } => d.copy_prop(*dst, *src),
            HostOp::SetElement { slot, index, value } => d.set_element(*slot, index, value),
            HostOp::ReduceScalar { name, op, value } => {
                let line =
                    format!("{name} = {name} {} {};", red_sym(*op), emit(value, &d.expr_style()));
                d.buf().line(&line);
            }
            HostOp::InitProps { kernel, inits } => d.init_props(*kernel, inits),
            HostOp::Launch { kernel } => d.launch(*kernel, or_flag),
            HostOp::SeqFor { var, set, body } => {
                d.buf().open(&format!("for (int {var} : {set}) {{"));
                render_host_schedule(d, body, or_flag);
                d.buf().close("}");
            }
            HostOp::FixedPoint { index, var, body } => {
                let flag = d.fixed_point_enter(*index, var);
                render_host_schedule(d, body, Some(&flag));
                d.fixed_point_exit(var);
            }
            HostOp::Bfs { index, var, from } => d.bfs(*index, var, from),
            HostOp::DoWhile { body, cond } => {
                d.buf().open("do {");
                render_host_schedule(d, body, or_flag);
                let c = emit(cond, &d.expr_style());
                d.buf().close(&format!("}} while ({c});"));
            }
            HostOp::While { cond, body } => {
                let c = emit(cond, &d.expr_style());
                d.buf().open(&format!("while ({c}) {{"));
                render_host_schedule(d, body, or_flag);
                d.buf().close("}");
            }
            HostOp::If { cond, then, els } => {
                let c = emit(cond, &d.expr_style());
                d.buf().open(&format!("if ({c}) {{"));
                render_host_schedule(d, then, or_flag);
                if let Some(e) = els {
                    d.buf().close("} else {");
                    d.buf().inc();
                    render_host_schedule(d, e, or_flag);
                }
                d.buf().close("}");
            }
            HostOp::Return { value } => {
                let line = format!("return {};", emit(value, &d.expr_style()));
                d.buf().line(&line);
            }
            HostOp::Unsupported { what } => {
                let line = format!("/* {what} unsupported */");
                d.buf().line(&line);
            }
            HostOp::EpilogueBegin => d.epilogue_begin(),
            HostOp::CopyOut { slot } => d.copy_out(*slot),
            HostOp::FreeProp { slot } => d.free_prop(*slot),
            HostOp::FreeFlag => d.free_flag(),
            HostOp::FreeGraph => d.free_graph(),
        }
    }
}

/// Standard file header: generator banner + the four manifest comment
/// blocks (device plan, host schedule, kernel ops, schedule plan) every
/// text backend embeds.
pub(crate) fn manifest_header(label: &str, plan: &DevicePlan) -> String {
    let mut out = format!("// Generated by starplat-rs — {label} backend\n");
    for l in plan
        .manifest()
        .iter()
        .chain(plan.host_manifest().iter())
        .chain(plan.kernel_manifest().iter())
        .chain(plan.schedule_manifest().iter())
    {
        out.push_str("// ");
        out.push_str(l);
        out.push('\n');
    }
    out
}

/// C operator for a host-side scalar reduction (shared by all renderers).
pub(crate) fn red_sym(op: ReduceOp) -> &'static str {
    match op {
        ReduceOp::Add | ReduceOp::Count => "+",
        ReduceOp::Mul => "*",
        ReduceOp::And => "&&",
        ReduceOp::Or => "||",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::ast::{BinOp, Expr};
    use crate::dsl::parser::parse;
    use crate::sema::check_function;

    #[test]
    fn filter_resolution() {
        let fns = parse(
            "function f(Graph g, propNode<bool> modified) {
               forall (v in g.nodes().filter(modified == True)) { }
             }",
        )
        .unwrap();
        let tf = check_function(&fns[0]).unwrap();
        let e = Expr::Binary {
            op: BinOp::Eq,
            lhs: Box::new(Expr::Var("modified".into())),
            rhs: Box::new(Expr::BoolLit(true)),
        };
        let r = resolve_filter(&e, "v", &tf);
        let s = simplify_bool_cmp(&r);
        assert_eq!(s, Expr::Prop { obj: "v".into(), prop: "modified".into() });
    }

    fn var(name: &str) -> Box<Expr> {
        Box::new(Expr::Var(name.into()))
    }

    fn lit(b: bool) -> Box<Expr> {
        Box::new(Expr::BoolLit(b))
    }

    fn cmp(op: BinOp, lhs: Box<Expr>, rhs: Box<Expr>) -> Expr {
        Expr::Binary { op, lhs, rhs }
    }

    fn not(e: Box<Expr>) -> Expr {
        Expr::Unary { op: crate::dsl::ast::UnOp::Not, expr: e }
    }

    #[test]
    fn bool_cmp_literal_on_the_right() {
        assert_eq!(simplify_bool_cmp(&cmp(BinOp::Eq, var("x"), lit(true))), *var("x"));
        assert_eq!(simplify_bool_cmp(&cmp(BinOp::Eq, var("x"), lit(false))), not(var("x")));
        assert_eq!(simplify_bool_cmp(&cmp(BinOp::Ne, var("x"), lit(false))), *var("x"));
        assert_eq!(simplify_bool_cmp(&cmp(BinOp::Ne, var("x"), lit(true))), not(var("x")));
    }

    #[test]
    fn bool_cmp_literal_on_the_left() {
        assert_eq!(simplify_bool_cmp(&cmp(BinOp::Eq, lit(true), var("x"))), *var("x"));
        assert_eq!(simplify_bool_cmp(&cmp(BinOp::Eq, lit(false), var("x"))), not(var("x")));
        assert_eq!(simplify_bool_cmp(&cmp(BinOp::Ne, lit(false), var("x"))), *var("x"));
        assert_eq!(simplify_bool_cmp(&cmp(BinOp::Ne, lit(true), var("x"))), not(var("x")));
    }

    #[test]
    fn non_bool_comparisons_are_untouched() {
        let e = cmp(BinOp::Lt, var("x"), Box::new(Expr::IntLit(3)));
        assert_eq!(simplify_bool_cmp(&e), e);
        // Eq without a bool literal on either side stays as written
        let e = cmp(BinOp::Eq, var("x"), var("y"));
        assert_eq!(simplify_bool_cmp(&e), e);
    }
}
