//! Indented code buffer shared by all emitters.

#[derive(Default)]
pub struct CodeBuf {
    out: String,
    indent: usize,
}

impl CodeBuf {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn line(&mut self, s: &str) {
        if s.is_empty() {
            self.out.push('\n');
            return;
        }
        self.out.push_str(&"  ".repeat(self.indent));
        self.out.push_str(s);
        self.out.push('\n');
    }
    pub fn lines(&mut self, s: &str) {
        for l in s.lines() {
            self.line(l);
        }
    }
    pub fn open(&mut self, s: &str) {
        self.line(s);
        self.indent += 1;
    }
    pub fn close(&mut self, s: &str) {
        self.indent = self.indent.saturating_sub(1);
        self.line(s);
    }
    /// Raw indent bump (for `} else {` re-opens).
    pub fn inc(&mut self) {
        self.indent += 1;
    }
    pub fn finish(self) -> String {
        self.out
    }
    pub fn indent_level(&self) -> usize {
        self.indent
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indents() {
        let mut b = CodeBuf::new();
        b.open("if (x) {");
        b.line("y();");
        b.close("}");
        assert_eq!(b.finish(), "if (x) {\n  y();\n}\n");
    }
}
