//! DSL-expression → target-expression translation, parameterized by a
//! naming [`Style`] so CUDA (`gpu_dist[nbr]`), OpenCL (`gpu_dist`), SYCL
//! (`g.gpu_dist`), OpenACC (`dist[nbr]`), Metal, and WGSL all share one
//! walker.

use crate::dsl::ast::*;
use std::collections::HashSet;

/// Naming conventions for one backend / context.
#[derive(Clone)]
pub struct Style {
    /// device array name for a property: e.g. "dist" -> "gpu_dist"
    pub prop_array: fn(&str) -> String,
    /// scalar variable reference (kernel parameter or local)
    pub scalar: fn(&str) -> String,
    /// graph CSR names: (offsets, edge_list, rev_offsets, src_list)
    pub offsets: &'static str,
    pub edge_list: &'static str,
    pub rev_offsets: &'static str,
    pub src_list: &'static str,
    pub num_nodes: &'static str,
    pub bool_true: &'static str,
    pub bool_false: &'static str,
    /// spelling of the DSL's `INF`. `(INT_MAX / 2)` in the C family — the
    /// halved sentinel keeps `dist[v] + weight[e]` from overflowing (UB in
    /// C) and matches the interpreter oracle's `reference::INF`, so plan
    /// execution and generated code agree bit-for-bit on unreachable
    /// vertices. WGSL has no macro, so it spells the literal.
    pub inf: &'static str,
    /// spelling of `abs(x)` ("fabs" for the C family, "abs" in WGSL)
    pub abs_fn: &'static str,
    /// does `is_an_edge`'s lookup helper take the CSR arrays as trailing
    /// arguments? (true for the C family; WGSL helpers read the module-scope
    /// bindings directly)
    pub edge_fn_passes_graph: bool,
    /// properties whose device buffer has an *atomic* element type in this
    /// kernel (Metal `atomic_int`, WGSL `atomic<i32>`): plain reads must go
    /// through `atomic_load` below. Empty for the C-family backends, whose
    /// atomics operate on plain cells.
    pub atomic_props: HashSet<String>,
    /// wrap a read of an atomic cell, e.g. `gpu_dist[v]` →
    /// `atomicLoad(&gpu_dist[v])`
    pub atomic_load: fn(&str) -> String,
    /// float properties whose buffer is an *integer-word* atomic in this
    /// kernel (WGSL's `array<atomic<u32>>` — WGSL has no f32 atomics, so
    /// atomically-updated f32 buffers store the bit pattern and every access
    /// bitcasts). Empty for every backend with native float atomics.
    pub atomic_f32_props: HashSet<String>,
    /// wrap a read of a bit-pattern f32 cell, e.g. `gpu_sigma[v]` →
    /// `bitcast<f32>(atomicLoad(&gpu_sigma[v]))`
    pub atomic_f32_load: fn(&str) -> String,
}

pub fn cuda_style() -> Style {
    Style {
        prop_array: |p| format!("gpu_{p}"),
        scalar: |s| s.to_string(),
        offsets: "gpu_OA",
        edge_list: "gpu_edgeList",
        rev_offsets: "gpu_rev_OA",
        src_list: "gpu_srcList",
        num_nodes: "V",
        bool_true: "true",
        bool_false: "false",
        inf: "(INT_MAX / 2)",
        abs_fn: "fabs",
        edge_fn_passes_graph: true,
        atomic_props: HashSet::new(),
        atomic_load: |r| r.to_string(),
        atomic_f32_props: HashSet::new(),
        atomic_f32_load: |r| r.to_string(),
    }
}

pub fn opencl_style() -> Style {
    Style { bool_true: "1", bool_false: "0", ..cuda_style() }
}

pub fn sycl_style() -> Style {
    Style {
        prop_array: |p| format!("g.gpu_{p}"),
        offsets: "g.gpu_indexOfNodes",
        edge_list: "g.gpu_edgeList",
        rev_offsets: "g.gpu_rev_indexOfNodes",
        src_list: "g.gpu_srcList",
        ..cuda_style()
    }
}

pub fn openacc_style() -> Style {
    Style {
        prop_array: |p| p.to_string(),
        offsets: "g.indexofNodes",
        edge_list: "g.edgeList",
        rev_offsets: "g.rev_indexofNodes",
        src_list: "g.srcList",
        num_nodes: "g.num_nodes()",
        ..cuda_style()
    }
}

/// MSL device code: CUDA naming, but buffers the kernel updates atomically
/// are `device atomic_*` and their plain reads need `atomic_load_explicit`.
pub fn metal_style(atomic_props: HashSet<String>) -> Style {
    Style {
        atomic_props,
        atomic_load: |r| format!("atomic_load_explicit(&{r}, memory_order_relaxed)"),
        ..cuda_style()
    }
}

/// WGSL device code: storage-buffer names keep the CUDA `gpu_` convention,
/// booleans are `i32` words (bool is not host-shareable), `INF` is the i32
/// max literal, and atomically-updated buffers are `array<atomic<i32>>`
/// whose reads go through `atomicLoad`. WGSL has no float atomics at all,
/// so atomically-updated *f32* buffers (`atomic_f32_props`) are
/// `array<atomic<u32>>` holding the bit pattern: plain reads bitcast the
/// loaded word back to f32, and the update helpers (`atomicAddF32` & co.)
/// run bitcast compare-exchange loops.
pub fn wgsl_style(atomic_props: HashSet<String>, atomic_f32_props: HashSet<String>) -> Style {
    Style {
        bool_true: "1",
        bool_false: "0",
        inf: "1073741823",
        abs_fn: "abs",
        edge_fn_passes_graph: false,
        atomic_props,
        atomic_load: |r| format!("atomicLoad(&{r})"),
        atomic_f32_props,
        atomic_f32_load: |r| format!("bitcast<f32>(atomicLoad(&{r}))"),
        ..cuda_style()
    }
}

/// Translate an expression in a kernel context. `elem` is unused today but
/// kept for future contexts where bare property names need an element.
pub fn emit(e: &Expr, st: &Style) -> String {
    match e {
        Expr::IntLit(n) => n.to_string(),
        Expr::FloatLit(x) => {
            if x.fract() == 0.0 {
                format!("{x:.1}")
            } else {
                x.to_string()
            }
        }
        Expr::BoolLit(true) => st.bool_true.to_string(),
        Expr::BoolLit(false) => st.bool_false.to_string(),
        Expr::Inf => st.inf.to_string(),
        Expr::Var(v) => (st.scalar)(v),
        Expr::Prop { obj, prop } => {
            let cell = format!("{}[{}]", (st.prop_array)(prop), (st.scalar)(obj));
            if st.atomic_f32_props.contains(prop) {
                (st.atomic_f32_load)(&cell)
            } else if st.atomic_props.contains(prop) {
                (st.atomic_load)(&cell)
            } else {
                cell
            }
        }
        Expr::Call { recv, name, args } => emit_call(recv.as_deref(), name, args, st),
        Expr::Unary { op, expr } => {
            let inner = emit_atom(expr, st);
            match op {
                UnOp::Not => format!("!{inner}"),
                UnOp::Neg => format!("-{inner}"),
            }
        }
        Expr::Binary { op, lhs, rhs } => {
            format!("{} {} {}", emit_atom(lhs, st), op.symbol(), emit_atom(rhs, st))
        }
    }
}

fn emit_atom(e: &Expr, st: &Style) -> String {
    match e {
        Expr::Binary { .. } => format!("({})", emit(e, st)),
        _ => emit(e, st),
    }
}

fn emit_call(recv: Option<&str>, name: &str, args: &[Expr], st: &Style) -> String {
    match (recv, name) {
        (Some(_), "num_nodes") => st.num_nodes.to_string(),
        (Some(_), "num_edges") => "E".to_string(),
        (Some(r), "outDegree") => {
            let v = (st.scalar)(r);
            format!("({off}[{v}+1] - {off}[{v}])", off = st.offsets)
        }
        (Some(r), "inDegree") => {
            let v = (st.scalar)(r);
            format!("({off}[{v}+1] - {off}[{v}])", off = st.rev_offsets)
        }
        (Some(_), "is_an_edge") => {
            let a: Vec<String> = args.iter().map(|x| emit(x, st)).collect();
            if st.edge_fn_passes_graph {
                format!("findNeighborSorted({}, {}, {}, {})", a[0], a[1], st.offsets, st.edge_list)
            } else {
                format!("findNeighborSorted({}, {})", a[0], a[1])
            }
        }
        (Some(_), "get_edge") => {
            // neighbor iteration supplies the current edge id
            "edge".to_string()
        }
        (None, "abs") => format!("{}({})", st.abs_fn, emit(&args[0], st)),
        _ => {
            let a: Vec<String> = args.iter().map(|x| emit(x, st)).collect();
            match recv {
                Some(r) => format!("{}.{name}({})", (st.scalar)(r), a.join(", ")),
                None => format!("{name}({})", a.join(", ")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parser::parse;
    use crate::dsl::ast::Stmt;

    fn first_expr(src: &str) -> Expr {
        let f = parse(src).unwrap().remove(0);
        match f.body.into_iter().next().unwrap() {
            Stmt::Decl { init: Some(e), .. } => e,
            _ => panic!(),
        }
    }

    #[test]
    fn cuda_prop_naming() {
        let e =
            first_expr("function f(Graph g, propNode<int> dist, node v) { int x = v.dist + 3; }");
        assert_eq!(emit(&e, &cuda_style()), "gpu_dist[v] + 3");
    }

    #[test]
    fn openacc_prop_naming() {
        let e =
            first_expr("function f(Graph g, propNode<int> dist, node v) { int x = v.dist + 3; }");
        assert_eq!(emit(&e, &openacc_style()), "dist[v] + 3");
    }

    #[test]
    fn out_degree_uses_offsets() {
        let e = first_expr("function f(Graph g, node v) { int d = v.outDegree(); }");
        assert_eq!(emit(&e, &cuda_style()), "(gpu_OA[v+1] - gpu_OA[v])");
        assert!(emit(&e, &sycl_style()).contains("g.gpu_indexOfNodes"));
    }

    #[test]
    fn inf_is_the_overflow_safe_half_sentinel() {
        // must equal the interpreter's `reference::INF` (i32::MAX / 2): the
        // plan executor differential-tests generated semantics against it
        let e = first_expr("function f(Graph g) { int x = INF; }");
        assert_eq!(emit(&e, &cuda_style()), "(INT_MAX / 2)");
        assert_eq!(crate::algorithms::reference::INF, 1073741823);
    }

    #[test]
    fn wgsl_style_spellings() {
        let e = first_expr("function f(Graph g) { int x = INF; }");
        assert_eq!(emit(&e, &wgsl_style(HashSet::new(), HashSet::new())), "1073741823");
        let e =
            first_expr("function f(Graph g, propNode<int> dist, node v) { int x = v.dist + 3; }");
        let mut st = wgsl_style(["dist".to_string()].into_iter().collect(), HashSet::new());
        assert_eq!(emit(&e, &st), "atomicLoad(&gpu_dist[v]) + 3");
        st.atomic_props.clear();
        assert_eq!(emit(&e, &st), "gpu_dist[v] + 3");
    }

    #[test]
    fn wgsl_style_bitcasts_f32_atomic_reads() {
        // an atomically-updated f32 buffer is atomic<u32> bit patterns:
        // plain reads load the word and bitcast back to f32
        let e = first_expr(
            "function f(Graph g, propNode<float> sigma, node v) { float x = v.sigma + 1.0; }",
        );
        let st = wgsl_style(HashSet::new(), ["sigma".to_string()].into_iter().collect());
        assert_eq!(emit(&e, &st), "bitcast<f32>(atomicLoad(&gpu_sigma[v])) + 1.0");
    }

    #[test]
    fn metal_style_wraps_atomic_reads() {
        let e =
            first_expr("function f(Graph g, propNode<int> dist, node v) { int x = v.dist + 3; }");
        let st = metal_style(["dist".to_string()].into_iter().collect());
        assert_eq!(emit(&e, &st), "atomic_load_explicit(&gpu_dist[v], memory_order_relaxed) + 3");
    }
}
