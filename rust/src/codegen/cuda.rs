//! CUDA-family backends (paper §3, Figures 2, 6, 9, 12).
//!
//! Split code generation: `__global__` kernels + a host driver that owns
//! allocation, H2D/D2H transfers (per the §4 transfer plan), kernel
//! launches, and the fixedPoint / BFS host loops.
//!
//! This is a thin renderer over [`DevicePlan`]: buffer names, kernel
//! parameter lists, transfer steps, the complete host-statement schedule
//! ([`crate::ir::plan::HostOp`]), and every kernel body
//! ([`crate::ir::kernel::KernelOp`], carried on the plan) come from the
//! plan. The host half is rendered by the shared
//! [`super::render_host_schedule`] driver through the [`HostDialect`] hooks
//! below; kernel bodies by `super::body::render_kernel_ops` through the
//! [`CudaKernel`] dialect. Everything CUDA-specific is a [`Spellings`]
//! table, which is exactly what lets `hip.rs` reuse this whole module: HIP
//! is the same renderer with `hipMalloc` / `hipMemcpy` /
//! `hipLaunchKernelGGL` spellings and zero lowering of its own.

use super::body::{render_kernel_ops, KernelDialect};
use super::buf::CodeBuf;
use super::cexpr::{cuda_style, emit, Style};
use super::{render_host_schedule, HostDialect};
use crate::dsl::ast::{Expr, MinMax, ReduceOp};
use crate::ir::plan::{DevicePlan, KernelParam, KernelPlan, TypeMap};
use crate::ir::{IrProgram, ScalarTy};

const TYPES: &TypeMap = &TypeMap::C;

/// The CUDA device dialect (also HIP's: ROCm compiles the CUDA kernel
/// idioms — `atomicMin`, `blockIdx` — as-is).
pub(crate) struct CudaKernel;

impl KernelDialect for CudaKernel {
    fn types(&self) -> &'static TypeMap {
        TYPES
    }

    fn style(&self) -> Style {
        cuda_style()
    }

    fn reduce(&self, buf: &mut CodeBuf, loc: &str, op: ReduceOp, _ty: ScalarTy, val: &str) {
        match op {
            ReduceOp::Add | ReduceOp::Count => buf.line(&format!("atomicAdd(&{loc}, {val});")),
            ReduceOp::Mul => buf.line(&format!("atomicMul(&{loc}, {val}); // emulated via CAS")),
            ReduceOp::And => buf.line(&format!("atomicAnd(&{loc}, {val});")),
            ReduceOp::Or => buf.line(&format!("atomicOr(&{loc}, {val});")),
        }
    }

    fn min_max_update(
        &self,
        buf: &mut CodeBuf,
        kind: MinMax,
        loc: &str,
        tmp: &str,
        _ty: ScalarTy,
    ) {
        buf.line(&format!(
            "atomic{}(&{loc}, {tmp});",
            if kind == MinMax::Min { "Min" } else { "Max" }
        ));
    }

    fn set_or_flag(&self, buf: &mut CodeBuf) {
        buf.line("gpu_finished[0] = false;");
    }
}

/// Everything that differs between CUDA and HIP: API entry points and the
/// kernel-launch statement. The renderer below is shared verbatim.
pub(crate) struct Spellings {
    /// banner label ("CUDA", "HIP")
    pub label: &'static str,
    pub includes: &'static [&'static str],
    pub malloc: &'static str,
    pub memcpy: &'static str,
    pub h2d: &'static str,
    pub d2h: &'static str,
    pub d2d: &'static str,
    pub free: &'static str,
    /// full synchronization statement, e.g. `cudaDeviceSynchronize();`
    pub sync: &'static str,
    /// render one kernel-launch statement from (kernel, grid, block, args)
    pub launch: fn(&str, &str, &str, &str) -> String,
}

fn cuda_launch(kernel: &str, grid: &str, block: &str, args: &str) -> String {
    format!("{kernel}<<<{grid}, {block}>>>({args});")
}

pub(crate) const CUDA_SPELLINGS: Spellings = Spellings {
    label: "CUDA",
    includes: &[
        "#include <cuda.h>",
        "#include <climits>",
        "#include <cstdlib>",
        "#include <cstring>",
        "#include \"libstarplat_cuda.h\"",
    ],
    malloc: "cudaMalloc",
    memcpy: "cudaMemcpy",
    h2d: "cudaMemcpyHostToDevice",
    d2h: "cudaMemcpyDeviceToHost",
    d2d: "cudaMemcpyDeviceToDevice",
    free: "cudaFree",
    sync: "cudaDeviceSynchronize();",
    launch: cuda_launch,
};

pub fn generate(ir: &IrProgram) -> Result<String, crate::dsl::diag::DslError> {
    Ok(generate_with(ir, &DevicePlan::build(ir)?))
}

/// Render with a pre-built plan ([`super::generate`] lowers once for all
/// backends).
pub(crate) fn generate_with(ir: &IrProgram, plan: &DevicePlan) -> String {
    generate_family(ir, plan, &CUDA_SPELLINGS)
}

/// Shared CUDA-family entry point: CUDA and HIP differ only in `sp`.
pub(crate) fn generate_family(
    _ir: &IrProgram,
    plan: &DevicePlan,
    sp: &'static Spellings,
) -> String {
    let mut g = Gen { plan, sp, kernels: CodeBuf::new(), host: CodeBuf::new() };
    g.run()
}

struct Gen<'a> {
    plan: &'a DevicePlan,
    sp: &'static Spellings,
    kernels: CodeBuf,
    host: CodeBuf,
}

impl<'a> Gen<'a> {
    fn run(&mut self) -> String {
        let plan = self.plan;
        self.host.line("");
        let params = plan.host_signature(TYPES);
        self.host.open(&format!("void {}({}) {{", plan.func, params.join(", ")));
        render_host_schedule(self, &plan.host_ops, None);
        self.host.close("}");

        let mut out = super::manifest_header(self.sp.label, plan);
        for inc in self.sp.includes {
            out.push_str(inc);
            out.push('\n');
        }
        out.push('\n');
        out.push_str(&std::mem::take(&mut self.kernels).finish());
        out.push_str(&std::mem::take(&mut self.host).finish());
        out
    }

    /// Declaration for one plan-ordered kernel parameter.
    fn param_decl(&self, p: &KernelParam) -> String {
        match p {
            KernelParam::NumNodes => "int V".to_string(),
            KernelParam::Graph(a) => format!("int* {}", a.device_name()),
            KernelParam::Prop(s) => {
                let m = self.plan.meta(*s);
                format!("{}* gpu_{}", TYPES.name(m.ty), m.name)
            }
            KernelParam::ReductionCell { name, ty } => format!("{}* d_{name}", TYPES.name(*ty)),
            KernelParam::Scalar { name, ty } => format!("{} {name}", TYPES.name(*ty)),
            KernelParam::OrFlag => "bool* gpu_finished".to_string(),
        }
    }

    fn launch_line(&mut self, kernel: &str, grid: &str, block: &str, args: &str) {
        let line = (self.sp.launch)(kernel, grid, block, args);
        self.host.line(&line);
    }
}

impl<'a> HostDialect for Gen<'a> {
    fn expr_style(&self) -> Style {
        cuda_style()
    }

    fn buf(&mut self) -> &mut CodeBuf {
        &mut self.host
    }

    fn decl_dims(&mut self) {
        self.host.line("int V = g.num_nodes();");
        self.host.line("int E = g.num_edges();");
        self.host.line("");
    }

    fn graph_to_device(&mut self) {
        self.host.line("// §4.1: the static graph is copied to the device once, never back");
        for &arr in &self.plan.graph_arrays {
            let (dev, host, len) = (arr.device_name(), arr.host_name(), arr.len_sym());
            self.host.line(&format!("int* {dev};"));
            self.host
                .line(&format!("{}(&{dev}, sizeof(int) * {len});", self.sp.malloc));
            self.host.line(&format!(
                "{}({dev}, {host}, sizeof(int) * {len}, {});",
                self.sp.memcpy, self.sp.h2d
            ));
        }
    }

    fn alloc_prop(&mut self, slot: u32) {
        let m = self.plan.meta(slot);
        let ty = TYPES.name(m.ty);
        let len = m.len_sym();
        self.host.line(&format!("{ty}* gpu_{};", m.name));
        self.host
            .line(&format!("{}(&gpu_{}, sizeof({ty}) * {len});", self.sp.malloc, m.name));
    }

    fn alloc_flag(&mut self) {
        self.host.line("bool* gpu_finished;");
        self.host.line(&format!("{}(&gpu_finished, sizeof(bool) * 1);", self.sp.malloc));
    }

    fn launch_setup(&mut self) {
        self.host.line("");
        self.host.line("unsigned threadsPerBlock = 512;");
        self.host.line("unsigned numBlocks = (V + threadsPerBlock - 1) / threadsPerBlock;");
        self.host.line("");
    }

    fn copy_prop(&mut self, dst: u32, src: u32) {
        let ty = TYPES.name(self.plan.meta(dst).ty);
        self.host.line(&format!(
            "{}(gpu_{}, gpu_{}, sizeof({ty}) * V, {});",
            self.sp.memcpy,
            self.plan.prop_name(dst),
            self.plan.prop_name(src),
            self.sp.d2d
        ));
    }

    fn set_element(&mut self, slot: u32, index: &str, value: &Expr) {
        // single-element device store, e.g. src.sigma = 1
        let m = self.plan.meta(slot);
        let ty = TYPES.name(m.ty);
        let val = emit(value, &cuda_style());
        let args = format!("V, gpu_{}, {index}, ({ty}){val}", m.name);
        self.launch_line(&format!("initIndex<{ty}>"), "1", "1", &args);
    }

    fn init_props(&mut self, _kernel: usize, inits: &[(u32, Expr)]) {
        for (slot, e) in inits {
            let m = self.plan.meta(*slot);
            let ty = TYPES.name(m.ty);
            let v = emit(e, &cuda_style());
            let args = format!("V, gpu_{}, ({ty}){v}", m.name);
            self.launch_line(
                &format!("initKernel<{ty}>"),
                "numBlocks",
                "threadsPerBlock",
                &args,
            );
        }
    }

    /// Fig 2 / Fig 6 kernel: one thread per vertex + the launch site. The
    /// signature and argument list are the plan's canonical parameter order;
    /// the body is the plan-carried [`crate::ir::kernel::KernelOp`] tree.
    fn launch(&mut self, kernel: usize, or_flag: Option<&str>) {
        let plan = self.plan;
        let k: &KernelPlan = &plan.kernels[kernel];
        let body = k.body.as_ref().expect("forall kernel carries a lowered body");
        let params = k.params(or_flag.is_some());
        let sig: Vec<String> = params.iter().map(|p| self.param_decl(p)).collect();
        self.kernels.open(&format!("__global__ void {}({}) {{", k.name, sig.join(", ")));
        self.kernels.line(&format!(
            "unsigned {v} = blockIdx.x * blockDim.x + threadIdx.x;",
            v = body.thread_var
        ));
        self.kernels.line(&format!("if ({} >= V) return;", body.thread_var));
        if let Some(g) = &body.guard {
            self.kernels.line(&format!("if (!({})) return;", emit(g, &cuda_style())));
        }
        render_kernel_ops(&CudaKernel, plan, &body.ops, &mut self.kernels);
        self.kernels.close("}");
        self.kernels.line("");
        // schedule plan: a derived pull twin re-orients the relaxation onto
        // the reverse CSR; the host picks a direction at runtime
        if let Some(pull) = &k.pull_body {
            self.kernels
                .open(&format!("__global__ void {}_pull({}) {{", k.name, sig.join(", ")));
            self.kernels.line(&format!(
                "unsigned {v} = blockIdx.x * blockDim.x + threadIdx.x;",
                v = pull.thread_var
            ));
            self.kernels.line(&format!("if ({} >= V) return;", pull.thread_var));
            render_kernel_ops(&CudaKernel, plan, &pull.ops, &mut self.kernels);
            self.kernels.close("}");
            self.kernels.line("");
        }
        // ---- launch site (Fig 2's host half): plan-bound transfer steps ----
        for &c in &k.copy_in {
            let m = self.plan.meta(c);
            let ty = TYPES.name(m.ty);
            let len = m.len_sym();
            self.host.line(&format!(
                "// copy-in (§4.1 analysis): {} is read before first device write",
                m.name
            ));
            self.host.line(&format!(
                "{}(gpu_{n}, {n}, sizeof({ty}) * {len}, {});",
                self.sp.memcpy,
                self.sp.h2d,
                n = m.name
            ));
        }
        for (r, _, ty) in &k.reductions {
            let t = TYPES.name(*ty);
            self.host.line(&format!("// device reduction cell for `{r}` (thrust-free, §3.3)"));
            self.host.line(&format!("{t}* d_{r};"));
            self.host.line(&format!("{}(&d_{r}, sizeof({t}));", self.sp.malloc));
            self.host
                .line(&format!("{}(d_{r}, &{r}, sizeof({t}), {});", self.sp.memcpy, self.sp.h2d));
        }
        let args: Vec<String> = params.iter().map(|p| self.plan.launch_arg(p)).collect();
        let name = k.name.clone();
        if k.pull_body.is_some() {
            self.host
                .line("// schedule plan: STARPLAT_DIRECTION=pull selects the reverse-CSR variant");
            self.host.line(&format!(
                "bool usePull_{} = getenv(\"STARPLAT_DIRECTION\") != NULL && \
                 strcmp(getenv(\"STARPLAT_DIRECTION\"), \"pull\") == 0;",
                k.id
            ));
            self.host.open(&format!("if (usePull_{}) {{", k.id));
            self.launch_line(
                &format!("{name}_pull"),
                "numBlocks",
                "threadsPerBlock",
                &args.join(", "),
            );
            self.host.close("} else {");
            self.host.inc();
            self.launch_line(&name, "numBlocks", "threadsPerBlock", &args.join(", "));
            self.host.close("}");
        } else {
            self.launch_line(&name, "numBlocks", "threadsPerBlock", &args.join(", "));
        }
        self.host.line(self.sp.sync);
        for (r, _, ty) in &k.reductions {
            let t = TYPES.name(*ty);
            self.host
                .line(&format!("{}(&{r}, d_{r}, sizeof({t}), {});", self.sp.memcpy, self.sp.d2h));
            self.host.line(&format!("{}(d_{r});", self.sp.free));
        }
        if !k.defer_to_loop_exit {
            for &c in &k.copy_out {
                let m = self.plan.meta(c);
                let ty = TYPES.name(m.ty);
                let len = m.len_sym();
                self.host.line(&format!(
                    "{}({n}, gpu_{n}, sizeof({ty}) * {len}, {});",
                    self.sp.memcpy,
                    self.sp.d2h,
                    n = m.name
                ));
            }
        }
    }

    /// Fig 9: host do-while over levels + BFS kernel(s), skeleton from the
    /// plan's [`crate::ir::plan::BfsPlan`], sweep bodies from the plan's
    /// kernels.
    fn bfs(&mut self, index: usize, var: &str, from: &str) {
        let plan = self.plan;
        let b = &plan.bfs_loops[index];
        let fwd = &plan.kernels[b.fwd];
        let fbody = fwd.body.as_ref().expect("BFS forward sweep carries a lowered body");
        // the skeleton binds level/depth/finished itself; remaining buffers
        // come from the plan's parameter list. A declared level property
        // keeps its plan type; the implicit buffer (e.g. BC) is int.
        let lt = b.level.map(|s| self.plan.c_ty(s, TYPES)).unwrap_or("int");
        let mut sig: Vec<String> = Vec::new();
        let mut args: Vec<String> = Vec::new();
        for p in fwd.bfs_params(b.level) {
            sig.push(self.param_decl(&p));
            args.push(self.plan.launch_arg(&p));
        }
        for (decl, arg) in [
            (format!("{lt}* gpu_level"), "gpu_level"),
            ("int* d_hops_from_source".to_string(), "d_hops_from_source"),
            ("bool* d_finished".to_string(), "d_finished"),
        ] {
            sig.push(decl);
            args.push(arg.to_string());
        }
        self.kernels.open(&format!("__global__ void {}({}) {{", fwd.name, sig.join(", ")));
        self.kernels.line(&format!("unsigned {var} = blockIdx.x * blockDim.x + threadIdx.x;"));
        self.kernels.line(&format!("if ({var} >= V) return;"));
        self.kernels.open(&format!("if (gpu_level[{var}] == *d_hops_from_source) {{"));
        // wavefront expansion
        self.kernels.open(&format!("for (int i = gpu_OA[{var}]; i < gpu_OA[{var}+1]; ++i) {{"));
        self.kernels.line("int nbr = gpu_edgeList[i];");
        self.kernels.open("if (gpu_level[nbr] == -1) {");
        self.kernels.line("gpu_level[nbr] = *d_hops_from_source + 1;");
        self.kernels.line("*d_finished = false;");
        self.kernels.close("}");
        self.kernels.close("}");
        render_kernel_ops(&CudaKernel, plan, &fbody.ops, &mut self.kernels);
        self.kernels.close("}");
        self.kernels.close("}");
        self.kernels.line("");
        // host loop (Fig 9)
        self.host.line("// iterateInBFS: level-synchronous host loop (Fig 9)");
        if b.level.is_none() {
            // implicit level buffer (e.g. BC): allocated by the skeleton
            self.host.line("int* gpu_level;");
            self.host.line(&format!("{}(&gpu_level, sizeof(int) * V);", self.sp.malloc));
        }
        self.host.line("int* d_hops_from_source;");
        self.host.line(&format!("{}(&d_hops_from_source, sizeof(int) * 1);", self.sp.malloc));
        self.host.line("bool* d_finished;");
        self.host.line(&format!("{}(&d_finished, sizeof(bool) * 1);", self.sp.malloc));
        self.launch_line(
            &format!("initKernel<{lt}>"),
            "numBlocks",
            "threadsPerBlock",
            "V, gpu_level, -1",
        );
        self.launch_line(&format!("initIndex<{lt}>"), "1", "1", &format!("V, gpu_level, {from}, 0"));
        self.host.line("int hops_from_source = 0;");
        self.host.line(&format!(
            "{}(d_hops_from_source, &hops_from_source, sizeof(int), {});",
            self.sp.memcpy, self.sp.h2d
        ));
        self.host.line("bool finished;");
        self.host.open("do {");
        self.host.line("finished = true;");
        self.host.line(&format!(
            "{}(d_finished, &finished, sizeof(bool), {});",
            self.sp.memcpy, self.sp.h2d
        ));
        let name = fwd.name.clone();
        self.launch_line(&name, "numBlocks", "threadsPerBlock", &args.join(", "));
        self.host.line(self.sp.sync);
        self.host.line("++hops_from_source;");
        self.host.line(&format!(
            "{}(d_hops_from_source, &hops_from_source, sizeof(int), {});",
            self.sp.memcpy, self.sp.h2d
        ));
        self.host.line(&format!(
            "{}(&finished, d_finished, sizeof(bool), {});",
            self.sp.memcpy, self.sp.d2h
        ));
        self.host.close("} while (!finished);");
        // reverse pass
        if let Some(ri) = b.rev {
            let rk = &plan.kernels[ri];
            let rbody = rk.body.as_ref().expect("BFS reverse sweep carries a lowered body");
            let mut rsig: Vec<String> = Vec::new();
            let mut rargs: Vec<String> = Vec::new();
            for p in rk.bfs_params(b.level) {
                rsig.push(self.param_decl(&p));
                rargs.push(self.plan.launch_arg(&p));
            }
            for (decl, arg) in [
                (format!("{lt}* gpu_level"), "gpu_level"),
                ("int* d_hops_from_source".to_string(), "d_hops_from_source"),
            ] {
                rsig.push(decl);
                rargs.push(arg.to_string());
            }
            self.kernels.open(&format!("__global__ void {}({}) {{", rk.name, rsig.join(", ")));
            self.kernels.line(&format!("unsigned {var} = blockIdx.x * blockDim.x + threadIdx.x;"));
            self.kernels.line(&format!("if ({var} >= V) return;"));
            self.kernels.line(&format!("if (gpu_level[{var}] != *d_hops_from_source) return;"));
            if let Some(g) = &rbody.guard {
                self.kernels.line(&format!("if (!({})) return;", emit(g, &cuda_style())));
            }
            render_kernel_ops(&CudaKernel, plan, &rbody.ops, &mut self.kernels);
            self.kernels.close("}");
            self.kernels.line("");
            self.host.line("// iterateInReverse: walk the BFS levels backwards");
            self.host.open("while (hops_from_source >= 0) {");
            self.host.line(&format!(
                "{}(d_hops_from_source, &hops_from_source, sizeof(int), {});",
                self.sp.memcpy, self.sp.h2d
            ));
            let rname = rk.name.clone();
            self.launch_line(&rname, "numBlocks", "threadsPerBlock", &rargs.join(", "));
            self.host.line(self.sp.sync);
            self.host.line("--hops_from_source;");
            self.host.close("}");
        }
        // skeleton-owned buffers are allocated at the BFS site (which may sit
        // inside a host loop, e.g. BC's per-source sweep), so free them here
        self.host.line(&format!("{}(d_hops_from_source);", self.sp.free));
        self.host.line(&format!("{}(d_finished);", self.sp.free));
        if b.level.is_none() {
            self.host.line(&format!("{}(gpu_level);", self.sp.free));
        }
    }

    fn fixed_point_enter(&mut self, index: usize, var: &str) -> String {
        // Fig 12's host loop, skeleton from the plan
        let flag = self.plan.fixed_points[index].flag_name.clone();
        self.host.line(&format!("// fixedPoint on `{flag}` via a single device flag (§4.1)"));
        self.host.line(&format!("bool {var} = false;"));
        self.host.open(&format!("while (!{var}) {{"));
        self.host.line(&format!("{var} = true;"));
        self.host.line(&format!(
            "{}(gpu_finished, &{var}, sizeof(bool) * 1, {});",
            self.sp.memcpy, self.sp.h2d
        ));
        flag
    }

    fn fixed_point_exit(&mut self, var: &str) {
        self.host.line(&format!(
            "{}(&{var}, gpu_finished, sizeof(bool) * 1, {});",
            self.sp.memcpy, self.sp.d2h
        ));
        self.host.close("}");
    }

    fn epilogue_begin(&mut self) {
        self.host.line("");
        self.host.line("// §4.1: only updated vertex attributes return to the host");
    }

    fn copy_out(&mut self, slot: u32) {
        let m = self.plan.meta(slot);
        let ty = TYPES.name(m.ty);
        let len = m.len_sym();
        self.host.line(&format!(
            "{}({n}, gpu_{n}, sizeof({ty}) * {len}, {});",
            self.sp.memcpy,
            self.sp.d2h,
            n = m.name
        ));
    }

    fn free_prop(&mut self, slot: u32) {
        self.host.line(&format!("{}(gpu_{});", self.sp.free, self.plan.prop_name(slot)));
    }

    fn free_flag(&mut self) {
        self.host.line(&format!("{}(gpu_finished);", self.sp.free));
    }

    fn free_graph(&mut self) {
        for &arr in &self.plan.graph_arrays {
            self.host.line(&format!("{}({});", self.sp.free, arr.device_name()));
        }
    }
}
