//! WebGPU/WGSL backend — the seventh text renderer and the proof that the
//! [`crate::ir::kernel::KernelOp`] lowering is genuinely backend-neutral:
//! WGSL is *not* a C dialect. There are no pointers into raw arrays, buffers
//! are module-scope `var<storage>` bindings addressed by `@group/@binding`
//! indices, declarations spell `var x : i32 = e;`, scalars arrive through a
//! uniform struct instead of by-value parameters, and atomics are
//! `atomic<i32>` element types — a buffer's declaration changes when any
//! kernel updates it atomically ([`KernelPlan::atomic_props`]). None of that
//! fits a walker whose dialect hooks assume `type name = init;` and
//! `&array[i]` spellings, which is exactly why the old per-`Target` match in
//! `codegen/body.rs` could never have produced this file.
//!
//! Layout mirrors the OpenCL split: one WGSL *module per kernel* (WebGPU
//! binds a pipeline per entry point; per-module bindings let each kernel's
//! `@binding` indices follow the plan's canonical parameter order — binding
//! 0 is the uniform params struct, then graph CSR arrays, property buffers
//! in slot order, reduction cells, and the fixedPoint OR-flag word), then a
//! host section written against the Dawn/webgpu_cpp API (`wgpu::Device`,
//! `queue.WriteBuffer`, compute-pass dispatches; `makeStorageBuffer` /
//! `readBuffer` / `fillBuffer` / `pipelineFor` / `bindGroupFor` helpers live
//! in `libstarplat_webgpu.h` — WebGPU readbacks are MapAsync ceremonies the
//! generated skeleton should not repeat at every site).
//!
//! Spelling notes (WGSL):
//! - 32-bit scalars only; `long` and `double` demote ([`TypeMap::WGSL`]),
//!   and `bool` buffers are `i32` words (bool is not host-shareable);
//! - `INF` is the literal `2147483647`;
//! - WGSL has i32/u32 atomics only (the §3.3 OpenCL float-atomics story
//!   again), so atomically-updated f32 buffers are `array<atomic<u32>>`
//!   *bit patterns*: emitted `atomicAddF32` / `atomicMinF32` /
//!   `atomicMaxF32` helpers run `bitcast` compare-exchange loops, plain
//!   reads spell `bitcast<f32>(atomicLoad(&…))`, and plain stores
//!   `atomicStore(&…, bitcast<u32>(…))`. Host-side transfers are unchanged
//!   — the bit pattern is byte-identical to the float array.

use super::body::{render_kernel_ops, KernelDialect};
use super::buf::CodeBuf;
use super::cexpr::{emit, wgsl_style, Style};
use super::{render_host_schedule, HostDialect};
use crate::dsl::ast::{Expr, MinMax, ReduceOp};
use crate::ir::kernel::KernelOp;
use crate::ir::plan::{DevicePlan, KernelParam, KernelPlan, TypeMap};
use crate::ir::{IrProgram, ScalarTy};
use std::collections::HashSet;

/// Host-side C++ sizes (bool props are `int` words on the device).
const HOST: &TypeMap = &TypeMap::OPENCL;
/// Device-side WGSL types.
const DEV: &TypeMap = &TypeMap::WGSL;

/// Is this type's buffer representable as `atomic<i32>`? (f32 buffers that
/// need atomics are `atomic<u32>` bit patterns instead — WGSL has i32/u32
/// atomics only.)
fn i32_atomic(ty: ScalarTy) -> bool {
    !matches!(ty, ScalarTy::F32 | ScalarTy::F64)
}

/// WGSL device dialect. `atomic` holds the i32-representable props this
/// kernel updates atomically — their buffers are `array<atomic<i32>>`, so
/// plain reads wrap in `atomicLoad` and plain stores in `atomicStore`.
/// `atomic_f32` holds the float props updated atomically: their buffers are
/// `array<atomic<u32>>` *bit patterns* (the real §3.3 story — WGSL has no
/// float atomics), so reads bitcast the loaded word, stores bitcast the
/// value, and the update helpers run bitcast-CAS loops.
struct WgslKernel {
    atomic: HashSet<String>,
    atomic_f32: HashSet<String>,
}

impl WgslKernel {
    fn for_kernel(plan: &DevicePlan, k: &KernelPlan) -> WgslKernel {
        let (ints, floats): (Vec<u32>, Vec<u32>) =
            k.atomic_props.iter().partition(|&&s| i32_atomic(plan.meta(s).ty));
        WgslKernel {
            atomic: ints.iter().map(|&s| plan.prop_name(s).to_string()).collect(),
            atomic_f32: floats.iter().map(|&s| plan.prop_name(s).to_string()).collect(),
        }
    }
}

impl KernelDialect for WgslKernel {
    fn types(&self) -> &'static TypeMap {
        DEV
    }

    fn style(&self) -> Style {
        wgsl_style(self.atomic.clone(), self.atomic_f32.clone())
    }

    fn decl(&self, buf: &mut CodeBuf, ty: ScalarTy, name: &str, init: Option<&str>) {
        let t = self.types().name(ty);
        match init {
            Some(e) => buf.line(&format!("var {name} : {t} = {e};")),
            None => buf.line(&format!("var {name} : {t};")),
        }
    }

    fn store(
        &self,
        buf: &mut CodeBuf,
        loc: &str,
        value: &str,
        atomic: bool,
        ty: Option<ScalarTy>,
    ) {
        // an atomic f32 target is an atomic<u32> bit-pattern cell: store the
        // value's bit pattern, not the float (type-driven, from the plan)
        if atomic && matches!(ty, Some(ScalarTy::F32 | ScalarTy::F64)) {
            buf.line(&format!("atomicStore(&{loc}, bitcast<u32>({value}));"));
        } else if atomic {
            buf.line(&format!("atomicStore(&{loc}, {value});"));
        } else {
            buf.line(&format!("{loc} = {value};"));
        }
    }

    fn reduce(&self, buf: &mut CodeBuf, loc: &str, op: ReduceOp, ty: ScalarTy, val: &str) {
        match (op, ty) {
            (ReduceOp::Add | ReduceOp::Count, ScalarTy::F32 | ScalarTy::F64) => {
                // WGSL atomics are i32/u32 only (§3.3's float story again)
                buf.line(&format!("atomicAddF32(&{loc}, {val});"));
            }
            (ReduceOp::Add | ReduceOp::Count, _) => {
                buf.line(&format!("atomicAdd(&{loc}, {val});"))
            }
            (ReduceOp::Mul, ScalarTy::F32 | ScalarTy::F64) => {
                // f32 products CAS on the bit-pattern cell, like the adds
                buf.line(&format!("atomicMulF32(&{loc}, {val});"));
            }
            (ReduceOp::Mul, _) => buf.line(&format!("atomicMulCAS(&{loc}, {val});")),
            (ReduceOp::And, _) => buf.line(&format!("atomicAnd(&{loc}, {val});")),
            (ReduceOp::Or, _) => buf.line(&format!("atomicOr(&{loc}, {val});")),
        }
    }

    fn min_max_update(&self, buf: &mut CodeBuf, kind: MinMax, loc: &str, tmp: &str, ty: ScalarTy) {
        let sym = if kind == MinMax::Min { "Min" } else { "Max" };
        if i32_atomic(ty) {
            buf.line(&format!("atomic{sym}(&{loc}, {tmp});"));
        } else {
            buf.line(&format!("atomic{sym}F32(&{loc}, {tmp});"));
        }
    }

    fn set_or_flag(&self, buf: &mut CodeBuf) {
        buf.line("atomicStore(&gpu_finished[0], 0);");
    }

    fn neighbor_loop_open(&self, buf: &mut CodeBuf, var: &str, of: &str, reverse: bool) {
        let st = self.style();
        let (off, list) =
            if reverse { (st.rev_offsets, st.src_list) } else { (st.offsets, st.edge_list) };
        buf.open(&format!(
            "for (var edge : i32 = {off}[{of}]; edge < {off}[{of} + 1]; edge++) {{"
        ));
        buf.line(&format!("let {var} = {list}[edge];"));
    }
}

/// Shader helpers one kernel's ops require.
#[derive(Default)]
struct Needs {
    f32_atomics: bool,
    f32_min: bool,
    f32_max: bool,
    f32_mul: bool,
    mul_cas: bool,
    edge_lookup: bool,
}

fn scan_expr(e: &Expr, needs: &mut Needs) {
    match e {
        Expr::Call { name, args, .. } => {
            if name == "is_an_edge" {
                needs.edge_lookup = true;
            }
            for a in args {
                scan_expr(a, needs);
            }
        }
        Expr::Unary { expr, .. } => scan_expr(expr, needs),
        Expr::Binary { lhs, rhs, .. } => {
            scan_expr(lhs, needs);
            scan_expr(rhs, needs);
        }
        _ => {}
    }
}

fn scan_ops(ops: &[KernelOp], needs: &mut Needs) {
    for op in ops {
        op.visit(&mut |o| match o {
            KernelOp::Decl { init, .. } => {
                if let Some(e) = init {
                    scan_expr(e, needs);
                }
            }
            KernelOp::AssignVar { value, .. } | KernelOp::AssignProp { value, .. } => {
                scan_expr(value, needs)
            }
            KernelOp::Reduce { op, ty, value, .. } => {
                match (op, ty) {
                    (ReduceOp::Add | ReduceOp::Count, ScalarTy::F32 | ScalarTy::F64) => {
                        needs.f32_atomics = true
                    }
                    (ReduceOp::Mul, ScalarTy::F32 | ScalarTy::F64) => needs.f32_mul = true,
                    (ReduceOp::Mul, _) => needs.mul_cas = true,
                    _ => {}
                }
                scan_expr(value, needs);
            }
            KernelOp::MinMax { kind, ty, compare, extra, .. } => {
                if !i32_atomic(*ty) {
                    match kind {
                        MinMax::Min => needs.f32_min = true,
                        MinMax::Max => needs.f32_max = true,
                    }
                }
                scan_expr(compare, needs);
                for (_, v) in extra {
                    scan_expr(v, needs);
                }
            }
            KernelOp::NeighborLoop { filter, .. } => {
                if let Some(f) = filter {
                    scan_expr(f, needs);
                }
            }
            KernelOp::If { cond, .. } => scan_expr(cond, needs),
            _ => {}
        });
    }
}

pub fn generate(ir: &IrProgram) -> Result<String, crate::dsl::diag::DslError> {
    Ok(generate_with(ir, &DevicePlan::build(ir)?))
}

/// Render with a pre-built plan ([`super::generate`] lowers once for all
/// backends).
pub(crate) fn generate_with(_ir: &IrProgram, plan: &DevicePlan) -> String {
    let mut g = Gen { plan, shaders: CodeBuf::new(), host: CodeBuf::new(), dispatch_id: 0 };
    g.run()
}

/// The uniform-struct fields and storage bindings of one WGSL module, in
/// binding order (binding 0 is the uniform).
struct Layout {
    /// (field name, host C type) pairs for the params struct
    uniform: Vec<(String, &'static str)>,
    /// (buffer name, element type, read-only) per storage binding
    storage: Vec<(String, String, bool)>,
}

struct Gen<'a> {
    plan: &'a DevicePlan,
    shaders: CodeBuf,
    host: CodeBuf,
    /// monotonic dispatch-site counter: uniform-staging locals get unique
    /// names so repeated launch sites never shadow one another
    dispatch_id: usize,
}

impl<'a> Gen<'a> {
    fn run(&mut self) -> String {
        let plan = self.plan;
        self.shaders.line("// ---- shaders.wgsl (one module per kernel/pipeline) ----");
        self.shaders.line("");
        self.host.line("// ---- host.cpp (Dawn / webgpu_cpp.h) ----");
        self.host.line("#include <webgpu/webgpu_cpp.h>");
        self.host.line("#include <climits>");
        self.host.line("#include <cstdlib>");
        self.host.line("#include <cstring>");
        self.host.line("#include \"libstarplat_webgpu.h\"");
        self.host.line("");
        let params = plan.host_signature(&TypeMap::C);
        self.host.open(&format!("void {}({}) {{", plan.func, params.join(", ")));
        render_host_schedule(self, &plan.host_ops, None);
        self.host.close("}");

        let mut out = super::manifest_header("WGSL", plan);
        out.push('\n');
        out.push_str(&std::mem::take(&mut self.shaders).finish());
        out.push_str(&std::mem::take(&mut self.host).finish());
        out
    }

    /// Map the plan's canonical parameter list onto a WGSL module layout:
    /// `V` and by-value scalars fold into the binding-0 uniform; everything
    /// else is a storage buffer in canonical order.
    fn layout(&self, params: &[KernelParam], atomic: &[u32]) -> Layout {
        let mut uniform = vec![("V".to_string(), HOST.name(ScalarTy::I32))];
        let mut storage = Vec::new();
        for p in params {
            match p {
                KernelParam::NumNodes => {}
                KernelParam::Scalar { name, ty } => uniform.push((name.clone(), HOST.name(*ty))),
                KernelParam::Graph(a) => {
                    storage.push((a.device_name().to_string(), "i32".to_string(), true))
                }
                KernelParam::Prop(s) => {
                    let m = self.plan.meta(*s);
                    let elem = if atomic.contains(s) {
                        // f32 atomics don't exist in WGSL: atomically-updated
                        // float buffers hold u32 bit patterns (same bytes on
                        // the host side, so transfers are unchanged)
                        if i32_atomic(m.ty) { "atomic<i32>" } else { "atomic<u32>" }.to_string()
                    } else {
                        DEV.name(m.ty).to_string()
                    };
                    storage.push((format!("gpu_{}", m.name), elem, false));
                }
                KernelParam::ReductionCell { name, ty } => {
                    let elem = if i32_atomic(*ty) { "atomic<i32>" } else { "atomic<u32>" };
                    storage.push((format!("d_{name}"), elem.to_string(), false));
                }
                KernelParam::OrFlag => {
                    storage.push(("gpu_finished".to_string(), "atomic<i32>".to_string(), false))
                }
            }
        }
        Layout { uniform, storage }
    }

    /// Emit one complete WGSL module: params struct, bindings, helpers, and
    /// the `@compute` entry point around `body_lines`.
    #[allow(clippy::too_many_arguments)]
    fn shader_module(
        &mut self,
        name: &str,
        layout: &Layout,
        needs: &Needs,
        thread_var: &str,
        guard: Option<&str>,
        prelude: impl FnOnce(&mut CodeBuf),
    ) {
        let b = &mut self.shaders;
        b.line(&format!("// shader module: {name}"));
        b.open("struct Params {");
        for (f, cty) in &layout.uniform {
            let wty = match *cty {
                "float" | "double" => "f32",
                _ => "i32",
            };
            b.line(&format!("{f} : {wty},"));
        }
        b.close("}");
        b.line("@group(0) @binding(0) var<uniform> params : Params;");
        for (i, (bname, elem, ro)) in layout.storage.iter().enumerate() {
            let access = if *ro { "read" } else { "read_write" };
            b.line(&format!(
                "@group(0) @binding({}) var<storage, {access}> {bname} : array<{elem}>;",
                i + 1
            ));
        }
        b.line("");
        if needs.edge_lookup {
            b.open("fn findNeighborSorted(u : i32, w : i32) -> bool {");
            b.line("var lo = gpu_OA[u];");
            b.line("var hi = gpu_OA[u + 1] - 1;");
            b.open("while (lo <= hi) {");
            b.line("let mid = (lo + hi) / 2;");
            b.line("if (gpu_edgeList[mid] == w) { return true; }");
            b.line("if (gpu_edgeList[mid] < w) { lo = mid + 1; } else { hi = mid - 1; }");
            b.close("}");
            b.line("return false;");
            b.close("}");
            b.line("");
        }
        if needs.f32_atomics || needs.f32_min || needs.f32_max || needs.f32_mul {
            b.line("// WGSL atomics are i32/u32-only: f32 cells are atomic<u32> bit");
            b.line("// patterns updated through bitcast compare-exchange loops (§3.3)");
        }
        if needs.f32_atomics {
            b.open("fn atomicAddF32(cell : ptr<storage, atomic<u32>, read_write>, value : f32) {");
            b.open("loop {");
            b.line("let old = atomicLoad(cell);");
            b.line("let updated = bitcast<u32>(bitcast<f32>(old) + value);");
            b.line("if (atomicCompareExchangeWeak(cell, old, updated).exchanged) { break; }");
            b.close("}");
            b.close("}");
            b.line("");
        }
        if needs.f32_min {
            b.open("fn atomicMinF32(cell : ptr<storage, atomic<u32>, read_write>, value : f32) {");
            b.open("loop {");
            b.line("let old = atomicLoad(cell);");
            b.line("if (bitcast<f32>(old) <= value) { break; }");
            b.line(
                "if (atomicCompareExchangeWeak(cell, old, bitcast<u32>(value)).exchanged) { break; }",
            );
            b.close("}");
            b.close("}");
            b.line("");
        }
        if needs.f32_max {
            b.open("fn atomicMaxF32(cell : ptr<storage, atomic<u32>, read_write>, value : f32) {");
            b.open("loop {");
            b.line("let old = atomicLoad(cell);");
            b.line("if (bitcast<f32>(old) >= value) { break; }");
            b.line(
                "if (atomicCompareExchangeWeak(cell, old, bitcast<u32>(value)).exchanged) { break; }",
            );
            b.close("}");
            b.close("}");
            b.line("");
        }
        if needs.f32_mul {
            b.open("fn atomicMulF32(cell : ptr<storage, atomic<u32>, read_write>, value : f32) {");
            b.open("loop {");
            b.line("let old = atomicLoad(cell);");
            b.line("let updated = bitcast<u32>(bitcast<f32>(old) * value);");
            b.line("if (atomicCompareExchangeWeak(cell, old, updated).exchanged) { break; }");
            b.close("}");
            b.close("}");
            b.line("");
        }
        if needs.mul_cas {
            b.open("fn atomicMulCAS(cell : ptr<storage, atomic<i32>, read_write>, value : i32) {");
            b.open("loop {");
            b.line("let old = atomicLoad(cell);");
            b.line(
                "if (atomicCompareExchangeWeak(cell, old, old * value).exchanged) { break; }",
            );
            b.close("}");
            b.close("}");
            b.line("");
        }
        b.line("@compute @workgroup_size(256)");
        b.open(&format!("fn {name}(@builtin(global_invocation_id) gid : vec3<u32>) {{"));
        b.line(&format!("let {thread_var} = i32(gid.x);"));
        for (f, _) in &layout.uniform {
            b.line(&format!("let {f} = params.{f};"));
        }
        b.line(&format!("if ({thread_var} >= V) {{ return; }}"));
        if let Some(g) = guard {
            // bool() absorbs both bool comparisons and i32 flag words
            b.line(&format!("if (!bool({g})) {{ return; }}"));
        }
        prelude(b);
        b.close("}");
        b.line("");
    }

    /// Host-side dispatch of one pipeline: build the uniform, the bind
    /// group (binding order = layout order), one compute pass. Scoped so
    /// loop-body launch sites don't redeclare locals.
    fn dispatch(&mut self, name: &str, layout: &Layout) {
        let id = self.dispatch_id;
        self.dispatch_id += 1;
        self.host.open("{");
        let fields: Vec<String> =
            layout.uniform.iter().map(|(f, cty)| format!("{cty} {f};")).collect();
        let inits: Vec<String> = layout.uniform.iter().map(|(f, _)| f.clone()).collect();
        self.host.line(&format!(
            "struct {{ {} }} params_{id} = {{ {} }};",
            fields.join(" "),
            inits.join(", ")
        ));
        self.host.line(&format!(
            "wgpu::Buffer params_buf_{id} = makeUniformBuffer(device, &params_{id}, sizeof(params_{id}));"
        ));
        let mut group = vec![format!("params_buf_{id}")];
        group.extend(layout.storage.iter().map(|(n, _, _)| n.clone()));
        self.host.line("wgpu::CommandEncoder enc = device.CreateCommandEncoder();");
        self.host.line("wgpu::ComputePassEncoder pass = enc.BeginComputePass();");
        self.host.line(&format!("pass.SetPipeline(pipelineFor(device, \"{name}\"));"));
        self.host.line(&format!(
            "pass.SetBindGroup(0, bindGroupFor(device, \"{name}\", {{ {} }}));",
            group.join(", ")
        ));
        self.host.line("pass.DispatchWorkgroups(numWorkgroups, 1, 1);");
        self.host.line("pass.End();");
        self.host.line("wgpu::CommandBuffer cb = enc.Finish();");
        self.host.line("queue.Submit(1, &cb);");
        self.host.line(&format!("params_buf_{id}.Destroy();"));
        self.host.close("}");
    }
}

impl<'a> HostDialect for Gen<'a> {
    fn expr_style(&self) -> Style {
        // host code is C++ against Dawn: C literals, CUDA-style buffer names
        super::cexpr::cuda_style()
    }

    fn buf(&mut self) -> &mut CodeBuf {
        &mut self.host
    }

    fn decl_dims(&mut self) {
        self.host.line("wgpu::Device device = requestDevice();");
        self.host.line("wgpu::Queue queue = device.GetQueue();");
        self.host.line("int V = g.num_nodes();");
        self.host.line("int E = g.num_edges();");
        self.host.line("");
    }

    fn graph_to_device(&mut self) {
        self.host.line("// §4.1: the static graph is copied to the device once, never back");
        for &arr in &self.plan.graph_arrays {
            let (dev, host, len) = (arr.device_name(), arr.host_name(), arr.len_sym());
            self.host.line(&format!(
                "wgpu::Buffer {dev} = makeStorageBuffer(device, sizeof(int) * {len});"
            ));
            self.host
                .line(&format!("queue.WriteBuffer({dev}, 0, {host}, sizeof(int) * {len});"));
        }
    }

    fn alloc_prop(&mut self, slot: u32) {
        let m = self.plan.meta(slot);
        let ty = HOST.name(m.ty);
        let len = m.len_sym();
        self.host.line(&format!(
            "wgpu::Buffer gpu_{} = makeStorageBuffer(device, sizeof({ty}) * {len});",
            m.name
        ));
    }

    fn alloc_flag(&mut self) {
        self.host
            .line("wgpu::Buffer gpu_finished = makeStorageBuffer(device, sizeof(int));");
    }

    fn launch_setup(&mut self) {
        self.host.line("");
        self.host.line("unsigned workgroupSize = 256;");
        self.host.line("unsigned numWorkgroups = (V + workgroupSize - 1) / workgroupSize;");
        self.host.line("");
    }

    fn copy_prop(&mut self, dst: u32, src: u32) {
        let ty = HOST.name(self.plan.meta(dst).ty);
        self.host.open("{");
        self.host.line("wgpu::CommandEncoder enc = device.CreateCommandEncoder();");
        self.host.line(&format!(
            "enc.CopyBufferToBuffer(gpu_{}, 0, gpu_{}, 0, sizeof({ty}) * V);",
            self.plan.prop_name(src),
            self.plan.prop_name(dst)
        ));
        self.host.line("wgpu::CommandBuffer cb = enc.Finish();");
        self.host.line("queue.Submit(1, &cb);");
        self.host.close("}");
    }

    fn set_element(&mut self, slot: u32, index: &str, value: &Expr) {
        let m = self.plan.meta(slot);
        let ty = HOST.name(m.ty);
        let val = emit(value, &self.expr_style());
        self.host.open("{");
        self.host.line(&format!("{ty} element = ({ty}){val};"));
        self.host.line(&format!(
            "queue.WriteBuffer(gpu_{}, {index} * sizeof({ty}), &element, sizeof({ty}));",
            m.name
        ));
        self.host.close("}");
    }

    fn init_props(&mut self, _kernel: usize, inits: &[(u32, Expr)]) {
        for (slot, e) in inits {
            let m = self.plan.meta(*slot);
            let ty = HOST.name(m.ty);
            let v = emit(e, &self.expr_style());
            self.host.line(&format!(
                "fillBuffer(device, queue, gpu_{}, V, ({ty}){v});",
                m.name
            ));
        }
    }

    fn launch(&mut self, kernel: usize, or_flag: Option<&str>) {
        let plan = self.plan;
        let k: &KernelPlan = &plan.kernels[kernel];
        let body = k.body.as_ref().expect("forall kernel carries a lowered body");
        let params = k.params(or_flag.is_some());
        let layout = self.layout(&params, &k.atomic_props);
        let dialect = WgslKernel::for_kernel(plan, k);
        let mut needs = Needs::default();
        scan_ops(&body.ops, &mut needs);
        if let Some(g) = &body.guard {
            scan_expr(g, &mut needs);
        }
        let guard = body.guard.as_ref().map(|g| emit(g, &dialect.style()));
        // shader module
        let name = k.name.clone();
        let tv = body.thread_var.clone();
        let ops = &body.ops;
        self.shader_module(&name, &layout, &needs, &tv, guard.as_deref(), |buf| {
            render_kernel_ops(&dialect, plan, ops, buf)
        });
        // schedule plan: a derived pull twin re-orients the relaxation onto
        // the reverse CSR; the host picks a direction at runtime
        if let Some(pull) = &k.pull_body {
            let mut pneeds = Needs::default();
            scan_ops(&pull.ops, &mut pneeds);
            let pops = &pull.ops;
            self.shader_module(
                &format!("{name}_pull"),
                &layout,
                &pneeds,
                &pull.thread_var,
                None,
                |buf| render_kernel_ops(&dialect, plan, pops, buf),
            );
        }
        // ---- launch site ----
        for &c in &k.copy_in {
            let m = self.plan.meta(c);
            let ty = HOST.name(m.ty);
            let len = m.len_sym();
            self.host.line(&format!(
                "// copy-in (§4.1 analysis): {} is read before first device write",
                m.name
            ));
            self.host
                .line(&format!("queue.WriteBuffer(gpu_{n}, 0, {n}, sizeof({ty}) * {len});", n = m.name));
        }
        for (r, _, ty) in &k.reductions {
            let t = HOST.name(*ty);
            self.host.line(&format!("// device reduction cell for `{r}` (§3.3)"));
            self.host
                .line(&format!("wgpu::Buffer d_{r} = makeStorageBuffer(device, sizeof({t}));"));
            self.host.line(&format!("queue.WriteBuffer(d_{r}, 0, &{r}, sizeof({t}));"));
        }
        if k.pull_body.is_some() {
            self.host
                .line("// schedule plan: STARPLAT_DIRECTION=pull selects the reverse-CSR variant");
            self.host.line(&format!(
                "bool usePull_{} = getenv(\"STARPLAT_DIRECTION\") != NULL && \
                 strcmp(getenv(\"STARPLAT_DIRECTION\"), \"pull\") == 0;",
                k.id
            ));
            self.host.open(&format!("if (usePull_{}) {{", k.id));
            self.dispatch(&format!("{name}_pull"), &layout);
            self.host.close("} else {");
            self.host.inc();
            self.dispatch(&name, &layout);
            self.host.close("}");
        } else {
            self.dispatch(&name, &layout);
        }
        for (r, _, ty) in &k.reductions {
            let t = HOST.name(*ty);
            self.host.line(&format!("readBuffer(device, queue, d_{r}, &{r}, sizeof({t}));"));
            self.host.line(&format!("d_{r}.Destroy();"));
        }
        if !k.defer_to_loop_exit {
            for &c in &k.copy_out {
                let m = self.plan.meta(c);
                let ty = HOST.name(m.ty);
                let len = m.len_sym();
                self.host.line(&format!(
                    "readBuffer(device, queue, gpu_{n}, {n}, sizeof({ty}) * {len});",
                    n = m.name
                ));
            }
        }
    }

    fn bfs(&mut self, index: usize, var: &str, from: &str) {
        let plan = self.plan;
        let b = &plan.bfs_loops[index];
        let fwd = &plan.kernels[b.fwd];
        let fbody = fwd.body.as_ref().expect("BFS forward sweep carries a lowered body");
        let lt = b.level.map(|s| self.plan.c_ty(s, DEV)).unwrap_or("i32");
        let params = fwd.bfs_params(b.level);
        let mut layout = self.layout(&params, &fwd.atomic_props);
        layout.uniform.push(("hops_from_source".to_string(), "int"));
        layout.storage.push(("gpu_level".to_string(), lt.to_string(), false));
        layout.storage.push(("d_finished".to_string(), "i32".to_string(), false));
        let dialect = WgslKernel::for_kernel(plan, fwd);
        let mut needs = Needs::default();
        scan_ops(&fbody.ops, &mut needs);
        let fname = fwd.name.clone();
        let ops = &fbody.ops;
        self.shader_module(&fname, &layout, &needs, var, None, |buf| {
            buf.open(&format!("if (gpu_level[{var}] == hops_from_source) {{"));
            buf.open(&format!(
                "for (var i : i32 = gpu_OA[{var}]; i < gpu_OA[{var} + 1]; i++) {{"
            ));
            buf.line("let nbr = gpu_edgeList[i];");
            buf.open("if (gpu_level[nbr] == -1) {");
            buf.line("gpu_level[nbr] = hops_from_source + 1;");
            buf.line("d_finished[0] = 0;");
            buf.close("}");
            buf.close("}");
            render_kernel_ops(&dialect, plan, ops, buf);
            buf.close("}");
        });
        // host loop (Fig 9)
        self.host.line("// iterateInBFS: level-synchronous host loop (Fig 9)");
        if b.level.is_none() {
            self.host
                .line("wgpu::Buffer gpu_level = makeStorageBuffer(device, sizeof(int) * V);");
        }
        self.host.line("wgpu::Buffer d_finished = makeStorageBuffer(device, sizeof(int));");
        self.host.line("fillBuffer(device, queue, gpu_level, V, -1);");
        self.host.open("{");
        self.host.line("int element = 0;");
        self.host.line(&format!(
            "queue.WriteBuffer(gpu_level, {from} * sizeof(int), &element, sizeof(int));"
        ));
        self.host.close("}");
        self.host.line("int hops_from_source = 0;");
        self.host.line("int finished_word;");
        self.host.open("do {");
        self.host.line("finished_word = 1;");
        self.host.line("queue.WriteBuffer(d_finished, 0, &finished_word, sizeof(int));");
        self.dispatch(&fname, &layout);
        self.host.line("++hops_from_source;");
        self.host.line("readBuffer(device, queue, d_finished, &finished_word, sizeof(int));");
        self.host.close("} while (!finished_word);");
        if let Some(ri) = b.rev {
            let rk = &plan.kernels[ri];
            let rbody = rk.body.as_ref().expect("BFS reverse sweep carries a lowered body");
            let rparams = rk.bfs_params(b.level);
            let mut rlayout = self.layout(&rparams, &rk.atomic_props);
            rlayout.uniform.push(("hops_from_source".to_string(), "int"));
            rlayout.storage.push(("gpu_level".to_string(), lt.to_string(), false));
            let rdialect = WgslKernel::for_kernel(plan, rk);
            let mut rneeds = Needs::default();
            scan_ops(&rbody.ops, &mut rneeds);
            if let Some(g) = &rbody.guard {
                scan_expr(g, &mut rneeds);
            }
            let rguard = rbody.guard.as_ref().map(|g| emit(g, &rdialect.style()));
            let rname = rk.name.clone();
            let rops = &rbody.ops;
            self.shader_module(&rname, &rlayout, &rneeds, var, None, |buf| {
                buf.line(&format!(
                    "if (gpu_level[{var}] != hops_from_source) {{ return; }}"
                ));
                if let Some(g) = &rguard {
                    buf.line(&format!("if (!bool({g})) {{ return; }}"));
                }
                render_kernel_ops(&rdialect, plan, rops, buf);
            });
            self.host.line("// iterateInReverse: walk the BFS levels backwards");
            self.host.open("while (--hops_from_source >= 0) {");
            self.dispatch(&rname, &rlayout);
            self.host.close("}");
        }
        // skeleton-owned buffers are created at the BFS site: destroy here
        self.host.line("d_finished.Destroy();");
        if b.level.is_none() {
            self.host.line("gpu_level.Destroy();");
        }
    }

    fn fixed_point_enter(&mut self, index: usize, var: &str) -> String {
        let flag = self.plan.fixed_points[index].flag_name.clone();
        self.host.line(&format!("// fixedPoint on `{flag}` via a single device flag word (§4.1)"));
        self.host.line(&format!("bool {var} = false;"));
        self.host.open(&format!("while (!{var}) {{"));
        self.host.line(&format!("{var} = true;"));
        self.host.line("int finished_word = 1;");
        self.host.line("queue.WriteBuffer(gpu_finished, 0, &finished_word, sizeof(int));");
        flag
    }

    fn fixed_point_exit(&mut self, var: &str) {
        self.host.line("readBuffer(device, queue, gpu_finished, &finished_word, sizeof(int));");
        self.host.line(&format!("{var} = finished_word != 0;"));
        self.host.close("}");
    }

    fn epilogue_begin(&mut self) {
        self.host.line("");
        self.host.line("// §4.1: only updated vertex attributes return to the host");
    }

    fn copy_out(&mut self, slot: u32) {
        let m = self.plan.meta(slot);
        let ty = HOST.name(m.ty);
        let len = m.len_sym();
        self.host.line(&format!(
            "readBuffer(device, queue, gpu_{n}, {n}, sizeof({ty}) * {len});",
            n = m.name
        ));
    }

    fn free_prop(&mut self, slot: u32) {
        self.host.line(&format!("gpu_{}.Destroy();", self.plan.prop_name(slot)));
    }

    fn free_flag(&mut self) {
        self.host.line("gpu_finished.Destroy();");
    }

    fn free_graph(&mut self) {
        for &arr in &self.plan.graph_arrays {
            self.host.line(&format!("{}.Destroy();", arr.device_name()));
        }
    }
}
