//! SYCL backend (paper §3, Figures 4, 8, 11; §4.3 optimizations).
//!
//! Kernels are `Q.submit` lambdas with the strided `parallel_for` idiom of
//! Fig 4 (`for (; v < V; v += NUM_THREADS)`); reductions and the Min/Max
//! construct use `atomic_ref` (Figs 8, 11). Graph data moves once via
//! `malloc_device` (§4.3), and the fixedPoint flag is a single device word.
//!
//! A thin renderer over [`DevicePlan`]: buffer set, property types, kernel
//! numbering, the entire host-statement schedule, and every kernel body come
//! from the plan — this module is the SYCL [`HostDialect`] + [`SyclKernel`]
//! dialect, driven by [`super::render_host_schedule`] and
//! `super::body::render_kernel_ops`. Lambdas capture buffers, so no
//! parameter lists are rendered here.

use super::body::{render_kernel_ops, KernelDialect};
use super::buf::CodeBuf;
use super::cexpr::{emit, sycl_style, Style};
use super::{render_host_schedule, HostDialect};
use crate::dsl::ast::{Expr, MinMax, ReduceOp};
use crate::ir::plan::{DevicePlan, GraphArray, TypeMap};
use crate::ir::{IrProgram, ScalarTy};

const TYPES: &TypeMap = &TypeMap::C;

/// SYCL device dialect: Fig 8 / Fig 11 `atomic_ref` idioms.
struct SyclKernel;

impl SyclKernel {
    fn atomic_ref_decl(buf: &mut CodeBuf, ty: ScalarTy, loc: &str) {
        buf.line(&format!(
            "atomic_ref<{t}, memory_order::relaxed, memory_scope::device, access::address_space::global_space> atomic_data({loc});",
            t = TYPES.name(ty)
        ));
    }
}

impl KernelDialect for SyclKernel {
    fn types(&self) -> &'static TypeMap {
        TYPES
    }

    fn style(&self) -> Style {
        sycl_style()
    }

    fn reduce(&self, buf: &mut CodeBuf, loc: &str, op: ReduceOp, ty: ScalarTy, val: &str) {
        // Fig 8's atomic_ref idiom
        Self::atomic_ref_decl(buf, ty, loc);
        match op {
            ReduceOp::Add | ReduceOp::Count => buf.line(&format!("atomic_data += {val};")),
            ReduceOp::Mul => {
                buf.line(&format!("atomic_data = atomic_data * {val}; // CAS loop"))
            }
            ReduceOp::And => buf.line(&format!("atomic_data &= {val};")),
            ReduceOp::Or => buf.line(&format!("atomic_data |= {val};")),
        }
    }

    fn min_max_update(&self, buf: &mut CodeBuf, kind: MinMax, loc: &str, tmp: &str, ty: ScalarTy) {
        Self::atomic_ref_decl(buf, ty, loc);
        buf.line(&format!(
            "atomic_data.fetch_{}({tmp});",
            if kind == MinMax::Min { "min" } else { "max" }
        ));
    }

    fn set_or_flag(&self, buf: &mut CodeBuf) {
        buf.line("*d_finished = false;");
    }
}

/// Device member for one CSR array (the SYCL graph wrapper owns them).
fn dev_arr(a: GraphArray) -> &'static str {
    match a {
        GraphArray::Offsets => "g.gpu_indexOfNodes",
        GraphArray::EdgeList => "g.gpu_edgeList",
        GraphArray::RevOffsets => "g.gpu_rev_indexOfNodes",
        GraphArray::SrcList => "g.gpu_srcList",
    }
}

pub fn generate(ir: &IrProgram) -> Result<String, crate::dsl::diag::DslError> {
    Ok(generate_with(ir, &DevicePlan::build(ir)?))
}

/// Render with a pre-built plan ([`super::generate`] lowers once for all
/// backends).
pub(crate) fn generate_with(_ir: &IrProgram, plan: &DevicePlan) -> String {
    let mut g = Gen { plan, buf: CodeBuf::new() };
    g.run()
}

struct Gen<'a> {
    plan: &'a DevicePlan,
    buf: CodeBuf,
}

impl<'a> Gen<'a> {
    fn run(&mut self) -> String {
        let plan = self.plan;
        let mut out = super::manifest_header("SYCL", plan);
        self.buf.line("#include <CL/sycl.hpp>");
        self.buf.line("#include <cstdlib>");
        self.buf.line("#include <cstring>");
        self.buf.line("#include \"libstarplat_sycl.h\"");
        self.buf.line("using namespace sycl;");
        self.buf.line("");
        let params = plan.host_signature(TYPES);
        self.buf.open(&format!("void {}({}) {{", plan.func, params.join(", ")));
        render_host_schedule(self, &plan.host_ops, None);
        self.buf.close("}");
        out.push_str(&std::mem::take(&mut self.buf).finish());
        out
    }

    /// Fig 4's submit + strided parallel_for wrapper.
    fn open_parallel(&mut self, var: &str) {
        self.buf.open("Q.submit([&](handler& h) {");
        self.buf.open(&format!("h.parallel_for(NUM_THREADS, [=](id<1> {var}) {{"));
        self.buf.open(&format!("for (; {var} < V; {var} += NUM_THREADS) {{"));
    }
    fn close_parallel(&mut self) {
        self.buf.close("}");
        self.buf.close("});");
        self.buf.close("}).wait();");
    }
}

impl<'a> HostDialect for Gen<'a> {
    fn expr_style(&self) -> Style {
        sycl_style()
    }

    fn buf(&mut self) -> &mut CodeBuf {
        &mut self.buf
    }

    fn decl_dims(&mut self) {
        self.buf.line("queue Q(default_selector_v);");
        self.buf.line("int V = g.num_nodes();");
        self.buf.line("int E = g.num_edges();");
        self.buf.line("");
    }

    fn graph_to_device(&mut self) {
        self.buf.line("// §4.3: graph transferred once with malloc_device, never copied back");
        for &arr in &self.plan.graph_arrays {
            let (dev, host, len) = (dev_arr(arr), arr.host_name(), arr.len_sym());
            self.buf.line(&format!("{dev} = malloc_device<int>({len}, Q);"));
            self.buf.line(&format!("Q.memcpy({dev}, {host}, sizeof(int) * {len}).wait();"));
        }
    }

    fn alloc_prop(&mut self, slot: u32) {
        let m = self.plan.meta(slot);
        let len = m.len_sym();
        let ty = TYPES.name(m.ty);
        self.buf.line(&format!("g.gpu_{} = malloc_device<{ty}>({len}, Q);", m.name));
    }

    fn alloc_flag(&mut self) {
        self.buf.line("bool* d_finished = malloc_device<bool>(1, Q);");
    }

    fn launch_setup(&mut self) {
        self.buf.line("");
    }

    fn copy_prop(&mut self, dst: u32, src: u32) {
        let ty = TYPES.name(self.plan.meta(dst).ty);
        self.buf.line(&format!(
            "Q.memcpy(g.gpu_{}, g.gpu_{}, sizeof({ty}) * V).wait();",
            self.plan.prop_name(dst),
            self.plan.prop_name(src)
        ));
    }

    fn set_element(&mut self, slot: u32, index: &str, value: &Expr) {
        self.buf.line(&format!(
            "setIndexDevice(Q, g.gpu_{}, {index}, {});",
            self.plan.prop_name(slot),
            emit(value, &sycl_style())
        ));
    }

    fn init_props(&mut self, _kernel: usize, inits: &[(u32, Expr)]) {
        self.open_parallel("v");
        for (slot, e) in inits {
            self.buf.line(&format!(
                "g.gpu_{}[v] = {};",
                self.plan.prop_name(*slot),
                emit(e, &sycl_style())
            ));
        }
        self.close_parallel();
    }

    fn launch(&mut self, kernel: usize, or_flag: Option<&str>) {
        let plan = self.plan;
        let k = &plan.kernels[kernel];
        let body = k.body.as_ref().expect("forall kernel carries a lowered body");
        let _ = or_flag; // lambdas capture d_finished; no parameter list
        for (r, _, _) in &k.reductions {
            self.buf.line(&format!("// device reduction cell for `{r}` (atomic_ref, Fig 8)"));
        }
        if let Some(pull) = &k.pull_body {
            // schedule plan: a derived pull twin re-orients the relaxation
            // onto the reverse CSR; the host picks a direction at runtime
            self.buf
                .line("// schedule plan: STARPLAT_DIRECTION=pull selects the reverse-CSR variant");
            self.buf.line(&format!(
                "bool usePull_{} = getenv(\"STARPLAT_DIRECTION\") != NULL && \
                 strcmp(getenv(\"STARPLAT_DIRECTION\"), \"pull\") == 0;",
                k.id
            ));
            self.buf.open(&format!("if (usePull_{}) {{", k.id));
            self.open_parallel(&pull.thread_var);
            render_kernel_ops(&SyclKernel, plan, &pull.ops, &mut self.buf);
            self.close_parallel();
            self.buf.close("} else {");
            self.buf.inc();
            self.open_parallel(&body.thread_var);
            if let Some(g) = &body.guard {
                self.buf.line(&format!("if (!({})) continue;", emit(g, &sycl_style())));
            }
            render_kernel_ops(&SyclKernel, plan, &body.ops, &mut self.buf);
            self.close_parallel();
            self.buf.close("}");
        } else {
            self.open_parallel(&body.thread_var);
            if let Some(g) = &body.guard {
                self.buf.line(&format!("if (!({})) continue;", emit(g, &sycl_style())));
            }
            render_kernel_ops(&SyclKernel, plan, &body.ops, &mut self.buf);
            self.close_parallel();
        }
    }

    fn bfs(&mut self, index: usize, var: &str, from: &str) {
        let plan = self.plan;
        let b = &plan.bfs_loops[index];
        let fbody =
            plan.kernels[b.fwd].body.as_ref().expect("BFS forward sweep carries a lowered body");
        self.buf.line("// iterateInBFS: host do-while, level kernel per hop (§3.4)");
        if b.level.is_none() {
            // implicit level buffer (e.g. BC): owned by the skeleton
            self.buf.line("g.gpu_level = malloc_device<int>(V, Q);");
        }
        self.open_parallel("i");
        self.buf.line("g.gpu_level[i] = -1;");
        self.close_parallel();
        self.buf.line(&format!("setIndexDevice(Q, g.gpu_level, {from}, 0);"));
        self.buf.line("int hops_from_source = 0;");
        self.buf.line("bool finished;");
        self.buf.open("do {");
        self.buf.line("finished = true;");
        self.buf.line("Q.memcpy(d_finished, &finished, sizeof(bool)).wait();");
        self.open_parallel(var);
        self.buf.open(&format!("if (g.gpu_level[{var}] == hops_from_source) {{"));
        self.buf.open(&format!(
            "for (int ee = g.gpu_indexOfNodes[{var}]; ee < g.gpu_indexOfNodes[{var}+1]; ee++) {{"
        ));
        self.buf.line("int nbr = g.gpu_edgeList[ee];");
        self.buf.open("if (g.gpu_level[nbr] == -1) {");
        self.buf.line("g.gpu_level[nbr] = hops_from_source + 1;");
        self.buf.line("*d_finished = false;");
        self.buf.close("}");
        self.buf.close("}");
        render_kernel_ops(&SyclKernel, plan, &fbody.ops, &mut self.buf);
        self.buf.close("}");
        self.close_parallel();
        self.buf.line("++hops_from_source;");
        self.buf.line("Q.memcpy(&finished, d_finished, sizeof(bool)).wait();");
        self.buf.close("} while (!finished);");
        if let Some(ri) = b.rev {
            let rbody =
                plan.kernels[ri].body.as_ref().expect("BFS reverse sweep carries a lowered body");
            self.buf.line("// iterateInReverse: no grid.sync needed — one submit per");
            self.buf.line("// level, which is why SYCL wins on road networks (§5.2)");
            self.buf.open("while (--hops_from_source >= 0) {");
            self.open_parallel(var);
            self.buf.line(&format!("if (g.gpu_level[{var}] != hops_from_source) continue;"));
            if let Some(g) = &rbody.guard {
                self.buf.line(&format!("if (!({})) continue;", emit(g, &sycl_style())));
            }
            render_kernel_ops(&SyclKernel, plan, &rbody.ops, &mut self.buf);
            self.close_parallel();
            self.buf.close("}");
        }
        if b.level.is_none() {
            self.buf.line("sycl::free(g.gpu_level, Q);");
        }
    }

    fn fixed_point_enter(&mut self, index: usize, var: &str) -> String {
        let flag = self.plan.fixed_points[index].flag_name.clone();
        self.buf.line(&format!("// fixedPoint on `{flag}`: single device flag word (§4.3)"));
        self.buf.line(&format!("bool {var} = false;"));
        self.buf.open(&format!("while (!{var}) {{"));
        self.buf.line(&format!("{var} = true;"));
        self.buf.line(&format!("Q.memcpy(d_finished, &{var}, sizeof(bool)).wait();"));
        flag
    }

    fn fixed_point_exit(&mut self, var: &str) {
        self.buf.line(&format!("Q.memcpy(&{var}, d_finished, sizeof(bool)).wait();"));
        self.buf.close("}");
    }

    fn epilogue_begin(&mut self) {
        self.buf.line("");
        self.buf.line("// §4.3: updated properties return to the host once");
    }

    fn copy_out(&mut self, slot: u32) {
        let m = self.plan.meta(slot);
        let len = m.len_sym();
        self.buf.line(&format!(
            "Q.memcpy({n}, g.gpu_{n}, sizeof({ty}) * {len}).wait();",
            n = m.name,
            ty = TYPES.name(m.ty)
        ));
    }

    fn free_prop(&mut self, slot: u32) {
        self.buf.line(&format!("sycl::free(g.gpu_{}, Q);", self.plan.prop_name(slot)));
    }

    fn free_flag(&mut self) {
        self.buf.line("sycl::free(d_finished, Q);");
    }

    fn free_graph(&mut self) {
        for &arr in &self.plan.graph_arrays {
            self.buf.line(&format!("sycl::free({}, Q);", dev_arr(arr)));
        }
    }
}
