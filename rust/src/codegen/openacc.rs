//! OpenACC backend (paper §3, Figures 3, 7, 10; §4.2 optimizations).
//!
//! Directive-based: no separate kernels. The §4.2 analysis promotes the data
//! clauses of every parallel loop up to one enclosing `#pragma acc data`
//! region (Fig 3), and scalar reductions become `reduction(op : var)`
//! clauses on the loop pragma (Fig 7).
//!
//! A thin renderer over [`DevicePlan`]: the data-clause buffer sets, local
//! property arrays, reduction clauses, the entire host-statement schedule,
//! and every kernel body come from the plan — this module is the OpenACC
//! [`HostDialect`] + [`AccKernel`] dialect, driven by
//! [`super::render_host_schedule`] and `super::body::render_kernel_ops`.
//! Because the data region owns all transfers, most transfer-shaped
//! [`HostOp`](crate::ir::plan::HostOp)s (graph H2D, flag allocation,
//! copy-outs) render to nothing here; the promoted region opens at the
//! `LaunchSetup` op (after the local `new[]` allocations) and closes at
//! `EpilogueBegin`.

use super::body::{render_kernel_ops, KernelDialect};
use super::buf::CodeBuf;
use super::cexpr::{emit, openacc_style, Style};
use super::{red_sym, render_host_schedule, HostDialect};
use crate::dsl::ast::{Expr, MinMax, ReduceOp};
use crate::ir::plan::{DevicePlan, GraphArray, PropMeta, TypeMap};
use crate::ir::{IrProgram, ScalarTy};

const TYPES: &TypeMap = &TypeMap::C;

/// OpenACC device dialect: atomic pragmas instead of atomic intrinsics.
struct AccKernel;

impl KernelDialect for AccKernel {
    fn types(&self) -> &'static TypeMap {
        TYPES
    }

    fn style(&self) -> Style {
        openacc_style()
    }

    fn reduce(&self, buf: &mut CodeBuf, loc: &str, op: ReduceOp, _ty: ScalarTy, val: &str) {
        buf.line("#pragma acc atomic update");
        buf.line(&format!("{loc} = {loc} {} {val};", red_sym(op)));
    }

    fn reduce_scalar(
        &self,
        buf: &mut CodeBuf,
        name: &str,
        op: ReduceOp,
        _ty: ScalarTy,
        val: &str,
    ) {
        // handled by the loop's reduction(...) clause (Fig 7)
        buf.line(&format!("{name} = {name} {} {val};", red_sym(op)));
    }

    fn min_max_update(
        &self,
        buf: &mut CodeBuf,
        _kind: MinMax,
        loc: &str,
        tmp: &str,
        _ty: ScalarTy,
    ) {
        // Fig 10: guard + atomic write (OpenACC has no atomicMin). The
        // compare temporary is typed from the plan by the driver; the old
        // walker's untyped, never-read `int oldValue` is gone.
        buf.line("#pragma acc atomic write");
        buf.line(&format!("{loc} = {tmp};"));
    }

    fn set_or_flag(&self, buf: &mut CodeBuf) {
        buf.line("#pragma acc atomic write");
        buf.line("finished = false;");
    }
}

pub fn generate(ir: &IrProgram) -> Result<String, crate::dsl::diag::DslError> {
    Ok(generate_with(ir, &DevicePlan::build(ir)?))
}

/// Render with a pre-built plan ([`super::generate`] lowers once for all
/// backends).
pub(crate) fn generate_with(_ir: &IrProgram, plan: &DevicePlan) -> String {
    let mut g = Gen { plan, buf: CodeBuf::new() };
    g.run()
}

struct Gen<'a> {
    plan: &'a DevicePlan,
    buf: CodeBuf,
}

impl<'a> Gen<'a> {
    fn run(&mut self) -> String {
        let plan = self.plan;
        let mut out = super::manifest_header("OpenACC", plan);
        self.buf.line("#include <climits>");
        self.buf.line("#include <cstdlib>");
        self.buf.line("#include <cstring>");
        self.buf.line("#include \"libstarplat_acc.h\"");
        self.buf.line("");
        let params = plan.host_signature(TYPES);
        self.buf.open(&format!("void {}({}) {{", plan.func, params.join(", ")));
        render_host_schedule(self, &plan.host_ops, None);
        self.buf.close("}");
        out.push_str(&std::mem::take(&mut self.buf).finish());
        out
    }

    /// Is this buffer a locally-`new[]`ed property array (declared in the
    /// body, node-sized)?
    fn is_local(m: &PropMeta) -> bool {
        !m.param && !m.edge
    }
}

impl<'a> HostDialect for Gen<'a> {
    fn expr_style(&self) -> Style {
        openacc_style()
    }

    fn buf(&mut self) -> &mut CodeBuf {
        &mut self.buf
    }

    fn decl_dims(&mut self) {
        self.buf.line("int num_nodes = g.num_nodes();");
    }

    fn graph_to_device(&mut self) {
        // the promoted data region (opened at launch_setup) owns the graph
    }

    fn alloc_prop(&mut self, slot: u32) {
        let m = self.plan.meta(slot);
        if Self::is_local(m) {
            let ty = TYPES.name(m.ty);
            self.buf.line(&format!("{ty}* {} = new {ty}[g.num_nodes()];", m.name));
        }
    }

    fn alloc_flag(&mut self) {
        // fixedPoint convergence is a plain host flag word under OpenACC
    }

    /// §4.2: open the one promoted data region for the whole function
    /// (Fig 3) — after the local `new[]` allocations, before the body.
    fn launch_setup(&mut self) {
        self.buf.line("");
        self.buf.line("// §4.2: data clauses promoted out of the loops — graph arrays and");
        self.buf.line("// all device-resident properties transfer once");
        self.buf.line("#pragma acc data copyin(g)");
        self.buf.open("{");
        let mut clauses: Vec<String> = vec![
            "g.edgeList[0:g.num_edges()]".into(),
            "g.indexofNodes[:g.num_nodes()+1]".into(),
        ];
        if self.plan.graph_arrays.contains(&GraphArray::RevOffsets) {
            clauses.push("g.rev_indexofNodes[:g.num_nodes()+1]".into());
            clauses.push("g.srcList[0:g.num_edges()]".into());
        }
        for &slot in &self.plan.device_resident {
            if self.plan.outputs.contains(&slot) {
                continue;
            }
            let m = self.plan.meta(slot);
            let len = if m.edge { "0:g.num_edges()" } else { "0:g.num_nodes()" };
            clauses.push(format!("{}[{len}]", m.name));
        }
        let copies: Vec<String> = self
            .plan
            .outputs
            .iter()
            .map(|&s| {
                let m = self.plan.meta(s);
                let len = if m.edge { "0:g.num_edges()" } else { "0:g.num_nodes()" };
                format!("{}[{len}]", m.name)
            })
            .collect();
        self.buf.line(&format!("#pragma acc data copyin({})", clauses.join(", ")));
        if !copies.is_empty() {
            self.buf.line(&format!("  copy({})", copies.join(", ")));
        }
        self.buf.open("{");
    }

    fn copy_prop(&mut self, dst: u32, src: u32) {
        self.buf.line("#pragma acc parallel loop");
        self.buf.open("for (int i = 0; i < g.num_nodes(); i++) {");
        self.buf.line(&format!(
            "{}[i] = {}[i];",
            self.plan.prop_name(dst),
            self.plan.prop_name(src)
        ));
        self.buf.close("}");
    }

    fn set_element(&mut self, slot: u32, index: &str, value: &Expr) {
        self.buf.line(&format!(
            "{}[{index}] = {};",
            self.plan.prop_name(slot),
            emit(value, &openacc_style())
        ));
    }

    fn init_props(&mut self, _kernel: usize, inits: &[(u32, Expr)]) {
        self.buf.line("#pragma acc parallel loop");
        self.buf.open("for (int v = 0; v < g.num_nodes(); v++) {");
        for (slot, e) in inits {
            self.buf.line(&format!(
                "{}[v] = {};",
                self.plan.prop_name(*slot),
                emit(e, &openacc_style())
            ));
        }
        self.buf.close("}");
    }

    fn launch(&mut self, kernel: usize, _or_flag: Option<&str>) {
        let plan = self.plan;
        let k = &plan.kernels[kernel];
        let body = k.body.as_ref().expect("forall kernel carries a lowered body");
        // Fig 7: reduction clause for scalar reductions, from the plan
        let mut pragma = "#pragma acc parallel loop".to_string();
        let reds: Vec<String> = k
            .reductions
            .iter()
            .map(|(r, op, _)| format!("reduction({}: {r})", red_sym(*op)))
            .collect();
        if !reds.is_empty() {
            pragma = format!("{pragma} {}", reds.join(" "));
        }
        if let Some(pull) = &k.pull_body {
            // schedule plan: a derived pull twin re-orients the relaxation
            // onto the reverse CSR; the host picks a direction at runtime
            self.buf
                .line("// schedule plan: STARPLAT_DIRECTION=pull selects the reverse-CSR variant");
            self.buf.line(&format!(
                "bool usePull_{} = getenv(\"STARPLAT_DIRECTION\") != NULL && \
                 strcmp(getenv(\"STARPLAT_DIRECTION\"), \"pull\") == 0;",
                k.id
            ));
            self.buf.open(&format!("if (usePull_{}) {{", k.id));
            self.buf.line(&pragma);
            self.buf.open(&format!(
                "for (int {v} = 0; {v} < g.num_nodes(); {v}++) {{",
                v = pull.thread_var
            ));
            render_kernel_ops(&AccKernel, plan, &pull.ops, &mut self.buf);
            self.buf.close("}");
            self.buf.close("} else {");
            self.buf.inc();
        }
        self.buf.line(&pragma);
        self.buf.open(&format!(
            "for (int {v} = 0; {v} < g.num_nodes(); {v}++) {{",
            v = body.thread_var
        ));
        if let Some(g) = &body.guard {
            self.buf.line(&format!("if (!({})) continue;", emit(g, &openacc_style())));
        }
        render_kernel_ops(&AccKernel, plan, &body.ops, &mut self.buf);
        self.buf.close("}");
        if k.pull_body.is_some() {
            self.buf.close("}");
        }
    }

    fn bfs(&mut self, index: usize, var: &str, from: &str) {
        let plan = self.plan;
        let b = &plan.bfs_loops[index];
        let fbody =
            plan.kernels[b.fwd].body.as_ref().expect("BFS forward sweep carries a lowered body");
        let implicit_level = b.level.is_none();
        self.buf.line("// iterateInBFS (§3.4): do-while over levels on the host");
        if implicit_level {
            // implicit level buffer (e.g. BC): owned by the skeleton
            self.buf.line("int* level = new int[g.num_nodes()];");
        }
        self.buf.line("#pragma acc parallel loop");
        self.buf.open("for (int i = 0; i < g.num_nodes(); i++) { level[i] = -1; }");
        self.buf.close("");
        self.buf.line(&format!("level[{from}] = 0;"));
        self.buf.line("int hops_from_source = 0;");
        self.buf.line("bool finished;");
        self.buf.open("do {");
        self.buf.line("finished = true;");
        self.buf.line("#pragma acc parallel loop");
        self.buf.open(&format!("for (int {var} = 0; {var} < g.num_nodes(); {var}++) {{"));
        self.buf.open(&format!("if (level[{var}] == hops_from_source) {{"));
        self.buf.open(&format!(
            "for (int ee = g.indexofNodes[{var}]; ee < g.indexofNodes[{var}+1]; ee++) {{"
        ));
        self.buf.line("int nbr = g.edgeList[ee];");
        self.buf.open("if (level[nbr] == -1) {");
        self.buf.line("level[nbr] = hops_from_source + 1;");
        self.buf.line("finished = false;");
        self.buf.close("}");
        self.buf.close("}");
        render_kernel_ops(&AccKernel, plan, &fbody.ops, &mut self.buf);
        self.buf.close("}");
        self.buf.close("}");
        self.buf.line("++hops_from_source;");
        self.buf.close("} while (!finished);");
        if let Some(ri) = b.rev {
            let rbody =
                plan.kernels[ri].body.as_ref().expect("BFS reverse sweep carries a lowered body");
            self.buf.line("// iterateInReverse: walk levels backwards");
            self.buf.open("while (--hops_from_source >= 0) {");
            self.buf.line("#pragma acc parallel loop");
            self.buf.open(&format!("for (int {var} = 0; {var} < g.num_nodes(); {var}++) {{"));
            self.buf.line(&format!("if (level[{var}] != hops_from_source) continue;"));
            if let Some(g) = &rbody.guard {
                self.buf.line(&format!("if (!({})) continue;", emit(g, &openacc_style())));
            }
            render_kernel_ops(&AccKernel, plan, &rbody.ops, &mut self.buf);
            self.buf.close("}");
            self.buf.close("}");
        }
        if implicit_level {
            self.buf.line("delete[] level;");
        }
    }

    fn fixed_point_enter(&mut self, index: usize, var: &str) -> String {
        let flag = self.plan.fixed_points[index].flag_name.clone();
        self.buf.line(&format!("// fixedPoint on `{flag}` (§4.2: host flag word)"));
        self.buf.line(&format!("bool {var} = false;"));
        self.buf.open(&format!("while (!{var}) {{"));
        self.buf.line(&format!("{var} = true;"));
        self.buf.line("bool finished = true;");
        flag
    }

    fn fixed_point_exit(&mut self, var: &str) {
        self.buf.line(&format!("{var} = finished;"));
        self.buf.close("}");
    }

    /// Close the promoted data regions: the `copy(...)` clause returns the
    /// outputs here, so the CopyOut ops render to nothing.
    fn epilogue_begin(&mut self) {
        self.buf.close("}");
        self.buf.close("}");
    }

    fn copy_out(&mut self, _slot: u32) {
        // handled by the data region's copy(...) clause
    }

    fn free_prop(&mut self, slot: u32) {
        let m = self.plan.meta(slot);
        if Self::is_local(m) {
            self.buf.line(&format!("delete[] {};", m.name));
        }
    }

    fn free_flag(&mut self) {}

    fn free_graph(&mut self) {}
}
