//! Kernel-body rendering: one structural driver over the plan-carried
//! [`KernelOp`] tree, with every backend-specific spelling behind the
//! [`KernelDialect`] trait — the device-side twin of the host half's
//! `HostDialect` / `render_host_schedule` pair.
//!
//! The paper's observation that "the parallelism concepts remain the same
//! [while] the syntax and the placement of constructs change significantly
//! across the backends" (§3.2) maps to this module: *structure* (loop
//! nesting, guards, the Min/Max compare-and-update shape, OR-flag clears)
//! comes from the [`crate::ir::kernel`] lowering and is rendered once by
//! [`render_kernel_ops`]; *dialect* (atomics idioms, declaration syntax,
//! loop spelling) is a trait impl per backend. There are no per-target
//! match arms here — which is exactly what lets non-C-family targets (WGSL,
//! Metal) plug in without teaching the walker their syntax.

use super::buf::CodeBuf;
use super::cexpr::{emit, Style};
use crate::dsl::ast::{MinMax, ReduceOp};
use crate::ir::kernel::{KCell, KTarget, KernelOp};
use crate::ir::plan::{DevicePlan, TypeMap};
use crate::ir::ScalarTy;

/// Per-backend spellings for device-kernel statements. Defaults cover the
/// C-family syntax shared by CUDA/HIP/OpenCL/SYCL/OpenACC/Metal; backends
/// override what differs (atomics, or-flag, declarations for WGSL).
pub(crate) trait KernelDialect {
    /// Scalar-type spelling inside device code.
    fn types(&self) -> &'static TypeMap;
    /// Expression naming style (buffer prefixes, literals, atomic loads).
    fn style(&self) -> Style;

    /// Kernel-local declaration.
    fn decl(&self, buf: &mut CodeBuf, ty: ScalarTy, name: &str, init: Option<&str>) {
        let t = self.types().name(ty);
        match init {
            Some(e) => buf.line(&format!("{t} {name} = {e};")),
            None => buf.line(&format!("{t} {name};")),
        }
    }

    /// Plain store. `atomic` marks a target whose buffer has an atomic
    /// element type in this dialect (Metal / WGSL), `ty` the target
    /// property's machine type (`None` for kernel locals) — WGSL needs it to
    /// bitcast stores into f32 bit-pattern buffers. The C family ignores
    /// both.
    fn store(
        &self,
        buf: &mut CodeBuf,
        loc: &str,
        value: &str,
        _atomic: bool,
        _ty: Option<ScalarTy>,
    ) {
        buf.line(&format!("{loc} = {value};"));
    }

    /// Device cell a scalar reduction lands in (matches the launch sites'
    /// `d_<name>` allocations).
    fn cell_ref(&self, name: &str) -> String {
        format!("d_{name}[0]")
    }

    /// Atomic reduction into one device location.
    fn reduce(&self, buf: &mut CodeBuf, loc: &str, op: ReduceOp, ty: ScalarTy, val: &str);

    /// Scalar-cell reduction. Default routes through [`Self::reduce`] on the
    /// cell; OpenACC overrides it (Fig 7's `reduction(op: var)` clause makes
    /// the plain statement atomic).
    fn reduce_scalar(&self, buf: &mut CodeBuf, name: &str, op: ReduceOp, ty: ScalarTy, val: &str) {
        self.reduce(buf, &self.cell_ref(name), op, ty, val);
    }

    /// The winning §3.5 Min/Max update (the compare guard is already open).
    fn min_max_update(&self, buf: &mut CodeBuf, kind: MinMax, loc: &str, tmp: &str, ty: ScalarTy);

    /// Clear the fixedPoint OR-flag after a winning Min/Max (§4.1).
    fn set_or_flag(&self, buf: &mut CodeBuf);

    fn if_open(&self, buf: &mut CodeBuf, cond: &str) {
        buf.open(&format!("if ({cond}) {{"));
    }
    fn if_else(&self, buf: &mut CodeBuf) {
        buf.close("} else {");
        buf.inc();
    }
    fn if_close(&self, buf: &mut CodeBuf) {
        buf.close("}");
    }

    /// Open a CSR (`reverse: false`) or reverse-CSR (`reverse: true`)
    /// neighbor scan and bind the neighbor variable.
    fn neighbor_loop_open(&self, buf: &mut CodeBuf, var: &str, of: &str, reverse: bool) {
        let st = self.style();
        let (off, list) =
            if reverse { (st.rev_offsets, st.src_list) } else { (st.offsets, st.edge_list) };
        let v = (st.scalar)(of);
        buf.open(&format!("for (int edge = {off}[{v}]; edge < {off}[{v}+1]; edge++) {{"));
        buf.line(&format!("int {var} = {list}[edge];"));
    }
    fn loop_close(&self, buf: &mut CodeBuf) {
        buf.close("}");
    }
}

/// Raw reference to one property element (no atomic-load wrapping — use as a
/// store / atomic-op target).
fn prop_ref(st: &Style, plan: &DevicePlan, slot: u32, obj: &str) -> String {
    format!("{}[{}]", (st.prop_array)(plan.prop_name(slot)), (st.scalar)(obj))
}

/// Read of one property element, wrapped in the dialect's atomic load when
/// the buffer is atomic in this kernel (bit-pattern f32 buffers additionally
/// bitcast the loaded word back to float).
fn prop_read(st: &Style, plan: &DevicePlan, slot: u32, obj: &str) -> String {
    let cell = prop_ref(st, plan, slot, obj);
    let name = plan.prop_name(slot);
    if st.atomic_f32_props.contains(name) {
        (st.atomic_f32_load)(&cell)
    } else if st.atomic_props.contains(name) {
        (st.atomic_load)(&cell)
    } else {
        cell
    }
}

/// Is this property's buffer atomically typed in the current kernel (either
/// the native-atomic set or the bit-pattern f32 set)? Stores to it must go
/// through the dialect's atomic-store spelling.
fn is_atomic(st: &Style, plan: &DevicePlan, slot: u32) -> bool {
    let name = plan.prop_name(slot);
    st.atomic_props.contains(name) || st.atomic_f32_props.contains(name)
}

/// The one kernel-statement driver shared by every text backend: walks a
/// [`KernelOp`] tree, rendering structure directly and delegating every
/// backend-specific spelling to the [`KernelDialect`].
pub(crate) fn render_kernel_ops<D: KernelDialect + ?Sized>(
    d: &D,
    plan: &DevicePlan,
    ops: &[KernelOp],
    buf: &mut CodeBuf,
) {
    let st = d.style();
    for op in ops {
        match op {
            KernelOp::Decl { name, ty, init } => {
                let init = init.as_ref().map(|e| emit(e, &st));
                d.decl(buf, *ty, name, init.as_deref());
            }
            KernelOp::AssignVar { name, value } => {
                d.store(buf, &(st.scalar)(name), &emit(value, &st), false, None);
            }
            KernelOp::AssignProp { slot, obj, value } => {
                let atomic = is_atomic(&st, plan, *slot);
                let loc = prop_ref(&st, plan, *slot, obj);
                d.store(buf, &loc, &emit(value, &st), atomic, Some(plan.meta(*slot).ty));
            }
            KernelOp::Reduce { cell, op, ty, value } => {
                let val = emit(value, &st);
                match cell {
                    KCell::Cell { name } => d.reduce_scalar(buf, name, *op, *ty, &val),
                    KCell::Prop { slot, obj } => {
                        let loc = prop_ref(&st, plan, *slot, obj);
                        d.reduce(buf, &loc, *op, *ty, &val);
                    }
                }
            }
            KernelOp::MinMax { kind, slot, obj, ty, compare, extra, or_flag } => {
                let loc = prop_ref(&st, plan, *slot, obj);
                let read = prop_read(&st, plan, *slot, obj);
                let tmp = format!("{}_new", plan.prop_name(*slot));
                d.decl(buf, *ty, &tmp, Some(&emit(compare, &st)));
                let cmp = if *kind == MinMax::Min { ">" } else { "<" };
                d.if_open(buf, &format!("{read} {cmp} {tmp}"));
                d.min_max_update(buf, *kind, &loc, &tmp, *ty);
                for (t, v) in extra {
                    let (tloc, atomic, tty) = match t {
                        KTarget::Var(n) => ((st.scalar)(n), false, None),
                        KTarget::Prop { slot, obj } => (
                            prop_ref(&st, plan, *slot, obj),
                            is_atomic(&st, plan, *slot),
                            Some(plan.meta(*slot).ty),
                        ),
                    };
                    d.store(buf, &tloc, &emit(v, &st), atomic, tty);
                }
                if *or_flag {
                    // any successful update un-finishes the fixed point (§4.1)
                    d.set_or_flag(buf);
                }
                d.if_close(buf);
            }
            KernelOp::NeighborLoop { var, of, reverse, bfs, filter, body } => {
                d.neighbor_loop_open(buf, var, of, *reverse);
                // §3.4 BFS-DAG filter — both sweeps walk the same DAG, so
                // one structured condition serves forward and reverse
                // sweeps alike: a CSR scan keeps the children
                // (level(parent) + 1); a reverse-CSR pull keeps the
                // parents (level(child) - 1)
                if bfs.is_some() {
                    let lvl = (st.prop_array)("level");
                    let v = (st.scalar)(of);
                    let rel = if *reverse { "- 1" } else { "+ 1" };
                    d.if_open(buf, &format!("{lvl}[{var}] == {lvl}[{v}] {rel}"));
                }
                if let Some(f) = filter {
                    d.if_open(buf, &emit(f, &st));
                }
                render_kernel_ops(d, plan, body, buf);
                if filter.is_some() {
                    d.if_close(buf);
                }
                if bfs.is_some() {
                    d.if_close(buf);
                }
                d.loop_close(buf);
            }
            KernelOp::If { cond, then, els } => {
                d.if_open(buf, &emit(cond, &st));
                render_kernel_ops(d, plan, then, buf);
                if let Some(e) = els {
                    d.if_else(buf);
                    render_kernel_ops(d, plan, e, buf);
                }
                d.if_close(buf);
            }
            KernelOp::Unsupported { what } => {
                buf.line(&format!("/* {what} not supported in kernels */"));
            }
        }
    }
}
