//! Kernel-body statement emitter shared by the C-family backends.
//!
//! One walker, four atomics dialects — the paper's observation that "the
//! parallelism concepts remain the same [while] the syntax and the placement
//! of constructs change significantly across the backends" (§3.2) maps to
//! this module: structure comes from the AST, dialect from [`Target`].

use super::buf::CodeBuf;
use super::cexpr::{emit, Style};
use super::red_sym;
use crate::dsl::ast::*;
use crate::ir::analyze::as_reduction;
use crate::ir::plan::{DevicePlan, TypeMap};
use crate::ir::ScalarTy;
use crate::sema::TypedFunction;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Target {
    Cuda,
    OpenCl,
    Sycl,
    OpenAcc,
}

pub struct BodyCtx<'a> {
    /// typed AST, for expression syntax (filter resolution)
    pub tf: &'a TypedFunction,
    /// device plan: the single source of property/buffer types
    pub plan: &'a DevicePlan,
    /// this backend's scalar-type spelling
    pub types: &'a TypeMap,
    pub style: Style,
    pub target: Target,
    /// inside iterateInBFS / iterateInReverse (affects neighbor iteration)
    pub bfs: Option<BfsDir>,
    /// OR-flag property of the enclosing fixedPoint, if any (§4.1)
    pub or_flag: Option<String>,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BfsDir {
    Forward,
    Reverse,
}

impl<'a> BodyCtx<'a> {
    fn prop_ty(&self, prop: &str) -> ScalarTy {
        self.plan.prop_ty_of(prop)
    }

    fn c_ty(&self, ty: &Type) -> String {
        self.types.name(ScalarTy::of(ty)).to_string()
    }
}

/// Emit the statements of a kernel body, assuming the surrounding emitter
/// already bound the vertex variable (e.g. `int v = ...;`).
pub fn emit_block(b: &[Stmt], cx: &BodyCtx<'_>, buf: &mut CodeBuf) {
    for s in b {
        emit_stmt(s, cx, buf);
    }
}

fn emit_stmt(s: &Stmt, cx: &BodyCtx<'_>, buf: &mut CodeBuf) {
    let st = &cx.style;
    match s {
        Stmt::Decl { ty, name, init, .. } => {
            match init {
                Some(e) => buf.line(&format!("{} {} = {};", cx.c_ty(ty), name, emit(e, st))),
                None => buf.line(&format!("{} {};", cx.c_ty(ty), name)),
            }
        }
        Stmt::Assign { target, value, .. } => {
            if let Some((t, op, rhs)) = as_reduction(target, value) {
                if matches!(t, LValue::Prop { .. }) {
                    emit_reduce(&t, op, &rhs, cx, buf);
                    return;
                }
            }
            match target {
                LValue::Var(v) => buf.line(&format!("{} = {};", (st.scalar)(v), emit(value, st))),
                LValue::Prop { obj, prop } => buf.line(&format!(
                    "{}[{}] = {};",
                    (st.prop_array)(prop),
                    (st.scalar)(obj),
                    emit(value, st)
                )),
            }
        }
        Stmt::Reduce { target, op, value, .. } => emit_reduce(target, *op, value, cx, buf),
        Stmt::MinMaxAssign { kind, target, compare, extra, .. } => {
            emit_min_max(*kind, target, compare, extra, cx, buf)
        }
        Stmt::For { iter, body, .. } => emit_neighbor_loop(iter, body, cx, buf),
        Stmt::If { cond, then, els, .. } => {
            buf.open(&format!("if ({}) {{", emit(cond, st)));
            emit_block(then, cx, buf);
            if let Some(e) = els {
                buf.close("} else {");
                buf.inc();
                emit_block(e, cx, buf);
            }
            buf.close("}");
        }
        other => {
            buf.line(&format!("/* unsupported in kernel: {:?} */", std::mem::discriminant(other)))
        }
    }
}

fn emit_neighbor_loop(iter: &Iterator_, body: &[Stmt], cx: &BodyCtx<'_>, buf: &mut CodeBuf) {
    let st = &cx.style;
    let var = &iter.var;
    match &iter.source {
        IterSource::Neighbors { of, .. } => {
            buf.open(&format!(
                "for (int edge = {off}[{v}]; edge < {off}[{v}+1]; edge++) {{",
                off = st.offsets,
                v = (st.scalar)(of)
            ));
            buf.line(&format!("int {var} = {}[edge];", st.edge_list));
            if let Some(dir) = cx.bfs {
                // BFS-DAG children only (paper §3.4 level filter)
                let lvl = (st.prop_array)("level");
                match dir {
                    BfsDir::Forward => buf.open(&format!(
                        "if ({lvl}[{var}] == {lvl}[{v}] + 1) {{",
                        v = (st.scalar)(of)
                    )),
                    BfsDir::Reverse => buf.open(&format!(
                        "if ({lvl}[{var}] == {lvl}[{v}] + 1) {{",
                        v = (st.scalar)(of)
                    )),
                }
            }
            if let Some(f) = &iter.filter {
                let fe = crate::codegen::simplify_bool_cmp(&crate::codegen::resolve_filter(
                    f, var, cx.tf,
                ));
                buf.open(&format!("if ({}) {{", emit(&fe, st)));
            }
            emit_block(body, cx, buf);
            if iter.filter.is_some() {
                buf.close("}");
            }
            if cx.bfs.is_some() {
                buf.close("}");
            }
            buf.close("}");
        }
        IterSource::NodesTo { of, .. } => {
            buf.open(&format!(
                "for (int edge = {off}[{v}]; edge < {off}[{v}+1]; edge++) {{",
                off = st.rev_offsets,
                v = (st.scalar)(of)
            ));
            buf.line(&format!("int {var} = {}[edge];", st.src_list));
            if let Some(f) = &iter.filter {
                let fe = crate::codegen::simplify_bool_cmp(&crate::codegen::resolve_filter(
                    f, var, cx.tf,
                ));
                buf.open(&format!("if ({}) {{", emit(&fe, st)));
            }
            emit_block(body, cx, buf);
            if iter.filter.is_some() {
                buf.close("}");
            }
            buf.close("}");
        }
        IterSource::Nodes { .. } | IterSource::Set { .. } => {
            buf.line("/* nested full-graph iteration not supported in kernels */");
        }
    }
}

fn emit_reduce(target: &LValue, op: ReduceOp, value: &Expr, cx: &BodyCtx<'_>, buf: &mut CodeBuf) {
    let st = &cx.style;
    let val = emit(value, st);
    let (loc, ty) = match target {
        LValue::Var(v) => {
            if cx.target == Target::OpenAcc {
                // handled by the loop's reduction(...) clause (Fig 7)
                buf.line(&format!("{v} = {v} {} {val};", red_sym(op)));
                return;
            }
            let sty = cx.tf.vars.get(v).map(ScalarTy::of).unwrap_or(ScalarTy::I64);
            (format!("d_{v}[0]", ), sty)
        }
        LValue::Prop { obj, prop } => (
            format!("{}[{}]", (st.prop_array)(prop), (st.scalar)(obj)),
            cx.prop_ty(prop),
        ),
    };
    match cx.target {
        Target::Cuda => match op {
            ReduceOp::Add | ReduceOp::Count => buf.line(&format!("atomicAdd(&{loc}, {val});")),
            ReduceOp::Mul => buf.line(&format!("atomicMul(&{loc}, {val}); // emulated via CAS")),
            ReduceOp::And => buf.line(&format!("atomicAnd(&{loc}, {val});")),
            ReduceOp::Or => buf.line(&format!("atomicOr(&{loc}, {val});")),
        },
        Target::OpenCl => match (op, ty) {
            (ReduceOp::Add | ReduceOp::Count, ScalarTy::F32 | ScalarTy::F64) => {
                // OpenCL has int/long atomics only: simulate via cmpxchg (§3.3)
                buf.line(&format!("atomicAddFloat(&{loc}, {val}); // atomic_cmpxchg loop"));
            }
            (ReduceOp::Add | ReduceOp::Count, _) => {
                buf.line(&format!("atomic_add(&{loc}, {val});"))
            }
            (ReduceOp::Mul, _) => buf.line(&format!("atomicMulCmpxchg(&{loc}, {val});")),
            (ReduceOp::And, _) => buf.line(&format!("atomic_and(&{loc}, {val});")),
            (ReduceOp::Or, _) => buf.line(&format!("atomic_or(&{loc}, {val});")),
        },
        Target::Sycl => {
            // Fig 8's atomic_ref idiom
            buf.line(&format!(
                "atomic_ref<{t}, memory_order::relaxed, memory_scope::device, access::address_space::global_space> atomic_data({loc});",
                t = cx.types.name(ty)
            ));
            match op {
                ReduceOp::Add | ReduceOp::Count => buf.line(&format!("atomic_data += {val};")),
                ReduceOp::Mul => {
                    buf.line(&format!("atomic_data = atomic_data * {val}; // CAS loop"))
                }
                ReduceOp::And => buf.line(&format!("atomic_data &= {val};")),
                ReduceOp::Or => buf.line(&format!("atomic_data |= {val};")),
            }
        }
        Target::OpenAcc => {
            buf.line("#pragma acc atomic update");
            buf.line(&format!("{loc} = {loc} {} {val};", red_sym(op)));
        }
    }
}

/// The Min/Max construct (paper §3.5; Figures 6, 10, 11).
fn emit_min_max(
    kind: MinMax,
    target: &LValue,
    compare: &Expr,
    extra: &[(LValue, Expr)],
    cx: &BodyCtx<'_>,
    buf: &mut CodeBuf,
) {
    let st = &cx.style;
    let LValue::Prop { obj, prop } = target else {
        buf.line("/* Min/Max on scalars unsupported */");
        return;
    };
    let loc = format!("{}[{}]", (st.prop_array)(prop), (st.scalar)(obj));
    let ty = cx.types.name(cx.prop_ty(prop));
    let cmp = if kind == MinMax::Min { ">" } else { "<" };
    buf.line(&format!("{ty} {prop}_new = {};", emit(compare, st)));
    buf.open(&format!("if ({loc} {cmp} {prop}_new) {{"));
    match cx.target {
        Target::Cuda => buf.line(&format!(
            "atomic{}(&{loc}, {prop}_new);",
            if kind == MinMax::Min { "Min" } else { "Max" }
        )),
        Target::OpenCl => buf.line(&format!(
            "atomic_{}(&{loc}, {prop}_new);",
            if kind == MinMax::Min { "min" } else { "max" }
        )),
        Target::Sycl => {
            buf.line(&format!(
                "atomic_ref<{ty}, memory_order::relaxed, memory_scope::device, access::address_space::global_space> atomic_data({loc});"
            ));
            buf.line(&format!(
                "atomic_data.fetch_{}({prop}_new);",
                if kind == MinMax::Min { "min" } else { "max" }
            ));
        }
        Target::OpenAcc => {
            // Fig 10: guard + atomic write (OpenACC has no atomicMin)
            buf.line(&format!("int oldValue = {loc};"));
            buf.line("#pragma acc atomic write");
            buf.line(&format!("{loc} = {prop}_new;"));
        }
    }
    for (t, v) in extra {
        match t {
            LValue::Prop { obj, prop } => buf.line(&format!(
                "{}[{}] = {};",
                (st.prop_array)(prop),
                (st.scalar)(obj),
                emit(v, st)
            )),
            LValue::Var(name) => buf.line(&format!("{} = {};", (st.scalar)(name), emit(v, st))),
        }
    }
    // OR-flag: any successful update un-finishes the fixed point (§4.1)
    if cx.or_flag.is_some() {
        match cx.target {
            Target::Cuda | Target::OpenCl => buf.line("gpu_finished[0] = false;"),
            Target::Sycl => buf.line("*d_finished = false;"),
            Target::OpenAcc => {
                buf.line("#pragma acc atomic write");
                buf.line("finished = false;");
            }
        }
    }
    buf.close("}");
}
