//! OpenCL backend (paper §3, Figure 5).
//!
//! Like CUDA, a split generation — `__kernel` functions plus host
//! boilerplate (`clCreateBuffer` / `clSetKernelArg` / NDRange launches).
//! Float/double atomics are simulated with `atomic_cmpxchg` (§3.3), and
//! booleans are `int` — resolved by [`TypeMap::OPENCL`] in the device plan,
//! not here. A thin renderer over [`DevicePlan`]: buffers, parameter lists,
//! kernel numbering, and host-loop skeletons all come from the plan.

use super::body::{emit_block, BfsDir, BodyCtx, Target};
use super::buf::CodeBuf;
use super::cexpr::{emit, opencl_style};
use super::red_sym;
use crate::dsl::ast::*;
use crate::ir::plan::{DevicePlan, KernelParam, KernelPlan, PlanCursor, TypeMap};
use crate::ir::{IrProgram, ScalarTy};
use crate::sema::TypedFunction;

/// Device-side types (bool → int, 64-bit → `long`).
const DEV: &TypeMap = &TypeMap::OPENCL;
/// Host halves are C++: plain C types.
const HOST: &TypeMap = &TypeMap::C;

pub fn generate(ir: &IrProgram) -> String {
    generate_with(ir, &DevicePlan::build(ir))
}

/// Render with a pre-built plan ([`super::generate`] lowers once for all
/// backends).
pub(crate) fn generate_with(ir: &IrProgram, plan: &DevicePlan) -> String {
    let mut g = Gen {
        tf: &ir.tf,
        plan,
        cursor: PlanCursor::default(),
        kernels: CodeBuf::new(),
        host: CodeBuf::new(),
    };
    g.run()
}

struct Gen<'a> {
    tf: &'a TypedFunction,
    plan: &'a DevicePlan,
    cursor: PlanCursor,
    kernels: CodeBuf,
    host: CodeBuf,
}

impl<'a> Gen<'a> {
    fn prop_c_ty(&self, p: &str) -> &'static str {
        self.plan.c_ty_of(p, DEV)
    }

    /// `__kernel` signature entry for one plan-ordered parameter.
    fn param_decl(&self, p: &KernelParam) -> String {
        match p {
            KernelParam::NumNodes => "int V".to_string(),
            KernelParam::Graph(a) => format!("__global int* {}", a.device_name()),
            KernelParam::Prop(s) => {
                let m = self.plan.meta(*s);
                format!("__global {}* gpu_{}", DEV.name(m.ty), m.name)
            }
            KernelParam::ReductionCell { name, ty } => {
                format!("__global {}* d_{name}", DEV.name(*ty))
            }
            KernelParam::Scalar { name, ty } => format!("{} {name}", DEV.name(*ty)),
            KernelParam::OrFlag => "__global int* gpu_finished".to_string(),
        }
    }

    fn body_ctx(&self, bfs: Option<BfsDir>, or_flag: Option<&str>) -> BodyCtx<'a> {
        BodyCtx {
            tf: self.tf,
            plan: self.plan,
            types: DEV,
            style: opencl_style(),
            target: Target::OpenCl,
            bfs,
            or_flag: or_flag.map(str::to_string),
        }
    }

    fn run(&mut self) -> String {
        let f = self.tf.func.clone(); // detach from `self` for the &mut walk
        self.kernels.line("// ---- kernels.cl ----");
        self.kernels.line("");
        let params = self.plan.host_signature(HOST);
        self.host.line("// ---- host.cpp ----");
        self.host.line("#include <CL/cl.h>");
        self.host.line("#include \"libstarplat_ocl.h\"");
        self.host.line("");
        self.host.open(&format!("void {}({}) {{", f.name, params.join(", ")));
        self.host.line("cl_int status;");
        self.host.line("int V = g.num_nodes();");
        self.host.line("int E = g.num_edges();");
        self.host.line("// context/queue/program setup elided to libstarplat_ocl.h helpers");
        self.host.line(
            "cl_mem gpu_OA = clCreateBuffer(context, CL_MEM_READ_ONLY, sizeof(int)*(1+V), NULL, &status);",
        );
        self.host.line(
            "cl_mem gpu_edgeList = clCreateBuffer(context, CL_MEM_READ_ONLY, sizeof(int)*E, NULL, &status);",
        );
        self.host.line(
            "clEnqueueWriteBuffer(command_queue, gpu_OA, CL_TRUE, 0, sizeof(int)*(1+V), g.indexofNodes, 0, NULL, NULL);",
        );
        self.host.line(
            "clEnqueueWriteBuffer(command_queue, gpu_edgeList, CL_TRUE, 0, sizeof(int)*E, g.edgeList, 0, NULL, NULL);",
        );
        for &slot in &self.plan.device_resident {
            let m = self.plan.meta(slot);
            let ty = DEV.name(m.ty);
            let len = m.len_sym();
            self.host.line(&format!(
                "cl_mem gpu_{} = clCreateBuffer(context, CL_MEM_READ_WRITE, sizeof({ty})*{len}, NULL, &status);",
                m.name
            ));
        }
        self.host.line(
            "cl_mem gpu_finished = clCreateBuffer(context, CL_MEM_READ_WRITE, sizeof(int), NULL, &status);",
        );
        self.host.line("size_t global_size = ((V + 127) / 128) * 128;");
        self.host.line("size_t local_size = 128;");
        self.host.line("");
        self.host_block(&f.body, None);
        self.host.line("");
        for &slot in &self.plan.outputs {
            let m = self.plan.meta(slot);
            let ty = DEV.name(m.ty);
            let len = m.len_sym();
            self.host.line(&format!(
                "clEnqueueReadBuffer(command_queue, gpu_{n}, CL_TRUE, 0, sizeof({ty})*{len}, {n}, 0, NULL, NULL);",
                n = m.name
            ));
        }
        self.host.close("}");
        let mut out = String::from("// Generated by starplat-rs — OpenCL backend\n");
        for l in self.plan.manifest() {
            out.push_str("// ");
            out.push_str(&l);
            out.push('\n');
        }
        out.push('\n');
        out.push_str(&std::mem::take(&mut self.kernels).finish());
        out.push('\n');
        out.push_str(&std::mem::take(&mut self.host).finish());
        out
    }

    fn host_block(&mut self, b: &[Stmt], or_flag: Option<&str>) {
        for s in b {
            self.host_stmt(s, or_flag);
        }
    }

    fn launch(&mut self, kernel_name: &str, args: &[String]) {
        self.host.line(&format!(
            "cl_kernel {kernel_name}_k = clCreateKernel(program, \"{kernel_name}\", &status);"
        ));
        for (i, a) in args.iter().enumerate() {
            self.host
                .line(&format!("clSetKernelArg({kernel_name}_k, {i}, sizeof({a}), (void*)&{a});"));
        }
        self.host.line(&format!(
            "clEnqueueNDRangeKernel(command_queue, {kernel_name}_k, 1, NULL, &global_size, &local_size, 0, NULL, NULL);"
        ));
        self.host.line("clFinish(command_queue);");
    }

    /// Open the `__kernel` header from the plan's parameter list; returns the
    /// launch-site argument names.
    fn kernel_header(&mut self, k: &KernelPlan, params: &[KernelParam]) -> Vec<String> {
        let sig: Vec<String> = params.iter().map(|p| self.param_decl(p)).collect();
        let args: Vec<String> = params.iter().map(|p| self.plan.launch_arg(p)).collect();
        self.kernels.open(&format!("__kernel void {}({}) {{", k.name, sig.join(", ")));
        args
    }

    fn host_stmt(&mut self, s: &Stmt, or_flag: Option<&str>) {
        let st = opencl_style();
        match s {
            Stmt::Decl { ty, name, init, .. } => {
                if ty.is_prop() {
                    return;
                }
                match init {
                    Some(e) => self.host.line(&format!(
                        "{} {} = {};",
                        HOST.name(ScalarTy::of(ty)),
                        name,
                        emit(e, &st)
                    )),
                    None => {
                        self.host.line(&format!("{} {};", HOST.name(ScalarTy::of(ty)), name))
                    }
                }
            }
            Stmt::AttachNodeProperty { inits, .. } => {
                self.cursor.next_kernel(self.plan);
                for (p, e) in inits {
                    self.host.line(&format!(
                        "initKernelCL(command_queue, program, gpu_{p}, V, ({}){});",
                        self.prop_c_ty(p),
                        emit(e, &st)
                    ));
                }
            }
            Stmt::For { parallel: true, iter, body, .. } => {
                let k = self.cursor.next_kernel(self.plan);
                for (r, _, ty) in &k.reductions {
                    let t = DEV.name(*ty);
                    self.host.line(&format!(
                        "cl_mem d_{r} = clCreateBuffer(context, CL_MEM_READ_WRITE, sizeof({t}), NULL, &status);"
                    ));
                    self.host.line(&format!(
                        "clEnqueueWriteBuffer(command_queue, d_{r}, CL_TRUE, 0, sizeof({t}), &{r}, 0, NULL, NULL);"
                    ));
                }
                let params = k.params(or_flag.is_some());
                let args = self.kernel_header(k, &params);
                self.kernels.line(&format!("unsigned {v} = get_global_id(0);", v = iter.var));
                self.kernels.line(&format!("if ({} >= V) return;", iter.var));
                if let Some(f) = &iter.filter {
                    let fe = super::simplify_bool_cmp(&super::resolve_filter(
                        f,
                        &iter.var,
                        self.tf,
                    ));
                    self.kernels.line(&format!("if (!({})) return;", emit(&fe, &st)));
                }
                let cx = self.body_ctx(None, or_flag);
                emit_block(body, &cx, &mut self.kernels);
                self.kernels.close("}");
                self.kernels.line("");
                self.launch(&k.name, &args);
                for (r, _, ty) in &k.reductions {
                    let t = DEV.name(*ty);
                    self.host.line(&format!(
                        "clEnqueueReadBuffer(command_queue, d_{r}, CL_TRUE, 0, sizeof({t}), &{r}, 0, NULL, NULL);"
                    ));
                    self.host.line(&format!("clReleaseMemObject(d_{r});"));
                }
            }
            Stmt::For { parallel: false, iter, body, .. } => {
                let set = match &iter.source {
                    IterSource::Set { set } => set.clone(),
                    _ => "g.nodes()".into(),
                };
                self.host.open(&format!("for (int {} : {set}) {{", iter.var));
                self.host_block(body, or_flag);
                self.host.close("}");
            }
            Stmt::IterateBFS { var, from, body, reverse, .. } => {
                // same structure as CUDA (§3.4: "The OpenCL backend code is
                // similar to CUDA"), kernel emitted with OpenCL decorations.
                let (b, fwd, rev) = self.cursor.next_bfs(self.plan);
                // the BFS skeleton binds level, depth, and the finished flag;
                // the rest of the signature is the plan's parameter list. A
                // declared level property keeps its plan type.
                let lt = b.level.map(|s| self.plan.c_ty(s, DEV)).unwrap_or("int");
                let params = fwd.bfs_params(b.level);
                let mut sig: Vec<String> = params.iter().map(|p| self.param_decl(p)).collect();
                let mut args: Vec<String> =
                    params.iter().map(|p| self.plan.launch_arg(p)).collect();
                for (decl, arg) in [
                    (format!("__global {lt}* gpu_level"), "gpu_level"),
                    ("__global int* d_hops_from_source".to_string(), "d_hops_from_source"),
                    ("__global int* gpu_finished".to_string(), "gpu_finished"),
                ] {
                    sig.push(decl);
                    args.push(arg.to_string());
                }
                self.kernels
                    .open(&format!("__kernel void {}({}) {{", fwd.name, sig.join(", ")));
                self.kernels.line(&format!("unsigned {var} = get_global_id(0);"));
                self.kernels.line(&format!("if ({var} >= V) return;"));
                self.kernels.open(&format!("if (gpu_level[{var}] == *d_hops_from_source) {{"));
                self.kernels
                    .open(&format!("for (int i = gpu_OA[{var}]; i < gpu_OA[{var}+1]; ++i) {{"));
                self.kernels.line("int nbr = gpu_edgeList[i];");
                self.kernels.open("if (gpu_level[nbr] == -1) {");
                self.kernels.line("gpu_level[nbr] = *d_hops_from_source + 1;");
                self.kernels.line("gpu_finished[0] = 0;");
                self.kernels.close("}");
                self.kernels.close("}");
                let cx = self.body_ctx(Some(BfsDir::Forward), None);
                emit_block(body, &cx, &mut self.kernels);
                self.kernels.close("}");
                self.kernels.close("}");
                self.kernels.line("");
                self.host.line("// iterateInBFS host loop (similar to CUDA, §3.4)");
                if b.level.is_none() {
                    self.host.line(
                        "cl_mem gpu_level = clCreateBuffer(context, CL_MEM_READ_WRITE, sizeof(int)*V, NULL, &status);",
                    );
                }
                self.host.line(
                    "cl_mem d_hops_from_source = clCreateBuffer(context, CL_MEM_READ_WRITE, sizeof(int), NULL, &status);",
                );
                self.host.line("initKernelCL(command_queue, program, gpu_level, V, -1);");
                self.host.line(&format!("setIndexCL(command_queue, gpu_level, {from}, 0);"));
                self.host.line("int hops_from_source = 0; int finished;");
                self.host.line(
                    "clEnqueueWriteBuffer(command_queue, d_hops_from_source, CL_TRUE, 0, sizeof(int), &hops_from_source, 0, NULL, NULL);",
                );
                self.host.open("do {");
                self.host.line("finished = 1;");
                self.host.line(
                    "clEnqueueWriteBuffer(command_queue, gpu_finished, CL_TRUE, 0, sizeof(int), &finished, 0, NULL, NULL);",
                );
                self.launch(&fwd.name, &args);
                self.host.line("++hops_from_source;");
                self.host.line(
                    "clEnqueueWriteBuffer(command_queue, d_hops_from_source, CL_TRUE, 0, sizeof(int), &hops_from_source, 0, NULL, NULL);",
                );
                self.host.line(
                    "clEnqueueReadBuffer(command_queue, gpu_finished, CL_TRUE, 0, sizeof(int), &finished, 0, NULL, NULL);",
                );
                self.host.close("} while (!finished);");
                if let (Some(rk), Some((_, rbody))) = (rev, reverse) {
                    self.host.line("// iterateInReverse host loop");
                    self.host.open("while (--hops_from_source >= 0) {");
                    self.host.line(&format!("/* launch {}: see kernels.cl */", rk.name));
                    self.host.close("}");
                    let rsig: Vec<String> = rk
                        .bfs_params(b.level)
                        .iter()
                        .map(|p| self.param_decl(p))
                        .chain([
                            format!("__global {lt}* gpu_level"),
                            "__global int* d_hops_from_source".to_string(),
                        ])
                        .collect();
                    self.kernels
                        .open(&format!("__kernel void {}({}) {{", rk.name, rsig.join(", ")));
                    self.kernels.line(&format!("unsigned {var} = get_global_id(0);"));
                    self.kernels.line(&format!(
                        "if ({var} >= V || gpu_level[{var}] != *d_hops_from_source) return;"
                    ));
                    let cx = self.body_ctx(Some(BfsDir::Reverse), None);
                    emit_block(rbody, &cx, &mut self.kernels);
                    self.kernels.close("}");
                    self.kernels.line("");
                }
                // skeleton-owned buffers were created at the BFS site (which
                // may sit inside a host loop): release them here
                self.host.line("clReleaseMemObject(d_hops_from_source);");
                if b.level.is_none() {
                    self.host.line("clReleaseMemObject(gpu_level);");
                }
            }
            Stmt::FixedPoint { var, body, .. } => {
                let flag = self.cursor.next_fixed_point(self.plan).flag_name.clone();
                self.host.line(&format!("// fixedPoint on `{flag}` (single int flag, §4.1)"));
                self.host.line(&format!("int {var} = 0;"));
                self.host.open(&format!("while (!{var}) {{"));
                self.host.line(&format!("{var} = 1;"));
                self.host.line(&format!(
                    "clEnqueueWriteBuffer(command_queue, gpu_finished, CL_TRUE, 0, sizeof(int), &{var}, 0, NULL, NULL);"
                ));
                self.host_block(body, Some(&flag));
                self.host.line(&format!(
                    "clEnqueueReadBuffer(command_queue, gpu_finished, CL_TRUE, 0, sizeof(int), &{var}, 0, NULL, NULL);"
                ));
                self.host.close("}");
            }
            Stmt::Assign { target, value, .. } => match target {
                LValue::Var(v) if self.plan.is_node_prop(v) => {
                    let Expr::Var(src) = value else { return };
                    let ty = self.prop_c_ty(v);
                    self.host.line(&format!(
                        "clEnqueueCopyBuffer(command_queue, gpu_{src}, gpu_{v}, 0, 0, sizeof({ty})*V, 0, NULL, NULL);"
                    ));
                }
                LValue::Var(v) => self.host.line(&format!("{v} = {};", emit(value, &st))),
                LValue::Prop { obj, prop } => self.host.line(&format!(
                    "setIndexCL(command_queue, gpu_{prop}, {obj}, {});",
                    emit(value, &st)
                )),
            },
            Stmt::Reduce { target, op, value, .. } => {
                if let LValue::Var(v) = target {
                    self.host.line(&format!("{v} = {v} {} {};", red_sym(*op), emit(value, &st)));
                }
            }
            Stmt::DoWhile { body, cond, .. } => {
                self.host.open("do {");
                self.host_block(body, or_flag);
                self.host.close(&format!("}} while ({});", emit(cond, &st)));
            }
            Stmt::While { cond, body, .. } => {
                self.host.open(&format!("while ({}) {{", emit(cond, &st)));
                self.host_block(body, or_flag);
                self.host.close("}");
            }
            Stmt::If { cond, then, els, .. } => {
                self.host.open(&format!("if ({}) {{", emit(cond, &st)));
                self.host_block(then, or_flag);
                if let Some(e) = els {
                    self.host.close("} else {");
                    self.host.inc();
                    self.host_block(e, or_flag);
                }
                self.host.close("}");
            }
            Stmt::Return { value, .. } => {
                self.host.line(&format!("return {};", emit(value, &st)));
            }
            Stmt::MinMaxAssign { .. } => {
                self.host.line("/* Min/Max outside a parallel loop unsupported */");
            }
        }
    }
}
