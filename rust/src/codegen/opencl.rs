//! OpenCL backend (paper §3, Figure 5).
//!
//! Like CUDA, a split generation — `__kernel` functions plus host
//! boilerplate (`clCreateBuffer` / `clSetKernelArg` / NDRange launches).
//! Float/double atomics are simulated with `atomic_cmpxchg` (§3.3), and
//! booleans are `int` — resolved by [`TypeMap::OPENCL`] in the device plan,
//! not here. A thin renderer over [`DevicePlan`]: buffers, parameter lists,
//! kernel numbering, the entire host-statement schedule, and every kernel
//! body come from the plan; this module is the OpenCL [`HostDialect`] +
//! [`OclKernel`] dialect — spellings only, driven by
//! [`super::render_host_schedule`] and `super::body::render_kernel_ops`.

use super::body::{render_kernel_ops, KernelDialect};
use super::buf::CodeBuf;
use super::cexpr::{emit, opencl_style, Style};
use super::{render_host_schedule, HostDialect};
use crate::dsl::ast::{Expr, MinMax, ReduceOp};
use crate::ir::plan::{DevicePlan, KernelParam, KernelPlan, TypeMap};
use crate::ir::{IrProgram, ScalarTy};

/// Device-side types (bool → int, 64-bit → `long`).
const DEV: &TypeMap = &TypeMap::OPENCL;

/// OpenCL C device dialect: `atomic_*` builtins on int/long cells, cmpxchg
/// emulation for float adds (§3.3).
struct OclKernel;

impl KernelDialect for OclKernel {
    fn types(&self) -> &'static TypeMap {
        DEV
    }

    fn style(&self) -> Style {
        opencl_style()
    }

    fn reduce(&self, buf: &mut CodeBuf, loc: &str, op: ReduceOp, ty: ScalarTy, val: &str) {
        match (op, ty) {
            (ReduceOp::Add | ReduceOp::Count, ScalarTy::F32 | ScalarTy::F64) => {
                // OpenCL has int/long atomics only: simulate via cmpxchg (§3.3)
                buf.line(&format!("atomicAddFloat(&{loc}, {val}); // atomic_cmpxchg loop"));
            }
            (ReduceOp::Add | ReduceOp::Count, _) => {
                buf.line(&format!("atomic_add(&{loc}, {val});"))
            }
            (ReduceOp::Mul, _) => buf.line(&format!("atomicMulCmpxchg(&{loc}, {val});")),
            (ReduceOp::And, _) => buf.line(&format!("atomic_and(&{loc}, {val});")),
            (ReduceOp::Or, _) => buf.line(&format!("atomic_or(&{loc}, {val});")),
        }
    }

    fn min_max_update(
        &self,
        buf: &mut CodeBuf,
        kind: MinMax,
        loc: &str,
        tmp: &str,
        _ty: ScalarTy,
    ) {
        buf.line(&format!(
            "atomic_{}(&{loc}, {tmp});",
            if kind == MinMax::Min { "min" } else { "max" }
        ));
    }

    fn set_or_flag(&self, buf: &mut CodeBuf) {
        buf.line("gpu_finished[0] = false;");
    }
}

pub fn generate(ir: &IrProgram) -> Result<String, crate::dsl::diag::DslError> {
    Ok(generate_with(ir, &DevicePlan::build(ir)?))
}

/// Render with a pre-built plan ([`super::generate`] lowers once for all
/// backends).
pub(crate) fn generate_with(_ir: &IrProgram, plan: &DevicePlan) -> String {
    let mut g = Gen { plan, kernels: CodeBuf::new(), host: CodeBuf::new() };
    g.run()
}

struct Gen<'a> {
    plan: &'a DevicePlan,
    kernels: CodeBuf,
    host: CodeBuf,
}

impl<'a> Gen<'a> {
    /// `__kernel` signature entry for one plan-ordered parameter.
    fn param_decl(&self, p: &KernelParam) -> String {
        match p {
            KernelParam::NumNodes => "int V".to_string(),
            KernelParam::Graph(a) => format!("__global int* {}", a.device_name()),
            KernelParam::Prop(s) => {
                let m = self.plan.meta(*s);
                format!("__global {}* gpu_{}", DEV.name(m.ty), m.name)
            }
            KernelParam::ReductionCell { name, ty } => {
                format!("__global {}* d_{name}", DEV.name(*ty))
            }
            KernelParam::Scalar { name, ty } => format!("{} {name}", DEV.name(*ty)),
            KernelParam::OrFlag => "__global int* gpu_finished".to_string(),
        }
    }

    fn run(&mut self) -> String {
        let plan = self.plan;
        self.kernels.line("// ---- kernels.cl ----");
        self.kernels.line("");
        let params = plan.host_signature(&TypeMap::C);
        self.host.line("// ---- host.cpp ----");
        self.host.line("#include <CL/cl.h>");
        self.host.line("#include <cstdlib>");
        self.host.line("#include <cstring>");
        self.host.line("#include \"libstarplat_ocl.h\"");
        self.host.line("");
        self.host.open(&format!("void {}({}) {{", plan.func, params.join(", ")));
        render_host_schedule(self, &plan.host_ops, None);
        self.host.close("}");

        let mut out = super::manifest_header("OpenCL", plan);
        out.push('\n');
        out.push_str(&std::mem::take(&mut self.kernels).finish());
        out.push('\n');
        out.push_str(&std::mem::take(&mut self.host).finish());
        out
    }

    fn enqueue_launch(&mut self, kernel_name: &str, args: &[String]) {
        self.host.line(&format!(
            "cl_kernel {kernel_name}_k = clCreateKernel(program, \"{kernel_name}\", &status);"
        ));
        for (i, a) in args.iter().enumerate() {
            self.host
                .line(&format!("clSetKernelArg({kernel_name}_k, {i}, sizeof({a}), (void*)&{a});"));
        }
        self.host.line(&format!(
            "clEnqueueNDRangeKernel(command_queue, {kernel_name}_k, 1, NULL, &global_size, &local_size, 0, NULL, NULL);"
        ));
        self.host.line("clFinish(command_queue);");
    }
}

impl<'a> HostDialect for Gen<'a> {
    fn expr_style(&self) -> Style {
        opencl_style()
    }

    fn buf(&mut self) -> &mut CodeBuf {
        &mut self.host
    }

    fn decl_dims(&mut self) {
        self.host.line("cl_int status;");
        self.host.line("int V = g.num_nodes();");
        self.host.line("int E = g.num_edges();");
        self.host.line("// context/queue/program setup elided to libstarplat_ocl.h helpers");
    }

    fn graph_to_device(&mut self) {
        for &arr in &self.plan.graph_arrays {
            let (dev, host, len) = (arr.device_name(), arr.host_name(), arr.len_sym());
            self.host.line(&format!(
                "cl_mem {dev} = clCreateBuffer(context, CL_MEM_READ_ONLY, sizeof(int) * {len}, NULL, &status);"
            ));
            self.host.line(&format!(
                "clEnqueueWriteBuffer(command_queue, {dev}, CL_TRUE, 0, sizeof(int) * {len}, {host}, 0, NULL, NULL);"
            ));
        }
    }

    fn alloc_prop(&mut self, slot: u32) {
        let m = self.plan.meta(slot);
        let ty = DEV.name(m.ty);
        let len = m.len_sym();
        self.host.line(&format!(
            "cl_mem gpu_{} = clCreateBuffer(context, CL_MEM_READ_WRITE, sizeof({ty}) * {len}, NULL, &status);",
            m.name
        ));
    }

    fn alloc_flag(&mut self) {
        self.host.line(
            "cl_mem gpu_finished = clCreateBuffer(context, CL_MEM_READ_WRITE, sizeof(int), NULL, &status);",
        );
    }

    fn launch_setup(&mut self) {
        self.host.line("size_t global_size = ((V + 127) / 128) * 128;");
        self.host.line("size_t local_size = 128;");
        self.host.line("");
    }

    fn copy_prop(&mut self, dst: u32, src: u32) {
        let ty = DEV.name(self.plan.meta(dst).ty);
        self.host.line(&format!(
            "clEnqueueCopyBuffer(command_queue, gpu_{}, gpu_{}, 0, 0, sizeof({ty}) * V, 0, NULL, NULL);",
            self.plan.prop_name(src),
            self.plan.prop_name(dst)
        ));
    }

    fn set_element(&mut self, slot: u32, index: &str, value: &Expr) {
        self.host.line(&format!(
            "setIndexCL(command_queue, gpu_{}, {index}, {});",
            self.plan.prop_name(slot),
            emit(value, &opencl_style())
        ));
    }

    fn init_props(&mut self, _kernel: usize, inits: &[(u32, Expr)]) {
        for (slot, e) in inits {
            let m = self.plan.meta(*slot);
            self.host.line(&format!(
                "initKernelCL(command_queue, program, gpu_{}, V, ({}){});",
                m.name,
                DEV.name(m.ty),
                emit(e, &opencl_style())
            ));
        }
    }

    fn launch(&mut self, kernel: usize, or_flag: Option<&str>) {
        let plan = self.plan;
        let k: &KernelPlan = &plan.kernels[kernel];
        let body = k.body.as_ref().expect("forall kernel carries a lowered body");
        for (r, _, ty) in &k.reductions {
            let t = DEV.name(*ty);
            self.host.line(&format!(
                "cl_mem d_{r} = clCreateBuffer(context, CL_MEM_READ_WRITE, sizeof({t}), NULL, &status);"
            ));
            self.host.line(&format!(
                "clEnqueueWriteBuffer(command_queue, d_{r}, CL_TRUE, 0, sizeof({t}), &{r}, 0, NULL, NULL);"
            ));
        }
        let params = k.params(or_flag.is_some());
        let sig: Vec<String> = params.iter().map(|p| self.param_decl(p)).collect();
        let args: Vec<String> = params.iter().map(|p| self.plan.launch_arg(p)).collect();
        self.kernels.open(&format!("__kernel void {}({}) {{", k.name, sig.join(", ")));
        self.kernels.line(&format!("unsigned {v} = get_global_id(0);", v = body.thread_var));
        self.kernels.line(&format!("if ({} >= V) return;", body.thread_var));
        if let Some(g) = &body.guard {
            self.kernels.line(&format!("if (!({})) return;", emit(g, &opencl_style())));
        }
        render_kernel_ops(&OclKernel, plan, &body.ops, &mut self.kernels);
        self.kernels.close("}");
        self.kernels.line("");
        // schedule plan: a derived pull twin re-orients the relaxation onto
        // the reverse CSR; the host picks a direction at runtime
        if let Some(pull) = &k.pull_body {
            self.kernels
                .open(&format!("__kernel void {}_pull({}) {{", k.name, sig.join(", ")));
            self.kernels.line(&format!("unsigned {v} = get_global_id(0);", v = pull.thread_var));
            self.kernels.line(&format!("if ({} >= V) return;", pull.thread_var));
            render_kernel_ops(&OclKernel, plan, &pull.ops, &mut self.kernels);
            self.kernels.close("}");
            self.kernels.line("");
        }
        let name = k.name.clone();
        if k.pull_body.is_some() {
            self.host
                .line("// schedule plan: STARPLAT_DIRECTION=pull selects the reverse-CSR variant");
            self.host.line(&format!(
                "bool usePull_{} = getenv(\"STARPLAT_DIRECTION\") != NULL && \
                 strcmp(getenv(\"STARPLAT_DIRECTION\"), \"pull\") == 0;",
                k.id
            ));
            self.host.open(&format!("if (usePull_{}) {{", k.id));
            self.enqueue_launch(&format!("{name}_pull"), &args);
            self.host.close("} else {");
            self.host.inc();
            self.enqueue_launch(&name, &args);
            self.host.close("}");
        } else {
            self.enqueue_launch(&name, &args);
        }
        for (r, _, ty) in &k.reductions {
            let t = DEV.name(*ty);
            self.host.line(&format!(
                "clEnqueueReadBuffer(command_queue, d_{r}, CL_TRUE, 0, sizeof({t}), &{r}, 0, NULL, NULL);"
            ));
            self.host.line(&format!("clReleaseMemObject(d_{r});"));
        }
    }

    fn bfs(&mut self, index: usize, var: &str, from: &str) {
        // same structure as CUDA (§3.4: "The OpenCL backend code is similar
        // to CUDA"), kernel emitted with OpenCL decorations.
        let plan = self.plan;
        let b = &plan.bfs_loops[index];
        let fwd = &plan.kernels[b.fwd];
        let fbody = fwd.body.as_ref().expect("BFS forward sweep carries a lowered body");
        // the BFS skeleton binds level, depth, and the finished flag; the
        // rest of the signature is the plan's parameter list. A declared
        // level property keeps its plan type.
        let lt = b.level.map(|s| self.plan.c_ty(s, DEV)).unwrap_or("int");
        let params = fwd.bfs_params(b.level);
        let mut sig: Vec<String> = params.iter().map(|p| self.param_decl(p)).collect();
        let mut args: Vec<String> = params.iter().map(|p| self.plan.launch_arg(p)).collect();
        for (decl, arg) in [
            (format!("__global {lt}* gpu_level"), "gpu_level"),
            ("__global int* d_hops_from_source".to_string(), "d_hops_from_source"),
            ("__global int* gpu_finished".to_string(), "gpu_finished"),
        ] {
            sig.push(decl);
            args.push(arg.to_string());
        }
        self.kernels.open(&format!("__kernel void {}({}) {{", fwd.name, sig.join(", ")));
        self.kernels.line(&format!("unsigned {var} = get_global_id(0);"));
        self.kernels.line(&format!("if ({var} >= V) return;"));
        self.kernels.open(&format!("if (gpu_level[{var}] == *d_hops_from_source) {{"));
        self.kernels.open(&format!("for (int i = gpu_OA[{var}]; i < gpu_OA[{var}+1]; ++i) {{"));
        self.kernels.line("int nbr = gpu_edgeList[i];");
        self.kernels.open("if (gpu_level[nbr] == -1) {");
        self.kernels.line("gpu_level[nbr] = *d_hops_from_source + 1;");
        self.kernels.line("gpu_finished[0] = 0;");
        self.kernels.close("}");
        self.kernels.close("}");
        render_kernel_ops(&OclKernel, plan, &fbody.ops, &mut self.kernels);
        self.kernels.close("}");
        self.kernels.close("}");
        self.kernels.line("");
        self.host.line("// iterateInBFS host loop (similar to CUDA, §3.4)");
        if b.level.is_none() {
            self.host.line(
                "cl_mem gpu_level = clCreateBuffer(context, CL_MEM_READ_WRITE, sizeof(int) * V, NULL, &status);",
            );
        }
        self.host.line(
            "cl_mem d_hops_from_source = clCreateBuffer(context, CL_MEM_READ_WRITE, sizeof(int), NULL, &status);",
        );
        self.host.line("initKernelCL(command_queue, program, gpu_level, V, -1);");
        self.host.line(&format!("setIndexCL(command_queue, gpu_level, {from}, 0);"));
        self.host.line("int hops_from_source = 0; int finished;");
        self.host.line(
            "clEnqueueWriteBuffer(command_queue, d_hops_from_source, CL_TRUE, 0, sizeof(int), &hops_from_source, 0, NULL, NULL);",
        );
        self.host.open("do {");
        self.host.line("finished = 1;");
        self.host.line(
            "clEnqueueWriteBuffer(command_queue, gpu_finished, CL_TRUE, 0, sizeof(int), &finished, 0, NULL, NULL);",
        );
        let fname = fwd.name.clone();
        self.enqueue_launch(&fname, &args);
        self.host.line("++hops_from_source;");
        self.host.line(
            "clEnqueueWriteBuffer(command_queue, d_hops_from_source, CL_TRUE, 0, sizeof(int), &hops_from_source, 0, NULL, NULL);",
        );
        self.host.line(
            "clEnqueueReadBuffer(command_queue, gpu_finished, CL_TRUE, 0, sizeof(int), &finished, 0, NULL, NULL);",
        );
        self.host.close("} while (!finished);");
        if let Some(ri) = b.rev {
            let rk = &plan.kernels[ri];
            let rbody = rk.body.as_ref().expect("BFS reverse sweep carries a lowered body");
            let rparams = rk.bfs_params(b.level);
            let rsig: Vec<String> = rparams
                .iter()
                .map(|p| self.param_decl(p))
                .chain([
                    format!("__global {lt}* gpu_level"),
                    "__global int* d_hops_from_source".to_string(),
                ])
                .collect();
            let rargs: Vec<String> = rparams
                .iter()
                .map(|p| self.plan.launch_arg(p))
                .chain(["gpu_level".to_string(), "d_hops_from_source".to_string()])
                .collect();
            self.kernels.open(&format!("__kernel void {}({}) {{", rk.name, rsig.join(", ")));
            self.kernels.line(&format!("unsigned {var} = get_global_id(0);"));
            self.kernels.line(&format!(
                "if ({var} >= V || gpu_level[{var}] != *d_hops_from_source) return;"
            ));
            if let Some(g) = &rbody.guard {
                self.kernels.line(&format!("if (!({})) return;", emit(g, &opencl_style())));
            }
            render_kernel_ops(&OclKernel, plan, &rbody.ops, &mut self.kernels);
            self.kernels.close("}");
            self.kernels.line("");
            self.host.line("// iterateInReverse host loop");
            self.host.open("while (--hops_from_source >= 0) {");
            self.host.line(
                "clEnqueueWriteBuffer(command_queue, d_hops_from_source, CL_TRUE, 0, sizeof(int), &hops_from_source, 0, NULL, NULL);",
            );
            let rname = rk.name.clone();
            self.enqueue_launch(&rname, &rargs);
            self.host.close("}");
        }
        // skeleton-owned buffers were created at the BFS site (which may sit
        // inside a host loop): release them here
        self.host.line("clReleaseMemObject(d_hops_from_source);");
        if b.level.is_none() {
            self.host.line("clReleaseMemObject(gpu_level);");
        }
    }

    fn fixed_point_enter(&mut self, index: usize, var: &str) -> String {
        let flag = self.plan.fixed_points[index].flag_name.clone();
        self.host.line(&format!("// fixedPoint on `{flag}` (single int flag, §4.1)"));
        self.host.line(&format!("int {var} = 0;"));
        self.host.open(&format!("while (!{var}) {{"));
        self.host.line(&format!("{var} = 1;"));
        self.host.line(&format!(
            "clEnqueueWriteBuffer(command_queue, gpu_finished, CL_TRUE, 0, sizeof(int), &{var}, 0, NULL, NULL);"
        ));
        flag
    }

    fn fixed_point_exit(&mut self, var: &str) {
        self.host.line(&format!(
            "clEnqueueReadBuffer(command_queue, gpu_finished, CL_TRUE, 0, sizeof(int), &{var}, 0, NULL, NULL);"
        ));
        self.host.close("}");
    }

    fn epilogue_begin(&mut self) {
        self.host.line("");
    }

    fn copy_out(&mut self, slot: u32) {
        let m = self.plan.meta(slot);
        let ty = DEV.name(m.ty);
        let len = m.len_sym();
        self.host.line(&format!(
            "clEnqueueReadBuffer(command_queue, gpu_{n}, CL_TRUE, 0, sizeof({ty}) * {len}, {n}, 0, NULL, NULL);",
            n = m.name
        ));
    }

    fn free_prop(&mut self, slot: u32) {
        self.host.line(&format!("clReleaseMemObject(gpu_{});", self.plan.prop_name(slot)));
    }

    fn free_flag(&mut self) {
        self.host.line("clReleaseMemObject(gpu_finished);");
    }

    fn free_graph(&mut self) {
        for &arr in &self.plan.graph_arrays {
            self.host.line(&format!("clReleaseMemObject({});", arr.device_name()));
        }
    }
}
