//! Metal backend — the sixth text renderer, and the first that the old
//! AST-walking kernel emitter could not express: MSL spells its atomics as
//! typed `device atomic_*` buffers updated through
//! `atomic_fetch_*_explicit(..., memory_order_relaxed)`, so a buffer's
//! *declaration* and every *plain read* of it change once any kernel updates
//! it atomically. That per-kernel knowledge ([`KernelPlan::atomic_props`])
//! is resolved by the plan's kernel-op lowering, not here.
//!
//! Layout mirrors the OpenCL split: an MSL `kernels.metal` section (one
//! `kernel void` per plan kernel, parameters carrying `[[buffer(i)]]`
//! indices in the plan's canonical order, thread index bound from
//! `[[thread_position_in_grid]]`) followed by a metal-cpp host section
//! (`MTL::Device` / `MTL::Buffer` with shared storage, command-buffer
//! dispatches; `pipelineFor` pipeline lookup lives in
//! `libstarplat_metal.h`). Shared-storage buffers make §4 transfers plain
//! `memcpy`/`contents()` accesses — the Metal twist on the paper's
//! "graph copied once, outputs only" transfer rules.
//!
//! Spelling notes (MSL):
//! - 64-bit ints spell `long`, `double` demotes to `float`
//!   ([`TypeMap::METAL`]);
//! - `atomic_float` cells assume Metal 3 atomics; MSL has no 64-bit
//!   fetch-ops, so 64-bit reduction cells demote to `atomic_int` (staged
//!   through a matching 32-bit host word); products fall back to a
//!   CAS-loop helper, as OpenCL's float adds do (§3.3).

use super::body::{render_kernel_ops, KernelDialect};
use super::buf::CodeBuf;
use super::cexpr::{emit, metal_style, Style};
use super::{render_host_schedule, HostDialect};
use crate::dsl::ast::{Expr, MinMax, ReduceOp};
use crate::ir::kernel::KernelOp;
use crate::ir::plan::{DevicePlan, KernelParam, KernelPlan, TypeMap};
use crate::ir::{IrProgram, ScalarTy};
use std::collections::HashSet;

/// Host-side C++ types (metal-cpp host code is plain C++).
const HOST: &TypeMap = &TypeMap::C;
/// Device-side MSL types.
const DEV: &TypeMap = &TypeMap::METAL;

/// MSL atomic element type for one scalar type. MSL has no 64-bit atomic
/// fetch-ops at all, so I64 cells demote to `atomic_int` — the host side
/// stages them through a matching 32-bit word ([`cell_host_ty`]).
fn atomic_ty(ty: ScalarTy) -> &'static str {
    match ty {
        ScalarTy::Bool => "atomic_bool",
        ScalarTy::F32 | ScalarTy::F64 => "atomic_float",
        ScalarTy::I32 | ScalarTy::I64 => "atomic_int",
    }
}

/// Host-side C type matching one reduction cell's device atomic width.
fn cell_host_ty(ty: ScalarTy) -> &'static str {
    match ty {
        ScalarTy::Bool => "bool",
        ScalarTy::F32 | ScalarTy::F64 => "float",
        ScalarTy::I32 | ScalarTy::I64 => "int",
    }
}

/// Metal device dialect: explicit-memory-order atomic intrinsics.
struct MetalKernel {
    /// names of the props this kernel updates atomically
    atomic: HashSet<String>,
}

impl MetalKernel {
    fn for_kernel(plan: &DevicePlan, k: &KernelPlan) -> MetalKernel {
        MetalKernel {
            atomic: k.atomic_props.iter().map(|&s| plan.prop_name(s).to_string()).collect(),
        }
    }
}

impl KernelDialect for MetalKernel {
    fn types(&self) -> &'static TypeMap {
        DEV
    }

    fn style(&self) -> Style {
        metal_style(self.atomic.clone())
    }

    fn store(
        &self,
        buf: &mut CodeBuf,
        loc: &str,
        value: &str,
        atomic: bool,
        _ty: Option<ScalarTy>,
    ) {
        if atomic {
            buf.line(&format!("atomic_store_explicit(&{loc}, {value}, memory_order_relaxed);"));
        } else {
            buf.line(&format!("{loc} = {value};"));
        }
    }

    fn reduce(&self, buf: &mut CodeBuf, loc: &str, op: ReduceOp, _ty: ScalarTy, val: &str) {
        match op {
            ReduceOp::Add | ReduceOp::Count => buf.line(&format!(
                "atomic_fetch_add_explicit(&{loc}, {val}, memory_order_relaxed);"
            )),
            ReduceOp::Mul => buf.line(&format!(
                "atomicMulCAS(&{loc}, {val}); // no fetch_mul in MSL: CAS-loop helper"
            )),
            ReduceOp::And => buf.line(&format!(
                "atomic_fetch_and_explicit(&{loc}, {val}, memory_order_relaxed);"
            )),
            ReduceOp::Or => buf.line(&format!(
                "atomic_fetch_or_explicit(&{loc}, {val}, memory_order_relaxed);"
            )),
        }
    }

    fn min_max_update(
        &self,
        buf: &mut CodeBuf,
        kind: MinMax,
        loc: &str,
        tmp: &str,
        _ty: ScalarTy,
    ) {
        buf.line(&format!(
            "atomic_fetch_{}_explicit(&{loc}, {tmp}, memory_order_relaxed);",
            if kind == MinMax::Min { "min" } else { "max" }
        ));
    }

    fn set_or_flag(&self, buf: &mut CodeBuf) {
        buf.line("atomic_store_explicit(gpu_finished, false, memory_order_relaxed);");
    }
}

pub fn generate(ir: &IrProgram) -> Result<String, crate::dsl::diag::DslError> {
    Ok(generate_with(ir, &DevicePlan::build(ir)?))
}

/// Render with a pre-built plan ([`super::generate`] lowers once for all
/// backends).
pub(crate) fn generate_with(_ir: &IrProgram, plan: &DevicePlan) -> String {
    let mut g = Gen { plan, kernels: CodeBuf::new(), host: CodeBuf::new() };
    g.run()
}

/// Does any lowered kernel body multiply into an atomic location? MSL has no
/// `atomic_fetch_mul`, so the Mul reduce arm calls the `atomicMulCAS` helper
/// this predicate gates.
fn needs_mul_cas(plan: &DevicePlan) -> bool {
    plan.kernels.iter().filter_map(|k| k.body.as_ref()).any(|b| {
        let mut found = false;
        for op in &b.ops {
            op.visit(&mut |o| {
                if matches!(o, KernelOp::Reduce { op: ReduceOp::Mul, .. }) {
                    found = true;
                }
            });
        }
        found
    })
}

struct Gen<'a> {
    plan: &'a DevicePlan,
    kernels: CodeBuf,
    host: CodeBuf,
}

impl<'a> Gen<'a> {
    fn run(&mut self) -> String {
        let plan = self.plan;
        self.kernels.line("// ---- kernels.metal ----");
        self.kernels.line("#include <metal_stdlib>");
        self.kernels.line("#include \"libstarplat_metal.h\"");
        self.kernels.line("using namespace metal;");
        self.kernels.line("");
        if needs_mul_cas(plan) {
            // products have no fetch-op (§3.3): CAS-loop over the cell,
            // overloaded for the two atomic element families the buffers use
            self.kernels.line("// MSL has no atomic_fetch_mul: products CAS-loop on the cell");
            for (aty, cty) in [("atomic_int", "int"), ("atomic_float", "float")] {
                self.kernels.open(&format!(
                    "static inline void atomicMulCAS(device {aty}* cell, {cty} value) {{"
                ));
                self.kernels.line(&format!(
                    "{cty} old = atomic_load_explicit(cell, memory_order_relaxed);"
                ));
                self.kernels.line(
                    "while (!atomic_compare_exchange_weak_explicit(cell, &old, old * value, memory_order_relaxed, memory_order_relaxed)) { }",
                );
                self.kernels.close("}");
            }
            self.kernels.line("");
        }
        self.host.line("// ---- host.mm (metal-cpp) ----");
        self.host.line("#include <Metal/Metal.hpp>");
        self.host.line("#include <climits>");
        self.host.line("#include <cstdlib>");
        self.host.line("#include <cstring>");
        self.host.line("#include \"libstarplat_metal.h\"");
        self.host.line("");
        let params = plan.host_signature(HOST);
        self.host.open(&format!("void {}({}) {{", plan.func, params.join(", ")));
        render_host_schedule(self, &plan.host_ops, None);
        self.host.close("}");

        let mut out = super::manifest_header("Metal", plan);
        out.push('\n');
        out.push_str(&std::mem::take(&mut self.kernels).finish());
        out.push('\n');
        out.push_str(&std::mem::take(&mut self.host).finish());
        out
    }

    /// MSL signature entry for one plan-ordered parameter; `i` is its
    /// `[[buffer(i)]]` index (the plan's canonical order is the binding
    /// order).
    fn param_decl(&self, p: &KernelParam, i: usize, atomic: &[u32]) -> String {
        match p {
            KernelParam::NumNodes => format!("constant int& V [[buffer({i})]]"),
            KernelParam::Graph(a) => {
                format!("device const int* {} [[buffer({i})]]", a.device_name())
            }
            KernelParam::Prop(s) => {
                let m = self.plan.meta(*s);
                let ty = if atomic.contains(s) { atomic_ty(m.ty) } else { DEV.name(m.ty) };
                format!("device {ty}* gpu_{} [[buffer({i})]]", m.name)
            }
            KernelParam::ReductionCell { name, ty } => {
                format!("device {}* d_{name} [[buffer({i})]]", atomic_ty(*ty))
            }
            KernelParam::Scalar { name, ty } => {
                format!("constant {}& {name} [[buffer({i})]]", DEV.name(*ty))
            }
            KernelParam::OrFlag => format!("device atomic_bool* gpu_finished [[buffer({i})]]"),
        }
    }

    /// One `enc->set…` host line per canonical parameter.
    fn bind_lines(&self, params: &[KernelParam]) -> Vec<String> {
        params
            .iter()
            .enumerate()
            .map(|(i, p)| match p {
                KernelParam::NumNodes => format!("enc->setBytes(&V, sizeof(int), {i});"),
                KernelParam::Graph(a) => format!("enc->setBuffer({}, 0, {i});", a.device_name()),
                KernelParam::Prop(s) => {
                    format!("enc->setBuffer(gpu_{}, 0, {i});", self.plan.prop_name(*s))
                }
                KernelParam::ReductionCell { name, .. } => {
                    format!("enc->setBuffer(d_{name}, 0, {i});")
                }
                KernelParam::Scalar { name, ty } => {
                    format!("enc->setBytes(&{name}, sizeof({}), {i});", HOST.name(*ty))
                }
                KernelParam::OrFlag => format!("enc->setBuffer(gpu_finished, 0, {i});"),
            })
            .collect()
    }

    /// One command-buffer dispatch, scoped so repeated launch sites (loop
    /// bodies) don't redeclare `cmd`/`enc`.
    fn dispatch(&mut self, kernel_name: &str, binds: Vec<String>) {
        self.host.open("{");
        self.host.line("MTL::CommandBuffer* cmd = queue->commandBuffer();");
        self.host.line("MTL::ComputeCommandEncoder* enc = cmd->computeCommandEncoder();");
        self.host.line(&format!(
            "enc->setComputePipelineState(pipelineFor(dev, \"{kernel_name}\"));"
        ));
        for b in binds {
            self.host.line(&b);
        }
        self.host.line("enc->dispatchThreads(gridSize, threadsPerGroup);");
        self.host.line("enc->endEncoding();");
        self.host.line("cmd->commit();");
        self.host.line("cmd->waitUntilCompleted();");
        self.host.close("}");
    }

    /// Open a kernel: signature, thread index, bounds guard.
    fn open_kernel(&mut self, name: &str, sig: &[String], thread_var: &str) {
        self.kernels.open(&format!("kernel void {name}({}) {{", sig.join(", ")));
        self.kernels.line(&format!("int {thread_var} = int(tid);"));
        self.kernels.line(&format!("if ({thread_var} >= V) return;"));
    }
}

impl<'a> HostDialect for Gen<'a> {
    fn expr_style(&self) -> Style {
        metal_style(HashSet::new())
    }

    fn buf(&mut self) -> &mut CodeBuf {
        &mut self.host
    }

    fn decl_dims(&mut self) {
        self.host.line("MTL::Device* dev = MTL::CreateSystemDefaultDevice();");
        self.host.line("MTL::CommandQueue* queue = dev->newCommandQueue();");
        self.host.line("int V = g.num_nodes();");
        self.host.line("int E = g.num_edges();");
        self.host.line("");
    }

    fn graph_to_device(&mut self) {
        self.host.line("// §4.1: the static graph is copied to the device once, never back");
        for &arr in &self.plan.graph_arrays {
            let (dev, host, len) = (arr.device_name(), arr.host_name(), arr.len_sym());
            self.host.line(&format!(
                "MTL::Buffer* {dev} = dev->newBuffer({host}, sizeof(int) * {len}, MTL::ResourceStorageModeShared);"
            ));
        }
    }

    fn alloc_prop(&mut self, slot: u32) {
        let m = self.plan.meta(slot);
        let ty = HOST.name(m.ty);
        let len = m.len_sym();
        self.host.line(&format!(
            "MTL::Buffer* gpu_{} = dev->newBuffer(sizeof({ty}) * {len}, MTL::ResourceStorageModeShared);",
            m.name
        ));
    }

    fn alloc_flag(&mut self) {
        self.host.line(
            "MTL::Buffer* gpu_finished = dev->newBuffer(sizeof(bool) * 1, MTL::ResourceStorageModeShared);",
        );
    }

    fn launch_setup(&mut self) {
        self.host.line("");
        self.host.line("MTL::Size threadsPerGroup = MTL::Size(512, 1, 1);");
        self.host.line("MTL::Size gridSize = MTL::Size(V, 1, 1);");
        self.host.line("");
    }

    fn copy_prop(&mut self, dst: u32, src: u32) {
        // shared storage: device-to-device copies are host memcpys
        let ty = HOST.name(self.plan.meta(dst).ty);
        self.host.line(&format!(
            "memcpy(gpu_{}->contents(), gpu_{}->contents(), sizeof({ty}) * V);",
            self.plan.prop_name(dst),
            self.plan.prop_name(src)
        ));
    }

    fn set_element(&mut self, slot: u32, index: &str, value: &Expr) {
        let m = self.plan.meta(slot);
        let ty = HOST.name(m.ty);
        let val = emit(value, &self.expr_style());
        self.host.line(&format!(
            "(({ty}*)gpu_{}->contents())[{index}] = ({ty}){val};",
            m.name
        ));
    }

    fn init_props(&mut self, _kernel: usize, inits: &[(u32, Expr)]) {
        for (slot, e) in inits {
            let m = self.plan.meta(*slot);
            let ty = HOST.name(m.ty);
            let v = emit(e, &self.expr_style());
            self.host.line(&format!(
                "for (int i = 0; i < V; i++) (({ty}*)gpu_{}->contents())[i] = ({ty}){v};",
                m.name
            ));
        }
    }

    fn launch(&mut self, kernel: usize, or_flag: Option<&str>) {
        let plan = self.plan;
        let k: &KernelPlan = &plan.kernels[kernel];
        let body = k.body.as_ref().expect("forall kernel carries a lowered body");
        let params = k.params(or_flag.is_some());
        let mut sig: Vec<String> = params
            .iter()
            .enumerate()
            .map(|(i, p)| self.param_decl(p, i, &k.atomic_props))
            .collect();
        sig.push("uint tid [[thread_position_in_grid]]".to_string());
        let dialect = MetalKernel::for_kernel(plan, k);
        self.open_kernel(&k.name, &sig, &body.thread_var);
        if let Some(g) = &body.guard {
            self.kernels.line(&format!("if (!({})) return;", emit(g, &dialect.style())));
        }
        render_kernel_ops(&dialect, plan, &body.ops, &mut self.kernels);
        self.kernels.close("}");
        self.kernels.line("");
        // schedule plan: a derived pull twin re-orients the relaxation onto
        // the reverse CSR; the host picks a direction at runtime
        if let Some(pull) = &k.pull_body {
            self.open_kernel(&format!("{}_pull", k.name), &sig, &pull.thread_var);
            render_kernel_ops(&dialect, plan, &pull.ops, &mut self.kernels);
            self.kernels.close("}");
            self.kernels.line("");
        }
        // ---- launch site: §4-bound transfers are shared-memory memcpys ----
        for &c in &k.copy_in {
            let m = self.plan.meta(c);
            let ty = HOST.name(m.ty);
            let len = m.len_sym();
            self.host.line(&format!(
                "// copy-in (§4.1 analysis): {} is read before first device write",
                m.name
            ));
            self.host
                .line(&format!("memcpy(gpu_{n}->contents(), {n}, sizeof({ty}) * {len});", n = m.name));
        }
        for (r, _, ty) in &k.reductions {
            let t = cell_host_ty(*ty);
            self.host.line(&format!("// device reduction cell for `{r}` (§3.3)"));
            self.host.line(&format!(
                "MTL::Buffer* d_{r} = dev->newBuffer(sizeof({t}) * 1, MTL::ResourceStorageModeShared);"
            ));
            self.host.line(&format!("*({t}*)d_{r}->contents() = ({t}){r};"));
        }
        let binds = self.bind_lines(&params);
        let name = k.name.clone();
        if k.pull_body.is_some() {
            self.host
                .line("// schedule plan: STARPLAT_DIRECTION=pull selects the reverse-CSR variant");
            self.host.line(&format!(
                "bool usePull_{} = getenv(\"STARPLAT_DIRECTION\") != NULL && \
                 strcmp(getenv(\"STARPLAT_DIRECTION\"), \"pull\") == 0;",
                k.id
            ));
            self.host.open(&format!("if (usePull_{}) {{", k.id));
            self.dispatch(&format!("{name}_pull"), binds.clone());
            self.host.close("} else {");
            self.host.inc();
            self.dispatch(&name, binds);
            self.host.close("}");
        } else {
            self.dispatch(&name, binds);
        }
        for (r, _, ty) in &k.reductions {
            let t = cell_host_ty(*ty);
            self.host.line(&format!("{r} = *({t}*)d_{r}->contents();"));
            self.host.line(&format!("d_{r}->release();"));
        }
        if !k.defer_to_loop_exit {
            for &c in &k.copy_out {
                let m = self.plan.meta(c);
                let ty = HOST.name(m.ty);
                let len = m.len_sym();
                self.host.line(&format!(
                    "memcpy({n}, gpu_{n}->contents(), sizeof({ty}) * {len});",
                    n = m.name
                ));
            }
        }
    }

    fn bfs(&mut self, index: usize, var: &str, from: &str) {
        let plan = self.plan;
        let b = &plan.bfs_loops[index];
        let fwd = &plan.kernels[b.fwd];
        let fbody = fwd.body.as_ref().expect("BFS forward sweep carries a lowered body");
        let lt = b.level.map(|s| self.plan.c_ty(s, HOST)).unwrap_or("int");
        let params = fwd.bfs_params(b.level);
        let mut sig: Vec<String> = params
            .iter()
            .enumerate()
            .map(|(i, p)| self.param_decl(p, i, &fwd.atomic_props))
            .collect();
        let base = sig.len();
        sig.push(format!("device {lt}* gpu_level [[buffer({base})]]"));
        sig.push(format!("constant int& hops_from_source [[buffer({})]]", base + 1));
        sig.push(format!("device bool* d_finished [[buffer({})]]", base + 2));
        sig.push("uint tid [[thread_position_in_grid]]".to_string());
        let dialect = MetalKernel::for_kernel(plan, fwd);
        self.open_kernel(&fwd.name, &sig, var);
        self.kernels.open(&format!("if (gpu_level[{var}] == hops_from_source) {{"));
        self.kernels.open(&format!("for (int i = gpu_OA[{var}]; i < gpu_OA[{var}+1]; ++i) {{"));
        self.kernels.line("int nbr = gpu_edgeList[i];");
        self.kernels.open("if (gpu_level[nbr] == -1) {");
        self.kernels.line("gpu_level[nbr] = hops_from_source + 1;");
        self.kernels.line("*d_finished = false;");
        self.kernels.close("}");
        self.kernels.close("}");
        render_kernel_ops(&dialect, plan, &fbody.ops, &mut self.kernels);
        self.kernels.close("}");
        self.kernels.close("}");
        self.kernels.line("");
        // host loop (Fig 9), shared-storage flavor
        self.host.line("// iterateInBFS: level-synchronous host loop (Fig 9)");
        if b.level.is_none() {
            self.host.line(&format!(
                "MTL::Buffer* gpu_level = dev->newBuffer(sizeof({lt}) * V, MTL::ResourceStorageModeShared);"
            ));
        }
        self.host.line(
            "MTL::Buffer* d_finished = dev->newBuffer(sizeof(bool) * 1, MTL::ResourceStorageModeShared);",
        );
        self.host
            .line(&format!("for (int i = 0; i < V; i++) (({lt}*)gpu_level->contents())[i] = -1;"));
        self.host.line(&format!("(({lt}*)gpu_level->contents())[{from}] = 0;"));
        self.host.line("int hops_from_source = 0;");
        self.host.line("bool finished;");
        self.host.open("do {");
        self.host.line("finished = true;");
        self.host.line("*(bool*)d_finished->contents() = finished;");
        let mut binds = self.bind_lines(&params);
        let base = binds.len();
        binds.push(format!("enc->setBuffer(gpu_level, 0, {base});"));
        binds.push(format!("enc->setBytes(&hops_from_source, sizeof(int), {});", base + 1));
        binds.push(format!("enc->setBuffer(d_finished, 0, {});", base + 2));
        let fname = fwd.name.clone();
        self.dispatch(&fname, binds);
        self.host.line("++hops_from_source;");
        self.host.line("finished = *(bool*)d_finished->contents();");
        self.host.close("} while (!finished);");
        if let Some(ri) = b.rev {
            let rk = &plan.kernels[ri];
            let rbody = rk.body.as_ref().expect("BFS reverse sweep carries a lowered body");
            let rparams = rk.bfs_params(b.level);
            let mut rsig: Vec<String> = rparams
                .iter()
                .enumerate()
                .map(|(i, p)| self.param_decl(p, i, &rk.atomic_props))
                .collect();
            let rbase = rsig.len();
            rsig.push(format!("device {lt}* gpu_level [[buffer({rbase})]]"));
            rsig.push(format!("constant int& hops_from_source [[buffer({})]]", rbase + 1));
            rsig.push("uint tid [[thread_position_in_grid]]".to_string());
            let rdialect = MetalKernel::for_kernel(plan, rk);
            self.open_kernel(&rk.name, &rsig, var);
            self.kernels.line(&format!("if (gpu_level[{var}] != hops_from_source) return;"));
            if let Some(g) = &rbody.guard {
                self.kernels.line(&format!("if (!({})) return;", emit(g, &rdialect.style())));
            }
            render_kernel_ops(&rdialect, plan, &rbody.ops, &mut self.kernels);
            self.kernels.close("}");
            self.kernels.line("");
            self.host.line("// iterateInReverse: walk the BFS levels backwards");
            self.host.open("while (--hops_from_source >= 0) {");
            let mut rbinds = self.bind_lines(&rparams);
            let rb = rbinds.len();
            rbinds.push(format!("enc->setBuffer(gpu_level, 0, {rb});"));
            rbinds.push(format!("enc->setBytes(&hops_from_source, sizeof(int), {});", rb + 1));
            let rname = rk.name.clone();
            self.dispatch(&rname, rbinds);
            self.host.close("}");
        }
        // skeleton-owned buffers are allocated at the BFS site: release here
        self.host.line("d_finished->release();");
        if b.level.is_none() {
            self.host.line("gpu_level->release();");
        }
    }

    fn fixed_point_enter(&mut self, index: usize, var: &str) -> String {
        let flag = self.plan.fixed_points[index].flag_name.clone();
        self.host.line(&format!("// fixedPoint on `{flag}` via a single device flag (§4.1)"));
        self.host.line(&format!("bool {var} = false;"));
        self.host.open(&format!("while (!{var}) {{"));
        self.host.line(&format!("{var} = true;"));
        self.host.line(&format!("*(bool*)gpu_finished->contents() = {var};"));
        flag
    }

    fn fixed_point_exit(&mut self, var: &str) {
        self.host.line(&format!("{var} = *(bool*)gpu_finished->contents();"));
        self.host.close("}");
    }

    fn epilogue_begin(&mut self) {
        self.host.line("");
        self.host.line("// §4.1: only updated vertex attributes return to the host");
    }

    fn copy_out(&mut self, slot: u32) {
        let m = self.plan.meta(slot);
        let ty = HOST.name(m.ty);
        let len = m.len_sym();
        self.host
            .line(&format!("memcpy({n}, gpu_{n}->contents(), sizeof({ty}) * {len});", n = m.name));
    }

    fn free_prop(&mut self, slot: u32) {
        self.host.line(&format!("gpu_{}->release();", self.plan.prop_name(slot)));
    }

    fn free_flag(&mut self) {
        self.host.line("gpu_finished->release();");
    }

    fn free_graph(&mut self) {
        for &arr in &self.plan.graph_arrays {
            self.host.line(&format!("{}->release();", arr.device_name()));
        }
    }
}
