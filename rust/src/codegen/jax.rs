//! JAX backend — the *executable* accelerator path (DESIGN.md §1–2).
//!
//! Mirrors the paper's CUDA split-codegen with a TPU-flavored twist:
//!
//! - **device half**: a Python module defining the per-iteration step
//!   function over padded ELL arrays, calling the Pallas kernel library
//!   (`python/compile/kernels/`). `aot.py` lowers it to HLO text once.
//! - **host half**: a JSON *host plan* — the fixedPoint / do-while / BFS
//!   loop skeleton, state buffers, and convergence flag — interpreted by the
//!   Rust coordinator (`backends/xla`), exactly like Fig 9/12's host loops.
//!
//! Kernel-template selection: the emitter recognizes the paper's algorithm
//! shapes from the IR (fixedPoint+Min ⇒ relaxation, do-while+pull ⇒ rank
//! iteration, BFS fwd/rev ⇒ Brandes, nested neighbor + count ⇒ triangle
//! counting). Programs outside these shapes get a clear compile error —
//! the honest limitation documented in DESIGN.md.

use crate::dsl::ast::*;
use crate::ir::plan::{DevicePlan, TypeMap};
use crate::ir::IrProgram;
use crate::util::json::Json;
use anyhow::{bail, Result};

/// Result of JAX codegen: python module text + host plan.
pub struct JaxProgram {
    /// algorithm template id (sssp | pr | bc | tc | bfs | cc)
    pub algo: String,
    pub python: String,
    pub plan: Json,
}

pub fn generate(ir: &IrProgram) -> Result<JaxProgram> {
    generate_with(ir, &DevicePlan::build(ir)?)
}

/// Generate with a pre-built plan ([`super::generate`] lowers once for all
/// backends). Buffer bindings (state names, dtypes, outputs) come from the
/// same slot tables the text backends render — see `ir/plan.rs`.
pub(crate) fn generate_with(ir: &IrProgram, plan: &DevicePlan) -> Result<JaxProgram> {
    let shape = recognize(ir, plan)?;
    Ok(match shape {
        Shape::Relax { dist, modified, weighted } => {
            relax_program(ir, plan, &dist, &modified, weighted)
        }
        Shape::Rank { rank, diff } => rank_program(ir, plan, &rank, &diff),
        Shape::Brandes { bc, sigma, delta } => brandes_program(ir, plan, &bc, &sigma, &delta),
        Shape::Triangles { counter } => triangles_program(ir, &counter),
        Shape::BfsLevels { level } => bfs_program(ir, plan, &level),
    })
}

/// numpy dtype of a plan buffer, with a fallback for implicit buffers (e.g.
/// BC's `level`, which no DSL property declares).
fn np_ty(plan: &DevicePlan, name: &str, default: &'static str) -> &'static str {
    match plan.props.slot(name) {
        Some(s) => TypeMap::NUMPY.name(plan.props.meta(s).ty),
        None => default,
    }
}

enum Shape {
    /// SSSP / CC: fixedPoint + Min construct (weighted ⇒ min-plus)
    Relax { dist: String, modified: String, weighted: bool },
    /// PR: do-while + pull over in-edges + scalar diff reduction
    Rank { rank: String, diff: String },
    /// BC: iterateInBFS + iterateInReverse per source
    Brandes { bc: String, sigma: String, delta: String },
    /// TC: doubly-nested neighbor loop + count reduction
    Triangles { counter: String },
    /// BFS: iterateInBFS without reverse
    BfsLevels { level: String },
}

fn recognize(ir: &IrProgram, plan: &DevicePlan) -> Result<Shape> {
    let tf = &ir.tf;
    if let Some(b) = plan.bfs_loops.first() {
        let out = plan.output_names().first().map(|s| s.to_string());
        if b.rev.is_some() {
            // Brandes: float props sigma/delta + an output prop
            return Ok(Shape::Brandes {
                bc: out.unwrap_or_else(|| "BC".into()),
                sigma: "sigma".into(),
                delta: "delta".into(),
            });
        }
        return Ok(Shape::BfsLevels { level: out.unwrap_or_else(|| "level".into()) });
    }
    // fixedPoint + MinMax ⇒ relaxation
    let or_flag = plan.fixed_points.iter().find(|f| f.flag.is_some());
    let has_min = contains_minmax(&tf.func.body);
    if let (Some(fp), true) = (or_flag, has_min) {
        let dist = plan
            .output_names()
            .first()
            .map(|s| s.to_string())
            .unwrap_or_else(|| "dist".into());
        let weighted = !tf.edge_props.is_empty();
        return Ok(Shape::Relax { dist, modified: fp.flag_name.clone(), weighted });
    }
    // do-while + pull + scalar float reduction ⇒ rank iteration
    let pulls = ir.kernels.iter().any(|k| k.uses.uses_in_edges);
    let float_red = ir
        .kernels
        .iter()
        .flat_map(|k| k.uses.reductions.iter())
        .find(|(r, op)| {
            *op == ReduceOp::Add
                && matches!(tf.vars.get(r), Some(Type::Float) | Some(Type::Double))
        })
        .map(|(r, _)| r.clone());
    if let (true, Some(diff)) = (pulls, float_red) {
        let rank = plan
            .output_names()
            .first()
            .map(|s| s.to_string())
            .unwrap_or_else(|| "pageRank".into());
        return Ok(Shape::Rank { rank, diff });
    }
    // count reduction + is_an_edge ⇒ triangles
    let counter = ir
        .kernels
        .iter()
        .flat_map(|k| k.uses.reductions.iter())
        .find(|(_, op)| matches!(op, ReduceOp::Add | ReduceOp::Count))
        .map(|(r, _)| r.clone());
    if counter.is_some() && ir.kernels.iter().any(|k| k.uses.uses_is_an_edge) {
        return Ok(Shape::Triangles { counter: counter.unwrap() });
    }
    bail!(
        "JAX backend: program `{}` does not match a known kernel template \
         (relax / rank / brandes / triangles / bfs) — see DESIGN.md §limitations",
        tf.func.name
    )
}

fn contains_minmax(b: &[Stmt]) -> bool {
    b.iter().any(|s| match s {
        Stmt::MinMaxAssign { .. } => true,
        Stmt::For { body, .. }
        | Stmt::FixedPoint { body, .. }
        | Stmt::DoWhile { body, .. }
        | Stmt::While { body, .. } => contains_minmax(body),
        Stmt::If { then, els, .. } => {
            contains_minmax(then) || els.as_ref().map(|e| contains_minmax(e)).unwrap_or(false)
        }
        Stmt::IterateBFS { body, reverse, .. } => {
            contains_minmax(body)
                || reverse.as_ref().map(|(_, r)| contains_minmax(r)).unwrap_or(false)
        }
        _ => false,
    })
}

fn header(ir: &IrProgram, algo: &str) -> String {
    format!(
        "\"\"\"Generated by starplat-rs (JAX backend) from `{fn_name}`.\n\nDevice half of the split codegen: step functions over padded ELL arrays,\nlowered to HLO by python/compile/aot.py. Host loop lives in the companion\n{algo}.plan.json, interpreted by the rust coordinator (backends/xla).\nDO NOT EDIT — regenerate with `starplat compile --backend jax`.\n\"\"\"\n\nimport jax\nimport jax.numpy as jnp\n\nfrom compile import kernels\n\n",
        fn_name = ir.tf.func.name,
    )
}

fn relax_program(
    ir: &IrProgram,
    plan: &DevicePlan,
    dist: &str,
    modified: &str,
    weighted: bool,
) -> JaxProgram {
    let algo = if weighted { "sssp" } else { "cc" };
    let init = if weighted { "INF" } else { "iota" };
    let mut py = header(ir, algo);
    py.push_str(&format!(
        r#"
def {algo}_step({dist}, idx, wgt, mask):
    """One fixedPoint iteration: pull min-plus relaxation over in-edges.

    The paper's push-relax with atomicMin (Fig 6) becomes a dense pull
    reduction — no scatter atomics on this backend (DESIGN.md §2).
    Returns (dist', finished) where finished is the §4.1 OR-flag scalar.
    """
    cand = kernels.ell_relax({dist}, idx, wgt, mask)
    new = jnp.minimum({dist}, cand)
    changed = new < {dist}
    # `{modified}` array is subsumed by the single OR-flag word (§4.1)
    finished = jnp.logical_not(jnp.any(changed)).astype(jnp.int32)
    return new, finished
"#
    ));
    let plan = Json::obj(vec![
        ("algorithm", Json::Str(algo.into())),
        ("function", Json::Str(ir.tf.func.name.clone())),
        ("template", Json::Str("fixedpoint-relax".into())),
        ("artifact", Json::Str(format!("{algo}_step"))),
        ("state", Json::obj(vec![(dist, Json::Str(np_ty(plan, dist, "int32").into()))])),
        ("init", Json::Str(init.into())),
        ("weighted", Json::Bool(weighted)),
        ("outputs", Json::Arr(vec![Json::Str(dist.into())])),
        ("ell", Json::Str("in".into())),
        ("or_flag", Json::Str(modified.into())),
    ]);
    JaxProgram { algo: algo.into(), python: py, plan }
}

fn rank_program(ir: &IrProgram, plan: &DevicePlan, rank: &str, diff: &str) -> JaxProgram {
    let mut py = header(ir, "pr");
    py.push_str(&format!(
        r#"
def pr_step({rank}, idx, mask, outdeg, delta, num_nodes):
    """One do-while iteration of double-buffered PageRank (Fig 7 analog).

    Pull over in-edges via the ell_spmv kernel; `{diff}` is the scalar
    L1-delta the host loop tests against beta.
    """
    contrib = {rank} / jnp.maximum(outdeg, 1.0)
    sums = kernels.ell_spmv(contrib, idx, mask)
    val = (1.0 - delta) / num_nodes + delta * sums
    {diff} = jnp.sum(jnp.abs(val - {rank}))
    return val, {diff}
"#
    ));
    let plan = Json::obj(vec![
        ("algorithm", Json::Str("pr".into())),
        ("function", Json::Str(ir.tf.func.name.clone())),
        ("template", Json::Str("dowhile-rank".into())),
        ("artifact", Json::Str("pr_step".into())),
        ("state", Json::obj(vec![(rank, Json::Str(np_ty(plan, rank, "float32").into()))])),
        ("outputs", Json::Arr(vec![Json::Str(rank.into())])),
        ("ell", Json::Str("in".into())),
        ("scalars", Json::Arr(vec![Json::Str("delta".into()), Json::Str("num_nodes".into())])),
        ("converge_on", Json::Str(diff.into())),
    ]);
    JaxProgram { algo: "pr".into(), python: py, plan }
}

fn brandes_program(
    ir: &IrProgram,
    plan: &DevicePlan,
    bc: &str,
    sigma: &str,
    delta: &str,
) -> JaxProgram {
    let mut py = header(ir, "bc");
    py.push_str(&format!(
        r#"
def bc_fwd_step(level, {sigma}, depth, idx, mask):
    """Forward BFS wavefront (paper §3.4 / Fig 9): discover depth+1 and
    accumulate {sigma} along BFS-DAG edges — the `w.sigma += v.sigma` of
    Fig 1, as a pull over in-edges."""
    return kernels.bc_forward(level, {sigma}, depth, idx, mask)


def bc_bwd_step(level, {sigma}, {delta}, {bc}, depth, src, idx, mask):
    """Reverse sweep (iterateInReverse): {delta} accumulation over BFS-DAG
    children (out-edges), then {bc} update for vertices at `depth`."""
    return kernels.bc_backward(level, {sigma}, {delta}, {bc}, depth, src, idx, mask)
"#
    ));
    let plan = Json::obj(vec![
        ("algorithm", Json::Str("bc".into())),
        ("function", Json::Str(ir.tf.func.name.clone())),
        ("template", Json::Str("bfs-fwd-rev".into())),
        ("artifact_fwd", Json::Str("bc_fwd_step".into())),
        ("artifact_bwd", Json::Str("bc_bwd_step".into())),
        (
            "state",
            Json::obj(vec![
                ("level", Json::Str(np_ty(plan, "level", "int32").into())),
                (sigma, Json::Str(np_ty(plan, sigma, "float32").into())),
                (delta, Json::Str(np_ty(plan, delta, "float32").into())),
                (bc, Json::Str(np_ty(plan, bc, "float32").into())),
            ]),
        ),
        ("outputs", Json::Arr(vec![Json::Str(bc.into())])),
        ("ell", Json::Str("both".into())),
        ("source_set", Json::Str("sourceSet".into())),
    ]);
    JaxProgram { algo: "bc".into(), python: py, plan }
}

fn triangles_program(ir: &IrProgram, counter: &str) -> JaxProgram {
    let mut py = header(ir, "tc");
    py.push_str(&format!(
        r#"
def tc_step(adj):
    """Triangle counting. The paper's per-edge sorted binary search (§5.1)
    is re-thought for the MXU: T = sum((A @ A) * A) / 6 on the dense
    adjacency — a systolic-array-friendly formulation (DESIGN.md §2).
    Returns the scalar `{counter}`."""
    return kernels.tc_matmul(adj)
"#
    ));
    let plan = Json::obj(vec![
        ("algorithm", Json::Str("tc".into())),
        ("function", Json::Str(ir.tf.func.name.clone())),
        ("template", Json::Str("dense-matmul-count".into())),
        ("artifact", Json::Str("tc_step".into())),
        ("state", Json::obj(vec![])),
        ("outputs", Json::Arr(vec![Json::Str(counter.into())])),
        ("ell", Json::Str("dense".into())),
        ("returns", Json::Str(counter.into())),
    ]);
    JaxProgram { algo: "tc".into(), python: py, plan }
}

fn bfs_program(ir: &IrProgram, plan: &DevicePlan, level: &str) -> JaxProgram {
    let mut py = header(ir, "bfs");
    py.push_str(&format!(
        r#"
def bfs_step({level}, depth, idx, mask):
    """One level-synchronous BFS hop (Fig 9's kernel): vertices with an
    in-neighbor at `depth` and no level yet get depth+1."""
    has_parent = kernels.ell_frontier({level}, depth, idx, mask)
    fresh = jnp.logical_and({level} < 0, has_parent)
    new = jnp.where(fresh, depth + 1, {level})
    finished = jnp.logical_not(jnp.any(fresh)).astype(jnp.int32)
    return new, finished
"#
    ));
    let plan = Json::obj(vec![
        ("algorithm", Json::Str("bfs".into())),
        ("function", Json::Str(ir.tf.func.name.clone())),
        ("template", Json::Str("bfs-levels".into())),
        ("artifact", Json::Str("bfs_step".into())),
        ("state", Json::obj(vec![(level, Json::Str(np_ty(plan, level, "int32").into()))])),
        ("outputs", Json::Arr(vec![Json::Str(level.into())])),
        ("ell", Json::Str("in".into())),
    ]);
    JaxProgram { algo: "bfs".into(), python: py, plan }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parser::parse_file;
    use crate::ir::lower;
    use crate::sema::check_function;

    fn gen(p: &str) -> JaxProgram {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("dsl_programs").join(p);
        let fns = parse_file(&path).unwrap();
        let tf = check_function(&fns[0]).unwrap();
        generate(&lower(&tf)).unwrap()
    }

    #[test]
    fn recognizes_all_templates() {
        assert_eq!(gen("sssp.sp").algo, "sssp");
        assert_eq!(gen("pr.sp").algo, "pr");
        assert_eq!(gen("bc.sp").algo, "bc");
        assert_eq!(gen("tc.sp").algo, "tc");
        assert_eq!(gen("bfs.sp").algo, "bfs");
        assert_eq!(gen("cc.sp").algo, "cc");
    }

    #[test]
    fn python_references_kernel_library() {
        let p = gen("sssp.sp");
        assert!(p.python.contains("kernels.ell_relax"));
        assert!(p.python.contains("finished"));
        let pr = gen("pr.sp");
        assert!(pr.python.contains("kernels.ell_spmv"));
    }

    #[test]
    fn plan_carries_host_loop_shape() {
        let p = gen("sssp.sp");
        assert_eq!(p.plan.get("template").as_str(), Some("fixedpoint-relax"));
        assert_eq!(p.plan.get("or_flag").as_str(), Some("modified"));
        assert_eq!(p.plan.get("outputs").as_arr().unwrap().len(), 1);
    }

    #[test]
    fn unknown_shape_fails_cleanly() {
        let fns = crate::dsl::parse(
            "function f(Graph g) { forall (v in g.nodes()) { int x = 1; } }",
        )
        .unwrap();
        let tf = check_function(&fns[0]).unwrap();
        assert!(generate(&lower(&tf)).is_err());
    }
}
