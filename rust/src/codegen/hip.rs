//! HIP backend — the fifth text renderer, and the proof of the plan-carried
//! host lowering: HIP contributes *spellings only* (hipMalloc / hipMemcpy /
//! `hipLaunchKernelGGL`), reusing the CUDA-family renderer in
//! [`super::cuda`] verbatim. There is zero lowering in this module — the
//! buffer slots, kernel parameter lists, §4 transfer steps, and the whole
//! host-statement schedule come from [`DevicePlan`], exactly as they do for
//! CUDA, which is why `tests/host_schedule_conformance.rs` can pin
//! HIP↔CUDA launch-argument agreement byte for byte.
//!
//! Spelling notes (ROCm):
//! - device code keeps the `__global__` qualifier and `blockIdx`/`blockDim`
//!   builtins — HIP compiles the CUDA kernel dialect as-is;
//! - launches use the portable `hipLaunchKernelGGL(kernel, dim3(grid),
//!   dim3(block), sharedMem, stream, args...)` macro instead of the
//!   `<<<>>>` chevron syntax, with template instantiations wrapped in
//!   `HIP_KERNEL_NAME(...)` as the HIP porting guide requires;
//! - the runtime API is the CUDA API with the `hip` prefix
//!   (`hipMemcpyHostToDevice`, `hipDeviceSynchronize`, …).

use super::cuda::{generate_family, Spellings};
use crate::ir::plan::DevicePlan;
use crate::ir::IrProgram;

fn hip_launch(kernel: &str, grid: &str, block: &str, args: &str) -> String {
    // template instantiations (initKernel<int>, …) need HIP_KERNEL_NAME
    let kref = if kernel.contains('<') {
        format!("HIP_KERNEL_NAME({kernel})")
    } else {
        kernel.to_string()
    };
    format!("hipLaunchKernelGGL({kref}, dim3({grid}), dim3({block}), 0, 0, {args});")
}

pub(crate) const HIP_SPELLINGS: Spellings = Spellings {
    label: "HIP",
    includes: &[
        "#include <hip/hip_runtime.h>",
        "#include <climits>",
        "#include <cstdlib>",
        "#include <cstring>",
        "#include \"libstarplat_hip.h\"",
    ],
    malloc: "hipMalloc",
    memcpy: "hipMemcpy",
    h2d: "hipMemcpyHostToDevice",
    d2h: "hipMemcpyDeviceToHost",
    d2d: "hipMemcpyDeviceToDevice",
    free: "hipFree",
    sync: "hipDeviceSynchronize();",
    launch: hip_launch,
};

pub fn generate(ir: &IrProgram) -> Result<String, crate::dsl::diag::DslError> {
    Ok(generate_with(ir, &DevicePlan::build(ir)?))
}

/// Render with a pre-built plan ([`super::generate`] lowers once for all
/// backends).
pub(crate) fn generate_with(ir: &IrProgram, plan: &DevicePlan) -> String {
    generate_family(ir, plan, &HIP_SPELLINGS)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn launch_spelling_wraps_templates_only() {
        let plain = hip_launch("Compute_SSSP_kernel_1", "numBlocks", "threadsPerBlock", "V, x");
        assert_eq!(
            plain,
            "hipLaunchKernelGGL(Compute_SSSP_kernel_1, dim3(numBlocks), dim3(threadsPerBlock), 0, 0, V, x);"
        );
        let templated = hip_launch("initKernel<int>", "numBlocks", "threadsPerBlock", "V, p, 0");
        assert!(templated.starts_with("hipLaunchKernelGGL(HIP_KERNEL_NAME(initKernel<int>),"));
    }
}
