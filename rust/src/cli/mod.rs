//! `starplat` command-line interface (hand-rolled: no clap offline).
//!
//! Subcommands:
//!   compile --backend <cuda|hip|opencl|sycl|openacc|metal|wgsl|jax|all> --out DIR FILES...
//!   export-graphs [--out DIR] [--scale N]     write shapes.json for aot.py
//!   run --algo A --graph SHORT --backend B    run one cell of Table 3/4
//!   stats [--scale N]                          print Table 2
//!   graphgen --kind K --nodes N --edges M --out FILE
//!   loc                                        paper §5 lines-of-code table

use crate::codegen;
use crate::dsl::parser::parse_file;
use crate::ir::lower;
use crate::sema::check_function;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

pub fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("starplat: error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny flag parser: `--key value` pairs + positionals.
pub struct Flags {
    pub flags: std::collections::HashMap<String, String>,
    pub positional: Vec<String>,
}

impl Flags {
    pub fn parse(args: &[String]) -> Flags {
        let mut flags = std::collections::HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(args[i].clone());
                i += 1;
            }
        }
        Flags { flags, positional }
    }
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let rest = Flags::parse(&args[1..]);
    match cmd.as_str() {
        "compile" => cmd_compile(&rest),
        "export-graphs" => cmd_export_graphs(&rest),
        "run" => cmd_run(&rest),
        "stats" => cmd_stats(&rest),
        "graphgen" => cmd_graphgen(&rest),
        "loc" => cmd_loc(&rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` (try `starplat help`)"),
    }
}

fn print_help() {
    println!(
        "starplat — StarPlat graph-DSL compiler for a variety of accelerators\n\
         \n\
         USAGE: starplat <COMMAND> [FLAGS]\n\
         \n\
         COMMANDS:\n\
         \x20 compile --backend <cuda|hip|opencl|sycl|openacc|metal|wgsl|jax|planexec|all> [--out DIR] FILE...\n\
         \x20         (--backend all emits every text backend for each file;\n\
         \x20          planexec emits the executable plan's schedule listing)\n\
         \x20 export-graphs [--out artifacts/graphs] [--scale 800]\n\
         \x20 run --algo <bc|pr|sssp|tc|bfs|cc> --graph <TW|..|UR> --backend <seq|par|planexec|xla|gunrock|lonestar>\n\
         \x20 stats [--scale 4000]          print the Table-2 graph suite\n\
         \x20 graphgen --kind <rmat|uniform|road|social> --nodes N --edges M --out FILE\n\
         \x20 loc                           paper §5 DSL vs generated LoC table"
    );
}

/// Output extension for one text backend.
pub fn backend_ext(b: &str) -> &'static str {
    match b {
        "cuda" => "cu",
        "hip" => "hip.cpp",
        "opencl" => "cl.cpp",
        "sycl" => "sycl.cpp",
        "metal" => "metal",
        "wgsl" => "wgsl",
        "planexec" => "planexec.txt",
        _ => "acc.cpp",
    }
}

fn cmd_compile(f: &Flags) -> Result<()> {
    let backend = f.get_or("backend", "cuda");
    let out_dir = PathBuf::from(f.get_or("out", "generated"));
    std::fs::create_dir_all(&out_dir)?;
    if f.positional.is_empty() {
        bail!("compile: no input .sp files");
    }
    for file in &f.positional {
        let path = Path::new(file);
        let fns = parse_file(path)?;
        let tf = check_function(&fns[0]).map_err(|e| {
            let src = std::fs::read_to_string(path).unwrap_or_default();
            anyhow::anyhow!("{}", e.in_file(file).render(&src))
        })?;
        let ir = lower(&tf);
        let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("out");
        match backend.as_str() {
            "jax" => {
                let prog = codegen::jax::generate(&ir)?;
                let py_path = out_dir.join(format!("{}_step.py", prog.algo));
                std::fs::write(&py_path, &prog.python)?;
                let plan_path = out_dir.join(format!("{}.plan.json", prog.algo));
                std::fs::write(&plan_path, prog.plan.to_string())?;
                println!("compiled {file} -> {} + {}", py_path.display(), plan_path.display());
            }
            // every text backend in one invocation (snapshot regeneration,
            // cross-backend diffing); one lowering feeds all seven renders
            "all" => {
                for b in codegen::TEXT_BACKENDS {
                    let src = codegen::generate(b, &ir)?;
                    let out = out_dir.join(format!("{stem}.{}", backend_ext(b)));
                    std::fs::write(&out, src)?;
                    println!("compiled {file} [{b}] -> {}", out.display());
                }
            }
            b => {
                let src = codegen::generate(b, &ir)?;
                let out = out_dir.join(format!("{stem}.{}", backend_ext(b)));
                std::fs::write(&out, src)?;
                println!("compiled {file} -> {}", out.display());
            }
        }
    }
    // ensure the generated dir is a package for python imports
    if backend == "jax" {
        let init = out_dir.join("__init__.py");
        if !init.exists() {
            std::fs::write(init, "# generated by starplat compile --backend jax\n")?;
        }
    }
    Ok(())
}

fn cmd_export_graphs(f: &Flags) -> Result<()> {
    let out_dir = PathBuf::from(f.get_or("out", "artifacts/graphs"));
    let scale = f.usize_or(
        "scale",
        std::env::var("STARPLAT_XLA_SCALE").ok().and_then(|v| v.parse().ok()).unwrap_or(800),
    );
    std::fs::create_dir_all(&out_dir)?;
    let json = crate::coordinator::export_shapes(scale);
    let path = out_dir.join("shapes.json");
    std::fs::write(&path, json.to_string()).context("write shapes.json")?;
    println!("wrote {} (scale {scale})", path.display());
    Ok(())
}

fn cmd_run(f: &Flags) -> Result<()> {
    let algo = f.get_or("algo", "sssp");
    let graph = f.get_or("graph", "RM");
    let backend = f.get_or("backend", "par");
    let scale = f.usize_or("scale", crate::graph::suite::default_scale());
    let sources = f.usize_or("sources", 5);
    let report = crate::coordinator::run_one(&algo, &graph, &backend, scale, sources)?;
    println!("{report}");
    Ok(())
}

fn cmd_stats(f: &Flags) -> Result<()> {
    let scale = f.usize_or("scale", crate::graph::suite::default_scale());
    println!("{}", crate::coordinator::table2(scale).render());
    Ok(())
}

fn cmd_graphgen(f: &Flags) -> Result<()> {
    let kind = f.get_or("kind", "rmat");
    let n = f.usize_or("nodes", 1000);
    let m = f.usize_or("edges", 4000);
    let seed = f.usize_or("seed", 42) as u64;
    let out = PathBuf::from(f.get_or("out", "graph.el"));
    use crate::graph::generators::*;
    let g = match kind.as_str() {
        "rmat" => rmat("rmat", n, m, seed),
        "uniform" => uniform_random("uniform", n, m, seed),
        "road" => {
            let side = (n as f64).sqrt().ceil() as usize;
            road_grid("road", side, side, seed)
        }
        "social" => preferential_attachment("social", n, (m / n).max(1), seed),
        other => bail!("unknown graph kind `{other}`"),
    };
    crate::graph::io::save_edge_list(&g, &out)?;
    println!("wrote {} (|V|={}, |E|={})", out.display(), g.num_nodes(), g.num_edges());
    Ok(())
}

fn cmd_loc(_f: &Flags) -> Result<()> {
    println!("{}", crate::coordinator::loc_table()?.render());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_parse() {
        let args: Vec<String> =
            ["--backend", "cuda", "file.sp", "--out", "dir", "x.sp", "--quick"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let f = Flags::parse(&args);
        assert_eq!(f.get("backend"), Some("cuda"));
        assert_eq!(f.get("out"), Some("dir"));
        assert_eq!(f.get("quick"), Some("true"));
        assert_eq!(f.positional, vec!["file.sp", "x.sp"]);
    }
}
