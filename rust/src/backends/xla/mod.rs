//! XLA backend: the accelerator execution path.
//!
//! Interprets the host plans the DSL compiler emits (fixedPoint / do-while /
//! BFS loop skeletons — paper Figs 9 & 12) against AOT-compiled HLO step
//! artifacts, with the graph packed into padded ELL tiles (DESIGN.md §2).
//!
//! Two execution strategies, toggled by [`Transfer`]:
//! - `LiteralRoundtrip` — naive: state crosses host↔device every iteration
//!   (the un-optimized strawman of the paper's §4);
//! - `DeviceResident` — state stays in PJRT buffers across iterations, only
//!   the finished/diff scalar is read back (the §4.1 optimization; default).

use crate::graph::csr::{Graph, Node};
use crate::graph::ell::EllGraph;
use crate::runtime::{self, Runtime};
use crate::xla_stub as xla;
use anyhow::{bail, Result};

/// Row/width padding must match python/compile/aot.py's shape grid
/// (BLOCK_ROWS in kernels/ell.py).
pub const ROW_PAD: usize = 256;
pub const WIDTH_PAD: usize = 8;

/// INF matching `reference::INF` and kernels/ref.py.
pub const INF: i32 = i32::MAX / 2;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transfer {
    LiteralRoundtrip,
    DeviceResident,
}

pub struct XlaBackend {
    pub rt: Runtime,
    pub transfer: Transfer,
}

impl XlaBackend {
    pub fn open(artifact_dir: &std::path::Path) -> Result<XlaBackend> {
        Ok(XlaBackend { rt: Runtime::open(artifact_dir)?, transfer: Transfer::DeviceResident })
    }

    /// Pack the pull-direction ELL arrays as literals.
    fn ell_in(&self, g: &Graph, n_pad: usize, width: usize) -> Result<[xla::Literal; 3]> {
        let e = EllGraph::from_csr_in(g, ROW_PAD, WIDTH_PAD);
        if e.n_pad != n_pad || e.width != width {
            bail!(
                "ELL shape mismatch: packed ({}, {}) vs artifact ({}, {}) — regenerate artifacts",
                e.n_pad,
                e.width,
                n_pad,
                width
            );
        }
        let idx: Vec<i32> = e.idx.iter().map(|&x| x as i32).collect();
        Ok([
            runtime::lit_i32_2d(&idx, e.n_pad, e.width)?,
            runtime::lit_i32_2d(&e.wgt, e.n_pad, e.width)?,
            runtime::lit_f32_2d(&e.mask, e.n_pad, e.width)?,
        ])
    }

    fn ell_out(&self, g: &Graph, n_pad: usize, width: usize) -> Result<[xla::Literal; 3]> {
        let e = EllGraph::from_csr_out(g, ROW_PAD, WIDTH_PAD);
        if e.n_pad != n_pad || e.width != width {
            bail!("out-ELL shape mismatch ({}, {}) vs ({}, {})", e.n_pad, e.width, n_pad, width);
        }
        let idx: Vec<i32> = e.idx.iter().map(|&x| x as i32).collect();
        Ok([
            runtime::lit_i32_2d(&idx, e.n_pad, e.width)?,
            runtime::lit_i32_2d(&e.wgt, e.n_pad, e.width)?,
            runtime::lit_f32_2d(&e.mask, e.n_pad, e.width)?,
        ])
    }

    /// fixedPoint-relax host plan (SSSP; also CC/BFS with derived inits).
    pub fn run_sssp(&self, short: &str, g: &Graph, src: Node) -> Result<Vec<i32>> {
        let info = self.rt.info("sssp", short)?;
        let exe = self.rt.executable("sssp", short)?;
        let [idx, wgt, mask] = self.ell_in(g, info.n_pad, info.width)?;
        let mut dist = vec![INF; info.n_pad];
        dist[src as usize] = 0;
        let max_iters = g.num_nodes() + 2;
        match self.transfer {
            Transfer::LiteralRoundtrip => {
                let mut dist_lit = runtime::lit_i32_1d(&dist);
                for _ in 0..max_iters {
                    let out =
                        self.rt.execute(&exe, &[dist_lit, idx.clone(), wgt.clone(), mask.clone()])?;
                    let finished = runtime::scalar_to_i32(&out[1])?;
                    dist_lit = out.into_iter().next().unwrap();
                    if finished == 1 {
                        break;
                    }
                }
                let mut v = runtime::to_vec_i32(&dist_lit)?;
                v.truncate(g.num_nodes());
                Ok(v)
            }
            Transfer::DeviceResident => {
                // §4.1: the static graph tiles (the big arrays) are uploaded
                // once and stay device-resident; only the small state vector
                // and the OR-flag word cross per iteration (Fig 12). PJRT
                // returns one tuple buffer per execution, so the state comes
                // back through the tuple literal.
                let idx_b = self.rt.buffer_from_literal(&idx)?;
                let wgt_b = self.rt.buffer_from_literal(&wgt)?;
                let mask_b = self.rt.buffer_from_literal(&mask)?;
                let mut dist_lit = runtime::lit_i32_1d(&dist);
                for _ in 0..max_iters {
                    let dist_buf = self.rt.buffer_from_literal(&dist_lit)?;
                    let out =
                        self.rt.execute_buffers(&exe, &[&dist_buf, &idx_b, &wgt_b, &mask_b])?;
                    let mut tuple = out
                        .into_iter()
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("no output buffer"))?
                        .to_literal_sync()
                        .map_err(|e| anyhow::anyhow!("{e:?}"))?
                        .to_tuple()
                        .map_err(|e| anyhow::anyhow!("{e:?}"))?;
                    let fin = tuple.pop().ok_or_else(|| anyhow::anyhow!("missing flag"))?;
                    dist_lit = tuple.pop().ok_or_else(|| anyhow::anyhow!("missing state"))?;
                    if runtime::scalar_to_i32(&fin)? == 1 {
                        break;
                    }
                }
                let mut v = runtime::to_vec_i32(&dist_lit)?;
                v.truncate(g.num_nodes());
                Ok(v)
            }
        }
    }

    /// do-while-rank host plan (PageRank).
    pub fn run_pr(
        &self,
        short: &str,
        g: &Graph,
        beta: f32,
        damping: f32,
        max_iter: usize,
    ) -> Result<Vec<f32>> {
        let info = self.rt.info("pr", short)?;
        let exe = self.rt.executable("pr", short)?;
        let [idx, _wgt, mask] = self.ell_in(g, info.n_pad, info.width)?;
        let outdeg = EllGraph::out_degrees(g, info.n_pad);
        let n = g.num_nodes();
        let mut pr = vec![0f32; info.n_pad];
        pr[..n].fill(1.0 / n as f32);
        let mut pr_lit = runtime::lit_f32_1d(&pr);
        let outdeg_lit = runtime::lit_f32_1d(&outdeg);
        let delta_lit = runtime::scalar_f32(damping);
        let nn_lit = runtime::scalar_f32(n as f32);
        for _ in 0..max_iter {
            let out = self.rt.execute(
                &exe,
                &[
                    pr_lit,
                    idx.clone(),
                    mask.clone(),
                    outdeg_lit.clone_literal()?,
                    delta_lit.clone_literal()?,
                    nn_lit.clone_literal()?,
                ],
            )?;
            let diff = runtime::scalar_to_f32(&out[1])?;
            pr_lit = out.into_iter().next().unwrap();
            if diff <= beta {
                break;
            }
        }
        let mut v = runtime::to_vec_f32(&pr_lit)?;
        v.truncate(n);
        Ok(v)
    }

    /// BFS-fwd-rev host plan (Brandes BC over a source set).
    pub fn run_bc(&self, short: &str, g: &Graph, sources: &[Node]) -> Result<Vec<f32>> {
        let fwd_info = self.rt.info("bc_fwd", short)?;
        let fwd = self.rt.executable("bc_fwd", short)?;
        let bwd = self.rt.executable("bc_bwd", short)?;
        let [idx_in, _w1, mask_in] = self.ell_in(g, fwd_info.n_pad, fwd_info.width)?;
        let [idx_out, _w2, mask_out] = self.ell_out(g, fwd_info.n_pad, fwd_info.width)?;
        let n_pad = fwd_info.n_pad;
        let n = g.num_nodes();
        let mut bc = vec![0f32; n_pad];
        for &src in sources {
            // forward: host loop over levels (Fig 9)
            let mut level = vec![-1i32; n_pad];
            let mut sigma = vec![0f32; n_pad];
            level[src as usize] = 0;
            sigma[src as usize] = 1.0;
            let mut level_lit = runtime::lit_i32_1d(&level);
            let mut sigma_lit = runtime::lit_f32_1d(&sigma);
            let mut depth = 0i32;
            loop {
                let out = self.rt.execute(
                    &fwd,
                    &[
                        level_lit,
                        sigma_lit,
                        runtime::scalar_i32(depth),
                        idx_in.clone(),
                        mask_in.clone(),
                    ],
                )?;
                let finished = runtime::scalar_to_i32(&out[2])?;
                let mut it = out.into_iter();
                level_lit = it.next().unwrap();
                sigma_lit = it.next().unwrap();
                if finished == 1 {
                    break;
                }
                depth += 1;
                if depth as usize > n + 1 {
                    bail!("BC forward failed to terminate");
                }
            }
            // backward: iterateInReverse — walk the levels backwards
            let mut delta_lit = runtime::lit_f32_1d(&vec![0f32; n_pad]);
            let mut bc_lit = runtime::lit_f32_1d(&bc);
            for d in (0..=depth).rev() {
                let out = self.rt.execute(
                    &bwd,
                    &[
                        level_lit.clone_literal()?,
                        sigma_lit.clone_literal()?,
                        delta_lit,
                        bc_lit,
                        runtime::scalar_i32(d),
                        runtime::scalar_i32(src as i32),
                        idx_out.clone(),
                        mask_out.clone(),
                    ],
                )?;
                let mut it = out.into_iter();
                delta_lit = it.next().unwrap();
                bc_lit = it.next().unwrap();
            }
            bc = runtime::to_vec_f32(&bc_lit)?;
        }
        bc.truncate(n);
        Ok(bc)
    }

    /// dense-matmul-count host plan (TC).
    pub fn run_tc(&self, short: &str, g: &Graph) -> Result<u64> {
        let info = self.rt.info("tc", short)?;
        let exe = self.rt.executable("tc", short)?;
        let nd = info.n_dense;
        let mut adj = vec![0f32; nd * nd];
        for u in 0..g.num_nodes() as Node {
            for &w in g.neighbors(u) {
                adj[u as usize * nd + w as usize] = 1.0;
            }
        }
        let adj_lit = runtime::lit_f32_2d(&adj, nd, nd)?;
        let out = self.rt.execute(&exe, &[adj_lit])?;
        let t = runtime::scalar_to_f32(&out[0])?;
        Ok(t.round() as u64)
    }

    /// bfs-levels host plan.
    pub fn run_bfs(&self, short: &str, g: &Graph, src: Node) -> Result<Vec<i32>> {
        let info = self.rt.info("bfs", short)?;
        let exe = self.rt.executable("bfs", short)?;
        let [idx, _wgt, mask] = self.ell_in(g, info.n_pad, info.width)?;
        let mut level = vec![-1i32; info.n_pad];
        level[src as usize] = 0;
        let mut level_lit = runtime::lit_i32_1d(&level);
        let mut depth = 0i32;
        loop {
            let out = self.rt.execute(
                &exe,
                &[level_lit, runtime::scalar_i32(depth), idx.clone(), mask.clone()],
            )?;
            let finished = runtime::scalar_to_i32(&out[1])?;
            level_lit = out.into_iter().next().unwrap();
            if finished == 1 {
                break;
            }
            depth += 1;
            if depth as usize > g.num_nodes() + 1 {
                bail!("BFS failed to terminate");
            }
        }
        let mut v = runtime::to_vec_i32(&level_lit)?;
        v.truncate(g.num_nodes());
        // unreached stay -1; map to INF for oracle comparisons
        for x in v.iter_mut() {
            if *x < 0 {
                *x = INF;
            }
        }
        Ok(v)
    }

    /// fixedPoint-relax with component-label init (CC).
    pub fn run_cc(&self, short: &str, g: &Graph) -> Result<Vec<i32>> {
        let info = self.rt.info("cc", short)?;
        let exe = self.rt.executable("cc", short)?;
        let e = EllGraph::from_csr_in(g, ROW_PAD, WIDTH_PAD);
        if e.n_pad != info.n_pad || e.width != info.width {
            bail!("CC ELL shape mismatch");
        }
        let idx: Vec<i32> = e.idx.iter().map(|&x| x as i32).collect();
        let zeros = vec![0i32; e.n_pad * e.width];
        let idx_lit = runtime::lit_i32_2d(&idx, e.n_pad, e.width)?;
        let wgt_lit = runtime::lit_i32_2d(&zeros, e.n_pad, e.width)?; // weight-0 min-plus
        let mask_lit = runtime::lit_f32_2d(&e.mask, e.n_pad, e.width)?;
        let mut comp: Vec<i32> = (0..info.n_pad as i32).collect();
        let mut comp_lit = runtime::lit_i32_1d(&comp);
        for _ in 0..g.num_nodes() + 2 {
            let out = self
                .rt
                .execute(&exe, &[comp_lit, idx_lit.clone(), wgt_lit.clone(), mask_lit.clone()])?;
            let finished = runtime::scalar_to_i32(&out[1])?;
            comp_lit = out.into_iter().next().unwrap();
            if finished == 1 {
                break;
            }
        }
        comp = runtime::to_vec_i32(&comp_lit)?;
        comp.truncate(g.num_nodes());
        Ok(comp)
    }
}

/// Helper: literals/buffers are not Clone in the xla crate — add cheap
/// clone-through-host helpers where sharing is needed.
trait CloneLiteral {
    fn clone_literal(&self) -> Result<xla::Literal>;
}
impl CloneLiteral for xla::Literal {
    fn clone_literal(&self) -> Result<xla::Literal> {
        // round-trip through raw bytes
        let shape = self.array_shape().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let dims: Vec<i64> = shape.dims().to_vec();
        let ty = self.ty().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        match ty {
            xla::ElementType::S32 => {
                let v = self.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
                reshape_if(xla::Literal::vec1(&v), &dims)
            }
            xla::ElementType::F32 => {
                let v = self.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
                reshape_if(xla::Literal::vec1(&v), &dims)
            }
            other => bail!("clone_literal: unsupported type {other:?}"),
        }
    }
}

fn reshape_if(l: xla::Literal, dims: &[i64]) -> Result<xla::Literal> {
    if dims.len() <= 1 {
        if dims.is_empty() {
            // scalar: reshape to []
            return l.reshape(&[]).map_err(|e| anyhow::anyhow!("{e:?}"));
        }
        return Ok(l);
    }
    l.reshape(dims).map_err(|e| anyhow::anyhow!("{e:?}"))
}
