//! Execution backends: the CPU interpreter (Seq/Par) and the XLA/PJRT
//! accelerator driver.

pub mod interp;
pub mod xla;
