//! Execution backends: the CPU interpreter (Seq/Par), the plan-level
//! reference executor (the semantic twin of the text codegens), and the
//! XLA/PJRT accelerator driver.

pub mod interp;
pub mod planexec;
pub mod xla;
