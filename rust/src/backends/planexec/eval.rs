//! Expression evaluation over the device plan's simulated state.
//!
//! Unlike the interpreter's compiled form ([`crate::backends::interp::eval`]),
//! planexec evaluates raw [`Expr`] trees — the same trees the text backends
//! spell out via `codegen::cexpr` — against plan-slot buffers. Numeric
//! semantics (promotion, division, short-circuiting) are shared with the
//! interpreter by delegating to [`interp::eval::binop`], so a differential
//! test between the two engines compares *lowering* semantics, never two
//! subtly different arithmetic models.

use crate::backends::interp::env::{PropData, Val, INF_I};
use crate::backends::interp::eval::binop;
use crate::dsl::ast::{BinOp, Expr, UnOp};
use crate::graph::csr::Graph;
use crate::ir::plan::DevicePlan;
use crate::ir::ScalarTy;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::rc::Rc;

/// One evaluation scope: host context (`frame: None`) or a kernel thread
/// (`frame: Some`, with the thread/loop variables bound). Cheap to build per
/// evaluation point; everything inside is a borrow.
pub(crate) struct Scope<'a> {
    pub g: &'a Graph,
    pub plan: &'a DevicePlan,
    /// simulated device buffers, indexed by plan slot
    pub device: &'a [Option<Rc<PropData>>],
    /// host scalars (declared locals + by-value scalar parameters)
    pub scalars: &'a HashMap<String, (ScalarTy, Val)>,
    /// kernel-local bindings (thread var, loop vars, `Decl`s); `None` on host
    pub frame: Option<&'a HashMap<String, Val>>,
    /// edge id of the innermost neighbor iteration (`get_edge` / `edge`)
    pub edge: Option<usize>,
}

impl Scope<'_> {
    /// Variable lookup: kernel frame first (loop vars shadow by-value scalar
    /// parameters, exactly as C block scoping does), then host scalars.
    pub fn var(&self, name: &str) -> Result<Val> {
        if let Some(f) = self.frame {
            if let Some(v) = f.get(name) {
                return Ok(*v);
            }
        }
        self.scalars
            .get(name)
            .map(|(_, v)| *v)
            .ok_or_else(|| anyhow!("unbound variable `{name}`"))
    }

    /// Variable lookup as an element index (node or edge id).
    pub fn index_of(&self, name: &str) -> Result<usize> {
        let i = self.var(name)?.as_i()?;
        if i < 0 {
            bail!("negative index {i} via `{name}`");
        }
        Ok(i as usize)
    }

    fn prop_buf(&self, prop: &str) -> Result<&PropData> {
        let slot = self
            .plan
            .props
            .slot(prop)
            .ok_or_else(|| anyhow!("property `{prop}` has no plan slot"))?;
        self.device[slot as usize]
            .as_deref()
            .ok_or_else(|| anyhow!("device buffer for `{prop}` (slot {slot}) is not allocated"))
    }
}

/// C-cast semantics onto a machine scalar type: the `({ty})` casts the text
/// backends emit at init launches, typed `Decl`s, and scalar declarations.
pub(crate) fn cast_to(st: ScalarTy, v: &Val) -> Val {
    match st {
        ScalarTy::F32 | ScalarTy::F64 => Val::F(match v {
            Val::I(x) => *x as f64,
            Val::F(x) => *x,
            Val::B(b) => *b as i64 as f64,
        }),
        ScalarTy::Bool => Val::B(match v {
            Val::B(b) => *b,
            Val::I(x) => *x != 0,
            Val::F(x) => *x != 0.0,
        }),
        _ => Val::I(match v {
            Val::I(x) => *x,
            Val::F(x) => *x as i64,
            Val::B(b) => *b as i64,
        }),
    }
}

pub(crate) fn eval(e: &Expr, s: &Scope<'_>) -> Result<Val> {
    Ok(match e {
        Expr::IntLit(n) => Val::I(*n),
        Expr::FloatLit(x) => Val::F(*x),
        Expr::BoolLit(b) => Val::B(*b),
        // the C family spells this `(INT_MAX / 2)` — the same halved
        // sentinel as the interpreter's `INF_I`
        Expr::Inf => Val::I(INF_I),
        Expr::Var(v) => s.var(v)?,
        Expr::Prop { obj, prop } => {
            let idx = s.index_of(obj)?;
            let buf = s.prop_buf(prop)?;
            if idx >= buf.len() {
                bail!("`{obj}.{prop}`: index {idx} out of range (len {})", buf.len());
            }
            buf.load(idx)
        }
        Expr::Call { recv, name, args } => eval_call(recv.as_deref(), name, args, s)?,
        Expr::Unary { op, expr } => {
            let v = eval(expr, s)?;
            match op {
                UnOp::Not => Val::B(!v.as_b()?),
                UnOp::Neg => match v {
                    Val::I(x) => Val::I(-x),
                    Val::F(x) => Val::F(-x),
                    Val::B(_) => bail!("cannot negate a bool"),
                },
            }
        }
        Expr::Binary { op, lhs, rhs } => {
            let l = eval(lhs, s)?;
            // short-circuit exactly like the generated `&&` / `||`
            if *op == BinOp::And {
                return Ok(Val::B(l.as_b()? && eval(rhs, s)?.as_b()?));
            }
            if *op == BinOp::Or {
                return Ok(Val::B(l.as_b()? || eval(rhs, s)?.as_b()?));
            }
            let r = eval(rhs, s)?;
            binop(*op, l, r)?
        }
    })
}

fn eval_call(recv: Option<&str>, name: &str, args: &[Expr], s: &Scope<'_>) -> Result<Val> {
    Ok(match (recv, name) {
        (Some(_), "num_nodes") => Val::I(s.g.num_nodes() as i64),
        (Some(_), "num_edges") => Val::I(s.g.num_edges() as i64),
        (Some(r), "outDegree") => {
            let v = s.index_of(r)?;
            Val::I(s.g.out_degree(v as u32) as i64)
        }
        (Some(r), "inDegree") => {
            let v = s.index_of(r)?;
            Val::I(s.g.in_degree(v as u32) as i64)
        }
        (Some(_), "is_an_edge") => {
            // generated code calls the `findNeighborSorted` binary-search
            // helper over the sorted CSR — semantically edge existence
            let u = eval(&args[0], s)?.as_i()?;
            let w = eval(&args[1], s)?.as_i()?;
            Val::B(s.g.is_an_edge(u as u32, w as u32))
        }
        (Some(_), "get_edge") => {
            // neighbor iteration supplies the current edge id (spelled
            // `edge` in generated kernels)
            let e = s.edge.ok_or_else(|| anyhow!("get_edge outside a neighbor iteration"))?;
            Val::I(e as i64)
        }
        (None, "abs") => match eval(&args[0], s)? {
            Val::I(x) => Val::I(x.abs()),
            Val::F(x) => Val::F(x.abs()),
            Val::B(_) => bail!("abs of bool"),
        },
        _ => bail!("unsupported call `{name}` in plan execution"),
    })
}
