//! Kernel-body execution: one simulated device thread at a time.
//!
//! A [`HostOp::Launch`](crate::ir::plan::HostOp::Launch) in the generated
//! code dispatches `V` threads; planexec sweeps them sequentially,
//! `v = 0..V`, executing the plan-carried [`KernelOp`] tree per thread. The
//! op semantics mirror `codegen::body::render_kernel_ops` statement for
//! statement: guard early-outs, the §3.4 BFS-DAG level filter as the *outer*
//! condition of a neighbor loop, §3.5 Min/Max as compare-then-update with
//! win-gated extras and OR-flag clearing, and atomics flattened to
//! sequential read-modify-write (sound because launches are single-threaded
//! here — every generated interleaving of these confluent updates reaches
//! the same fixpoint, which the differential suite checks against the
//! interpreter).

use super::eval::{cast_to, eval, Scope};
use crate::backends::interp::env::{PropData, Val};
use crate::backends::interp::eval::{apply_reduce, binop};
use crate::dsl::ast::{BinOp, MinMax};
use crate::graph::csr::Graph;
use crate::ir::kernel::{KCell, KTarget, KernelBody, KernelOp};
use crate::ir::plan::DevicePlan;
use crate::ir::ScalarTy;
use anyhow::{anyhow, bail, Result};
use std::cell::Cell;
use std::collections::HashMap;
use std::rc::Rc;

/// Everything a launch's threads can see: simulated device buffers, host
/// scalars passed by value, the BFS level buffer (inside a BFS sweep), and
/// the fixedPoint OR-flag word.
pub(crate) struct KernelCtx<'a> {
    pub g: &'a Graph,
    pub plan: &'a DevicePlan,
    pub device: &'a [Option<Rc<PropData>>],
    pub scalars: &'a HashMap<String, (ScalarTy, Val)>,
    /// the enclosing BFS skeleton's level buffer (`gpu_level` in generated
    /// kernels); `None` outside BFS sweeps
    pub levels: Option<&'a PropData>,
    /// the fixedPoint convergence word (`d_finished`); a winning Min/Max
    /// with `or_flag` clears it
    pub flag: &'a Cell<bool>,
}

impl KernelCtx<'_> {
    fn scope<'b>(&'b self, frame: &'b HashMap<String, Val>, edge: Option<usize>) -> Scope<'b> {
        Scope {
            g: self.g,
            plan: self.plan,
            device: self.device,
            scalars: self.scalars,
            frame: Some(frame),
            edge,
        }
    }

    fn buf(&self, slot: u32) -> Result<&PropData> {
        self.device
            .get(slot as usize)
            .and_then(|b| b.as_deref())
            .ok_or_else(|| anyhow!("kernel touches unallocated device slot {slot}"))
    }
}

/// Run one simulated thread of a kernel body: bind the thread variable,
/// apply the guard early-out (`if (!(guard)) return;`), then execute the op
/// tree. `cells` holds the launch's scalar-reduction words.
pub(crate) fn exec_thread(
    cx: &KernelCtx<'_>,
    body: &KernelBody,
    v: usize,
    cells: &mut HashMap<String, Val>,
) -> Result<()> {
    let mut frame: HashMap<String, Val> = HashMap::new();
    frame.insert(body.thread_var.clone(), Val::I(v as i64));
    if let Some(g) = &body.guard {
        if !eval(g, &cx.scope(&frame, None))?.as_b()? {
            return Ok(());
        }
    }
    exec_ops(cx, &body.ops, &mut frame, cells, None)
}

fn exec_ops(
    cx: &KernelCtx<'_>,
    ops: &[KernelOp],
    frame: &mut HashMap<String, Val>,
    cells: &mut HashMap<String, Val>,
    edge: Option<usize>,
) -> Result<()> {
    for op in ops {
        match op {
            KernelOp::Decl { name, ty, init } => {
                let v = match init {
                    Some(e) => cast_to(*ty, &eval(e, &cx.scope(frame, edge))?),
                    None => Val::zero_st(*ty),
                };
                frame.insert(name.clone(), v);
            }
            KernelOp::AssignVar { name, value } => {
                let v = eval(value, &cx.scope(frame, edge))?;
                // C assignment converts to the lvalue's declared kind
                let v = match frame.get(name) {
                    Some(old) => cast_to(val_kind(old), &v),
                    None => v,
                };
                frame.insert(name.clone(), v);
            }
            KernelOp::AssignProp { slot, obj, value } => {
                let (idx, v) = {
                    let s = cx.scope(frame, edge);
                    (s.index_of(obj)?, eval(value, &s)?)
                };
                cx.buf(*slot)?.store(idx, cast_to(cx.plan.props.meta(*slot).ty, &v));
            }
            KernelOp::Reduce { cell, op, ty, value } => {
                let rhs = eval(value, &cx.scope(frame, edge))?;
                match cell {
                    KCell::Cell { name } => {
                        let cur = *cells
                            .get(name)
                            .ok_or_else(|| anyhow!("reduction cell `{name}` not bound"))?;
                        let next = apply_reduce(*op, cur, rhs)?;
                        cells.insert(name.clone(), cast_to(*ty, &next));
                    }
                    KCell::Prop { slot, obj } => {
                        let idx = cx.scope(frame, edge).index_of(obj)?;
                        let buf = cx.buf(*slot)?;
                        let next = apply_reduce(*op, buf.load(idx), rhs)?;
                        buf.store(idx, cast_to(cx.plan.props.meta(*slot).ty, &next));
                    }
                }
            }
            KernelOp::MinMax { kind, slot, obj, ty, compare, extra, or_flag } => {
                // rendered as: `{ty} {prop}_new = compare; if (cur > new) {...}`
                let (idx, proposed) = {
                    let s = cx.scope(frame, edge);
                    (s.index_of(obj)?, cast_to(*ty, &eval(compare, &s)?))
                };
                let buf = cx.buf(*slot)?;
                let cmp = match kind {
                    MinMax::Min => BinOp::Gt,
                    MinMax::Max => BinOp::Lt,
                };
                if binop(cmp, buf.load(idx), proposed)?.as_b()? {
                    buf.store(idx, proposed);
                    for (target, e) in extra {
                        let v = eval(e, &cx.scope(frame, edge))?;
                        match target {
                            KTarget::Var(name) => {
                                let v = match frame.get(name) {
                                    Some(old) => cast_to(val_kind(old), &v),
                                    None => v,
                                };
                                frame.insert(name.clone(), v);
                            }
                            KTarget::Prop { slot, obj } => {
                                let idx = cx.scope(frame, edge).index_of(obj)?;
                                cx.buf(*slot)?
                                    .store(idx, cast_to(cx.plan.props.meta(*slot).ty, &v));
                            }
                        }
                    }
                    if *or_flag {
                        cx.flag.set(false);
                    }
                }
            }
            KernelOp::NeighborLoop { var, of, reverse, bfs, filter, body } => {
                let of_idx = cx.scope(frame, edge).index_of(of)?;
                let (start, end) = if *reverse {
                    (
                        cx.g.rev_offsets[of_idx] as usize,
                        cx.g.rev_offsets[of_idx + 1] as usize,
                    )
                } else {
                    (cx.g.offsets[of_idx] as usize, cx.g.offsets[of_idx + 1] as usize)
                };
                let saved = frame.get(var).copied();
                for i in start..end {
                    let nbr = if *reverse { cx.g.rev_adj[i] } else { cx.g.adj[i] } as usize;
                    frame.insert(var.clone(), Val::I(nbr as i64));
                    // §3.4 BFS-DAG filter, the outer condition: a CSR scan
                    // keeps the children (level(of) + 1), a reverse-CSR pull
                    // keeps the parents (level(of) - 1)
                    if bfs.is_some() {
                        let lv = cx
                            .levels
                            .ok_or_else(|| anyhow!("BFS-DAG filter outside a BFS sweep"))?;
                        let rel = if *reverse { -1 } else { 1 };
                        if lv.load(nbr).as_i()? != lv.load(of_idx).as_i()? + rel {
                            continue;
                        }
                    }
                    if let Some(f) = filter {
                        if !eval(f, &cx.scope(frame, Some(i)))?.as_b()? {
                            continue;
                        }
                    }
                    exec_ops(cx, body, frame, cells, Some(i))?;
                }
                // the loop variable is block-scoped in the rendered kernel
                match saved {
                    Some(v) => frame.insert(var.clone(), v),
                    None => frame.remove(var),
                };
            }
            KernelOp::If { cond, then, els } => {
                if eval(cond, &cx.scope(frame, edge))?.as_b()? {
                    exec_ops(cx, then, frame, cells, edge)?;
                } else if let Some(e) = els {
                    exec_ops(cx, e, frame, cells, edge)?;
                }
            }
            KernelOp::Unsupported { what } => {
                bail!("kernel op unsupported by every backend: {what}")
            }
        }
    }
    Ok(())
}

/// The machine kind a runtime value currently has — used to model C's
/// convert-on-assignment for kernel locals (whose declared width is not
/// tracked past their `Decl`).
fn val_kind(v: &Val) -> ScalarTy {
    match v {
        Val::F(_) => ScalarTy::F64,
        Val::B(_) => ScalarTy::Bool,
        Val::I(_) => ScalarTy::I64,
    }
}
