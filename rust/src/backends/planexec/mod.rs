//! Plan-level reference executor: runs the backend-neutral [`DevicePlan`]
//! the way a generated program would run it.
//!
//! Every text backend (CUDA, OpenCL, SYCL, OpenACC, HIP, Metal, WGSL) is a
//! spelling table over the same lowered artifact: the [`HostOp`] schedule
//! plus plan-carried [`KernelOp`](crate::ir::kernel::KernelOp) bodies. Until
//! now that artifact was only checked *syntactically* (snapshot and
//! conformance tests over the rendered text); whether the lowering it
//! describes actually computes SSSP was untested without a GPU. This module
//! closes that gap: it interprets the plan itself — simulated device buffers
//! keyed by plan slot, the §4.1 transfer protocol, Fig 9/12 loop skeletons,
//! kernel sweeps as sequential `v = 0..V` thread loops — so the lowering's
//! *semantics* differential-test against the AST interpreter
//! ([`crate::backends::interp`]) on every machine (`tests/planexec_parity.rs`).
//!
//! What it models faithfully:
//! - buffer identity by **slot number** (an aliasing bug in the plan's slot
//!   assignment shows up as wrong answers, exactly as it would on device);
//! - the launch protocol: bound H2D copies, scalar-reduction cells seeded
//!   from host scalars and copied back after the launch, deferred D2H left
//!   to the epilogue's outputs-only copy-out;
//! - the fixedPoint skeleton's single OR-flag word and the BFS skeleton's
//!   level expansion / reverse level descent, including the synthetic
//!   level save/restore repair kernels the plan inserts;
//! - the `SchedulePlan` pull twins behind the same runtime direction switch
//!   generated hosts compile in (`STARPLAT_DIRECTION=pull`).
//!
//! What it deliberately does not model: device *concurrency*. A launch runs
//! its threads sequentially, and atomics collapse to plain
//! read-modify-write. The algorithms the DSL targets are confluent — any
//! interleaving reaches the same fixpoint — so the sequential schedule is
//! one of the schedules real hardware could produce, and bit-for-bit parity
//! with the interpreter is exactly the property the differential suite
//! asserts. Floats are f64, like the interpreter oracle (hardware f32
//! backends diverge in precision, not in semantics).

mod eval;
mod kexec;

use crate::backends::interp::env::{PropData, Val};
use crate::backends::interp::eval::apply_reduce;
use crate::backends::interp::{Args, Direction, ExecOpts, ExecStats, Output};
use crate::graph::csr::Graph;
use crate::ir::plan::{DevicePlan, HostOp, HostParam};
use crate::ir::{lower, ScalarTy};
use crate::sema::TypedFunction;
use anyhow::{anyhow, bail, ensure, Result};
use eval::{cast_to, eval as eval_expr, Scope};
use kexec::KernelCtx;
use std::cell::Cell;
use std::collections::HashMap;
use std::rc::Rc;

/// Run a type-checked DSL function by executing its device plan. Direction
/// policy falls back to `STARPLAT_DIRECTION`, mirroring the `getenv` switch
/// compiled into generated hosts.
pub fn run(tf: &TypedFunction, g: &Graph, args: &Args) -> Result<Output> {
    run_with_opts(tf, g, args, ExecOpts::default())
}

/// [`run`] with explicit [`ExecOpts`]. Only the `direction` option is
/// meaningful here — generated programs have no thread-count, frontier,
/// fault, or delta switches, so the executor ignores those fields.
pub fn run_with_opts(tf: &TypedFunction, g: &Graph, args: &Args, opts: ExecOpts) -> Result<Output> {
    let ir = lower(tf);
    let plan = DevicePlan::build(&ir)?;
    run_plan(&plan, g, args, &opts)
}

/// Execute an already-built plan (the parity and coverage tests build plans
/// once and reuse them).
pub fn run_plan(plan: &DevicePlan, g: &Graph, args: &Args, opts: &ExecOpts) -> Result<Output> {
    let mut ex = Exec::new(plan, g, args, opts)?;
    ex.run_ops(&plan.host_ops)?;
    Ok(ex.into_output())
}

/// Host-side control flow: a generated `return` unwinds the whole schedule.
enum Flow {
    Normal,
    Return,
}

/// Runaway guard for `while` / `do-while` loops the plan carries verbatim
/// (fixedPoints get the interpreter's tighter `4V + 16` bound; a plain DSL
/// loop like PageRank's is bounded by its own condition).
const LOOP_CAP: usize = 1_000_000;

struct Exec<'a> {
    g: &'a Graph,
    plan: &'a DevicePlan,
    /// simulated device buffers, by plan slot
    device: Vec<Option<Rc<PropData>>>,
    /// host-side arrays (property parameters; epilogue copy-outs land here)
    host: Vec<Option<Rc<PropData>>>,
    /// host scalars: declared locals and by-value scalar parameters
    scalars: HashMap<String, (ScalarTy, Val)>,
    sets: HashMap<String, Vec<crate::graph::csr::Node>>,
    /// the single fixedPoint OR-flag word (§4.1)
    flag: Cell<bool>,
    /// the host's `STARPLAT_DIRECTION=pull` switch: launches with a pull
    /// twin run it instead of the push body
    use_pull: bool,
    pull_rounds: u64,
    ret: Option<Val>,
}

impl<'a> Exec<'a> {
    fn new(plan: &'a DevicePlan, g: &'a Graph, args: &Args, opts: &ExecOpts) -> Result<Exec<'a>> {
        let mut host: Vec<Option<Rc<PropData>>> = vec![None; plan.props.len()];
        let mut scalars = HashMap::new();
        let mut sets = HashMap::new();
        for p in &plan.host_params {
            match p {
                HostParam::Graph { .. } => {}
                HostParam::Prop { slot } => {
                    let m = plan.props.meta(*slot);
                    // edge-property parameters are the weight array, exactly
                    // as generated mains pass them; node parameters arrive
                    // zeroed like the interpreter's
                    let buf = if m.edge {
                        PropData::from_weights(g)
                    } else {
                        PropData::alloc_st(m.ty, g.num_nodes())
                    };
                    host[*slot as usize] = Some(Rc::new(buf));
                }
                HostParam::Scalar { name, ty } => {
                    let v = args
                        .scalars
                        .get(name)
                        .ok_or_else(|| anyhow!("missing scalar argument `{name}`"))?;
                    scalars.insert(name.clone(), (*ty, cast_to(*ty, v)));
                }
                HostParam::Set { name } => {
                    let vs = args
                        .sets
                        .get(name)
                        .ok_or_else(|| anyhow!("missing SetN argument `{name}`"))?;
                    sets.insert(name.clone(), vs.clone());
                }
            }
        }
        let dir = opts.direction.unwrap_or_else(Direction::from_env);
        Ok(Exec {
            g,
            plan,
            device: vec![None; plan.props.len()],
            host,
            scalars,
            sets,
            flag: Cell::new(true),
            // generated hosts test `getenv("STARPLAT_DIRECTION") == "pull"`;
            // anything else (including Auto) runs the push body
            use_pull: dir == Direction::Pull,
            pull_rounds: 0,
            ret: None,
        })
    }

    fn into_output(self) -> Output {
        let mut props = HashMap::new();
        for (i, h) in self.host.iter().enumerate() {
            if let Some(buf) = h {
                props.insert(self.plan.props.meta(i as u32).name.clone(), clone_buf(buf));
            }
        }
        Output {
            props,
            ret: self.ret,
            stats: ExecStats { pull_rounds: self.pull_rounds, ..ExecStats::default() },
        }
    }

    fn heval(&self, e: &crate::dsl::ast::Expr) -> Result<Val> {
        let s = Scope {
            g: self.g,
            plan: self.plan,
            device: &self.device,
            scalars: &self.scalars,
            frame: None,
            edge: None,
        };
        eval_expr(e, &s)
    }

    fn dev(&self, slot: u32) -> Result<Rc<PropData>> {
        self.device
            .get(slot as usize)
            .and_then(|b| b.clone())
            .ok_or_else(|| anyhow!("device slot {slot} used before its AllocProp"))
    }

    fn elem_count(&self, slot: u32) -> usize {
        if self.plan.props.meta(slot).edge {
            self.g.num_edges()
        } else {
            self.g.num_nodes()
        }
    }

    fn run_ops(&mut self, ops: &[HostOp]) -> Result<Flow> {
        for op in ops {
            if let Flow::Return = self.op(op)? {
                return Ok(Flow::Return);
            }
        }
        Ok(Flow::Normal)
    }

    fn op(&mut self, op: &HostOp) -> Result<Flow> {
        let plan = self.plan;
        match op {
            // pure setup/teardown spellings — nothing to simulate
            HostOp::DeclDims
            | HostOp::GraphToDevice
            | HostOp::LaunchSetup
            | HostOp::AllocFlag
            | HostOp::EpilogueBegin
            | HostOp::FreeFlag
            | HostOp::FreeGraph => {}
            HostOp::AllocProp { slot } => {
                let m = plan.props.meta(*slot);
                self.device[*slot as usize] =
                    Some(Rc::new(PropData::alloc_st(m.ty, self.elem_count(*slot))));
            }
            HostOp::DeclScalar { name, ty, init } => {
                let v = match init {
                    Some(e) => cast_to(*ty, &self.heval(e)?),
                    None => Val::zero_st(*ty),
                };
                self.scalars.insert(name.clone(), (*ty, v));
            }
            HostOp::AssignScalar { name, value } => {
                let v = self.heval(value)?;
                let v = match self.scalars.get(name) {
                    Some((ty, _)) => (*ty, cast_to(*ty, &v)),
                    None => (kind_of(&v), v),
                };
                self.scalars.insert(name.clone(), v);
            }
            HostOp::ReduceScalar { name, op, value } => {
                let rhs = self.heval(value)?;
                let (ty, cur) = *self
                    .scalars
                    .get(name)
                    .ok_or_else(|| anyhow!("reduction into undeclared scalar `{name}`"))?;
                let next = apply_reduce(*op, cur, rhs)?;
                self.scalars.insert(name.clone(), (ty, cast_to(ty, &next)));
            }
            HostOp::CopyProp { dst, src } => {
                // device-to-device memcpy
                copy_buf(&self.dev(*dst)?, &self.dev(*src)?)?;
            }
            HostOp::SetElement { slot, index, value } => {
                // single-element device store (`src.dist = 0`)
                let idx = self
                    .scalars
                    .get(index)
                    .ok_or_else(|| anyhow!("SetElement index `{index}` unbound"))?
                    .1
                    .as_i()?;
                ensure!(idx >= 0, "SetElement index `{index}` is negative");
                let v = self.heval(value)?;
                self.dev(*slot)?
                    .store(idx as usize, cast_to(plan.props.meta(*slot).ty, &v));
            }
            HostOp::InitProps { inits, .. } => {
                // attachNodeProperty: host-evaluated broadcast with a C cast,
                // `initKernel<ty>(len, buf, (ty)value)`
                for (slot, e) in inits {
                    let v = cast_to(plan.props.meta(*slot).ty, &self.heval(e)?);
                    let buf = self.dev(*slot)?;
                    for i in 0..buf.len() {
                        buf.store(i, v);
                    }
                }
            }
            HostOp::Launch { kernel } => self.launch(*kernel)?,
            HostOp::SeqFor { var, set, body } => {
                let items: Vec<i64> = if set == "g.nodes()" {
                    (0..self.g.num_nodes() as i64).collect()
                } else {
                    self.sets
                        .get(set)
                        .ok_or_else(|| anyhow!("sequential loop over unbound set `{set}`"))?
                        .iter()
                        .map(|&n| n as i64)
                        .collect()
                };
                for it in items {
                    self.scalars.insert(var.clone(), (ScalarTy::I32, Val::I(it)));
                    if let Flow::Return = self.run_ops(body)? {
                        return Ok(Flow::Return);
                    }
                }
            }
            HostOp::FixedPoint { var, body, .. } => {
                // Fig 12: host bool mirrors the device OR-flag word each
                // iteration; converged when no launch cleared it
                self.scalars.insert(var.clone(), (ScalarTy::Bool, Val::B(false)));
                let cap = 4 * self.g.num_nodes() + 16;
                let mut iters = 0usize;
                loop {
                    self.scalars.insert(var.clone(), (ScalarTy::Bool, Val::B(true)));
                    self.flag.set(true);
                    if let Flow::Return = self.run_ops(body)? {
                        return Ok(Flow::Return);
                    }
                    let fin = self.flag.get();
                    self.scalars.insert(var.clone(), (ScalarTy::Bool, Val::B(fin)));
                    if fin {
                        break;
                    }
                    iters += 1;
                    ensure!(iters <= cap, "fixedPoint exceeded {cap} iterations");
                }
            }
            HostOp::Bfs { index, from, .. } => self.bfs(*index, from)?,
            HostOp::DoWhile { body, cond } => {
                let mut iters = 0usize;
                loop {
                    if let Flow::Return = self.run_ops(body)? {
                        return Ok(Flow::Return);
                    }
                    if !self.heval(cond)?.as_b()? {
                        break;
                    }
                    iters += 1;
                    ensure!(iters <= LOOP_CAP, "do-while exceeded {LOOP_CAP} iterations");
                }
            }
            HostOp::While { cond, body } => {
                let mut iters = 0usize;
                while self.heval(cond)?.as_b()? {
                    if let Flow::Return = self.run_ops(body)? {
                        return Ok(Flow::Return);
                    }
                    iters += 1;
                    ensure!(iters <= LOOP_CAP, "while exceeded {LOOP_CAP} iterations");
                }
            }
            HostOp::If { cond, then, els } => {
                let taken = if self.heval(cond)?.as_b()? {
                    then
                } else {
                    match els {
                        Some(e) => e,
                        None => return Ok(Flow::Normal),
                    }
                };
                return self.run_ops(taken);
            }
            HostOp::Return { value } => {
                self.ret = Some(self.heval(value)?);
                return Ok(Flow::Return);
            }
            HostOp::Unsupported { what } => {
                bail!("host construct generated code cannot express: {what}")
            }
            HostOp::CopyOut { slot } => {
                let dst = self.host_buf(*slot);
                copy_buf(&dst, &self.dev(*slot)?)?;
            }
            HostOp::FreeProp { slot } => self.device[*slot as usize] = None,
        }
        Ok(Flow::Normal)
    }

    /// Host array for a slot, created on first use (parameters preexist).
    fn host_buf(&mut self, slot: u32) -> Rc<PropData> {
        let len = self.elem_count(slot);
        let m = self.plan.props.meta(slot);
        self.host[slot as usize]
            .get_or_insert_with(|| Rc::new(PropData::alloc_st(m.ty, len)))
            .clone()
    }

    /// One `forall` launch: the full §4.1 protocol around a sequential
    /// thread sweep.
    fn launch(&mut self, kernel: usize) -> Result<()> {
        let plan = self.plan;
        let k = &plan.kernels[kernel];
        // bound H2D copies
        for &slot in &k.copy_in {
            let src = self.host[slot as usize]
                .clone()
                .ok_or_else(|| anyhow!("copy-in of slot {slot} with no host array"))?;
            copy_buf(&self.dev(slot)?, &src)?;
        }
        // scalar-reduction cells seeded from the current host scalars
        let mut cells: HashMap<String, Val> = HashMap::new();
        for (name, _, ty) in &k.reductions {
            let cur = self
                .scalars
                .get(name)
                .map(|(_, v)| *v)
                .unwrap_or_else(|| Val::zero_st(*ty));
            cells.insert(name.clone(), cast_to(*ty, &cur));
        }
        // the host-side direction switch: run the pull twin when compiled in
        // and selected, else the push body
        let pull = self.use_pull && k.pull_body.is_some();
        let body = if pull {
            k.pull_body.as_ref().unwrap()
        } else {
            k.body
                .as_ref()
                .ok_or_else(|| anyhow!("kernel {} has no body to launch", k.name))?
        };
        {
            let cx = KernelCtx {
                g: self.g,
                plan,
                device: &self.device,
                scalars: &self.scalars,
                levels: None,
                flag: &self.flag,
            };
            for v in 0..self.g.num_nodes() {
                kexec::exec_thread(&cx, body, v, &mut cells)?;
            }
        }
        if pull {
            self.pull_rounds += 1;
        }
        // cells return to their host scalars
        for (name, _, _) in &k.reductions {
            let v = cells.remove(name).expect("cell seeded above");
            let ty = self.scalars.get(name).map(|(t, _)| *t).unwrap_or(kind_of(&v));
            self.scalars.insert(name.clone(), (ty, cast_to(ty, &v)));
        }
        // bound D2H copies — unless deferred, in which case the epilogue's
        // outputs-only copy-out is the only return path (generated loops
        // never flush mid-stream)
        if !k.defer_to_loop_exit {
            for &slot in &k.copy_out {
                let dst = self.host_buf(slot);
                copy_buf(&dst, &self.dev(slot)?)?;
            }
        }
        Ok(())
    }

    /// The Fig 9 `iterateInBFS` skeleton: level-synchronous forward
    /// expansion over an explicit level buffer, then the optional reverse
    /// sweep walking the levels back down.
    fn bfs(&mut self, index: usize, from: &str) -> Result<()> {
        let plan = self.plan;
        let b = &plan.bfs_loops[index];
        let vcount = self.g.num_nodes();
        let src = self
            .scalars
            .get(from)
            .ok_or_else(|| anyhow!("BFS source `{from}` unbound"))?
            .1
            .as_i()?;
        ensure!(
            (0..vcount as i64).contains(&src),
            "BFS source `{from}` = {src} out of range (V = {vcount})"
        );
        // a declared `level` property doubles as the skeleton's buffer;
        // otherwise the skeleton allocates an implicit one at the site (BC)
        let lvl: Rc<PropData> = match b.level {
            Some(slot) => self.dev(slot)?,
            None => Rc::new(PropData::alloc_st(ScalarTy::I32, vcount)),
        };
        for i in 0..vcount {
            lvl.store(i, Val::I(-1));
        }
        lvl.store(src as usize, Val::I(0));

        let fwd = &plan.kernels[b.fwd];
        ensure!(
            fwd.reductions.is_empty(),
            "BFS sweep kernels with scalar reductions are not modeled"
        );
        let fwd_body =
            fwd.body.as_ref().ok_or_else(|| anyhow!("BFS forward kernel has no body"))?;
        let mut hops: i64 = 0;
        loop {
            let mut finished = true;
            for v in 0..vcount {
                if lvl.load(v).as_i()? != hops {
                    continue;
                }
                // discovery first, then the sweep body — the generated
                // kernel's statement order
                for i in self.g.edge_range(v as u32) {
                    let nbr = self.g.adj[i] as usize;
                    if lvl.load(nbr).as_i()? == -1 {
                        lvl.store(nbr, Val::I(hops + 1));
                        finished = false;
                    }
                }
                let cx = KernelCtx {
                    g: self.g,
                    plan,
                    device: &self.device,
                    scalars: &self.scalars,
                    levels: Some(&*lvl),
                    flag: &self.flag,
                };
                let mut cells = HashMap::new();
                kexec::exec_thread(&cx, fwd_body, v, &mut cells)?;
            }
            hops += 1;
            if finished {
                break;
            }
        }

        if let Some(rk) = b.rev {
            let rev = &plan.kernels[rk];
            ensure!(
                rev.reductions.is_empty(),
                "BFS sweep kernels with scalar reductions are not modeled"
            );
            let rev_body =
                rev.body.as_ref().ok_or_else(|| anyhow!("BFS reverse kernel has no body"))?;
            // the skeleton re-descends from the depth counter where the
            // forward loop left it (one past the deepest level)
            let mut h = hops;
            while h >= 0 {
                for v in 0..vcount {
                    if lvl.load(v).as_i()? != h {
                        continue;
                    }
                    let cx = KernelCtx {
                        g: self.g,
                        plan,
                        device: &self.device,
                        scalars: &self.scalars,
                        levels: Some(&*lvl),
                        flag: &self.flag,
                    };
                    let mut cells = HashMap::new();
                    kexec::exec_thread(&cx, rev_body, v, &mut cells)?;
                }
                h -= 1;
            }
        }
        Ok(())
    }
}

/// The machine kind a runtime value currently has (declared types are used
/// wherever the plan records them; this is the fallback for undeclared
/// bindings).
fn kind_of(v: &Val) -> ScalarTy {
    match v {
        Val::F(_) => ScalarTy::F64,
        Val::B(_) => ScalarTy::Bool,
        Val::I(_) => ScalarTy::I64,
    }
}

/// Element-wise buffer copy (the simulated `memcpy`).
fn copy_buf(dst: &PropData, src: &PropData) -> Result<()> {
    ensure!(
        dst.len() == src.len(),
        "buffer copy length mismatch ({} vs {})",
        dst.len(),
        src.len()
    );
    for i in 0..src.len() {
        dst.store(i, src.load(i));
    }
    Ok(())
}

fn clone_buf(src: &PropData) -> PropData {
    let dst = match src {
        PropData::I(_) => PropData::alloc_st(ScalarTy::I64, src.len()),
        PropData::F(_) => PropData::alloc_st(ScalarTy::F64, src.len()),
        PropData::B(_) => PropData::alloc_st(ScalarTy::Bool, src.len()),
    };
    for i in 0..src.len() {
        dst.store(i, src.load(i));
    }
    dst
}
