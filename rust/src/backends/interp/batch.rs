//! Batched multi-source execution: many roots, one traversal.
//!
//! The service's traffic shape is thousands of BFS/SSSP requests against a
//! handful of registered graphs, differing only in the root argument. Run
//! independently, each request streams the whole CSR through the cache
//! once; batched, up to 64 roots share every adjacency-row read:
//!
//! - **MS-BFS** ([`BatchPlan::BfsLevels`]): per-vertex `u64` bitmasks carry
//!   one lane per root. `visited[w]` accumulates the lanes that have
//!   reached `w`; a frontier vertex offers its whole frontier mask to each
//!   neighbor in one `fetch_or`, and the bits that come back *new* assign
//!   that lane's level. Discovery is level-synchronous, so every lane's
//!   levels are exactly its single-source run's levels. Larger batches
//!   tile in waves of ≤ 64 lanes.
//! - **k-lane relaxation** ([`BatchPlan::KLane`]): the distance property
//!   becomes k row-major lanes (one contiguous row per root) and per-vertex
//!   `u64` active masks replace the bool flag ping-pong. One edge scan
//!   CAS-mins every active lane; convergence is the all-lanes-quiet
//!   fixpoint, which for the idempotent monotone Min relaxation is the same
//!   unique fixpoint each single-source run reaches. (Min-label CC has no
//!   root parameter; the service deduplicates those through the result
//!   cache instead.)
//!
//! Programs that match neither shape — and roots that are out of range —
//! run as ordinary independent [`super::run_with_opts`] calls, so
//! [`run_batch_with_opts`] is *always* bit-for-bit faithful per root; the
//! recognizers only decide how much sharing is safe. A `claim_gather`
//! fault firing mid-wave abandons that wave the same way the sparse
//! frontier schedule degrades: the wave's roots re-run independently (each
//! run carrying the proven sparse→dense fallback machinery), counted in
//! [`super::ExecStats::fallbacks`].
//!
//! Lane width comes from [`super::ExecOpts::batch`], falling back to the
//! `STARPLAT_BATCH` environment knob (clamped to 1..=64; default 64).

use super::compile::{self, CExpr, DevIter, DevStmt, HostStmt, Idx, ParamBind, Program};
use super::env::PropData;
use super::{frontier_par_min, run_with_opts, Args, ExecError, ExecOpts, ExecStats, Output};
use crate::dsl::ast::BinOp;
use crate::graph::csr::{Graph, Node};
use crate::ir::ScalarTy;
use crate::sema::TypedFunction;
use crate::util::cancel::CancelToken;
use crate::util::fault::{FaultPlan, FaultSite};
use crate::util::pool::{self, Arena};
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

/// Hard lane ceiling: one bit per root in the per-vertex `u64` masks.
/// Batches beyond it tile in waves.
pub const MAX_LANES: usize = 64;

/// One distance/level row per lane, row-major: `rows[lane][vertex]`.
type LaneRows = Vec<Vec<AtomicI64>>;

/// Effective lane width: explicit [`ExecOpts::batch`], else the
/// `STARPLAT_BATCH` environment knob (cached on first read), else 64.
/// Always clamped to 1..=[`MAX_LANES`].
pub fn batch_width(opts: &ExecOpts) -> usize {
    static ENV: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    let env = *ENV.get_or_init(|| {
        std::env::var("STARPLAT_BATCH")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&w| w > 0)
            .unwrap_or(MAX_LANES)
    });
    opts.batch.unwrap_or(env).clamp(1, MAX_LANES)
}

// ---------------------------------------------------------------------------
// Shape recognition
// ---------------------------------------------------------------------------

/// How a compiled program may be batched across roots. Both shapes require
/// that the *entire* observable output is reconstructible per lane: every
/// property of the program is either the batched one, a flag pair that ends
/// all-false, or the graph's own edge weights.
#[derive(Clone, Copy, Debug)]
pub enum BatchPlan {
    /// `iterateInBFS` level assignment (bfs.sp): attach `level = init`,
    /// seed `root.level = root_val`, then each BFS-DAG child stores
    /// `parent.level + step`. Level-synchronous ⇒ `level(w) = root_val +
    /// depth(w) · step`, reproducible from MS-BFS discovery alone.
    BfsLevels { level: u32, init: i64, root_val: i64, step: i64 },
    /// Canonical relaxation fixedPoint (sssp.sp): attach `dist = init` and
    /// both flags false, seed `root.{flag, dist}`, relax to the Min
    /// fixpoint. `weight == None` means weight-free relaxation (adds 0).
    KLane { dist: u32, weight: Option<u32>, init: i64, root_val: i64 },
}

/// The scalar slot bound to `root_param`, if the program declares it.
fn root_slot(prog: &Program, root_param: &str) -> Option<u32> {
    prog.params.iter().find_map(|p| match p {
        ParamBind::Scalar { name, slot, .. } if name == root_param => Some(*slot),
        _ => None,
    })
}

fn const_i(e: &CExpr) -> Option<i64> {
    match e {
        CExpr::ConstI(x) => Some(*x),
        _ => None,
    }
}

/// `LoadProp(prop, Reg(reg)) + ConstI(step)` in either operand order.
fn level_step(e: &CExpr, prop: u32, reg: u32) -> Option<i64> {
    let CExpr::Binary { op: BinOp::Add, lhs, rhs } = e else { return None };
    let is_load = |e: &CExpr| {
        matches!(e, CExpr::LoadProp { prop: p, idx: Idx::Reg(r) } if *p == prop && *r == reg)
    };
    match (&**lhs, &**rhs) {
        (l, r) if is_load(l) => const_i(r),
        (l, r) if is_load(r) => const_i(l),
        _ => None,
    }
}

/// Recognize the batched-BFS shape: exactly
/// `[Attach{level = init}, root.level = root_val, iterateInBFS{...}]` whose
/// BFS body is one DAG-children loop storing `parent.level + step`.
fn recognize_bfs(prog: &Program, root: u32) -> Option<BatchPlan> {
    let [HostStmt::Attach { inits }, HostStmt::PropElemStore { prop, obj, value }, HostStmt::IterateBFS { reg, from, body, reverse: None, .. }] =
        prog.body.as_slice()
    else {
        return None;
    };
    let [(level, init_e)] = inits.as_slice() else { return None };
    let init = const_i(init_e)?;
    if *prop != *level || *obj != root || *from != root {
        return None;
    }
    let root_val = const_i(value)?;
    let [DevStmt::For { reg: w, source, filter: None, body: fbody }] = body.as_slice() else {
        return None;
    };
    let DevIter::Neighbors { of: Idx::Reg(of), dag: true } = source else { return None };
    if *of != *reg {
        return None;
    }
    let [DevStmt::PropStore { prop, idx: Idx::Reg(widx), value }] = fbody.as_slice() else {
        return None;
    };
    if *prop != *level || *widx != *w {
        return None;
    }
    let step = level_step(value, *level, *reg)?;
    // the level property must be the program's only property: the engine
    // reconstructs the whole Output per lane
    if prog.props.len() != 1 || prog.props[*level as usize].edge {
        return None;
    }
    Some(BatchPlan::BfsLevels { level: *level, init, root_val, step })
}

/// Recognize the k-lane relaxation shape: a prefix of pure declarations,
/// one attach covering `{dist = init, flag = false, nxt = false}`, the two
/// root seeds, then a trailing frontier-eligible relaxation fixedPoint —
/// and no other properties anywhere.
fn recognize_klane(prog: &Program, root: u32) -> Option<BatchPlan> {
    let body = prog.body.as_slice();
    let HostStmt::FixedPoint { flag, frontier: Some(fi), .. } = body.last()? else {
        return None;
    };
    let r = fi.relax?;
    // push-only writes: the engine's edge scan walks the forward CSR
    if !fi.gather_out || fi.gather_in || fi.flag != *flag {
        return None;
    }
    if body.len() < 4 {
        return None;
    }
    // the two root seeds, in either order
    let seeds = &body[body.len() - 3..body.len() - 1];
    let seed = |prop: u32| {
        seeds.iter().find_map(|s| match s {
            HostStmt::PropElemStore { prop: p, obj, value } if *p == prop && *obj == root => {
                Some(value)
            }
            _ => None,
        })
    };
    if !matches!(seed(fi.flag)?, CExpr::ConstB(true)) {
        return None;
    }
    let root_val = const_i(seed(r.dist)?)?;
    // one attach covering exactly {dist, flag, nxt}
    let HostStmt::Attach { inits } = &body[body.len() - 4] else { return None };
    if inits.len() != 3 {
        return None;
    }
    let attach = |prop: u32| inits.iter().find_map(|(p, e)| (*p == prop).then_some(e));
    let init = const_i(attach(r.dist)?)?;
    for flagp in [fi.flag, fi.nxt] {
        if !matches!(attach(flagp)?, CExpr::ConstB(false)) {
            return None;
        }
    }
    // prefix: declarations only, whose effects are invisible in the Output
    for s in &body[..body.len() - 4] {
        match s {
            HostStmt::AllocProp { .. } => {}
            HostStmt::DeclScalar { init: None, .. } => {}
            HostStmt::DeclScalar {
                init: Some(CExpr::ConstI(_) | CExpr::ConstF(_) | CExpr::ConstB(_)),
                ..
            } => {}
            _ => return None,
        }
    }
    // every property must be reconstructible per lane: the dist lanes, the
    // all-false flag pair, or the graph's own (param-bound) edge weights
    for (slot, meta) in prog.props.iter().enumerate() {
        let slot = slot as u32;
        let ok = (slot == r.dist && !meta.edge)
            || (slot == fi.flag && !meta.edge)
            || (slot == fi.nxt && !meta.edge)
            || (Some(slot) == r.weight && meta.edge && meta.param);
        if !ok {
            return None;
        }
    }
    Some(BatchPlan::KLane { dist: r.dist, weight: r.weight, init, root_val })
}

/// Recognize either batchable shape (BFS first — it is the more specific).
pub fn recognize(prog: &Program, root_param: &str) -> Option<BatchPlan> {
    let root = root_slot(prog, root_param)?;
    if !prog.sets.is_empty() {
        return None;
    }
    recognize_bfs(prog, root).or_else(|| recognize_klane(prog, root))
}

// ---------------------------------------------------------------------------
// Wave engines
// ---------------------------------------------------------------------------

/// Shared per-wave execution context — the `Env`-free analog of the pieces
/// the single-run engines read.
struct Wave<'g> {
    g: &'g Graph,
    threads: usize,
    par_min: usize,
    cancel: Option<CancelToken>,
    fault: Option<FaultPlan>,
    /// recycled claim buffers, same role as `Env::buf_arena`
    arena: Arena<Vec<Node>>,
}

impl Wave<'_> {
    fn check_cancel(&self) -> Result<()> {
        if let Some(c) = &self.cancel {
            if let Some(i) = c.interrupted() {
                return Err(anyhow::Error::new(ExecError::from(i)));
            }
        }
        Ok(())
    }

    /// The same injected-fault site the sparse gather polls, keyed by the
    /// wave's round index — a firing abandons the wave for per-root runs.
    fn fault_fires(&self, round: u64) -> bool {
        self.fault.is_some_and(|fp| fp.fires(FaultSite::ClaimGather, round))
    }

    /// Claim-buffer collect over `list`, sequential under the same
    /// small-frontier cutover the sparse gather uses.
    fn collect(
        &self,
        list: &[Node],
        emit: impl Fn(usize, &mut Vec<Node>) + Sync,
    ) -> Result<Vec<Node>> {
        if self.threads > 1 && list.len() >= self.par_min {
            pool::try_parallel_collect_in(
                list.len(),
                self.threads,
                64,
                self.cancel.as_ref(),
                &self.arena,
                emit,
            )
            .map_err(super::pool_err)
        } else {
            let mut out = self.arena.take().unwrap_or_default();
            out.clear();
            for i in 0..list.len() {
                emit(i, &mut out);
            }
            Ok(out)
        }
    }
}

/// CAS-min on one lane cell; `true` iff `cand` strictly improved it (the
/// same contract as `PropData::atomic_min_max`).
#[inline]
fn atomic_min(cell: &AtomicI64, cand: i64) -> bool {
    let mut cur = cell.load(Ordering::Relaxed);
    while cand < cur {
        match cell.compare_exchange_weak(cur, cand, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return true,
            Err(seen) => cur = seen,
        }
    }
    false
}

/// One wave of MS-BFS: ≤ 64 roots swept level-synchronously through shared
/// bitmask frontiers. Returns one level row per lane; `Ok(None)` when an
/// injected fault abandons the wave (the caller re-runs its roots
/// independently); `Err` on interrupt.
fn ms_bfs_wave(
    w: &Wave<'_>,
    init: i64,
    root_val: i64,
    step: i64,
    roots: &[Node],
) -> Result<Option<LaneRows>> {
    let g = w.g;
    let n = g.num_nodes();
    let rows: LaneRows =
        roots.iter().map(|_| (0..n).map(|_| AtomicI64::new(init)).collect()).collect();
    let visited: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    let fmask: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    let nmask: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    let mut worklist: Vec<Node> = Vec::new();
    for (r, &root) in roots.iter().enumerate() {
        let v = root as usize;
        rows[r][v].store(root_val, Ordering::Relaxed);
        visited[v].fetch_or(1 << r, Ordering::Relaxed);
        // duplicate roots share one worklist entry
        if fmask[v].fetch_or(1 << r, Ordering::Relaxed) == 0 {
            worklist.push(root);
        }
    }
    let mut depth: i64 = 0;
    while !worklist.is_empty() {
        w.check_cancel()?;
        if w.fault_fires(depth as u64) {
            return Ok(None);
        }
        // every lane discovered this round lands on the same level value:
        // MS-BFS is level-synchronous, so depth alone determines it
        let lvl = root_val + (depth + 1) * step;
        let rows = &rows;
        let visited = &visited;
        let fmask_r = &fmask;
        let nmask_r = &nmask;
        let worklist_ref = &worklist;
        let expand = move |i: usize, out: &mut Vec<Node>| {
            let v = worklist_ref[i];
            let fv = fmask_r[v as usize].load(Ordering::Relaxed);
            for &t in g.neighbors(v) {
                let ti = t as usize;
                let cand = fv & !visited[ti].load(Ordering::Relaxed);
                if cand == 0 {
                    continue;
                }
                // fetch_or hands each lane's first discovery of `t` to
                // exactly one worker — the winner assigns that lane's level
                let prev = visited[ti].fetch_or(cand, Ordering::Relaxed);
                let claim = cand & !prev;
                if claim == 0 {
                    continue;
                }
                let mut new = claim;
                while new != 0 {
                    let r = new.trailing_zeros() as usize;
                    new &= new - 1;
                    rows[r][ti].store(lvl, Ordering::Relaxed);
                }
                // exclusive worklist claim: the claim_true idiom widened to
                // the whole mask
                if nmask_r[ti].fetch_or(claim, Ordering::Relaxed) == 0 {
                    out.push(t);
                }
            }
        };
        let next = w.collect(&worklist, expand)?;
        // hand the frontier over: clear the old masks fully *before*
        // installing the new (a vertex can sit in consecutive frontiers
        // when different lanes reach it at different depths)
        for &v in &worklist {
            fmask[v as usize].store(0, Ordering::Relaxed);
        }
        for &v in &next {
            let vi = v as usize;
            fmask[vi].store(nmask[vi].swap(0, Ordering::Relaxed), Ordering::Relaxed);
        }
        w.arena.put(std::mem::replace(&mut worklist, next));
        depth += 1;
    }
    Ok(Some(rows))
}

/// One wave of k-lane relaxation: ≤ 64 lanes of the distance property
/// relaxed by a single edge scan per round. Same return contract as
/// [`ms_bfs_wave`].
fn klane_wave(
    w: &Wave<'_>,
    init: i64,
    root_val: i64,
    weighted: bool,
    roots: &[Node],
) -> Result<Option<LaneRows>> {
    let g = w.g;
    let n = g.num_nodes();
    let rows: LaneRows =
        roots.iter().map(|_| (0..n).map(|_| AtomicI64::new(init)).collect()).collect();
    let active: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    let nmask: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    let mut worklist: Vec<Node> = Vec::new();
    for (r, &root) in roots.iter().enumerate() {
        let v = root as usize;
        rows[r][v].store(root_val, Ordering::Relaxed);
        if active[v].fetch_or(1 << r, Ordering::Relaxed) == 0 {
            worklist.push(root);
        }
    }
    let max_iters = 4 * n + 16;
    for round in 0..max_iters {
        if worklist.is_empty() {
            return Ok(Some(rows));
        }
        w.check_cancel()?;
        if w.fault_fires(round as u64) {
            return Ok(None);
        }
        let rows = &rows;
        let active_r = &active;
        let nmask_r = &nmask;
        let worklist_ref = &worklist;
        let relax = move |i: usize, out: &mut Vec<Node>| {
            let v = worklist_ref[i] as usize;
            let av = active_r[v].load(Ordering::Relaxed);
            for e in g.edge_range(v as Node) {
                let t = g.adj[e] as usize;
                let we = if weighted { g.weights[e] as i64 } else { 0 };
                let mut bits = av;
                while bits != 0 {
                    let r = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    let cand = rows[r][v].load(Ordering::Relaxed).saturating_add(we);
                    if atomic_min(&rows[r][t], cand)
                        && nmask_r[t].fetch_or(1 << r, Ordering::Relaxed) == 0
                    {
                        out.push(t as Node);
                    }
                }
            }
        };
        let next = w.collect(&worklist, relax)?;
        for &v in &worklist {
            active[v as usize].store(0, Ordering::Relaxed);
        }
        for &v in &next {
            let vi = v as usize;
            active[vi].store(nmask[vi].swap(0, Ordering::Relaxed), Ordering::Relaxed);
        }
        w.arena.put(std::mem::replace(&mut worklist, next));
    }
    bail!("fixedPoint did not converge after {max_iters} iterations")
}

// ---------------------------------------------------------------------------
// Output reconstruction and the driver
// ---------------------------------------------------------------------------

/// Assemble one lane's [`Output`]: the batched property row plus the
/// invariant rest (all-false flag pair, graph edge weights).
fn lane_output(
    prog: &Program,
    g: &Graph,
    plan: &BatchPlan,
    row: Vec<AtomicI64>,
    wave_k: usize,
) -> Output {
    let n = g.num_nodes();
    let batched = match plan {
        BatchPlan::BfsLevels { level, .. } => *level,
        BatchPlan::KLane { dist, .. } => *dist,
    };
    let mut props = std::collections::HashMap::new();
    let mut row = Some(row);
    for (slot, meta) in prog.props.iter().enumerate() {
        let data = if slot as u32 == batched {
            PropData::I(row.take().expect("one batched row per lane"))
        } else if meta.edge {
            PropData::from_weights(g)
        } else {
            // converged flag pair: all-false, exactly the fixpoint exit
            PropData::alloc_st(ScalarTy::Bool, n)
        };
        props.insert(meta.name.clone(), data);
    }
    let stats = ExecStats { batched_roots: wave_k as u64, ..ExecStats::default() };
    Output { props, ret: None, stats }
}

/// `base` with the root parameter rebound — the arguments an independent
/// (fallback) run of one root needs.
fn args_with_root(base: &Args, root_param: &str, root: Node) -> Args {
    base.clone().node(root_param, root)
}

/// Execute the program once per root, sharing CSR traversals across roots
/// where the compiled shape allows it (waves of ≤ [`batch_width`] lanes).
/// Results align positionally with `roots`, and each is bit-for-bit equal
/// to [`run_with_opts`] with that root bound — unbatchable programs,
/// out-of-range roots, and fault-abandoned waves all take the independent
/// path, so the equivalence holds unconditionally.
pub fn run_batch_with_opts(
    tf: &TypedFunction,
    g: &Graph,
    base_args: &Args,
    root_param: &str,
    roots: &[Node],
    opts: &ExecOpts,
) -> Vec<Result<Output>> {
    let mut results: Vec<Option<Result<Output>>> = roots.iter().map(|_| None).collect();
    let fallback = |root: Node| -> Result<Output> {
        run_with_opts(tf, g, &args_with_root(base_args, root_param, root), opts.clone())
    };
    let plan = compile::compile(tf)
        .ok()
        .and_then(|prog| recognize(&prog, root_param).map(|plan| (prog, plan)));
    let Some((prog, plan)) = plan else {
        return roots.iter().map(|&root| fallback(root)).collect();
    };
    let n = g.num_nodes();
    let threads = if opts.threads == 0 { pool::default_threads() } else { opts.threads }.max(1);
    let wave = Wave {
        g,
        threads,
        par_min: opts.frontier_par_min.unwrap_or_else(frontier_par_min),
        cancel: opts.cancel.clone(),
        fault: opts.fault.or_else(FaultPlan::from_env),
        arena: Arena::new(),
    };
    // engine-eligible roots batch in waves; out-of-range roots surface the
    // same error their independent run would
    let mut in_range: Vec<usize> = Vec::new();
    for (i, &root) in roots.iter().enumerate() {
        if (root as usize) < n {
            in_range.push(i);
        } else {
            results[i] = Some(fallback(root));
        }
    }
    let width = batch_width(opts);
    'waves: for chunk in in_range.chunks(width) {
        let wave_roots: Vec<Node> = chunk.iter().map(|&i| roots[i]).collect();
        let ran = match plan {
            BatchPlan::BfsLevels { init, root_val, step, .. } => {
                ms_bfs_wave(&wave, init, root_val, step, &wave_roots)
            }
            BatchPlan::KLane { init, root_val, weight, .. } => {
                klane_wave(&wave, init, root_val, weight.is_some(), &wave_roots)
            }
        };
        match ran {
            Ok(Some(rows)) => {
                let wave_k = wave_roots.len();
                for (lane, row) in rows.into_iter().enumerate() {
                    results[chunk[lane]] = Some(Ok(lane_output(&prog, g, &plan, row, wave_k)));
                }
            }
            // injected fault: degrade this wave to independent runs (each
            // carrying its own sparse→dense fallback machinery) and count
            // the abandonment the way the sparse schedule does
            Ok(None) => {
                for &i in chunk {
                    results[i] = Some(fallback(roots[i]).map(|mut out| {
                        out.stats.fallbacks += 1;
                        out
                    }));
                }
            }
            // an interrupt poisons this wave and every wave after it, the
            // same way it stops a single run mid-request
            Err(e) => {
                let typed = e.downcast_ref::<ExecError>().cloned();
                let mut original = Some(e);
                for &i in &in_range {
                    if results[i].is_none() {
                        results[i] = Some(Err(match (original.take(), &typed) {
                            (Some(e), _) => e,
                            (None, Some(te)) => anyhow::Error::new(te.clone()),
                            (None, None) => anyhow!("batched wave interrupted"),
                        }));
                    }
                }
                break 'waves;
            }
        }
    }
    results
        .into_iter()
        .map(|r| r.expect("every root resolved by wave, fallback, or interrupt"))
        .collect()
}
