//! Runtime state for the interpreter: property arrays (atomic, shared across
//! worker threads) and host scalars.

use crate::dsl::ast::{MinMax, ReduceOp, Type};
use crate::graph::csr::{Graph, Node};
use crate::sema::TypedFunction;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};

/// A runtime scalar value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Val {
    I(i64),
    F(f64),
    B(bool),
}

/// The DSL's INF sentinel (safe for additive arithmetic).
pub const INF_I: i64 = crate::algorithms::reference::INF as i64;

impl Val {
    pub fn as_i(&self) -> Result<i64> {
        match self {
            Val::I(v) => Ok(*v),
            Val::F(v) => Ok(*v as i64),
            Val::B(_) => bail!("expected a number, got bool"),
        }
    }
    pub fn as_f(&self) -> Result<f64> {
        match self {
            Val::I(v) => Ok(*v as f64),
            Val::F(v) => Ok(*v),
            Val::B(_) => bail!("expected a number, got bool"),
        }
    }
    pub fn as_b(&self) -> Result<bool> {
        match self {
            Val::B(b) => Ok(*b),
            _ => bail!("expected a bool"),
        }
    }
    pub fn zero_of(ty: &Type) -> Val {
        match crate::ir::ScalarTy::of(ty) {
            crate::ir::ScalarTy::F32 | crate::ir::ScalarTy::F64 => Val::F(0.0),
            crate::ir::ScalarTy::Bool => Val::B(false),
            _ => Val::I(0),
        }
    }
}

/// Shared property storage. Integer-family properties (int/long/node) live in
/// `I`, float-family in `F` (as f64 bit patterns), bool in `B`.
#[derive(Debug)]
pub enum PropData {
    I(Vec<AtomicI64>),
    F(Vec<AtomicU64>),
    B(Vec<AtomicBool>),
}

impl PropData {
    pub fn alloc(ty: &Type, len: usize) -> PropData {
        match crate::ir::ScalarTy::of(ty) {
            crate::ir::ScalarTy::F32 | crate::ir::ScalarTy::F64 => {
                PropData::F((0..len).map(|_| AtomicU64::new(0f64.to_bits())).collect())
            }
            crate::ir::ScalarTy::Bool => {
                PropData::B((0..len).map(|_| AtomicBool::new(false)).collect())
            }
            _ => PropData::I((0..len).map(|_| AtomicI64::new(0)).collect()),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            PropData::I(v) => v.len(),
            PropData::F(v) => v.len(),
            PropData::B(v) => v.len(),
        }
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn load(&self, i: usize) -> Val {
        match self {
            PropData::I(v) => Val::I(v[i].load(Ordering::Relaxed)),
            PropData::F(v) => Val::F(f64::from_bits(v[i].load(Ordering::Relaxed))),
            PropData::B(v) => Val::B(v[i].load(Ordering::Relaxed)),
        }
    }

    pub fn store(&self, i: usize, val: Val) {
        match self {
            PropData::I(v) => v[i].store(val.as_i().unwrap_or(0), Ordering::Relaxed),
            PropData::F(v) => v[i].store(val.as_f().unwrap_or(0.0).to_bits(), Ordering::Relaxed),
            PropData::B(v) => v[i].store(val.as_b().unwrap_or(false), Ordering::Relaxed),
        }
    }

    /// Atomic reduction at index `i` (device semantics: atomicAdd & co).
    pub fn atomic_reduce(&self, i: usize, op: ReduceOp, rhs: Val) {
        match (self, op) {
            (PropData::I(v), ReduceOp::Add | ReduceOp::Count) => {
                v[i].fetch_add(rhs.as_i().unwrap_or(0), Ordering::Relaxed);
            }
            (PropData::I(v), ReduceOp::Mul) => {
                // CAS loop (no fetch_mul)
                let rhs = rhs.as_i().unwrap_or(1);
                let mut cur = v[i].load(Ordering::Relaxed);
                loop {
                    match v[i].compare_exchange_weak(
                        cur,
                        cur.wrapping_mul(rhs),
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    ) {
                        Ok(_) => break,
                        Err(now) => cur = now,
                    }
                }
            }
            (PropData::F(v), ReduceOp::Add | ReduceOp::Count) => {
                crate::util::atomics::atomic_add_f64(&v[i], rhs.as_f().unwrap_or(0.0));
            }
            (PropData::F(v), ReduceOp::Mul) => {
                let rhs = rhs.as_f().unwrap_or(1.0);
                let mut cur = v[i].load(Ordering::Relaxed);
                loop {
                    let new = (f64::from_bits(cur) * rhs).to_bits();
                    match v[i].compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed)
                    {
                        Ok(_) => break,
                        Err(now) => cur = now,
                    }
                }
            }
            (PropData::B(v), ReduceOp::And) => {
                if !rhs.as_b().unwrap_or(true) {
                    v[i].store(false, Ordering::Relaxed);
                }
            }
            (PropData::B(v), ReduceOp::Or) => {
                if rhs.as_b().unwrap_or(false) {
                    v[i].store(true, Ordering::Relaxed);
                }
            }
            _ => {}
        }
    }

    /// Atomic Min/Max; returns true if the proposed value won (the paper's
    /// Min construct updates its extra targets only on improvement).
    pub fn atomic_min_max(&self, i: usize, proposed: Val, kind: MinMax) -> bool {
        match self {
            PropData::I(v) => {
                let p = proposed.as_i().unwrap_or(0);
                let mut cur = v[i].load(Ordering::Relaxed);
                loop {
                    let better = match kind {
                        MinMax::Min => p < cur,
                        MinMax::Max => p > cur,
                    };
                    if !better {
                        return false;
                    }
                    match v[i].compare_exchange_weak(cur, p, Ordering::Relaxed, Ordering::Relaxed) {
                        Ok(_) => return true,
                        Err(now) => cur = now,
                    }
                }
            }
            PropData::F(v) => {
                let p = proposed.as_f().unwrap_or(0.0);
                let prev = match kind {
                    MinMax::Min => crate::util::atomics::atomic_min_f64(&v[i], p),
                    MinMax::Max => crate::util::atomics::atomic_max_f64(&v[i], p),
                };
                match kind {
                    MinMax::Min => p < prev,
                    MinMax::Max => p > prev,
                }
            }
            PropData::B(_) => false,
        }
    }

    /// OR over a bool property (fixedPoint convergence check).
    pub fn any_true(&self) -> bool {
        match self {
            PropData::B(v) => v.iter().any(|b| b.load(Ordering::Relaxed)),
            _ => false,
        }
    }

    pub fn to_f64_vec(&self) -> Vec<f64> {
        (0..self.len()).map(|i| self.load(i).as_f().unwrap_or(f64::NAN)).collect()
    }
    pub fn to_i64_vec(&self) -> Vec<i64> {
        (0..self.len())
            .map(|i| match self.load(i) {
                Val::B(b) => b as i64,
                v => v.as_i().unwrap_or(0),
            })
            .collect()
    }
}

/// Host scalar cell — atomics so device reductions (e.g. `triangle_count +=`)
/// work from worker threads.
#[derive(Debug)]
pub enum ScalarCell {
    I(AtomicI64),
    F(AtomicU64),
    B(AtomicBool),
}

impl ScalarCell {
    fn new(v: Val) -> ScalarCell {
        match v {
            Val::I(x) => ScalarCell::I(AtomicI64::new(x)),
            Val::F(x) => ScalarCell::F(AtomicU64::new(x.to_bits())),
            Val::B(x) => ScalarCell::B(AtomicBool::new(x)),
        }
    }
    fn load(&self) -> Val {
        match self {
            ScalarCell::I(c) => Val::I(c.load(Ordering::Relaxed)),
            ScalarCell::F(c) => Val::F(f64::from_bits(c.load(Ordering::Relaxed))),
            ScalarCell::B(c) => Val::B(c.load(Ordering::Relaxed)),
        }
    }
    fn store(&self, v: Val) -> Result<()> {
        match (self, v) {
            (ScalarCell::I(c), v) => c.store(v.as_i()?, Ordering::Relaxed),
            (ScalarCell::F(c), v) => c.store(v.as_f()?.to_bits(), Ordering::Relaxed),
            (ScalarCell::B(c), Val::B(b)) => c.store(b, Ordering::Relaxed),
            (ScalarCell::B(_), _) => bail!("type mismatch storing into bool scalar"),
        }
        Ok(())
    }
}

pub struct Env<'g> {
    pub g: &'g Graph,
    pub threads: usize,
    props: HashMap<String, PropData>,
    scalars: HashMap<String, ScalarCell>,
    sets: HashMap<String, Vec<Node>>,
}

impl<'g> Env<'g> {
    pub fn new(g: &'g Graph, tf: &TypedFunction, threads: usize) -> Result<Env<'g>> {
        let mut props = HashMap::new();
        for p in &tf.func.params {
            match &p.ty {
                Type::PropNode(_) => {
                    props.insert(p.name.clone(), PropData::alloc(&p.ty, g.num_nodes()));
                }
                Type::PropEdge(_) => {
                    // edge property parameters bind to the graph's weights
                    let data = PropData::I(
                        g.weights.iter().map(|&w| AtomicI64::new(w as i64)).collect(),
                    );
                    props.insert(p.name.clone(), data);
                }
                _ => {}
            }
        }
        Ok(Env { g, threads, props, scalars: HashMap::new(), sets: HashMap::new() })
    }

    pub fn alloc_prop(&mut self, name: &str, ty: &Type) -> Result<()> {
        let len = match ty {
            Type::PropEdge(_) => self.g.num_edges(),
            _ => self.g.num_nodes(),
        };
        self.props.insert(name.to_string(), PropData::alloc(ty, len));
        Ok(())
    }

    pub fn is_prop(&self, name: &str) -> bool {
        self.props.contains_key(name)
    }

    pub fn prop(&self, name: &str) -> Result<&PropData> {
        self.props.get(name).ok_or_else(|| anyhow!("unknown property `{name}`"))
    }

    pub fn copy_prop(&mut self, dst: &str, src: &str) -> Result<()> {
        let n = self.prop(src)?.len();
        for i in 0..n {
            let v = self.prop(src)?.load(i);
            self.prop(dst)?.store(i, v);
        }
        Ok(())
    }

    pub fn declare_scalar(&mut self, name: &str, v: Val) {
        self.scalars.insert(name.to_string(), ScalarCell::new(v));
    }

    pub fn set_scalar(&mut self, name: &str, v: Val) {
        match self.scalars.get(name) {
            Some(cell) => {
                if cell.store(v).is_err() {
                    self.scalars.insert(name.to_string(), ScalarCell::new(v));
                }
            }
            None => self.declare_scalar(name, v),
        }
    }

    pub fn scalar(&self, name: &str) -> Result<Val> {
        self.scalars
            .get(name)
            .map(|c| c.load())
            .ok_or_else(|| anyhow!("unknown scalar `{name}`"))
    }

    /// Shared scalar store from a device thread.
    pub fn scalar_store(&self, name: &str, v: Val) -> Result<()> {
        self.scalars
            .get(name)
            .ok_or_else(|| anyhow!("unknown scalar `{name}`"))?
            .store(v)
    }

    /// Shared scalar reduction from a device thread (atomicAdd-style).
    pub fn scalar_reduce(&self, name: &str, op: ReduceOp, rhs: Val) -> Result<()> {
        let cell =
            self.scalars.get(name).ok_or_else(|| anyhow!("unknown scalar `{name}`"))?;
        match (cell, op) {
            (ScalarCell::I(c), ReduceOp::Add | ReduceOp::Count) => {
                c.fetch_add(rhs.as_i()?, Ordering::Relaxed);
            }
            (ScalarCell::F(c), ReduceOp::Add | ReduceOp::Count) => {
                crate::util::atomics::atomic_add_f64(c, rhs.as_f()?);
            }
            (ScalarCell::I(c), ReduceOp::Mul) => {
                let r = rhs.as_i()?;
                let mut cur = c.load(Ordering::Relaxed);
                loop {
                    match c.compare_exchange_weak(
                        cur,
                        cur.wrapping_mul(r),
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    ) {
                        Ok(_) => break,
                        Err(now) => cur = now,
                    }
                }
            }
            (ScalarCell::F(c), ReduceOp::Mul) => {
                let r = rhs.as_f()?;
                let mut cur = c.load(Ordering::Relaxed);
                loop {
                    let new = (f64::from_bits(cur) * r).to_bits();
                    match c.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
                        Ok(_) => break,
                        Err(now) => cur = now,
                    }
                }
            }
            (ScalarCell::B(c), ReduceOp::Or) => {
                if rhs.as_b()? {
                    c.store(true, Ordering::Relaxed);
                }
            }
            (ScalarCell::B(c), ReduceOp::And) => {
                if !rhs.as_b()? {
                    c.store(false, Ordering::Relaxed);
                }
            }
            _ => bail!("unsupported scalar reduction {op:?}"),
        }
        Ok(())
    }

    pub fn bind_set(&mut self, name: &str, vs: Vec<Node>) {
        self.sets.insert(name.to_string(), vs);
    }

    pub fn set_items(&self, name: &str) -> Result<Vec<Node>> {
        self.sets.get(name).cloned().ok_or_else(|| anyhow!("unknown set `{name}`"))
    }

    pub fn take_props(&mut self) -> HashMap<String, PropData> {
        std::mem::take(&mut self.props)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_reduce_and_minmax() {
        let p = PropData::alloc(&Type::PropNode(Box::new(Type::Int)), 4);
        p.store(0, Val::I(10));
        p.atomic_reduce(0, ReduceOp::Add, Val::I(5));
        assert_eq!(p.load(0), Val::I(15));
        assert!(p.atomic_min_max(0, Val::I(3), MinMax::Min));
        assert!(!p.atomic_min_max(0, Val::I(100), MinMax::Min));
        assert_eq!(p.load(0), Val::I(3));
    }

    #[test]
    fn bool_prop_or_flag() {
        let p = PropData::alloc(&Type::PropNode(Box::new(Type::Bool)), 3);
        assert!(!p.any_true());
        p.store(2, Val::B(true));
        assert!(p.any_true());
    }

    #[test]
    fn float_prop_f64_roundtrip() {
        let p = PropData::alloc(&Type::PropNode(Box::new(Type::Float)), 2);
        p.store(1, Val::F(0.25));
        assert_eq!(p.load(1), Val::F(0.25));
        p.atomic_reduce(1, ReduceOp::Add, Val::F(0.5));
        assert_eq!(p.load(1), Val::F(0.75));
    }
}
