//! Runtime state for the interpreter: property arrays (atomic, shared across
//! worker threads), shared scalar cells, and node sets — all indexed by the
//! dense `u32` slots assigned by the lowering pass ([`super::compile`]).
//!
//! No string-keyed container is touched during execution: the only
//! `HashMap<String, _>` left in this module is produced by [`Env::take_props`]
//! at the API boundary, when execution results are handed back as an
//! [`super::Output`].

use crate::dsl::ast::{MinMax, ReduceOp, Type};
use crate::graph::csr::{Graph, Node};
use crate::ir::ScalarTy;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicI32, AtomicI64, AtomicU64, Ordering};

/// A runtime scalar value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Val {
    I(i64),
    F(f64),
    B(bool),
}

/// The DSL's INF sentinel (safe for additive arithmetic).
pub const INF_I: i64 = crate::algorithms::reference::INF as i64;

impl Val {
    pub fn as_i(&self) -> Result<i64> {
        match self {
            Val::I(v) => Ok(*v),
            Val::F(v) => Ok(*v as i64),
            Val::B(_) => bail!("expected a number, got bool"),
        }
    }
    pub fn as_f(&self) -> Result<f64> {
        match self {
            Val::I(v) => Ok(*v as f64),
            Val::F(v) => Ok(*v),
            Val::B(_) => bail!("expected a number, got bool"),
        }
    }
    pub fn as_b(&self) -> Result<bool> {
        match self {
            Val::B(b) => Ok(*b),
            _ => bail!("expected a bool"),
        }
    }
    pub fn zero_of(ty: &Type) -> Val {
        Val::zero_st(ScalarTy::of(ty))
    }
    /// Zero value for a machine scalar type.
    pub fn zero_st(st: ScalarTy) -> Val {
        match st {
            ScalarTy::F32 | ScalarTy::F64 => Val::F(0.0),
            ScalarTy::Bool => Val::B(false),
            _ => Val::I(0),
        }
    }
}

/// Shared property storage. Integer-family properties (int/long/node) live in
/// `I`, float-family in `F` (as f64 bit patterns), bool in `B`.
#[derive(Debug)]
pub enum PropData {
    I(Vec<AtomicI64>),
    F(Vec<AtomicU64>),
    B(Vec<AtomicBool>),
}

impl PropData {
    pub fn alloc(ty: &Type, len: usize) -> PropData {
        PropData::alloc_st(ScalarTy::of(ty), len)
    }

    /// Allocate zero-initialized storage for a machine scalar type.
    pub fn alloc_st(st: ScalarTy, len: usize) -> PropData {
        match st {
            ScalarTy::F32 | ScalarTy::F64 => {
                PropData::F((0..len).map(|_| AtomicU64::new(0f64.to_bits())).collect())
            }
            ScalarTy::Bool => PropData::B((0..len).map(|_| AtomicBool::new(false)).collect()),
            _ => PropData::I((0..len).map(|_| AtomicI64::new(0)).collect()),
        }
    }

    /// Wrap the graph's edge weights (propEdge parameters bind to these).
    pub fn from_weights(g: &Graph) -> PropData {
        PropData::I(g.weights.iter().map(|&w| AtomicI64::new(w as i64)).collect())
    }

    pub fn len(&self) -> usize {
        match self {
            PropData::I(v) => v.len(),
            PropData::F(v) => v.len(),
            PropData::B(v) => v.len(),
        }
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn load(&self, i: usize) -> Val {
        match self {
            PropData::I(v) => Val::I(v[i].load(Ordering::Relaxed)),
            PropData::F(v) => Val::F(f64::from_bits(v[i].load(Ordering::Relaxed))),
            PropData::B(v) => Val::B(v[i].load(Ordering::Relaxed)),
        }
    }

    /// Fast path for bool properties (frontier scans).
    #[inline]
    pub fn load_bool(&self, i: usize) -> bool {
        match self {
            PropData::B(v) => v[i].load(Ordering::Relaxed),
            other => matches!(other.load(i), Val::I(x) if x != 0),
        }
    }

    #[inline]
    pub fn store(&self, i: usize, val: Val) {
        match self {
            PropData::I(v) => v[i].store(val.as_i().unwrap_or(0), Ordering::Relaxed),
            PropData::F(v) => v[i].store(val.as_f().unwrap_or(0.0).to_bits(), Ordering::Relaxed),
            PropData::B(v) => v[i].store(val.as_b().unwrap_or(false), Ordering::Relaxed),
        }
    }

    /// Atomic reduction at index `i` (device semantics: atomicAdd & co).
    /// Unsupported (storage, operator) combinations are an error: the old
    /// silent fall-through dropped reductions on the floor, which hid type
    /// bugs in lowered programs.
    pub fn atomic_reduce(&self, i: usize, op: ReduceOp, rhs: Val) -> Result<()> {
        match (self, op) {
            (PropData::I(v), ReduceOp::Add | ReduceOp::Count) => {
                v[i].fetch_add(rhs.as_i()?, Ordering::Relaxed);
            }
            (PropData::I(v), ReduceOp::Mul) => {
                // CAS loop (no fetch_mul)
                let rhs = rhs.as_i()?;
                let mut cur = v[i].load(Ordering::Relaxed);
                loop {
                    match v[i].compare_exchange_weak(
                        cur,
                        cur.wrapping_mul(rhs),
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    ) {
                        Ok(_) => break,
                        Err(now) => cur = now,
                    }
                }
            }
            (PropData::F(v), ReduceOp::Add | ReduceOp::Count) => {
                crate::util::atomics::atomic_add_f64(&v[i], rhs.as_f()?);
            }
            (PropData::F(v), ReduceOp::Mul) => {
                let rhs = rhs.as_f()?;
                let mut cur = v[i].load(Ordering::Relaxed);
                loop {
                    let new = (f64::from_bits(cur) * rhs).to_bits();
                    match v[i].compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed)
                    {
                        Ok(_) => break,
                        Err(now) => cur = now,
                    }
                }
            }
            (PropData::B(v), ReduceOp::And) => {
                if !rhs.as_b()? {
                    v[i].store(false, Ordering::Relaxed);
                }
            }
            (PropData::B(v), ReduceOp::Or) => {
                if rhs.as_b()? {
                    v[i].store(true, Ordering::Relaxed);
                }
            }
            (data, op) => {
                let kind = match data {
                    PropData::I(_) => "int",
                    PropData::F(_) => "float",
                    PropData::B(_) => "bool",
                };
                bail!("unsupported property reduction `{}` on {kind} storage", op.symbol());
            }
        }
        Ok(())
    }

    /// Atomic Min/Max; returns true if the proposed value won (the paper's
    /// Min construct updates its extra targets only on improvement).
    pub fn atomic_min_max(&self, i: usize, proposed: Val, kind: MinMax) -> bool {
        match self {
            PropData::I(v) => {
                let p = proposed.as_i().unwrap_or(0);
                let mut cur = v[i].load(Ordering::Relaxed);
                loop {
                    let better = match kind {
                        MinMax::Min => p < cur,
                        MinMax::Max => p > cur,
                    };
                    if !better {
                        return false;
                    }
                    match v[i].compare_exchange_weak(cur, p, Ordering::Relaxed, Ordering::Relaxed) {
                        Ok(_) => return true,
                        Err(now) => cur = now,
                    }
                }
            }
            PropData::F(v) => {
                let p = proposed.as_f().unwrap_or(0.0);
                let prev = match kind {
                    MinMax::Min => crate::util::atomics::atomic_min_f64(&v[i], p),
                    MinMax::Max => crate::util::atomics::atomic_max_f64(&v[i], p),
                };
                match kind {
                    MinMax::Min => p < prev,
                    MinMax::Max => p > prev,
                }
            }
            PropData::B(_) => false,
        }
    }

    /// Atomically claim a set bool cell: returns `true` iff the bit was set
    /// and *this caller* cleared it. The parallel frontier gather uses this
    /// so concurrent workers scanning overlapping neighborhoods claim each
    /// newly-flagged vertex exactly once (no duplicates in the next
    /// worklist). Non-bool storage never wins a claim — the compiler only
    /// marks bool ping-pong buffers frontier-eligible.
    #[inline]
    pub fn claim_true(&self, i: usize) -> bool {
        match self {
            PropData::B(v) => v[i].swap(false, Ordering::Relaxed),
            _ => false,
        }
    }

    /// OR over a bool property (fixedPoint convergence check).
    pub fn any_true(&self) -> bool {
        match self {
            PropData::B(v) => v.iter().any(|b| b.load(Ordering::Relaxed)),
            _ => false,
        }
    }

    pub fn to_f64_vec(&self) -> Vec<f64> {
        (0..self.len()).map(|i| self.load(i).as_f().unwrap_or(f64::NAN)).collect()
    }
    pub fn to_i64_vec(&self) -> Vec<i64> {
        (0..self.len())
            .map(|i| match self.load(i) {
                Val::B(b) => b as i64,
                v => v.as_i().unwrap_or(0),
            })
            .collect()
    }
}

/// BFS level array discovered *by the compiled forward sweep itself* (the
/// generated CUDA's do-while shape): `-1` marks undiscovered, the source is
/// level 0, and workers racing to label a vertex settle it with one CAS —
/// the winner also owns the vertex's slot in the next level bucket, so the
/// per-level frontier gather produces no duplicates. Replaces the old
/// host-side `reference::bfs_levels` pass (one whole O(V+E) traversal the
/// interpreter no longer pays).
pub struct Levels {
    cells: Vec<AtomicI32>,
}

impl Levels {
    /// All vertices undiscovered (`-1`).
    pub fn new(n: usize) -> Levels {
        Levels { cells: (0..n).map(|_| AtomicI32::new(-1)).collect() }
    }

    /// Unconditional label (the BFS source).
    pub fn set(&self, v: usize, level: i32) {
        self.cells[v].store(level, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self, v: usize) -> i32 {
        self.cells[v].load(Ordering::Relaxed)
    }

    /// CAS `-1 → level`: `true` iff this caller discovered `v` (and so owns
    /// pushing it into the next level bucket).
    #[inline]
    pub fn claim(&self, v: usize, level: i32) -> bool {
        self.cells[v]
            .compare_exchange(-1, level, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }
}

/// Shared scalar cell — atomics so device reductions (e.g. `triangle_count +=`)
/// work from worker threads.
///
/// Padded to a cache line (`repr(align(64))`): hot reduction cells live next
/// to each other in `Env`'s `Vec<ScalarCell>` (e.g. PageRank's `diff` beside
/// `iterCount`), and without padding every atomic RMW from one worker would
/// invalidate the line under all other workers' unrelated cells (false
/// sharing). The scalar table is tiny — a handful of cells per program — so
/// the memory cost is nil while Par-mode reductions stop bouncing lines.
#[derive(Debug)]
#[repr(align(64))]
pub enum ScalarCell {
    I(AtomicI64),
    F(AtomicU64),
    B(AtomicBool),
}

impl ScalarCell {
    fn new(v: Val) -> ScalarCell {
        match v {
            Val::I(x) => ScalarCell::I(AtomicI64::new(x)),
            Val::F(x) => ScalarCell::F(AtomicU64::new(x.to_bits())),
            Val::B(x) => ScalarCell::B(AtomicBool::new(x)),
        }
    }
    #[inline]
    fn load(&self) -> Val {
        match self {
            ScalarCell::I(c) => Val::I(c.load(Ordering::Relaxed)),
            ScalarCell::F(c) => Val::F(f64::from_bits(c.load(Ordering::Relaxed))),
            ScalarCell::B(c) => Val::B(c.load(Ordering::Relaxed)),
        }
    }
    fn store(&self, v: Val) -> Result<()> {
        match (self, v) {
            (ScalarCell::I(c), v) => c.store(v.as_i()?, Ordering::Relaxed),
            (ScalarCell::F(c), v) => c.store(v.as_f()?.to_bits(), Ordering::Relaxed),
            (ScalarCell::B(c), Val::B(b)) => c.store(b, Ordering::Relaxed),
            (ScalarCell::B(_), _) => bail!("type mismatch storing into bool scalar"),
        }
        Ok(())
    }
}

/// Slot-indexed runtime state. Constructed once per [`super::run`] from the
/// compiled program's slot tables; every access during execution is a plain
/// vector index.
pub struct Env<'g> {
    pub g: &'g Graph,
    pub threads: usize,
    /// frontier-eligible fixedPoints may run the sparse worklist schedule
    /// (see [`super::ExecOpts::frontier`]; `false` forces dense sweeps —
    /// the bench harness uses it to time both paths on the same program)
    pub frontier_enabled: bool,
    /// cooperative cancellation token for this run (deadline + explicit
    /// cancel), polled at statement / iteration / pool-block boundaries
    pub cancel: Option<crate::util::cancel::CancelToken>,
    /// deterministic fault-injection plan for this run (see
    /// [`crate::util::fault`])
    pub fault: Option<crate::util::fault::FaultPlan>,
    /// sparse→dense schedule fallbacks taken during this run (reported as
    /// [`super::ExecStats::fallbacks`])
    pub fallbacks: AtomicU64,
    /// direction policy for frontier rounds / BFS levels (resolved from
    /// [`super::ExecOpts::direction`] / `STARPLAT_DIRECTION` once per run)
    pub direction: super::Direction,
    /// delta-stepping policy for relaxation-shaped fixedPoints (resolved
    /// from [`super::ExecOpts::delta`] / `STARPLAT_DELTA` once per run)
    pub delta: super::DeltaMode,
    /// sequential/parallel cutover for sweeps and gathers, resolved once per
    /// run ([`super::ExecOpts::frontier_par_min`] overrides the cached
    /// `STARPLAT_FRONTIER_PAR_MIN` read) — the hot loops never consult the
    /// environment
    pub frontier_par_min: usize,
    /// push↔pull direction changes taken across frontier rounds and BFS
    /// levels (reported as [`super::ExecStats::direction_switches`])
    pub direction_switches: AtomicU64,
    /// rounds / levels executed in the pull (reverse-CSR) direction
    pub pull_rounds: AtomicU64,
    /// did any fixedPoint run the delta-stepping schedule this run?
    pub delta_used: AtomicBool,
    /// recycled per-worker register frames: a sweep takes one frame per
    /// participant and returns it afterwards, so a fixedPoint running
    /// hundreds of rounds allocates frames only on its first sweep
    pub frame_arena: crate::util::pool::Arena<Vec<Val>>,
    /// recycled claim/worklist buffers for the parallel frontier gathers
    /// and BFS level discovery (same per-level reuse story)
    pub buf_arena: crate::util::pool::Arena<Vec<Node>>,
    props: Vec<PropData>,
    prop_names: Vec<String>,
    scalars: Vec<ScalarCell>,
    sets: Vec<Vec<Node>>,
}

impl<'g> Env<'g> {
    pub fn new(g: &'g Graph, prog: &super::compile::Program, threads: usize) -> Env<'g> {
        let props = prog
            .props
            .iter()
            .map(|m| {
                if m.param {
                    if m.edge {
                        // edge property parameters bind to the graph's weights
                        PropData::from_weights(g)
                    } else {
                        PropData::alloc_st(m.ty, g.num_nodes())
                    }
                } else {
                    // declared properties are materialized by AllocProp
                    PropData::alloc_st(m.ty, 0)
                }
            })
            .collect();
        let prop_names = prog.props.iter().map(|m| m.name.clone()).collect();
        let scalars = prog.scalars.iter().map(|m| ScalarCell::new(Val::zero_st(m.ty))).collect();
        let sets = vec![Vec::new(); prog.sets.len()];
        Env {
            g,
            threads,
            frontier_enabled: true,
            cancel: None,
            fault: None,
            fallbacks: AtomicU64::new(0),
            direction: super::Direction::Auto,
            delta: super::DeltaMode::Off,
            frontier_par_min: super::frontier_par_min(),
            direction_switches: AtomicU64::new(0),
            pull_rounds: AtomicU64::new(0),
            delta_used: AtomicBool::new(false),
            frame_arena: crate::util::pool::Arena::new(),
            buf_arena: crate::util::pool::Arena::new(),
            props,
            prop_names,
            scalars,
            sets,
        }
    }

    /// Cooperative cancellation point: maps a tripped token onto the typed
    /// [`super::ExecError`] variants (carried inside `anyhow::Error`).
    pub fn check_cancel(&self) -> Result<()> {
        if let Some(c) = &self.cancel {
            if let Some(i) = c.interrupted() {
                return Err(anyhow::Error::new(super::ExecError::from(i)));
            }
        }
        Ok(())
    }

    /// Record one sparse→dense schedule fallback (graceful degradation).
    pub fn note_fallback(&self) {
        self.fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one push↔pull direction change (Beamer-style switching).
    pub fn note_direction_switch(&self) {
        self.direction_switches.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one round / level executed in the pull direction.
    pub fn note_pull_round(&self) {
        self.pull_rounds.fetch_add(1, Ordering::Relaxed);
    }

    /// (Re-)allocate a declared property. Re-executing a declaration (e.g. a
    /// propNode declared inside a sequential source loop, as in BC) resets
    /// the array, matching the scoped-declaration semantics of the DSL.
    pub fn alloc_prop(&mut self, slot: u32, ty: ScalarTy, edge: bool) {
        let len = if edge { self.g.num_edges() } else { self.g.num_nodes() };
        self.props[slot as usize] = PropData::alloc_st(ty, len);
    }

    #[inline]
    pub fn prop(&self, slot: u32) -> &PropData {
        &self.props[slot as usize]
    }

    /// Whole-property copy (`modified = modified_nxt`). Atomic element-wise
    /// stores, so it is safe from the host while no kernel is running; runs
    /// on the pool because it sits inside every dense fixedPoint / do-while
    /// iteration (e.g. PageRank's double-buffer swap).
    pub fn copy_prop(&self, dst: u32, src: u32) {
        let (d, s) = (&self.props[dst as usize], &self.props[src as usize]);
        crate::util::pool::parallel_for(s.len(), self.threads, |i| {
            d.store(i, s.load(i));
        });
    }

    /// Host scalar write: stores in place, re-typing the cell when the value
    /// family changes (C-style declarations can re-bind, e.g. in loops).
    pub fn set_scalar(&mut self, slot: u32, v: Val) {
        if self.scalars[slot as usize].store(v).is_err() {
            self.scalars[slot as usize] = ScalarCell::new(v);
        }
    }

    /// Host declaration: always installs a fresh, correctly-typed cell.
    pub fn declare_scalar(&mut self, slot: u32, v: Val) {
        self.scalars[slot as usize] = ScalarCell::new(v);
    }

    #[inline]
    pub fn scalar(&self, slot: u32) -> Val {
        self.scalars[slot as usize].load()
    }

    /// Shared scalar store from a device thread (atomic).
    pub fn scalar_store(&self, slot: u32, v: Val) -> Result<()> {
        self.scalars[slot as usize].store(v)
    }

    /// Shared scalar reduction from a device thread (atomicAdd-style).
    pub fn scalar_reduce(&self, slot: u32, op: ReduceOp, rhs: Val) -> Result<()> {
        let cell = &self.scalars[slot as usize];
        match (cell, op) {
            (ScalarCell::I(c), ReduceOp::Add | ReduceOp::Count) => {
                c.fetch_add(rhs.as_i()?, Ordering::Relaxed);
            }
            (ScalarCell::F(c), ReduceOp::Add | ReduceOp::Count) => {
                crate::util::atomics::atomic_add_f64(c, rhs.as_f()?);
            }
            (ScalarCell::I(c), ReduceOp::Mul) => {
                let r = rhs.as_i()?;
                let mut cur = c.load(Ordering::Relaxed);
                loop {
                    match c.compare_exchange_weak(
                        cur,
                        cur.wrapping_mul(r),
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    ) {
                        Ok(_) => break,
                        Err(now) => cur = now,
                    }
                }
            }
            (ScalarCell::F(c), ReduceOp::Mul) => {
                let r = rhs.as_f()?;
                let mut cur = c.load(Ordering::Relaxed);
                loop {
                    let new = (f64::from_bits(cur) * r).to_bits();
                    match c.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
                        Ok(_) => break,
                        Err(now) => cur = now,
                    }
                }
            }
            (ScalarCell::B(c), ReduceOp::Or) => {
                if rhs.as_b()? {
                    c.store(true, Ordering::Relaxed);
                }
            }
            (ScalarCell::B(c), ReduceOp::And) => {
                if !rhs.as_b()? {
                    c.store(false, Ordering::Relaxed);
                }
            }
            _ => bail!("unsupported scalar reduction {op:?}"),
        }
        Ok(())
    }

    pub fn bind_set(&mut self, slot: u32, vs: Vec<Node>) {
        self.sets[slot as usize] = vs;
    }

    #[inline]
    pub fn set_items(&self, slot: u32) -> &[Node] {
        &self.sets[slot as usize]
    }

    /// Hand results back by name — the only point where names re-enter.
    pub fn take_props(&mut self) -> HashMap<String, PropData> {
        let names = std::mem::take(&mut self.prop_names);
        let props = std::mem::take(&mut self.props);
        names.into_iter().zip(props).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_reduce_and_minmax() {
        let p = PropData::alloc(&Type::PropNode(Box::new(Type::Int)), 4);
        p.store(0, Val::I(10));
        p.atomic_reduce(0, ReduceOp::Add, Val::I(5)).unwrap();
        assert_eq!(p.load(0), Val::I(15));
        assert!(p.atomic_min_max(0, Val::I(3), MinMax::Min));
        assert!(!p.atomic_min_max(0, Val::I(100), MinMax::Min));
        assert_eq!(p.load(0), Val::I(3));
    }

    #[test]
    fn bool_prop_or_flag() {
        let p = PropData::alloc(&Type::PropNode(Box::new(Type::Bool)), 3);
        assert!(!p.any_true());
        p.store(2, Val::B(true));
        assert!(p.any_true());
        assert!(p.load_bool(2));
        assert!(!p.load_bool(0));
    }

    #[test]
    fn float_prop_f64_roundtrip() {
        let p = PropData::alloc(&Type::PropNode(Box::new(Type::Float)), 2);
        p.store(1, Val::F(0.25));
        assert_eq!(p.load(1), Val::F(0.25));
        p.atomic_reduce(1, ReduceOp::Add, Val::F(0.5)).unwrap();
        assert_eq!(p.load(1), Val::F(0.75));
    }

    #[test]
    fn atomic_reduce_every_supported_arm() {
        let i = PropData::alloc_st(ScalarTy::I64, 1);
        i.store(0, Val::I(6));
        i.atomic_reduce(0, ReduceOp::Add, Val::I(4)).unwrap();
        i.atomic_reduce(0, ReduceOp::Count, Val::I(1)).unwrap();
        i.atomic_reduce(0, ReduceOp::Mul, Val::I(3)).unwrap();
        assert_eq!(i.load(0), Val::I(33));

        let f = PropData::alloc_st(ScalarTy::F64, 1);
        f.store(0, Val::F(2.0));
        f.atomic_reduce(0, ReduceOp::Add, Val::F(1.5)).unwrap();
        f.atomic_reduce(0, ReduceOp::Count, Val::I(1)).unwrap();
        f.atomic_reduce(0, ReduceOp::Mul, Val::F(2.0)).unwrap();
        assert_eq!(f.load(0), Val::F(9.0));

        let b = PropData::alloc_st(ScalarTy::Bool, 1);
        b.atomic_reduce(0, ReduceOp::Or, Val::B(true)).unwrap();
        assert_eq!(b.load(0), Val::B(true));
        b.atomic_reduce(0, ReduceOp::And, Val::B(false)).unwrap();
        assert_eq!(b.load(0), Val::B(false));
    }

    #[test]
    fn claim_true_is_exclusive() {
        let p = PropData::alloc_st(ScalarTy::Bool, 3);
        p.store(1, Val::B(true));
        assert!(p.claim_true(1), "first claim wins");
        assert!(!p.claim_true(1), "second claim must lose");
        assert!(!p.load_bool(1), "claim clears the bit");
        assert!(!p.claim_true(0), "unset bit is never claimed");
        // non-bool storage never wins (frontier buffers are always bool)
        let i = PropData::alloc_st(ScalarTy::I32, 1);
        i.store(0, Val::I(1));
        assert!(!i.claim_true(0));
    }

    #[test]
    fn levels_claim_once_and_get() {
        let l = Levels::new(4);
        assert_eq!(l.get(2), -1);
        l.set(0, 0);
        assert_eq!(l.get(0), 0);
        assert!(l.claim(2, 1), "undiscovered vertex is claimable");
        assert!(!l.claim(2, 1), "a vertex is discovered exactly once");
        assert!(!l.claim(0, 5), "the source is never re-labeled");
        assert_eq!(l.get(2), 1);
        assert_eq!(l.len(), 4);
    }

    #[test]
    fn scalar_cells_are_cache_line_padded() {
        // adjacent cells in Env's scalar table must not share a cache line
        assert_eq!(std::mem::align_of::<ScalarCell>(), 64);
        assert_eq!(std::mem::size_of::<ScalarCell>(), 64);
    }

    #[test]
    fn atomic_reduce_rejects_unsupported_combinations() {
        let i = PropData::alloc_st(ScalarTy::I32, 1);
        assert!(i.atomic_reduce(0, ReduceOp::And, Val::B(true)).is_err());
        assert!(i.atomic_reduce(0, ReduceOp::Or, Val::B(false)).is_err());
        let b = PropData::alloc_st(ScalarTy::Bool, 1);
        assert!(b.atomic_reduce(0, ReduceOp::Add, Val::I(1)).is_err());
        assert!(b.atomic_reduce(0, ReduceOp::Mul, Val::I(2)).is_err());
        assert!(b.atomic_reduce(0, ReduceOp::Count, Val::I(1)).is_err());
        // type-mismatched right-hand sides surface instead of defaulting
        let f = PropData::alloc_st(ScalarTy::F32, 1);
        assert!(f.atomic_reduce(0, ReduceOp::Add, Val::B(true)).is_err());
    }
}
