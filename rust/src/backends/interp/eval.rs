//! Expression evaluation for the interpreter.

use super::env::{Env, Val, INF_I};
use crate::dsl::ast::*;
use crate::graph::csr::Node;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

/// Per-thread evaluation context: loop-element bindings, local scalars,
/// current edge id, and BFS level info.
pub struct EvalCtx<'e, 'g> {
    env: &'e Env<'g>,
    elements: HashMap<String, Node>,
    locals: HashMap<String, Val>,
    /// innermost loop element — bare property names in filters resolve here
    primary: Option<Node>,
    current_edge: Option<usize>,
    levels: Option<&'e [i32]>,
    bfs_dag: bool,
    #[allow(dead_code)]
    device: bool,
}

impl<'e, 'g> EvalCtx<'e, 'g> {
    pub fn host(env: &'e Env<'g>) -> Self {
        EvalCtx {
            env,
            elements: HashMap::new(),
            locals: HashMap::new(),
            primary: None,
            current_edge: None,
            levels: None,
            bfs_dag: false,
            device: false,
        }
    }
    pub fn device(env: &'e Env<'g>) -> Self {
        EvalCtx { device: true, ..Self::host(env) }
    }

    pub fn with_element(mut self, name: &str, v: Node) -> Self {
        self.elements.insert(name.to_string(), v);
        self.primary = Some(v);
        self
    }

    pub fn with_bfs(mut self, levels: &'e [i32], dag: bool) -> Self {
        self.levels = Some(levels);
        self.bfs_dag = dag;
        self
    }

    /// Clone bindings for a nested scope (cheap: small maps).
    pub fn child(&self) -> EvalCtx<'e, 'g> {
        EvalCtx {
            env: self.env,
            elements: self.elements.clone(),
            locals: self.locals.clone(),
            primary: self.primary,
            current_edge: self.current_edge,
            levels: self.levels,
            bfs_dag: self.bfs_dag,
            device: self.device,
        }
    }

    pub fn declare_local(&mut self, name: &str, v: Val) {
        // Hot path: re-declaring the same local each loop iteration must not
        // re-allocate the key (§Perf in EXPERIMENTS.md).
        if let Some(slot) = self.locals.get_mut(name) {
            *slot = v;
        } else {
            self.locals.insert(name.to_string(), v);
        }
    }
    pub fn has_local(&self, name: &str) -> bool {
        self.locals.contains_key(name)
    }
    pub fn set_local(&mut self, name: &str, v: Val) {
        self.declare_local(name, v);
    }
    pub fn local(&self, name: &str) -> Result<Val> {
        self.locals.get(name).copied().ok_or_else(|| anyhow!("unknown local `{name}`"))
    }
    pub fn set_current_edge(&mut self, e: usize) {
        self.current_edge = e.into();
    }

    /// Saved loop bindings for in-place nested iteration.
    pub fn save_loop_state(&self, var: &str) -> (Option<Node>, Option<Node>, Option<usize>) {
        (self.elements.get(var).copied(), self.primary, self.current_edge)
    }
    pub fn bind_element(&mut self, name: &str, v: Node) {
        // allocation-free on the per-edge re-bind path
        if let Some(slot) = self.elements.get_mut(name) {
            *slot = v;
        } else {
            self.elements.insert(name.to_string(), v);
        }
        self.primary = Some(v);
    }
    pub fn restore_loop_state(
        &mut self,
        var: &str,
        saved: (Option<Node>, Option<Node>, Option<usize>),
    ) {
        match saved.0 {
            Some(v) => {
                self.elements.insert(var.to_string(), v);
            }
            None => {
                self.elements.remove(var);
            }
        }
        self.primary = saved.1;
        self.current_edge = saved.2;
    }
    pub fn levels(&self) -> Option<&'e [i32]> {
        self.levels
    }
    pub fn bfs_dag(&self) -> bool {
        self.bfs_dag
    }

    /// Resolve a node/edge-typed name to its element index.
    pub fn element(&self, name: &str) -> Result<Node> {
        if let Some(v) = self.elements.get(name) {
            return Ok(*v);
        }
        if let Some(Val::I(v)) = self.locals.get(name) {
            return Ok(*v as Node);
        }
        // host scalars can hold node ids (e.g. `src`)
        Ok(self.env.scalar(name)?.as_i()? as Node)
    }
}

pub fn eval(e: &Expr, ctx: &EvalCtx<'_, '_>) -> Result<Val> {
    Ok(match e {
        Expr::IntLit(n) => Val::I(*n),
        Expr::FloatLit(x) => Val::F(*x),
        Expr::BoolLit(b) => Val::B(*b),
        Expr::Inf => Val::I(INF_I),
        Expr::Var(name) => {
            if let Some(v) = ctx.locals.get(name) {
                *v
            } else if let Some(v) = ctx.elements.get(name) {
                Val::I(*v as i64)
            } else if ctx.env.is_prop(name) {
                // bare property name: current element's value (filter idiom)
                let idx = ctx
                    .primary
                    .ok_or_else(|| anyhow!("property `{name}` used without an element"))?;
                ctx.env.prop(name)?.load(idx as usize)
            } else {
                ctx.env.scalar(name)?
            }
        }
        Expr::Prop { obj, prop } => {
            let idx = ctx.element(obj)?;
            ctx.env.prop(prop)?.load(idx as usize)
        }
        Expr::Call { recv, name, args } => return eval_call(recv.as_deref(), name, args, ctx),
        Expr::Unary { op, expr } => {
            let v = eval(expr, ctx)?;
            match op {
                UnOp::Not => Val::B(!v.as_b()?),
                UnOp::Neg => match v {
                    Val::I(x) => Val::I(-x),
                    Val::F(x) => Val::F(-x),
                    Val::B(_) => bail!("cannot negate a bool"),
                },
            }
        }
        Expr::Binary { op, lhs, rhs } => {
            let l = eval(lhs, ctx)?;
            if *op == BinOp::And {
                return Ok(Val::B(l.as_b()? && eval(rhs, ctx)?.as_b()?));
            }
            if *op == BinOp::Or {
                return Ok(Val::B(l.as_b()? || eval(rhs, ctx)?.as_b()?));
            }
            let r = eval(rhs, ctx)?;
            binop(*op, l, r)?
        }
    })
}

fn binop(op: BinOp, l: Val, r: Val) -> Result<Val> {
    // bool equality
    if let (Val::B(a), Val::B(b)) = (l, r) {
        return Ok(match op {
            BinOp::Eq => Val::B(a == b),
            BinOp::Ne => Val::B(a != b),
            _ => bail!("operator {} on bools", op.symbol()),
        });
    }
    let float = matches!(l, Val::F(_)) || matches!(r, Val::F(_));
    if float {
        let (a, b) = (l.as_f()?, r.as_f()?);
        Ok(match op {
            BinOp::Add => Val::F(a + b),
            BinOp::Sub => Val::F(a - b),
            BinOp::Mul => Val::F(a * b),
            BinOp::Div => Val::F(a / b),
            BinOp::Mod => Val::F(a % b),
            BinOp::Lt => Val::B(a < b),
            BinOp::Gt => Val::B(a > b),
            BinOp::Le => Val::B(a <= b),
            BinOp::Ge => Val::B(a >= b),
            BinOp::Eq => Val::B(a == b),
            BinOp::Ne => Val::B(a != b),
            _ => unreachable!(),
        })
    } else {
        let (a, b) = (l.as_i()?, r.as_i()?);
        Ok(match op {
            BinOp::Add => Val::I(a + b),
            BinOp::Sub => Val::I(a - b),
            BinOp::Mul => Val::I(a * b),
            BinOp::Div => {
                if b == 0 {
                    bail!("integer division by zero")
                }
                Val::I(a / b)
            }
            BinOp::Mod => {
                if b == 0 {
                    bail!("integer modulo by zero")
                }
                Val::I(a % b)
            }
            BinOp::Lt => Val::B(a < b),
            BinOp::Gt => Val::B(a > b),
            BinOp::Le => Val::B(a <= b),
            BinOp::Ge => Val::B(a >= b),
            BinOp::Eq => Val::B(a == b),
            BinOp::Ne => Val::B(a != b),
            _ => unreachable!(),
        })
    }
}

fn eval_call(recv: Option<&str>, name: &str, args: &[Expr], ctx: &EvalCtx<'_, '_>) -> Result<Val> {
    let g = ctx.env.g;
    match (recv, name, args.len()) {
        (None, "abs", 1) => match eval(&args[0], ctx)? {
            Val::I(x) => Ok(Val::I(x.abs())),
            Val::F(x) => Ok(Val::F(x.abs())),
            Val::B(_) => bail!("abs of bool"),
        },
        (Some(_), "num_nodes", 0) => Ok(Val::I(g.num_nodes() as i64)),
        (Some(_), "num_edges", 0) => Ok(Val::I(g.num_edges() as i64)),
        (Some(_), "minWt", 0) => Ok(Val::I(g.min_weight() as i64)),
        (Some(_), "maxWt", 0) => Ok(Val::I(g.max_weight() as i64)),
        (Some(_), "is_an_edge", 2) => {
            let u = eval(&args[0], ctx)?.as_i()? as Node;
            let w = eval(&args[1], ctx)?.as_i()? as Node;
            Ok(Val::B(g.is_an_edge(u, w)))
        }
        (Some(_), "get_edge", 2) => {
            let u = eval(&args[0], ctx)?.as_i()? as Node;
            let w = eval(&args[1], ctx)?.as_i()? as Node;
            // fast path: the edge of the current neighbor iteration
            if let Some(e) = ctx.current_edge {
                if g.adj[e] == w {
                    return Ok(Val::I(e as i64));
                }
            }
            let lo = g.offsets[u as usize] as usize;
            let nb = g.neighbors(u);
            match nb.binary_search(&w) {
                Ok(k) => Ok(Val::I((lo + k) as i64)),
                Err(_) => bail!("get_edge({u},{w}): no such edge"),
            }
        }
        (Some(r), "outDegree", 0) => {
            let v = ctx.element(r)?;
            Ok(Val::I(g.out_degree(v) as i64))
        }
        (Some(r), "inDegree", 0) => {
            let v = ctx.element(r)?;
            Ok(Val::I(g.in_degree(v) as i64))
        }
        _ => bail!(
            "unknown builtin `{}{name}/{}`",
            recv.map(|r| format!("{r}.")).unwrap_or_default(),
            args.len()
        ),
    }
}

/// Combine for reduction operators (host + per-thread locals).
pub fn apply_reduce(op: ReduceOp, cur: Val, rhs: Val) -> Result<Val> {
    Ok(match op {
        ReduceOp::Add | ReduceOp::Count => binop(BinOp::Add, cur, rhs)?,
        ReduceOp::Mul => binop(BinOp::Mul, cur, rhs)?,
        ReduceOp::And => Val::B(cur.as_b()? && rhs.as_b()?),
        ReduceOp::Or => Val::B(cur.as_b()? || rhs.as_b()?),
    })
}
