//! Expression evaluation over the slot-resolved form.
//!
//! The evaluation context is a small `Copy` struct (environment pointer,
//! current edge id, optional BFS levels); locals and loop elements live in a
//! flat register `frame` owned by the worker thread. There are no maps to
//! clone per scope and no string lookups of any kind on this path — every
//! operand of [`CExpr`] is a dense index resolved at compile time
//! ([`super::compile`]).

use super::compile::{CExpr, Idx};
use super::env::{Env, Levels, Val};
use crate::dsl::ast::{BinOp, ReduceOp, UnOp};
use crate::graph::csr::Node;
use anyhow::{anyhow, bail, Result};

/// Sentinel for "no current edge" (outside a tracked neighbor loop).
pub const NO_EDGE: usize = usize::MAX;

/// Per-element evaluation context. Cheap to construct and copy: nested
/// scopes mutate the worker's register frame in place instead of cloning
/// binding maps.
#[derive(Clone, Copy)]
pub struct EvalCtx<'e, 'g> {
    pub env: &'e Env<'g>,
    /// edge id of the innermost tracked neighbor iteration
    pub current_edge: usize,
    /// BFS level array while inside iterateInBFS / iterateInReverse —
    /// discovered on the fly by the compiled forward sweep, so the cells are
    /// atomic ([`Levels`])
    pub levels: Option<&'e Levels>,
}

impl<'e, 'g> EvalCtx<'e, 'g> {
    pub fn new(env: &'e Env<'g>) -> Self {
        EvalCtx { env, current_edge: NO_EDGE, levels: None }
    }
}

/// Resolve a property-index operand to a node/edge id.
#[inline]
pub fn node_of(idx: Idx, ctx: &EvalCtx<'_, '_>, frame: &[Val]) -> Result<Node> {
    match idx {
        Idx::Reg(r) => Ok(frame[r as usize].as_i()? as Node),
        Idx::Scalar(s) => Ok(ctx.env.scalar(s).as_i()? as Node),
    }
}

pub fn eval(e: &CExpr, ctx: &EvalCtx<'_, '_>, frame: &[Val]) -> Result<Val> {
    let g = ctx.env.g;
    Ok(match e {
        CExpr::ConstI(n) => Val::I(*n),
        CExpr::ConstF(x) => Val::F(*x),
        CExpr::ConstB(b) => Val::B(*b),
        CExpr::LoadReg(r) => frame[*r as usize],
        CExpr::LoadScalar(s) => ctx.env.scalar(*s),
        CExpr::LoadProp { prop, idx } => {
            ctx.env.prop(*prop).load(node_of(*idx, ctx, frame)? as usize)
        }
        CExpr::Unary { op, expr } => {
            let v = eval(expr, ctx, frame)?;
            match op {
                UnOp::Not => Val::B(!v.as_b()?),
                UnOp::Neg => match v {
                    Val::I(x) => Val::I(-x),
                    Val::F(x) => Val::F(-x),
                    Val::B(_) => bail!("cannot negate a bool"),
                },
            }
        }
        CExpr::Binary { op, lhs, rhs } => {
            let l = eval(lhs, ctx, frame)?;
            if *op == BinOp::And {
                return Ok(Val::B(l.as_b()? && eval(rhs, ctx, frame)?.as_b()?));
            }
            if *op == BinOp::Or {
                return Ok(Val::B(l.as_b()? || eval(rhs, ctx, frame)?.as_b()?));
            }
            let r = eval(rhs, ctx, frame)?;
            binop(*op, l, r)?
        }
        CExpr::Abs(inner) => match eval(inner, ctx, frame)? {
            Val::I(x) => Val::I(x.abs()),
            Val::F(x) => Val::F(x.abs()),
            Val::B(_) => bail!("abs of bool"),
        },
        CExpr::NumNodes => Val::I(g.num_nodes() as i64),
        CExpr::NumEdges => Val::I(g.num_edges() as i64),
        CExpr::MinWt => Val::I(g.min_weight() as i64),
        CExpr::MaxWt => Val::I(g.max_weight() as i64),
        CExpr::OutDegree(idx) => Val::I(g.out_degree(node_of(*idx, ctx, frame)?) as i64),
        CExpr::InDegree(idx) => Val::I(g.in_degree(node_of(*idx, ctx, frame)?) as i64),
        CExpr::IsAnEdge(a, b) => {
            let u = eval(a, ctx, frame)?.as_i()? as Node;
            let w = eval(b, ctx, frame)?.as_i()? as Node;
            Val::B(g.is_an_edge(u, w))
        }
        CExpr::CurrentEdge => {
            if ctx.current_edge == NO_EDGE {
                return Err(anyhow!("get_edge outside a neighbor iteration"));
            }
            Val::I(ctx.current_edge as i64)
        }
        CExpr::EdgeLookup { u, w } => {
            let u = eval(u, ctx, frame)?.as_i()? as Node;
            let w = eval(w, ctx, frame)?.as_i()? as Node;
            // fast path: the edge of the current neighbor iteration — valid
            // only if that edge actually originates at `u` (the tracked loop
            // may be iterating a different source vertex)
            let range = g.edge_range(u);
            if ctx.current_edge != NO_EDGE
                && range.contains(&ctx.current_edge)
                && g.adj[ctx.current_edge] == w
            {
                return Ok(Val::I(ctx.current_edge as i64));
            }
            match g.neighbors(u).binary_search(&w) {
                Ok(k) => Val::I((range.start + k) as i64),
                Err(_) => bail!("get_edge({u},{w}): no such edge"),
            }
        }
    })
}

pub fn binop(op: BinOp, l: Val, r: Val) -> Result<Val> {
    // bool equality
    if let (Val::B(a), Val::B(b)) = (l, r) {
        return Ok(match op {
            BinOp::Eq => Val::B(a == b),
            BinOp::Ne => Val::B(a != b),
            _ => bail!("operator {} on bools", op.symbol()),
        });
    }
    let float = matches!(l, Val::F(_)) || matches!(r, Val::F(_));
    if float {
        let (a, b) = (l.as_f()?, r.as_f()?);
        Ok(match op {
            BinOp::Add => Val::F(a + b),
            BinOp::Sub => Val::F(a - b),
            BinOp::Mul => Val::F(a * b),
            BinOp::Div => Val::F(a / b),
            BinOp::Mod => Val::F(a % b),
            BinOp::Lt => Val::B(a < b),
            BinOp::Gt => Val::B(a > b),
            BinOp::Le => Val::B(a <= b),
            BinOp::Ge => Val::B(a >= b),
            BinOp::Eq => Val::B(a == b),
            BinOp::Ne => Val::B(a != b),
            _ => unreachable!(),
        })
    } else {
        let (a, b) = (l.as_i()?, r.as_i()?);
        Ok(match op {
            BinOp::Add => Val::I(a + b),
            BinOp::Sub => Val::I(a - b),
            BinOp::Mul => Val::I(a * b),
            BinOp::Div => {
                if b == 0 {
                    bail!("integer division by zero")
                }
                Val::I(a / b)
            }
            BinOp::Mod => {
                if b == 0 {
                    bail!("integer modulo by zero")
                }
                Val::I(a % b)
            }
            BinOp::Lt => Val::B(a < b),
            BinOp::Gt => Val::B(a > b),
            BinOp::Le => Val::B(a <= b),
            BinOp::Ge => Val::B(a >= b),
            BinOp::Eq => Val::B(a == b),
            BinOp::Ne => Val::B(a != b),
            _ => unreachable!(),
        })
    }
}

/// Combine for reduction operators (host + per-thread locals).
pub fn apply_reduce(op: ReduceOp, cur: Val, rhs: Val) -> Result<Val> {
    Ok(match op {
        ReduceOp::Add | ReduceOp::Count => binop(BinOp::Add, cur, rhs)?,
        ReduceOp::Mul => binop(BinOp::Mul, cur, rhs)?,
        ReduceOp::And => Val::B(cur.as_b()? && rhs.as_b()?),
        ReduceOp::Or => Val::B(cur.as_b()? || rhs.as_b()?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binop_families() {
        assert_eq!(binop(BinOp::Add, Val::I(2), Val::I(3)).unwrap(), Val::I(5));
        assert_eq!(binop(BinOp::Add, Val::I(2), Val::F(0.5)).unwrap(), Val::F(2.5));
        assert_eq!(binop(BinOp::Eq, Val::B(true), Val::B(true)).unwrap(), Val::B(true));
        assert!(binop(BinOp::Add, Val::B(true), Val::I(1)).is_err());
        assert!(binop(BinOp::Div, Val::I(1), Val::I(0)).is_err());
    }

    #[test]
    fn reduce_ops() {
        assert_eq!(apply_reduce(ReduceOp::Add, Val::I(1), Val::I(2)).unwrap(), Val::I(3));
        assert_eq!(apply_reduce(ReduceOp::Mul, Val::F(2.0), Val::F(3.0)).unwrap(), Val::F(6.0));
        assert_eq!(
            apply_reduce(ReduceOp::Or, Val::B(false), Val::B(true)).unwrap(),
            Val::B(true)
        );
        assert_eq!(
            apply_reduce(ReduceOp::And, Val::B(true), Val::B(false)).unwrap(),
            Val::B(false)
        );
        assert_eq!(apply_reduce(ReduceOp::Count, Val::I(7), Val::I(1)).unwrap(), Val::I(8));
    }
}
