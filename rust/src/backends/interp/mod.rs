//! CPU interpreter backend: executes the typed DSL AST directly over CSR.
//!
//! Plays two roles from the paper's evaluation:
//! - **Seq** mode = the single-thread CPU rows (the OpenACC-on-Intel-CPU
//!   analog in Table 4);
//! - **Par** mode = the multicore rows (SYCL-on-Intel-CPU analog): vertex
//!   loops fan out over the thread pool and all shared mutation goes through
//!   the same atomic idioms the generated GPU code uses (`atomicMin`,
//!   `atomicAdd`, OR-flags).
//!
//! Semantics notes (matching §2/§3 of the paper):
//! - `x.p = x.p + e` inside a parallel region is executed as an atomic
//!   reduction (StarPlat emits `atomicAdd` for this idiom);
//! - inside `iterateInBFS` / `iterateInReverse`, `g.neighbors(v)` yields the
//!   BFS-DAG children of `v` (level(w) == level(v)+1);
//! - `fixedPoint until (fin : !prop)` loops until no vertex has `prop` set.

pub mod env;
pub mod eval;

use crate::dsl::ast::*;
use crate::graph::csr::{Graph, Node};
use crate::sema::TypedFunction;
use anyhow::{anyhow, bail, Result};
use env::{Env, PropData, Val};
use eval::{eval, EvalCtx};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Seq,
    Par,
}

/// External argument bindings for a DSL function invocation.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub scalars: std::collections::HashMap<String, Val>,
    pub sets: std::collections::HashMap<String, Vec<Node>>,
}

impl Args {
    pub fn scalar(mut self, name: &str, v: Val) -> Self {
        self.scalars.insert(name.to_string(), v);
        self
    }
    pub fn node(self, name: &str, v: Node) -> Self {
        self.scalar(name, Val::I(v as i64))
    }
    pub fn set(mut self, name: &str, vs: Vec<Node>) -> Self {
        self.sets.insert(name.to_string(), vs);
        self
    }
}

/// Execution result: output properties + optional scalar return.
#[derive(Debug)]
pub struct Output {
    pub props: std::collections::HashMap<String, PropData>,
    pub ret: Option<Val>,
}

impl Output {
    pub fn prop_f64(&self, name: &str) -> Vec<f64> {
        self.props.get(name).map(|p| p.to_f64_vec()).unwrap_or_default()
    }
    pub fn prop_i64(&self, name: &str) -> Vec<i64> {
        self.props.get(name).map(|p| p.to_i64_vec()).unwrap_or_default()
    }
}

/// Run a type-checked DSL function on a graph.
pub fn run(tf: &TypedFunction, g: &Graph, args: &Args, mode: Mode) -> Result<Output> {
    let threads = match mode {
        Mode::Seq => 1,
        Mode::Par => crate::util::pool::default_threads(),
    };
    let mut env = Env::new(g, tf, threads)?;
    // bind scalar / set params
    for p in &tf.func.params {
        match &p.ty {
            Type::Graph => {}
            Type::PropNode(_) | Type::PropEdge(_) => {} // allocated by Env::new
            Type::SetN(_) => {
                let vs = args
                    .sets
                    .get(&p.name)
                    .ok_or_else(|| anyhow!("missing SetN argument `{}`", p.name))?;
                env.bind_set(&p.name, vs.clone());
            }
            _ => {
                let v = args
                    .scalars
                    .get(&p.name)
                    .ok_or_else(|| anyhow!("missing scalar argument `{}`", p.name))?;
                env.set_scalar(&p.name, coerce(*v, &p.ty)?);
            }
        }
    }
    let mut interp = Interp { env, ret: None };
    interp.exec_block(&tf.func.body)?;
    Ok(Output { props: interp.env.take_props(), ret: interp.ret })
}

/// Coerce a value to a declared scalar type (C-style): `float x = g.num_nodes()`
/// must produce a float cell so later divisions stay floating-point.
fn coerce(v: Val, ty: &Type) -> Result<Val> {
    Ok(match crate::ir::ScalarTy::of(ty) {
        crate::ir::ScalarTy::F32 | crate::ir::ScalarTy::F64 => Val::F(v.as_f()?),
        crate::ir::ScalarTy::Bool => v, // type checker guarantees bool
        _ => match v {
            Val::B(_) => v,
            _ => Val::I(v.as_i()?),
        },
    })
}

struct Interp<'g> {
    env: Env<'g>,
    ret: Option<Val>,
}

impl<'g> Interp<'g> {
    /// Host-context (sequential) execution.
    fn exec_block(&mut self, b: &[Stmt]) -> Result<()> {
        for s in b {
            if self.ret.is_some() {
                return Ok(());
            }
            self.exec_stmt(s)?;
        }
        Ok(())
    }

    fn exec_stmt(&mut self, s: &Stmt) -> Result<()> {
        match s {
            Stmt::Decl { ty, name, init, .. } => {
                if ty.is_prop() {
                    self.env.alloc_prop(name, ty)?;
                } else {
                    let v = match init {
                        Some(e) => coerce(self.host_eval(e)?, ty)?,
                        None => Val::zero_of(ty),
                    };
                    self.env.declare_scalar(name, v);
                }
                Ok(())
            }
            Stmt::Assign { target, value, .. } => match target {
                LValue::Var(v) if self.env.is_prop(v) => {
                    // whole-property copy
                    let Expr::Var(src) = value else { bail!("property copy needs a property rhs") };
                    self.env.copy_prop(v, src)
                }
                LValue::Var(v) => {
                    let val = self.host_eval(value)?;
                    self.env.set_scalar(v, val);
                    Ok(())
                }
                LValue::Prop { obj, prop } => {
                    // e.g. `src.sigma = 1;` on the host
                    let idx = self.env.scalar(obj)?.as_i()? as usize;
                    let val = self.host_eval(value)?;
                    self.env.prop(prop)?.store(idx, val);
                    Ok(())
                }
            },
            Stmt::Reduce { target, op, value, .. } => {
                let LValue::Var(v) = target else { bail!("host reduction target must be scalar") };
                let cur = self.env.scalar(v)?;
                let rhs = self.host_eval(value)?;
                self.env.set_scalar(v, eval::apply_reduce(*op, cur, rhs)?);
                Ok(())
            }
            Stmt::AttachNodeProperty { inits, .. } => {
                let n = self.env.g.num_nodes();
                for (prop, e) in inits {
                    let v = self.host_eval(e)?;
                    let arr = self.env.prop(prop)?;
                    let threads = self.env.threads;
                    crate::util::pool::parallel_for(arr.len().max(n), threads, |i| {
                        arr.store(i, v);
                    });
                }
                Ok(())
            }
            Stmt::For { iter, body, parallel, .. } => self.exec_for(iter, body, *parallel),
            Stmt::IterateBFS { var, from, body, reverse, .. } => {
                self.exec_bfs(var, from, body, reverse.as_ref())
            }
            Stmt::FixedPoint { var, cond, body, .. } => {
                let prop = crate::ir::or_flag_prop(cond)
                    .ok_or_else(|| anyhow!("unsupported fixedPoint condition form"))?;
                self.env.set_scalar(var, Val::B(false));
                let max_iters = 4 * self.env.g.num_nodes() + 16;
                for _ in 0..max_iters {
                    self.exec_block(body)?;
                    // finished when no vertex has `prop` set (logical-OR flag)
                    if !self.env.prop(&prop)?.any_true() {
                        self.env.set_scalar(var, Val::B(true));
                        return Ok(());
                    }
                }
                bail!("fixedPoint did not converge after {max_iters} iterations")
            }
            Stmt::DoWhile { body, cond, .. } => {
                loop {
                    self.exec_block(body)?;
                    if self.ret.is_some() || !self.host_eval(cond)?.as_b()? {
                        return Ok(());
                    }
                }
            }
            Stmt::While { cond, body, .. } => {
                while self.host_eval(cond)?.as_b()? {
                    self.exec_block(body)?;
                    if self.ret.is_some() {
                        return Ok(());
                    }
                }
                Ok(())
            }
            Stmt::If { cond, then, els, .. } => {
                if self.host_eval(cond)?.as_b()? {
                    self.exec_block(then)
                } else if let Some(e) = els {
                    self.exec_block(e)
                } else {
                    Ok(())
                }
            }
            Stmt::Return { value, .. } => {
                self.ret = Some(self.host_eval(value)?);
                Ok(())
            }
            Stmt::MinMaxAssign { .. } => bail!("Min/Max construct outside a parallel loop"),
        }
    }

    fn host_eval(&self, e: &Expr) -> Result<Val> {
        let ctx = EvalCtx::host(&self.env);
        eval(e, &ctx)
    }

    /// Sequential `for` at host level iterates sets or nodes; parallel
    /// `forall` becomes a vertex-parallel kernel.
    fn exec_for(&mut self, iter: &Iterator_, body: &[Stmt], parallel: bool) -> Result<()> {
        let domain: Vec<Node> = match &iter.source {
            IterSource::Nodes { .. } => (0..self.env.g.num_nodes() as Node).collect(),
            IterSource::Set { set } => self.env.set_items(set)?,
            IterSource::Neighbors { of, .. } => {
                let v = self.env.scalar(of)?.as_i()? as Node;
                self.env.g.neighbors(v).to_vec()
            }
            IterSource::NodesTo { of, .. } => {
                let v = self.env.scalar(of)?.as_i()? as Node;
                self.env.g.in_neighbors(v).to_vec()
            }
        };
        if !parallel {
            // host-sequential loop (e.g. `for (src in sourceSet)`)
            for v in domain {
                self.env.declare_scalar(&iter.var, Val::I(v as i64));
                if let Some(f) = &iter.filter {
                    let ctx = EvalCtx::host(&self.env).with_element(&iter.var, v);
                    if !eval(f, &ctx)?.as_b()? {
                        continue;
                    }
                }
                self.exec_block(body)?;
            }
            return Ok(());
        }
        // device kernel: vertex-parallel over the domain
        let env = &self.env;
        let threads = env.threads;
        let err = std::sync::Mutex::new(None::<anyhow::Error>);
        let filter = iter.filter.as_ref();
        crate::util::pool::parallel_for_dynamic(domain.len(), threads, 64, |i| {
            let v = domain[i];
            let ctx = EvalCtx::device(env).with_element(&iter.var, v);
            let r = (|| -> Result<()> {
                if let Some(f) = filter {
                    if !eval(f, &ctx)?.as_b()? {
                        return Ok(());
                    }
                }
                exec_device_block(env, body, &ctx)
            })();
            if let Err(e) = r {
                *err.lock().unwrap() = Some(e);
            }
        });
        match err.into_inner().unwrap() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// `iterateInBFS … iterateInReverse` (paper §3.4): level-synchronous
    /// sweeps with DAG-children neighbor semantics.
    fn exec_bfs(
        &mut self,
        var: &str,
        from: &str,
        body: &[Stmt],
        reverse: Option<&(Expr, Block)>,
    ) -> Result<()> {
        let src = self.env.scalar(from)?.as_i()? as Node;
        let levels = crate::algorithms::reference::bfs_levels(self.env.g, src);
        let maxl = levels
            .iter()
            .filter(|&&l| l != crate::algorithms::reference::INF)
            .copied()
            .max()
            .unwrap_or(0);
        // bucket vertices by level
        let mut by_level: Vec<Vec<Node>> = vec![Vec::new(); (maxl + 1) as usize];
        for (v, &l) in levels.iter().enumerate() {
            if l != crate::algorithms::reference::INF {
                by_level[l as usize].push(v as Node);
            }
        }
        let env = &self.env;
        let threads = env.threads;
        // forward sweep
        for frontier in &by_level {
            let err = std::sync::Mutex::new(None::<anyhow::Error>);
            crate::util::pool::parallel_for(frontier.len(), threads, |i| {
                let v = frontier[i];
                let ctx = EvalCtx::device(env).with_element(var, v).with_bfs(&levels, true);
                if let Err(e) = exec_device_block(env, body, &ctx) {
                    *err.lock().unwrap() = Some(e);
                }
            });
            if let Some(e) = err.into_inner().unwrap() {
                return Err(e);
            }
        }
        // reverse sweep
        if let Some((cond, rbody)) = reverse {
            for frontier in by_level.iter().rev() {
                let err = std::sync::Mutex::new(None::<anyhow::Error>);
                crate::util::pool::parallel_for(frontier.len(), threads, |i| {
                    let v = frontier[i];
                    let ctx = EvalCtx::device(env).with_element(var, v).with_bfs(&levels, true);
                    let r = (|| -> Result<()> {
                        if !eval(cond, &ctx)?.as_b()? {
                            return Ok(());
                        }
                        exec_device_block(env, rbody, &ctx)
                    })();
                    if let Err(e) = r {
                        *err.lock().unwrap() = Some(e);
                    }
                });
                if let Some(e) = err.into_inner().unwrap() {
                    return Err(e);
                }
            }
        }
        Ok(())
    }
}

/// Execute a kernel body for one element (thread context). All shared
/// mutation is atomic; local declarations live in the per-thread `ctx`.
fn exec_device_block(env: &Env<'_>, body: &[Stmt], ctx: &EvalCtx<'_, '_>) -> Result<()> {
    let mut ctx = ctx.child();
    for s in body {
        exec_device_stmt(env, s, &mut ctx)?;
    }
    Ok(())
}

fn exec_device_stmt(env: &Env<'_>, s: &Stmt, ctx: &mut EvalCtx<'_, '_>) -> Result<()> {
    match s {
        Stmt::Decl { ty, name, init, .. } => {
            let v = match init {
                Some(e) => coerce(eval(e, ctx)?, ty)?,
                None => Val::zero_of(ty),
            };
            ctx.declare_local(name, v);
            Ok(())
        }
        Stmt::Assign { target, value, .. } => {
            // read-modify-write on shared state becomes an atomic reduction
            if let Some((t, op, rhs)) = crate::ir::analyze::as_reduction(target, value) {
                if matches!(&t, LValue::Prop { .. }) {
                    return device_reduce(env, &t, op, &rhs, ctx);
                }
            }
            match target {
                LValue::Var(v) => {
                    let val = eval(value, ctx)?;
                    if ctx.has_local(v) {
                        ctx.set_local(v, val);
                    } else {
                        // scalar shared write (rare; e.g. flags) — atomic store
                        env.scalar_store(v, val)?;
                    }
                    Ok(())
                }
                LValue::Prop { obj, prop } => {
                    let idx = ctx.element(obj)?;
                    let val = eval(value, ctx)?;
                    env.prop(prop)?.store(idx as usize, val);
                    Ok(())
                }
            }
        }
        Stmt::Reduce { target, op, value, .. } => device_reduce(env, target, *op, value, ctx),
        Stmt::MinMaxAssign { kind, target, compare, extra, .. } => {
            let LValue::Prop { obj, prop } = target else {
                bail!("Min/Max target must be a property")
            };
            let idx = ctx.element(obj)? as usize;
            let proposed = eval(compare, ctx)?;
            let improved = env.prop(prop)?.atomic_min_max(idx, proposed, *kind);
            if improved {
                for (t, v) in extra {
                    let val = eval(v, ctx)?;
                    match t {
                        LValue::Prop { obj, prop } => {
                            let i = ctx.element(obj)? as usize;
                            env.prop(prop)?.store(i, val);
                        }
                        LValue::Var(name) => env.scalar_store(name, val)?,
                    }
                }
            }
            Ok(())
        }
        Stmt::For { iter, body, .. } => {
            // nested loops run sequentially within the thread (same-kernel
            // folding, as the paper's generated code does)
            let (domain, edge_base): (Vec<Node>, Option<usize>) = match &iter.source {
                IterSource::Neighbors { of, .. } => {
                    let v = ctx.element(of)? as Node;
                    if ctx.bfs_dag() {
                        // BFS context: DAG children only
                        let levels = ctx.levels().unwrap();
                        let kids: Vec<Node> = env
                            .g
                            .neighbors(v)
                            .iter()
                            .copied()
                            .filter(|&w| levels[w as usize] == levels[v as usize] + 1)
                            .collect();
                        (kids, None)
                    } else {
                        (env.g.neighbors(v).to_vec(), Some(env.g.offsets[v as usize] as usize))
                    }
                }
                IterSource::NodesTo { of, .. } => {
                    let v = ctx.element(of)? as Node;
                    (env.g.in_neighbors(v).to_vec(), None)
                }
                IterSource::Nodes { .. } => ((0..env.g.num_nodes() as Node).collect(), None),
                IterSource::Set { set } => (env.set_items(set)?, None),
            };
            // Mutate the context in place (save/restore the loop bindings)
            // so writes to enclosing locals — e.g. PageRank's `sum`
            // accumulator — are visible outside each iteration.
            let saved = ctx.save_loop_state(&iter.var);
            let mut result = Ok(());
            for (k, w) in domain.iter().enumerate() {
                ctx.bind_element(&iter.var, *w);
                // current edge id for `g.get_edge(v, w)` in this iteration
                if let Some(base) = edge_base {
                    // adj is sorted; k-th neighbor = k-th out-edge
                    ctx.set_current_edge(base + k);
                }
                if let Some(f) = &iter.filter {
                    match eval(f, ctx) {
                        Ok(v) if !v.as_b()? => continue,
                        Ok(_) => {}
                        Err(e) => {
                            result = Err(e);
                            break;
                        }
                    }
                }
                for st in body {
                    if let Err(e) = exec_device_stmt(env, st, ctx) {
                        result = Err(e);
                        break;
                    }
                }
                if result.is_err() {
                    break;
                }
            }
            ctx.restore_loop_state(&iter.var, saved);
            result
        }
        Stmt::If { cond, then, els, .. } => {
            if eval(cond, ctx)?.as_b()? {
                for st in then {
                    exec_device_stmt(env, st, ctx)?;
                }
            } else if let Some(e) = els {
                for st in e {
                    exec_device_stmt(env, st, ctx)?;
                }
            }
            Ok(())
        }
        other => bail!("statement not allowed inside a parallel region: {other:?}"),
    }
}

fn device_reduce(
    env: &Env<'_>,
    target: &LValue,
    op: ReduceOp,
    value: &Expr,
    ctx: &mut EvalCtx<'_, '_>,
) -> Result<()> {
    let rhs = eval(value, ctx)?;
    match target {
        LValue::Var(v) => {
            if ctx.has_local(v) {
                let cur = ctx.local(v)?;
                ctx.set_local(v, eval::apply_reduce(op, cur, rhs)?);
            } else {
                env.scalar_reduce(v, op, rhs)?;
            }
            Ok(())
        }
        LValue::Prop { obj, prop } => {
            let idx = ctx.element(obj)? as usize;
            env.prop(prop)?.atomic_reduce(idx, op, rhs);
            Ok(())
        }
    }
}
