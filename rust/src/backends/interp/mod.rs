//! CPU interpreter backend: executes DSL programs over CSR through a
//! **compile → execute** pipeline.
//!
//! Plays two roles from the paper's evaluation:
//! - **Seq** mode = the single-thread CPU rows (the OpenACC-on-Intel-CPU
//!   analog in Table 4);
//! - **Par** mode = the multicore rows (SYCL-on-Intel-CPU analog): vertex
//!   loops fan out over the thread pool and all shared mutation goes through
//!   the same atomic idioms the generated GPU code uses (`atomicMin`,
//!   `atomicAdd`, OR-flags).
//!
//! # Pipeline
//!
//! [`run`] first lowers the typed AST to a slot-resolved program
//! ([`compile`]): every property, scalar, local, and loop element is interned
//! into a dense `u32` index, so the execution loop below performs **zero
//! string lookups** — property access is `Vec` indexing, locals live in a
//! flat per-worker register frame, and the per-element context
//! ([`eval::EvalCtx`]) is a small `Copy` struct (nested scopes no longer
//! clone any maps).
//!
//! # Threads
//!
//! Par mode uses `STARPLAT_THREADS` workers when set, otherwise the machine's
//! available parallelism (see [`crate::util::pool::default_threads`]).
//! [`run_with_threads`] pins an explicit worker count — the Seq/Par parity
//! suite uses it to check determinism across 1/2/8 workers — and
//! [`run_with_opts`] additionally exposes the frontier knob below.
//!
//! # Frontier engine
//!
//! `fixedPoint` loops whose body is the canonical relaxation shape
//! (`forall` filtered on a bool flag, then `flag = flag_nxt`, then
//! `attach(flag_nxt = False)`) are executed as a **sparse worklist**: only
//! flagged vertices are processed, and the next worklist is gathered from
//! exactly the neighborhoods the kernel can have written.
//!
//! - **Eligibility** ([`compile::FrontierInfo`]): all flag-nxt writes must
//!   land on the loop element, its out-neighbors (push kernels, e.g.
//!   SSSP/CC), or its in-neighbors (reverse-CSR pull kernels — the gather
//!   then walks `rev_offsets/srcList`). Kernels writing 2-hop neighborhoods
//!   stay dense.
//! - **Parallel claim-buffer gather**: after each sweep, workers scan the
//!   frontier's neighborhoods with per-worker claim buffers
//!   ([`crate::util::pool::try_parallel_collect_in`], recycled through the
//!   run's arena); an atomic swap on the ping-pong bit
//!   ([`env::PropData::claim_true`]) makes each claim exclusive, and the
//!   buffers concatenate via prefix offsets into the next worklist. Small
//!   frontiers (< [`frontier_par_min`], default 1024 now that dispatch is a
//!   condvar wake on the persistent pool rather than a thread spawn) keep
//!   the sequential scan — even a wake only pays for itself past that size.
//! - **Density fallback**: when the frontier exceeds |V| / 4 the executor
//!   uses a dense filtered sweep, so mesh-like graphs (road networks) get
//!   the asymptotic win while dense frontiers keep the streaming sweep.
//! - **Direction optimization**: fixedPoints whose kernel is the canonical
//!   relaxation ([`compile::RelaxInfo`]) may run **pull** rounds — a dense
//!   reverse-CSR scan where each vertex min-reduces `dist (+ weight)` over
//!   flagged in-neighbors and commits to its own slot with a plain store —
//!   chosen per round from the frontier's out-edge volume (enter at
//!   mf·4 ≥ m, leave at mf·8 < m: ×2 hysteresis). `iterateInBFS` levels
//!   switch push/bottom-up the same way with Beamer's α=14 / β=24 pair.
//!   `STARPLAT_DIRECTION=push|pull` (or [`ExecOpts::direction`]) pins the
//!   mode; programs with no redirectable kernel always push.
//! - **Delta-stepping**: weighted canonical relaxations may opt into
//!   bucketed priority worklists (`STARPLAT_DELTA=auto|<width>` /
//!   [`ExecOpts::delta`]): buckets keyed by `dist / Δ`, light edges
//!   (weight ≤ Δ) drained to a fixpoint per bucket before heavy edges relax
//!   once, stale entries lazily skipped. Negative weights or a weight-free
//!   relaxation fall back to the schedules above at run time.
//! - Results are bit-identical to the dense schedule: the kernel body itself
//!   is unchanged, only the set of vertices known to fail the filter is
//!   skipped. `STARPLAT_FRONTIER=0` (or [`ExecOpts::frontier`] = false)
//!   forces the dense schedule — the bench harness times both paths.
//!
//! # Compiled BFS levels
//!
//! `iterateInBFS` discovers levels **in the compiled form itself**: a
//! claim-buffer expansion loop CAS-labels each next level
//! ([`env::Levels`]) and builds the per-level buckets directly — the
//! buckets the forward sweep walks and the reverse sweep replays backwards.
//! This replaces the old host-side `reference::bfs_levels` call (a separate
//! sequential traversal) plus its O(V) bucketing scan with one parallel
//! discovery. Discovery settles every label before any body sweep runs,
//! because nested BFS-DAG loops read levels two hops from the current
//! frontier.
//!
//! Semantics notes (matching §2/§3 of the paper):
//! - `x.p = x.p + e` inside a parallel region is executed as an atomic
//!   reduction (StarPlat emits `atomicAdd` for this idiom);
//! - inside `iterateInBFS` / `iterateInReverse`, `g.neighbors(v)` yields the
//!   BFS-DAG children of `v` (level(w) == level(v)+1);
//! - `fixedPoint until (fin : !prop)` loops until no vertex has `prop` set.
//!
//! The end-to-end pipeline (parse → sema → plan → render, and where this
//! backend sits in it) is documented in `docs/ARCHITECTURE.md`.

pub mod batch;
pub mod compile;
pub mod env;
pub mod eval;

use crate::graph::csr::{Graph, Node};
use crate::ir::ScalarTy;
use crate::sema::TypedFunction;
use crate::util::cancel::{CancelToken, Interrupt};
use crate::util::fault::{FaultPlan, FaultSite};
use crate::util::pool::PoolInterrupt;
use anyhow::{anyhow, bail, Result};
use compile::{
    CExpr, CKernel, CUpdate, DevIter, DevStmt, FrontierInfo, HostIter, HostStmt, Idx, ParamBind,
    RelaxInfo,
};
use env::{Env, Levels, PropData, Val};
use eval::{apply_reduce, eval, node_of, EvalCtx, NO_EDGE};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Seq,
    Par,
}

/// Below this many frontier vertices the post-sweep gather stays sequential:
/// even a condvar wake costs more than scanning a few hundred adjacency rows.
/// The persistent pool dropped dispatch from a thread spawn (~tens of µs ×
/// workers) to a wake (single-digit µs), so the default is 1024 — a quarter
/// of the old spawn-era 4096. `STARPLAT_FRONTIER_PAR_MIN` overrides it (the
/// bench harness sweeps the knob when re-tuning).
pub const FRONTIER_PAR_MIN_DEFAULT: usize = 1024;

/// The effective small-frontier threshold (cached after first read).
pub fn frontier_par_min() -> usize {
    static MIN: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *MIN.get_or_init(|| {
        std::env::var("STARPLAT_FRONTIER_PAR_MIN")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(FRONTIER_PAR_MIN_DEFAULT)
    })
}

/// Typed failure classes of one interpreter request. Surfaced inside the
/// [`anyhow::Error`] the run returns — callers (the execution service)
/// recover the variant with `err.downcast_ref::<ExecError>()`.
#[derive(Clone, Debug, PartialEq, Eq, thiserror::Error)]
pub enum ExecError {
    /// The run's [`CancelToken`] was cancelled.
    #[error("request cancelled")]
    Cancelled,
    /// The run's deadline passed before it finished.
    #[error("deadline exceeded")]
    DeadlineExceeded,
    /// A pool worker panicked; the panic was confined to this run (the pool
    /// and shared graph stay healthy) and its message is preserved.
    #[error("worker panicked: {0}")]
    WorkerPanic(String),
    /// An injected fault tripped at the named site (see
    /// [`crate::util::fault`]).
    #[error("injected fault at {0}")]
    Fault(&'static str),
}

impl From<Interrupt> for ExecError {
    fn from(i: Interrupt) -> ExecError {
        match i {
            Interrupt::Cancelled => ExecError::Cancelled,
            Interrupt::DeadlineExceeded => ExecError::DeadlineExceeded,
        }
    }
}

impl From<PoolInterrupt> for ExecError {
    fn from(i: PoolInterrupt) -> ExecError {
        match i {
            PoolInterrupt::Cancelled => ExecError::Cancelled,
            PoolInterrupt::DeadlineExceeded => ExecError::DeadlineExceeded,
            PoolInterrupt::Panicked(msg) => ExecError::WorkerPanic(msg),
        }
    }
}

/// Is this a cooperative interrupt (cancel / deadline)? Interrupts must
/// always propagate; other sweep failures may instead trigger the dense
/// schedule fallback in [`Exec::frontier_loop`].
fn is_interrupt(e: &anyhow::Error) -> bool {
    matches!(
        e.downcast_ref::<ExecError>(),
        Some(ExecError::Cancelled | ExecError::DeadlineExceeded)
    )
}

/// Convert a pool interrupt into the typed error anyhow carries.
fn pool_err(i: PoolInterrupt) -> anyhow::Error {
    anyhow::Error::new(ExecError::from(i))
}

/// Traversal direction policy for frontier rounds and BFS levels.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Direction {
    /// Beamer-style switching on frontier size / scanned-edge estimates
    /// (with hysteresis) — the default
    #[default]
    Auto,
    /// always walk the frontier's out-edges (the classic top-down sweep)
    Push,
    /// always scan unvisited/all vertices reading in-edges over
    /// `rev_offsets/srcList` (bottom-up); programs with no pull-eligible
    /// kernel ignore the force and stay push
    Pull,
}

impl Direction {
    /// Parse `STARPLAT_DIRECTION` (`auto` / `push` / `pull`; anything else,
    /// including unset, means `Auto`).
    pub fn from_env() -> Direction {
        match std::env::var("STARPLAT_DIRECTION") {
            Ok(v) if v.eq_ignore_ascii_case("push") => Direction::Push,
            Ok(v) if v.eq_ignore_ascii_case("pull") => Direction::Pull,
            _ => Direction::Auto,
        }
    }
}

/// Delta-stepping policy for relaxation-shaped fixedPoints.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DeltaMode {
    /// never bucket; run the sweep/frontier schedule — the default
    #[default]
    Off,
    /// bucket with the degree-based default width
    /// `Δ = max(1, avg_weight / avg_degree)`
    Auto,
    /// bucket with an explicit width (> 0)
    Width(i64),
}

impl DeltaMode {
    /// Parse `STARPLAT_DELTA` (`auto`, a positive integer width, or
    /// `0`/unset/garbage = off).
    pub fn from_env() -> DeltaMode {
        match std::env::var("STARPLAT_DELTA") {
            Ok(v) if v.eq_ignore_ascii_case("auto") => DeltaMode::Auto,
            Ok(v) => match v.parse::<i64>() {
                Ok(w) if w > 0 => DeltaMode::Width(w),
                _ => DeltaMode::Off,
            },
            Err(_) => DeltaMode::Off,
        }
    }
}

/// Execution knobs beyond the worker count.
#[derive(Clone, Debug)]
pub struct ExecOpts {
    /// worker count; 0 = [`crate::util::pool::default_threads`]
    pub threads: usize,
    /// allow the sparse frontier schedule for eligible fixedPoints (default
    /// true; `STARPLAT_FRONTIER=0` in the environment also disables it)
    pub frontier: bool,
    /// cooperative cancellation (deadline + explicit cancel), polled at
    /// host-statement, loop-iteration, and pool block boundaries
    pub cancel: Option<CancelToken>,
    /// deterministic fault injection; `None` falls back to `STARPLAT_FAULT`
    /// (use [`FaultPlan::off`] to force injection off regardless)
    pub fault: Option<FaultPlan>,
    /// traversal direction policy; `None` falls back to `STARPLAT_DIRECTION`
    pub direction: Option<Direction>,
    /// delta-stepping policy; `None` falls back to `STARPLAT_DELTA`
    pub delta: Option<DeltaMode>,
    /// sequential/parallel cutover override; `None` falls back to the cached
    /// `STARPLAT_FRONTIER_PAR_MIN` read (tests override here instead of
    /// mutating the process environment)
    pub frontier_par_min: Option<usize>,
    /// lane width for [`batch::run_batch_with_opts`] (1..=64); `None` falls
    /// back to `STARPLAT_BATCH` (default 64). Single runs ignore it.
    pub batch: Option<usize>,
}

impl Default for ExecOpts {
    fn default() -> Self {
        ExecOpts {
            threads: 0,
            frontier: true,
            cancel: None,
            fault: None,
            direction: None,
            delta: None,
            frontier_par_min: None,
            batch: None,
        }
    }
}

/// External argument bindings for a DSL function invocation.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub scalars: std::collections::HashMap<String, Val>,
    pub sets: std::collections::HashMap<String, Vec<Node>>,
}

impl Args {
    pub fn scalar(mut self, name: &str, v: Val) -> Self {
        self.scalars.insert(name.to_string(), v);
        self
    }
    pub fn node(self, name: &str, v: Node) -> Self {
        self.scalar(name, Val::I(v as i64))
    }
    pub fn set(mut self, name: &str, vs: Vec<Node>) -> Self {
        self.sets.insert(name.to_string(), vs);
        self
    }
}

/// Per-run execution statistics: the graceful-degradation accounting the
/// service and bench harness surface.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    /// sparse (frontier) fixedPoint schedules abandoned for the dense
    /// schedule after an injected or real sweep fault
    pub fallbacks: u64,
    /// push↔pull direction changes across frontier rounds and BFS levels
    pub direction_switches: u64,
    /// rounds / levels executed in the pull (reverse-CSR) direction
    pub pull_rounds: u64,
    /// did any fixedPoint run the delta-stepping schedule?
    pub delta_used: bool,
    /// lanes sharing the traversal that produced this output (0 for single
    /// runs; set by [`batch::run_batch_with_opts`] to the wave's lane count)
    pub batched_roots: u64,
}

/// Execution result: output properties + optional scalar return.
#[derive(Debug)]
pub struct Output {
    pub props: std::collections::HashMap<String, PropData>,
    pub ret: Option<Val>,
    pub stats: ExecStats,
}

impl Output {
    pub fn prop_f64(&self, name: &str) -> Vec<f64> {
        self.props.get(name).map(|p| p.to_f64_vec()).unwrap_or_default()
    }
    pub fn prop_i64(&self, name: &str) -> Vec<i64> {
        self.props.get(name).map(|p| p.to_i64_vec()).unwrap_or_default()
    }
}

/// Run a type-checked DSL function on a graph.
pub fn run(tf: &TypedFunction, g: &Graph, args: &Args, mode: Mode) -> Result<Output> {
    let threads = match mode {
        Mode::Seq => 1,
        Mode::Par => crate::util::pool::default_threads(),
    };
    run_with_threads(tf, g, args, threads)
}

/// [`run`] with an explicit worker count (1 = sequential). The parity test
/// suite sweeps thread counts to check scheduling-independence of results.
pub fn run_with_threads(
    tf: &TypedFunction,
    g: &Graph,
    args: &Args,
    threads: usize,
) -> Result<Output> {
    run_with_opts(tf, g, args, ExecOpts { threads, ..ExecOpts::default() })
}

/// Does the environment allow the sparse frontier schedule?
/// (`STARPLAT_FRONTIER=0` / `off` forces dense sweeps everywhere.) Public
/// so the bench harness labels its cells with the same gate it runs under.
pub fn frontier_env_enabled() -> bool {
    match std::env::var("STARPLAT_FRONTIER") {
        Ok(v) => !(v == "0" || v.eq_ignore_ascii_case("off")),
        Err(_) => true,
    }
}

/// [`run`] with full execution options ([`ExecOpts`]). The bench harness
/// uses this to time the frontier and dense schedules on the same program.
pub fn run_with_opts(tf: &TypedFunction, g: &Graph, args: &Args, opts: ExecOpts) -> Result<Output> {
    let threads =
        if opts.threads == 0 { crate::util::pool::default_threads() } else { opts.threads };
    let prog = compile::compile(tf)?;
    let mut env = Env::new(g, &prog, threads.max(1));
    env.frontier_enabled = opts.frontier && frontier_env_enabled();
    env.cancel = opts.cancel.clone();
    env.fault = opts.fault.or_else(FaultPlan::from_env);
    env.direction = opts.direction.unwrap_or_else(Direction::from_env);
    env.delta = opts.delta.unwrap_or_else(DeltaMode::from_env);
    if let Some(min) = opts.frontier_par_min {
        env.frontier_par_min = min;
    }
    // bind scalar / set params
    for pb in &prog.params {
        match pb {
            ParamBind::Scalar { name, slot, ty } => {
                let v = args
                    .scalars
                    .get(name)
                    .ok_or_else(|| anyhow!("missing scalar argument `{name}`"))?;
                env.declare_scalar(*slot, coerce_st(*v, *ty)?);
            }
            ParamBind::Set { name, slot } => {
                let vs = args
                    .sets
                    .get(name)
                    .ok_or_else(|| anyhow!("missing SetN argument `{name}`"))?;
                env.bind_set(*slot, vs.clone());
            }
        }
    }
    let mut ex = Exec { env, ret: None };
    ex.block(&prog.body)?;
    let stats = ExecStats {
        fallbacks: ex.env.fallbacks.load(std::sync::atomic::Ordering::Relaxed),
        direction_switches: ex.env.direction_switches.load(std::sync::atomic::Ordering::Relaxed),
        pull_rounds: ex.env.pull_rounds.load(std::sync::atomic::Ordering::Relaxed),
        delta_used: ex.env.delta_used.load(std::sync::atomic::Ordering::Relaxed),
        batched_roots: 0,
    };
    Ok(Output { props: ex.env.take_props(), ret: ex.ret, stats })
}

/// Coerce a value to a declared scalar type (C-style): `float x = g.num_nodes()`
/// must produce a float cell so later divisions stay floating-point.
fn coerce_st(v: Val, st: ScalarTy) -> Result<Val> {
    Ok(match st {
        ScalarTy::F32 | ScalarTy::F64 => Val::F(v.as_f()?),
        ScalarTy::Bool => v, // type checker guarantees bool
        _ => match v {
            Val::B(_) => v,
            _ => Val::I(v.as_i()?),
        },
    })
}

// ---------------------------------------------------------------------------
// Host executor
// ---------------------------------------------------------------------------

struct Exec<'g> {
    env: Env<'g>,
    ret: Option<Val>,
}

/// How a sparse frontier loop ended (short of an error).
enum FrontierExit {
    /// Reached the fixpoint; the convergence flag is set.
    Converged,
    /// Abandoned the sparse schedule at an iteration boundary after a sweep
    /// fault — the caller's dense loop continues from the same state.
    FellBack,
}

impl<'g> Exec<'g> {
    /// Host-context (sequential) execution. Every statement boundary is a
    /// cancellation point.
    fn block(&mut self, b: &[HostStmt]) -> Result<()> {
        for s in b {
            self.env.check_cancel()?;
            if self.ret.is_some() {
                return Ok(());
            }
            self.stmt(s)?;
        }
        Ok(())
    }

    fn host_eval(&self, e: &CExpr) -> Result<Val> {
        eval(e, &EvalCtx::new(&self.env), &[])
    }

    fn stmt(&mut self, s: &HostStmt) -> Result<()> {
        match s {
            HostStmt::AllocProp { prop, ty, edge } => {
                self.env.alloc_prop(*prop, *ty, *edge);
                Ok(())
            }
            HostStmt::DeclScalar { slot, ty, init } => {
                let v = match init {
                    Some(e) => coerce_st(self.host_eval(e)?, *ty)?,
                    None => Val::zero_st(*ty),
                };
                self.env.declare_scalar(*slot, v);
                Ok(())
            }
            HostStmt::SetScalar { slot, value } => {
                let v = self.host_eval(value)?;
                self.env.set_scalar(*slot, v);
                Ok(())
            }
            HostStmt::ScalarReduce { slot, op, value } => {
                let cur = self.env.scalar(*slot);
                let rhs = self.host_eval(value)?;
                let v = apply_reduce(*op, cur, rhs)?;
                self.env.set_scalar(*slot, v);
                Ok(())
            }
            HostStmt::PropElemStore { prop, obj, value } => {
                let i = self.env.scalar(*obj).as_i()? as usize;
                let v = self.host_eval(value)?;
                self.env.prop(*prop).store(i, v);
                Ok(())
            }
            HostStmt::PropCopy { dst, src } => {
                self.env.copy_prop(*dst, *src);
                Ok(())
            }
            HostStmt::Attach { inits } => {
                for (p, e) in inits {
                    let v = self.host_eval(e)?;
                    let arr = self.env.prop(*p);
                    crate::util::pool::parallel_for(arr.len(), self.env.threads, |i| {
                        arr.store(i, v);
                    });
                }
                Ok(())
            }
            HostStmt::Kernel(k) => self.launch(k),
            HostStmt::SeqFor { var, source, filter, body } => {
                // host-sequential loop (e.g. `for (src in sourceSet)`)
                let domain: Vec<Node> = match source {
                    HostIter::AllNodes => (0..self.env.g.num_nodes() as Node).collect(),
                    HostIter::Set(s) => self.env.set_items(*s).to_vec(),
                    HostIter::Neighbors { of } => {
                        let v = self.env.scalar(*of).as_i()? as Node;
                        self.env.g.neighbors(v).to_vec()
                    }
                    HostIter::InNeighbors { of } => {
                        let v = self.env.scalar(*of).as_i()? as Node;
                        self.env.g.in_neighbors(v).to_vec()
                    }
                };
                for v in domain {
                    self.env.set_scalar(*var, Val::I(v as i64));
                    if let Some(f) = filter {
                        if !self.host_eval(f)?.as_b()? {
                            continue;
                        }
                    }
                    self.block(body)?;
                    if self.ret.is_some() {
                        return Ok(());
                    }
                }
                Ok(())
            }
            HostStmt::IterateBFS { reg, from, body, reverse, frame_size } => {
                self.exec_bfs(*reg, *from, body, reverse.as_ref(), *frame_size)
            }
            HostStmt::FixedPoint { var, flag, body, frontier } => {
                self.exec_fixed_point(*var, *flag, body, *frontier)
            }
            HostStmt::DoWhile { body, cond } => loop {
                self.block(body)?;
                if self.ret.is_some() || !self.host_eval(cond)?.as_b()? {
                    return Ok(());
                }
            },
            HostStmt::While { cond, body } => {
                while self.host_eval(cond)?.as_b()? {
                    self.block(body)?;
                    if self.ret.is_some() {
                        return Ok(());
                    }
                }
                Ok(())
            }
            HostStmt::If { cond, then, els } => {
                if self.host_eval(cond)?.as_b()? {
                    self.block(then)
                } else {
                    self.block(els)
                }
            }
            HostStmt::Return { value } => {
                self.ret = Some(self.host_eval(value)?);
                Ok(())
            }
        }
    }

    /// Launch a vertex-parallel kernel over its compiled domain.
    fn launch(&self, k: &CKernel) -> Result<()> {
        let env = &self.env;
        match &k.source {
            DevIter::AllNodes => sweep(
                env,
                Domain::Range(env.g.num_nodes()),
                k.reg,
                k.filter.as_ref(),
                &k.body,
                k.frame_size,
                None,
            ),
            DevIter::Set(s) => sweep(
                env,
                Domain::List(env.set_items(*s)),
                k.reg,
                k.filter.as_ref(),
                &k.body,
                k.frame_size,
                None,
            ),
            DevIter::Neighbors { of, .. } => {
                let Idx::Scalar(slot) = of else {
                    bail!("top-level forall over neighbors needs a host node variable")
                };
                let v = env.scalar(*slot).as_i()? as Node;
                sweep(
                    env,
                    Domain::List(env.g.neighbors(v)),
                    k.reg,
                    k.filter.as_ref(),
                    &k.body,
                    k.frame_size,
                    None,
                )
            }
            DevIter::InNeighbors { of } => {
                let Idx::Scalar(slot) = of else {
                    bail!("top-level forall over in-neighbors needs a host node variable")
                };
                let v = env.scalar(*slot).as_i()? as Node;
                sweep(
                    env,
                    Domain::List(env.g.in_neighbors(v)),
                    k.reg,
                    k.filter.as_ref(),
                    &k.body,
                    k.frame_size,
                    None,
                )
            }
        }
    }

    /// `iterateInBFS … iterateInReverse` (paper §3.4): level-synchronous
    /// sweeps with DAG-children neighbor semantics. Levels are discovered by
    /// the compiled form itself: a claim-buffer expansion loop CAS-labels
    /// each next level ([`env::Levels`]) and builds the per-level buckets
    /// directly, replacing the old host-side `reference::bfs_levels` pass
    /// plus its O(V) bucketing scan. Discovery completes *before* any body
    /// sweep runs: a nested BFS-DAG loop (`neighbors` of a DAG child) reads
    /// levels two hops out, so labels must be settled for the whole graph,
    /// not just one level ahead.
    fn exec_bfs(
        &self,
        reg: u32,
        from: u32,
        body: &[DevStmt],
        reverse: Option<&(CExpr, Vec<DevStmt>)>,
        frame_size: usize,
    ) -> Result<()> {
        let env = &self.env;
        let n = env.g.num_nodes();
        let src = env.scalar(from).as_i()? as usize;
        if src >= n {
            bail!("iterateInBFS source {src} out of range (|V| = {n})");
        }
        let levels = Levels::new(n);
        levels.set(src, 0);
        let mut frontier: Vec<Node> = vec![src as Node];
        let mut by_level: Vec<Vec<Node>> = Vec::new();
        let mut depth: i32 = 0;
        // Beamer direction-optimizing discovery: `mf` estimates the edges a
        // push step would scan (Σ out-degree over the frontier), `mu` the
        // edges still hanging off unvisited vertices. Switch to the pull
        // (bottom-up) scan when the frontier's edge frontier dominates
        // (mf > mu/α), and back to push when the frontier thins out
        // (|frontier| < n/β) — the classic α=14 / β=24 hysteresis pair.
        // Forced directions (`STARPLAT_DIRECTION` / ExecOpts) pin the mode.
        let mut mf: u64 = env.g.out_degree(src as Node) as u64;
        let mut mu: u64 = (env.g.num_edges() as u64).saturating_sub(mf);
        let mut pulling = env.direction == Direction::Pull;
        while !frontier.is_empty() {
            env.check_cancel()?; // level boundary = cancellation point
            let want_pull = match env.direction {
                Direction::Push => false,
                Direction::Pull => true,
                Direction::Auto => {
                    if pulling {
                        // hysteresis: stay bottom-up until the frontier thins
                        frontier.len() >= n / 24
                    } else {
                        mf > mu / 14
                    }
                }
            };
            if want_pull != pulling {
                env.note_direction_switch();
                pulling = want_pull;
            }
            let parallel = env.threads > 1;
            let next: Vec<Node> = if pulling {
                env.note_pull_round();
                // bottom-up: every unvisited vertex checks its in-edges for
                // a parent on the current level and claims itself. Early
                // exit on the first parent found is the pull win.
                let discover = |v: usize, out: &mut Vec<Node>| {
                    if levels.get(v) != -1 {
                        return;
                    }
                    for &u in env.g.in_neighbors(v as Node) {
                        if levels.get(u as usize) == depth {
                            if levels.claim(v, depth + 1) {
                                out.push(v as Node);
                            }
                            break;
                        }
                    }
                };
                if !parallel || n < env.frontier_par_min {
                    let mut out = Vec::new();
                    for v in 0..n {
                        discover(v, &mut out);
                    }
                    out
                } else {
                    crate::util::pool::try_parallel_collect_in(
                        n,
                        env.threads,
                        1024,
                        env.cancel.as_ref(),
                        &env.buf_arena,
                        discover,
                    )
                    .map_err(pool_err)?
                }
            } else {
                let discover = |i: usize, out: &mut Vec<Node>| {
                    for &w in env.g.neighbors(frontier[i]) {
                        if levels.claim(w as usize, depth + 1) {
                            out.push(w);
                        }
                    }
                };
                if !parallel || frontier.len() < env.frontier_par_min {
                    let mut out = Vec::new();
                    for i in 0..frontier.len() {
                        discover(i, &mut out);
                    }
                    out
                } else {
                    crate::util::pool::try_parallel_collect_in(
                        frontier.len(),
                        env.threads,
                        64,
                        env.cancel.as_ref(),
                        &env.buf_arena,
                        discover,
                    )
                    .map_err(pool_err)?
                }
            };
            // the next level's push cost; claimed vertices leave `mu`
            let next_edges: u64 = next.iter().map(|&v| env.g.out_degree(v) as u64).sum();
            mu = mu.saturating_sub(next_edges);
            mf = next_edges;
            by_level.push(frontier);
            frontier = next;
            depth += 1;
        }
        // forward sweep over the discovered buckets
        for bucket in &by_level {
            env.check_cancel()?;
            sweep(env, Domain::List(bucket), reg, None, body, frame_size, Some(&levels))?;
        }
        // reverse sweep: walk the level buckets backwards
        if let Some((cond, rbody)) = reverse {
            for bucket in by_level.iter().rev() {
                env.check_cancel()?;
                sweep(
                    env,
                    Domain::List(bucket),
                    reg,
                    Some(cond),
                    rbody,
                    frame_size,
                    Some(&levels),
                )?;
            }
        }
        Ok(())
    }

    fn exec_fixed_point(
        &mut self,
        var: u32,
        flag: u32,
        body: &[HostStmt],
        frontier: Option<FrontierInfo>,
    ) -> Result<()> {
        self.env.set_scalar(var, Val::B(false));
        let max_iters = 4 * self.env.g.num_nodes() + 16;
        if let Some(fi) = frontier {
            // The sparse schedule assumes the ping-pong buffer starts clear
            // (the compiler proved the kernel only sets bits reachable from
            // the frontier). A program that pre-seeds `nxt` before the loop
            // gets the dense schedule instead, as does an execution with the
            // frontier engine switched off (ExecOpts / STARPLAT_FRONTIER=0).
            if self.env.frontier_enabled && !self.env.prop(fi.nxt).any_true() {
                // delta-stepping: a weighted canonical relaxation may run
                // the bucketed priority schedule instead of round-based
                // sweeps (opt-in via STARPLAT_DELTA / ExecOpts::delta;
                // ineligible or negative-weight programs fall through)
                if let Some(r) = fi.relax {
                    if self.try_delta(var, fi, r)?.is_some() {
                        return Ok(());
                    }
                }
                let HostStmt::Kernel(k) = &body[0] else {
                    bail!("internal: frontier plan without a leading kernel")
                };
                match self.frontier_loop(var, fi, k, max_iters)? {
                    FrontierExit::Converged => return Ok(()),
                    // a sweep fault abandoned the sparse schedule at an
                    // iteration boundary; the dense loop below continues
                    // from the same flag/nxt state
                    FrontierExit::FellBack => {}
                }
            }
        }
        for _ in 0..max_iters {
            self.block(body)?;
            if self.ret.is_some() {
                return Ok(());
            }
            // finished when no vertex has the flag set (logical-OR flag)
            if !self.env.prop(flag).any_true() {
                self.env.set_scalar(var, Val::B(true));
                return Ok(());
            }
        }
        bail!("fixedPoint did not converge after {max_iters} iterations")
    }

    /// Delta-stepping execution of a weighted canonical relaxation: bucketed
    /// priority worklists keyed by `dist / Δ`, light edges (weight ≤ Δ)
    /// relaxed to a fixpoint inside the current bucket before heavy edges
    /// (weight > Δ) are relaxed once from the settled distances. Correctness
    /// does not hinge on the bucket order — every successful improvement
    /// re-enqueues its vertex, and the loop drains until no bucket is left —
    /// so the order is purely a work-efficiency heuristic, exactly like the
    /// push/pull choice. Entries are lazily invalidated: a vertex whose
    /// distance migrated to a lower bucket is skipped when its stale entry
    /// surfaces.
    ///
    /// Returns `Ok(None)` when the schedule does not apply (delta mode off,
    /// a negative edge weight at run time, or uninitialized properties) —
    /// the caller then runs the frontier/dense schedule unchanged.
    fn try_delta(&self, var: u32, fi: FrontierInfo, r: RelaxInfo) -> Result<Option<()>> {
        let Some(wslot) = r.weight else { return Ok(None) };
        let env = &self.env;
        if env.delta == DeltaMode::Off {
            return Ok(None);
        }
        let g = env.g;
        let n = g.num_nodes();
        let me = g.num_edges();
        let dist = env.prop(r.dist);
        let flag = env.prop(fi.flag);
        let weight = env.prop(wslot);
        if flag.len() != n || dist.len() != n || weight.len() != me {
            return Ok(None); // let the dense path surface the real error
        }
        // one O(m) scan resolves the non-negativity precondition, the
        // degree-based default width Δ = max(1, avg_weight / avg_degree),
        // and the max weight that bounds the bucket ring's window
        let mut total: i64 = 0;
        let mut minw = i64::MAX;
        let mut maxw: i64 = 0;
        for e in 0..me {
            let w = ival(weight.load(e));
            total = total.saturating_add(w);
            minw = minw.min(w);
            maxw = maxw.max(w);
        }
        if me > 0 && minw < 0 {
            return Ok(None); // delta-stepping requires non-negative weights
        }
        let width = match env.delta {
            DeltaMode::Width(d) => d.max(1),
            _ => {
                let avg_w = total / me.max(1) as i64;
                let avg_deg = (me / n.max(1)).max(1) as i64;
                (avg_w / avg_deg).max(1)
            }
        };
        // seed the buckets from the flagged vertices and clear their flags:
        // the bucketed run replaces the whole ping-pong loop, so it must
        // exit in the converged dense state (both flag arrays all-false).
        // Relaxations from bucket `bi` land in [bi, bi + maxw/Δ + 1] (light
        // wins stay < (bi+1)Δ, heavy wins add ≤ maxw), so an indexed ring
        // of maxw/Δ + 2 slots replaces the old ordered-map bucket store —
        // O(1) slot addressing instead of a tree walk per insert. The cap
        // plus arbitrary seed distances go through the overflow list, which
        // rebases into the window when it drains.
        let bucket_of = |v: Node| ival(dist.load(v as usize)) / width;
        let mut ring = BucketRing::new(((maxw / width) + 2).clamp(2, 4096) as usize);
        let mut seeded = false;
        for v in 0..n {
            if flag.load_bool(v) {
                let node = v as Node;
                if !seeded {
                    ring.base = bucket_of(node);
                    seeded = true;
                }
                ring.insert(bucket_of(node), node);
                flag.store(v, Val::B(false));
            }
        }
        env.delta_used.store(true, std::sync::atomic::Ordering::Relaxed);
        // relax one vertex's light or heavy out-edges from its current
        // distance; every winning Min emits the relaxed head for re-bucketing
        let relax_edges = |v: Node, light: bool, out: &mut Vec<Node>| {
            let dv = ival(dist.load(v as usize));
            for e in g.edge_range(v) {
                let we = ival(weight.load(e));
                if (we <= width) != light {
                    continue;
                }
                let u = g.adj[e] as usize;
                let cand = Val::I(dv.saturating_add(we));
                if dist.atomic_min_max(u, cand, crate::dsl::ast::MinMax::Min) {
                    out.push(u as Node);
                }
            }
        };
        let run_phase = |list: &[Node], light: bool| -> Result<Vec<Node>> {
            if env.threads > 1 && list.len() >= env.frontier_par_min {
                crate::util::pool::try_parallel_collect_in(
                    list.len(),
                    env.threads,
                    64,
                    env.cancel.as_ref(),
                    &env.buf_arena,
                    |i, out| relax_edges(list[i], light, out),
                )
                .map_err(pool_err)
            } else {
                let mut out = Vec::new();
                for &v in list {
                    relax_edges(v, light, &mut out);
                }
                Ok(out)
            }
        };
        while let Some(bi) = ring.next(&bucket_of) {
            env.check_cancel()?; // bucket boundary = cancellation point
            let mut settled: Vec<Node> = Vec::new();
            // light phase: drain bucket `bi` to a fixpoint (light-edge wins
            // can land back in it)
            loop {
                let bucket = ring.take(bi);
                if bucket.is_empty() {
                    break;
                }
                let fresh: Vec<Node> = bucket.into_iter().filter(|&v| bucket_of(v) == bi).collect();
                let improved = run_phase(&fresh, true)?;
                settled.extend_from_slice(&fresh);
                for &u in &improved {
                    ring.insert(bucket_of(u), u);
                }
            }
            // heavy phase: one pass from the settled distances
            let improved = run_phase(&settled, false)?;
            for &u in &improved {
                ring.insert(bucket_of(u), u);
            }
        }
        env.scalar_store(var, Val::B(true))?;
        Ok(Some(()))
    }

    /// Sparse-worklist execution of a frontier-eligible fixedPoint: process
    /// only flagged vertices, gather the next worklist from the updated
    /// neighborhoods (the compiler proved all flag-nxt writes land on the
    /// element, its out-neighbors, and/or its in-neighbors — `fi.gather_*`),
    /// and fall back to dense filtered sweeps while the frontier is > |V|/4.
    ///
    /// The post-sweep gather runs on the pool once the frontier is large
    /// enough ([`frontier_par_min`]): workers claim newly-flagged vertices
    /// into per-worker buffers via an exclusive atomic swap
    /// ([`PropData::claim_true`]) and the buffers concatenate by prefix
    /// offsets ([`crate::util::pool::try_parallel_collect_in`], buffers
    /// recycled through the run's arena) — this was a sequential scan that
    /// bottlenecked past ~10M vertices.
    fn frontier_loop(
        &self,
        var: u32,
        fi: FrontierInfo,
        k: &CKernel,
        max_iters: usize,
    ) -> Result<FrontierExit> {
        let env = &self.env;
        let n = env.g.num_nodes();
        let flag = env.prop(fi.flag);
        let nxt = env.prop(fi.nxt);
        if flag.len() != n || nxt.len() != n {
            bail!("fixedPoint flag properties are not initialized");
        }
        let mut frontier: Vec<Node> =
            (0..n as Node).filter(|&v| flag.load_bool(v as usize)).collect();
        // reused across iterations on the sequential gather paths (mesh
        // graphs run hundreds of small-frontier rounds: no per-round alloc)
        let mut next: Vec<Node> = Vec::new();
        // claim a vertex whose nxt bit the kernel set: the swap is exclusive,
        // so concurrent workers scanning overlapping neighborhoods emit each
        // vertex into exactly one claim buffer
        let claim = |w: Node, out: &mut Vec<Node>| {
            if nxt.claim_true(w as usize) {
                flag.store(w as usize, Val::B(true));
                out.push(w);
            }
        };
        // scan one frontier vertex's written neighborhoods
        let claim_around = |v: Node, out: &mut Vec<Node>| {
            claim(v, out);
            if fi.gather_out {
                for &w in env.g.neighbors(v) {
                    claim(w, out);
                }
            }
            if fi.gather_in {
                // pull kernels write in-neighbors: walk rev_offsets/srcList
                for &w in env.g.in_neighbors(v) {
                    claim(w, out);
                }
            }
        };
        // Direction-optimizing rounds: the canonical relaxation shape
        // (fi.relax) admits a pull round — a dense scan where every vertex
        // reads its *in*-edges over rev_offsets/srcList and min-reduces over
        // flagged in-neighbors, writing only its own distance (no atomics,
        // no ping-pong traffic). Chosen when the frontier's out-edge volume
        // `mf` reaches the total edge count (mf·4 ≥ m), with a ×2 hysteresis
        // margin so borderline rounds don't flap; `STARPLAT_DIRECTION` /
        // ExecOpts force push or pull outright.
        let m = env.g.num_edges() as u64;
        let mut pulling = false;
        for iter in 0..max_iters {
            env.check_cancel()?; // iteration boundary = cancellation point
            if frontier.is_empty() {
                // dense-equivalent exit state: both flag arrays all-false
                env.scalar_store(var, Val::B(true))?;
                return Ok(FrontierExit::Converged);
            }
            let want_pull = fi.relax.is_some()
                && match env.direction {
                    Direction::Push => false,
                    Direction::Pull => true,
                    Direction::Auto => {
                        let mf: u64 =
                            frontier.iter().map(|&v| env.g.out_degree(v) as u64).sum();
                        // hysteresis: leaving pull needs the estimate to
                        // drop twice as far as entering it required
                        if pulling {
                            mf * 8 >= m
                        } else {
                            mf * 4 >= m
                        }
                    }
                };
            if want_pull != pulling {
                env.note_direction_switch();
                pulling = want_pull;
            }
            if pulling {
                // the injected-fault hook sits before any flag mutation, so
                // the dense schedule resumes from a consistent boundary
                if env.fault.is_some_and(|fp| fp.fires(FaultSite::ClaimGather, iter as u64)) {
                    env.note_fallback();
                    return Ok(FrontierExit::FellBack);
                }
                env.note_pull_round();
                env.buf_arena.put(std::mem::take(&mut next));
                next = pull_round(env, fi.relax.unwrap(), flag)?;
                // emulate the round's flag hand-over: clear the old
                // frontier, then flag the improved vertices (a vertex can be
                // in both sets, so the clear fully precedes the sets)
                for &v in &frontier {
                    flag.store(v as usize, Val::B(false));
                }
                for &v in &next {
                    flag.store(v as usize, Val::B(true));
                }
                std::mem::swap(&mut frontier, &mut next);
                continue;
            }
            let dense = frontier.len() * 4 >= n;
            let swept = if dense {
                sweep(env, Domain::Range(n), k.reg, k.filter.as_ref(), &k.body, k.frame_size, None)
            } else {
                // every frontier vertex passes the flag filter by
                // construction — skip evaluating it
                sweep(env, Domain::List(&frontier), k.reg, None, &k.body, k.frame_size, None)
            };
            if let Err(e) = swept {
                if is_interrupt(&e) {
                    return Err(e);
                }
                // graceful degradation: a failed sweep (injected panic, real
                // kernel error) abandons the sparse schedule. Frontier-
                // eligible kernels are idempotent relaxations, so the dense
                // loop may safely re-run this iteration from the current
                // flag/nxt state; a persistent error surfaces again there.
                env.note_fallback();
                return Ok(FrontierExit::FellBack);
            }
            // injected fault point at the claim-buffer gather: trips before
            // any flag mutation below, so the dense schedule takes over from
            // a consistent iteration boundary (frontier flags set, nxt
            // holding exactly the kernel's writes)
            if env.fault.is_some_and(|fp| fp.fires(FaultSite::ClaimGather, iter as u64)) {
                env.note_fallback();
                return Ok(FrontierExit::FellBack);
            }
            // emulate `flag = nxt; attach(nxt = False);` sparsely: clear the
            // old frontier's flags, then claim the newly-flagged vertices.
            // The clear must fully precede the claims (a vertex may be in
            // both sets), so these are two pool passes, not one.
            let parallel = env.threads > 1 && frontier.len() >= env.frontier_par_min;
            if parallel {
                let fr = &frontier;
                crate::util::pool::parallel_for(fr.len(), env.threads, |i| {
                    flag.store(fr[i] as usize, Val::B(false));
                });
            } else {
                for &v in &frontier {
                    flag.store(v as usize, Val::B(false));
                }
            }
            // NOTE: a gather interrupt must PROPAGATE, never fall back — a
            // partially-run gather has already consumed nxt bits (the claim
            // swap clears them as it sets flags), so continuing densely from
            // that state would drop the claimed vertices.
            if dense {
                if env.threads > 1 && n >= env.frontier_par_min {
                    env.buf_arena.put(std::mem::take(&mut next));
                    next = crate::util::pool::try_parallel_collect_in(
                        n,
                        env.threads,
                        1024,
                        env.cancel.as_ref(),
                        &env.buf_arena,
                        |i, out| claim(i as Node, out),
                    )
                    .map_err(pool_err)?;
                } else {
                    next.clear();
                    for v in 0..n as Node {
                        claim(v, &mut next);
                    }
                }
            } else if parallel {
                let fr = &frontier;
                env.buf_arena.put(std::mem::take(&mut next));
                next = crate::util::pool::try_parallel_collect_in(
                    fr.len(),
                    env.threads,
                    64,
                    env.cancel.as_ref(),
                    &env.buf_arena,
                    |i, out| claim_around(fr[i], out),
                )
                .map_err(pool_err)?;
            } else {
                next.clear();
                for &v in &frontier {
                    claim_around(v, &mut next);
                }
            }
            std::mem::swap(&mut frontier, &mut next);
        }
        bail!("fixedPoint did not converge after {max_iters} iterations")
    }
}

/// Indexed circular bucket store for the delta-stepping drain: a window of
/// consecutive bucket indices maps onto a fixed slot ring (`O(1)` insert,
/// no ordered-map walk), and everything outside the window parks in an
/// overflow list that rebases when the window drains. Bucket order is a
/// work-efficiency heuristic only (see [`Exec::try_delta`]), so overflow
/// rebasing — which recomputes buckets from *current* distances — never
/// affects the fixpoint, just how much stale work gets filtered.
struct BucketRing {
    /// lowest bucket index the window currently covers; slides forward as
    /// buckets drain
    base: i64,
    /// `slots[bi.rem_euclid(len)]` holds bucket `bi` for
    /// `bi ∈ [base, base + len)`
    slots: Vec<Vec<Node>>,
    /// entries whose bucket fell outside the window at insert time
    overflow: Vec<Node>,
}

impl BucketRing {
    fn new(window: usize) -> BucketRing {
        BucketRing { base: 0, slots: (0..window).map(|_| Vec::new()).collect(), overflow: Vec::new() }
    }

    fn idx(&self, bi: i64) -> usize {
        bi.rem_euclid(self.slots.len() as i64) as usize
    }

    fn insert(&mut self, bi: i64, v: Node) {
        if bi >= self.base && bi < self.base + self.slots.len() as i64 {
            let i = self.idx(bi);
            self.slots[i].push(v);
        } else {
            self.overflow.push(v);
        }
    }

    /// Drain bucket `bi`'s slot (valid while `bi` is in the window).
    fn take(&mut self, bi: i64) -> Vec<Node> {
        let i = self.idx(bi);
        std::mem::take(&mut self.slots[i])
    }

    /// The next non-empty bucket at or above `base`, sliding the window to
    /// it. When the window is dry, the overflow rebases in (re-bucketed by
    /// `bucket_of` from current distances) and the scan repeats; `None`
    /// means the whole drain is complete.
    fn next(&mut self, bucket_of: impl Fn(Node) -> i64) -> Option<i64> {
        loop {
            let nb = self.slots.len() as i64;
            if let Some(bi) = (self.base..self.base + nb).find(|&bi| {
                let i = self.idx(bi);
                !self.slots[i].is_empty()
            }) {
                self.base = bi;
                return Some(bi);
            }
            if self.overflow.is_empty() {
                return None;
            }
            let pending = std::mem::take(&mut self.overflow);
            self.base = pending.iter().map(|&v| bucket_of(v)).min().expect("pending not empty");
            for v in pending {
                self.insert(bucket_of(v), v);
            }
        }
    }
}

/// Integer view of a runtime value (the relax/delta paths only ever touch
/// properties the compiler proved integer-typed).
#[inline]
fn ival(v: Val) -> i64 {
    match v {
        Val::I(x) => x,
        Val::F(x) => x as i64,
        Val::B(b) => b as i64,
    }
}

/// One pull (bottom-up) round of a canonical relaxation: every vertex scans
/// its in-edges over `rev_offsets/srcList/rev_edge_id`, min-reduces
/// `dist[u] (+ weight)` over *flagged* in-neighbors `u`, and — having sole
/// ownership of its own slot this round — commits any improvement with a
/// plain store. Returns the improved vertices: the next frontier. The
/// caller swaps the flag sets afterwards, so this round reads a stable
/// frontier snapshot.
fn pull_round(env: &Env<'_>, r: RelaxInfo, flag: &PropData) -> Result<Vec<Node>> {
    let g = env.g;
    let n = g.num_nodes();
    let dist = env.prop(r.dist);
    let weight = r.weight.map(|w| env.prop(w));
    let scan = |v: usize, out: &mut Vec<Node>| {
        let cur = ival(dist.load(v));
        let mut best = cur;
        for i in g.rev_offsets[v] as usize..g.rev_offsets[v + 1] as usize {
            let u = g.rev_adj[i] as usize;
            if !flag.load_bool(u) {
                continue;
            }
            let mut cand = ival(dist.load(u));
            if let Some(w) = weight {
                // rev_edge_id maps the reverse slot to its forward edge —
                // the id the push kernel's `get_edge` would have seen
                cand = cand.saturating_add(ival(w.load(g.rev_edge_id[i] as usize)));
            }
            best = best.min(cand);
        }
        if best < cur {
            dist.store(v, Val::I(best));
            out.push(v as Node);
        }
    };
    if env.threads > 1 && n >= env.frontier_par_min {
        crate::util::pool::try_parallel_collect_in(
            n,
            env.threads,
            1024,
            env.cancel.as_ref(),
            &env.buf_arena,
            scan,
        )
        .map_err(pool_err)
    } else {
        let mut out = Vec::new();
        for v in 0..n {
            scan(v, &mut out);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Device execution
// ---------------------------------------------------------------------------

/// Iteration domain of one kernel launch.
#[derive(Clone, Copy)]
enum Domain<'a> {
    Range(usize),
    List(&'a [Node]),
}

impl Domain<'_> {
    #[inline]
    fn len(&self) -> usize {
        match self {
            Domain::Range(n) => *n,
            Domain::List(l) => l.len(),
        }
    }
    #[inline]
    fn get(&self, i: usize) -> Node {
        match self {
            Domain::Range(_) => i as Node,
            Domain::List(l) => l[i],
        }
    }
}

/// Run a kernel body over `domain`, one element per worker-claimed index.
/// Each worker takes one register frame from the run's arena up front
/// (zeroed, resized to this kernel's frame size) and reuses it for every
/// element it processes; the frames return to the arena afterwards, so
/// repeated sweeps — a fixedPoint running hundreds of rounds — allocate
/// nothing on the per-vertex path.
fn sweep(
    env: &Env<'_>,
    domain: Domain<'_>,
    reg: u32,
    filter: Option<&CExpr>,
    body: &[DevStmt],
    frame_size: usize,
    levels: Option<&Levels>,
) -> Result<()> {
    let err = std::sync::Mutex::new(None::<anyhow::Error>);
    let failed = std::sync::atomic::AtomicBool::new(false);
    let frame_len = frame_size.max(1);
    let outcome = crate::util::pool::try_parallel_for_dynamic_scoped(
        domain.len(),
        env.threads,
        64,
        env.cancel.as_ref(),
        || {
            // recycled frames carry a previous sweep's values: clear before
            // resize so every slot starts zeroed, exactly like a fresh alloc
            let mut frame = env.frame_arena.take().unwrap_or_default();
            frame.clear();
            frame.resize(frame_len, Val::I(0));
            frame
        },
        |frame, i| {
            // once any element errors, skip the rest of the sweep
            if failed.load(std::sync::atomic::Ordering::Relaxed) {
                return;
            }
            let v = domain.get(i);
            // injected fault point inside the worker: the catch_unwind wall
            // at the pool boundary turns this into ExecError::WorkerPanic
            if let Some(fp) = &env.fault {
                if fp.fires(FaultSite::PoolDispatch, v as u64) {
                    panic!("injected fault: pool_dispatch at element {v}");
                }
            }
            let r = (|| -> Result<()> {
                let mut ctx = EvalCtx { env, current_edge: NO_EDGE, levels };
                frame[reg as usize] = Val::I(v as i64);
                if let Some(f) = filter {
                    if !eval(f, &ctx, frame)?.as_b()? {
                        return Ok(());
                    }
                }
                for s in body {
                    exec_dev(env, s, &mut ctx, frame)?;
                }
                Ok(())
            })();
            if let Err(e) = r {
                failed.store(true, std::sync::atomic::Ordering::Relaxed);
                let mut slot = err.lock().unwrap();
                // keep the first error, not the last
                if slot.is_none() {
                    *slot = Some(e);
                }
            }
        },
    );
    match outcome {
        Ok(frames) => {
            for f in frames {
                env.frame_arena.put(f);
            }
        }
        Err(i) => return Err(pool_err(i)),
    }
    match err.into_inner().unwrap() {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Deterministic fault point: a typed error when the run's plan fires for
/// this `(site, key)`.
#[inline]
fn fault_check(env: &Env<'_>, site: FaultSite, key: u64) -> Result<()> {
    if let Some(fp) = &env.fault {
        if fp.fires(site, key) {
            return Err(anyhow::Error::new(ExecError::Fault(site.name())));
        }
    }
    Ok(())
}

/// Execute one device statement for the current element. All shared mutation
/// is atomic; locals live in the worker's register `frame`.
fn exec_dev(
    env: &Env<'_>,
    s: &DevStmt,
    ctx: &mut EvalCtx<'_, '_>,
    frame: &mut [Val],
) -> Result<()> {
    match s {
        DevStmt::SetReg { reg, coerce, value } => {
            let mut v = eval(value, ctx, frame)?;
            if let Some(st) = coerce {
                v = coerce_st(v, *st)?;
            }
            frame[*reg as usize] = v;
            Ok(())
        }
        DevStmt::RegReduce { reg, op, value } => {
            let rhs = eval(value, ctx, frame)?;
            let cur = frame[*reg as usize];
            frame[*reg as usize] = apply_reduce(*op, cur, rhs)?;
            Ok(())
        }
        DevStmt::ScalarStore { slot, value } => {
            let v = eval(value, ctx, frame)?;
            env.scalar_store(*slot, v)
        }
        DevStmt::ScalarReduce { slot, op, value } => {
            fault_check(env, FaultSite::AtomicReduce, *slot as u64)?;
            let v = eval(value, ctx, frame)?;
            env.scalar_reduce(*slot, *op, v)
        }
        DevStmt::PropStore { prop, idx, value } => {
            let i = node_of(*idx, ctx, frame)? as usize;
            let v = eval(value, ctx, frame)?;
            env.prop(*prop).store(i, v);
            Ok(())
        }
        DevStmt::PropReduce { prop, idx, op, value } => {
            let i = node_of(*idx, ctx, frame)? as usize;
            fault_check(env, FaultSite::AtomicReduce, i as u64)?;
            let v = eval(value, ctx, frame)?;
            env.prop(*prop).atomic_reduce(i, *op, v)
        }
        DevStmt::MinMax { kind, prop, idx, compare, extra } => {
            let i = node_of(*idx, ctx, frame)? as usize;
            // Min/Max constructs are atomic reduces too (paper Fig 1's
            // relaxation shape) — same injection site as Prop/ScalarReduce
            fault_check(env, FaultSite::AtomicReduce, i as u64)?;
            let proposed = eval(compare, ctx, frame)?;
            let improved = env.prop(*prop).atomic_min_max(i, proposed, *kind);
            if improved {
                for u in extra {
                    match u {
                        CUpdate::Prop { prop, idx, value } => {
                            let j = node_of(*idx, ctx, frame)? as usize;
                            let v = eval(value, ctx, frame)?;
                            env.prop(*prop).store(j, v);
                        }
                        CUpdate::Scalar { slot, value } => {
                            let v = eval(value, ctx, frame)?;
                            env.scalar_store(*slot, v)?;
                        }
                    }
                }
            }
            Ok(())
        }
        DevStmt::For { reg, source, filter, body } => {
            exec_dev_for(env, *reg, source, filter.as_ref(), body, ctx, frame)
        }
        DevStmt::If { cond, then, els } => {
            let branch = if eval(cond, ctx, frame)?.as_b()? { then } else { els };
            for st in branch {
                exec_dev(env, st, ctx, frame)?;
            }
            Ok(())
        }
    }
}

/// Nested loops run sequentially within the worker thread (same-kernel
/// folding, as the paper's generated code does). The loop element register
/// is rebound in place; no per-iteration state is allocated.
fn exec_dev_for(
    env: &Env<'_>,
    reg: u32,
    source: &DevIter,
    filter: Option<&CExpr>,
    body: &[DevStmt],
    ctx: &mut EvalCtx<'_, '_>,
    frame: &mut [Val],
) -> Result<()> {
    match source {
        DevIter::Neighbors { of, dag: false } => {
            let v = node_of(*of, ctx, frame)?;
            let base = env.g.offsets[v as usize] as usize;
            run_list(env, reg, filter, body, env.g.neighbors(v), Some(base), ctx, frame)
        }
        DevIter::Neighbors { of, dag: true } => {
            // BFS context: DAG children only
            let v = node_of(*of, ctx, frame)?;
            let levels =
                ctx.levels.ok_or_else(|| anyhow!("BFS-DAG iteration outside iterateInBFS"))?;
            let lv = levels.get(v as usize);
            if lv < 0 {
                // a vertex outside the BFS tree (sentinel -1) has no DAG
                // children; without this guard `-1 + 1` would claim the
                // level-0 source as its child
                return Ok(());
            }
            let saved_edge = ctx.current_edge;
            ctx.current_edge = NO_EDGE;
            for &w in env.g.neighbors(v) {
                if levels.get(w as usize) != lv + 1 {
                    continue;
                }
                frame[reg as usize] = Val::I(w as i64);
                if let Some(f) = filter {
                    if !eval(f, ctx, frame)?.as_b()? {
                        continue;
                    }
                }
                for st in body {
                    exec_dev(env, st, ctx, frame)?;
                }
            }
            ctx.current_edge = saved_edge;
            Ok(())
        }
        DevIter::InNeighbors { of } => {
            let v = node_of(*of, ctx, frame)?;
            run_list(env, reg, filter, body, env.g.in_neighbors(v), None, ctx, frame)
        }
        DevIter::AllNodes => {
            let n = env.g.num_nodes();
            for w in 0..n as Node {
                frame[reg as usize] = Val::I(w as i64);
                if let Some(f) = filter {
                    if !eval(f, ctx, frame)?.as_b()? {
                        continue;
                    }
                }
                for st in body {
                    exec_dev(env, st, ctx, frame)?;
                }
            }
            Ok(())
        }
        DevIter::Set(s) => run_list(env, reg, filter, body, env.set_items(*s), None, ctx, frame),
    }
}

/// Iterate a node list, rebinding the loop register in place. `edge_base`
/// supplies edge-id tracking for sorted neighbor iterations (the k-th
/// neighbor of `v` is the k-th out-edge of `v`).
#[allow(clippy::too_many_arguments)]
fn run_list(
    env: &Env<'_>,
    reg: u32,
    filter: Option<&CExpr>,
    body: &[DevStmt],
    list: &[Node],
    edge_base: Option<usize>,
    ctx: &mut EvalCtx<'_, '_>,
    frame: &mut [Val],
) -> Result<()> {
    let saved_edge = ctx.current_edge;
    for (k, &w) in list.iter().enumerate() {
        frame[reg as usize] = Val::I(w as i64);
        if let Some(base) = edge_base {
            ctx.current_edge = base + k;
        }
        if let Some(f) = filter {
            if !eval(f, ctx, frame)?.as_b()? {
                continue;
            }
        }
        for st in body {
            exec_dev(env, st, ctx, frame)?;
        }
    }
    ctx.current_edge = saved_edge;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frontier_par_min_env_is_read_once() {
        // the threshold is cached on first use: later environment changes
        // must not reach the hot loops (they read `env.frontier_par_min`,
        // resolved once per run; tests override via ExecOpts instead)
        let first = frontier_par_min();
        std::env::set_var("STARPLAT_FRONTIER_PAR_MIN", (first + 999).to_string());
        assert_eq!(frontier_par_min(), first, "STARPLAT_FRONTIER_PAR_MIN must be read once");
        std::env::remove_var("STARPLAT_FRONTIER_PAR_MIN");
        assert_eq!(frontier_par_min(), first);
    }

    #[test]
    fn schedule_knobs_default_off() {
        assert_eq!(Direction::default(), Direction::Auto);
        assert_eq!(DeltaMode::default(), DeltaMode::Off);
        let opts = ExecOpts::default();
        assert!(opts.direction.is_none() && opts.delta.is_none());
        assert!(opts.frontier_par_min.is_none());
    }
}
